#!/usr/bin/env bash
# Tier-1 verification (see ROADMAP.md): build + tests on the default
# feature set, plus fmt/clippy when the components are installed.
set -euo pipefail

cd "$(dirname "$0")/../rust"

cargo build --release
cargo test -q

# Concurrency stress suite again at release opt-level, with the libtest
# runner forced to run the stress tests in parallel with each other —
# more cross-test thread pressure than the default scheduling gives.
cargo test --release --test stress_concurrent -- --test-threads=8

if cargo fmt --version >/dev/null 2>&1; then
    # Advisory until the one-shot `cargo fmt` sweep lands (ROADMAP):
    # the pre-rustfmt tree is not fully clean, and reformatting it is
    # its own mechanical PR, not a rider on feature work.
    cargo fmt --check \
        || echo "tier1: WARNING — tree is not rustfmt-clean (advisory)"
else
    echo "tier1: rustfmt not installed, skipping format check"
fi

if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy -- -D warnings
else
    echo "tier1: cargo-clippy not installed, skipping lint step"
fi

# Benches must keep compiling at release opt-level (they are the perf
# acceptance artifacts for the sharded-server work).
cargo build --release --benches

echo "tier1: OK"
