#!/usr/bin/env bash
# Tier-1 verification (see ROADMAP.md): build + tests on the default
# feature set, plus the distributed multi-process suite and, when the
# components are installed, fmt/clippy.
set -euo pipefail

cd "$(dirname "$0")/../rust"

cargo build --release
cargo test -q

# House static analysis (mirrors the CI `lint` leg): float-ordering,
# wire-integer-cast, panic-path and lock-hierarchy disciplines over
# src/.  Violations without a `// lint:allow(rule): reason` pragma
# exit nonzero.  See docs/ARCHITECTURE.md, "Enforced invariants".
cargo run --release --bin mltuner_lint

# Concurrency stress suite again at release opt-level, with the libtest
# runner forced to run the stress tests in parallel with each other —
# more cross-test thread pressure than the default scheduling gives.
cargo test --release --test stress_concurrent -- --test-threads=8

# Distributed suite: spawns real `mltuner serve` shard-server processes
# on loopback ephemeral ports and checks (a) bit-exact parity with the
# single-process run under BOTH the JSON `line` framing and the
# negotiated `binary` data-plane codec, (b) the batched-read-plane
# bound — one MF training clock issues at most `shard servers x
# workers` data-plane read RPCs
# (`training_clock_issues_bounded_read_rpcs`), so read batching cannot
# silently regress, (c) the durable-checkpoint acceptance: a
# mid-episode checkpoint survives SIGKILLing every shard server and
# resumes bit-exact on a fresh cluster, (d) the full tuner and the
# `mltuner tune --ps-framing binary` CLI over the binary wire, and
# (e) the observability smoke: `mltuner top --json --once` against a
# live two-server cluster prints one well-formed schema-versioned
# stats frame per server with nonzero per-shard apply throughput, and
# (f) the multi-tenant leg: two concurrent `--session-name` tunes on
# one shared cluster each bit-exact with the solo reference
# (`two_concurrent_sessions_are_isolated_and_bit_exact`), a SIGKILLed
# tune client garbage-collected after its lease expires, and the
# `--session-rows-per-sec` fairness share holding a co-tenant's
# throughput against a saturating bulk writer
# (mirrors the CI `distributed` leg).
cargo test --release --test integration_distributed

# Checkpoint/restore plane: codec round-trips (NaN/Inf/-0 included),
# fail-closed corruption handling, scripted + full-tuner kill-and-resume
# (already part of `cargo test -q` above; re-run at release opt-level
# alongside the other release legs so optimizations cannot change the
# bit-exactness story).
cargo test --release --test integration_checkpoint

# Non-stationary workload scenarios (mirrors the CI `scenarios` leg):
# mid-run step drift collapses a fixed setting while the slope watchdog
# re-tunes and recovers >= 2x sooner; the coupled lr+momentum adaptive
# adversary stays far from the threshold in the same budget; a 6x load
# spike mid-tune breaks neither convergence nor determinism; all
# bit-reproducible per seed, kill-and-resume included (already part of
# `cargo test -q` above; re-run at release opt-level so optimizations
# cannot change the bit-exactness story).
cargo test --release --test integration_scenarios

# Module docs are load-bearing (docs/ARCHITECTURE.md links into them):
# rustdoc must stay warning-clean (mirrors the CI `docs` leg).
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

if cargo fmt --version >/dev/null 2>&1; then
    # Mandatory since the one-shot rustfmt sweep landed; the style is
    # pinned by rustfmt.toml at the repo root.
    cargo fmt --check
else
    echo "tier1: rustfmt not installed, skipping format check"
fi

if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy -- -D warnings
else
    echo "tier1: cargo-clippy not installed, skipping lint step"
fi

# Benches must keep compiling at release opt-level (they are the perf
# acceptance artifacts for the sharded-server work).
cargo build --release --benches

# Advisory ThreadSanitizer pass over the concurrency stress suite
# (mirrors the CI `tsan` leg).  Needs nightly with rust-src for
# -Zbuild-std; a TSan report is printed but never fails tier-1.
if command -v rustup >/dev/null 2>&1 \
    && rustup run nightly cargo --version >/dev/null 2>&1 \
    && rustup component list --toolchain nightly 2>/dev/null \
        | grep -q 'rust-src (installed)'; then
    host=$(rustc -vV | sed -n 's/^host: //p')
    RUSTFLAGS="-Zsanitizer=thread" \
        cargo +nightly test -Zbuild-std --target "$host" \
        --release --test stress_concurrent -- --test-threads=8 \
        || echo "tier1: TSan reported issues (advisory leg, not gating)"
else
    echo "tier1: nightly toolchain with rust-src not installed, skipping TSan leg"
fi

echo "tier1: OK"
