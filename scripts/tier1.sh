#!/usr/bin/env bash
# Tier-1 verification (see ROADMAP.md): build + tests on the default
# feature set, plus the distributed multi-process suite and, when the
# components are installed, fmt/clippy.
set -euo pipefail

cd "$(dirname "$0")/../rust"

cargo build --release
cargo test -q

# Concurrency stress suite again at release opt-level, with the libtest
# runner forced to run the stress tests in parallel with each other —
# more cross-test thread pressure than the default scheduling gives.
cargo test --release --test stress_concurrent -- --test-threads=8

# Distributed suite: spawns real `mltuner serve` shard-server processes
# on loopback ephemeral ports and checks (a) bit-exact parity with the
# single-process run and (b) the batched-read-plane bound — one MF
# training clock issues at most `shard servers x workers` data-plane
# read RPCs (`training_clock_issues_bounded_read_rpcs`), so read
# batching cannot silently regress (mirrors the CI `distributed` leg).
cargo test --release --test integration_distributed

if cargo fmt --version >/dev/null 2>&1; then
    # Mandatory since the one-shot rustfmt sweep landed; the style is
    # pinned by rustfmt.toml at the repo root.
    cargo fmt --check
else
    echo "tier1: rustfmt not installed, skipping format check"
fi

if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy -- -D warnings
else
    echo "tier1: cargo-clippy not installed, skipping lint step"
fi

# Benches must keep compiling at release opt-level (they are the perf
# acceptance artifacts for the sharded-server work).
cargo build --release --benches

echo "tier1: OK"
