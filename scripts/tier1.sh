#!/usr/bin/env bash
# Tier-1 verification (see ROADMAP.md): build + tests on the default
# feature set, plus clippy when the component is installed.
set -euo pipefail

cd "$(dirname "$0")/../rust"

cargo build --release
cargo test -q

if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy -- -D warnings
else
    echo "tier1: cargo-clippy not installed, skipping lint step"
fi

echo "tier1: OK"
