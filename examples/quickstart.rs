//! Quickstart: tune and train a benchmark in one call.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Uses the simulated AlexNet-on-Cifar10 profile (no artifacts needed)
//! so it finishes in seconds: MLtuner searches the 4-tunable space of
//! Table 3, trains, re-tunes on every accuracy plateau, and stops when
//! no better setting exists.

use mltuner::apps::sim::{SimProfile, SimSystem};
use mltuner::tuner::{MLtuner, TunerConfig};

fn main() -> anyhow::Result<()> {
    // 1. A training system: 8 simulated workers on the Cifar10 profile.
    let system = SimSystem::new(SimProfile::alexnet_cifar10(), 8, 42);

    // 2. MLtuner over the system's tunable space (learning rate,
    //    momentum, per-machine batch size, data staleness — Table 3).
    let mut cfg = TunerConfig::new(system.space.clone());
    cfg.seed = 42;
    cfg.max_epochs = 400;
    let space = cfg.space.clone();
    let mut tuner = MLtuner::new(system, cfg);

    // 3. Run: initial tuning -> train -> re-tune on plateau -> converge.
    let report = tuner.run()?;

    println!("converged:      {}", report.converged);
    println!("epochs:         {}", report.epochs);
    println!("final accuracy: {:.1}%", report.final_accuracy * 100.0);
    println!(
        "total time:     {:.0}s simulated ({} tunings, {:.0}% tuning overhead)",
        report.total_time,
        report.tunings.len(),
        100.0 * report.tuning_time / report.total_time
    );
    for (i, t) in report.tunings.iter().enumerate() {
        println!(
            "  tuning[{i}] {}: {} trials -> {}",
            if t.initial { "initial" } else { "re-tune" },
            t.trials,
            t.chosen
                .as_ref()
                .map(|s| s.describe(&space))
                .unwrap_or_else(|| "(model converged)".into())
        );
    }
    Ok(())
}
