//! End-to-end driver over the REAL three-layer stack.
//!
//! ```text
//! make artifacts && cargo run --release --example image_classification
//! ```
//!
//! Loads the JAX/Pallas AOT artifacts (`artifacts/*.hlo.txt`, built once
//! by python; python is NOT running now), spins up the data-parallel
//! training system over the branch-versioned parameter server, and lets
//! MLtuner drive the whole job: fork trial branches, measure
//! convergence speeds from real training losses, pick tunables, train,
//! re-tune on plateau.  Logs the loss curve and accuracy trajectory —
//! the run recorded in EXPERIMENTS.md.
//!
//! Flags: --model (alexnet_proxy|inception_proxy) --variant (xla|pallas)
//!        --workers N --seed N --train-examples N --max-epochs N

use mltuner::apps::dnn::{DnnConfig, DnnSystem};
use mltuner::optim::OptimizerKind;
use mltuner::runtime::Runtime;
use mltuner::tuner::{ConvergenceCriterion, MLtuner, TunerConfig};
use mltuner::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let model = args.get_or("model", "alexnet_proxy").to_string();
    let variant = args.get_or("variant", "xla").to_string();
    let workers = args.get_usize("workers", 4);
    let seed = args.get_u64("seed", 0);
    let train_examples = args.get_usize("train-examples", 8192);
    let max_epochs = args.get_u64("max-epochs", 60);

    let t0 = std::time::Instant::now();
    let runtime = Runtime::load(args.get_or("artifacts-dir", "artifacts"))?;
    let mm = runtime.model(&model)?;
    println!(
        "model {model} ({} params: {} -> {:?} -> {}), variant {variant}, {workers} workers",
        mm.num_params(),
        mm.input_dim,
        mm.hidden,
        mm.classes
    );
    let system = DnnSystem::new(
        DnnConfig {
            model: model.clone(),
            variant,
            num_workers: workers,
            seed,
            train_examples,
            val_examples: 1024,
            spread: 0.55,
        },
        runtime,
        OptimizerKind::Sgd,
    )?;
    let space = system.space().clone();

    let mut cfg = TunerConfig::new(space.clone());
    cfg.seed = seed;
    cfg.max_epochs = max_epochs;
    cfg.convergence = ConvergenceCriterion::AccuracyPlateau { epochs: 4 };
    cfg.max_trials_per_tuning = 24;
    let mut tuner = MLtuner::new(system, cfg);
    let report = tuner.run()?;

    println!(
        "\n=== end-to-end run (wall {:.1}s) ===",
        t0.elapsed().as_secs_f64()
    );
    println!("epochs:          {}", report.epochs);
    println!("converged:       {}", report.converged);
    println!("final accuracy:  {:.2}%", report.final_accuracy * 100.0);
    println!(
        "tuning overhead: {:.1}s of {:.1}s ({:.0}%)",
        report.tuning_time,
        report.total_time,
        100.0 * report.tuning_time / report.total_time.max(1e-9)
    );
    for (i, t) in report.tunings.iter().enumerate() {
        println!(
            "tuning[{i}] {}: {} trials, trial_time {:.2}s → {}",
            if t.initial { "initial" } else { "re-tune" },
            t.trials,
            t.trial_time,
            t.chosen
                .as_ref()
                .map(|s| s.describe(&space))
                .unwrap_or_else(|| "(model converged)".into())
        );
    }
    println!("\nloss curve (every ~20th clock):");
    for (i, (t, c, l)) in report.recorder.losses.iter().enumerate() {
        if i % 20 == 0 {
            println!("  t={t:8.2}s clock={c:5} loss={l:.4}");
        }
    }
    println!("\naccuracy trajectory:");
    for (t, e, a) in &report.recorder.accuracies {
        println!("  t={t:8.2}s epoch={e:3} accuracy={:.2}%", a * 100.0);
    }
    if let Some(path) = args.get("csv") {
        report.recorder.write_csv(std::fs::File::create(path)?)?;
        println!("wrote {path}");
    }
    Ok(())
}
