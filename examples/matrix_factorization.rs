//! Matrix-factorization workload (the paper's CPU app, §5.1): tune the
//! initial AdaRevision learning rate, then train to a loss threshold.
//!
//! ```text
//! cargo run --release --example matrix_factorization
//! ```
//!
//! Also sweeps a grid of fixed initial LRs to show the Fig. 7 effect:
//! many untuned settings converge an order of magnitude slower (or
//! never), while MLtuner's pick is near-optimal.

use mltuner::apps::mf::{MfConfig, MfSystem};
use mltuner::comm::BranchType;
use mltuner::training::TrainingSystem;
use mltuner::tuner::{ConvergenceCriterion, MLtuner, TunerConfig};
use mltuner::util::cli::Args;

fn fresh(seed: u64) -> MfSystem {
    MfSystem::new(MfConfig {
        users: 300,
        items: 200,
        rank: 16,
        n_ratings: 20_000,
        num_workers: 8,
        seed,
        ..Default::default()
    })
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let seed = args.get_u64("seed", 1);

    let sys = fresh(seed);
    let threshold = sys.default_threshold();
    println!("loss threshold: {threshold:.3e} (5% of initial)");

    // --- fixed-LR grid (the untuned baselines of Fig. 7) ---
    println!("\nfixed initial AdaRevision LR → passes to threshold (cap 400):");
    let grid = [1e-4, 1e-3, 1e-2, 1e-1, 0.5, 2.0, 8.0];
    let mut best_fixed = u64::MAX;
    for lr in grid {
        let mut sys = fresh(seed);
        let space = sys.space().clone();
        let setting = space.decode(&[space.specs[0].encode(lr)]);
        sys.fork_branch(0, 1, None, &setting, BranchType::Training)?;
        let mut passes = None;
        for c in 0..400u64 {
            let p = sys.schedule_branch(c, 1)?;
            if p.value.is_finite() && p.value <= threshold {
                passes = Some(c + 1);
                break;
            }
            if !p.value.is_finite() {
                break;
            }
        }
        match passes {
            Some(n) => {
                best_fixed = best_fixed.min(n);
                println!("  lr={lr:>7.0e}: {n} passes");
            }
            None => println!("  lr={lr:>7.0e}: not converged (diverged or >400)"),
        }
    }

    // --- MLtuner picks the initial LR automatically ---
    let sys = fresh(seed);
    let space = sys.space().clone();
    let mut cfg = TunerConfig::new(space.clone());
    cfg.convergence = ConvergenceCriterion::LossThreshold { value: threshold };
    cfg.retune = false; // MF protocol: single metric, no re-tuning
    cfg.seed = seed;
    cfg.max_epochs = 2000;
    let mut tuner = MLtuner::new(sys, cfg);
    let report = tuner.run()?;
    println!(
        "\nMLtuner: converged={} after {} passes (incl. tuning), lr={:.3e}",
        report.converged,
        report.epochs,
        report.final_setting.lr(&space),
    );
    println!(
        "best fixed-LR setting took {best_fixed} passes; MLtuner total {} passes",
        report.epochs
    );
    Ok(())
}
