//! §5.3: tuning the initial learning rate FOR adaptive LR algorithms.
//!
//! ```text
//! cargo run --release --example adaptive_lr
//! ```
//!
//! AdaGrad / RMSProp / Adam / AdaDelta / Nesterov / AdaRevision all
//! still require an initial LR, and a bad one costs accuracy (Fig. 6)
//! or an order of magnitude of time (Fig. 7).  This example sweeps a
//! fixed-LR grid per algorithm on the simulated Cifar10 profile, then
//! lets MLtuner pick the initial LR (tuning only that tunable, no
//! re-tuning — exactly the §5.3 protocol).

use mltuner::apps::sim::{SimProfile, SimSystem};
use mltuner::optim::OptimizerKind;
use mltuner::tunable::{TunableSpace, TunableSpec};
use mltuner::tuner::{ConvergenceCriterion, MLtuner, TunerConfig};

/// Run one fixed-LR training to convergence; return final accuracy.
fn fixed_run(kind: OptimizerKind, lr: f64, seed: u64) -> f64 {
    let space = TunableSpace::new(vec![TunableSpec::Log {
        name: "lr".into(),
        min: 1e-5,
        max: 1.0,
    }]);
    let sys = SimSystem::with_space(SimProfile::alexnet_cifar10(), space.clone(), 8, seed)
        .with_optimizer(kind);
    let mut cfg = TunerConfig::new(space.clone());
    cfg.initial_setting = Some(space.decode(&[space.specs[0].encode(lr)]));
    cfg.retune = false;
    cfg.convergence = ConvergenceCriterion::AccuracyPlateau { epochs: 10 };
    cfg.max_epochs = 250;
    cfg.seed = seed;
    MLtuner::new(sys, cfg).run().map(|r| r.final_accuracy).unwrap_or(0.0)
}

/// Let MLtuner pick the initial LR for the algorithm.
fn tuned_run(kind: OptimizerKind, seed: u64) -> (f64, f64) {
    let space = TunableSpace::new(vec![TunableSpec::Log {
        name: "lr".into(),
        min: 1e-5,
        max: 1.0,
    }]);
    let sys = SimSystem::with_space(SimProfile::alexnet_cifar10(), space.clone(), 8, seed)
        .with_optimizer(kind);
    let mut cfg = TunerConfig::new(space.clone());
    cfg.retune = false;
    cfg.convergence = ConvergenceCriterion::AccuracyPlateau { epochs: 10 };
    cfg.max_epochs = 250;
    cfg.seed = seed;
    let report = MLtuner::new(sys, cfg).run().unwrap();
    (report.final_setting.lr(&space), report.final_accuracy)
}

fn main() {
    let grid = [1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0];
    println!("converged accuracy by initial LR (fixed) vs MLtuner pick:\n");
    print!("{:<12}", "optimizer");
    for lr in grid {
        print!("{:>9.0e}", lr);
    }
    println!("{:>22}", "MLtuner (lr -> acc)");
    for kind in OptimizerKind::ADAPTIVE {
        print!("{:<12}", kind.name());
        for lr in grid {
            print!("{:>9.3}", fixed_run(kind, lr, 7));
        }
        let (lr, acc) = tuned_run(kind, 7);
        println!("{:>12.1e} -> {:.3}", lr, acc);
    }
    println!(
        "\nNote the Fig. 6 shape: only 1-2 grid settings per algorithm reach\n\
         the optimum, the best LR differs per algorithm, and MLtuner's pick\n\
         is within a couple points of the per-algorithm optimum."
    );
}
