//! §5.5 (Fig. 10): robustness to suboptimal initial settings.
//!
//! ```text
//! cargo run --release --example robustness_retune
//! ```
//!
//! Disables MLtuner's initial tuning stage and hard-codes deliberately
//! suboptimal initial tunables; re-tuning alone must still recover good
//! validation accuracy.

use mltuner::apps::sim::{SimProfile, SimSystem};
use mltuner::tuner::{MLtuner, TunerConfig};
use mltuner::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let profile = SimProfile::alexnet_cifar10();
    println!(
        "profile: {} (accuracy ceiling {:.2})\n",
        profile.name, profile.acc_max
    );

    // tuned baseline
    let sys = SimSystem::new(profile.clone(), 8, 99);
    let mut cfg = TunerConfig::new(sys.space.clone());
    cfg.seed = 99;
    cfg.max_epochs = 400;
    let tuned = MLtuner::new(sys, cfg).run()?;
    println!(
        "tuned initial setting : acc {:.3} in {:>8.0}s ({} tunings)",
        tuned.final_accuracy,
        tuned.total_time,
        tuned.tunings.len()
    );

    // randomly-picked suboptimal (but non-divergent) initial settings
    let mut rng = Rng::seed_from_u64(4);
    for i in 0..4 {
        let sys = SimSystem::new(profile.clone(), 8, 100 + i);
        let space = sys.space.clone();
        // lr in the "too small" half of the range, random momentum
        let u = vec![
            0.25 + 0.3 * rng.gen_f64(), // lr 10^-3.75 .. 10^-2.25
            rng.gen_f64() * 0.5,
            rng.gen_f64(),
            0.0,
        ];
        let setting = space.decode(&u);
        let mut cfg = TunerConfig::new(space.clone());
        cfg.initial_setting = Some(setting.clone());
        cfg.seed = 100 + i;
        cfg.max_epochs = 600;
        let report = MLtuner::new(sys, cfg).run()?;
        println!(
            "suboptimal start #{i}  : acc {:.3} in {:>8.0}s ({} re-tunings) [start {}]",
            report.final_accuracy,
            report.total_time,
            report.tunings.len(),
            setting.describe(&space),
        );
    }
    println!("\nAll starts converge to comparable accuracy via re-tuning (Fig. 10).");
    Ok(())
}
