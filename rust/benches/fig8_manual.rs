//! Fig. 8: MLtuner (tuning all four tunables) vs idealized manually
//! tuned settings from the literature.

use mltuner::figures::fig8;
use mltuner::util::bench::{table_header, table_row};

fn main() {
    let t0 = std::time::Instant::now();
    let rows = fig8(2).unwrap();
    table_header(
        "Fig 8 — MLtuner vs idealized manual settings",
        &["profile", "manual_acc", "manual_time", "mltuner_acc", "mltuner_time", "slowdown"],
    );
    for r in &rows {
        table_row(&[
            r.profile.into(),
            format!("{:.3}", r.manual_acc),
            format!("{:.0}s", r.manual_time),
            format!("{:.3}", r.mltuner_acc),
            format!("{:.0}s", r.mltuner_time),
            format!("{:.1}x", r.mltuner_time / r.manual_time.max(1e-9)),
        ]);
    }
    println!(
        "\npaper shape: accuracies match or exceed manual (Inception-BN/GoogLeNet\n\
         exceed); slowdown ~5x on the small benchmark, smaller on large ones."
    );
    println!("\n[bench wall time {:.1}s]", t0.elapsed().as_secs_f64());
}
