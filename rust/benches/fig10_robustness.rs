//! Fig. 10: MLtuner robustness to hard-coded suboptimal initial
//! settings (initial tuning disabled; re-tuning must recover).

use mltuner::figures::fig10;
use mltuner::util::bench::{table_header, table_row};

fn main() {
    let t0 = std::time::Instant::now();
    // deliberately suboptimal initial LRs (optimal effective ≈ 5e-2)
    let starts = [3e-4, 1e-3, 5e-3, 2e-2];
    let rows = fig10(&starts, 50).unwrap();
    table_header(
        "Fig 10 — suboptimal initial settings, re-tuning recovery",
        &["start_lr", "final_acc", "time", "tunings"],
    );
    for r in &rows {
        table_row(&[
            format!("{:.0e}", r.start_lr),
            format!("{:.3}", r.final_accuracy),
            format!("{:.0}s", r.total_time),
            r.retunings.to_string(),
        ]);
    }
    println!("\npaper shape: all starts converge to good accuracy via re-tuning");
    println!("\n[bench wall time {:.1}s]", t0.elapsed().as_secs_f64());
}
