//! Fig. 5: MLtuner consistency over multiple runs — per-benchmark
//! (time, accuracy) endpoints and their coefficients of variation.

use mltuner::figures::fig5;
use mltuner::util::bench::{table_header, table_row};

fn main() {
    let t0 = std::time::Instant::now();
    // paper: 10 runs for Cifar10, 3 each for the larger benchmarks
    let rows = fig5(10, 3).unwrap();
    table_header(
        "Fig 5 — multi-run consistency",
        &["profile", "runs", "acc_mean", "acc_cov", "time_cov"],
    );
    for r in &rows {
        let acc_mean =
            r.finals.iter().map(|f| f.1).sum::<f64>() / r.finals.len() as f64;
        table_row(&[
            r.profile.into(),
            r.finals.len().to_string(),
            format!("{acc_mean:.3}"),
            format!("{:.3}", r.acc_cov),
            format!("{:.3}", r.time_cov),
        ]);
        for (t, a) in &r.finals {
            println!("# run end: {t:.0}s acc {a:.3}");
        }
    }
    println!("\n[bench wall time {:.1}s]", t0.elapsed().as_secs_f64());
}
