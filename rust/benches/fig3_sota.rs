//! Fig. 3: MLtuner vs Spearmint vs Hyperband — runtime and achieved
//! validation accuracies on the large (Inception-BN/ILSVRC12 profile)
//! and small (AlexNet/Cifar10 profile) benchmarks.

use mltuner::apps::sim::SimProfile;
use mltuner::figures::fig3;
use mltuner::util::bench::{table_header, table_row};

fn run(profile: SimProfile, budget: f64, target_acc: f64) {
    let title = format!("Fig 3 — {} (budget {:.0}s)", profile.name, budget);
    table_header(&title, &[
        "arm",
        "best_acc",
        "time_to_target",
        "total_time",
        "configs",
    ]);
    let arms = fig3(profile, budget, 1).unwrap();
    for a in &arms {
        let t_target = a
            .curve
            .iter()
            .find(|&&(_, acc)| acc >= target_acc)
            .map(|&(t, _)| format!("{t:.0}s"))
            .unwrap_or_else(|| "never".into());
        table_row(&[
            a.name.into(),
            format!("{:.3}", a.best_accuracy),
            t_target,
            format!("{:.0}s", a.total_time),
            a.configs_tried.to_string(),
        ]);
    }
    // curves for plotting
    for a in &arms {
        println!("# curve {}", a.name);
        for (i, (t, acc)) in a.curve.iter().enumerate() {
            if i % (a.curve.len() / 20).max(1) == 0 {
                println!("{t:.0},{acc:.4}");
            }
        }
    }
}

fn main() {
    let t0 = std::time::Instant::now();
    // large benchmark: budget = 5 simulated days (the paper's cut-off)
    run(SimProfile::inception_bn(), 432_000.0, 0.60);
    // small benchmark: generous budget, everyone converges
    run(SimProfile::alexnet_cifar10(), 100_000.0, 0.70);
    println!("\n[bench wall time {:.1}s]", t0.elapsed().as_secs_f64());
}
