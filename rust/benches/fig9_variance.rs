//! Fig. 9: run-to-run variance of fixed-setting training (RMSProp with
//! the optimal initial LR) under shared vs distinct random seeds.

use mltuner::figures::fig9;
use mltuner::util::bench::{table_header, table_row};

fn main() {
    let t0 = std::time::Instant::now();
    let r = fig9(10).unwrap();
    table_header(
        "Fig 9 — convergence-time variance (10 runs each)",
        &["arm", "time_cov", "acc_cov"],
    );
    table_row(&[
        "same data seed".into(),
        format!("{:.3}", r.same_cov),
        format!("{:.3}", r.acc_cov),
    ]);
    table_row(&[
        "distinct seeds".into(),
        format!("{:.3}", r.distinct_cov),
        "—".into(),
    ]);
    println!(
        "# same-seed times: {:?}",
        r.same_seed_times.iter().map(|t| *t as u64).collect::<Vec<_>>()
    );
    println!(
        "# distinct-seed times: {:?}",
        r.distinct_seed_times.iter().map(|t| *t as u64).collect::<Vec<_>>()
    );
    println!("\npaper: CoV 0.16 / 0.18 for times, 0.01 for accuracies");
    println!("\n[bench wall time {:.1}s]", t0.elapsed().as_secs_f64());
}
