//! Fig. 7: matrix-factorization convergence time vs initial AdaRevision
//! learning rate (the real MF app, not the simulator), plus MLtuner.

use mltuner::figures::fig7;
use mltuner::util::bench::{table_header, table_row};

fn main() {
    let t0 = std::time::Instant::now();
    let grid: Vec<f64> = (0..13).map(|i| 10f64.powf(-4.0 + i as f64 * 0.5)).collect();
    let r = fig7(&grid, 1, 500).unwrap();
    table_header(
        "Fig 7 — MF passes-to-threshold vs initial AdaRevision LR",
        &["lr", "passes"],
    );
    let mut best = u64::MAX;
    let mut slow_or_never = 0;
    for (lr, p) in &r.grid {
        table_row(&[
            format!("{lr:.1e}"),
            p.map(|v| v.to_string())
                .unwrap_or_else(|| ">cap/diverged".into()),
        ]);
        if let Some(v) = p {
            best = best.min(*v);
        }
    }
    for (_, p) in &r.grid {
        match p {
            None => slow_or_never += 1,
            Some(v) if *v > best * 10 => slow_or_never += 1,
            _ => {}
        }
    }
    println!(
        "\nbest fixed: {best} passes; {}/{} settings >10x slower or never (paper: >40%)",
        slow_or_never,
        r.grid.len()
    );
    println!(
        "MLtuner: lr={:.2e}, {} passes total incl. tuning (threshold {:.3e})",
        r.mltuner_lr, r.mltuner_passes, r.threshold
    );
    // Scale note: this synthetic MF converges in ~{best} passes; the
    // paper's Netflix run needs hundreds, over which the same absolute
    // tuning cost amortizes to near-ideal (see EXPERIMENTS.md).
    let tuning_passes = r.mltuner_passes.saturating_sub(best);
    println!(
        "tuning cost {} passes; projected vs a Netflix-scale 600-pass ideal: {:.2}x",
        tuning_passes,
        (600 + tuning_passes) as f64 / 600.0
    );
    println!("\n[bench wall time {:.1}s]", t0.elapsed().as_secs_f64());
}
