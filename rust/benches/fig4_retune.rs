//! Fig. 4: MLtuner tuning/re-tuning behaviour on the four deep-learning
//! benchmarks — accuracy trajectory with shaded tuning spans.

use mltuner::figures::fig4;
use mltuner::util::bench::{table_header, table_row};

fn main() {
    let t0 = std::time::Instant::now();
    let runs = fig4(1).unwrap();
    for r in &runs {
        let title = format!(
            "Fig 4 — {} (final {:.3} in {:.0}s)",
            r.profile, r.final_accuracy, r.total_time
        );
        table_header(&title, &["kind", "start", "end"]);
        for (s, e, initial) in &r.tuning_spans {
            table_row(&[
                if *initial { "initial-tuning" } else { "re-tuning" }.into(),
                format!("{s:.0}s"),
                format!("{e:.0}s"),
            ]);
        }
        println!("# accuracy trajectory (time, epoch, acc)");
        for (i, (t, e, a)) in r.accuracies.iter().enumerate() {
            if i % (r.accuracies.len() / 25).max(1) == 0 {
                println!("{t:.0},{e},{a:.4}");
            }
        }
    }
    println!("\n[bench wall time {:.1}s]", t0.elapsed().as_secs_f64());
}
