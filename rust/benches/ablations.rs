//! Ablations of MLtuner's design choices (DESIGN.md §5):
//!
//! 1. **Noise-penalized convergence speed (§4.1)** vs the naive
//!    first/last-point slope: how often does each estimator rank the
//!    truly-better of two settings higher, from a short noisy trial?
//! 2. **Automatic trial time (Algorithm 1)** vs TuPAQ-style fixed
//!    trial lengths: chosen-setting quality and tuning cost.
//! 3. **Copy-on-write branch snapshots (§4.6)** vs eager deep-copy
//!    forks: fork latency across model sizes — COW must be flat in
//!    model bytes and ≥10× cheaper at DNN scale.

use mltuner::apps::sim::{SimProfile, SimSystem};
use mltuner::comm::BranchType;
use mltuner::ps::pool::MemoryPool;
use mltuner::ps::storage::{Entry, Shard};
use mltuner::summarizer::{ProgressPoint, ProgressSummarizer};
use mltuner::training::TrainingSystem;
use mltuner::tunable::TunableSetting;
use mltuner::tuner::{ConvergenceCriterion, MLtuner, TunerConfig};
use mltuner::util::bench::{bench, table_header, table_row};
use mltuner::util::rng::Rng;

/// Naive estimator the paper argues against: slope from the first and
/// last raw points, no downsampling, no noise penalty.
fn naive_slope(trace: &[ProgressPoint]) -> f64 {
    if trace.len() < 2 {
        return 0.0;
    }
    let (a, b) = (trace[0], trace[trace.len() - 1]);
    if b.t > a.t {
        ((a.x - b.x) / (b.t - a.t)).max(0.0)
    } else {
        0.0
    }
}

/// Downsampled slope WITHOUT the noise penalty (isolates the penalty's
/// contribution from the downsampling's).
fn unpenalized_speed(s: &ProgressSummarizer, trace: &[ProgressPoint]) -> f64 {
    let ds = s.downsample(trace);
    if ds.len() < 2 {
        return 0.0;
    }
    let (a, b) = (ds[0], ds[ds.len() - 1]);
    if b.t > a.t {
        ((a.x - b.x) / (b.t - a.t)).max(0.0)
    } else {
        0.0
    }
}

fn trial_trace(
    sys: &mut SimSystem,
    branch: u32,
    parent: u32,
    setting: &TunableSetting,
    clocks: u64,
) -> Vec<ProgressPoint> {
    sys.fork_branch(0, branch, Some(parent), setting, BranchType::Training)
        .unwrap();
    let mut t = 0.0;
    (0..clocks)
        .map(|c| {
            let p = sys.schedule_branch(c, branch).unwrap();
            t += p.time;
            ProgressPoint { t, x: p.value }
        })
        .collect()
}

fn ablate_summarizer() {
    let profile = SimProfile::alexnet_cifar10();
    let summarizer = ProgressSummarizer::default();
    let mut rng = Rng::seed_from_u64(42);
    table_header(
        "Ablation 1 — pairwise ranking accuracy of speed estimators",
        &["trial clocks", "paper (penalized)", "downsample only", "naive slope"],
    );
    for clocks in [15u64, 30, 60, 120] {
        let mut wins = [0usize; 3];
        let trials = 120;
        for i in 0..trials {
            let mut sys = SimSystem::new(profile.clone(), 8, 1000 + i);
            let space = sys.space.clone();
            // two random non-divergent candidate settings
            let mut pick = || {
                let u = vec![
                    0.2 + 0.5 * rng.gen_f64(), // lr below divergence
                    rng.gen_f64() * 0.5,
                    rng.gen_f64(),
                    0.0,
                ];
                space.decode(&u)
            };
            let (sa, sb) = (pick(), pick());
            let ta = trial_trace(&mut sys, 1, 0, &sa, clocks);
            let tb = trial_trace(&mut sys, 2, 0, &sb, clocks);
            // ground truth: true-loss drop over the same horizon
            let la = sys.branch_loss(1).unwrap();
            let lb = sys.branch_loss(2).unwrap();
            let a_better = la < lb;
            let verdicts = [
                summarizer.summarize(&ta).speed > summarizer.summarize(&tb).speed,
                unpenalized_speed(&summarizer, &ta) > unpenalized_speed(&summarizer, &tb),
                naive_slope(&ta) > naive_slope(&tb),
            ];
            for (w, v) in wins.iter_mut().zip(verdicts) {
                if v == a_better {
                    *w += 1;
                }
            }
        }
        table_row(&[
            clocks.to_string(),
            format!("{:.2}", wins[0] as f64 / trials as f64),
            format!("{:.2}", wins[1] as f64 / trials as f64),
            format!("{:.2}", wins[2] as f64 / trials as f64),
        ]);
    }
}

fn ablate_trial_time() {
    table_header(
        "Ablation 2 — Algorithm-1 auto trial time vs fixed trial lengths",
        &["policy", "final_acc", "total_time", "tuning_time"],
    );
    let profile = SimProfile::alexnet_cifar10();
    // paper: automatic doubling
    let sys = SimSystem::new(profile.clone(), 8, 9);
    let mut cfg = TunerConfig::new(sys.space.clone());
    cfg.seed = 9;
    cfg.max_epochs = 400;
    cfg.convergence = ConvergenceCriterion::AccuracyPlateau { epochs: 20 };
    let auto = MLtuner::new(sys, cfg).run().unwrap();
    table_row(&[
        "Algorithm 1 (auto)".into(),
        format!("{:.3}", auto.final_accuracy),
        format!("{:.0}s", auto.total_time),
        format!("{:.0}s", auto.tuning_time),
    ]);
    // TuPAQ-style: fixed trial length ≈ 10 clocks of the reference
    // batch size, emulated by capping the trial time very low (the
    // doubling never engages) — under-measures and picks noisy winners.
    for fixed_clocks in [10u64, 40] {
        let mut sys = SimSystem::new(profile.clone(), 8, 9);
        let space = sys.space.clone();
        // emulate: try 20 random settings for fixed_clocks each; pick
        // the best naive slope; train it to convergence.
        let mut rng = Rng::seed_from_u64(7);
        let mut best: Option<(TunableSetting, f64)> = None;
        let mut tuning_time = 0.0;
        let mut next_branch = 1u32;
        for _ in 0..20 {
            let u: Vec<f64> = (0..space.dim()).map(|_| rng.gen_f64()).collect();
            let setting = space.decode(&u);
            let b = next_branch;
            next_branch += 1;
            let trace = trial_trace(&mut sys, b, 0, &setting, fixed_clocks);
            tuning_time += trace.last().map(|p| p.t).unwrap_or(0.0);
            let speed = naive_slope(&trace);
            sys.free_branch(0, b).unwrap();
            if best.as_ref().map_or(true, |(_, s)| speed > *s) {
                best = Some((setting, speed));
            }
        }
        let (setting, _) = best.unwrap();
        // train the winner
        let b = next_branch;
        sys.fork_branch(0, b, Some(0), &setting, BranchType::Training)
            .unwrap();
        let mut now = tuning_time;
        let mut best_acc: f64 = 0.0;
        let mut since = 0;
        let mut tb = b + 1;
        for epoch in 0..400u64 {
            let clocks = sys.clocks_per_epoch(b).max(1);
            let mut dead = false;
            for c in 0..clocks {
                let p = sys.schedule_branch(epoch * 10_000 + c, b).unwrap();
                now += p.time;
                if !p.value.is_finite() {
                    dead = true;
                    break;
                }
            }
            sys.fork_branch(0, tb, Some(b), &setting, BranchType::Testing)
                .unwrap();
            let acc = sys.schedule_branch(0, tb).unwrap();
            now += acc.time;
            sys.free_branch(0, tb).unwrap();
            tb += 1;
            if acc.value > best_acc {
                best_acc = acc.value;
                since = 0;
            } else {
                since += 1;
            }
            if dead || since >= 20 {
                break;
            }
        }
        table_row(&[
            format!("fixed {fixed_clocks}-clock trials (TuPAQ-style)"),
            format!("{best_acc:.3}"),
            format!("{now:.0}s"),
            format!("{tuning_time:.0}s"),
        ]);
    }
    println!(
        "\nNo re-tuning in the fixed arms (TuPAQ tunes once) — the accuracy gap\n\
         shows what Algorithm 1 + re-tuning buy."
    );
}

fn ablate_fork_cost() {
    table_header(
        "Ablation 3 — branch fork latency: COW vs eager deep-copy",
        &["model (rows x row_len)", "cow mean", "eager mean", "eager/cow"],
    );
    // 26k params (alexnet_proxy) -> 8.4M params (large DNN); one
    // SGD velocity slot per row, like the real server under Sgd.
    for (rows, row_len) in [(8usize, 4096usize), (343, 4096), (2048, 4096)] {
        let build = || {
            let mut shard = Shard::default();
            for k in 0..rows {
                shard.insert(
                    0,
                    0,
                    k as u64,
                    Entry {
                        data: vec![0.5; row_len],
                        slots: vec![vec![0.0; row_len]],
                        step: 0,
                    },
                );
            }
            shard
        };
        let mut pool = MemoryPool::new();
        let mut shard = build();
        let mut next = 1u32;
        let cow = bench(
            &format!("cow fork+free ({rows}x{row_len})"),
            150.0,
            20_000,
            || {
                shard.fork(next, 0, &mut pool);
                shard.free(next, &mut pool);
                next += 1;
            },
        );
        let mut shard = build();
        let mut next = 1u32;
        let eager = bench(
            &format!("eager fork+free ({rows}x{row_len})"),
            250.0,
            5_000,
            || {
                shard.fork_eager(next, 0, &mut pool);
                shard.free(next, &mut pool);
                next += 1;
            },
        );
        table_row(&[
            format!("{rows}x{row_len}"),
            format!("{:.1}µs", cow.mean_ns / 1e3),
            format!("{:.1}µs", eager.mean_ns / 1e3),
            format!("{:.1}x", eager.mean_ns / cow.mean_ns.max(1.0)),
        ]);
    }
    println!(
        "\nCOW forks clone only the branch index (Arc bumps), so their cost\n\
         tracks #rows, not model bytes; eager forks copy every buffer."
    );
}

fn main() {
    let t0 = std::time::Instant::now();
    ablate_summarizer();
    ablate_trial_time();
    ablate_fork_cost();
    println!("\n[bench wall time {:.1}s]", t0.elapsed().as_secs_f64());
}
