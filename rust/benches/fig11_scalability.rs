//! Fig. 11: tuning-cost scalability with the number of tunables —
//! standard 4-tunable space vs the duplicated 4x2 space.

use mltuner::figures::fig11;
use mltuner::util::bench::{table_header, table_row};

fn main() {
    let t0 = std::time::Instant::now();
    let rows = fig11(&[1, 2, 3, 4, 5]).unwrap();
    table_header(
        "Fig 11 — scalability with more tunables",
        &["tunables", "final_acc", "total_time", "init_tuning_time", "init_trials"],
    );
    for r in &rows {
        table_row(&[
            r.tunables.to_string(),
            format!("{:.3}", r.final_accuracy),
            format!("{:.0}s", r.total_time),
            format!("{:.0}s", r.initial_tuning_time),
            r.trials.to_string(),
        ]);
    }
    if rows.len() == 2 {
        println!(
            "\ninitial-tuning-time ratio 8-vs-4 tunables: {:.2}x (paper: ~2x, same accuracy)",
            rows[1].initial_tuning_time / rows[0].initial_tuning_time.max(1e-9)
        );
    }
    println!("\n[bench wall time {:.1}s]", t0.elapsed().as_secs_f64());
}
