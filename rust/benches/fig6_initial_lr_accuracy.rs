//! Fig. 6: converged validation accuracy vs initial learning rate for
//! the six adaptive-LR algorithms, plus MLtuner's automatic pick.

use mltuner::figures::fig6;
use mltuner::util::bench::{table_header, table_row};

fn main() {
    let t0 = std::time::Instant::now();
    let grid = [1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0];
    let rows = fig6(&grid, 7).unwrap();
    let mut cols: Vec<String> = vec!["optimizer".into()];
    cols.extend(grid.iter().map(|g| format!("{g:.0e}")));
    cols.push("mltuner_lr".into());
    cols.push("mltuner_acc".into());
    let cols_ref: Vec<&str> = cols.iter().map(String::as_str).collect();
    table_header("Fig 6 — converged accuracy vs initial LR", &cols_ref);
    for r in &rows {
        let mut cells = vec![r.optimizer.name().to_string()];
        cells.extend(r.grid.iter().map(|(_, a)| format!("{a:.3}")));
        cells.push(format!("{:.1e}", r.mltuner_pick.0));
        cells.push(format!("{:.3}", r.mltuner_pick.1));
        table_row(&cells);
        // the paper's headline check: MLtuner within 2% of the optimum
        let best = r.grid.iter().map(|g| g.1).fold(0.0, f64::max);
        println!(
            "# {}: optimum {best:.3}, mltuner gap {:+.3}",
            r.optimizer.name(),
            r.mltuner_pick.1 - best
        );
    }
    println!("\n[bench wall time {:.1}s]", t0.elapsed().as_secs_f64());
}
