//! Micro-benchmarks of the L3 hot paths (§Perf): parameter-server
//! fork/free/update, batched vs looped updates, multi-threaded shard
//! update throughput, branch switch (cache clear), progress
//! summarizer, searcher proposals, and — when artifacts are present —
//! the PJRT gradient-step dispatch.

use std::time::Instant;

use mltuner::optim::{Hyper, Optimizer, OptimizerKind};
use mltuner::ps::cache::WorkerCache;
use mltuner::ps::ParamServer;
use mltuner::ps::pool::MemoryPool;
use mltuner::ps::storage::{Entry, RowKey, Shard, TableId};
use mltuner::runtime::Runtime;
use mltuner::searcher::{Proposal, SearcherKind};
use mltuner::summarizer::{ProgressPoint, ProgressSummarizer};
use mltuner::util::bench::{bench, black_box};
use mltuner::util::rng::Rng;

fn ps_with_model(rows: usize, row_len: usize) -> ParamServer {
    let ps = ParamServer::new(8, Optimizer::new(OptimizerKind::Sgd));
    for k in 0..rows {
        ps.insert_row(0, 0, k as u64, vec![0.5; row_len]);
    }
    ps
}

/// Aggregate update throughput with `threads` workers batch-updating
/// disjoint row slices of the 2048x4096 table (the acceptance table):
/// returns rows/sec.  Each worker pushes 64-row batches through
/// `apply_batch` — routed once, one lock acquisition per shard.
fn shard_update_throughput(threads: usize, passes: usize) -> (f64, u64) {
    const TABLE_ROWS: usize = 2048;
    let ps = ps_with_model(TABLE_ROWS, 4096);
    let grad = vec![0.01f32; 4096];
    let h = Hyper { lr: 0.01, momentum: 0.9 };
    let per_thread = TABLE_ROWS / threads * passes;
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for w in 0..threads {
            let ps = &ps;
            let grad = &grad;
            s.spawn(move || {
                // disjoint slice: rows with index % threads == w
                let keys: Vec<RowKey> = (0..TABLE_ROWS)
                    .filter(|k| k % threads == w)
                    .map(|k| k as RowKey)
                    .collect();
                let mut done = 0usize;
                let mut updates: Vec<(TableId, RowKey, &[f32])> =
                    Vec::with_capacity(64);
                let mut cursor = 0usize;
                while done < per_thread {
                    updates.clear();
                    for _ in 0..64 {
                        updates.push((0, keys[cursor % keys.len()], &grad[..]));
                        cursor += 1;
                        done += 1;
                    }
                    ps.apply_batch(0, &updates, h).unwrap();
                }
            });
        }
    });
    let secs = t0.elapsed().as_secs_f64();
    (
        (threads * per_thread) as f64 / secs.max(1e-12),
        ps.snapshot().server.shard_lock_contentions,
    )
}

/// Build a shard directly (exposes the eager-fork baseline the
/// `ParamServer` API no longer routes through).
fn shard_with_model(rows: usize, row_len: usize) -> Shard {
    let mut shard = Shard::default();
    for k in 0..rows {
        shard.insert(
            0,
            0,
            k as u64,
            Entry {
                data: vec![0.5; row_len],
                slots: vec![vec![0.0; row_len]],
                step: 0,
            },
        );
    }
    shard
}

fn main() {
    println!("== L3 micro hot paths ==");

    // Branch fork/free under copy-on-write: cost must be independent of
    // row length (model bytes) — only the index size (#rows) matters.
    // 8x4096 ≈ alexnet_proxy (26k params), 343x4096 ≈ inception_proxy
    // (1.4M params), 2048x4096 ≈ a 8.4M-param DNN.
    for (rows, label) in [(8usize, "8x4096"), (343, "343x4096"), (2048, "2048x4096")] {
        let ps = ps_with_model(rows, 4096);
        let mut next = 1u32;
        bench(
            &format!("ps fork+free COW ({label} rows)"),
            200.0,
            20_000,
            || {
                ps.fork_branch(next, 0).unwrap();
                ps.free_branch(next).unwrap();
                next += 1;
            },
        );
    }
    // Eager deep-copy baseline (the pre-COW fork), same sizes: O(model
    // bytes) per fork.  The COW/eager gap at the DNN sizes is the
    // tentpole speedup; record both in CHANGES.md.
    for (rows, label) in [(8usize, "8x4096"), (343, "343x4096"), (2048, "2048x4096")] {
        let mut shard = shard_with_model(rows, 4096);
        let mut pool = MemoryPool::new();
        let mut next = 1u32;
        bench(
            &format!("shard fork_eager+free ({label} rows, pooled)"),
            300.0,
            5_000,
            || {
                shard.fork_eager(next, 0, &mut pool);
                shard.free(next, &mut pool);
                next += 1;
            },
        );
    }
    // First write after a COW fork: the deferred per-row
    // materialization cost a trial pays only for rows it touches.
    {
        let ps = ps_with_model(343, 4096);
        let grad = vec![0.01f32; 4096];
        let h = Hyper { lr: 0.01, momentum: 0.9 };
        let mut next = 1u32;
        bench(
            "ps fork + first-write 1 row + free (COW materialize)",
            200.0,
            20_000,
            || {
                ps.fork_branch(next, 0).unwrap();
                ps.apply_update(next, 0, 0, &grad, h, None).unwrap();
                ps.free_branch(next).unwrap();
                next += 1;
            },
        );
    }
    // server-side update application
    {
        let ps = ps_with_model(343, 4096);
        let grad = vec![0.01f32; 4096];
        let h = Hyper { lr: 0.01, momentum: 0.9 };
        let mut k = 0u64;
        bench("ps apply_update (1 row of 4096)", 200.0, 100_000, || {
            ps.apply_update(0, 0, k % 343, &grad, h, None).unwrap();
            k += 1;
        });
    }
    // batched vs looped updates: one routing pass + one lock
    // acquisition per shard vs one lock per row (the tentpole's
    // single-thread win; the multi-thread win is below).
    {
        let ps = ps_with_model(343, 4096);
        let grad = vec![0.01f32; 4096];
        let h = Hyper { lr: 0.01, momentum: 0.9 };
        let keys: Vec<RowKey> = (0..64u64).collect();
        bench("ps apply_update x64 rows (looped)", 300.0, 20_000, || {
            for &k in &keys {
                ps.apply_update(0, 0, k, &grad, h, None).unwrap();
            }
        });
        let updates: Vec<(TableId, RowKey, &[f32])> =
            keys.iter().map(|&k| (0, k, &grad[..])).collect();
        bench("ps apply_batch  x64 rows (1 call)", 300.0, 20_000, || {
            ps.apply_batch(0, &updates, h).unwrap();
        });
    }
    // batched vs looped reads: the read plane mirrors the write plane
    // — one routing pass + one read-lock acquisition per shard vs one
    // lock per row (the gather-phase hot path of both PS apps).
    {
        let ps = ps_with_model(343, 4096);
        let keys: Vec<RowKey> = (0..64u64).collect();
        bench("ps read_row  x64 rows (looped)", 300.0, 20_000, || {
            for &k in &keys {
                black_box(ps.read_row(0, 0, k).unwrap());
            }
        });
        let batch_keys: Vec<(TableId, RowKey)> = keys.iter().map(|&k| (0, k)).collect();
        bench("ps read_rows x64 rows (1 call)", 300.0, 20_000, || {
            black_box(ps.read_rows(0, &batch_keys, false));
        });
    }
    // Wire codecs on the 64x4096-row data-plane frames (one gather /
    // update batch per worker): JSON decimal formatting + per-row
    // Vec<String> work vs the binary codec's raw f32 bit patterns into
    // a reused buffer (no per-row allocation, no float formatting).
    {
        use mltuner::comm::binwire;
        use mltuner::comm::wire::{
            decode_ps_reply, decode_ps_request, encode_ps_reply, encode_ps_request, PsReply,
            PsRequest,
        };
        let grad = vec![0.012345f32; 4096];
        let req = PsRequest::ApplyBatch {
            branch: 1,
            hyper: Hyper { lr: 0.01, momentum: 0.9 },
            updates: (0..64u64).map(|k| (0, k, grad.clone())).collect(),
        };
        let reply = PsReply::RowsData {
            rows: (0..64).map(|_| Some((grad.clone(), None))).collect(),
        };
        let json_req = encode_ps_request(&req);
        let json_reply = encode_ps_reply(&reply);
        let mut buf = Vec::new();
        binwire::encode_request(&req, &mut buf).unwrap();
        let bin_req = buf.clone();
        binwire::encode_reply(&reply, &mut buf).unwrap();
        let bin_reply = buf.clone();
        println!(
            "\n== wire codecs (64x4096-row frames: {} B json, {} B binary) ==",
            json_req.len(),
            bin_req.len()
        );
        bench("encode ApplyBatch 64x4096 (json)", 300.0, 2_000, || {
            black_box(encode_ps_request(&req));
        });
        bench("encode ApplyBatch 64x4096 (binary, reused buf)", 300.0, 2_000, || {
            binwire::encode_request(&req, &mut buf).unwrap();
            black_box(&buf);
        });
        bench("decode ApplyBatch 64x4096 (json)", 300.0, 2_000, || {
            black_box(decode_ps_request(&json_req).unwrap());
        });
        bench("decode ApplyBatch 64x4096 (binary)", 300.0, 2_000, || {
            black_box(binwire::decode_request(&bin_req).unwrap());
        });
        bench("decode ReadRows reply 64x4096 (json)", 300.0, 2_000, || {
            black_box(decode_ps_reply(&json_reply).unwrap());
        });
        bench("decode ReadRows reply 64x4096 (binary)", 300.0, 2_000, || {
            black_box(binwire::decode_reply(&bin_reply).unwrap());
        });
    }
    // Loopback RPC latency through the event-loop shard server at
    // 1/8/64 pooled connections (one connection lease per in-flight
    // read_row), JSON line framing vs the negotiated binary codec.
    #[cfg(unix)]
    {
        use mltuner::comm::socket::Framing;
        use mltuner::ps::remote::{spawn_local_server, RemoteParamServer, ShardRange};
        use mltuner::ps::ParamStore as _;
        println!("\n== loopback RPC latency (event-loop server, pooled connections) ==");
        for framing in [Framing::Line, Framing::Binary] {
            let (spec, handle, _server) =
                spawn_local_server(ShardRange { begin: 0, end: 4 }, OptimizerKind::Sgd, framing)
                    .unwrap();
            let remote = RemoteParamServer::connect(&[spec], framing).unwrap();
            remote.insert_row(0, 0, 0, vec![0.5; 256]).unwrap();
            for conc in [1usize, 8, 64] {
                let per = 2_000 / conc + 50;
                let t0 = Instant::now();
                std::thread::scope(|s| {
                    for _ in 0..conc {
                        let remote = &remote;
                        s.spawn(move || {
                            for _ in 0..per {
                                black_box(remote.read_row(0, 0, 0).unwrap());
                            }
                        });
                    }
                });
                let secs = t0.elapsed().as_secs_f64();
                let total = (conc * per) as f64;
                println!(
                    "read_row 256 f32 ({}, {conc:>2} conns): {:>7.1} us/rpc, {:>8.0} rpc/s",
                    framing.name(),
                    secs / per as f64 * 1e6,
                    total / secs.max(1e-12)
                );
            }
            remote.shutdown_all().unwrap();
            handle.join().unwrap().unwrap();
        }
    }
    // Multi-threaded shard throughput on the 2048x4096 acceptance
    // table: aggregate batched-update rows/sec at 1/2/4/8 worker
    // threads over disjoint row slices.  Acceptance: >=2x aggregate
    // throughput at 4 threads over the single-threaded path.
    {
        println!("\n== sharded update throughput (2048x4096 table, 8 shards) ==");
        let mut base = 0f64;
        for threads in [1usize, 2, 4, 8] {
            let (thru, contended) = shard_update_throughput(threads, 4);
            if threads == 1 {
                base = thru;
            }
            println!(
                "{threads} threads: {:>12.0} row-updates/s  ({:.2}x vs 1 thread, {contended} lock contentions)",
                thru,
                thru / base.max(1.0),
            );
        }
    }
    // branch switch = cache clear + refill
    {
        let ps = ps_with_model(343, 4096);
        let mut cache = WorkerCache::new();
        let mut b = 1u32;
        bench("cache switch+refill (343 rows)", 300.0, 5_000, || {
            cache.switch_branch(b);
            for k in 0..343u64 {
                if cache.get(0, k, 0, 0).is_none() {
                    cache.put(0, k, ps.read_row(0, 0, k).unwrap(), 0);
                }
            }
            b += 1;
        });
    }
    // summarizer over a long trace
    {
        let s = ProgressSummarizer::default();
        let mut rng = Rng::seed_from_u64(1);
        let trace: Vec<ProgressPoint> = (0..10_000)
            .map(|i| ProgressPoint {
                t: i as f64,
                x: 10.0 - i as f64 * 1e-3 + rng.gen_normal() * 0.05,
            })
            .collect();
        bench("summarizer (10k-point trace)", 200.0, 50_000, || {
            black_box(s.summarize(&trace));
        });
    }
    // searcher proposal cost at 40 observations
    for kind in [SearcherKind::Random, SearcherKind::HyperOpt, SearcherKind::BayesianOpt] {
        let mut s = kind.build(4, 1);
        for i in 0..40 {
            if let Proposal::Point(p) = s.propose() {
                let speed = 1.0 - (p[0] - 0.4).abs() + i as f64 * 1e-3;
                s.observe(p, speed);
            }
        }
        bench(
            &format!("searcher propose ({}, 40 obs)", s.name()),
            300.0,
            10_000,
            || {
                black_box(s.propose());
            },
        );
    }
    // PJRT grad-step dispatch (end-to-end L3→runtime hot path)
    if let Ok(mut rt) = Runtime::load("artifacts") {
        let mm = rt.model("alexnet_proxy").unwrap().clone();
        for &bs in &[4usize, 64] {
            if !mm.batch_sizes("xla").contains(&bs) {
                continue;
            }
            let params: Vec<Vec<f32>> = mm
                .param_shapes
                .iter()
                .map(|s| vec![0.01; s.iter().product()])
                .collect();
            let x = vec![0.1f32; bs * mm.input_dim];
            let y = vec![0i32; bs];
            // warm the executable cache
            rt.run_grad("alexnet_proxy", bs, "xla", &params, &x, &y)
                .unwrap();
            bench(
                &format!("pjrt grad step (alexnet_proxy bs={bs}, xla)"),
                500.0,
                2_000,
                || {
                    black_box(
                        rt.run_grad("alexnet_proxy", bs, "xla", &params, &x, &y)
                            .unwrap(),
                    );
                },
            );
        }
        // pallas variant (the interpret-lowered L1 kernels)
        if let Some(&bs) = mm.batch_sizes("pallas").first() {
            let params: Vec<Vec<f32>> = mm
                .param_shapes
                .iter()
                .map(|s| vec![0.01; s.iter().product()])
                .collect();
            let x = vec![0.1f32; bs * mm.input_dim];
            let y = vec![0i32; bs];
            rt.run_grad("alexnet_proxy", bs, "pallas", &params, &x, &y)
                .unwrap();
            bench(
                &format!("pjrt grad step (alexnet_proxy bs={bs}, pallas)"),
                500.0,
                2_000,
                || {
                    black_box(
                        rt.run_grad("alexnet_proxy", bs, "pallas", &params, &x, &y)
                            .unwrap(),
                    );
                },
            );
        }
    } else {
        println!("(artifacts missing — pjrt benches skipped; run `make artifacts`)");
    }

    // Whole training clock of the real DnnSystem (gather → PJRT grad →
    // server updates), the end-to-end L3 hot path.
    if let Ok(rt) = Runtime::load("artifacts") {
        use mltuner::apps::dnn::{DnnConfig, DnnSystem};
        use mltuner::comm::BranchType;
        use mltuner::training::TrainingSystem;
        use mltuner::tunable::TunableSetting;
        for (model, bs) in [("alexnet_proxy", 64.0), ("inception_proxy", 16.0)] {
            let rt = Runtime::load("artifacts").unwrap();
            let mut sys = DnnSystem::new(
                DnnConfig {
                    model: model.into(),
                    num_workers: 4,
                    train_examples: 2048,
                    val_examples: 256,
                    ..Default::default()
                },
                rt,
                OptimizerKind::Sgd,
            )
            .unwrap();
            let setting = TunableSetting::new(vec![0.01, 0.9, bs, 0.0]);
            sys.fork_branch(0, 1, None, &setting, BranchType::Training)
                .unwrap();
            sys.schedule_branch(0, 1).unwrap(); // warm executable cache
            let mut c = 1u64;
            bench(
                &format!("dnn training clock ({model} bs={bs} x4 workers)"),
                1_000.0,
                2_000,
                || {
                    black_box(sys.schedule_branch(c, 1).unwrap());
                    c += 1;
                },
            );
        }
        let _ = rt;
    }
}
