//! Binary codec for the PS data plane (`--framing binary`).
//!
//! The JSON codec of [`super::wire`] spells every f32 as a decimal
//! bit-pattern string — correct but slow at production row volumes:
//! a 4096-float row costs ~40 KB of decimal text plus a parse per
//! value.  This module encodes the same [`PsRequest`]/[`PsReply`]
//! values as fixed **little-endian** frames instead:
//!
//! ```text
//! frame body := opcode:u8 fields...
//! u32/u64    := little-endian
//! bool       := u8 (0 | 1, anything else is an error)
//! string     := len:u32 utf-8-bytes
//! f32s       := count:u32 raw-bit-pattern:u32 ×count
//! opt(x)     := tag:u8 (0 | 1) x?
//! ```
//!
//! Row payloads are the raw IEEE-754 bit patterns (`f32::to_bits`
//! little-endian), so the bit-exactness invariant of the JSON plane
//! carries over by construction — bits on the wire are bits either
//! way, which is what the binary↔JSON equality proptest pins.
//!
//! Encoders append into a caller-owned reusable `Vec<u8>` and perform
//! **zero per-row heap allocations and zero float→decimal
//! formatting**: the hot `ReadRows`/`ApplyBatch` loops are
//! `extend_from_slice` of 4-byte bit patterns straight out of the row
//! buffers (the scatter/gather buffers the server copies shard rows
//! into under the read lock).  Decoding is as strict as the JSON
//! plane's `num_*` helpers: every length is checked against the
//! remaining bytes, bools and option tags must be exactly 0/1, and
//! trailing bytes after a complete value are an error — a truncated
//! or padded frame can never decode to a different value.
//!
//! Request opcodes live in `0x01..=0x10` plus `0x1b`
//! (`ListBranches`), reply opcodes in `0x11..=0x1b` — requests and
//! replies are **separate decode spaces**, so a value may repeat
//! across the two directions.  Every opcode is below `0x20`, and a
//! JSON frame body always starts with `{` (0x7b), so a receiver can
//! dispatch a frame to the right codec from its first byte alone
//! ([`is_binary_frame`]) — that is how a binary-framing server keeps
//! answering plain-JSON peers during negotiation.
//!
//! Branch-scoped frames carry their session id as an unconditional
//! `u32` right after the opcode (binary peers are never
//! version-skewed: the codec is negotiated per connection, so there
//! is no legacy layout to stay byte-compatible with — unlike the JSON
//! plane, which omits the `session` key for session 0).

use anyhow::{anyhow, bail, Result};

use crate::optim::Hyper;
use crate::ps::checkpoint::SegmentMeta;
use crate::ps::pool::PoolStats;
use crate::ps::RowData;
use crate::stats::{
    ServerDelta, ServerPlane, SessionStats, ShardRows, StorePlane, TrialEvent, WirePlane,
    HIST_BUCKETS, SCHEMA_VERSION,
};

use super::wire::{PsReply, PsRequest, SessionHello, WireCodec};

// Request opcodes.
const OP_HELLO: u8 = 0x01;
const OP_INSERT: u8 = 0x02;
const OP_READ: u8 = 0x03;
const OP_READ_ROWS: u8 = 0x04;
const OP_UPDATE: u8 = 0x05;
const OP_BATCH: u8 = 0x06;
const OP_FORK: u8 = 0x07;
const OP_FREE: u8 = 0x08;
const OP_CKPT: u8 = 0x09;
const OP_VERIFY: u8 = 0x0a;
const OP_RESTORE: u8 = 0x0b;
const OP_STATS: u8 = 0x0c;
const OP_SHUTDOWN: u8 = 0x0d;
const OP_SUB_STATS: u8 = 0x0e;
const OP_PUBLISH: u8 = 0x0f;
const OP_END_SESSION: u8 = 0x10;
// 0x11..=0x1a shadow the reply range below; requests and replies are
// separate decode spaces, but keeping the values disjoint where we
// can makes hexdumps less confusing — only 0x1b doubles up.
const OP_LIST_BRANCHES: u8 = 0x1b;

// Reply opcodes.
const RE_HELLO: u8 = 0x11;
const RE_OK: u8 = 0x12;
const RE_ROW: u8 = 0x13;
const RE_ROWS: u8 = 0x14;
const RE_SEGMENTS: u8 = 0x15;
const RE_VERIFIED: u8 = 0x16;
const RE_RESTORED: u8 = 0x17;
const RE_STATS: u8 = 0x18;
const RE_ERR: u8 = 0x19;
const RE_STATS_DELTA: u8 = 0x1a;
const RE_BRANCH_LIST: u8 = 0x1b;

/// Does this frame body carry the binary codec?  Binary opcodes are
/// all `< 0x20`; a JSON body starts with `{` (0x7b).  An empty body is
/// neither and fails both decoders.
pub fn is_binary_frame(body: &[u8]) -> bool {
    body.first().is_some_and(|b| *b < 0x20)
}

// ---------------------------------------------------------------------------
// Encoding primitives
// ---------------------------------------------------------------------------

fn len_u32(n: usize, what: &str) -> Result<u32> {
    u32::try_from(n).map_err(|_| anyhow!("{what} length {n} out of u32 range"))
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_usize(out: &mut Vec<u8>, v: usize, what: &str) -> Result<()> {
    let n = u64::try_from(v).map_err(|_| anyhow!("{what} {v} out of u64 range"))?;
    put_u64(out, n);
    Ok(())
}

fn put_bool(out: &mut Vec<u8>, v: bool) {
    out.push(u8::from(v));
}

fn put_str(out: &mut Vec<u8>, s: &str, what: &str) -> Result<()> {
    put_u32(out, len_u32(s.len(), what)?);
    out.extend_from_slice(s.as_bytes());
    Ok(())
}

/// The row-payload hot path: count + raw bit patterns, no per-value
/// allocation or formatting of any kind.
fn put_f32s(out: &mut Vec<u8>, data: &[f32], what: &str) -> Result<()> {
    put_u32(out, len_u32(data.len(), what)?);
    out.reserve(data.len().saturating_mul(4));
    for v in data {
        out.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    Ok(())
}

fn put_opt_f32s(out: &mut Vec<u8>, data: Option<&[f32]>, what: &str) -> Result<()> {
    match data {
        None => out.push(0),
        Some(d) => {
            out.push(1);
            put_f32s(out, d, what)?;
        }
    }
    Ok(())
}

fn put_hyper(out: &mut Vec<u8>, hyper: Hyper) {
    put_u32(out, hyper.lr.to_bits());
    put_u32(out, hyper.momentum.to_bits());
}

fn put_codec(out: &mut Vec<u8>, codec: WireCodec) {
    out.push(match codec {
        WireCodec::Json => 0,
        WireCodec::Binary => 1,
    });
}

// ---------------------------------------------------------------------------
// Decoding primitives
// ---------------------------------------------------------------------------

/// Strict cursor over a frame body.  Every read checks the remaining
/// length; [`Reader::finish`] rejects trailing bytes.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|e| *e <= self.buf.len())
            .ok_or_else(|| anyhow!("truncated {what}: need {n} bytes at offset {}", self.pos))?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        let b = self.take(4, what)?;
        let mut le = [0u8; 4];
        le.copy_from_slice(b);
        Ok(u32::from_le_bytes(le))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        let b = self.take(8, what)?;
        let mut le = [0u8; 8];
        le.copy_from_slice(b);
        Ok(u64::from_le_bytes(le))
    }

    fn usize(&mut self, what: &str) -> Result<usize> {
        let n = self.u64(what)?;
        usize::try_from(n).map_err(|_| anyhow!("bad {what}: {n} out of usize range"))
    }

    /// A `count` prefix that is about to drive a loop of ≥
    /// `min_elem_bytes`-byte elements: bounded by the bytes actually
    /// present so a forged count cannot drive a huge pre-allocation.
    fn count(&mut self, min_elem_bytes: usize, what: &str) -> Result<usize> {
        let n = self.u32(what)?;
        let n = usize::try_from(n).map_err(|_| anyhow!("bad {what}: out of usize range"))?;
        let need = n.saturating_mul(min_elem_bytes.max(1));
        if need > self.buf.len() - self.pos {
            bail!("truncated {what}: count {n} exceeds remaining bytes");
        }
        Ok(n)
    }

    fn bool(&mut self, what: &str) -> Result<bool> {
        match self.u8(what)? {
            0 => Ok(false),
            1 => Ok(true),
            b => bail!("bad {what}: {b} is not a bool"),
        }
    }

    fn str(&mut self, what: &str) -> Result<String> {
        let n = self.count(1, what)?;
        let bytes = self.take(n, what)?;
        Ok(std::str::from_utf8(bytes)
            .map_err(|_| anyhow!("bad {what}: not utf-8"))?
            .to_string())
    }

    fn f32s(&mut self, what: &str) -> Result<Vec<f32>> {
        let n = self.count(4, what)?;
        let bytes = self.take(n.saturating_mul(4), what)?;
        let mut out = Vec::with_capacity(n);
        for chunk in bytes.chunks_exact(4) {
            let mut le = [0u8; 4];
            le.copy_from_slice(chunk);
            out.push(f32::from_bits(u32::from_le_bytes(le)));
        }
        Ok(out)
    }

    fn opt_f32s(&mut self, what: &str) -> Result<Option<Vec<f32>>> {
        match self.u8(what)? {
            0 => Ok(None),
            1 => Ok(Some(self.f32s(what)?)),
            b => bail!("bad {what}: {b} is not an option tag"),
        }
    }

    fn hyper(&mut self) -> Result<Hyper> {
        Ok(Hyper {
            lr: f32::from_bits(self.u32("lr")?),
            momentum: f32::from_bits(self.u32("momentum")?),
        })
    }

    fn codec(&mut self) -> Result<WireCodec> {
        match self.u8("codec")? {
            0 => Ok(WireCodec::Json),
            1 => Ok(WireCodec::Binary),
            b => bail!("bad codec byte {b}"),
        }
    }

    fn trial_event(&mut self) -> Result<TrialEvent> {
        Ok(TrialEvent {
            session: self.u32("session")?,
            episode: self.u32("episode")?,
            trial: self.u32("trial")?,
            branch: self.u32("branch")?,
            clock: self.u64("clock")?,
            progress: f64::from_bits(self.u64("progress")?),
            time: f64::from_bits(self.u64("time")?),
        })
    }

    /// The versioned stats body (see `put_server_delta`).  The schema
    /// version is checked first, so a frame from a newer peer fails
    /// with a version mismatch instead of a misleading truncation
    /// error further in.
    fn server_delta(&mut self) -> Result<ServerDelta> {
        let version = self.u32("stats schema version")?;
        if version != SCHEMA_VERSION {
            bail!(
                "unsupported stats schema version {version} (this peer speaks {SCHEMA_VERSION})"
            );
        }
        let server = ServerPlane {
            shard_lock_contentions: self.u64("contended")?,
            batch_calls: self.u64("batch_calls")?,
            batched_rows: self.u64("batched_rows")?,
            reads_batched: self.u64("reads_batched")?,
            rows_applied: self.u64("rows_applied")?,
            rows_read: self.u64("rows_read")?,
        };
        let store = StorePlane {
            forks: self.u64("forks")?,
            peak_branches: self.usize("peak")?,
            live_branches: self.usize("live")?,
            cow_buffer_copies: self.u64("cow")?,
            read_rpcs: self.u64("read_rpcs")?,
        };
        let pool = PoolStats {
            reused: self.u64("reused")?,
            allocated: self.u64("allocated")?,
            idle: self.u64("idle")?,
            idle_len: self.u64("idle_len")?,
        };
        let wire = WirePlane {
            bytes_tx: self.u64("bytes_tx")?,
            bytes_rx: self.u64("bytes_rx")?,
            frames_json: self.u64("frames_json")?,
            frames_bin: self.u64("frames_bin")?,
        };
        let n = self.count(24, "shards")?;
        let mut shards = Vec::with_capacity(n);
        for _ in 0..n {
            shards.push(ShardRows {
                shard: self.u64("shard")?,
                rows_applied: self.u64("shard rows_applied")?,
                rows_read: self.u64("shard rows_read")?,
            });
        }
        let mut rpc_hist = [0u64; HIST_BUCKETS];
        for slot in rpc_hist.iter_mut() {
            *slot = self.u64("rpc_hist bucket")?;
        }
        let n = self.count(12, "branches")?;
        let mut branches = Vec::with_capacity(n);
        for _ in 0..n {
            branches.push((self.u32("branch")?, self.usize("rows")?));
        }
        let n = self.count(40, "trials")?;
        let mut trials = Vec::with_capacity(n);
        for _ in 0..n {
            trials.push(self.trial_event()?);
        }
        let n = self.count(36, "sessions")?;
        let mut sessions = Vec::with_capacity(n);
        for _ in 0..n {
            sessions.push(SessionStats {
                session: self.u32("session")?,
                rows_applied: self.u64("session rows_applied")?,
                rows_read: self.u64("session rows_read")?,
                deferrals: self.u64("session deferrals")?,
                live_branches: self.usize("session live")?,
            });
        }
        Ok(ServerDelta {
            version,
            server,
            store,
            pool,
            wire,
            shards,
            rpc_hist,
            branches,
            trials,
            sessions,
        })
    }

    fn finish(self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!("trailing bytes: {} past end of frame", self.buf.len() - self.pos);
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Requests
// ---------------------------------------------------------------------------

/// Encode one PS request into `out` (cleared first; reuse the buffer
/// across frames to amortize its allocation).
pub fn encode_request(req: &PsRequest, out: &mut Vec<u8>) -> Result<()> {
    out.clear();
    match req {
        PsRequest::Hello { codec, session } => {
            out.push(OP_HELLO);
            put_codec(out, *codec);
            match session {
                None => out.push(0),
                Some(s) => {
                    out.push(1);
                    put_str(out, &s.name, "session name")?;
                    put_u64(out, s.lease_ms);
                }
            }
        }
        PsRequest::InsertRow {
            session,
            branch,
            table,
            key,
            data,
        } => {
            out.push(OP_INSERT);
            put_u32(out, *session);
            put_u32(out, *branch);
            put_u32(out, *table);
            put_u64(out, *key);
            put_f32s(out, data, "data")?;
        }
        PsRequest::ReadRow {
            session,
            branch,
            table,
            key,
            with_accum,
        } => {
            out.push(OP_READ);
            put_u32(out, *session);
            put_u32(out, *branch);
            put_u32(out, *table);
            put_u64(out, *key);
            put_bool(out, *with_accum);
        }
        PsRequest::ReadRows {
            session,
            branch,
            with_accum,
            keys,
        } => {
            out.push(OP_READ_ROWS);
            put_u32(out, *session);
            put_u32(out, *branch);
            put_bool(out, *with_accum);
            put_u32(out, len_u32(keys.len(), "keys")?);
            for (table, key) in keys {
                put_u32(out, *table);
                put_u64(out, *key);
            }
        }
        PsRequest::ApplyUpdate {
            session,
            branch,
            table,
            key,
            grad,
            hyper,
            z_old,
        } => {
            out.push(OP_UPDATE);
            put_u32(out, *session);
            put_u32(out, *branch);
            put_u32(out, *table);
            put_u64(out, *key);
            put_hyper(out, *hyper);
            put_f32s(out, grad, "grad")?;
            put_opt_f32s(out, z_old.as_deref(), "z_old")?;
        }
        PsRequest::ApplyBatch {
            session,
            branch,
            hyper,
            updates,
        } => {
            out.push(OP_BATCH);
            put_u32(out, *session);
            put_u32(out, *branch);
            put_hyper(out, *hyper);
            put_u32(out, len_u32(updates.len(), "updates")?);
            for (table, key, grad) in updates {
                put_u32(out, *table);
                put_u64(out, *key);
                put_f32s(out, grad, "grad")?;
            }
        }
        PsRequest::ForkBranch {
            session,
            child,
            parent,
        } => {
            out.push(OP_FORK);
            put_u32(out, *session);
            put_u32(out, *child);
            put_u32(out, *parent);
        }
        PsRequest::FreeBranch { session, branch } => {
            out.push(OP_FREE);
            put_u32(out, *session);
            put_u32(out, *branch);
        }
        PsRequest::CheckpointBranch {
            session,
            branch,
            dir,
        } => {
            out.push(OP_CKPT);
            put_u32(out, *session);
            put_u32(out, *branch);
            put_str(out, dir, "dir")?;
        }
        PsRequest::VerifyBranch {
            session,
            branch,
            dir,
        } => {
            out.push(OP_VERIFY);
            put_u32(out, *session);
            put_u32(out, *branch);
            put_str(out, dir, "dir")?;
        }
        PsRequest::RestoreBranch {
            session,
            branch,
            dir,
        } => {
            out.push(OP_RESTORE);
            put_u32(out, *session);
            put_u32(out, *branch);
            put_str(out, dir, "dir")?;
        }
        PsRequest::ServerStats => out.push(OP_STATS),
        PsRequest::SubscribeStats { interval_ms } => {
            out.push(OP_SUB_STATS);
            put_u64(out, *interval_ms);
        }
        PsRequest::PublishProgress { event } => {
            // the event's own `session` field doubles as the frame's
            // session stamp, exactly like the JSON plane
            out.push(OP_PUBLISH);
            put_trial_event(out, event);
        }
        PsRequest::ListBranches { session } => {
            out.push(OP_LIST_BRANCHES);
            put_u32(out, *session);
        }
        PsRequest::EndSession { session } => {
            out.push(OP_END_SESSION);
            put_u32(out, *session);
        }
        PsRequest::Shutdown => out.push(OP_SHUTDOWN),
    }
    Ok(())
}

/// Fixed 40-byte trial-event record (session:u32 episode:u32
/// trial:u32 branch:u32 clock:u64 progress:u64 time:u64); `f64`s ride
/// as raw bit patterns, same invariant as the row payloads.
fn put_trial_event(out: &mut Vec<u8>, t: &TrialEvent) {
    put_u32(out, t.session);
    put_u32(out, t.episode);
    put_u32(out, t.trial);
    put_u32(out, t.branch);
    put_u64(out, t.clock);
    put_u64(out, t.progress.to_bits());
    put_u64(out, t.time.to_bits());
}

/// The versioned [`ServerDelta`] body shared by [`RE_STATS`] and
/// [`RE_STATS_DELTA`].  The histogram is a fixed [`HIST_BUCKETS`]-long
/// run of `u64`s — no count prefix, the schema version pins the
/// length.
fn put_server_delta(out: &mut Vec<u8>, d: &ServerDelta) -> Result<()> {
    put_u32(out, d.version);
    put_u64(out, d.server.shard_lock_contentions);
    put_u64(out, d.server.batch_calls);
    put_u64(out, d.server.batched_rows);
    put_u64(out, d.server.reads_batched);
    put_u64(out, d.server.rows_applied);
    put_u64(out, d.server.rows_read);
    put_u64(out, d.store.forks);
    put_usize(out, d.store.peak_branches, "peak")?;
    put_usize(out, d.store.live_branches, "live")?;
    put_u64(out, d.store.cow_buffer_copies);
    put_u64(out, d.store.read_rpcs);
    put_u64(out, d.pool.reused);
    put_u64(out, d.pool.allocated);
    put_u64(out, d.pool.idle);
    put_u64(out, d.pool.idle_len);
    put_u64(out, d.wire.bytes_tx);
    put_u64(out, d.wire.bytes_rx);
    put_u64(out, d.wire.frames_json);
    put_u64(out, d.wire.frames_bin);
    put_u32(out, len_u32(d.shards.len(), "shards")?);
    for s in &d.shards {
        put_u64(out, s.shard);
        put_u64(out, s.rows_applied);
        put_u64(out, s.rows_read);
    }
    for b in &d.rpc_hist {
        put_u64(out, *b);
    }
    put_u32(out, len_u32(d.branches.len(), "branches")?);
    for (id, rows) in &d.branches {
        put_u32(out, *id);
        put_usize(out, *rows, "rows")?;
    }
    put_u32(out, len_u32(d.trials.len(), "trials")?);
    for t in &d.trials {
        put_trial_event(out, t);
    }
    put_u32(out, len_u32(d.sessions.len(), "sessions")?);
    for s in &d.sessions {
        put_u32(out, s.session);
        put_u64(out, s.rows_applied);
        put_u64(out, s.rows_read);
        put_u64(out, s.deferrals);
        put_usize(out, s.live_branches, "session live")?;
    }
    Ok(())
}

/// Decode one binary PS request frame (strict: bad opcodes,
/// truncation, and trailing bytes are all errors, never panics).
pub fn decode_request(buf: &[u8]) -> Result<PsRequest> {
    let mut r = Reader::new(buf);
    let op = r.u8("opcode")?;
    let req = match op {
        OP_HELLO => {
            let codec = r.codec()?;
            let session = match r.u8("session tag")? {
                0 => None,
                1 => Some(SessionHello {
                    name: r.str("session name")?,
                    lease_ms: r.u64("lease_ms")?,
                }),
                b => bail!("bad session tag {b}"),
            };
            PsRequest::Hello { codec, session }
        }
        OP_INSERT => PsRequest::InsertRow {
            session: r.u32("session")?,
            branch: r.u32("branch")?,
            table: r.u32("table")?,
            key: r.u64("key")?,
            data: r.f32s("data")?,
        },
        OP_READ => PsRequest::ReadRow {
            session: r.u32("session")?,
            branch: r.u32("branch")?,
            table: r.u32("table")?,
            key: r.u64("key")?,
            with_accum: r.bool("accum")?,
        },
        OP_READ_ROWS => {
            let session = r.u32("session")?;
            let branch = r.u32("branch")?;
            let with_accum = r.bool("accum")?;
            let n = r.count(12, "keys")?;
            let mut keys = Vec::with_capacity(n);
            for _ in 0..n {
                keys.push((r.u32("table")?, r.u64("key")?));
            }
            PsRequest::ReadRows {
                session,
                branch,
                with_accum,
                keys,
            }
        }
        OP_UPDATE => PsRequest::ApplyUpdate {
            session: r.u32("session")?,
            branch: r.u32("branch")?,
            table: r.u32("table")?,
            key: r.u64("key")?,
            hyper: r.hyper()?,
            grad: r.f32s("grad")?,
            z_old: r.opt_f32s("z_old")?,
        },
        OP_BATCH => {
            let session = r.u32("session")?;
            let branch = r.u32("branch")?;
            let hyper = r.hyper()?;
            let n = r.count(16, "updates")?;
            let mut updates = Vec::with_capacity(n);
            for _ in 0..n {
                updates.push((r.u32("table")?, r.u64("key")?, r.f32s("grad")?));
            }
            PsRequest::ApplyBatch {
                session,
                branch,
                hyper,
                updates,
            }
        }
        OP_FORK => PsRequest::ForkBranch {
            session: r.u32("session")?,
            child: r.u32("child")?,
            parent: r.u32("parent")?,
        },
        OP_FREE => PsRequest::FreeBranch {
            session: r.u32("session")?,
            branch: r.u32("branch")?,
        },
        OP_CKPT => PsRequest::CheckpointBranch {
            session: r.u32("session")?,
            branch: r.u32("branch")?,
            dir: r.str("dir")?,
        },
        OP_VERIFY => PsRequest::VerifyBranch {
            session: r.u32("session")?,
            branch: r.u32("branch")?,
            dir: r.str("dir")?,
        },
        OP_RESTORE => PsRequest::RestoreBranch {
            session: r.u32("session")?,
            branch: r.u32("branch")?,
            dir: r.str("dir")?,
        },
        OP_STATS => PsRequest::ServerStats,
        OP_SUB_STATS => PsRequest::SubscribeStats { interval_ms: r.u64("interval_ms")? },
        OP_PUBLISH => PsRequest::PublishProgress { event: r.trial_event()? },
        OP_LIST_BRANCHES => PsRequest::ListBranches { session: r.u32("session")? },
        OP_END_SESSION => PsRequest::EndSession { session: r.u32("session")? },
        OP_SHUTDOWN => PsRequest::Shutdown,
        other => bail!("unknown binary request opcode {other:#04x}"),
    };
    r.finish()?;
    Ok(req)
}

// ---------------------------------------------------------------------------
// Replies
// ---------------------------------------------------------------------------

/// Encode one PS reply into `out` (cleared first).  The
/// `RowsData` arm is the server's hottest send path: one tag byte and
/// the raw bit patterns per row, straight out of the gather buffers.
pub fn encode_reply(reply: &PsReply, out: &mut Vec<u8>) -> Result<()> {
    out.clear();
    match reply {
        PsReply::Hello {
            shard_begin,
            shard_end,
            optimizer,
            codec,
            session,
        } => {
            out.push(RE_HELLO);
            put_usize(out, *shard_begin, "begin")?;
            put_usize(out, *shard_end, "end")?;
            put_str(out, optimizer, "optimizer")?;
            put_codec(out, *codec);
            put_u32(out, *session);
        }
        PsReply::Ok => out.push(RE_OK),
        PsReply::Row { data, accum } => {
            out.push(RE_ROW);
            put_opt_f32s(out, data.as_deref(), "data")?;
            put_opt_f32s(out, accum.as_deref(), "accum")?;
        }
        PsReply::RowsData { rows } => {
            out.push(RE_ROWS);
            put_u32(out, len_u32(rows.len(), "rows")?);
            for row in rows {
                match row {
                    None => out.push(0),
                    Some((data, accum)) => {
                        out.push(1);
                        put_f32s(out, data, "data")?;
                        put_opt_f32s(out, accum.as_deref(), "accum")?;
                    }
                }
            }
        }
        PsReply::Segments { segments } => {
            out.push(RE_SEGMENTS);
            put_u32(out, len_u32(segments.len(), "segments")?);
            for s in segments {
                put_str(out, &s.file, "file")?;
                put_u32(out, s.branch);
                put_usize(out, s.range_begin, "range begin")?;
                put_usize(out, s.range_end, "range end")?;
                put_usize(out, s.local_shard, "shard")?;
                put_u64(out, s.rows);
                put_u64(out, s.bytes);
                put_u64(out, s.checksum);
            }
        }
        PsReply::Verified { rows } => {
            out.push(RE_VERIFIED);
            put_u64(out, *rows);
        }
        PsReply::Restored { rows } => {
            out.push(RE_RESTORED);
            put_u64(out, *rows);
        }
        PsReply::Stats(d) => {
            out.push(RE_STATS);
            put_server_delta(out, d)?;
        }
        PsReply::StatsDelta(d) => {
            out.push(RE_STATS_DELTA);
            put_server_delta(out, d)?;
        }
        PsReply::BranchList { branches } => {
            out.push(RE_BRANCH_LIST);
            put_u32(out, len_u32(branches.len(), "branches")?);
            for (id, rows) in branches {
                put_u32(out, *id);
                put_usize(out, *rows, "rows")?;
            }
        }
        PsReply::Err { message } => {
            out.push(RE_ERR);
            put_str(out, message, "msg")?;
        }
    }
    Ok(())
}

/// Decode one binary PS reply frame (strict, like [`decode_request`]).
pub fn decode_reply(buf: &[u8]) -> Result<PsReply> {
    let mut r = Reader::new(buf);
    let op = r.u8("opcode")?;
    let reply = match op {
        RE_HELLO => PsReply::Hello {
            shard_begin: r.usize("begin")?,
            shard_end: r.usize("end")?,
            optimizer: r.str("optimizer")?,
            codec: r.codec()?,
            session: r.u32("session")?,
        },
        RE_OK => PsReply::Ok,
        RE_ROW => PsReply::Row {
            data: r.opt_f32s("data")?,
            accum: r.opt_f32s("accum")?,
        },
        RE_ROWS => {
            let n = r.count(1, "rows")?;
            let mut rows: Vec<Option<RowData>> = Vec::with_capacity(n);
            for _ in 0..n {
                rows.push(match r.u8("row tag")? {
                    0 => None,
                    1 => Some((r.f32s("data")?, r.opt_f32s("accum")?)),
                    b => bail!("bad row tag {b}"),
                });
            }
            PsReply::RowsData { rows }
        }
        RE_SEGMENTS => {
            let n = r.count(49, "segments")?;
            let mut segments = Vec::with_capacity(n);
            for _ in 0..n {
                segments.push(SegmentMeta {
                    file: r.str("file")?,
                    branch: r.u32("segment branch")?,
                    range_begin: r.usize("segment range begin")?,
                    range_end: r.usize("segment range end")?,
                    local_shard: r.usize("segment shard")?,
                    rows: r.u64("segment rows")?,
                    bytes: r.u64("segment bytes")?,
                    checksum: r.u64("segment checksum")?,
                });
            }
            PsReply::Segments { segments }
        }
        RE_VERIFIED => PsReply::Verified { rows: r.u64("rows")? },
        RE_RESTORED => PsReply::Restored { rows: r.u64("rows")? },
        RE_STATS => PsReply::Stats(r.server_delta()?),
        RE_STATS_DELTA => PsReply::StatsDelta(r.server_delta()?),
        RE_BRANCH_LIST => {
            let n = r.count(12, "branches")?;
            let mut branches = Vec::with_capacity(n);
            for _ in 0..n {
                branches.push((r.u32("branch")?, r.usize("rows")?));
            }
            PsReply::BranchList { branches }
        }
        RE_ERR => PsReply::Err { message: r.str("msg")? },
        other => bail!("unknown binary reply opcode {other:#04x}"),
    };
    r.finish()?;
    Ok(reply)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_req(req: &PsRequest) {
        let mut buf = Vec::new();
        encode_request(req, &mut buf).unwrap();
        assert!(is_binary_frame(&buf));
        let back = decode_request(&buf).unwrap_or_else(|e| panic!("{req:?}: {e}"));
        assert_eq!(req, &back);
    }

    fn roundtrip_reply(reply: &PsReply) {
        let mut buf = Vec::new();
        encode_reply(reply, &mut buf).unwrap();
        assert!(is_binary_frame(&buf));
        let back = decode_reply(&buf).unwrap_or_else(|e| panic!("{reply:?}: {e}"));
        assert_eq!(reply, &back);
    }

    #[test]
    fn requests_roundtrip() {
        let hyper = Hyper { lr: 0.1, momentum: 0.9 };
        roundtrip_req(&PsRequest::Hello {
            codec: WireCodec::Json,
            session: None,
        });
        roundtrip_req(&PsRequest::Hello {
            codec: WireCodec::Binary,
            session: None,
        });
        roundtrip_req(&PsRequest::Hello {
            codec: WireCodec::Binary,
            session: Some(SessionHello {
                name: "mf-sweep \"a\"".into(),
                lease_ms: 30_000,
            }),
        });
        roundtrip_req(&PsRequest::Hello {
            codec: WireCodec::Json,
            session: Some(SessionHello {
                name: String::new(),
                lease_ms: 0,
            }),
        });
        roundtrip_req(&PsRequest::InsertRow {
            session: 0,
            branch: 0,
            table: 1,
            key: 7,
            data: vec![1.0, -0.0, f32::INFINITY, f32::NEG_INFINITY, 1.0e-45],
        });
        roundtrip_req(&PsRequest::ReadRow {
            session: 7,
            branch: 3,
            table: 0,
            key: u64::MAX,
            with_accum: true,
        });
        roundtrip_req(&PsRequest::ReadRows {
            session: u32::MAX,
            branch: 3,
            with_accum: true,
            keys: vec![(0, 7), (1, u64::MAX), (0, 0)],
        });
        roundtrip_req(&PsRequest::ReadRows {
            session: 0,
            branch: 0,
            with_accum: false,
            keys: vec![],
        });
        roundtrip_req(&PsRequest::ApplyUpdate {
            session: 1,
            branch: 1,
            table: 0,
            key: 5,
            grad: vec![0.25, -1.5],
            hyper,
            z_old: Some(vec![2.0, 3.0]),
        });
        roundtrip_req(&PsRequest::ApplyUpdate {
            session: 0,
            branch: 1,
            table: 0,
            key: 5,
            grad: vec![],
            hyper,
            z_old: None,
        });
        roundtrip_req(&PsRequest::ApplyBatch {
            session: 3,
            branch: 2,
            hyper,
            updates: vec![(0, 1, vec![1.0]), (1, 9, vec![-2.5, 0.125])],
        });
        roundtrip_req(&PsRequest::ForkBranch {
            session: 2,
            child: 4,
            parent: 1,
        });
        roundtrip_req(&PsRequest::FreeBranch {
            session: 2,
            branch: 4,
        });
        roundtrip_req(&PsRequest::CheckpointBranch {
            session: 0,
            branch: 3,
            dir: "/tmp/with \"quotes\"\nand → unicode".into(),
        });
        roundtrip_req(&PsRequest::VerifyBranch {
            session: 9,
            branch: 7,
            dir: "/tmp/ck".into(),
        });
        roundtrip_req(&PsRequest::RestoreBranch {
            session: 0,
            branch: 0,
            dir: "relative/dir".into(),
        });
        roundtrip_req(&PsRequest::ServerStats);
        roundtrip_req(&PsRequest::SubscribeStats { interval_ms: 250 });
        roundtrip_req(&PsRequest::PublishProgress {
            event: TrialEvent {
                session: 6,
                episode: 1,
                trial: 4,
                branch: 9,
                clock: 1 << 60,
                progress: -1.25e-3,
                time: 0.5,
            },
        });
        roundtrip_req(&PsRequest::ListBranches { session: 0 });
        roundtrip_req(&PsRequest::ListBranches { session: 12 });
        roundtrip_req(&PsRequest::EndSession { session: 12 });
        roundtrip_req(&PsRequest::Shutdown);
    }

    fn sample_delta() -> ServerDelta {
        let mut rpc_hist = [0u64; HIST_BUCKETS];
        rpc_hist[0] = 5;
        rpc_hist[7] = 2;
        ServerDelta {
            server: ServerPlane {
                shard_lock_contentions: 3,
                batch_calls: 10,
                batched_rows: 640,
                reads_batched: 4096,
                rows_applied: 1000,
                rows_read: 5000,
            },
            store: StorePlane {
                forks: 7,
                peak_branches: 3,
                live_branches: 2,
                cow_buffer_copies: 3,
                read_rpcs: 11,
            },
            pool: PoolStats {
                reused: 1,
                allocated: 2,
                idle: 3,
                idle_len: 48,
            },
            wire: WirePlane {
                bytes_tx: u64::MAX,
                bytes_rx: 1,
                frames_json: 2,
                frames_bin: 3,
            },
            shards: vec![
                ShardRows { shard: 2, rows_applied: 600, rows_read: 3000 },
                ShardRows { shard: 3, rows_applied: 400, rows_read: 2000 },
            ],
            rpc_hist,
            branches: vec![(0, 100), (5, 40)],
            trials: vec![TrialEvent {
                session: 2,
                episode: 0,
                trial: 3,
                branch: 5,
                clock: 42,
                progress: -1.25,
                time: 0.5,
            }],
            sessions: vec![SessionStats {
                session: 2,
                rows_applied: 600,
                rows_read: 3000,
                deferrals: 4,
                live_branches: 1,
            }],
            ..ServerDelta::default()
        }
    }

    #[test]
    fn replies_roundtrip() {
        roundtrip_reply(&PsReply::Hello {
            shard_begin: 2,
            shard_end: 4,
            optimizer: "adarevision".into(),
            codec: WireCodec::Binary,
            session: 0,
        });
        roundtrip_reply(&PsReply::Hello {
            shard_begin: 0,
            shard_end: 2,
            optimizer: "sgd".into(),
            codec: WireCodec::Json,
            session: 3,
        });
        roundtrip_reply(&PsReply::Ok);
        roundtrip_reply(&PsReply::BranchList { branches: vec![] });
        roundtrip_reply(&PsReply::BranchList {
            branches: vec![(0, 22), (5, 0)],
        });
        roundtrip_reply(&PsReply::Row {
            data: Some(vec![1.0, f32::NEG_INFINITY, -0.0]),
            accum: None,
        });
        roundtrip_reply(&PsReply::Row { data: None, accum: None });
        roundtrip_reply(&PsReply::RowsData {
            rows: vec![
                Some((vec![1.0, f32::NEG_INFINITY, -0.0], None)),
                None,
                Some((vec![], Some(vec![2.5, 1.0e-45]))),
            ],
        });
        roundtrip_reply(&PsReply::RowsData { rows: vec![] });
        roundtrip_reply(&PsReply::Segments {
            segments: vec![SegmentMeta {
                file: "b1-r0-2-s0.seg".into(),
                branch: 1,
                range_begin: 0,
                range_end: 2,
                local_shard: 0,
                rows: 17,
                bytes: 4096,
                checksum: u64::MAX,
            }],
        });
        roundtrip_reply(&PsReply::Verified { rows: 0 });
        roundtrip_reply(&PsReply::Restored { rows: 1 << 40 });
        let delta = sample_delta();
        roundtrip_reply(&PsReply::Stats(delta.clone()));
        roundtrip_reply(&PsReply::StatsDelta(delta));
        roundtrip_reply(&PsReply::Err {
            message: "row (0,99) missing in branch 7\nwith \"quotes\"".into(),
        });
    }

    #[test]
    fn stats_frames_are_versioned_and_truncation_safe() {
        let mut buf = Vec::new();
        encode_reply(&PsReply::StatsDelta(sample_delta()), &mut buf).unwrap();
        // the schema version rides right after the opcode, little-endian
        assert_eq!(buf[1..5], SCHEMA_VERSION.to_le_bytes());
        // a frame stamped with a newer version is a typed error
        let mut newer = buf.clone();
        newer[1..5].copy_from_slice(&3u32.to_le_bytes());
        let err = decode_reply(&newer).unwrap_err().to_string();
        assert!(err.contains("schema version 3"), "{err}");
        // every truncation of the stats frame errors instead of
        // panicking or decoding short
        for cut in 0..buf.len() {
            assert!(decode_reply(&buf[..cut]).is_err(), "cut at {cut}");
        }
        buf.push(0);
        assert!(decode_reply(&buf).is_err(), "trailing byte accepted");
    }

    #[test]
    fn nan_bit_patterns_survive() {
        let weird = [
            f32::NAN,
            f32::from_bits(0x7fc0_dead),
            f32::INFINITY,
            f32::NEG_INFINITY,
            -0.0f32,
            f32::MIN_POSITIVE,
            1.0e-45,
            f32::MAX,
        ];
        let req = PsRequest::InsertRow {
            session: 0,
            branch: 0,
            table: 0,
            key: 0,
            data: weird.to_vec(),
        };
        let mut buf = Vec::new();
        encode_request(&req, &mut buf).unwrap();
        let PsRequest::InsertRow { data, .. } = decode_request(&buf).unwrap() else {
            panic!("wrong op")
        };
        for (a, b) in weird.iter().zip(&data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn decode_is_strict() {
        // empty frame
        assert!(decode_request(&[]).is_err());
        assert!(decode_reply(&[]).is_err());
        // unknown opcodes (incl. a JSON first byte fed to the binary
        // decoder) fail cleanly
        assert!(decode_request(&[0xff]).is_err());
        assert!(decode_request(b"{\"op\":\"hello\"}").is_err());
        assert!(decode_reply(&[0x0e]).is_err());
        // every truncation of a valid frame is an error, never a panic
        let req = PsRequest::ApplyUpdate {
            session: 1,
            branch: 1,
            table: 0,
            key: 5,
            grad: vec![0.25, -1.5],
            hyper: Hyper { lr: 0.1, momentum: 0.9 },
            z_old: Some(vec![2.0]),
        };
        let mut buf = Vec::new();
        encode_request(&req, &mut buf).unwrap();
        for cut in 0..buf.len() {
            assert!(decode_request(&buf[..cut]).is_err(), "cut at {cut}");
        }
        // trailing bytes are rejected too
        buf.push(0);
        assert!(decode_request(&buf).is_err());
        // bad bool / option-tag / codec / session-tag bytes (ReadRow
        // body: session:u32 branch:u32 table:u32 key:u64 accum:u8)
        assert!(decode_request(&[
            OP_READ, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 2
        ])
        .is_err());
        assert!(decode_request(&[OP_HELLO, 9]).is_err());
        // codec ok, session tag is neither 0 nor 1
        assert!(decode_request(&[OP_HELLO, 0, 9]).is_err());
        // a forged count larger than the remaining bytes fails before
        // any allocation proportional to the count
        let mut rows = vec![RE_ROWS];
        rows.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_reply(&rows).is_err());
    }

    #[test]
    fn frame_dispatch_is_unambiguous() {
        // JSON bodies start with '{'; binary bodies with an opcode
        // below 0x20 — is_binary_frame separates them from byte one.
        assert!(!is_binary_frame(b"{\"op\":\"hello\"}"));
        assert!(!is_binary_frame(b""));
        let mut buf = Vec::new();
        for req in [
            PsRequest::Hello {
                codec: WireCodec::Binary,
                session: None,
            },
            PsRequest::ServerStats,
            PsRequest::Shutdown,
        ] {
            encode_request(&req, &mut buf).unwrap();
            assert!(is_binary_frame(&buf));
        }
    }
}
