//! Readiness-driven server core: a dependency-light poller (epoll on
//! Linux, `poll(2)` on other unix) plus the nonblocking event loop
//! [`ServerCore`] that replaced the shard server's thread-per-
//! connection model.
//!
//! # Thread model — which thread owns which buffer
//!
//! One **poll thread** (the caller of [`ServerCore::run`]) owns every
//! per-connection state machine: the raw [`Stream`], its partial-read
//! buffer `rbuf`, and its pending-write buffer `wbuf`.  All socket I/O
//! happens on this thread, nonblocking, driven by readiness events; no
//! other thread ever touches a socket or a connection buffer.
//!
//! A small **worker pool** (O(cores), not O(connections)) executes
//! decoded requests: the poll thread extracts one complete frame body
//! from `rbuf`, hands the owned bytes to a worker through an mpsc
//! channel, and the worker calls [`FrameHandler::on_frame`] — for the
//! shard server that is decode → `ShardServer::handle` against the
//! `&self` engine → encode into a fresh reply buffer.  The finished
//! reply travels back through a completion queue; a byte written to a
//! self-wake pipe (a `UnixStream::pair`) makes the poller return so
//! the poll thread can copy the reply into the connection's `wbuf` and
//! flush as writability allows.  Buffer hand-off is by ownership
//! transfer (`Vec<u8>` moves through the channels), so no frame bytes
//! are ever shared between threads.
//!
//! Per-connection ordering: a connection with a request in flight
//! queues further frames (`pending`) instead of dispatching them, so
//! replies go back in request order even though different connections
//! execute concurrently on the pool.
//!
//! **Session fairness** (optional): when a [`SessionThrottle`] is
//! installed, every completed frame reports the tuning session it
//! belonged to and its row cost ([`FrameResult::session`] /
//! [`FrameResult::cost_rows`]) and the poll thread debits a post-paid
//! per-session token bucket.  A session over its configured rows/sec
//! share has its connections' further frames *deferred* — parked in
//! the same per-connection `pending` queues, never dropped — and
//! re-dispatched as the bucket refills; the poll timeout is bounded
//! while anything is deferred so refills are observed even with no
//! new traffic.  Without a throttle the dispatch path is untouched.
//!
//! Accept errors never terminate the listener: transient `accept()`
//! failures (`EMFILE`, aborted handshakes, …) are counted, logged,
//! and retried after a short backoff — a garbage or failed connection
//! must not take the server down for the other clients (regression-
//! tested in `ps::remote`).

#[cfg(unix)]
use std::collections::{HashMap, VecDeque};
#[cfg(unix)]
use std::io::{Read, Write};
#[cfg(unix)]
use std::os::unix::io::{AsRawFd, RawFd};
#[cfg(unix)]
use std::os::unix::net::UnixStream;
use std::sync::atomic::AtomicU64;
#[cfg(unix)]
use std::sync::atomic::Ordering;
#[cfg(unix)]
use std::sync::{mpsc, Mutex, MutexGuard};

#[cfg(unix)]
use anyhow::{anyhow, bail, Context, Result};

#[cfg(unix)]
use super::socket::{decode_length_frame, Framing, PsListener, Stream, MAX_FRAME_LEN};
#[cfg(unix)]
use super::SessionId;
use crate::stats::LatencyHist;

/// Transport-level counters owned by whoever runs a [`ServerCore`]
/// (the shard server), readable concurrently while the loop runs —
/// this is what feeds the `wire` plane of the stats
/// [`crate::stats::ServerDelta`].
#[derive(Debug, Default)]
pub struct CoreMetrics {
    /// Wire bytes written (headers + payloads).
    pub bytes_tx: AtomicU64,
    /// Wire bytes read.
    pub bytes_rx: AtomicU64,
    /// Connections accepted over the core's lifetime.
    pub conns_accepted: AtomicU64,
    /// Peak simultaneously-open connections.
    pub peak_conns: AtomicU64,
    /// `accept()` errors survived (log-and-continue with backoff).
    pub accept_errors: AtomicU64,
    /// Size of the worker pool (set once at startup; the O(pool)
    /// bound the thread-count acceptance test asserts).
    pub workers: AtomicU64,
    /// Request service-time histogram (decode → handle → encode, as
    /// timed around [`FrameHandler::on_frame`] on the worker pool).
    /// Relaxed atomics — zero hot-path locking.
    pub rpc_hist: LatencyHist,
}

/// One executed request's outcome, produced by a worker thread.
#[cfg(unix)]
#[derive(Default)]
pub struct FrameResult {
    /// Encoded reply frame body (framing header added by the poll
    /// thread).
    pub reply: Vec<u8>,
    /// Flush the reply, then stop accepting and exit the event loop.
    pub shutdown: bool,
    /// `Some(interval_ms)`: after queuing the reply, mark this
    /// connection subscribed to [`FrameHandler::on_tick`] pushes at
    /// roughly that cadence (the poll thread clamps it).
    pub subscribe: Option<u64>,
    /// Tuning session this frame belonged to, when known.  The poll
    /// thread records it on the connection and, if a
    /// [`SessionThrottle`] is installed, debits that session's
    /// fairness bucket by [`FrameResult::cost_rows`] (post-paid).
    pub session: Option<SessionId>,
    /// Parameter rows this frame touched — the fairness currency.
    /// Ignored when `session` is `None` or no throttle is installed.
    pub cost_rows: u64,
}

/// What a [`ServerCore`] serves: one complete frame body in, one
/// reply body out.  Called on worker-pool threads, concurrently
/// across connections — implementations dispatch against `&self`.
#[cfg(unix)]
pub trait FrameHandler: Sync {
    fn on_frame(&self, body: Vec<u8>) -> FrameResult;

    /// Called on the **poll thread** when the tick timer fires and at
    /// least one connection is subscribed (see
    /// [`FrameResult::subscribe`]).  The returned body is framed and
    /// broadcast to every subscribed connection; the tick cadence is
    /// the minimum subscribed interval, so this is the low-priority
    /// push path — it runs between readiness sweeps and never touches
    /// the worker pool or the data plane.
    fn on_tick(&self) -> Option<Vec<u8>> {
        None
    }
}

#[cfg(unix)]
fn lock<'a, T>(m: &'a Mutex<T>) -> MutexGuard<'a, T> {
    // a poisoned queue only means another worker panicked mid-push;
    // the data is a plain VecDeque/Receiver and stays usable
    m.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(unix)]
fn as_u64(n: usize) -> u64 {
    u64::try_from(n).unwrap_or(u64::MAX)
}

// ---------------------------------------------------------------------------
// SessionThrottle: per-session data-plane fairness
// ---------------------------------------------------------------------------

/// Post-paid per-session token bucket enforcing a rows/sec share on
/// the data plane.
///
/// The poll thread charges each completed frame's row cost to the
/// session it belonged to; a session whose accumulated debt exceeds
/// one second's share is *throttled* — its connections' queued frames
/// are deferred (held in the per-connection `pending` queues, never
/// dropped) until refill pays the debt back down.  Every method takes
/// the caller's clock (`now_ms`, any monotonic millisecond base), so
/// the arithmetic is deterministic under test.
///
/// Shared between the poll thread (charging/deferring) and the stats
/// plane (reading deferral counters for the per-session census), so
/// state sits behind a mutex — taken per completed frame, not per
/// row, and only when fairness is enabled at all.
#[cfg(unix)]
pub struct SessionThrottle {
    /// Configured per-session share, rows per second (min 1).
    rows_per_sec: u64,
    /// Debt a session may carry before deferral kicks in: one
    /// second's share, so short bursts pass without jitter.
    burst_rows: u64,
    state: Mutex<ThrottleState>,
}

#[cfg(unix)]
#[derive(Default)]
struct ThrottleState {
    buckets: HashMap<SessionId, Bucket>,
    /// Deferral events per session over the throttle's lifetime
    /// (monotonic; feeds `stats::SessionStats::deferrals`).
    deferrals: HashMap<SessionId, u64>,
}

#[cfg(unix)]
struct Bucket {
    /// Unpaid row debt.
    debt_rows: u64,
    /// Clock of the last refill that credited anything — partial
    /// milliseconds of credit carry over by *not* advancing this.
    last_ms: u64,
}

#[cfg(unix)]
impl SessionThrottle {
    pub fn new(rows_per_sec: u64) -> SessionThrottle {
        let rows_per_sec = rows_per_sec.max(1);
        SessionThrottle {
            rows_per_sec,
            burst_rows: rows_per_sec,
            state: Mutex::new(ThrottleState::default()),
        }
    }

    /// The configured per-session share in rows/sec.
    pub fn rows_per_sec(&self) -> u64 {
        self.rows_per_sec
    }

    fn refill(&self, b: &mut Bucket, now_ms: u64) {
        let elapsed = now_ms.saturating_sub(b.last_ms);
        let credit = elapsed.saturating_mul(self.rows_per_sec) / 1000;
        if credit > 0 {
            b.debt_rows = b.debt_rows.saturating_sub(credit);
            b.last_ms = now_ms;
        }
    }

    /// Debit `rows` against `session`'s bucket.  Post-paid: the frame
    /// already executed; the debt throttles *future* dispatch.
    pub fn charge(&self, session: SessionId, rows: u64, now_ms: u64) {
        let mut st = lock(&self.state);
        let b = st.buckets.entry(session).or_insert(Bucket {
            debt_rows: 0,
            last_ms: now_ms,
        });
        self.refill(b, now_ms);
        b.debt_rows = b.debt_rows.saturating_add(rows);
    }

    /// Whether `session` is over its share (debt beyond the burst
    /// allowance) after refilling at `now_ms`.
    pub fn throttled(&self, session: SessionId, now_ms: u64) -> bool {
        let mut st = lock(&self.state);
        let Some(b) = st.buckets.get_mut(&session) else {
            return false;
        };
        self.refill(b, now_ms);
        b.debt_rows > self.burst_rows
    }

    /// Count one deferral event against `session` — the poll thread
    /// calls this whenever dispatch is held back by the throttle.
    pub fn note_deferral(&self, session: SessionId) {
        let mut st = lock(&self.state);
        *st.deferrals.entry(session).or_insert(0) += 1;
    }

    /// Milliseconds until the most-ready throttled session drops back
    /// under its burst allowance; `None` when nothing is throttled.
    /// The poll thread bounds its wait by this while frames sit
    /// deferred.
    pub fn ready_in_ms(&self, now_ms: u64) -> Option<u64> {
        let mut st = lock(&self.state);
        let mut soonest: Option<u64> = None;
        for b in st.buckets.values_mut() {
            self.refill(b, now_ms);
            let excess = b.debt_rows.saturating_sub(self.burst_rows);
            if excess == 0 {
                continue;
            }
            // ceil(excess / rows-per-ms), saturating — an absurd debt
            // just means "wait the maximum bound"
            let num = excess.saturating_mul(1000).saturating_add(self.rows_per_sec - 1);
            let ms = (num / self.rows_per_sec).max(1);
            soonest = Some(soonest.map_or(ms, |s| s.min(ms)));
        }
        soonest
    }

    /// Lifetime deferral counts per session, sorted by session id —
    /// the source for `stats::SessionStats::deferrals`.
    pub fn deferrals(&self) -> Vec<(SessionId, u64)> {
        let st = lock(&self.state);
        let mut out: Vec<(SessionId, u64)> = st.deferrals.iter().map(|(s, n)| (*s, *n)).collect();
        out.sort_unstable_by_key(|(s, _)| *s);
        out
    }
}

/// `true` iff fairness is enabled, the connection's session is known,
/// and that session is over budget right now.
#[cfg(unix)]
fn is_throttled(
    throttle: Option<&SessionThrottle>,
    session: Option<SessionId>,
    now_ms: u64,
) -> bool {
    match (throttle, session) {
        (Some(t), Some(s)) => t.throttled(s, now_ms),
        _ => false,
    }
}

#[cfg(unix)]
fn note_deferral(throttle: Option<&SessionThrottle>, session: Option<SessionId>) {
    if let (Some(t), Some(s)) = (throttle, session) {
        t.note_deferral(s);
    }
}

// ---------------------------------------------------------------------------
// Poller: epoll (Linux) / poll(2) (other unix)
// ---------------------------------------------------------------------------

/// One readiness event: `token` is the caller's registration key.
#[cfg(unix)]
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
}

#[cfg(target_os = "linux")]
mod sys {
    //! Hand-declared epoll FFI against the system libc — no crates.
    pub const EPOLLIN: u32 = 0x1;
    pub const EPOLLOUT: u32 = 0x4;
    pub const EPOLLERR: u32 = 0x8;
    pub const EPOLLHUP: u32 = 0x10;
    pub const EPOLL_CTL_ADD: i32 = 1;
    pub const EPOLL_CTL_DEL: i32 = 2;
    pub const EPOLL_CTL_MOD: i32 = 3;
    pub const EPOLL_CLOEXEC: i32 = 0x80000;

    /// `struct epoll_event`; packed on x86_64 (the kernel ABI),
    /// naturally aligned elsewhere.
    #[derive(Clone, Copy)]
    #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
    #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    extern "C" {
        pub fn epoll_create1(flags: i32) -> i32;
        pub fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        pub fn epoll_wait(epfd: i32, evs: *mut EpollEvent, max: i32, timeout_ms: i32) -> i32;
        pub fn close(fd: i32) -> i32;
    }
}

#[cfg(all(unix, not(target_os = "linux")))]
mod sys {
    //! Hand-declared `poll(2)` FFI for the non-Linux unix fallback.
    pub const POLLIN: i16 = 0x1;
    pub const POLLOUT: i16 = 0x4;

    #[derive(Clone, Copy)]
    #[repr(C)]
    pub struct PollFd {
        pub fd: i32,
        pub events: i16,
        pub revents: i16,
    }

    extern "C" {
        pub fn poll(fds: *mut PollFd, nfds: std::os::raw::c_ulong, timeout: i32) -> i32;
    }
}

/// Level-triggered readiness poller over raw fds.
///
/// Linux: one `epoll` instance, `O(ready)` wakeups.  Other unix: a
/// registration table swept through `poll(2)` per wait.  Both expose
/// the same tiny API, which is all the event loop needs.
#[cfg(target_os = "linux")]
pub struct Poller {
    epfd: RawFd,
    /// Reused kernel-event buffer (one syscall writes into it).
    ebuf: Vec<sys::EpollEvent>,
}

#[cfg(target_os = "linux")]
impl Poller {
    pub fn new() -> Result<Poller> {
        // SAFETY: plain syscall, no pointers.
        let epfd = unsafe { sys::epoll_create1(sys::EPOLL_CLOEXEC) };
        if epfd < 0 {
            return Err(anyhow!(std::io::Error::last_os_error()).context("epoll_create1"));
        }
        Ok(Poller {
            epfd,
            ebuf: vec![sys::EpollEvent { events: 0, data: 0 }; 256],
        })
    }

    fn ctl(&self, op: i32, fd: RawFd, token: u64, readable: bool, writable: bool) -> Result<()> {
        let mut ev = sys::EpollEvent {
            events: interest_bits(readable, writable),
            data: token,
        };
        let evp: *mut sys::EpollEvent = if op == sys::EPOLL_CTL_DEL {
            std::ptr::null_mut()
        } else {
            &mut ev
        };
        // SAFETY: evp is null (DEL) or points at a live EpollEvent.
        let rc = unsafe { sys::epoll_ctl(self.epfd, op, fd, evp) };
        if rc < 0 {
            return Err(anyhow!(std::io::Error::last_os_error()).context("epoll_ctl"));
        }
        Ok(())
    }

    pub fn register(&mut self, fd: RawFd, token: u64, read: bool, write: bool) -> Result<()> {
        self.ctl(sys::EPOLL_CTL_ADD, fd, token, read, write)
    }

    pub fn modify(&mut self, fd: RawFd, token: u64, read: bool, write: bool) -> Result<()> {
        self.ctl(sys::EPOLL_CTL_MOD, fd, token, read, write)
    }

    pub fn deregister(&mut self, fd: RawFd) -> Result<()> {
        self.ctl(sys::EPOLL_CTL_DEL, fd, 0, false, false)
    }

    /// Block until at least one registered fd is ready (`timeout_ms <
    /// 0` = forever); ready events are appended to `out` (cleared
    /// first).
    pub fn wait(&mut self, out: &mut Vec<Event>, timeout_ms: i32) -> Result<()> {
        out.clear();
        let max = i32::try_from(self.ebuf.len()).unwrap_or(i32::MAX);
        // SAFETY: ebuf is a live buffer of `max` EpollEvents.
        let n = unsafe { sys::epoll_wait(self.epfd, self.ebuf.as_mut_ptr(), max, timeout_ms) };
        if n < 0 {
            let err = std::io::Error::last_os_error();
            if err.kind() == std::io::ErrorKind::Interrupted {
                return Ok(());
            }
            return Err(anyhow!(err).context("epoll_wait"));
        }
        let n = usize::try_from(n).unwrap_or(0);
        for ev in self.ebuf.iter().take(n) {
            // copy out of the (possibly packed) struct before use
            let bits = ev.events;
            let token = ev.data;
            // errors/hangups surface as both: the conn does I/O and
            // observes the failure there
            let trouble = sys::EPOLLERR | sys::EPOLLHUP;
            out.push(Event {
                token,
                readable: (bits & (sys::EPOLLIN | trouble)) != 0,
                writable: (bits & (sys::EPOLLOUT | trouble)) != 0,
            });
        }
        Ok(())
    }
}

#[cfg(target_os = "linux")]
fn interest_bits(readable: bool, writable: bool) -> u32 {
    let mut bits = 0;
    if readable {
        bits |= sys::EPOLLIN;
    }
    if writable {
        bits |= sys::EPOLLOUT;
    }
    bits
}

#[cfg(target_os = "linux")]
impl Drop for Poller {
    fn drop(&mut self) {
        // SAFETY: epfd came from epoll_create1 and is closed once.
        unsafe { sys::close(self.epfd) };
    }
}

#[cfg(all(unix, not(target_os = "linux")))]
pub struct Poller {
    /// (fd, token, readable, writable) registration table.
    regs: Vec<(RawFd, u64, bool, bool)>,
}

#[cfg(all(unix, not(target_os = "linux")))]
impl Poller {
    pub fn new() -> Result<Poller> {
        Ok(Poller { regs: Vec::new() })
    }

    pub fn register(&mut self, fd: RawFd, token: u64, read: bool, write: bool) -> Result<()> {
        if self.regs.iter().any(|(f, ..)| *f == fd) {
            bail!("fd {fd} already registered");
        }
        self.regs.push((fd, token, read, write));
        Ok(())
    }

    pub fn modify(&mut self, fd: RawFd, token: u64, read: bool, write: bool) -> Result<()> {
        for r in &mut self.regs {
            if r.0 == fd {
                *r = (fd, token, read, write);
                return Ok(());
            }
        }
        bail!("fd {fd} not registered")
    }

    pub fn deregister(&mut self, fd: RawFd) -> Result<()> {
        self.regs.retain(|(f, ..)| *f != fd);
        Ok(())
    }

    pub fn wait(&mut self, out: &mut Vec<Event>, timeout_ms: i32) -> Result<()> {
        out.clear();
        let mut fds: Vec<sys::PollFd> = self
            .regs
            .iter()
            .map(|(fd, _, readable, writable)| {
                let mut events = 0;
                if *readable {
                    events |= sys::POLLIN;
                }
                if *writable {
                    events |= sys::POLLOUT;
                }
                sys::PollFd { fd: *fd, events, revents: 0 }
            })
            .collect();
        let nfds = std::os::raw::c_ulong::try_from(fds.len())
            .map_err(|_| anyhow!("too many fds ({})", fds.len()))?;
        // SAFETY: fds is a live array of nfds PollFds.
        let n = unsafe { sys::poll(fds.as_mut_ptr(), nfds, timeout_ms) };
        if n < 0 {
            let err = std::io::Error::last_os_error();
            if err.kind() == std::io::ErrorKind::Interrupted {
                return Ok(());
            }
            return Err(anyhow!(err).context("poll"));
        }
        for (pfd, (_, token, ..)) in fds.iter().zip(&self.regs) {
            if pfd.revents != 0 {
                // POLLERR/POLLHUP/POLLNVAL surface as both directions
                let trouble = pfd.revents & !(sys::POLLIN | sys::POLLOUT) != 0;
                out.push(Event {
                    token: *token,
                    readable: trouble || (pfd.revents & sys::POLLIN) != 0,
                    writable: trouble || (pfd.revents & sys::POLLOUT) != 0,
                });
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// ServerCore: the event loop
// ---------------------------------------------------------------------------

#[cfg(unix)]
const TOKEN_LISTENER: u64 = 0;
#[cfg(unix)]
const TOKEN_WAKE: u64 = 1;
#[cfg(unix)]
const FIRST_CONN_TOKEN: u64 = 2;

/// Per-connection state machine, owned exclusively by the poll thread.
#[cfg(unix)]
struct ConnState {
    stream: Stream,
    /// Bytes read but not yet framed (partial frames accumulate here).
    rbuf: Vec<u8>,
    /// Framed reply bytes not yet written; `wpos` is the write cursor.
    wbuf: Vec<u8>,
    wpos: usize,
    /// A request from this connection is on the worker pool.
    busy: bool,
    /// Frames decoded while busy — dispatched one at a time to keep
    /// per-connection request/reply ordering.
    pending: VecDeque<Vec<u8>>,
    /// Peer half-closed (EOF read); drain outstanding work then drop.
    eof: bool,
    /// Unrecoverable I/O or framing error; drop at the next sweep.
    dead: bool,
    /// Currently registered for writability (epoll interest cache).
    want_write: bool,
    /// Stats-stream subscription interval in ms (see
    /// [`FrameResult::subscribe`]); `None` = not subscribed.
    subscribed: Option<u64>,
    /// Tuning session this connection's traffic is attributed to,
    /// learned from the first completed frame that reported one (see
    /// [`FrameResult::session`]); the fairness plane throttles by it.
    session: Option<SessionId>,
}

#[cfg(unix)]
impl ConnState {
    fn new(stream: Stream) -> ConnState {
        ConnState {
            stream,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            wpos: 0,
            busy: false,
            pending: VecDeque::new(),
            eof: false,
            dead: false,
            want_write: false,
            subscribed: None,
            session: None,
        }
    }

    fn flushed(&self) -> bool {
        self.wpos == self.wbuf.len()
    }

    /// Drained and idle: safe to drop after peer EOF.
    fn finished(&self) -> bool {
        self.eof && self.flushed() && !self.busy && self.pending.is_empty()
    }
}

/// Extract one complete frame body from the front of `rbuf`.
/// `Ok(None)` = need more bytes; errors are unrecoverable framing
/// garbage (close the connection).
#[cfg(unix)]
fn extract_frame(framing: Framing, rbuf: &[u8]) -> Result<Option<(Vec<u8>, usize)>> {
    match framing {
        Framing::Line => match rbuf.iter().position(|b| *b == b'\n') {
            None if rbuf.len() > MAX_FRAME_LEN => bail!("line frame exceeds {MAX_FRAME_LEN}"),
            None => Ok(None),
            Some(i) => {
                let mut end = i;
                while end > 0 && rbuf[end - 1] == b'\r' {
                    end -= 1;
                }
                Ok(Some((rbuf[..end].to_vec(), i + 1)))
            }
        },
        Framing::Length | Framing::Binary => decode_length_frame(rbuf),
    }
}

/// Append one framed reply to `wbuf`.
#[cfg(unix)]
fn frame_reply(framing: Framing, body: &[u8], wbuf: &mut Vec<u8>) -> Result<()> {
    match framing {
        Framing::Line => {
            if body.contains(&b'\n') {
                bail!("line framing cannot carry embedded newlines");
            }
            wbuf.extend_from_slice(body);
            wbuf.push(b'\n');
        }
        Framing::Length | Framing::Binary => {
            if body.len() > MAX_FRAME_LEN {
                bail!("frame length {} exceeds maximum {MAX_FRAME_LEN}", body.len());
            }
            let len = u32::try_from(body.len())
                .map_err(|_| anyhow!("frame length {} exceeds u32", body.len()))?;
            wbuf.extend_from_slice(&len.to_be_bytes());
            wbuf.extend_from_slice(body);
        }
    }
    Ok(())
}

/// The readiness-driven replacement for thread-per-connection serving:
/// one poll thread owns all sockets and buffers, `workers` threads
/// execute requests.  See the module docs for the full thread model.
#[cfg(unix)]
pub struct ServerCore<'a, H: FrameHandler> {
    pub listener: PsListener,
    pub framing: Framing,
    pub handler: &'a H,
    pub metrics: &'a CoreMetrics,
    /// Worker-pool size; clamped to at least 1.
    pub workers: usize,
    /// Optional per-session fairness plane.  `None` (the default
    /// deployment) leaves the dispatch path byte-identical to the
    /// pre-fairness behavior.
    pub throttle: Option<&'a SessionThrottle>,
}

/// Default worker-pool size: the machine's parallelism, clamped to
/// [2, 8] — request execution is lock-bound on the shard engine, so
/// more threads than that only adds convoying.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(2, 8)
}

#[cfg(unix)]
impl<H: FrameHandler> ServerCore<'_, H> {
    /// Run the event loop until a handler asks for shutdown (its reply
    /// is flushed first) or the poller fails fatally.  Accept errors
    /// are survived; connection errors only drop that connection.
    pub fn run(self) -> Result<()> {
        let ServerCore {
            listener,
            framing,
            handler,
            metrics,
            workers,
            throttle,
        } = self;
        listener.set_nonblocking(true).context("listener nonblocking")?;
        let mut poller = Poller::new()?;
        poller.register(listener.as_raw_fd(), TOKEN_LISTENER, true, false)?;
        let (mut wake_rx, wake_tx) = UnixStream::pair().context("wake pipe")?;
        wake_rx.set_nonblocking(true)?;
        wake_tx.set_nonblocking(true)?;
        poller.register(wake_rx.as_raw_fd(), TOKEN_WAKE, true, false)?;

        let nworkers = workers.max(1);
        metrics.workers.store(as_u64(nworkers), Ordering::Relaxed);
        let (jobs_tx, jobs_rx) = mpsc::channel::<(u64, Vec<u8>)>();
        let jobs_rx = Mutex::new(jobs_rx);
        let completions: Mutex<VecDeque<(u64, FrameResult)>> = Mutex::new(VecDeque::new());

        std::thread::scope(|scope| -> Result<()> {
            for _ in 0..nworkers {
                let jobs_rx = &jobs_rx;
                let completions = &completions;
                let mut wake = wake_tx.try_clone().context("cloning wake pipe")?;
                scope.spawn(move || loop {
                    // holding the lock only across recv: one idle
                    // worker blocks here, the rest queue on the mutex
                    let job = lock(jobs_rx).recv();
                    let Ok((token, body)) = job else { break };
                    let t0 = std::time::Instant::now();
                    let result = handler.on_frame(body);
                    // service time (decode → handle → encode) into the
                    // coarse log2 histogram; relaxed, never blocks
                    metrics
                        .rpc_hist
                        .record_micros(u64::try_from(t0.elapsed().as_micros()).unwrap_or(u64::MAX));
                    lock(completions).push_back((token, result));
                    // a full pipe already guarantees a pending wakeup
                    let _ = wake.write(&[1u8]);
                });
            }

            let mut conns: HashMap<u64, ConnState> = HashMap::new();
            let mut events: Vec<Event> = Vec::new();
            let mut scratch = vec![0u8; 64 * 1024];
            let mut next_token = FIRST_CONN_TOKEN;
            let mut accepting = true;
            // token of the connection owed the shutdown ack
            let mut shutting: Option<u64> = None;
            let mut accept_backoff_ms: u64 = 1;
            // stats-push ticker state: the poll thread *is* the ticker,
            // so pushes cost nothing when nobody is subscribed
            let mut last_tick = std::time::Instant::now();
            // fairness clock: monotonic ms since loop start, injected
            // into the throttle so its arithmetic stays clock-agnostic
            let clock0 = std::time::Instant::now();
            let now_ms = || u64::try_from(clock0.elapsed().as_millis()).unwrap_or(u64::MAX);

            loop {
                // cadence = minimum subscribed interval, clamped so a
                // hostile subscriber can neither spin the loop nor park
                // it for minutes
                let tick_ms: Option<u64> = conns
                    .values()
                    .filter_map(|c| c.subscribed)
                    .min()
                    .map(|ms| ms.clamp(50, 10_000));
                let tick_left: Option<u128> = tick_ms
                    .map(|ms| u128::from(ms).saturating_sub(last_tick.elapsed().as_millis()));
                // while frames sit deferred, bound the wait so bucket
                // refills are observed even with no traffic or ticks
                let throttle_left: Option<u128> = throttle.and_then(|t| {
                    let deferred = conns
                        .values()
                        .any(|c| !c.busy && !c.dead && !c.pending.is_empty());
                    if deferred {
                        Some(u128::from(t.ready_in_ms(now_ms()).unwrap_or(1).clamp(1, 100)))
                    } else {
                        None
                    }
                });
                let timeout = match (tick_left, throttle_left) {
                    (None, None) => -1,
                    (a, b) => {
                        let left = a.unwrap_or(u128::MAX).min(b.unwrap_or(u128::MAX));
                        i32::try_from(left).unwrap_or(i32::MAX)
                    }
                };
                poller.wait(&mut events, timeout)?;
                for ev in events.drain(..) {
                    match ev.token {
                        TOKEN_LISTENER if accepting => loop {
                            match listener.accept_stream() {
                                Ok(stream) => {
                                    accept_backoff_ms = 1;
                                    if stream.set_nonblocking(true).is_err() {
                                        continue;
                                    }
                                    let token = next_token;
                                    next_token += 1;
                                    if poller
                                        .register(stream.as_raw_fd(), token, true, false)
                                        .is_err()
                                    {
                                        continue;
                                    }
                                    conns.insert(token, ConnState::new(stream));
                                    metrics.conns_accepted.fetch_add(1, Ordering::Relaxed);
                                    let live = as_u64(conns.len());
                                    metrics.peak_conns.fetch_max(live, Ordering::Relaxed);
                                }
                                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                                Err(e) => {
                                    // transient accept failure (EMFILE,
                                    // aborted handshake, …): log, back
                                    // off briefly, keep listening — it
                                    // must never take the server down
                                    metrics.accept_errors.fetch_add(1, Ordering::Relaxed);
                                    eprintln!("mltuner serve: accept error (retrying): {e}");
                                    std::thread::sleep(std::time::Duration::from_millis(
                                        accept_backoff_ms,
                                    ));
                                    accept_backoff_ms = (accept_backoff_ms * 2).min(100);
                                    break;
                                }
                            }
                        },
                        TOKEN_LISTENER => {}
                        TOKEN_WAKE => {
                            // drain the wake pipe; completions are
                            // swept below regardless
                            while let Ok(n) = wake_rx.read(&mut scratch) {
                                if n == 0 {
                                    break;
                                }
                            }
                        }
                        token => {
                            let Some(conn) = conns.get_mut(&token) else {
                                continue;
                            };
                            if ev.readable {
                                read_conn(conn, &mut scratch, metrics);
                                extract_and_dispatch(
                                    conn,
                                    token,
                                    framing,
                                    &jobs_tx,
                                    throttle,
                                    now_ms(),
                                );
                            }
                            if ev.writable {
                                flush_conn(conn, metrics);
                            }
                        }
                    }
                }

                // completions: frame replies, kick pending work
                loop {
                    let Some((token, result)) = lock(&completions).pop_front() else {
                        break;
                    };
                    if result.shutdown && shutting.is_none() {
                        accepting = false;
                        let _ = poller.deregister(listener.as_raw_fd());
                        shutting = Some(token);
                    }
                    let Some(conn) = conns.get_mut(&token) else {
                        continue; // connection died while we worked
                    };
                    if let Some(interval) = result.subscribe {
                        conn.subscribed = Some(interval);
                    }
                    if frame_reply(framing, &result.reply, &mut conn.wbuf).is_err() {
                        conn.dead = true;
                    } else {
                        flush_conn(conn, metrics);
                    }
                    // post-paid fairness: attribute the finished frame
                    // to its session and debit the bucket
                    if let Some(s) = result.session {
                        conn.session = Some(s);
                        if let Some(t) = throttle {
                            t.charge(s, result.cost_rows, now_ms());
                        }
                    }
                    if is_throttled(throttle, conn.session, now_ms()) {
                        // over budget: park queued frames (deferred,
                        // never dropped) until the bucket refills
                        if !conn.pending.is_empty() {
                            note_deferral(throttle, conn.session);
                        }
                        conn.busy = false;
                    } else {
                        match conn.pending.pop_front() {
                            Some(body) if !conn.dead => {
                                let _ = jobs_tx.send((token, body));
                            }
                            _ => conn.busy = false,
                        }
                    }
                }

                // throttle re-dispatch: deferred frames re-enter the
                // normal per-connection queue as buckets refill
                if throttle.is_some() {
                    let now = now_ms();
                    for (token, conn) in &mut conns {
                        if conn.busy || conn.dead || conn.pending.is_empty() {
                            continue;
                        }
                        if is_throttled(throttle, conn.session, now) {
                            continue;
                        }
                        if let Some(body) = conn.pending.pop_front() {
                            conn.busy = true;
                            let _ = jobs_tx.send((*token, body));
                        }
                    }
                }

                // tick: broadcast one stats delta to every subscriber.
                // Framed per-connection on this thread — the push path
                // never touches the worker pool or the data plane.
                if let Some(ms) = tick_ms {
                    if last_tick.elapsed().as_millis() >= u128::from(ms) {
                        last_tick = std::time::Instant::now();
                        if let Some(body) = handler.on_tick() {
                            for conn in conns.values_mut() {
                                if conn.subscribed.is_none() || conn.dead {
                                    continue;
                                }
                                if frame_reply(framing, &body, &mut conn.wbuf).is_err() {
                                    conn.dead = true;
                                } else {
                                    flush_conn(conn, metrics);
                                }
                            }
                        }
                    }
                }

                // reconcile epoll write interest with buffer state
                for (token, conn) in &mut conns {
                    let want = !conn.flushed() && !conn.dead;
                    if want != conn.want_write {
                        conn.want_write = want;
                        if poller
                            .modify(conn.stream.as_raw_fd(), *token, true, want)
                            .is_err()
                        {
                            conn.dead = true;
                        }
                    }
                }

                // sweep dead and drained-after-EOF connections
                let drop_tokens: Vec<u64> = conns
                    .iter()
                    .filter(|(_, c)| c.dead || c.finished())
                    .map(|(t, _)| *t)
                    .collect();
                for token in drop_tokens {
                    if let Some(conn) = conns.remove(&token) {
                        let _ = poller.deregister(conn.stream.as_raw_fd());
                    }
                }

                if let Some(token) = shutting {
                    match conns.get(&token) {
                        // ack flushed (or its connection vanished):
                        // the server's work is done
                        None => break,
                        Some(conn) if conn.flushed() && !conn.busy => break,
                        Some(_) => {}
                    }
                }
            }
            drop(jobs_tx); // workers see the hangup and exit
            Ok(())
        })
    }
}

/// Nonblocking read into `rbuf` until `WouldBlock`/EOF/error.
#[cfg(unix)]
fn read_conn(conn: &mut ConnState, scratch: &mut [u8], metrics: &CoreMetrics) {
    loop {
        match conn.stream.read(scratch) {
            Ok(0) => {
                conn.eof = true;
                break;
            }
            Ok(n) => {
                conn.rbuf.extend_from_slice(&scratch[..n]);
                metrics.bytes_rx.fetch_add(as_u64(n), Ordering::Relaxed);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => {
                conn.dead = true;
                break;
            }
        }
    }
}

/// Frame out everything `rbuf` holds; dispatch the first frame if the
/// connection is idle and its session under budget, queue the rest.
/// A frame held back *only* by the throttle counts as a deferral.
#[cfg(unix)]
fn extract_and_dispatch(
    conn: &mut ConnState,
    token: u64,
    framing: Framing,
    jobs_tx: &mpsc::Sender<(u64, Vec<u8>)>,
    throttle: Option<&SessionThrottle>,
    now_ms: u64,
) {
    if conn.dead {
        return;
    }
    loop {
        match extract_frame(framing, &conn.rbuf) {
            Ok(None) => break,
            Ok(Some((body, consumed))) => {
                conn.rbuf.drain(..consumed);
                if conn.busy {
                    conn.pending.push_back(body);
                } else if is_throttled(throttle, conn.session, now_ms) {
                    note_deferral(throttle, conn.session);
                    conn.pending.push_back(body);
                } else {
                    conn.busy = true;
                    let _ = jobs_tx.send((token, body));
                }
            }
            Err(_) => {
                // unframeable garbage (oversized header): the stream
                // can never resynchronize — drop this connection only
                conn.dead = true;
                break;
            }
        }
    }
}

/// Write as much of `wbuf` as the socket accepts right now.
#[cfg(unix)]
fn flush_conn(conn: &mut ConnState, metrics: &CoreMetrics) {
    while conn.wpos < conn.wbuf.len() {
        match conn.stream.write(&conn.wbuf[conn.wpos..]) {
            Ok(0) => {
                conn.dead = true;
                break;
            }
            Ok(n) => {
                conn.wpos += n;
                metrics.bytes_tx.fetch_add(as_u64(n), Ordering::Relaxed);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => {
                conn.dead = true;
                break;
            }
        }
    }
    if conn.flushed() {
        conn.wbuf.clear();
        conn.wpos = 0;
    }
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;
    use crate::comm::socket::SocketSpec;

    #[test]
    fn poller_reports_readability() {
        let mut poller = Poller::new().unwrap();
        let (mut a, mut b) = UnixStream::pair().unwrap();
        b.set_nonblocking(true).unwrap();
        poller.register(b.as_raw_fd(), 7, true, false).unwrap();
        let mut events = Vec::new();
        // nothing readable yet: a zero timeout returns empty
        poller.wait(&mut events, 0).unwrap();
        assert!(events.iter().all(|e| e.token != 7 || !e.readable));
        a.write_all(b"x").unwrap();
        poller.wait(&mut events, 1000).unwrap();
        assert!(events.iter().any(|e| e.token == 7 && e.readable));
        let mut buf = [0u8; 8];
        let n = b.read(&mut buf).unwrap();
        assert_eq!(&buf[..n], b"x");
        poller.deregister(b.as_raw_fd()).unwrap();
    }

    /// Uppercases every frame; shuts down on the frame "stop".
    struct Shout;
    impl FrameHandler for Shout {
        fn on_frame(&self, body: Vec<u8>) -> FrameResult {
            let shutdown = body == b"stop";
            FrameResult {
                reply: body.to_ascii_uppercase(),
                shutdown,
                ..FrameResult::default()
            }
        }
    }

    fn run_core(framing: Framing) -> (SocketSpec, std::thread::JoinHandle<()>) {
        let listener = PsListener::bind(&SocketSpec::parse("127.0.0.1:0").unwrap()).unwrap();
        let spec = listener.local_spec().unwrap();
        let handle = std::thread::spawn(move || {
            let metrics = CoreMetrics::default();
            ServerCore {
                listener,
                framing,
                handler: &Shout,
                metrics: &metrics,
                workers: 2,
                throttle: None,
            }
            .run()
            .unwrap();
            assert!(metrics.bytes_rx.load(Ordering::Relaxed) > 0);
            assert!(metrics.bytes_tx.load(Ordering::Relaxed) > 0);
        });
        (spec, handle)
    }

    #[test]
    fn event_loop_serves_concurrent_connections() {
        for framing in [Framing::Line, Framing::Length, Framing::Binary] {
            let (spec, handle) = run_core(framing);
            let clients: Vec<_> = (0..8)
                .map(|i| {
                    let spec = spec.clone();
                    std::thread::spawn(move || {
                        let mut conn = spec.connect(framing).unwrap();
                        for round in 0..5 {
                            let msg = format!("c{i}r{round}");
                            conn.send(&msg).unwrap();
                            assert_eq!(conn.recv_expect().unwrap(), msg.to_uppercase());
                        }
                    })
                })
                .collect();
            for c in clients {
                c.join().unwrap();
            }
            let mut conn = spec.connect(framing).unwrap();
            conn.send("stop").unwrap();
            assert_eq!(conn.recv_expect().unwrap(), "STOP");
            handle.join().unwrap();
        }
    }

    #[test]
    fn garbage_connection_does_not_break_other_clients() {
        let (spec, handle) = run_core(Framing::Binary);
        // a client that sends an unframeable 4 GiB length header gets
        // dropped without disturbing anyone else
        let mut garbage = spec.connect(Framing::Binary).unwrap();
        garbage.send_bytes(b"fine before the garbage").unwrap();
        assert!(garbage.recv_bytes().unwrap().is_some());
        {
            // raw stream write: bypass Conn's header discipline
            let mut raw = match &spec {
                SocketSpec::Tcp(addr) => std::net::TcpStream::connect(addr).unwrap(),
                SocketSpec::Unix(_) => unreachable!(),
            };
            raw.write_all(&[0xff, 0xff, 0xff, 0xff, 1, 2, 3]).unwrap();
            // server drops us; reading eventually sees EOF/reset
        }
        let mut ok = spec.connect(Framing::Binary).unwrap();
        ok.send("still works").unwrap();
        assert_eq!(ok.recv_expect().unwrap(), "STILL WORKS");
        ok.send("stop").unwrap();
        assert_eq!(ok.recv_expect().unwrap(), "STOP");
        handle.join().unwrap();
    }

    #[test]
    fn session_throttle_math_is_deterministic() {
        let t = SessionThrottle::new(1_000); // 1k rows/sec, burst 1k
        // under the burst allowance: never throttled
        t.charge(1, 500, 0);
        assert!(!t.throttled(1, 0));
        // push past burst: throttled until refill pays the debt down
        t.charge(1, 600, 0); // debt 1100 > burst 1000
        assert!(t.throttled(1, 0));
        assert_eq!(t.ready_in_ms(0), Some(100));
        // 99 ms of credit leaves debt 1001: still over…
        assert!(t.throttled(1, 99));
        // …one more millisecond clears it exactly
        assert!(!t.throttled(1, 100));
        assert_eq!(t.ready_in_ms(100), None);
        // sessions are independent
        t.charge(2, 10_000, 100);
        assert!(t.throttled(2, 100));
        assert!(!t.throttled(1, 100));
        // deferral counters are monotonic and per-session
        t.note_deferral(2);
        t.note_deferral(2);
        assert_eq!(t.deferrals(), vec![(2, 2)]);
    }

    /// Echoes frames, attributing each to session 1 at a fixed row
    /// cost, so the throttle path is exercised end to end.
    struct Metered;
    impl FrameHandler for Metered {
        fn on_frame(&self, body: Vec<u8>) -> FrameResult {
            FrameResult {
                shutdown: body == b"stop",
                reply: body,
                session: Some(1),
                cost_rows: 60_000,
                ..FrameResult::default()
            }
        }
    }

    #[test]
    fn throttled_session_frames_are_deferred_not_dropped() {
        let throttle = SessionThrottle::new(200_000); // burst: 200k rows
        let listener = PsListener::bind(&SocketSpec::parse("127.0.0.1:0").unwrap()).unwrap();
        let spec = listener.local_spec().unwrap();
        let metrics = CoreMetrics::default();
        std::thread::scope(|scope| {
            let throttle = &throttle;
            let metrics = &metrics;
            scope.spawn(move || {
                ServerCore {
                    listener,
                    framing: Framing::Length,
                    handler: &Metered,
                    metrics,
                    workers: 2,
                    throttle: Some(throttle),
                }
                .run()
                .unwrap();
            });
            let mut conn = spec.connect(Framing::Length).unwrap();
            // 8 frames × 60k rows ≫ the 200k burst: the tail must be
            // deferred, yet every reply still arrives, in order
            for i in 0..8 {
                conn.send(&format!("f{i}")).unwrap();
            }
            for i in 0..8 {
                assert_eq!(conn.recv_expect().unwrap(), format!("f{i}"));
            }
            conn.send("stop").unwrap();
            assert_eq!(conn.recv_expect().unwrap(), "stop");
        });
        let deferred: u64 = throttle.deferrals().iter().map(|(_, n)| *n).sum();
        assert!(deferred > 0, "expected the over-budget tail to defer");
    }

    #[test]
    fn pipelined_frames_reply_in_order() {
        let (spec, handle) = run_core(Framing::Length);
        let mut conn = spec.connect(Framing::Length).unwrap();
        // fire a burst without reading: replies must come back in
        // request order (per-conn pending queue)
        for i in 0..20 {
            conn.send(&format!("burst{i}")).unwrap();
        }
        for i in 0..20 {
            assert_eq!(conn.recv_expect().unwrap(), format!("BURST{i}"));
        }
        conn.send("stop").unwrap();
        assert_eq!(conn.recv_expect().unwrap(), "STOP");
        handle.join().unwrap();
    }
}
