//! In-process message transport (§4.5 "Distributed training support").
//!
//! MLtuner broadcasts every branch operation to all training workers
//! **in the same order**, and each worker reports its per-clock
//! progress separately; MLtuner folds the reports with a user-defined
//! aggregation (sum for the SGD apps).  This module provides that
//! broker over `std::sync::mpsc` channels with the wire encoding of
//! [`super::wire`], so the coordinator-side code is identical whether
//! the workers are threads here or processes on another machine.

use std::sync::mpsc::{channel, Receiver, Sender};

use anyhow::{anyhow, Result};

use super::wire::{decode_system_msg, decode_tuner_msg, encode_system_msg, encode_tuner_msg};
use super::{ProgressAggregation, SystemMsg, TunerMsg};

/// Worker-side endpoint: receives ordered branch-operation lines,
/// sends progress lines back.
pub struct WorkerEndpoint {
    pub worker_id: usize,
    ops_rx: Receiver<String>,
    progress_tx: Sender<(usize, String)>,
}

impl WorkerEndpoint {
    /// Block for the next branch operation.
    pub fn recv(&self) -> Result<TunerMsg> {
        let line = self
            .ops_rx
            .recv()
            .map_err(|_| anyhow!("coordinator hung up"))?;
        decode_tuner_msg(&line)
    }

    /// Report this worker's progress for a clock.
    pub fn report(&self, msg: &SystemMsg) -> Result<()> {
        self.progress_tx
            .send((self.worker_id, encode_system_msg(msg)))
            .map_err(|_| anyhow!("coordinator hung up"))
    }
}

/// Coordinator-side broker: broadcast ops, gather + fold progress.
pub struct Broker {
    ops_tx: Vec<Sender<String>>,
    progress_rx: Receiver<(usize, String)>,
    aggregation: ProgressAggregation,
}

impl Broker {
    /// Create a broker and its `n` worker endpoints.
    pub fn new(n: usize, aggregation: ProgressAggregation) -> (Broker, Vec<WorkerEndpoint>) {
        assert!(n > 0);
        let (progress_tx, progress_rx) = channel();
        let mut ops_tx = Vec::with_capacity(n);
        let mut endpoints = Vec::with_capacity(n);
        for worker_id in 0..n {
            let (tx, rx) = channel();
            ops_tx.push(tx);
            endpoints.push(WorkerEndpoint {
                worker_id,
                ops_rx: rx,
                progress_tx: progress_tx.clone(),
            });
        }
        (
            Broker {
                ops_tx,
                progress_rx,
                aggregation,
            },
            endpoints,
        )
    }

    /// Broadcast one branch operation to every worker, in order.
    pub fn broadcast(&self, msg: &TunerMsg) -> Result<()> {
        let line = encode_tuner_msg(msg);
        for tx in &self.ops_tx {
            tx.send(line.clone())
                .map_err(|_| anyhow!("worker hung up"))?;
        }
        Ok(())
    }

    /// Gather one progress report from every worker for `clock` and
    /// fold them (§4.5: "aggregate the training progress with a
    /// user-defined aggregation function").  Returns (value, max time).
    pub fn gather_progress(&self, clock: u64) -> Result<(f64, f64)> {
        let n = self.ops_tx.len();
        let mut values = vec![f64::NAN; n];
        let mut times = vec![0.0f64; n];
        let mut got = 0;
        while got < n {
            let (worker, line) = self
                .progress_rx
                .recv()
                .map_err(|_| anyhow!("workers hung up"))?;
            let SystemMsg::ReportProgress {
                clock: c,
                progress,
                time,
            } = decode_system_msg(&line)?;
            if c != clock {
                anyhow::bail!("worker {worker} reported clock {c}, expected {clock}");
            }
            if values[worker].is_nan() {
                got += 1;
            }
            values[worker] = progress;
            times[worker] = time;
        }
        // wall time of a data-parallel clock = slowest worker
        let time = times.iter().cloned().fold(0.0, f64::max);
        Ok((self.aggregation.fold(&values), time))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::BranchType;
    use crate::tunable::TunableSetting;

    #[test]
    fn broadcast_reaches_all_workers_in_order() {
        let (broker, endpoints) = Broker::new(3, ProgressAggregation::Sum);
        let msgs = vec![
            TunerMsg::ForkBranch {
                clock: 0,
                branch_id: 1,
                parent_branch_id: Some(0),
                tunable: TunableSetting::new(vec![0.1]),
                branch_type: BranchType::Training,
            },
            TunerMsg::ScheduleBranch {
                clock: 0,
                branch_id: 1,
            },
            TunerMsg::FreeBranch {
                clock: 1,
                branch_id: 1,
            },
        ];
        for m in &msgs {
            broker.broadcast(m).unwrap();
        }
        for ep in &endpoints {
            for expected in &msgs {
                assert_eq!(&ep.recv().unwrap(), expected);
            }
        }
    }

    #[test]
    fn progress_gathering_folds_per_worker_reports() {
        let (broker, endpoints) = Broker::new(4, ProgressAggregation::Sum);
        // workers report out of order — gather must still line up
        for (i, ep) in endpoints.iter().enumerate().rev() {
            ep.report(&SystemMsg::ReportProgress {
                clock: 5,
                progress: (i + 1) as f64,
                time: 0.1 * (i + 1) as f64,
            })
            .unwrap();
        }
        let (value, time) = broker.gather_progress(5).unwrap();
        assert_eq!(value, 1.0 + 2.0 + 3.0 + 4.0);
        assert!((time - 0.4).abs() < 1e-12, "slowest worker's time");
    }

    #[test]
    fn clock_mismatch_is_an_error() {
        let (broker, endpoints) = Broker::new(1, ProgressAggregation::Sum);
        endpoints[0]
            .report(&SystemMsg::ReportProgress {
                clock: 9,
                progress: 1.0,
                time: 0.1,
            })
            .unwrap();
        assert!(broker.gather_progress(5).is_err());
    }

    #[test]
    fn threaded_worker_loop_end_to_end() {
        // Full §4.5 deployment shape: worker threads consuming ordered
        // branch ops and reporting per-clock progress over the wire.
        let n = 4;
        let (broker, endpoints) = Broker::new(n, ProgressAggregation::Sum);
        let handles: Vec<_> = endpoints
            .into_iter()
            .map(|ep| {
                std::thread::spawn(move || {
                    loop {
                        match ep.recv() {
                            Err(_) => break, // coordinator done
                            Ok(TunerMsg::ScheduleBranch { clock, .. }) => {
                                ep.report(&SystemMsg::ReportProgress {
                                    clock,
                                    progress: 1.0 + ep.worker_id as f64,
                                    time: 0.01,
                                })
                                .unwrap();
                            }
                            Ok(_) => {}
                        }
                    }
                })
            })
            .collect();
        broker
            .broadcast(&TunerMsg::ForkBranch {
                clock: 0,
                branch_id: 1,
                parent_branch_id: Some(0),
                tunable: TunableSetting::new(vec![0.5]),
                branch_type: BranchType::Training,
            })
            .unwrap();
        for clock in 0..10u64 {
            broker
                .broadcast(&TunerMsg::ScheduleBranch {
                    clock,
                    branch_id: 1,
                })
                .unwrap();
            let (value, time) = broker.gather_progress(clock).unwrap();
            assert_eq!(value, 1.0 + 2.0 + 3.0 + 4.0);
            assert!(time > 0.0);
        }
        drop(broker); // hang up; workers exit
        for h in handles {
            h.join().unwrap();
        }
    }
}
