//! Wire format for the Table-1 messages (§4.5).
//!
//! MLtuner "works as a separate process that communicates with the
//! training system via messages".  This module gives the messages a
//! concrete wire encoding (line-delimited JSON, parsed by the in-tree
//! `util::json`) so the coordinator and a training system can sit on
//! opposite ends of any byte stream; [`super::transport`] provides the
//! in-process broker used by the simulated deployments.

use anyhow::{anyhow, bail, Result};

use crate::tunable::TunableSetting;
use crate::util::json::Json;

use super::{BranchType, SystemMsg, TunerMsg};

/// Encode one tuner message as a single JSON line.
pub fn encode_tuner_msg(msg: &TunerMsg) -> String {
    match msg {
        TunerMsg::ForkBranch {
            clock,
            branch_id,
            parent_branch_id,
            tunable,
            branch_type,
        } => {
            let parent = parent_branch_id
                .map(|p| p.to_string())
                .unwrap_or_else(|| "null".into());
            let vals: Vec<String> =
                tunable.values.iter().map(|v| format!("{v:e}")).collect();
            format!(
                "{{\"op\":\"fork\",\"clock\":{clock},\"branch\":{branch_id},\"parent\":{parent},\"tunable\":[{}],\"type\":\"{}\"}}",
                vals.join(","),
                match branch_type {
                    BranchType::Training => "training",
                    BranchType::Testing => "testing",
                }
            )
        }
        TunerMsg::FreeBranch { clock, branch_id } => format!(
            "{{\"op\":\"free\",\"clock\":{clock},\"branch\":{branch_id}}}"
        ),
        TunerMsg::ScheduleBranch { clock, branch_id } => format!(
            "{{\"op\":\"schedule\",\"clock\":{clock},\"branch\":{branch_id}}}"
        ),
    }
}

/// Encode one system message as a single JSON line.
pub fn encode_system_msg(msg: &SystemMsg) -> String {
    match msg {
        SystemMsg::ReportProgress {
            clock,
            progress,
            time,
        } => format!(
            "{{\"op\":\"progress\",\"clock\":{clock},\"progress\":{progress:e},\"time\":{time:e}}}"
        ),
    }
}

fn field<'a>(v: &'a Json, k: &str) -> Result<&'a Json> {
    v.get(k).ok_or_else(|| anyhow!("missing field {k}"))
}

/// Decode a tuner message from its wire line.
pub fn decode_tuner_msg(line: &str) -> Result<TunerMsg> {
    let v = Json::parse(line.trim())?;
    let op = field(&v, "op")?
        .as_str()
        .ok_or_else(|| anyhow!("op not a string"))?;
    let clock = field(&v, "clock")?
        .as_f64()
        .ok_or_else(|| anyhow!("bad clock"))? as u64;
    match op {
        "fork" => {
            let branch_id = field(&v, "branch")?
                .as_f64()
                .ok_or_else(|| anyhow!("bad branch"))? as u32;
            let parent_branch_id = match field(&v, "parent")? {
                Json::Null => None,
                p => Some(p.as_f64().ok_or_else(|| anyhow!("bad parent"))? as u32),
            };
            let tunable = TunableSetting::new(
                field(&v, "tunable")?
                    .as_array()
                    .ok_or_else(|| anyhow!("bad tunable"))?
                    .iter()
                    .map(|x| x.as_f64().ok_or_else(|| anyhow!("bad tunable value")))
                    .collect::<Result<Vec<f64>>>()?,
            );
            let branch_type = match field(&v, "type")?.as_str() {
                Some("training") => BranchType::Training,
                Some("testing") => BranchType::Testing,
                other => bail!("bad branch type {other:?}"),
            };
            Ok(TunerMsg::ForkBranch {
                clock,
                branch_id,
                parent_branch_id,
                tunable,
                branch_type,
            })
        }
        "free" | "schedule" => {
            let branch_id = field(&v, "branch")?
                .as_f64()
                .ok_or_else(|| anyhow!("bad branch"))? as u32;
            Ok(if op == "free" {
                TunerMsg::FreeBranch { clock, branch_id }
            } else {
                TunerMsg::ScheduleBranch { clock, branch_id }
            })
        }
        other => bail!("unknown op {other}"),
    }
}

/// Decode a system message from its wire line.
pub fn decode_system_msg(line: &str) -> Result<SystemMsg> {
    let v = Json::parse(line.trim())?;
    match field(&v, "op")?.as_str() {
        Some("progress") => Ok(SystemMsg::ReportProgress {
            clock: field(&v, "clock")?
                .as_f64()
                .ok_or_else(|| anyhow!("bad clock"))? as u64,
            progress: field(&v, "progress")?
                .as_f64()
                .ok_or_else(|| anyhow!("bad progress"))?,
            time: field(&v, "time")?
                .as_f64()
                .ok_or_else(|| anyhow!("bad time"))?,
        }),
        other => bail!("unknown op {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tuner_msgs_roundtrip() {
        let msgs = vec![
            TunerMsg::ForkBranch {
                clock: 7,
                branch_id: 3,
                parent_branch_id: Some(1),
                tunable: TunableSetting::new(vec![1e-3, 0.9, 32.0, 0.0]),
                branch_type: BranchType::Training,
            },
            TunerMsg::ForkBranch {
                clock: 8,
                branch_id: 4,
                parent_branch_id: None,
                tunable: TunableSetting::new(vec![]),
                branch_type: BranchType::Testing,
            },
            TunerMsg::FreeBranch {
                clock: 9,
                branch_id: 3,
            },
            TunerMsg::ScheduleBranch {
                clock: 10,
                branch_id: 4,
            },
        ];
        for m in msgs {
            let line = encode_tuner_msg(&m);
            let back = decode_tuner_msg(&line).unwrap();
            assert_eq!(m, back, "wire: {line}");
        }
    }

    #[test]
    fn system_msgs_roundtrip() {
        let m = SystemMsg::ReportProgress {
            clock: 42,
            progress: -1.25e-3,
            time: 0.5,
        };
        assert_eq!(decode_system_msg(&encode_system_msg(&m)).unwrap(), m);
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(decode_tuner_msg("not json").is_err());
        assert!(decode_tuner_msg("{\"op\":\"dance\",\"clock\":0}").is_err());
        assert!(decode_tuner_msg("{\"op\":\"fork\",\"clock\":0}").is_err());
        assert!(decode_system_msg("{\"op\":\"progress\"}").is_err());
    }

    #[test]
    fn float_precision_survives_the_wire() {
        let m = TunerMsg::ForkBranch {
            clock: 0,
            branch_id: 1,
            parent_branch_id: Some(0),
            tunable: TunableSetting::new(vec![
                1.2345678901234567e-5,
                0.9999999999999999,
            ]),
            branch_type: BranchType::Training,
        };
        let back = decode_tuner_msg(&encode_tuner_msg(&m)).unwrap();
        assert_eq!(m, back);
    }
}
