//! Wire format for the two protocol planes (§4.5, §4.6).
//!
//! MLtuner "works as a separate process that communicates with the
//! training system via messages".  This module gives both planes a
//! concrete wire encoding (one JSON object per frame, parsed by the
//! in-tree `util::json`) so the endpoints can sit on opposite ends of
//! any byte stream — the in-process [`super::transport`] broker or the
//! real sockets of [`super::socket`]:
//!
//! * **Control plane** — the Table-1 tuner/system messages
//!   ([`TunerMsg`]/[`SystemMsg`]): fork/free/schedule broadcast in
//!   clock order, per-clock progress reports folded by the
//!   coordinator.  Human-oriented float encoding (`{v:e}`, shortest
//!   round-trippable decimal).
//! * **Data plane** — the parameter-server RPCs
//!   ([`PsRequest`]/[`PsReply`]) that a remote training process issues
//!   against a shard server: row reads (single `ReadRow` and the
//!   batched `ReadRows`/`RowsData` pair the gather phases ride — one
//!   frame carries a whole per-server key group, with the optional
//!   AdaRevision accumulator snapshot per row), batched updates,
//!   branch fork/free replication, durable branch checkpoint/restore
//!   (`CheckpointBranch`/`RestoreBranch` — each server dumps or
//!   restores its own shard range, see [`crate::ps::checkpoint`]), and
//!   the stats probe.  Row payloads
//!   are `f32` values encoded as their IEEE-754 **bit patterns**
//!   (`u32` integers), so every value — including NaN payloads and the
//!   infinities a diverging trial produces — survives the wire
//!   bit-exact, which is what makes remote training runs bit-identical
//!   to local ones.
//! * **Observability plane** — the versioned stats frames of
//!   [`crate::stats`]: the pull probe ([`PsRequest::ServerStats`] →
//!   [`PsReply::Stats`]) and the push stream
//!   ([`PsRequest::SubscribeStats`] → periodic [`PsReply::StatsDelta`]
//!   frames) share one [`ServerDelta`] payload carrying a `"v"` schema
//!   version, so an old peer fed a newer frame gets a typed decode
//!   error instead of silently misreading fields.  Delta `f64`s
//!   (trial progress/time) ride as hex strings of their IEEE-754 bit
//!   patterns — `{v:e}` cannot emit NaN as valid JSON and plain JSON
//!   numbers cap at 2^53.
//!
//! Numbers are decoded *strictly*: `clock`/`branch`/key/bit-pattern
//! fields reject non-integral, negative, and out-of-range values
//! instead of silently truncating through `as` casts.

use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

use crate::optim::Hyper;
use crate::ps::checkpoint::{hex_u64, parse_hex_u64, SegmentMeta};
use crate::ps::pool::PoolStats;
use crate::ps::storage::{RowKey, TableId};
use crate::ps::RowData;
use crate::stats::{
    ServerDelta, ServerPlane, SessionStats, ShardRows, StorePlane, TrialEvent, WirePlane,
    HIST_BUCKETS, SCHEMA_VERSION,
};
use crate::tunable::TunableSetting;
use crate::util::json::Json;

use super::{BranchId, BranchType, SessionId, SystemMsg, TunerMsg};

/// Payload codec for the PS data plane, negotiated at `Hello`.
///
/// The client advertises the codec it wants in [`PsRequest::Hello`];
/// the server echoes the codec it will actually speak in
/// [`PsReply::Hello`].  [`WireCodec::Json`] is the default and the
/// only codec old peers know — its `Hello` frames carry no `codec`
/// field at all, so negotiation is invisible to them.
/// [`WireCodec::Binary`] selects the fixed little-endian frames of
/// [`super::binwire`] for the data plane; JSON remains the
/// control-plane and debug format either way.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WireCodec {
    #[default]
    Json,
    Binary,
}

impl WireCodec {
    pub fn name(self) -> &'static str {
        match self {
            WireCodec::Json => "json",
            WireCodec::Binary => "binary",
        }
    }
}

/// Encode one tuner message as a single JSON line.
pub fn encode_tuner_msg(msg: &TunerMsg) -> String {
    match msg {
        TunerMsg::ForkBranch {
            clock,
            branch_id,
            parent_branch_id,
            tunable,
            branch_type,
        } => {
            let parent = parent_branch_id
                .map(|p| p.to_string())
                .unwrap_or_else(|| "null".into());
            let vals: Vec<String> =
                tunable.values.iter().map(|v| format!("{v:e}")).collect();
            format!(
                "{{\"op\":\"fork\",\"clock\":{clock},\"branch\":{branch_id},\"parent\":{parent},\"tunable\":[{}],\"type\":\"{}\"}}",
                vals.join(","),
                match branch_type {
                    BranchType::Training => "training",
                    BranchType::Testing => "testing",
                }
            )
        }
        TunerMsg::FreeBranch { clock, branch_id } => format!(
            "{{\"op\":\"free\",\"clock\":{clock},\"branch\":{branch_id}}}"
        ),
        TunerMsg::ScheduleBranch { clock, branch_id } => format!(
            "{{\"op\":\"schedule\",\"clock\":{clock},\"branch\":{branch_id}}}"
        ),
    }
}

/// Encode one system message as a single JSON line.
pub fn encode_system_msg(msg: &SystemMsg) -> String {
    match msg {
        SystemMsg::ReportProgress {
            clock,
            progress,
            time,
        } => format!(
            "{{\"op\":\"progress\",\"clock\":{clock},\"progress\":{progress:e},\"time\":{time:e}}}"
        ),
    }
}

fn field<'a>(v: &'a Json, k: &str) -> Result<&'a Json> {
    v.get(k).ok_or_else(|| anyhow!("missing field {k}"))
}

/// Exclusive integer bound for wire numbers: 2^53.  The JSON number
/// type (f64) represents every integer *below* 2^53 exactly; 2^53
/// itself is excluded because 2^53 + 1 also parses to that same f64,
/// so accepting it would readmit a silent truncation.
const INT_BOUND: f64 = 9_007_199_254_740_992.0;

/// Strictly decode an unsigned integer field: non-numbers,
/// non-integral values, negatives, and values at or beyond 2^53 are
/// errors, never silent `as` truncations.
fn num_u64(v: &Json, what: &str) -> Result<u64> {
    let f = v.as_f64().ok_or_else(|| anyhow!("bad {what}: not a number"))?;
    if !f.is_finite() || f.fract() != 0.0 || !(0.0..INT_BOUND).contains(&f) {
        bail!("bad {what}: {f} is not an unsigned integer");
    }
    // lint:allow(wire-int-cast): this IS the strict helper — the cast
    // is exact for every integral f64 in [0, 2^53) admitted above
    Ok(f as u64)
}

fn num_u32(v: &Json, what: &str) -> Result<u32> {
    let n = num_u64(v, what)?;
    u32::try_from(n).map_err(|_| anyhow!("bad {what}: {n} out of u32 range"))
}

fn num_usize(v: &Json, what: &str) -> Result<usize> {
    let n = num_u64(v, what)?;
    usize::try_from(n).map_err(|_| anyhow!("bad {what}: {n} out of usize range"))
}

/// Decode one `f32` from its wire form (IEEE-754 bit pattern).
fn num_f32_bits(v: &Json, what: &str) -> Result<f32> {
    Ok(f32::from_bits(num_u32(v, what)?))
}

/// Decode one `f64` carried as the hex string of its IEEE-754 bit
/// pattern (see [`hex_u64`]) — bit-exact for every value including
/// NaN payloads, which neither `{v:e}` (invalid JSON for NaN) nor a
/// plain JSON number (2^53 cap) could carry.
fn f64_hex_bits(v: &Json, what: &str) -> Result<f64> {
    let s = v
        .as_str()
        .ok_or_else(|| anyhow!("bad {what}: not a bit-pattern hex string"))?;
    Ok(f64::from_bits(parse_hex_u64(s)?))
}

/// Decode a tuner message from its wire line.
pub fn decode_tuner_msg(line: &str) -> Result<TunerMsg> {
    let v = Json::parse(line.trim())?;
    let op = field(&v, "op")?
        .as_str()
        .ok_or_else(|| anyhow!("op not a string"))?;
    let clock = num_u64(field(&v, "clock")?, "clock")?;
    match op {
        "fork" => {
            let branch_id = num_u32(field(&v, "branch")?, "branch")?;
            let parent_branch_id = match field(&v, "parent")? {
                Json::Null => None,
                p => Some(num_u32(p, "parent")?),
            };
            let tunable = TunableSetting::new(
                field(&v, "tunable")?
                    .as_array()
                    .ok_or_else(|| anyhow!("bad tunable"))?
                    .iter()
                    .map(|x| x.as_f64().ok_or_else(|| anyhow!("bad tunable value")))
                    .collect::<Result<Vec<f64>>>()?,
            );
            let branch_type = match field(&v, "type")?.as_str() {
                Some("training") => BranchType::Training,
                Some("testing") => BranchType::Testing,
                other => bail!("bad branch type {other:?}"),
            };
            Ok(TunerMsg::ForkBranch {
                clock,
                branch_id,
                parent_branch_id,
                tunable,
                branch_type,
            })
        }
        "free" | "schedule" => {
            let branch_id = num_u32(field(&v, "branch")?, "branch")?;
            Ok(if op == "free" {
                TunerMsg::FreeBranch { clock, branch_id }
            } else {
                TunerMsg::ScheduleBranch { clock, branch_id }
            })
        }
        other => bail!("unknown op {other}"),
    }
}

/// Decode a system message from its wire line.
pub fn decode_system_msg(line: &str) -> Result<SystemMsg> {
    let v = Json::parse(line.trim())?;
    match field(&v, "op")?.as_str() {
        Some("progress") => Ok(SystemMsg::ReportProgress {
            clock: num_u64(field(&v, "clock")?, "clock")?,
            progress: field(&v, "progress")?
                .as_f64()
                .ok_or_else(|| anyhow!("bad progress"))?,
            time: field(&v, "time")?
                .as_f64()
                .ok_or_else(|| anyhow!("bad time"))?,
        }),
        other => bail!("unknown op {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// Data plane: parameter-server RPC frames
// ---------------------------------------------------------------------------

/// Named-session attach carried by [`PsRequest::Hello`]: registers
/// the name on first sight (admission-checked) or re-attaches to the
/// existing session of that name, refreshing its lease either way.
/// `None` in the `Hello` means the default session-0 namespace — and a
/// byte-identical legacy encoding.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SessionHello {
    /// User-chosen session name (`tune --session-name`).
    pub name: String,
    /// Lease duration in milliseconds: the server garbage-collects
    /// the session's branches if no stamped frame arrives for this
    /// long (crashed-client cleanup).  0 asks for the server default.
    pub lease_ms: u64,
}

/// One request from a remote training process to a shard server.
///
/// `ForkBranch`/`FreeBranch` are broadcast by the client to **every**
/// shard server (branch index replication), exactly like the control
/// plane broadcasts branch ops to every worker; row ops are routed to
/// the one server owning the row's global shard.
///
/// Every branch-scoped frame carries a `session` id (0 = the default
/// namespace; the JSON key is omitted when 0, so legacy frames are
/// byte-identical and pre-session peers interoperate unchanged).
#[derive(Debug, Clone, PartialEq)]
pub enum PsRequest {
    /// Handshake: which global shards does this server own, and with
    /// which optimizer was its engine built?  `codec` advertises the
    /// data-plane payload codec the client wants; servers that predate
    /// the field simply never echo it back, which the client treats as
    /// a JSON-only peer.  `session` optionally registers/attaches a
    /// named session (see [`SessionHello`]); the granted id comes back
    /// in [`PsReply::Hello`].
    Hello {
        codec: WireCodec,
        session: Option<SessionHello>,
    },
    /// Install a fresh row (root-branch model initialization).
    InsertRow {
        session: SessionId,
        branch: BranchId,
        table: TableId,
        key: RowKey,
        data: Vec<f32>,
    },
    /// Read one row; `with_accum` additionally returns the
    /// AdaRevision grad-accumulator snapshot (slot 1).
    ReadRow {
        session: SessionId,
        branch: BranchId,
        table: TableId,
        key: RowKey,
        with_accum: bool,
    },
    /// Read this server's group of a routed batch of keys under the
    /// engine's batched read path (one read-lock acquisition per local
    /// shard).  The reply lists one row per key, in key order.
    ReadRows {
        session: SessionId,
        branch: BranchId,
        with_accum: bool,
        keys: Vec<(TableId, RowKey)>,
    },
    /// Apply one row update (the AdaRevision path, which carries the
    /// `z_old` snapshot read together with the row).
    ApplyUpdate {
        session: SessionId,
        branch: BranchId,
        table: TableId,
        key: RowKey,
        grad: Vec<f32>,
        hyper: Hyper,
        z_old: Option<Vec<f32>>,
    },
    /// Apply this server's group of a routed batch under the engine's
    /// batched path (one lock acquisition per local shard).
    ApplyBatch {
        session: SessionId,
        branch: BranchId,
        hyper: Hyper,
        updates: Vec<(TableId, RowKey, Vec<f32>)>,
    },
    /// Fork `child` from `parent` on this server's shards.
    ForkBranch {
        session: SessionId,
        child: BranchId,
        parent: BranchId,
    },
    /// Free `branch` on this server's shards (last-owner buffers are
    /// reclaimed into the server-local pools).
    FreeBranch {
        session: SessionId,
        branch: BranchId,
    },
    /// Dump `branch`'s rows on this server into per-shard segment
    /// files under `dir` (a path reachable from the server process);
    /// the reply carries the written [`SegmentMeta`]s so the
    /// coordinator can assemble the checkpoint manifest.  Broadcast to
    /// every shard server: each dumps exactly its own shard range,
    /// concurrently with the others.
    CheckpointBranch {
        session: SessionId,
        branch: BranchId,
        dir: String,
    },
    /// Decode and fully verify `branch`'s segment files for this
    /// server's shard range under `dir` **without installing
    /// anything** — phase one of the coordinator's two-phase restore
    /// (verify everywhere, then install everywhere), which keeps a
    /// corrupted checkpoint from leaving a cross-server torn branch.
    VerifyBranch {
        session: SessionId,
        branch: BranchId,
        dir: String,
    },
    /// Restore `branch` on this server from the segment files of its
    /// shard range under `dir`.  Fail-closed server-side: a corrupted,
    /// truncated or missing segment is an `Err` reply with the
    /// server's state unchanged.
    RestoreBranch {
        session: SessionId,
        branch: BranchId,
        dir: String,
    },
    /// Probe the server's full stats document once (pull side of the
    /// observability plane; same [`ServerDelta`] payload the push
    /// stream uses).
    ServerStats,
    /// Subscribe this connection to periodic [`PsReply::StatsDelta`]
    /// pushes, one every `interval_ms` milliseconds (the server clamps
    /// the cadence).  Push frames are always JSON payloads, even on a
    /// binary-codec connection — subscribers are dashboards, not the
    /// data plane.
    SubscribeStats { interval_ms: u64 },
    /// Publish one trial-progress event into the server's stats
    /// stream (best-effort side channel from the tuner; the server
    /// keeps a bounded latest-per-trial map **per session** and folds
    /// it into deltas).  The event's `session` field doubles as the
    /// frame's session stamp.
    PublishProgress { event: TrialEvent },
    /// List the branches live in `session`'s namespace, with this
    /// server's local row counts — the session-scoped census behind
    /// the remote store's `live_branches`/`branch_row_count` (and the
    /// reason attaching to a shared cluster can no longer free a
    /// co-tenant's branches).
    ListBranches { session: SessionId },
    /// Tear the session down: free every branch in its namespace and
    /// drop the registration.  Graceful counterpart of lease-expiry
    /// GC.  `EndSession { session: 0 }` is rejected — the default
    /// namespace has no lifecycle.
    EndSession { session: SessionId },
    /// Ask the server process to exit after acknowledging.
    Shutdown,
}

impl PsRequest {
    /// The session a frame is scoped to, when it carries one.
    /// `Hello` answers `None` — the connection holds no granted id
    /// yet — and the control frames (`ServerStats`, `SubscribeStats`,
    /// `Shutdown`) are unscoped.  `PublishProgress` is stamped
    /// through its event.
    pub fn session(&self) -> Option<SessionId> {
        match self {
            PsRequest::InsertRow { session, .. }
            | PsRequest::ReadRow { session, .. }
            | PsRequest::ReadRows { session, .. }
            | PsRequest::ApplyUpdate { session, .. }
            | PsRequest::ApplyBatch { session, .. }
            | PsRequest::ForkBranch { session, .. }
            | PsRequest::FreeBranch { session, .. }
            | PsRequest::CheckpointBranch { session, .. }
            | PsRequest::VerifyBranch { session, .. }
            | PsRequest::RestoreBranch { session, .. }
            | PsRequest::ListBranches { session }
            | PsRequest::EndSession { session } => Some(*session),
            PsRequest::PublishProgress { event } => Some(event.session),
            PsRequest::Hello { .. }
            | PsRequest::ServerStats
            | PsRequest::SubscribeStats { .. }
            | PsRequest::Shutdown => None,
        }
    }

    /// Parameter rows this request touches — the currency of the
    /// data-plane fairness plane.  Row ops cost their row count;
    /// branch and control ops cost nothing.
    pub fn cost_rows(&self) -> u64 {
        match self {
            PsRequest::InsertRow { .. }
            | PsRequest::ReadRow { .. }
            | PsRequest::ApplyUpdate { .. } => 1,
            PsRequest::ReadRows { keys, .. } => u64::try_from(keys.len()).unwrap_or(u64::MAX),
            PsRequest::ApplyBatch { updates, .. } => {
                u64::try_from(updates.len()).unwrap_or(u64::MAX)
            }
            _ => 0,
        }
    }
}

/// One reply from a shard server.
#[derive(Debug, Clone, PartialEq)]
pub enum PsReply {
    Hello {
        shard_begin: usize,
        shard_end: usize,
        optimizer: String,
        /// The codec the server will speak on this connection.  A
        /// server only acks [`WireCodec::Binary`] when it was started
        /// with binary framing; anything else (including a pre-codec
        /// server that omits the field entirely) means JSON.
        codec: WireCodec,
        /// Session id granted for the `Hello`'s [`SessionHello`]
        /// attach; 0 (key omitted on the wire) when none was
        /// requested, so pre-session peers parse the reply unchanged.
        session: SessionId,
    },
    Ok,
    Row {
        data: Option<Vec<f32>>,
        accum: Option<Vec<f32>>,
    },
    /// One row per requested key, in key order (`None` = missing row);
    /// each present row carries its data and, when the request asked
    /// `with_accum`, the AdaRevision accumulator snapshot.  All floats
    /// are bit patterns, like every other row payload.
    RowsData { rows: Vec<Option<RowData>> },
    /// Segment metadata written by a [`PsRequest::CheckpointBranch`].
    Segments { segments: Vec<SegmentMeta> },
    /// Row count decoded by a [`PsRequest::VerifyBranch`] (nothing was
    /// installed).
    Verified { rows: u64 },
    /// Row count installed by a [`PsRequest::RestoreBranch`].
    Restored { rows: u64 },
    /// The session-scoped branch census answering a
    /// [`PsRequest::ListBranches`]: user-visible branch ids and this
    /// server's local row counts, branch-id order.
    BranchList { branches: Vec<(BranchId, usize)> },
    /// Full stats document answering a [`PsRequest::ServerStats`]
    /// probe.
    Stats(ServerDelta),
    /// Unsolicited periodic push on a subscribed connection (see
    /// [`PsRequest::SubscribeStats`]).  Same payload as [`Stats`],
    /// different op so a client can tell its own probe reply from the
    /// stream.
    ///
    /// [`Stats`]: PsReply::Stats
    StatsDelta(ServerDelta),
    Err { message: String },
}

/// Escape a string for a JSON string literal (the in-tree parser
/// understands exactly these escapes).  Shared with the session
/// checkpoint codec (`crate::tuner::session`).
pub(crate) fn push_json_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if u32::from(c) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", u32::from(c));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Append an f32 slice as a JSON array of bit patterns.
fn push_f32_bits(out: &mut String, data: &[f32]) {
    out.push('[');
    for (i, v) in data.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "{}", v.to_bits());
    }
    out.push(']');
}

fn push_opt_f32_bits(out: &mut String, data: Option<&[f32]>) {
    match data {
        None => out.push_str("null"),
        Some(d) => push_f32_bits(out, d),
    }
}

fn f32_bits_array(v: &Json, what: &str) -> Result<Vec<f32>> {
    v.as_array()
        .ok_or_else(|| anyhow!("bad {what}: not an array"))?
        .iter()
        .map(|x| num_f32_bits(x, what))
        .collect()
}

fn opt_f32_bits_array(v: &Json, what: &str) -> Result<Option<Vec<f32>>> {
    match v {
        Json::Null => Ok(None),
        v => Ok(Some(f32_bits_array(v, what)?)),
    }
}

fn push_hyper(out: &mut String, hyper: Hyper) {
    let _ = write!(
        out,
        "\"lr\":{},\"momentum\":{}",
        hyper.lr.to_bits(),
        hyper.momentum.to_bits()
    );
}

/// Decode the optional `codec` field of a `Hello` frame: absent means
/// JSON (every pre-codec peer), unknown codec names are an error
/// rather than a silent downgrade.
fn codec_of(v: &Json) -> Result<WireCodec> {
    match v.get("codec") {
        None => Ok(WireCodec::Json),
        Some(c) => match c.as_str() {
            Some("json") => Ok(WireCodec::Json),
            Some("binary") => Ok(WireCodec::Binary),
            other => bail!("bad codec {other:?}"),
        },
    }
}

fn hyper_of(v: &Json) -> Result<Hyper> {
    Ok(Hyper {
        lr: num_f32_bits(field(v, "lr")?, "lr")?,
        momentum: num_f32_bits(field(v, "momentum")?, "momentum")?,
    })
}

/// Append the session stamp.  The key is **omitted for session 0** so
/// default-namespace frames stay byte-identical to the pre-session
/// wire format (and old peers keep decoding them).
fn push_session(out: &mut String, session: SessionId) {
    if session != 0 {
        let _ = write!(out, ",\"session\":{session}");
    }
}

/// Decode the optional `session` stamp: absent means 0, the default
/// namespace every pre-session peer lives in.
fn session_of(v: &Json) -> Result<SessionId> {
    match v.get("session") {
        None => Ok(0),
        Some(s) => num_u32(s, "session"),
    }
}

/// Encode one PS request as a single JSON frame.
pub fn encode_ps_request(req: &PsRequest) -> String {
    let mut out = String::new();
    match req {
        PsRequest::Hello { codec, session } => {
            out.push_str("{\"op\":\"hello\"");
            if *codec == WireCodec::Binary {
                out.push_str(",\"codec\":\"binary\"");
            }
            if let Some(s) = session {
                out.push_str(",\"session_name\":");
                push_json_str(&mut out, &s.name);
                let _ = write!(out, ",\"lease_ms\":{}", s.lease_ms);
            }
            out.push('}');
        }
        PsRequest::InsertRow {
            session,
            branch,
            table,
            key,
            data,
        } => {
            out.push_str("{\"op\":\"insert\"");
            push_session(&mut out, *session);
            let _ = write!(out, ",\"branch\":{branch},\"table\":{table},\"key\":{key},\"data\":");
            push_f32_bits(&mut out, data);
            out.push('}');
        }
        PsRequest::ReadRow {
            session,
            branch,
            table,
            key,
            with_accum,
        } => {
            out.push_str("{\"op\":\"read\"");
            push_session(&mut out, *session);
            let _ = write!(
                out,
                ",\"branch\":{branch},\"table\":{table},\"key\":{key},\"accum\":{with_accum}}}"
            );
        }
        PsRequest::ReadRows {
            session,
            branch,
            with_accum,
            keys,
        } => {
            out.push_str("{\"op\":\"read_rows\"");
            push_session(&mut out, *session);
            let _ = write!(out, ",\"branch\":{branch},\"accum\":{with_accum},\"keys\":[");
            for (i, (table, key)) in keys.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                let _ = write!(out, "[{table},{key}]");
            }
            out.push_str("]}");
        }
        PsRequest::ApplyUpdate {
            session,
            branch,
            table,
            key,
            grad,
            hyper,
            z_old,
        } => {
            out.push_str("{\"op\":\"update\"");
            push_session(&mut out, *session);
            let _ = write!(out, ",\"branch\":{branch},\"table\":{table},\"key\":{key},");
            push_hyper(&mut out, *hyper);
            out.push_str(",\"grad\":");
            push_f32_bits(&mut out, grad);
            out.push_str(",\"z_old\":");
            push_opt_f32_bits(&mut out, z_old.as_deref());
            out.push('}');
        }
        PsRequest::ApplyBatch {
            session,
            branch,
            hyper,
            updates,
        } => {
            out.push_str("{\"op\":\"batch\"");
            push_session(&mut out, *session);
            let _ = write!(out, ",\"branch\":{branch},");
            push_hyper(&mut out, *hyper);
            out.push_str(",\"updates\":[");
            for (i, (table, key, grad)) in updates.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                let _ = write!(out, "[{table},{key},");
                push_f32_bits(&mut out, grad);
                out.push(']');
            }
            out.push_str("]}");
        }
        PsRequest::ForkBranch {
            session,
            child,
            parent,
        } => {
            out.push_str("{\"op\":\"fork\"");
            push_session(&mut out, *session);
            let _ = write!(out, ",\"child\":{child},\"parent\":{parent}}}");
        }
        PsRequest::FreeBranch { session, branch } => {
            out.push_str("{\"op\":\"free\"");
            push_session(&mut out, *session);
            let _ = write!(out, ",\"branch\":{branch}}}");
        }
        PsRequest::CheckpointBranch {
            session,
            branch,
            dir,
        } => {
            out.push_str("{\"op\":\"ckpt\"");
            push_session(&mut out, *session);
            let _ = write!(out, ",\"branch\":{branch},\"dir\":");
            push_json_str(&mut out, dir);
            out.push('}');
        }
        PsRequest::VerifyBranch {
            session,
            branch,
            dir,
        } => {
            out.push_str("{\"op\":\"verify\"");
            push_session(&mut out, *session);
            let _ = write!(out, ",\"branch\":{branch},\"dir\":");
            push_json_str(&mut out, dir);
            out.push('}');
        }
        PsRequest::RestoreBranch {
            session,
            branch,
            dir,
        } => {
            out.push_str("{\"op\":\"restore\"");
            push_session(&mut out, *session);
            let _ = write!(out, ",\"branch\":{branch},\"dir\":");
            push_json_str(&mut out, dir);
            out.push('}');
        }
        PsRequest::ServerStats => out.push_str("{\"op\":\"stats\"}"),
        PsRequest::SubscribeStats { interval_ms } => {
            let _ = write!(out, "{{\"op\":\"sub_stats\",\"interval_ms\":{interval_ms}}}");
        }
        PsRequest::PublishProgress { event } => {
            out.push_str("{\"op\":\"publish\"");
            push_session(&mut out, event.session);
            let _ = write!(
                out,
                ",\"episode\":{},\"trial\":{},\"branch\":{},\"clock\":{},\"progress\":",
                event.episode, event.trial, event.branch, event.clock
            );
            push_json_str(&mut out, &hex_u64(event.progress.to_bits()));
            out.push_str(",\"time\":");
            push_json_str(&mut out, &hex_u64(event.time.to_bits()));
            out.push('}');
        }
        PsRequest::ListBranches { session } => {
            out.push_str("{\"op\":\"list_branches\"");
            push_session(&mut out, *session);
            out.push('}');
        }
        PsRequest::EndSession { session } => {
            out.push_str("{\"op\":\"end_session\"");
            push_session(&mut out, *session);
            out.push('}');
        }
        PsRequest::Shutdown => out.push_str("{\"op\":\"shutdown\"}"),
    }
    out
}

/// Decode one PS request frame.
pub fn decode_ps_request(line: &str) -> Result<PsRequest> {
    let v = Json::parse(line.trim())?;
    let op = field(&v, "op")?
        .as_str()
        .ok_or_else(|| anyhow!("op not a string"))?;
    match op {
        "hello" => {
            let session = match v.get("session_name") {
                None => None,
                Some(n) => Some(SessionHello {
                    name: n
                        .as_str()
                        .ok_or_else(|| anyhow!("bad session_name: not a string"))?
                        .to_string(),
                    lease_ms: num_u64(field(&v, "lease_ms")?, "lease_ms")?,
                }),
            };
            Ok(PsRequest::Hello { codec: codec_of(&v)?, session })
        }
        "insert" => Ok(PsRequest::InsertRow {
            session: session_of(&v)?,
            branch: num_u32(field(&v, "branch")?, "branch")?,
            table: num_u32(field(&v, "table")?, "table")?,
            key: num_u64(field(&v, "key")?, "key")?,
            data: f32_bits_array(field(&v, "data")?, "data")?,
        }),
        "read" => Ok(PsRequest::ReadRow {
            session: session_of(&v)?,
            branch: num_u32(field(&v, "branch")?, "branch")?,
            table: num_u32(field(&v, "table")?, "table")?,
            key: num_u64(field(&v, "key")?, "key")?,
            with_accum: match field(&v, "accum")? {
                Json::Bool(b) => *b,
                _ => bail!("bad accum: not a bool"),
            },
        }),
        "read_rows" => Ok(PsRequest::ReadRows {
            session: session_of(&v)?,
            branch: num_u32(field(&v, "branch")?, "branch")?,
            with_accum: match field(&v, "accum")? {
                Json::Bool(b) => *b,
                _ => bail!("bad accum: not a bool"),
            },
            keys: field(&v, "keys")?
                .as_array()
                .ok_or_else(|| anyhow!("bad keys: not an array"))?
                .iter()
                .map(|k| {
                    let k = k.as_array().ok_or_else(|| anyhow!("bad key pair"))?;
                    if k.len() != 2 {
                        bail!("bad key pair: len {}", k.len());
                    }
                    Ok((num_u32(&k[0], "table")?, num_u64(&k[1], "key")?))
                })
                .collect::<Result<Vec<_>>>()?,
        }),
        "update" => Ok(PsRequest::ApplyUpdate {
            session: session_of(&v)?,
            branch: num_u32(field(&v, "branch")?, "branch")?,
            table: num_u32(field(&v, "table")?, "table")?,
            key: num_u64(field(&v, "key")?, "key")?,
            grad: f32_bits_array(field(&v, "grad")?, "grad")?,
            hyper: hyper_of(&v)?,
            z_old: opt_f32_bits_array(field(&v, "z_old")?, "z_old")?,
        }),
        "batch" => {
            let updates = field(&v, "updates")?
                .as_array()
                .ok_or_else(|| anyhow!("bad updates: not an array"))?
                .iter()
                .map(|u| {
                    let u = u.as_array().ok_or_else(|| anyhow!("bad update triple"))?;
                    if u.len() != 3 {
                        bail!("bad update triple: len {}", u.len());
                    }
                    Ok((
                        num_u32(&u[0], "table")?,
                        num_u64(&u[1], "key")?,
                        f32_bits_array(&u[2], "grad")?,
                    ))
                })
                .collect::<Result<Vec<_>>>()?;
            Ok(PsRequest::ApplyBatch {
                session: session_of(&v)?,
                branch: num_u32(field(&v, "branch")?, "branch")?,
                hyper: hyper_of(&v)?,
                updates,
            })
        }
        "fork" => Ok(PsRequest::ForkBranch {
            session: session_of(&v)?,
            child: num_u32(field(&v, "child")?, "child")?,
            parent: num_u32(field(&v, "parent")?, "parent")?,
        }),
        "free" => Ok(PsRequest::FreeBranch {
            session: session_of(&v)?,
            branch: num_u32(field(&v, "branch")?, "branch")?,
        }),
        "ckpt" | "verify" | "restore" => {
            let session = session_of(&v)?;
            let branch = num_u32(field(&v, "branch")?, "branch")?;
            let dir = field(&v, "dir")?
                .as_str()
                .ok_or_else(|| anyhow!("bad dir: not a string"))?
                .to_string();
            Ok(match op {
                "ckpt" => PsRequest::CheckpointBranch { session, branch, dir },
                "verify" => PsRequest::VerifyBranch { session, branch, dir },
                _ => PsRequest::RestoreBranch { session, branch, dir },
            })
        }
        "stats" => Ok(PsRequest::ServerStats),
        "sub_stats" => Ok(PsRequest::SubscribeStats {
            interval_ms: num_u64(field(&v, "interval_ms")?, "interval_ms")?,
        }),
        "publish" => Ok(PsRequest::PublishProgress {
            event: TrialEvent {
                session: session_of(&v)?,
                episode: num_u32(field(&v, "episode")?, "episode")?,
                trial: num_u32(field(&v, "trial")?, "trial")?,
                branch: num_u32(field(&v, "branch")?, "branch")?,
                clock: num_u64(field(&v, "clock")?, "clock")?,
                progress: f64_hex_bits(field(&v, "progress")?, "progress")?,
                time: f64_hex_bits(field(&v, "time")?, "time")?,
            },
        }),
        "list_branches" => Ok(PsRequest::ListBranches { session: session_of(&v)? }),
        "end_session" => Ok(PsRequest::EndSession { session: session_of(&v)? }),
        "shutdown" => Ok(PsRequest::Shutdown),
        other => bail!("unknown ps request op {other}"),
    }
}

/// Append one [`ServerDelta`] as the body of a stats frame.  Shared by
/// the pull probe (`op:"stats"`) and the push stream
/// (`op:"stats_delta"`) so the two can never drift apart.
fn push_server_delta(out: &mut String, op: &str, d: &ServerDelta) {
    let _ = write!(
        out,
        "{{\"op\":\"{op}\",\"v\":{},\
         \"server\":{{\"contended\":{},\"batch_calls\":{},\"batched_rows\":{},\
         \"reads_batched\":{},\"rows_applied\":{},\"rows_read\":{}}},\
         \"store\":{{\"forks\":{},\"peak\":{},\"live\":{},\"cow\":{},\"read_rpcs\":{}}},\
         \"pool\":{{\"reused\":{},\"allocated\":{},\"idle\":{},\"idle_len\":{}}},\
         \"wire\":{{\"bytes_tx\":{},\"bytes_rx\":{},\"frames_json\":{},\"frames_bin\":{}}}",
        d.version,
        d.server.shard_lock_contentions,
        d.server.batch_calls,
        d.server.batched_rows,
        d.server.reads_batched,
        d.server.rows_applied,
        d.server.rows_read,
        d.store.forks,
        d.store.peak_branches,
        d.store.live_branches,
        d.store.cow_buffer_copies,
        d.store.read_rpcs,
        d.pool.reused,
        d.pool.allocated,
        d.pool.idle,
        d.pool.idle_len,
        d.wire.bytes_tx,
        d.wire.bytes_rx,
        d.wire.frames_json,
        d.wire.frames_bin,
    );
    out.push_str(",\"shards\":[");
    for (i, s) in d.shards.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "[{},{},{}]", s.shard, s.rows_applied, s.rows_read);
    }
    out.push_str("],\"rpc_hist\":[");
    for (i, b) in d.rpc_hist.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "{b}");
    }
    out.push_str("],\"branches\":[");
    for (i, (id, rows)) in d.branches.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "[{id},{rows}]");
    }
    out.push_str("],\"trials\":[");
    for (i, t) in d.trials.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(
            out,
            "[{},{},{},{},{},",
            t.session, t.episode, t.trial, t.branch, t.clock
        );
        push_json_str(out, &hex_u64(t.progress.to_bits()));
        out.push(',');
        push_json_str(out, &hex_u64(t.time.to_bits()));
        out.push(']');
    }
    out.push_str("],\"sessions\":[");
    for (i, s) in d.sessions.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(
            out,
            "[{},{},{},{},{}]",
            s.session, s.rows_applied, s.rows_read, s.deferrals, s.live_branches
        );
    }
    out.push_str("]}");
}

/// Decode the body shared by `op:"stats"` and `op:"stats_delta"`
/// frames.  The `"v"` schema version is checked first: a frame from a
/// newer peer fails here with a version mismatch instead of a
/// confusing missing-field error further down.
fn server_delta_of(v: &Json) -> Result<ServerDelta> {
    let version = num_u32(field(v, "v")?, "stats schema version")?;
    if version != SCHEMA_VERSION {
        bail!(
            "unsupported stats schema version {version} (this peer speaks {SCHEMA_VERSION})"
        );
    }
    let sv = field(v, "server")?;
    let server = ServerPlane {
        shard_lock_contentions: num_u64(field(sv, "contended")?, "contended")?,
        batch_calls: num_u64(field(sv, "batch_calls")?, "batch_calls")?,
        batched_rows: num_u64(field(sv, "batched_rows")?, "batched_rows")?,
        reads_batched: num_u64(field(sv, "reads_batched")?, "reads_batched")?,
        rows_applied: num_u64(field(sv, "rows_applied")?, "rows_applied")?,
        rows_read: num_u64(field(sv, "rows_read")?, "rows_read")?,
    };
    let st = field(v, "store")?;
    let store = StorePlane {
        forks: num_u64(field(st, "forks")?, "forks")?,
        peak_branches: num_usize(field(st, "peak")?, "peak")?,
        live_branches: num_usize(field(st, "live")?, "live")?,
        cow_buffer_copies: num_u64(field(st, "cow")?, "cow")?,
        read_rpcs: num_u64(field(st, "read_rpcs")?, "read_rpcs")?,
    };
    let pv = field(v, "pool")?;
    let pool = PoolStats {
        reused: num_u64(field(pv, "reused")?, "reused")?,
        allocated: num_u64(field(pv, "allocated")?, "allocated")?,
        idle: num_u64(field(pv, "idle")?, "idle")?,
        idle_len: num_u64(field(pv, "idle_len")?, "idle_len")?,
    };
    let wv = field(v, "wire")?;
    let wire = WirePlane {
        bytes_tx: num_u64(field(wv, "bytes_tx")?, "bytes_tx")?,
        bytes_rx: num_u64(field(wv, "bytes_rx")?, "bytes_rx")?,
        frames_json: num_u64(field(wv, "frames_json")?, "frames_json")?,
        frames_bin: num_u64(field(wv, "frames_bin")?, "frames_bin")?,
    };
    let shards = field(v, "shards")?
        .as_array()
        .ok_or_else(|| anyhow!("bad shards: not an array"))?
        .iter()
        .map(|s| {
            let s = s.as_array().ok_or_else(|| anyhow!("bad shard triple"))?;
            if s.len() != 3 {
                bail!("bad shard triple: len {}", s.len());
            }
            Ok(ShardRows {
                shard: num_u64(&s[0], "shard")?,
                rows_applied: num_u64(&s[1], "shard rows_applied")?,
                rows_read: num_u64(&s[2], "shard rows_read")?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let hist = field(v, "rpc_hist")?
        .as_array()
        .ok_or_else(|| anyhow!("bad rpc_hist: not an array"))?;
    if hist.len() != HIST_BUCKETS {
        bail!("bad rpc_hist: {} buckets (want {HIST_BUCKETS})", hist.len());
    }
    let mut rpc_hist = [0u64; HIST_BUCKETS];
    for (slot, b) in rpc_hist.iter_mut().zip(hist.iter()) {
        *slot = num_u64(b, "rpc_hist bucket")?;
    }
    let branches = field(v, "branches")?
        .as_array()
        .ok_or_else(|| anyhow!("bad branches"))?
        .iter()
        .map(|b| {
            let b = b.as_array().ok_or_else(|| anyhow!("bad branch pair"))?;
            if b.len() != 2 {
                bail!("bad branch pair: len {}", b.len());
            }
            Ok((num_u32(&b[0], "branch")?, num_usize(&b[1], "rows")?))
        })
        .collect::<Result<Vec<_>>>()?;
    let trials = field(v, "trials")?
        .as_array()
        .ok_or_else(|| anyhow!("bad trials"))?
        .iter()
        .map(|t| {
            let t = t.as_array().ok_or_else(|| anyhow!("bad trial entry"))?;
            if t.len() != 7 {
                bail!("bad trial entry: len {}", t.len());
            }
            Ok(TrialEvent {
                session: num_u32(&t[0], "session")?,
                episode: num_u32(&t[1], "episode")?,
                trial: num_u32(&t[2], "trial")?,
                branch: num_u32(&t[3], "branch")?,
                clock: num_u64(&t[4], "clock")?,
                progress: f64_hex_bits(&t[5], "progress")?,
                time: f64_hex_bits(&t[6], "time")?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let sessions = field(v, "sessions")?
        .as_array()
        .ok_or_else(|| anyhow!("bad sessions"))?
        .iter()
        .map(|s| {
            let s = s.as_array().ok_or_else(|| anyhow!("bad session entry"))?;
            if s.len() != 5 {
                bail!("bad session entry: len {}", s.len());
            }
            Ok(SessionStats {
                session: num_u32(&s[0], "session")?,
                rows_applied: num_u64(&s[1], "session rows_applied")?,
                rows_read: num_u64(&s[2], "session rows_read")?,
                deferrals: num_u64(&s[3], "session deferrals")?,
                live_branches: num_usize(&s[4], "session live_branches")?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(ServerDelta {
        version,
        server,
        store,
        pool,
        wire,
        shards,
        rpc_hist,
        branches,
        trials,
        sessions,
    })
}

/// Encode one PS reply as a single JSON frame.
pub fn encode_ps_reply(reply: &PsReply) -> String {
    let mut out = String::new();
    match reply {
        PsReply::Hello {
            shard_begin,
            shard_end,
            optimizer,
            codec,
            session,
        } => {
            let _ = write!(
                out,
                "{{\"op\":\"hello\",\"begin\":{shard_begin},\"end\":{shard_end},\"optimizer\":"
            );
            push_json_str(&mut out, optimizer);
            if *codec == WireCodec::Binary {
                out.push_str(",\"codec\":\"binary\"");
            }
            push_session(&mut out, *session);
            out.push('}');
        }
        PsReply::Ok => out.push_str("{\"op\":\"ok\"}"),
        PsReply::Row { data, accum } => {
            out.push_str("{\"op\":\"row\",\"data\":");
            push_opt_f32_bits(&mut out, data.as_deref());
            out.push_str(",\"accum\":");
            push_opt_f32_bits(&mut out, accum.as_deref());
            out.push('}');
        }
        PsReply::RowsData { rows } => {
            out.push_str("{\"op\":\"rows\",\"rows\":[");
            for (i, row) in rows.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                match row {
                    None => out.push_str("null"),
                    Some((data, accum)) => {
                        out.push('[');
                        push_f32_bits(&mut out, data);
                        out.push(',');
                        push_opt_f32_bits(&mut out, accum.as_deref());
                        out.push(']');
                    }
                }
            }
            out.push_str("]}");
        }
        PsReply::Segments { segments } => {
            out.push_str("{\"op\":\"segments\",\"segments\":[");
            for (i, s) in segments.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push('[');
                push_json_str(&mut out, &s.file);
                let _ = write!(
                    out,
                    ",{},{},{},{},{},{},",
                    s.branch, s.range_begin, s.range_end, s.local_shard, s.rows, s.bytes
                );
                push_json_str(&mut out, &hex_u64(s.checksum));
                out.push(']');
            }
            out.push_str("]}");
        }
        PsReply::Verified { rows } => {
            let _ = write!(out, "{{\"op\":\"verified\",\"rows\":{rows}}}");
        }
        PsReply::Restored { rows } => {
            let _ = write!(out, "{{\"op\":\"restored\",\"rows\":{rows}}}");
        }
        PsReply::BranchList { branches } => {
            out.push_str("{\"op\":\"branch_list\",\"branches\":[");
            for (i, (id, rows)) in branches.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                let _ = write!(out, "[{id},{rows}]");
            }
            out.push_str("]}");
        }
        PsReply::Stats(d) => push_server_delta(&mut out, "stats", d),
        PsReply::StatsDelta(d) => push_server_delta(&mut out, "stats_delta", d),
        PsReply::Err { message } => {
            out.push_str("{\"op\":\"err\",\"msg\":");
            push_json_str(&mut out, message);
            out.push('}');
        }
    }
    out
}

/// Decode one PS reply frame.
pub fn decode_ps_reply(line: &str) -> Result<PsReply> {
    let v = Json::parse(line.trim())?;
    let op = field(&v, "op")?
        .as_str()
        .ok_or_else(|| anyhow!("op not a string"))?;
    match op {
        "hello" => Ok(PsReply::Hello {
            shard_begin: num_usize(field(&v, "begin")?, "begin")?,
            shard_end: num_usize(field(&v, "end")?, "end")?,
            optimizer: field(&v, "optimizer")?
                .as_str()
                .ok_or_else(|| anyhow!("bad optimizer"))?
                .to_string(),
            codec: codec_of(&v)?,
            session: session_of(&v)?,
        }),
        "ok" => Ok(PsReply::Ok),
        "row" => Ok(PsReply::Row {
            data: opt_f32_bits_array(field(&v, "data")?, "data")?,
            accum: opt_f32_bits_array(field(&v, "accum")?, "accum")?,
        }),
        "rows" => Ok(PsReply::RowsData {
            rows: field(&v, "rows")?
                .as_array()
                .ok_or_else(|| anyhow!("bad rows: not an array"))?
                .iter()
                .map(|r| match r {
                    Json::Null => Ok(None),
                    r => {
                        let r = r.as_array().ok_or_else(|| anyhow!("bad row pair"))?;
                        if r.len() != 2 {
                            bail!("bad row pair: len {}", r.len());
                        }
                        Ok(Some((
                            f32_bits_array(&r[0], "data")?,
                            opt_f32_bits_array(&r[1], "accum")?,
                        )))
                    }
                })
                .collect::<Result<Vec<_>>>()?,
        }),
        "segments" => Ok(PsReply::Segments {
            segments: field(&v, "segments")?
                .as_array()
                .ok_or_else(|| anyhow!("bad segments: not an array"))?
                .iter()
                .map(|s| {
                    let s = s.as_array().ok_or_else(|| anyhow!("bad segment entry"))?;
                    if s.len() != 8 {
                        bail!("bad segment entry: len {}", s.len());
                    }
                    Ok(SegmentMeta {
                        file: s[0]
                            .as_str()
                            .ok_or_else(|| anyhow!("bad segment file"))?
                            .to_string(),
                        branch: num_u32(&s[1], "segment branch")?,
                        range_begin: num_usize(&s[2], "segment range begin")?,
                        range_end: num_usize(&s[3], "segment range end")?,
                        local_shard: num_usize(&s[4], "segment shard")?,
                        rows: num_u64(&s[5], "segment rows")?,
                        bytes: num_u64(&s[6], "segment bytes")?,
                        checksum: parse_hex_u64(
                            s[7].as_str().ok_or_else(|| anyhow!("bad segment checksum"))?,
                        )?,
                    })
                })
                .collect::<Result<Vec<_>>>()?,
        }),
        "verified" => Ok(PsReply::Verified {
            rows: num_u64(field(&v, "rows")?, "rows")?,
        }),
        "restored" => Ok(PsReply::Restored {
            rows: num_u64(field(&v, "rows")?, "rows")?,
        }),
        "branch_list" => Ok(PsReply::BranchList {
            branches: field(&v, "branches")?
                .as_array()
                .ok_or_else(|| anyhow!("bad branches"))?
                .iter()
                .map(|b| {
                    let b = b.as_array().ok_or_else(|| anyhow!("bad branch pair"))?;
                    if b.len() != 2 {
                        bail!("bad branch pair: len {}", b.len());
                    }
                    Ok((num_u32(&b[0], "branch")?, num_usize(&b[1], "rows")?))
                })
                .collect::<Result<Vec<_>>>()?,
        }),
        "stats" => Ok(PsReply::Stats(server_delta_of(&v)?)),
        "stats_delta" => Ok(PsReply::StatsDelta(server_delta_of(&v)?)),
        "err" => Ok(PsReply::Err {
            message: field(&v, "msg")?
                .as_str()
                .ok_or_else(|| anyhow!("bad msg"))?
                .to_string(),
        }),
        other => bail!("unknown ps reply op {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tuner_msgs_roundtrip() {
        let msgs = vec![
            TunerMsg::ForkBranch {
                clock: 7,
                branch_id: 3,
                parent_branch_id: Some(1),
                tunable: TunableSetting::new(vec![1e-3, 0.9, 32.0, 0.0]),
                branch_type: BranchType::Training,
            },
            TunerMsg::ForkBranch {
                clock: 8,
                branch_id: 4,
                parent_branch_id: None,
                tunable: TunableSetting::new(vec![]),
                branch_type: BranchType::Testing,
            },
            TunerMsg::FreeBranch {
                clock: 9,
                branch_id: 3,
            },
            TunerMsg::ScheduleBranch {
                clock: 10,
                branch_id: 4,
            },
        ];
        for m in msgs {
            let line = encode_tuner_msg(&m);
            let back = decode_tuner_msg(&line).unwrap();
            assert_eq!(m, back, "wire: {line}");
        }
    }

    #[test]
    fn system_msgs_roundtrip() {
        let m = SystemMsg::ReportProgress {
            clock: 42,
            progress: -1.25e-3,
            time: 0.5,
        };
        assert_eq!(decode_system_msg(&encode_system_msg(&m)).unwrap(), m);
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(decode_tuner_msg("not json").is_err());
        assert!(decode_tuner_msg("{\"op\":\"dance\",\"clock\":0}").is_err());
        assert!(decode_tuner_msg("{\"op\":\"fork\",\"clock\":0}").is_err());
        assert!(decode_system_msg("{\"op\":\"progress\"}").is_err());
    }

    #[test]
    fn decode_rejects_non_integral_and_out_of_range_ids() {
        // Regression: these used to be accepted via silent `as` casts.
        assert!(decode_tuner_msg("{\"op\":\"free\",\"clock\":1.5,\"branch\":1}").is_err());
        assert!(decode_tuner_msg("{\"op\":\"free\",\"clock\":-1,\"branch\":1}").is_err());
        assert!(decode_tuner_msg("{\"op\":\"free\",\"clock\":0,\"branch\":2.5}").is_err());
        assert!(decode_tuner_msg("{\"op\":\"free\",\"clock\":0,\"branch\":-3}").is_err());
        // u32 overflow: 2^32 is a valid JSON integer but not a BranchId
        assert!(decode_tuner_msg("{\"op\":\"free\",\"clock\":0,\"branch\":4294967296}").is_err());
        // at or beyond 2^53 a u64 clock cannot round-trip through JSON
        // (2^53 + 1 parses to the same f64 as 2^53, so 2^53 itself is
        // rejected too — accepting it would readmit silent truncation)
        assert!(
            decode_tuner_msg("{\"op\":\"free\",\"clock\":9007199254740992,\"branch\":1}").is_err()
        );
        assert!(
            decode_tuner_msg("{\"op\":\"free\",\"clock\":9007199254740993,\"branch\":1}").is_err()
        );
        assert!(decode_tuner_msg("{\"op\":\"free\",\"clock\":\"7\",\"branch\":1}").is_err());
        assert!(decode_system_msg(
            "{\"op\":\"progress\",\"clock\":0.5,\"progress\":1.0,\"time\":1.0}"
        )
        .is_err());
        // the largest exactly-representable integer still decodes
        let ok = decode_tuner_msg("{\"op\":\"free\",\"clock\":9007199254740991,\"branch\":1}");
        assert_eq!(ok.unwrap().clock(), (1u64 << 53) - 1);
    }

    fn roundtrip_req(req: &PsRequest) {
        let line = encode_ps_request(req);
        let back = decode_ps_request(&line).unwrap_or_else(|e| panic!("{line}: {e}"));
        assert_eq!(req, &back, "wire: {line}");
    }

    fn roundtrip_reply(reply: &PsReply) {
        let line = encode_ps_reply(reply);
        let back = decode_ps_reply(&line).unwrap_or_else(|e| panic!("{line}: {e}"));
        assert_eq!(reply, &back, "wire: {line}");
    }

    #[test]
    fn ps_request_frames_roundtrip() {
        let hyper = Hyper { lr: 0.1, momentum: 0.9 };
        roundtrip_req(&PsRequest::Hello { codec: WireCodec::Json, session: None });
        roundtrip_req(&PsRequest::Hello { codec: WireCodec::Binary, session: None });
        roundtrip_req(&PsRequest::Hello {
            codec: WireCodec::Json,
            session: Some(SessionHello { name: "tune \"a\"".into(), lease_ms: 0 }),
        });
        roundtrip_req(&PsRequest::Hello {
            codec: WireCodec::Binary,
            session: Some(SessionHello { name: "b".into(), lease_ms: 30_000 }),
        });
        // NaN payloads are covered by f32_bit_patterns_survive_bit_exact
        // (NaN != NaN breaks the PartialEq comparison used here).
        roundtrip_req(&PsRequest::InsertRow {
            session: 0,
            branch: 0,
            table: 1,
            key: 7,
            data: vec![1.0, -0.0, f32::INFINITY, f32::NEG_INFINITY, 1.0e-45],
        });
        roundtrip_req(&PsRequest::InsertRow {
            session: u32::MAX,
            branch: 0,
            table: 1,
            key: 7,
            data: vec![],
        });
        roundtrip_req(&PsRequest::ReadRow {
            session: 2,
            branch: 3,
            table: 0,
            key: u64::MAX >> 12,
            with_accum: true,
        });
        roundtrip_req(&PsRequest::ReadRows {
            session: 0,
            branch: 3,
            with_accum: true,
            keys: vec![(0, 7), (1, u64::MAX >> 12), (0, 0)],
        });
        roundtrip_req(&PsRequest::ReadRows {
            session: 9,
            branch: 0,
            with_accum: false,
            keys: vec![],
        });
        roundtrip_req(&PsRequest::ApplyUpdate {
            session: 0,
            branch: 1,
            table: 0,
            key: 5,
            grad: vec![0.25, -1.5],
            hyper,
            z_old: Some(vec![2.0, 3.0]),
        });
        roundtrip_req(&PsRequest::ApplyUpdate {
            session: 1,
            branch: 1,
            table: 0,
            key: 5,
            grad: vec![],
            hyper,
            z_old: None,
        });
        roundtrip_req(&PsRequest::ApplyBatch {
            session: 3,
            branch: 2,
            hyper,
            updates: vec![(0, 1, vec![1.0]), (1, 9, vec![-2.5, 0.125])],
        });
        roundtrip_req(&PsRequest::ForkBranch { session: 0, child: 4, parent: 1 });
        roundtrip_req(&PsRequest::ForkBranch { session: 5, child: 4, parent: 1 });
        roundtrip_req(&PsRequest::FreeBranch { session: 5, branch: 4 });
        roundtrip_req(&PsRequest::CheckpointBranch {
            session: 1,
            branch: 3,
            dir: "/tmp/with \"quotes\"\nand newlines".into(),
        });
        roundtrip_req(&PsRequest::VerifyBranch {
            session: 0,
            branch: 7,
            dir: "/tmp/ck".into(),
        });
        roundtrip_req(&PsRequest::RestoreBranch {
            session: 2,
            branch: 0,
            dir: "relative/dir".into(),
        });
        roundtrip_req(&PsRequest::ServerStats);
        roundtrip_req(&PsRequest::SubscribeStats { interval_ms: 250 });
        roundtrip_req(&PsRequest::PublishProgress {
            event: TrialEvent {
                session: 6,
                episode: 1,
                trial: 4,
                branch: 9,
                clock: 1 << 60,
                progress: -1.25e-3,
                time: 0.5,
            },
        });
        roundtrip_req(&PsRequest::ListBranches { session: 0 });
        roundtrip_req(&PsRequest::ListBranches { session: 12 });
        roundtrip_req(&PsRequest::EndSession { session: 12 });
        roundtrip_req(&PsRequest::Shutdown);
    }

    #[test]
    fn session_stamp_is_backward_compatible() {
        // Session-0 frames must encode WITHOUT a session key — byte
        // identical to the pre-session wire format...
        let line = encode_ps_request(&PsRequest::ReadRow {
            session: 0,
            branch: 3,
            table: 0,
            key: 9,
            with_accum: false,
        });
        assert!(!line.contains("session"), "{line}");
        // ...and a pre-session peer's frame (no key) decodes as
        // session 0.
        let old = "{\"op\":\"free\",\"branch\":4}";
        assert_eq!(
            decode_ps_request(old).unwrap(),
            PsRequest::FreeBranch { session: 0, branch: 4 }
        );
        // stamped frames put the session right after the op
        let line = encode_ps_request(&PsRequest::FreeBranch { session: 7, branch: 4 });
        assert_eq!(line, "{\"op\":\"free\",\"session\":7,\"branch\":4}");
        // strict decode: non-integers rejected like every id field
        assert!(decode_ps_request("{\"op\":\"free\",\"session\":1.5,\"branch\":4}").is_err());
        assert!(decode_ps_request("{\"op\":\"free\",\"session\":-1,\"branch\":4}").is_err());
        // hello attach: name must be a string, lease must be present
        assert!(decode_ps_request("{\"op\":\"hello\",\"session_name\":7}").is_err());
        assert!(decode_ps_request("{\"op\":\"hello\",\"session_name\":\"x\"}").is_err());
    }

    #[test]
    fn publish_progress_f64s_survive_bit_exact() {
        // NaN progress is exactly what a diverging trial reports; the
        // hex bit-pattern encoding must round-trip it (PartialEq
        // cannot, so compare bits directly).
        let req = PsRequest::PublishProgress {
            event: TrialEvent {
                session: 0,
                episode: 0,
                trial: 0,
                branch: 1,
                clock: 3,
                progress: f64::from_bits(0x7ff8_0000_dead_beef),
                time: f64::NEG_INFINITY,
            },
        };
        let back = decode_ps_request(&encode_ps_request(&req)).unwrap();
        let PsRequest::PublishProgress { event } = back else {
            panic!("wrong op")
        };
        assert_eq!(event.progress.to_bits(), 0x7ff8_0000_dead_beef);
        assert_eq!(event.time.to_bits(), f64::NEG_INFINITY.to_bits());
    }

    #[test]
    fn checkpoint_frames_roundtrip() {
        roundtrip_reply(&PsReply::Segments { segments: vec![] });
        roundtrip_reply(&PsReply::Segments {
            segments: vec![
                SegmentMeta {
                    file: "b1-r0-2-s0.seg".into(),
                    branch: 1,
                    range_begin: 0,
                    range_end: 2,
                    local_shard: 0,
                    rows: 17,
                    bytes: 4096,
                    checksum: u64::MAX,
                },
                SegmentMeta {
                    file: "b1-r0-2-s1.seg".into(),
                    branch: 1,
                    range_begin: 0,
                    range_end: 2,
                    local_shard: 1,
                    rows: 0,
                    bytes: 48,
                    checksum: 0,
                },
            ],
        });
        roundtrip_reply(&PsReply::Verified { rows: 0 });
        roundtrip_reply(&PsReply::Restored { rows: 1 << 20 });
        // strict decoding: short entries and bad checksums are errors
        let short = "{\"op\":\"segments\",\"segments\":[[\"f\",1,0,2,0,1,2]]}";
        assert!(decode_ps_reply(short).is_err());
        assert!(decode_ps_reply(
            "{\"op\":\"segments\",\"segments\":[[\"f\",1,0,2,0,1,2,\"nothex\"]]}"
        )
        .is_err());
        assert!(decode_ps_request("{\"op\":\"ckpt\",\"branch\":0}").is_err());
        assert!(decode_ps_request("{\"op\":\"restore\",\"branch\":0,\"dir\":7}").is_err());
    }

    #[test]
    fn ps_reply_frames_roundtrip() {
        roundtrip_reply(&PsReply::Hello {
            shard_begin: 2,
            shard_end: 4,
            optimizer: "adarevision".into(),
            codec: WireCodec::Json,
            session: 0,
        });
        roundtrip_reply(&PsReply::Hello {
            shard_begin: 0,
            shard_end: 8,
            optimizer: "sgd".into(),
            codec: WireCodec::Binary,
            session: 3,
        });
        roundtrip_reply(&PsReply::Ok);
        roundtrip_reply(&PsReply::BranchList { branches: vec![] });
        roundtrip_reply(&PsReply::BranchList { branches: vec![(0, 22), (5, 0)] });
        roundtrip_reply(&PsReply::Row {
            data: Some(vec![1.0, f32::NEG_INFINITY, -0.0]),
            accum: None,
        });
        roundtrip_reply(&PsReply::Row { data: None, accum: None });
        roundtrip_reply(&PsReply::RowsData {
            rows: vec![
                Some((vec![1.0, f32::NEG_INFINITY, -0.0], None)),
                None,
                Some((vec![], Some(vec![2.5, 1.0e-45]))),
            ],
        });
        roundtrip_reply(&PsReply::RowsData { rows: vec![] });
        let delta = sample_delta();
        roundtrip_reply(&PsReply::Stats(delta.clone()));
        roundtrip_reply(&PsReply::StatsDelta(delta));
        roundtrip_reply(&PsReply::Err {
            message: "row (0,99) missing in branch 7\nwith \"quotes\"".into(),
        });
    }

    fn sample_delta() -> ServerDelta {
        let mut rpc_hist = [0u64; HIST_BUCKETS];
        rpc_hist[0] = 5;
        rpc_hist[7] = 2;
        ServerDelta {
            server: ServerPlane {
                shard_lock_contentions: 3,
                batch_calls: 10,
                batched_rows: 640,
                reads_batched: 4096,
                rows_applied: 1000,
                rows_read: 5000,
            },
            store: StorePlane {
                forks: 7,
                peak_branches: 3,
                live_branches: 2,
                cow_buffer_copies: 3,
                read_rpcs: 11,
            },
            pool: PoolStats {
                reused: 1,
                allocated: 2,
                idle: 3,
                idle_len: 48,
            },
            wire: WirePlane {
                bytes_tx: 1 << 30,
                bytes_rx: 12345,
                frames_json: 17,
                frames_bin: 9000,
            },
            shards: vec![
                ShardRows { shard: 2, rows_applied: 600, rows_read: 3000 },
                ShardRows { shard: 3, rows_applied: 400, rows_read: 2000 },
            ],
            rpc_hist,
            branches: vec![(0, 100), (5, 40)],
            trials: vec![TrialEvent {
                session: 2,
                episode: 0,
                trial: 3,
                branch: 5,
                clock: 42,
                progress: -1.25,
                time: 0.5,
            }],
            sessions: vec![SessionStats {
                session: 2,
                rows_applied: 600,
                rows_read: 3000,
                deferrals: 4,
                live_branches: 1,
            }],
            ..ServerDelta::default()
        }
    }

    #[test]
    fn stats_frames_are_versioned() {
        // Every stats frame carries the schema version up front...
        let line = encode_ps_reply(&PsReply::StatsDelta(ServerDelta::default()));
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.get("v").and_then(|x| x.as_f64()), Some(2.0));
        // ...and a frame from a hypothetical newer peer is a typed
        // version error, not a field-by-field misdecode.
        let newer = line.replacen("\"v\":2", "\"v\":3", 1);
        let err = decode_ps_reply(&newer).unwrap_err().to_string();
        assert!(err.contains("schema version 3"), "{err}");
        // missing version is rejected too
        let unversioned = line.replacen("\"v\":2,", "", 1);
        assert!(decode_ps_reply(&unversioned).is_err());
        // truncated histograms never decode into a short array
        let line = encode_ps_reply(&PsReply::Stats(sample_delta()));
        let chopped = line.replacen("\"rpc_hist\":[5,", "\"rpc_hist\":[", 1);
        assert!(decode_ps_reply(&chopped).is_err());
    }

    #[test]
    fn hello_codec_negotiation_is_backward_compatible() {
        // A pre-codec peer sends hello frames with no codec field at
        // all; both sides must decode that as JSON, and a JSON hello
        // must *encode* without the field so old peers can parse it.
        assert_eq!(
            decode_ps_request("{\"op\":\"hello\"}").unwrap(),
            PsRequest::Hello { codec: WireCodec::Json, session: None }
        );
        assert_eq!(
            encode_ps_request(&PsRequest::Hello { codec: WireCodec::Json, session: None }),
            "{\"op\":\"hello\"}"
        );
        let old_reply = "{\"op\":\"hello\",\"begin\":0,\"end\":4,\"optimizer\":\"sgd\"}";
        let PsReply::Hello { codec, .. } = decode_ps_reply(old_reply).unwrap() else {
            panic!("wrong op")
        };
        assert_eq!(codec, WireCodec::Json);
        // unknown codec names are a typed error, not a silent downgrade
        assert!(decode_ps_request("{\"op\":\"hello\",\"codec\":\"msgpack\"}").is_err());
        assert!(decode_ps_request("{\"op\":\"hello\",\"codec\":7}").is_err());
    }

    #[test]
    fn f32_bit_patterns_survive_bit_exact() {
        // NaN payloads included: the bit-pattern encoding must return
        // the identical u32 for every value.
        let weird = [
            f32::NAN,
            f32::from_bits(0x7fc0_dead),
            f32::INFINITY,
            f32::NEG_INFINITY,
            -0.0f32,
            f32::MIN_POSITIVE,
            1.0e-45,
            f32::MAX,
        ];
        let req = PsRequest::InsertRow {
            session: 0,
            branch: 0,
            table: 0,
            key: 0,
            data: weird.to_vec(),
        };
        let back = decode_ps_request(&encode_ps_request(&req)).unwrap();
        let PsRequest::InsertRow { data, .. } = back else {
            panic!("wrong op")
        };
        for (a, b) in weird.iter().zip(&data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn ps_decode_rejects_garbage() {
        assert!(decode_ps_request("not json").is_err());
        assert!(decode_ps_request("{\"op\":\"dance\"}").is_err());
        assert!(decode_ps_request("{\"op\":\"insert\",\"branch\":0}").is_err());
        // bit patterns must be u32-range integers
        assert!(
            decode_ps_request(
                "{\"op\":\"insert\",\"branch\":0,\"table\":0,\"key\":0,\"data\":[1.5]}"
            )
            .is_err()
        );
        assert!(
            decode_ps_request(
                "{\"op\":\"insert\",\"branch\":0,\"table\":0,\"key\":0,\"data\":[4294967296]}"
            )
            .is_err()
        );
        assert!(decode_ps_reply("{\"op\":\"row\"}").is_err());
        assert!(decode_ps_reply("{\"op\":\"stats\"}").is_err());
        // batched-read frames decode just as strictly
        assert!(
            decode_ps_request("{\"op\":\"read_rows\",\"branch\":0,\"accum\":true,\"keys\":[[0]]}")
                .is_err()
        );
        assert!(
            decode_ps_request("{\"op\":\"read_rows\",\"branch\":0,\"accum\":1,\"keys\":[]}")
                .is_err()
        );
        assert!(
            decode_ps_request(
                "{\"op\":\"read_rows\",\"branch\":0,\"accum\":false,\"keys\":[[0,1.5]]}"
            )
            .is_err()
        );
        assert!(decode_ps_reply("{\"op\":\"rows\"}").is_err());
        assert!(decode_ps_reply("{\"op\":\"rows\",\"rows\":[[[1.5],null]]}").is_err());
        assert!(decode_ps_reply("{\"op\":\"rows\",\"rows\":[[]]}").is_err());
    }

    #[test]
    fn float_precision_survives_the_wire() {
        let m = TunerMsg::ForkBranch {
            clock: 0,
            branch_id: 1,
            parent_branch_id: Some(0),
            tunable: TunableSetting::new(vec![
                1.2345678901234567e-5,
                0.9999999999999999,
            ]),
            branch_type: BranchType::Training,
        };
        let back = decode_tuner_msg(&encode_tuner_msg(&m)).unwrap();
        assert_eq!(m, back);
    }
}
