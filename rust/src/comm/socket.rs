//! Byte-stream transport over real sockets (§4.5 "works as a separate
//! process") — TCP and Unix-domain, carrying both protocol planes of
//! [`super::wire`]: the TunerMsg/SystemMsg control plane and the
//! PsRequest/PsReply parameter-server data plane.
//!
//! Framing is selectable per connection and must match on both ends:
//!
//! * [`Framing::Line`] — one JSON frame per `\n`-terminated line (the
//!   encoding of `wire.rs` never emits a newline inside a frame).
//!   Human-readable; `nc` works against it.
//! * [`Framing::Length`] — a 4-byte big-endian payload length followed
//!   by the payload bytes.  Self-delimiting without scanning, and the
//!   framing the truncation/garbage tests exercise: a frame whose
//!   header promises more bytes than [`MAX_FRAME_LEN`] is rejected
//!   outright instead of allocating unboundedly.
//! * [`Framing::Binary`] — the same 4-byte big-endian outer header as
//!   `Length`, but the frame *body* may carry either a JSON text frame
//!   or a [`super::binwire`] binary frame; the receiver dispatches on
//!   the body's first byte (JSON starts with `{`, binary opcodes are
//!   all below `0x20`).  This is what lets codec negotiation ride a
//!   plain-JSON `Hello` over the same connection that then switches to
//!   binary data-plane frames.
//!
//! Addresses are parsed by [`SocketSpec`]: `host:port`,
//! `tcp://host:port`, or `unix:/path/to.sock`.  A client-side server
//! list (`remote://addr1,addr2,...`) is parsed by
//! [`parse_server_list`].  TCP connections set `TCP_NODELAY`: the data
//! plane is request/response, where Nagle+delayed-ACK would add ~40 ms
//! to every RPC.

use std::fmt;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};

use anyhow::{anyhow, bail, Context, Result};

use super::wire::{
    decode_system_msg, decode_tuner_msg, encode_system_msg, encode_tuner_msg,
};
use super::{SystemMsg, TunerMsg};

/// Upper bound on one frame's payload (64 MiB).  Far above any real
/// frame (the largest is an `apply_batch` group), small enough that a
/// garbage length header cannot drive an unbounded allocation.
pub const MAX_FRAME_LEN: usize = 64 << 20;

/// One socket address, TCP or Unix-domain.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SocketSpec {
    /// `host:port` (port 0 = ephemeral, resolved at bind).
    Tcp(String),
    /// Filesystem path of a Unix-domain socket.
    Unix(String),
}

impl SocketSpec {
    /// Parse `host:port`, `tcp://host:port`, or `unix:/path`.
    pub fn parse(s: &str) -> Result<SocketSpec> {
        let s = s.trim();
        if let Some(path) = s.strip_prefix("unix:") {
            let path = path.strip_prefix("//").unwrap_or(path);
            if path.is_empty() {
                bail!("empty unix socket path in {s:?}");
            }
            return Ok(SocketSpec::Unix(path.to_string()));
        }
        let addr = s.strip_prefix("tcp://").unwrap_or(s);
        // require host:port shape (rsplit: IPv6 hosts contain ':')
        match addr.rsplit_once(':') {
            Some((host, port)) if !host.is_empty() && port.parse::<u16>().is_ok() => {
                Ok(SocketSpec::Tcp(addr.to_string()))
            }
            _ => bail!("bad socket address {s:?} (want host:port or unix:/path)"),
        }
    }

    /// Connect a client [`Conn`] to this address.
    pub fn connect(&self, framing: Framing) -> Result<Conn> {
        match self {
            SocketSpec::Tcp(addr) => {
                let stream =
                    TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
                Conn::from_tcp(stream, framing)
            }
            #[cfg(unix)]
            SocketSpec::Unix(path) => {
                let stream =
                    UnixStream::connect(path).with_context(|| format!("connecting to {path}"))?;
                Conn::from_unix(stream, framing)
            }
            #[cfg(not(unix))]
            SocketSpec::Unix(path) => {
                bail!("unix-domain sockets unsupported on this platform: {path}")
            }
        }
    }
}

impl fmt::Display for SocketSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SocketSpec::Tcp(addr) => write!(f, "{addr}"),
            SocketSpec::Unix(path) => write!(f, "unix:{path}"),
        }
    }
}

/// Parse a client-side shard-server list: `remote://addr1,addr2,...`
/// (the `remote://` prefix is optional so bare comma lists also work).
pub fn parse_server_list(s: &str) -> Result<Vec<SocketSpec>> {
    let list = s.trim().strip_prefix("remote://").unwrap_or(s.trim());
    let specs = list
        .split(',')
        .filter(|p| !p.trim().is_empty())
        .map(SocketSpec::parse)
        .collect::<Result<Vec<_>>>()?;
    if specs.is_empty() {
        bail!("empty shard-server list {s:?}");
    }
    Ok(specs)
}

/// Frame delimiting on the byte stream; must match on both ends.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Framing {
    #[default]
    Line,
    Length,
    /// Length-framed bodies that may be JSON *or* `binwire` binary
    /// frames, dispatched per frame on the body's first byte.  The
    /// data-plane codec itself is negotiated at `Hello`
    /// (`wire::WireCodec`).
    Binary,
}

impl Framing {
    pub fn parse(s: &str) -> Result<Framing> {
        match s {
            "line" => Ok(Framing::Line),
            "length" => Ok(Framing::Length),
            "binary" => Ok(Framing::Binary),
            other => bail!("unknown framing {other:?} (want line|length|binary)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Framing::Line => "line",
            Framing::Length => "length",
            Framing::Binary => "binary",
        }
    }
}

/// Encode one length-prefixed frame (4-byte big-endian header).
/// Errors when the payload exceeds [`MAX_FRAME_LEN`] — the old `as
/// u32` header cast silently truncated oversized payloads into frames
/// that decoded as garbage.
pub fn encode_length_frame(payload: &[u8]) -> Result<Vec<u8>> {
    if payload.len() > MAX_FRAME_LEN {
        bail!("frame length {} exceeds maximum {MAX_FRAME_LEN}", payload.len());
    }
    let len = u32::try_from(payload.len())
        .map_err(|_| anyhow!("frame length {} exceeds u32", payload.len()))?;
    let mut out = Vec::with_capacity(payload.len() + 4);
    out.extend_from_slice(&len.to_be_bytes());
    out.extend_from_slice(payload);
    Ok(out)
}

/// Decode one length-prefixed frame from the front of `buf`.
///
/// Returns `Ok(None)` when the buffer holds only a truncated frame
/// (header or payload incomplete — the caller needs more bytes), and
/// an error when the header promises more than [`MAX_FRAME_LEN`].
pub fn decode_length_frame(buf: &[u8]) -> Result<Option<(Vec<u8>, usize)>> {
    if buf.len() < 4 {
        return Ok(None);
    }
    let header = u32::from_be_bytes([buf[0], buf[1], buf[2], buf[3]]);
    let len = usize::try_from(header)
        .map_err(|_| anyhow!("frame length {header} exceeds usize"))?;
    if len > MAX_FRAME_LEN {
        bail!("frame length {len} exceeds maximum {MAX_FRAME_LEN}");
    }
    if buf.len() < 4 + len {
        return Ok(None);
    }
    Ok(Some((buf[4..4 + len].to_vec(), 4 + len)))
}

/// One framed, buffered, bidirectional connection.
pub struct Conn {
    reader: BufReader<Box<dyn Read + Send>>,
    writer: BufWriter<Box<dyn Write + Send>>,
    framing: Framing,
}

impl Conn {
    pub fn from_tcp(stream: TcpStream, framing: Framing) -> Result<Conn> {
        // request/response RPCs: never let Nagle hold a frame back
        stream.set_nodelay(true)?;
        let reader = stream.try_clone()?;
        Ok(Conn {
            reader: BufReader::new(Box::new(reader)),
            writer: BufWriter::new(Box::new(stream)),
            framing,
        })
    }

    #[cfg(unix)]
    pub fn from_unix(stream: UnixStream, framing: Framing) -> Result<Conn> {
        let reader = stream.try_clone()?;
        Ok(Conn {
            reader: BufReader::new(Box::new(reader)),
            writer: BufWriter::new(Box::new(stream)),
            framing,
        })
    }

    pub fn framing(&self) -> Framing {
        self.framing
    }

    /// Send one frame (flushes: every frame is an RPC half).
    pub fn send(&mut self, payload: &str) -> Result<()> {
        match self.framing {
            Framing::Line => {
                if payload.as_bytes().contains(&b'\n') {
                    bail!("line framing cannot carry embedded newlines");
                }
                self.writer.write_all(payload.as_bytes())?;
                self.writer.write_all(b"\n")?;
                self.writer.flush()?;
                Ok(())
            }
            Framing::Length | Framing::Binary => self.send_bytes(payload.as_bytes()),
        }
    }

    /// Send one raw frame body (the binary data plane).  Only the
    /// self-delimiting framings can carry arbitrary bytes; asking line
    /// framing to is a protocol bug, not a truncation.
    pub fn send_bytes(&mut self, payload: &[u8]) -> Result<()> {
        match self.framing {
            Framing::Line => bail!("line framing cannot carry binary frames"),
            Framing::Length | Framing::Binary => {
                if payload.len() > MAX_FRAME_LEN {
                    bail!("frame length {} exceeds maximum {MAX_FRAME_LEN}", payload.len());
                }
                let len = u32::try_from(payload.len())
                    .map_err(|_| anyhow!("frame length {} exceeds u32", payload.len()))?;
                self.writer.write_all(&len.to_be_bytes())?;
                self.writer.write_all(payload)?;
                self.writer.flush()?;
                Ok(())
            }
        }
    }

    /// Receive one frame; `Ok(None)` on clean EOF at a frame boundary.
    pub fn recv(&mut self) -> Result<Option<String>> {
        match self.framing {
            Framing::Line => {
                let mut line = String::new();
                let n = self.reader.read_line(&mut line)?;
                if n == 0 {
                    return Ok(None);
                }
                while line.ends_with('\n') || line.ends_with('\r') {
                    line.pop();
                }
                Ok(Some(line))
            }
            Framing::Length | Framing::Binary => match self.recv_bytes()? {
                None => Ok(None),
                Some(payload) => String::from_utf8(payload)
                    .map(Some)
                    .map_err(|_| anyhow!("frame is not utf-8")),
            },
        }
    }

    /// Receive one raw frame body; `Ok(None)` on clean EOF at a frame
    /// boundary.  Line framing cannot delimit arbitrary bytes.
    pub fn recv_bytes(&mut self) -> Result<Option<Vec<u8>>> {
        match self.framing {
            Framing::Line => bail!("line framing cannot carry binary frames"),
            Framing::Length | Framing::Binary => {
                let mut header = [0u8; 4];
                match self.reader.read_exact(&mut header) {
                    Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
                        return Ok(None)
                    }
                    r => r?,
                }
                let wire_len = u32::from_be_bytes(header);
                let len = usize::try_from(wire_len)
                    .map_err(|_| anyhow!("frame length {wire_len} exceeds usize"))?;
                if len > MAX_FRAME_LEN {
                    bail!("frame length {len} exceeds maximum {MAX_FRAME_LEN}");
                }
                let mut payload = vec![0u8; len];
                self.reader
                    .read_exact(&mut payload)
                    .context("truncated frame")?;
                Ok(Some(payload))
            }
        }
    }

    /// Receive one frame, treating EOF as an error (RPC reply wanted).
    pub fn recv_expect(&mut self) -> Result<String> {
        self.recv()?
            .ok_or_else(|| anyhow!("peer closed the connection mid-protocol"))
    }

    // -- control-plane helpers: Table-1 messages over the socket -----

    pub fn send_tuner_msg(&mut self, msg: &TunerMsg) -> Result<()> {
        self.send(&encode_tuner_msg(msg))
    }

    pub fn recv_tuner_msg(&mut self) -> Result<Option<TunerMsg>> {
        match self.recv()? {
            None => Ok(None),
            Some(line) => Ok(Some(decode_tuner_msg(&line)?)),
        }
    }

    pub fn send_system_msg(&mut self, msg: &SystemMsg) -> Result<()> {
        self.send(&encode_system_msg(msg))
    }

    pub fn recv_system_msg(&mut self) -> Result<Option<SystemMsg>> {
        match self.recv()? {
            None => Ok(None),
            Some(line) => Ok(Some(decode_system_msg(&line)?)),
        }
    }
}

/// One accepted byte stream in its raw, unbuffered form — what the
/// readiness-driven server loop (`comm::poll`) drives nonblocking,
/// with its own per-connection buffers instead of `BufReader`/
/// `BufWriter`.
pub enum Stream {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Stream {
    pub fn set_nonblocking(&self, nonblocking: bool) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_nonblocking(nonblocking),
            #[cfg(unix)]
            Stream::Unix(s) => s.set_nonblocking(nonblocking),
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Stream::Unix(s) => s.flush(),
        }
    }
}

#[cfg(unix)]
impl std::os::unix::io::AsRawFd for Stream {
    fn as_raw_fd(&self) -> std::os::unix::io::RawFd {
        match self {
            Stream::Tcp(s) => s.as_raw_fd(),
            Stream::Unix(s) => s.as_raw_fd(),
        }
    }
}

/// A bound listener (TCP or Unix-domain).
pub enum PsListener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener, String),
}

impl PsListener {
    /// Bind to `spec`.  For TCP port 0 the kernel picks an ephemeral
    /// port; [`PsListener::local_spec`] reports the resolved address.
    pub fn bind(spec: &SocketSpec) -> Result<PsListener> {
        match spec {
            SocketSpec::Tcp(addr) => {
                let l = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
                Ok(PsListener::Tcp(l))
            }
            #[cfg(unix)]
            SocketSpec::Unix(path) => {
                // a stale socket file from a dead server blocks bind
                let _ = std::fs::remove_file(path);
                let l = UnixListener::bind(path).with_context(|| format!("binding {path}"))?;
                Ok(PsListener::Unix(l, path.clone()))
            }
            #[cfg(not(unix))]
            SocketSpec::Unix(path) => {
                bail!("unix-domain sockets unsupported on this platform: {path}")
            }
        }
    }

    /// The bound address (with the kernel-resolved port for TCP :0).
    pub fn local_spec(&self) -> Result<SocketSpec> {
        match self {
            PsListener::Tcp(l) => Ok(SocketSpec::Tcp(l.local_addr()?.to_string())),
            #[cfg(unix)]
            PsListener::Unix(_, path) => Ok(SocketSpec::Unix(path.clone())),
        }
    }

    /// Block for the next connection.
    pub fn accept(&self, framing: Framing) -> Result<Conn> {
        match self {
            PsListener::Tcp(l) => {
                let (stream, _) = l.accept()?;
                Conn::from_tcp(stream, framing)
            }
            #[cfg(unix)]
            PsListener::Unix(l, _) => {
                let (stream, _) = l.accept()?;
                Conn::from_unix(stream, framing)
            }
        }
    }

    /// Accept the next connection as a raw [`Stream`] — the form the
    /// event loop wants.  Returns `std::io::Error` unwrapped so a
    /// nonblocking listener's `WouldBlock` stays matchable.
    pub fn accept_stream(&self) -> std::io::Result<Stream> {
        match self {
            PsListener::Tcp(l) => {
                let (stream, _) = l.accept()?;
                stream.set_nodelay(true)?;
                Ok(Stream::Tcp(stream))
            }
            #[cfg(unix)]
            PsListener::Unix(l, _) => {
                let (stream, _) = l.accept()?;
                Ok(Stream::Unix(stream))
            }
        }
    }

    pub fn set_nonblocking(&self, nonblocking: bool) -> std::io::Result<()> {
        match self {
            PsListener::Tcp(l) => l.set_nonblocking(nonblocking),
            #[cfg(unix)]
            PsListener::Unix(l, _) => l.set_nonblocking(nonblocking),
        }
    }
}

#[cfg(unix)]
impl std::os::unix::io::AsRawFd for PsListener {
    fn as_raw_fd(&self) -> std::os::unix::io::RawFd {
        match self {
            PsListener::Tcp(l) => l.as_raw_fd(),
            PsListener::Unix(l, _) => l.as_raw_fd(),
        }
    }
}

#[cfg(unix)]
impl Drop for PsListener {
    fn drop(&mut self) {
        if let PsListener::Unix(_, path) = self {
            let _ = std::fs::remove_file(path);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::BranchType;
    use crate::tunable::TunableSetting;

    fn ephemeral_tcp() -> (PsListener, SocketSpec) {
        let l = PsListener::bind(&SocketSpec::parse("127.0.0.1:0").unwrap()).unwrap();
        let spec = l.local_spec().unwrap();
        (l, spec)
    }

    fn echo_roundtrip(listener: PsListener, spec: SocketSpec, framing: Framing) {
        let server = std::thread::spawn(move || {
            let mut conn = listener.accept(framing).unwrap();
            while let Some(frame) = conn.recv().unwrap() {
                conn.send(&format!("echo:{frame}")).unwrap();
            }
        });
        let mut conn = spec.connect(framing).unwrap();
        for payload in ["hello", "", "{\"op\":\"stats\"}", "x".repeat(100_000).as_str()] {
            conn.send(payload).unwrap();
            assert_eq!(conn.recv_expect().unwrap(), format!("echo:{payload}"));
        }
        drop(conn); // EOF ends the echo loop
        server.join().unwrap();
    }

    #[test]
    fn tcp_line_framing_roundtrip() {
        let (l, spec) = ephemeral_tcp();
        echo_roundtrip(l, spec, Framing::Line);
    }

    #[test]
    fn tcp_length_framing_roundtrip() {
        let (l, spec) = ephemeral_tcp();
        echo_roundtrip(l, spec, Framing::Length);
    }

    #[test]
    fn tcp_binary_framing_roundtrip() {
        // text frames ride binary framing unchanged (that is how the
        // JSON Hello negotiates before any binary frame flows)
        let (l, spec) = ephemeral_tcp();
        echo_roundtrip(l, spec, Framing::Binary);
    }

    #[test]
    fn binary_framing_carries_raw_bytes() {
        let (listener, spec) = ephemeral_tcp();
        let server = std::thread::spawn(move || {
            let mut conn = listener.accept(Framing::Binary).unwrap();
            while let Some(frame) = conn.recv_bytes().unwrap() {
                let mut echoed = frame;
                echoed.reverse();
                conn.send_bytes(&echoed).unwrap();
            }
        });
        let mut conn = spec.connect(Framing::Binary).unwrap();
        // non-UTF-8, NULs, 0xff — anything length framing delimits
        let payloads: [&[u8]; 3] = [&[0x01, 0xff, 0x00, 0x80], &[], &[0x7b, 0x00]];
        for payload in payloads {
            conn.send_bytes(payload).unwrap();
            let mut want = payload.to_vec();
            want.reverse();
            assert_eq!(conn.recv_bytes().unwrap().unwrap(), want);
        }
        drop(conn);
        server.join().unwrap();
    }

    #[test]
    fn line_framing_rejects_byte_frames() {
        let (listener, spec) = ephemeral_tcp();
        let _server = std::thread::spawn(move || {
            let _conn = listener.accept(Framing::Line);
        });
        let mut conn = spec.connect(Framing::Line).unwrap();
        assert!(conn.send_bytes(&[1, 2, 3]).is_err());
        assert!(conn.recv_bytes().is_err());
    }

    #[test]
    fn framing_parses_all_three() {
        for (s, f) in [
            ("line", Framing::Line),
            ("length", Framing::Length),
            ("binary", Framing::Binary),
        ] {
            assert_eq!(Framing::parse(s).unwrap(), f);
            assert_eq!(f.name(), s);
        }
        assert!(Framing::parse("msgpack").is_err());
    }

    #[cfg(unix)]
    #[test]
    fn unix_socket_roundtrip() {
        let path = std::env::temp_dir().join(format!("mltuner-sock-test-{}", std::process::id()));
        let spec = SocketSpec::Unix(path.to_string_lossy().into_owned());
        let listener = PsListener::bind(&spec).unwrap();
        echo_roundtrip(listener, spec.clone(), Framing::Length);
        // Drop removed the socket file, so a rebind must succeed.
        let listener = PsListener::bind(&spec).unwrap();
        drop(listener);
    }

    #[test]
    fn control_plane_messages_cross_a_real_socket() {
        // The §4.5 shape over TCP: coordinator sends ordered branch
        // ops, worker answers with per-clock progress.
        let (listener, spec) = ephemeral_tcp();
        let worker = std::thread::spawn(move || {
            let mut conn = listener.accept(Framing::Line).unwrap();
            let mut got = Vec::new();
            while let Some(msg) = conn.recv_tuner_msg().unwrap() {
                if let TunerMsg::ScheduleBranch { clock, .. } = msg {
                    conn.send_system_msg(&SystemMsg::ReportProgress {
                        clock,
                        progress: clock as f64 * 2.0,
                        time: 0.5,
                    })
                    .unwrap();
                }
                got.push(msg);
            }
            got
        });
        let mut conn = spec.connect(Framing::Line).unwrap();
        let fork = TunerMsg::ForkBranch {
            clock: 0,
            branch_id: 1,
            parent_branch_id: Some(0),
            tunable: TunableSetting::new(vec![1.25e-3]),
            branch_type: BranchType::Training,
        };
        conn.send_tuner_msg(&fork).unwrap();
        for clock in 0..3u64 {
            let sched = TunerMsg::ScheduleBranch {
                clock,
                branch_id: 1,
            };
            conn.send_tuner_msg(&sched).unwrap();
            let reply = conn.recv_system_msg().unwrap().unwrap();
            assert_eq!(reply, SystemMsg::ReportProgress {
                clock,
                progress: clock as f64 * 2.0,
                time: 0.5,
            });
        }
        drop(conn);
        let got = worker.join().unwrap();
        assert_eq!(got.len(), 4);
        assert_eq!(got[0], fork);
    }

    #[test]
    fn spec_parsing() {
        assert_eq!(
            SocketSpec::parse("127.0.0.1:80").unwrap(),
            SocketSpec::Tcp("127.0.0.1:80".into())
        );
        assert_eq!(
            SocketSpec::parse("tcp://h.example:9000").unwrap(),
            SocketSpec::Tcp("h.example:9000".into())
        );
        assert_eq!(
            SocketSpec::parse("unix:/tmp/x.sock").unwrap(),
            SocketSpec::Unix("/tmp/x.sock".into())
        );
        assert_eq!(
            SocketSpec::parse("unix:///tmp/x.sock").unwrap(),
            SocketSpec::Unix("/tmp/x.sock".into())
        );
        assert!(SocketSpec::parse("").is_err());
        assert!(SocketSpec::parse("no-port").is_err());
        assert!(SocketSpec::parse("host:notaport").is_err());
        assert!(SocketSpec::parse("unix:").is_err());
        let list = parse_server_list("remote://127.0.0.1:1,127.0.0.1:2").unwrap();
        assert_eq!(list.len(), 2);
        assert_eq!(list[1], SocketSpec::Tcp("127.0.0.1:2".into()));
        assert!(parse_server_list("remote://").is_err());
        // round-trip through Display
        for s in ["10.0.0.1:5001", "unix:/run/mltuner.sock"] {
            let spec = SocketSpec::parse(s).unwrap();
            assert_eq!(SocketSpec::parse(&spec.to_string()).unwrap(), spec);
        }
    }

    #[test]
    fn length_frame_codec_rejects_truncation_and_garbage() {
        let frame = encode_length_frame(b"abc").unwrap();
        assert_eq!(frame, vec![0, 0, 0, 3, b'a', b'b', b'c']);
        // whole frame decodes
        let (payload, used) = decode_length_frame(&frame).unwrap().unwrap();
        assert_eq!((payload.as_slice(), used), (&b"abc"[..], 7));
        // every truncation is "need more bytes", never a wrong decode
        for cut in 0..frame.len() {
            assert!(decode_length_frame(&frame[..cut]).unwrap().is_none(), "cut {cut}");
        }
        // a garbage header promising 4 GiB is rejected outright
        let garbage = [0xff, 0xff, 0xff, 0xff, 0, 0];
        assert!(decode_length_frame(&garbage).is_err());
        // concatenated frames decode one at a time
        let mut two = encode_length_frame(b"x").unwrap();
        two.extend(encode_length_frame(b"yz").unwrap());
        let (p1, used) = decode_length_frame(&two).unwrap().unwrap();
        assert_eq!(p1, b"x");
        let (p2, _) = decode_length_frame(&two[used..]).unwrap().unwrap();
        assert_eq!(p2, b"yz");
    }

    #[test]
    fn line_framing_rejects_embedded_newline() {
        let (listener, spec) = ephemeral_tcp();
        let _server = std::thread::spawn(move || {
            let _conn = listener.accept(Framing::Line);
        });
        let mut conn = spec.connect(Framing::Line).unwrap();
        assert!(conn.send("a\nb").is_err());
    }
}
