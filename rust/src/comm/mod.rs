//! MLtuner ↔ training-system messaging: a **two-plane protocol** over
//! interchangeable byte streams (§4.5, §4.6).
//!
//! **Control plane** — the Table-1 interface.  MLtuner identifies each
//! branch with a unique branch ID and uses `clock` as logical time —
//! unique and totally ordered across all branches.  Branch operations
//! ([`TunerMsg`]) are sent in clock order, with exactly one
//! `ScheduleBranch` per clock; the training system reports progress
//! ([`SystemMsg`]) with one `ReportProgress` per clock.  For
//! distributed systems the operations are broadcast to all workers in
//! the same order and the per-worker progress is folded with a
//! user-defined aggregation (sum, for the SGD loss apps in the paper).
//!
//! **Data plane** — the parameter-server RPCs
//! ([`wire::PsRequest`]/[`wire::PsReply`]) a training process issues
//! against remote shard servers.  Both directions of the hot path are
//! batched and routed once per call: updates group per shard server
//! into one `ApplyBatch` frame, and the gather phases' reads group the
//! same way into one `ReadRows` frame per server (±the AdaRevision
//! accumulator snapshot per row), so a data-parallel clock costs
//! O(shard servers × workers) RPCs instead of O(touched rows).
//! Single-row reads/updates, replicated branch fork/free, and the
//! stats probe ride the same frames; each client↔server link is a
//! small per-worker connection pool (one lease per in-flight RPC).
//! Row payloads are f32 bit patterns, so remote runs are bit-identical
//! to local ones.
//!
//! Two codecs encode a data-plane frame body: [`wire`] (one JSON
//! object per frame — the control-plane, debug, and compatibility
//! format) and [`binwire`] (fixed little-endian binary layout for the
//! hot path — raw f32 bit patterns, no decimal formatting, no per-row
//! allocation).  The codec is negotiated per connection at `Hello`:
//! old JSON-only peers keep working unchanged, and a frame body's
//! first byte (`{` vs. a binary opcode `< 0x20`) makes the two
//! self-distinguishing on the wire.
//!
//! Three carriers implement the byte stream:
//!
//! * [`transport`] — the in-process broker (mpsc channels) used by the
//!   simulated multi-worker deployments;
//! * [`socket`] — real TCP / Unix-domain sockets with line,
//!   length-prefix, or binary framing, carrying both planes between
//!   processes (the `mltuner serve` / `mltuner tune --ps remote://...`
//!   deployment, see [`crate::ps::remote`]);
//! * [`poll`] — the readiness-driven event loop (`epoll`/`poll(2)`)
//!   that `mltuner serve` runs sockets under: one poll thread, a
//!   bounded worker pool, no thread-per-connection.

pub mod binwire;
pub mod poll;
pub mod socket;
pub mod transport;
pub mod wire;

use crate::tunable::TunableSetting;

/// Logical time, unique and totally ordered across all branches.
pub type Clock = u64;

/// Unique branch identifier.
pub type BranchId = u32;

/// Tuning-session identifier, assigned by a shard server at `Hello`.
/// Session 0 is the default namespace every unregistered client lives
/// in — branch ids pass through unscoped, so a lone session behaves
/// bit-identically to the pre-session protocol.
pub type SessionId = u32;

/// Branch type carried by [`TunerMsg::ForkBranch`]: `Testing` branches
/// evaluate the model on validation data and report the validation
/// accuracy as their progress (§4.5 "Evaluating the model").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BranchType {
    #[default]
    Training,
    Testing,
}

/// Messages sent from MLtuner to the training system (Table 1).
#[derive(Debug, Clone, PartialEq)]
pub enum TunerMsg {
    /// Fork a branch by taking a consistent snapshot at `clock`.
    ForkBranch {
        clock: Clock,
        branch_id: BranchId,
        /// `None` forks from the pristine initial state (used by the
        /// train-to-completion baselines).
        parent_branch_id: Option<BranchId>,
        tunable: TunableSetting,
        branch_type: BranchType,
    },
    /// Free a branch at `clock`; the system reclaims its resources.
    FreeBranch { clock: Clock, branch_id: BranchId },
    /// Schedule `branch_id` to run (one clock of work) at `clock`.
    ScheduleBranch { clock: Clock, branch_id: BranchId },
}

impl TunerMsg {
    pub fn clock(&self) -> Clock {
        match self {
            TunerMsg::ForkBranch { clock, .. }
            | TunerMsg::FreeBranch { clock, .. }
            | TunerMsg::ScheduleBranch { clock, .. } => *clock,
        }
    }
}

/// Messages sent from the training system to MLtuner (Table 1).
#[derive(Debug, Clone, PartialEq)]
pub enum SystemMsg {
    /// Per-clock training progress (training loss for the SGD apps;
    /// validation accuracy for Testing branches).  `time` is the
    /// elapsed time of the clock in seconds (wall or simulated).
    ReportProgress {
        clock: Clock,
        progress: f64,
        time: f64,
    },
}

/// Fold per-worker progress reports into one value (§4.5 "Distributed
/// training support").  All SGD apps in the paper sum worker losses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ProgressAggregation {
    #[default]
    Sum,
    Mean,
    Max,
}

impl ProgressAggregation {
    pub fn fold(&self, parts: &[f64]) -> f64 {
        if parts.is_empty() {
            return f64::NAN;
        }
        match self {
            ProgressAggregation::Sum => parts.iter().sum(),
            ProgressAggregation::Mean => {
                parts.iter().sum::<f64>() / parts.len() as f64
            }
            ProgressAggregation::Max => {
                parts.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
            }
        }
    }
}

/// Clock-order validator: enforces the §4.5 protocol invariants —
/// branch operations arrive in clock order and exactly one
/// `ScheduleBranch` is sent for every clock.  Both the in-process
/// training systems and the tests wrap message streams in this.
#[derive(Debug, Default)]
pub struct ProtocolChecker {
    last_clock: Option<Clock>,
    schedules_seen: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProtocolError {
    OutOfOrder { got: Clock, last: Clock },
    DuplicateSchedule { clock: Clock },
    MissingSchedule { expected: Clock, got: Clock },
}

impl std::fmt::Display for ProtocolError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtocolError::OutOfOrder { got, last } => write!(
                f,
                "clock {got} not monotonically increasing (last {last})"
            ),
            ProtocolError::DuplicateSchedule { clock } => {
                write!(f, "clock {clock} scheduled more than once")
            }
            ProtocolError::MissingSchedule { expected, got } => write!(
                f,
                "clock gap: expected schedule for clock {expected}, got {got}"
            ),
        }
    }
}

impl std::error::Error for ProtocolError {}

impl ProtocolChecker {
    pub fn check(&mut self, msg: &TunerMsg) -> Result<(), ProtocolError> {
        let clock = msg.clock();
        if let Some(last) = self.last_clock {
            if clock < last {
                return Err(ProtocolError::OutOfOrder { got: clock, last });
            }
        }
        if let TunerMsg::ScheduleBranch { .. } = msg {
            if clock != self.schedules_seen {
                if clock < self.schedules_seen {
                    return Err(ProtocolError::DuplicateSchedule { clock });
                }
                return Err(ProtocolError::MissingSchedule {
                    expected: self.schedules_seen,
                    got: clock,
                });
            }
            self.schedules_seen += 1;
        }
        self.last_clock = Some(clock);
        Ok(())
    }

    pub fn schedules_seen(&self) -> u64 {
        self.schedules_seen
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched(clock: Clock) -> TunerMsg {
        TunerMsg::ScheduleBranch {
            clock,
            branch_id: 1,
        }
    }

    #[test]
    fn table1_signatures() {
        // Table 1: ForkBranch(clock, branchId, parentBranchId, tunable[, type]),
        // FreeBranch(clock, branchId), ScheduleBranch(clock, branchId),
        // ReportProgress(clock, progress).
        let fork = TunerMsg::ForkBranch {
            clock: 0,
            branch_id: 1,
            parent_branch_id: Some(0),
            tunable: TunableSetting::new(vec![0.01, 0.9, 32.0, 0.0]),
            branch_type: BranchType::Training,
        };
        assert_eq!(fork.clock(), 0);
        assert_eq!(fork.clone(), fork);
        let free = TunerMsg::FreeBranch {
            clock: 3,
            branch_id: 1,
        };
        assert_eq!(free.clock(), 3);
        let sched = TunerMsg::ScheduleBranch {
            clock: 4,
            branch_id: 2,
        };
        assert_eq!(sched.clock(), 4);
        let r = SystemMsg::ReportProgress {
            clock: 4,
            progress: 1.25,
            time: 0.5,
        };
        assert_eq!(r.clone(), r);
        // the optional branch type defaults to Training
        assert_eq!(BranchType::default(), BranchType::Training);
    }

    #[test]
    fn aggregation_folds() {
        let parts = [1.0, 2.0, 3.0];
        assert_eq!(ProgressAggregation::Sum.fold(&parts), 6.0);
        assert_eq!(ProgressAggregation::Mean.fold(&parts), 2.0);
        assert_eq!(ProgressAggregation::Max.fold(&parts), 3.0);
        assert!(ProgressAggregation::Sum.fold(&[]).is_nan());
    }

    #[test]
    fn checker_accepts_clock_ordered_stream() {
        let mut c = ProtocolChecker::default();
        let tun = TunableSetting::new(vec![0.1]);
        assert!(c
            .check(&TunerMsg::ForkBranch {
                clock: 0,
                branch_id: 1,
                parent_branch_id: None,
                tunable: tun.clone(),
                branch_type: BranchType::Training,
            })
            .is_ok());
        assert!(c.check(&sched(0)).is_ok());
        assert!(c.check(&sched(1)).is_ok());
        assert!(c
            .check(&TunerMsg::FreeBranch {
                clock: 2,
                branch_id: 1
            })
            .is_ok());
        assert_eq!(c.schedules_seen(), 2);
    }

    #[test]
    fn checker_rejects_out_of_order() {
        let mut c = ProtocolChecker::default();
        assert!(c.check(&sched(0)).is_ok());
        assert!(c.check(&sched(1)).is_ok());
        assert_eq!(
            c.check(&TunerMsg::FreeBranch {
                clock: 0,
                branch_id: 1
            }),
            Err(ProtocolError::OutOfOrder { got: 0, last: 1 })
        );
    }

    #[test]
    fn checker_rejects_schedule_gap_and_duplicate() {
        let mut c = ProtocolChecker::default();
        assert!(c.check(&sched(0)).is_ok());
        assert_eq!(
            c.check(&sched(2)),
            Err(ProtocolError::MissingSchedule {
                expected: 1,
                got: 2
            })
        );
        assert_eq!(
            c.check(&sched(0)),
            Err(ProtocolError::DuplicateSchedule { clock: 0 })
        );
    }
}
