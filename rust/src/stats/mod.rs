//! Unified, versioned statistics API — the observability plane's schema.
//!
//! One PR-8 redesign collapsed the three overlapping stats structs that
//! had accreted (`ps::ServerStats`, `ps::StoreStats`,
//! `training::SnapshotStats`) into the single [`Snapshot`] defined
//! here: a schema-versioned document with nested planes —
//! [`ServerPlane`] (engine hot-path counters), [`StorePlane`] (branch
//! census), [`crate::ps::pool::PoolStats`] (buffer pool), [`WirePlane`]
//! (transport).  Every probe in the stack returns it:
//!
//! * `ParamStore::stats` — one method, local engine and remote cluster
//!   alike (the remote impl merges per-server [`ServerDelta`]s);
//! * `TrainingSystem::stats` — apps overlay their branch view on the
//!   store probe;
//! * the wire — both the pull probe (`PsRequest::ServerStats`) and the
//!   push stream (`PsReply::StatsDelta`) carry a [`ServerDelta`], whose
//!   leading `version` field lets old peers reject frames from a newer
//!   schema with a typed error instead of misdecoding them.
//!
//! [`ServerDelta`] counters are **cumulative totals, not diffs**: a
//! subscriber that drops frames loses resolution, never correctness,
//! and merging is idempotent (take the latest frame per server).  That
//! choice gives the monotonic-merge invariant checked by
//! [`ServerDelta::check_monotonic`]: a later frame from the same server
//! may never report a smaller value for any cumulative counter.  Gauges
//! (`pool.idle`, live branch census) are exempt — they legitimately
//! shrink.
//!
//! [`LatencyHist`] is the coarse RPC-latency histogram recorded by the
//! `comm/poll.rs` worker pool: fixed log2 microsecond buckets, relaxed
//! atomics, zero hot-path locking.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Result};

use crate::comm::{BranchId, Clock, SessionId};
use crate::ps::pool::PoolStats;

/// Version stamped on every stats document and wire frame.  Bump it
/// whenever a field is added, removed or reinterpreted; decoders reject
/// unknown versions with a typed error.  v2 added the per-session
/// census ([`SessionStats`]) and the `session` field on
/// [`TrialEvent`].
pub const SCHEMA_VERSION: u32 = 2;

/// Number of log2 latency buckets: bucket `i` counts requests whose
/// service time fell in `[2^i, 2^(i+1))` microseconds (bucket 0 also
/// absorbs sub-microsecond requests; the last bucket is unbounded
/// above, covering everything from ~32ms up).
pub const HIST_BUCKETS: usize = 16;

/// Lock-free log2-bucketed latency histogram (microsecond scale).
///
/// Recording is one relaxed `fetch_add` — safe to call from every
/// worker thread on the request hot path.  Snapshots are relaxed loads
/// and therefore approximate under concurrent writers, which is fine:
/// the observability plane is monotonic per bucket, not transactional.
#[derive(Debug, Default)]
pub struct LatencyHist {
    buckets: [AtomicU64; HIST_BUCKETS],
}

/// Bucket index for a service time in microseconds.
pub fn bucket_of(micros: u64) -> usize {
    let log2 = 63u32.saturating_sub((micros | 1).leading_zeros());
    usize::try_from(log2).unwrap_or(HIST_BUCKETS - 1).min(HIST_BUCKETS - 1)
}

/// Inclusive lower bound of bucket `i` in microseconds (for display).
pub fn bucket_floor_micros(i: usize) -> u64 {
    if i == 0 {
        0
    } else {
        1u64 << i.min(63)
    }
}

impl LatencyHist {
    /// Count one request that took `micros` microseconds.
    pub fn record_micros(&self, micros: u64) {
        self.buckets[bucket_of(micros)].fetch_add(1, Ordering::Relaxed);
    }

    /// Relaxed snapshot of all bucket counts.
    pub fn snapshot(&self) -> [u64; HIST_BUCKETS] {
        std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed))
    }
}

/// Engine hot-path counters (sums across shards; cumulative).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServerPlane {
    /// Times a shard lock was found contended on first try.
    pub shard_lock_contentions: u64,
    /// `apply_batch` invocations.
    pub batch_calls: u64,
    /// Rows applied through `apply_batch`.
    pub batched_rows: u64,
    /// Rows read through `read_rows`.
    pub reads_batched: u64,
    /// Total rows applied (batched + single-row updates).
    pub rows_applied: u64,
    /// Total rows read (batched + single-row reads).
    pub rows_read: u64,
}

/// Branch-census plane (forks/peaks are per-process cumulative).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StorePlane {
    /// Branches forked since start.
    pub forks: u64,
    /// High-water mark of simultaneously live branches.
    pub peak_branches: usize,
    /// Branches live right now (gauge — may shrink).
    pub live_branches: usize,
    /// Buffers materialized for copy-on-write (`pool.allocated +
    /// pool.reused`).
    pub cow_buffer_copies: u64,
    /// Client-side read RPC count (0 in server-side documents; the
    /// remote store overlays its own counter).
    pub read_rpcs: u64,
}

/// Transport counters (zero for the in-process engine; `ShardServer`
/// overlays its socket-core metrics).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WirePlane {
    pub bytes_tx: u64,
    pub bytes_rx: u64,
    pub frames_json: u64,
    pub frames_bin: u64,
}

/// The one stats document every probe in the stack returns.
///
/// `Default` stamps the current [`SCHEMA_VERSION`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Snapshot {
    /// Schema version ([`SCHEMA_VERSION`] for documents built by this
    /// build).
    pub version: u32,
    pub server: ServerPlane,
    pub store: StorePlane,
    pub pool: PoolStats,
    pub wire: WirePlane,
}

impl Default for Snapshot {
    fn default() -> Self {
        Snapshot {
            version: SCHEMA_VERSION,
            server: ServerPlane::default(),
            store: StorePlane::default(),
            pool: PoolStats::default(),
            wire: WirePlane::default(),
        }
    }
}

impl Snapshot {
    /// Exact machine-readable rendering (`mltuner tune --stats-json`).
    /// Every field is an integer, so the document is lossless without
    /// any bit-pattern encoding.
    pub fn to_json(&self) -> String {
        format!(
            concat!(
                "{{\"v\":{},",
                "\"server\":{{\"shard_lock_contentions\":{},\"batch_calls\":{},",
                "\"batched_rows\":{},\"reads_batched\":{},\"rows_applied\":{},",
                "\"rows_read\":{}}},",
                "\"store\":{{\"forks\":{},\"peak_branches\":{},\"live_branches\":{},",
                "\"cow_buffer_copies\":{},\"read_rpcs\":{}}},",
                "\"pool\":{{\"reused\":{},\"allocated\":{},\"idle\":{},\"idle_len\":{}}},",
                "\"wire\":{{\"bytes_tx\":{},\"bytes_rx\":{},\"frames_json\":{},",
                "\"frames_bin\":{}}}}}"
            ),
            self.version,
            self.server.shard_lock_contentions,
            self.server.batch_calls,
            self.server.batched_rows,
            self.server.reads_batched,
            self.server.rows_applied,
            self.server.rows_read,
            self.store.forks,
            self.store.peak_branches,
            self.store.live_branches,
            self.store.cow_buffer_copies,
            self.store.read_rpcs,
            self.pool.reused,
            self.pool.allocated,
            self.pool.idle,
            self.pool.idle_len,
            self.wire.bytes_tx,
            self.wire.bytes_rx,
            self.wire.frames_json,
            self.wire.frames_bin,
        )
    }
}

/// Per-shard row-throughput counters (cumulative).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ShardRows {
    /// Global shard id.
    pub shard: u64,
    pub rows_applied: u64,
    pub rows_read: u64,
}

/// One tuner trial's latest progress, published into the stream so
/// `mltuner top` can show per-trial state next to the server counters.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TrialEvent {
    /// Session the trial belongs to (0 = the default namespace).  The
    /// server stamps this from the publishing frame's session, so a
    /// client cannot spoof another tenant's drill-down.
    pub session: SessionId,
    /// Tuning episode (0 = initial tuning).
    pub episode: u32,
    /// Trial index within the episode.
    pub trial: u32,
    /// Branch the trial trains on.
    pub branch: BranchId,
    /// Training clock of the sample.
    pub clock: Clock,
    /// Latest progress value (loss or accuracy; NaN survives the wire
    /// as a bit pattern).
    pub progress: f64,
    /// Trial-local training time at the sample.
    pub time: f64,
}

/// Per-session census entry: one line of the multi-tenant drill-down.
/// Row counters and `deferrals` are cumulative (monotonic per server
/// while the session lives); `live_branches` is a gauge.  Sessions may
/// appear (registration) and disappear (teardown / lease GC) between
/// frames, so the monotonic check only compares sessions present in
/// both.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SessionStats {
    /// Server-assigned session id (0 = the default namespace).
    pub session: SessionId,
    /// Rows applied on behalf of this session.
    pub rows_applied: u64,
    /// Rows read on behalf of this session.
    pub rows_read: u64,
    /// Times a frame from this session was deferred by the fairness
    /// token bucket (re-queued, never dropped).
    pub deferrals: u64,
    /// Branches live in this session's namespace right now (gauge).
    pub live_branches: usize,
}

/// One shard server's full stats document: the payload of both the
/// pull probe reply (`PsReply::Stats`) and the pushed stream frame
/// (`PsReply::StatsDelta`).  Counters are cumulative totals (see the
/// module docs for why), so "delta" refers to the frame cadence, not
/// the arithmetic.
#[derive(Debug, Clone, PartialEq)]
pub struct ServerDelta {
    /// Schema version; decoders reject anything newer than they know.
    pub version: u32,
    pub server: ServerPlane,
    pub store: StorePlane,
    pub pool: PoolStats,
    pub wire: WirePlane,
    /// Per-shard throughput, one entry per shard this server owns.
    pub shards: Vec<ShardRows>,
    /// RPC service-time histogram (log2 µs buckets).
    pub rpc_hist: [u64; HIST_BUCKETS],
    /// Live branches and their local row counts.
    pub branches: Vec<(BranchId, usize)>,
    /// Latest published trial progress, newest episode/trial last.
    pub trials: Vec<TrialEvent>,
    /// Per-session census, session-id order (empty when only the
    /// default session has ever touched this server).
    pub sessions: Vec<SessionStats>,
}

impl Default for ServerDelta {
    fn default() -> Self {
        ServerDelta {
            version: SCHEMA_VERSION,
            server: ServerPlane::default(),
            store: StorePlane::default(),
            pool: PoolStats::default(),
            wire: WirePlane::default(),
            shards: Vec::new(),
            rpc_hist: [0; HIST_BUCKETS],
            branches: Vec::new(),
            trials: Vec::new(),
            sessions: Vec::new(),
        }
    }
}

macro_rules! check_mono {
    ($prev:expr, $next:expr, $($field:ident . $sub:ident),+ $(,)?) => {
        $(
            if $next.$field.$sub < $prev.$field.$sub {
                bail!(
                    concat!(
                        "stats delta went backwards: ",
                        stringify!($field), ".", stringify!($sub),
                        " {} -> {} (same server must never decrease a counter)"
                    ),
                    $prev.$field.$sub,
                    $next.$field.$sub,
                );
            }
        )+
    };
}

impl ServerDelta {
    /// Monotonic-merge invariant: `self` (the newer frame) may never
    /// report a smaller value than `prev` for any cumulative counter.
    ///
    /// Counters are read with relaxed atomics while writers race, so a
    /// probe can be mid-clock *stale* but never *regressing*: each
    /// counter is its own monotonic atomic and a later probe strictly
    /// happens-after an earlier one on the same server.  Gauges
    /// (`pool.idle`, `pool.idle_len`, live branches, trials) are
    /// exempt.
    pub fn check_monotonic(&self, prev: &ServerDelta) -> Result<()> {
        check_mono!(
            prev,
            self,
            server.shard_lock_contentions,
            server.batch_calls,
            server.batched_rows,
            server.reads_batched,
            server.rows_applied,
            server.rows_read,
            store.forks,
            store.peak_branches,
            store.cow_buffer_copies,
            store.read_rpcs,
            pool.reused,
            pool.allocated,
            wire.bytes_tx,
            wire.bytes_rx,
            wire.frames_json,
            wire.frames_bin,
        );
        for (i, b) in self.rpc_hist.iter().enumerate() {
            if *b < prev.rpc_hist[i] {
                bail!("stats delta went backwards: rpc_hist[{i}] {} -> {}", prev.rpc_hist[i], b);
            }
        }
        for p in &prev.shards {
            match self.shards.iter().find(|s| s.shard == p.shard) {
                None => bail!("stats delta dropped shard {} (shard set is fixed)", p.shard),
                Some(s) if s.rows_applied < p.rows_applied || s.rows_read < p.rows_read => {
                    bail!(
                        "stats delta went backwards: shard {} rows ({}, {}) -> ({}, {})",
                        p.shard,
                        p.rows_applied,
                        p.rows_read,
                        s.rows_applied,
                        s.rows_read,
                    );
                }
                Some(_) => {}
            }
        }
        // Sessions may be registered or torn down between frames, so
        // only sessions present in BOTH frames are held monotonic.
        for p in &prev.sessions {
            if let Some(s) = self.sessions.iter().find(|s| s.session == p.session) {
                if s.rows_applied < p.rows_applied
                    || s.rows_read < p.rows_read
                    || s.deferrals < p.deferrals
                {
                    bail!(
                        "stats delta went backwards: session {} ({}, {}, {}) -> ({}, {}, {})",
                        p.session,
                        p.rows_applied,
                        p.rows_read,
                        p.deferrals,
                        s.rows_applied,
                        s.rows_read,
                        s.deferrals,
                    );
                }
            }
        }
        Ok(())
    }
}

/// Cluster-wide merge of the latest delta from each server: the view
/// `mltuner top` renders and the basis of the remote store's
/// [`Snapshot`] probe.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ClusterView {
    pub snapshot: Snapshot,
    /// Union of per-shard throughput across servers, shard-id order.
    pub shards: Vec<ShardRows>,
    /// Branch census: per-branch row counts summed across servers.
    pub branches: Vec<(BranchId, usize)>,
    /// Summed RPC latency histogram.
    pub rpc_hist: [u64; HIST_BUCKETS],
    /// Per-trial progress, deduplicated by (session, episode, trial).
    pub trials: Vec<TrialEvent>,
    /// Per-session census: row/deferral counters summed across
    /// servers, live branches maxed (branch ops replicate).
    pub sessions: Vec<SessionStats>,
    /// Servers that contributed a delta.
    pub servers: usize,
}

/// Merge per-server documents into one cluster view.
///
/// Throughput/wire/pool counters **sum** across servers; `forks` and
/// `peak_branches` take the **max** (branch ops broadcast, so every
/// server replicates them); branches **union** with row counts summed
/// (each server holds its own rows of a branch).
pub fn merge_cluster<'a>(deltas: impl IntoIterator<Item = &'a ServerDelta>) -> ClusterView {
    let mut out = ClusterView::default();
    let mut branches: BTreeMap<BranchId, usize> = BTreeMap::new();
    let mut shards: BTreeMap<u64, ShardRows> = BTreeMap::new();
    let mut trials: BTreeMap<(SessionId, u32, u32), TrialEvent> = BTreeMap::new();
    let mut sessions: BTreeMap<SessionId, SessionStats> = BTreeMap::new();
    for d in deltas {
        out.servers += 1;
        let snap = &mut out.snapshot;
        snap.version = snap.version.max(d.version);
        snap.server.shard_lock_contentions += d.server.shard_lock_contentions;
        snap.server.batch_calls += d.server.batch_calls;
        snap.server.batched_rows += d.server.batched_rows;
        snap.server.reads_batched += d.server.reads_batched;
        snap.server.rows_applied += d.server.rows_applied;
        snap.server.rows_read += d.server.rows_read;
        snap.store.forks = snap.store.forks.max(d.store.forks);
        snap.store.peak_branches = snap.store.peak_branches.max(d.store.peak_branches);
        snap.store.read_rpcs += d.store.read_rpcs;
        snap.pool.accumulate(d.pool);
        snap.wire.bytes_tx += d.wire.bytes_tx;
        snap.wire.bytes_rx += d.wire.bytes_rx;
        snap.wire.frames_json += d.wire.frames_json;
        snap.wire.frames_bin += d.wire.frames_bin;
        for (i, b) in d.rpc_hist.iter().enumerate() {
            out.rpc_hist[i] += b;
        }
        for s in &d.shards {
            let e = shards.entry(s.shard).or_insert(ShardRows { shard: s.shard, ..Default::default() });
            e.rows_applied += s.rows_applied;
            e.rows_read += s.rows_read;
        }
        for (id, rows) in &d.branches {
            *branches.entry(*id).or_default() += rows;
        }
        for t in &d.trials {
            trials.insert((t.session, t.episode, t.trial), *t);
        }
        for s in &d.sessions {
            let e = sessions
                .entry(s.session)
                .or_insert(SessionStats { session: s.session, ..Default::default() });
            e.rows_applied += s.rows_applied;
            e.rows_read += s.rows_read;
            e.deferrals += s.deferrals;
            // branch ops replicate to every server, so the per-server
            // live count is the session's count — max, not sum
            e.live_branches = e.live_branches.max(s.live_branches);
        }
    }
    out.snapshot.store.live_branches = branches.len();
    out.snapshot.store.cow_buffer_copies = out.snapshot.pool.allocated + out.snapshot.pool.reused;
    out.shards = shards.into_values().collect();
    out.branches = branches.into_iter().collect();
    out.trials = trials.into_values().collect();
    out.sessions = sessions.into_values().collect();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_indexing_is_log2_with_clamp() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 0);
        assert_eq!(bucket_of(2), 1);
        assert_eq!(bucket_of(3), 1);
        assert_eq!(bucket_of(4), 2);
        assert_eq!(bucket_of(1023), 9);
        assert_eq!(bucket_of(1024), 10);
        assert_eq!(bucket_of(u64::MAX), HIST_BUCKETS - 1);
        assert_eq!(bucket_floor_micros(0), 0);
        assert_eq!(bucket_floor_micros(1), 2);
        assert_eq!(bucket_floor_micros(10), 1024);
    }

    #[test]
    fn hist_records_and_snapshots() {
        let h = LatencyHist::default();
        h.record_micros(0);
        h.record_micros(1);
        h.record_micros(5);
        h.record_micros(1 << 20);
        let s = h.snapshot();
        assert_eq!(s[0], 2);
        assert_eq!(s[2], 1);
        assert_eq!(s[HIST_BUCKETS - 1], 1);
        assert_eq!(s.iter().sum::<u64>(), 4);
    }

    #[test]
    fn default_documents_carry_the_schema_version() {
        assert_eq!(Snapshot::default().version, SCHEMA_VERSION);
        assert_eq!(ServerDelta::default().version, SCHEMA_VERSION);
    }

    #[test]
    fn monotonic_check_accepts_growth_and_rejects_regression() {
        let mut a = ServerDelta::default();
        a.server.batched_rows = 10;
        a.shards = vec![ShardRows { shard: 3, rows_applied: 5, rows_read: 1 }];
        let mut b = a.clone();
        b.server.batched_rows = 12;
        b.shards[0].rows_applied = 9;
        b.pool.idle = 4; // gauge: free to move either way
        assert!(b.check_monotonic(&a).is_ok());
        assert!(b.check_monotonic(&b).is_ok(), "equality is monotonic");
        let err = a.check_monotonic(&b).unwrap_err().to_string();
        assert!(err.contains("went backwards"), "{err}");
        let mut c = b.clone();
        c.shards.clear();
        let err = c.check_monotonic(&b).unwrap_err().to_string();
        assert!(err.contains("dropped shard"), "{err}");
    }

    #[test]
    fn cluster_merge_sums_maxes_and_unions() {
        let mut a = ServerDelta::default();
        a.server.rows_applied = 10;
        a.store.forks = 4;
        a.store.peak_branches = 3;
        a.pool.allocated = 2;
        a.pool.reused = 1;
        a.shards = vec![ShardRows { shard: 0, rows_applied: 10, rows_read: 0 }];
        a.branches = vec![(0, 7), (2, 1)];
        a.rpc_hist[1] = 5;
        let mut b = ServerDelta::default();
        b.server.rows_applied = 20;
        b.store.forks = 4;
        b.store.peak_branches = 2;
        b.pool.allocated = 3;
        b.shards = vec![ShardRows { shard: 1, rows_applied: 20, rows_read: 2 }];
        b.branches = vec![(0, 5)];
        b.rpc_hist[1] = 7;
        let v = merge_cluster([&a, &b]);
        assert_eq!(v.servers, 2);
        assert_eq!(v.snapshot.server.rows_applied, 30);
        assert_eq!(v.snapshot.store.forks, 4, "forks replicate: max, not sum");
        assert_eq!(v.snapshot.store.peak_branches, 3);
        assert_eq!(v.snapshot.store.live_branches, 2);
        assert_eq!(v.snapshot.store.cow_buffer_copies, 6);
        assert_eq!(v.branches, vec![(0, 12), (2, 1)]);
        assert_eq!(v.shards.len(), 2);
        assert_eq!(v.rpc_hist[1], 12);
    }

    #[test]
    fn session_census_merges_and_stays_monotonic() {
        let mut a = ServerDelta::default();
        a.sessions = vec![
            SessionStats {
                session: 0,
                rows_applied: 5,
                rows_read: 2,
                deferrals: 0,
                live_branches: 1,
            },
            SessionStats {
                session: 7,
                rows_applied: 9,
                rows_read: 1,
                deferrals: 3,
                live_branches: 4,
            },
        ];
        let mut b = ServerDelta::default();
        b.sessions = vec![SessionStats {
            session: 7,
            rows_applied: 11,
            rows_read: 1,
            deferrals: 0,
            live_branches: 4,
        }];
        let v = merge_cluster([&a, &b]);
        assert_eq!(v.sessions.len(), 2);
        assert_eq!(v.sessions[1].session, 7);
        assert_eq!(v.sessions[1].rows_applied, 20, "row counters sum across servers");
        assert_eq!(v.sessions[1].deferrals, 3);
        assert_eq!(v.sessions[1].live_branches, 4, "live branches replicate: max");

        // same-server monotonicity: growth ok, shrink rejected,
        // appearing/disappearing sessions tolerated
        let mut next = a.clone();
        next.sessions[1].rows_applied = 12;
        next.sessions.remove(0); // session 0 torn down
        assert!(next.check_monotonic(&a).is_ok());
        let mut bad = a.clone();
        bad.sessions[1].deferrals = 1;
        let err = bad.check_monotonic(&a).unwrap_err().to_string();
        assert!(err.contains("session 7"), "{err}");
    }

    #[test]
    fn snapshot_json_is_versioned_and_parseable() {
        let mut s = Snapshot::default();
        s.server.rows_applied = 42;
        let doc = crate::util::json::Json::parse(&s.to_json()).unwrap();
        assert_eq!(doc.get("v").and_then(|v| v.as_f64()), Some(2.0));
        let server = doc.get("server").unwrap();
        assert_eq!(server.get("rows_applied").and_then(|v| v.as_f64()), Some(42.0));
    }
}
