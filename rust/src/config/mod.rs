//! TOML experiment configuration and system builders — the launcher's
//! config layer (`mltuner tune --config experiment.toml`).
//!
//! Parsed with the in-tree TOML-subset parser (`util::toml`); see the
//! `configs/` directory for examples.

use std::path::Path;

use anyhow::{bail, Result};

use crate::apps::dnn::{DnnConfig, DnnSystem};
use crate::apps::mf::{MfConfig, MfSystem};
use crate::apps::sim::{SimProfile, SimSystem};
use crate::comm::socket::{Framing, parse_server_list};
use crate::data::DriftSchedule;
use crate::comm::{BranchId, BranchType, Clock};
use crate::optim::OptimizerKind;
use crate::ps::PsHandle;
use crate::ps::checkpoint::StoreCheckpoint;
use crate::ps::remote::RemoteParamServer;
use crate::runtime::Runtime;
use crate::searcher::SearcherKind;
use crate::stats::{Snapshot, TrialEvent};
use crate::training::{Progress, TrainingSystem};
use crate::tunable::{TunableSetting, TunableSpace};
use crate::tuner::session::CheckpointPolicy;
use crate::tuner::{ConvergenceCriterion, TunerConfig};
use crate::util::toml::TomlDoc;

/// Top-level experiment config.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// "sim" | "dnn" | "mf"
    pub app: String,
    /// SimApp profile: inception_bn | googlenet | alexnet_cifar10 |
    /// rnn_ucf101 | mf_netflix
    pub profile: Option<String>,
    pub workers: usize,
    pub seed: u64,
    pub searcher: String,
    pub optimizer: String,
    pub plateau_epochs: u32,
    pub max_epochs: u64,
    pub retune: bool,
    /// Loss-threshold convergence (MF); accuracy plateau otherwise.
    pub loss_threshold: Option<f64>,
    /// Parameter-store deployment: `None`/`"local"` for the in-process
    /// server, or a shard-server list `remote://addr1,addr2,...` —
    /// every address one `mltuner serve` process (see `ps/remote`).
    pub ps: Option<String>,
    /// Socket framing for the remote store: "line" | "length" | "binary".
    pub ps_framing: String,
    /// Named tuning session to register on the remote store: scopes
    /// this run's branches to their own namespace so several tunes
    /// can share one shard-server cluster.  `None` uses the shared
    /// default namespace (the single-tenant behavior).
    /// CLI: `--session-name`.
    pub session_name: Option<String>,
    /// Durable session checkpoints: root directory for checkpoint
    /// steps (`None` = checkpointing off).  CLI: `--checkpoint-dir`.
    pub checkpoint_dir: Option<String>,
    /// Clocks between checkpoints.  CLI: `--checkpoint-every`.
    pub checkpoint_every: u64,
    /// Resume from the latest checkpoint under `checkpoint_dir`
    /// instead of starting fresh.  CLI: `--resume`.
    pub resume: bool,
    /// Data drift injected by the training system: "none" | "step" |
    /// "ramp" (non-stationary workload harness).  CLI: `--drift`.
    pub drift: String,
    /// Clock at which the drift begins.  CLI: `--drift-at`.
    pub drift_at: u64,
    /// Clocks over which a "ramp" drift reaches full shift.
    pub drift_ramp: u64,
    /// Seed for the drift transform (independent of `seed`).
    pub drift_seed: u64,
    /// Slope watchdog: fire a re-tune episode when training progress
    /// degrades mid-run (only effective while `retune` is on).
    pub watchdog: bool,
    /// Degraded means slope below this fraction of the trailing best.
    pub watchdog_fraction: f64,
    /// Consecutive degraded windows before the watchdog fires.
    pub watchdog_windows: u32,
    pub dnn: DnnSection,
    pub mf: MfSection,
}

#[derive(Debug, Clone)]
pub struct DnnSection {
    pub model: String,
    pub variant: String,
    pub artifacts_dir: String,
    pub train_examples: usize,
    pub val_examples: usize,
    pub spread: f64,
}

impl Default for DnnSection {
    fn default() -> Self {
        DnnSection {
            model: "alexnet_proxy".into(),
            variant: "xla".into(),
            artifacts_dir: "artifacts".into(),
            train_examples: 4096,
            val_examples: 512,
            spread: 0.6,
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct MfSection {
    pub users: Option<usize>,
    pub items: Option<usize>,
    pub rank: Option<usize>,
    pub n_ratings: Option<usize>,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            app: "sim".into(),
            profile: None,
            workers: 8,
            seed: 0,
            searcher: "hyperopt".into(),
            optimizer: "sgd".into(),
            plateau_epochs: 5,
            max_epochs: 200,
            retune: true,
            loss_threshold: None,
            ps: None,
            ps_framing: "line".into(),
            session_name: None,
            checkpoint_dir: None,
            checkpoint_every: 50,
            resume: false,
            drift: "none".into(),
            drift_at: 0,
            drift_ramp: 64,
            drift_seed: 0,
            watchdog: true,
            watchdog_fraction: 0.25,
            watchdog_windows: 3,
            dnn: DnnSection::default(),
            mf: MfSection::default(),
        }
    }
}

impl ExperimentConfig {
    pub fn from_toml(text: &str) -> Result<Self> {
        let doc = TomlDoc::parse(text)?;
        let mut cfg = ExperimentConfig::default();
        if let Some(v) = doc.get_str("app") {
            cfg.app = v.to_string();
        }
        if let Some(v) = doc.get_str("profile") {
            cfg.profile = Some(v.to_string());
        }
        if let Some(v) = doc.get_i64("workers") {
            cfg.workers = v as usize;
        }
        if let Some(v) = doc.get_i64("seed") {
            cfg.seed = v as u64;
        }
        if let Some(v) = doc.get_str("searcher") {
            cfg.searcher = v.to_string();
        }
        if let Some(v) = doc.get_str("optimizer") {
            cfg.optimizer = v.to_string();
        }
        if let Some(v) = doc.get_i64("plateau_epochs") {
            cfg.plateau_epochs = v as u32;
        }
        if let Some(v) = doc.get_i64("max_epochs") {
            cfg.max_epochs = v as u64;
        }
        if let Some(v) = doc.get_bool("retune") {
            cfg.retune = v;
        }
        if let Some(v) = doc.get_f64("loss_threshold") {
            cfg.loss_threshold = Some(v);
        }
        if let Some(v) = doc.get_str("ps") {
            cfg.ps = Some(v.to_string());
        }
        if let Some(v) = doc.get_str("ps_framing") {
            cfg.ps_framing = v.to_string();
        }
        if let Some(v) = doc.get_str("session_name") {
            cfg.session_name = Some(v.to_string());
        }
        if let Some(v) = doc.get_str("checkpoint_dir") {
            cfg.checkpoint_dir = Some(v.to_string());
        }
        if let Some(v) = doc.get_i64("checkpoint_every") {
            cfg.checkpoint_every = v.max(1) as u64;
        }
        if let Some(v) = doc.get_bool("resume") {
            cfg.resume = v;
        }
        if let Some(v) = doc.get_str("drift") {
            cfg.drift = v.to_string();
        }
        if let Some(v) = doc.get_i64("drift_at") {
            cfg.drift_at = v.max(0) as u64;
        }
        if let Some(v) = doc.get_i64("drift_ramp") {
            cfg.drift_ramp = v.max(1) as u64;
        }
        if let Some(v) = doc.get_i64("drift_seed") {
            cfg.drift_seed = v as u64;
        }
        if let Some(v) = doc.get_bool("watchdog") {
            cfg.watchdog = v;
        }
        if let Some(v) = doc.get_f64("watchdog_fraction") {
            cfg.watchdog_fraction = v;
        }
        if let Some(v) = doc.get_i64("watchdog_windows") {
            cfg.watchdog_windows = v.max(1) as u32;
        }
        if let Some(v) = doc.get_str("dnn.model") {
            cfg.dnn.model = v.to_string();
        }
        if let Some(v) = doc.get_str("dnn.variant") {
            cfg.dnn.variant = v.to_string();
        }
        if let Some(v) = doc.get_str("dnn.artifacts_dir") {
            cfg.dnn.artifacts_dir = v.to_string();
        }
        if let Some(v) = doc.get_i64("dnn.train_examples") {
            cfg.dnn.train_examples = v as usize;
        }
        if let Some(v) = doc.get_i64("dnn.val_examples") {
            cfg.dnn.val_examples = v as usize;
        }
        if let Some(v) = doc.get_f64("dnn.spread") {
            cfg.dnn.spread = v;
        }
        if let Some(v) = doc.get_i64("mf.users") {
            cfg.mf.users = Some(v as usize);
        }
        if let Some(v) = doc.get_i64("mf.items") {
            cfg.mf.items = Some(v as usize);
        }
        if let Some(v) = doc.get_i64("mf.rank") {
            cfg.mf.rank = Some(v as usize);
        }
        if let Some(v) = doc.get_i64("mf.n_ratings") {
            cfg.mf.n_ratings = Some(v as usize);
        }
        Ok(cfg)
    }

    pub fn optimizer_kind(&self) -> Result<OptimizerKind> {
        OptimizerKind::parse(&self.optimizer)
            .ok_or_else(|| anyhow::anyhow!("unknown optimizer {}", self.optimizer))
    }

    pub fn searcher_kind(&self) -> Result<SearcherKind> {
        SearcherKind::parse(&self.searcher)
            .ok_or_else(|| anyhow::anyhow!("unknown searcher {}", self.searcher))
    }

    /// Connect the remote parameter store when this config names one
    /// (`ps = "remote://addr1,addr2"`); `None` means in-process.  The
    /// servers must have been started with this config's optimizer —
    /// the rule is applied server-side, so a silent mismatch would
    /// train a different experiment than the one configured.
    fn remote_store(&self) -> Result<Option<PsHandle>> {
        let Some(url) = self.ps.as_deref() else {
            return Ok(None);
        };
        if url == "local" {
            return Ok(None);
        }
        let specs = parse_server_list(url)?;
        let framing = Framing::parse(&self.ps_framing)?;
        let remote =
            RemoteParamServer::connect_session(&specs, framing, self.session_name.as_deref())?;
        let expected = self.optimizer_kind()?;
        if remote.optimizer_kind() != expected {
            bail!(
                "shard servers run optimizer {} but the config says {}; \
                 restart `mltuner serve` with --optimizer {}",
                remote.optimizer_kind().name(),
                expected.name(),
                expected.name()
            );
        }
        Ok(Some(PsHandle::Remote(remote)))
    }

    /// The drift schedule described by this config (`DriftKind::None`
    /// unless the config opts in).
    pub fn drift_schedule(&self) -> Result<DriftSchedule> {
        DriftSchedule::parse(&self.drift, self.drift_at, self.drift_ramp, self.drift_seed)
    }

    /// Build the training system described by this config.
    pub fn build_system(&self) -> Result<(AnySystem, TunableSpace)> {
        let drift = self.drift_schedule()?;
        match self.app.as_str() {
            "sim" => {
                if self.ps.is_some() {
                    bail!("the sim app has no parameter server; drop the `ps` setting");
                }
                let name = self.profile.as_deref().unwrap_or("alexnet_cifar10");
                let profile = SimProfile::by_name(name)
                    .ok_or_else(|| anyhow::anyhow!("unknown profile {name}"))?;
                let sys = SimSystem::new(profile, self.workers as u32, self.seed)
                    .with_optimizer(self.optimizer_kind()?)
                    .with_drift(drift);
                let space = sys.space.clone();
                Ok((AnySystem::Sim(sys), space))
            }
            "dnn" => {
                let d = &self.dnn;
                let runtime = Runtime::load(&d.artifacts_dir)?;
                let cfg = DnnConfig {
                    model: d.model.clone(),
                    variant: d.variant.clone(),
                    num_workers: self.workers,
                    seed: self.seed,
                    train_examples: d.train_examples,
                    val_examples: d.val_examples,
                    spread: d.spread,
                };
                let sys = match self.remote_store()? {
                    Some(store) => DnnSystem::with_store(cfg, runtime, store)?,
                    None => DnnSystem::new(cfg, runtime, self.optimizer_kind()?)?,
                };
                let sys = sys.with_drift(drift);
                let space = sys.space().clone();
                Ok((AnySystem::Dnn(Box::new(sys)), space))
            }
            "mf" => {
                let m = &self.mf;
                let mut cfg = MfConfig {
                    num_workers: self.workers,
                    seed: self.seed,
                    optimizer: self.optimizer_kind()?,
                    ..Default::default()
                };
                if let Some(u) = m.users {
                    cfg.users = u;
                }
                if let Some(i) = m.items {
                    cfg.items = i;
                }
                if let Some(r) = m.rank {
                    cfg.rank = r;
                }
                if let Some(n) = m.n_ratings {
                    cfg.n_ratings = n;
                }
                let sys = match self.remote_store()? {
                    Some(store) => MfSystem::with_store(cfg, store)?,
                    None => MfSystem::new(cfg),
                };
                let sys = sys.with_drift(drift);
                let space = sys.space().clone();
                Ok((AnySystem::Mf(Box::new(sys)), space))
            }
            other => bail!("unknown app {other}"),
        }
    }

    /// Build the tuner config for `space`.
    pub fn tuner_config(&self, space: TunableSpace) -> Result<TunerConfig> {
        let mut cfg = TunerConfig::new(space);
        cfg.searcher = self.searcher_kind()?;
        cfg.seed = self.seed;
        cfg.max_epochs = self.max_epochs;
        cfg.retune = self.retune;
        cfg.convergence = match self.loss_threshold {
            Some(value) => ConvergenceCriterion::LossThreshold { value },
            None => ConvergenceCriterion::AccuracyPlateau {
                epochs: self.plateau_epochs,
            },
        };
        if let Some(dir) = &self.checkpoint_dir {
            cfg.checkpoint = Some(CheckpointPolicy {
                dir: dir.into(),
                every_clocks: self.checkpoint_every.max(1),
            });
        }
        cfg.resume = self.resume;
        cfg.watchdog.enabled = self.watchdog;
        cfg.watchdog.fraction = self.watchdog_fraction;
        cfg.watchdog.windows = self.watchdog_windows.max(1);
        Ok(cfg)
    }
}

/// Enum dispatch over the three training systems (keeps `MLtuner<S>`
/// monomorphic without trait objects in the hot path).
pub enum AnySystem {
    Sim(SimSystem),
    Dnn(Box<DnnSystem>),
    Mf(Box<MfSystem>),
}

impl TrainingSystem for AnySystem {
    fn fork_branch(
        &mut self,
        clock: Clock,
        branch_id: BranchId,
        parent: Option<BranchId>,
        tunable: &TunableSetting,
        branch_type: BranchType,
    ) -> Result<()> {
        match self {
            AnySystem::Sim(s) => s.fork_branch(clock, branch_id, parent, tunable, branch_type),
            AnySystem::Dnn(s) => s.fork_branch(clock, branch_id, parent, tunable, branch_type),
            AnySystem::Mf(s) => s.fork_branch(clock, branch_id, parent, tunable, branch_type),
        }
    }

    fn free_branch(&mut self, clock: Clock, branch_id: BranchId) -> Result<()> {
        match self {
            AnySystem::Sim(s) => s.free_branch(clock, branch_id),
            AnySystem::Dnn(s) => s.free_branch(clock, branch_id),
            AnySystem::Mf(s) => s.free_branch(clock, branch_id),
        }
    }

    fn schedule_branch(&mut self, clock: Clock, branch_id: BranchId) -> Result<Progress> {
        match self {
            AnySystem::Sim(s) => s.schedule_branch(clock, branch_id),
            AnySystem::Dnn(s) => s.schedule_branch(clock, branch_id),
            AnySystem::Mf(s) => s.schedule_branch(clock, branch_id),
        }
    }

    fn clocks_per_epoch(&self, branch_id: BranchId) -> u64 {
        match self {
            AnySystem::Sim(s) => s.clocks_per_epoch(branch_id),
            AnySystem::Dnn(s) => s.clocks_per_epoch(branch_id),
            AnySystem::Mf(s) => s.clocks_per_epoch(branch_id),
        }
    }

    fn update_tunable(&mut self, branch_id: BranchId, tunable: &TunableSetting) -> Result<()> {
        match self {
            AnySystem::Sim(s) => s.update_tunable(branch_id, tunable),
            AnySystem::Dnn(s) => s.update_tunable(branch_id, tunable),
            AnySystem::Mf(s) => s.update_tunable(branch_id, tunable),
        }
    }

    fn system_name(&self) -> &'static str {
        match self {
            AnySystem::Sim(s) => s.system_name(),
            AnySystem::Dnn(s) => s.system_name(),
            AnySystem::Mf(s) => s.system_name(),
        }
    }

    fn stats(&self) -> Snapshot {
        match self {
            AnySystem::Sim(s) => s.stats(),
            AnySystem::Dnn(s) => s.stats(),
            AnySystem::Mf(s) => s.stats(),
        }
    }

    fn publish_trial(&self, event: TrialEvent) {
        match self {
            AnySystem::Sim(s) => s.publish_trial(event),
            AnySystem::Dnn(s) => s.publish_trial(event),
            AnySystem::Mf(s) => s.publish_trial(event),
        }
    }

    fn checkpoint_session(&self, dir: &Path) -> Result<Option<StoreCheckpoint>> {
        match self {
            AnySystem::Sim(s) => s.checkpoint_session(dir),
            AnySystem::Dnn(s) => s.checkpoint_session(dir),
            AnySystem::Mf(s) => s.checkpoint_session(dir),
        }
    }

    fn restore_session(&mut self, store: &StoreCheckpoint, dir: &Path) -> Result<bool> {
        match self {
            AnySystem::Sim(s) => s.restore_session(store, dir),
            AnySystem::Dnn(s) => s.restore_session(store, dir),
            AnySystem::Mf(s) => s.restore_session(store, dir),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn toml_roundtrip_minimal() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            app = "sim"
            profile = "alexnet_cifar10"
            seed = 7
        "#,
        )
        .unwrap();
        assert_eq!(cfg.app, "sim");
        assert_eq!(cfg.workers, 8);
        assert_eq!(cfg.searcher, "hyperopt");
        assert!(cfg.retune);
        let (sys, space) = cfg.build_system().unwrap();
        assert_eq!(sys.system_name(), "sim");
        assert_eq!(space.dim(), 4);
    }

    #[test]
    fn toml_mf_section() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            app = "mf"
            optimizer = "adarevision"
            loss_threshold = 100.0
            [mf]
            users = 50
            items = 40
            rank = 4
            n_ratings = 500
        "#,
        )
        .unwrap();
        let (sys, space) = cfg.build_system().unwrap();
        assert_eq!(sys.system_name(), "mf");
        assert_eq!(space.dim(), 1);
        let tc = cfg.tuner_config(space).unwrap();
        assert_eq!(
            tc.convergence,
            ConvergenceCriterion::LossThreshold { value: 100.0 }
        );
    }

    #[test]
    fn drift_and_watchdog_keys_parse_and_plumb_through() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            app = "sim"
            profile = "alexnet_cifar10"
            drift = "step"
            drift_at = 40
            drift_seed = 9
            watchdog = false
            watchdog_fraction = 0.4
            watchdog_windows = 5
        "#,
        )
        .unwrap();
        let sched = cfg.drift_schedule().unwrap();
        assert!(sched.is_active());
        assert_eq!(sched.at, 40);
        assert_eq!(sched.seed, 9);
        let (sys, space) = cfg.build_system().unwrap();
        assert_eq!(sys.system_name(), "sim");
        let tc = cfg.tuner_config(space).unwrap();
        assert!(!tc.watchdog.enabled);
        assert_eq!(tc.watchdog.fraction, 0.4);
        assert_eq!(tc.watchdog.windows, 5);
        // defaults: no drift, watchdog armed
        let plain = ExperimentConfig::from_toml(r#"app = "sim""#).unwrap();
        assert!(!plain.drift_schedule().unwrap().is_active());
        assert!(plain.watchdog);
        // bad drift kind rejected
        let mut bad = plain;
        bad.drift = "tsunami".into();
        assert!(bad.drift_schedule().is_err());
    }

    #[test]
    fn bad_app_rejected() {
        let cfg = ExperimentConfig::from_toml(r#"app = "nope""#).unwrap();
        assert!(cfg.build_system().is_err());
    }

    #[test]
    fn ps_field_parses_and_sim_rejects_it() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            app = "sim"
            ps = "remote://127.0.0.1:5001,127.0.0.1:5002"
            ps_framing = "length"
        "#,
        )
        .unwrap();
        assert_eq!(cfg.ps.as_deref(), Some("remote://127.0.0.1:5001,127.0.0.1:5002"));
        assert_eq!(cfg.ps_framing, "length");
        let err = cfg.build_system().unwrap_err();
        assert!(err.to_string().contains("no parameter server"), "{err}");
        // explicit "local" is the in-process server
        let cfg = ExperimentConfig::from_toml(r#"app = "mf""#).unwrap();
        let mut cfg = cfg;
        cfg.ps = Some("local".into());
        cfg.mf.users = Some(10);
        cfg.mf.items = Some(8);
        cfg.mf.rank = Some(2);
        cfg.mf.n_ratings = Some(50);
        assert!(cfg.build_system().is_ok());
    }

    #[test]
    fn build_system_connects_a_remote_mf_store() {
        use crate::comm::socket::Framing;
        use crate::ps::remote::{spawn_local_server, ShardRange};
        let kind = OptimizerKind::AdaRevision;
        let (a, ha, _) = spawn_local_server(ShardRange { begin: 0, end: 1 }, kind, Framing::Line)
            .unwrap();
        let (b, hb, _) = spawn_local_server(ShardRange { begin: 1, end: 2 }, kind, Framing::Line)
            .unwrap();
        let cfg = ExperimentConfig::from_toml(&format!(
            "app = \"mf\"\noptimizer = \"adarevision\"\nps = \"remote://{a},{b}\"\n\
             [mf]\nusers = 12\nitems = 10\nrank = 2\nn_ratings = 60\n"
        ))
        .unwrap();
        let (sys, space) = cfg.build_system().unwrap();
        assert_eq!(sys.system_name(), "mf");
        assert_eq!(space.dim(), 1);
        // root model rows crossed the wire during construction
        let AnySystem::Mf(sys) = sys else { panic!("wrong system") };
        use crate::ps::ParamStore;
        assert_eq!(sys.store().branch_row_count(0).unwrap(), 22);
        match sys.store() {
            PsHandle::Remote(remote) => remote.shutdown_all().unwrap(),
            PsHandle::Local(_) => panic!("expected a remote store"),
        }
        drop(sys);
        ha.join().unwrap().unwrap();
        hb.join().unwrap().unwrap();
    }

    /// Regression: two tunes attach to ONE shared cluster under
    /// different `session_name`s; the second attach's `with_store`
    /// stale-branch sweep used to free *all* live branches — now each
    /// session's census (and therefore its sweep) sees only its own
    /// namespace, so session one's in-flight trial branch survives.
    #[test]
    fn with_store_cleanup_is_session_scoped() {
        use crate::comm::socket::Framing;
        use crate::ps::remote::{spawn_local_server, ShardRange};
        use crate::ps::ParamStore;
        let kind = OptimizerKind::AdaRevision;
        let (a, ha, _) = spawn_local_server(ShardRange { begin: 0, end: 1 }, kind, Framing::Line)
            .unwrap();
        let (b, hb, _) = spawn_local_server(ShardRange { begin: 1, end: 2 }, kind, Framing::Line)
            .unwrap();
        let toml = |session: &str| {
            format!(
                "app = \"mf\"\noptimizer = \"adarevision\"\nps = \"remote://{a},{b}\"\n\
                 session_name = \"{session}\"\n\
                 [mf]\nusers = 12\nitems = 10\nrank = 2\nn_ratings = 60\n"
            )
        };
        let cfg_one = ExperimentConfig::from_toml(&toml("one")).unwrap();
        assert_eq!(cfg_one.session_name.as_deref(), Some("one"));
        let (sys_one, _) = cfg_one.build_system().unwrap();
        let AnySystem::Mf(sys_one) = sys_one else { panic!("wrong system") };
        // a tune in flight: session one holds a forked trial branch
        sys_one.store().fork_branch(1, 0).unwrap();
        assert_eq!(sys_one.store().branch_row_count(1).unwrap(), 22);
        let cfg_two = ExperimentConfig::from_toml(&toml("two")).unwrap();
        let (sys_two, _) = cfg_two.build_system().unwrap();
        let AnySystem::Mf(sys_two) = sys_two else { panic!("wrong system") };
        // session one's branch survived session two's attach sweep...
        assert_eq!(sys_one.store().branch_row_count(1).unwrap(), 22);
        // ...and session two sees only its own (branchless) namespace
        assert_eq!(sys_two.store().live_branches().unwrap(), vec![0]);
        match sys_one.store() {
            PsHandle::Remote(remote) => remote.shutdown_all().unwrap(),
            PsHandle::Local(_) => panic!("expected a remote store"),
        }
        drop(sys_one);
        drop(sys_two);
        ha.join().unwrap().unwrap();
        hb.join().unwrap().unwrap();
    }
}
