//! `mltuner_lint` — the house static-analysis pass (see
//! `docs/ARCHITECTURE.md`, "Enforced invariants").
//!
//! ```text
//! cargo run --release --bin mltuner_lint            # lint src/
//! cargo run --release --bin mltuner_lint -- path --rules float-ord,lock-order
//! ```
//!
//! Exits 0 when the tree is clean, 1 on violations, 2 on I/O or
//! usage errors — CI and `scripts/tier1.sh` gate on the exit code.

use std::path::PathBuf;
use std::process::ExitCode;

use mltuner::analysis;
use mltuner::util::cli::Args;

const USAGE: &str = "\
mltuner_lint — house static analysis for the mltuner crate

USAGE:
    mltuner_lint [src-root] [--rules <r1,r2,…>] [--help]

Rules: float-ord, wire-int-cast, panic-path, lock-order (default: all).
Suppress a finding with `// lint:allow(rule): reason` placed on, or
directly above, the offending line.";

fn main() -> ExitCode {
    let args = Args::from_env();
    if args.get_bool("help", false) {
        println!("{USAGE}");
        return ExitCode::SUCCESS;
    }
    let root = match args.positional.first() {
        Some(p) => PathBuf::from(p),
        None => default_src_root(),
    };
    let mut enabled: Vec<&'static str> = Vec::new();
    match args.get("rules") {
        None => enabled.extend(analysis::RULES),
        Some(list) => {
            for name in list.split(',') {
                let name = name.trim();
                match analysis::RULES.iter().find(|r| **r == name) {
                    Some(r) => enabled.push(r),
                    None => {
                        eprintln!("mltuner_lint: unknown rule `{name}`\n\n{USAGE}");
                        return ExitCode::from(2);
                    }
                }
            }
        }
    }
    let report = match analysis::run_dir(&root, &enabled) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("mltuner_lint: {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };
    for d in &report.diags {
        println!("{d}");
    }
    if report.diags.is_empty() {
        println!(
            "mltuner_lint: OK — {} files clean under {} ({})",
            report.files,
            root.display(),
            enabled.join(", ")
        );
        ExitCode::SUCCESS
    } else {
        eprintln!("mltuner_lint: {} violation(s)", report.diags.len());
        ExitCode::FAILURE
    }
}

/// Under `cargo run` the manifest dir locates `src/` regardless of the
/// invoking directory; fall back to a relative `src` otherwise.
fn default_src_root() -> PathBuf {
    match std::env::var("CARGO_MANIFEST_DIR") {
        Ok(dir) => PathBuf::from(dir).join("src"),
        Err(_) => PathBuf::from("src"),
    }
}
