//! `mltuner` — leader entrypoint and CLI.
//!
//! Subcommands:
//! * `tune`     — run MLtuner-managed training from a TOML config (or
//!                `--app`/`--profile` flags), print the report, dump CSV.
//! * `serve`    — run one parameter-server shard process (distributed
//!                deployments: the `tune` coordinator connects with
//!                `--ps remote://...`).
//! * `top`      — live dashboard over a running cluster's streaming
//!                stats channel (`--json --once` for scripted probes).
//! * `baseline` — run the Spearmint / Hyperband baseline tuners (§5.2).
//! * `train`    — train a fixed hard-coded tunable setting (no tuner).
//! * `info`     — show the artifact manifest and available profiles.
//!
//! Every framing flag (`--framing`, `--ps-framing`) takes the same
//! enum — `line | length | binary` — and rejects anything else with a
//! typed error at parse time ([`Framing::parse`]); there is no
//! fallback framing.
//!
//! Examples:
//! ```text
//! mltuner tune --app sim --profile inception_bn --seed 1 --csv run.csv
//! mltuner tune --config configs/dnn_quickstart.toml
//! mltuner serve --shards 0..2 --listen 127.0.0.1:5001 --optimizer adarevision
//! mltuner serve --shards 2..4 --listen 127.0.0.1:5002 --optimizer adarevision
//! mltuner tune --app mf --ps remote://127.0.0.1:5001,127.0.0.1:5002
//! mltuner top --ps remote://127.0.0.1:5001,127.0.0.1:5002
//! mltuner baseline --kind hyperband --profile alexnet_cifar10
//! mltuner train --profile googlenet --lr 0.03 --momentum 0.9
//! ```

use std::io::Write as _;

use anyhow::{bail, Result};

use mltuner::baselines::{CoupledAdaptiveDriver, HyperbandDriver, SpearmintDriver};
use mltuner::comm::socket::{parse_server_list, Framing, PsListener, SocketSpec};
use mltuner::config::ExperimentConfig;
use mltuner::optim::OptimizerKind;
use mltuner::ps::remote::{ServeOpts, ShardRange, ShardServer};
use mltuner::runtime::Runtime;
use mltuner::top::TopConfig;
use mltuner::tuner::MLtuner;
use mltuner::util::cli::Args;

const USAGE: &str = "\
mltuner — automatic machine learning tuning (paper reproduction)

USAGE: mltuner <tune|serve|top|baseline|train|info> [--flags]

tune:     --config <file.toml> | --app sim --profile <name>
          --seed N --searcher hyperopt|random|grid|spearmint --csv out.csv
          --ps remote://host:port,host:port --ps-framing line|length|binary
          --session-name NAME (own branch namespace on a shared cluster)
          --checkpoint-dir DIR --checkpoint-every N --resume
          --stats-json out.json (final stats snapshot, machine-readable)
          --drift none|step|ramp --drift-at CLOCK --drift-ramp CLOCKS
          --drift-seed N (non-stationary workload injection)
          --watchdog true|false --watchdog-fraction F --watchdog-windows N
          (slope watchdog: re-tune on mid-run progress degradation)
          (--crash-after-clocks N: fault injection for recovery tests)
serve:    --shards a..b --listen host:port|unix:/path
          --optimizer sgd|adam|adarevision|... --framing line|length|binary
          --max-sessions N --max-branches-per-session N
          --session-lease-ms N --session-rows-per-sec N (fairness share)
top:      --ps remote://host:port,host:port --framing line|length|binary
          --interval-ms N --json --once
baseline: --kind spearmint|hyperband|coupled --profile <name> --seed N
          --budget <virtual seconds> --csv out.csv
          --lr F (coupled: initial learning rate of the adaptive rule)
          --drift none|step|ramp --drift-at CLOCK --drift-seed N
train:    --profile <name> --lr F --momentum F --seed N --max-epochs N
info:     --artifacts-dir artifacts

Framing flags share one enum (line | length | binary); unknown values
are rejected, never defaulted.
";

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("");
    match cmd {
        "tune" => cmd_tune(&args),
        "serve" => cmd_serve(&args),
        "top" => cmd_top(&args),
        "baseline" => cmd_baseline(&args),
        "train" => cmd_train(&args),
        "info" => cmd_info(&args),
        _ => {
            eprint!("{USAGE}");
            if cmd.is_empty() {
                Ok(())
            } else {
                bail!("unknown subcommand {cmd}")
            }
        }
    }
}

/// One shard-server process: serve a global shard range until a
/// client sends Shutdown.  The resolved listen address (ephemeral
/// ports included) is printed on the first stdout line so orchestration
/// — and the multi-process CI harness — can parse it.
fn cmd_serve(args: &Args) -> Result<()> {
    let shards = ShardRange::parse(args.get_or("shards", "0..1"))?;
    let listen = SocketSpec::parse(args.get_or("listen", "127.0.0.1:0"))?;
    let optimizer = {
        let name = args.get_or("optimizer", "sgd");
        OptimizerKind::parse(name).ok_or_else(|| anyhow::anyhow!("unknown optimizer {name}"))?
    };
    let framing = Framing::parse(args.get_or("framing", "line"))?;
    // multi-tenancy knobs: session admission, lease, fairness share
    let defaults = ServeOpts::default();
    let max_sessions = args.get_u64("max-sessions", defaults.max_sessions as u64);
    let max_branches =
        args.get_u64("max-branches-per-session", defaults.max_branches_per_session as u64);
    let opts = ServeOpts {
        max_sessions: max_sessions as usize,
        max_branches_per_session: max_branches as usize,
        default_lease_ms: args.get_u64("session-lease-ms", defaults.default_lease_ms),
        session_rows_per_sec: args
            .get("session-rows-per-sec")
            .map(|v| v.parse::<u64>())
            .transpose()?,
    };
    let listener = PsListener::bind(&listen)?;
    let local = listener.local_spec()?;
    println!(
        "mltuner serve: listening on {local} shards {shards} optimizer {} framing {}",
        optimizer.name(),
        framing.name()
    );
    std::io::stdout().flush()?;
    ShardServer::with_opts(shards, optimizer, framing, opts).serve(listener)
}

/// Live observability dashboard: subscribe to every shard server's
/// streaming stats channel and render the merged cluster view
/// (`--json` for newline-delimited delta frames, `--once` for
/// scripted probes — the distributed CI leg drives exactly that).
fn cmd_top(args: &Args) -> Result<()> {
    let ps = args
        .get("ps")
        .ok_or_else(|| anyhow::anyhow!("top needs --ps remote://host:port,..."))?;
    let cfg = TopConfig {
        servers: parse_server_list(ps)?,
        framing: Framing::parse(args.get_or("framing", "line"))?,
        interval_ms: args.get_u64("interval-ms", 1000),
        json: args.get_bool("json", false),
        once: args.get_bool("once", false),
        max_ticks: None,
    };
    let stdout = std::io::stdout();
    let mut out = stdout.lock();
    mltuner::top::run(&cfg, &mut out)
}

fn cmd_tune(args: &Args) -> Result<()> {
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::from_toml(&std::fs::read_to_string(path)?)?,
        None => ExperimentConfig::from_toml(&format!(
            "app = \"{}\"\nprofile = \"{}\"\nseed = {}\nsearcher = \"{}\"\n",
            args.get_or("app", "sim"),
            args.get_or("profile", "alexnet_cifar10"),
            args.get_u64("seed", 0),
            args.get_or("searcher", "hyperopt"),
        ))?,
    };
    // deployment flags override the config file
    if let Some(ps) = args.get("ps") {
        cfg.ps = Some(ps.to_string());
    }
    if let Some(f) = args.get("ps-framing") {
        cfg.ps_framing = f.to_string();
    }
    if let Some(name) = args.get("session-name") {
        cfg.session_name = Some(name.to_string());
    }
    if let Some(dir) = args.get("checkpoint-dir") {
        cfg.checkpoint_dir = Some(dir.to_string());
    }
    if args.get("checkpoint-every").is_some() {
        cfg.checkpoint_every = args.get_u64("checkpoint-every", cfg.checkpoint_every).max(1);
    }
    if args.get_bool("resume", false) {
        cfg.resume = true;
    }
    apply_drift_flags(args, &mut cfg);
    if args.get("watchdog").is_some() {
        cfg.watchdog = args.get_bool("watchdog", cfg.watchdog);
    }
    if args.get("watchdog-fraction").is_some() {
        cfg.watchdog_fraction = args.get_f64("watchdog-fraction", cfg.watchdog_fraction);
    }
    if args.get("watchdog-windows").is_some() {
        cfg.watchdog_windows = args.get_u64("watchdog-windows", u64::from(cfg.watchdog_windows))
            as u32;
    }
    let (system, space) = cfg.build_system()?;
    let mut tuner_cfg = cfg.tuner_config(space.clone())?;
    if let Some(n) = args.get("crash-after-clocks") {
        tuner_cfg.crash_after_clocks = Some(n.parse()?);
    }
    let mut tuner = MLtuner::new(system, tuner_cfg);
    let report = tuner.run()?;
    println!("=== MLtuner report ===");
    println!("epochs:          {}", report.epochs);
    println!("converged:       {}", report.converged);
    println!("final accuracy:  {:.4}", report.final_accuracy);
    println!("final loss:      {:.4e}", report.final_loss);
    println!("total time:      {:.1}s", report.total_time);
    println!(
        "tuning overhead: {:.1}s ({:.1}%)",
        report.tuning_time,
        100.0 * report.tuning_time / report.total_time.max(1e-9)
    );
    println!("tunings:         {}", report.tunings.len());
    println!(
        "branching:       {} forks, peak {} live, {} COW buffer copies",
        report.stats.store.forks,
        report.stats.store.peak_branches,
        report.stats.store.cow_buffer_copies
    );
    println!(
        "server:          {} rows in {} update batches, {} rows batch-read \
         ({} read RPCs), {} shard-lock contentions",
        report.stats.server.batched_rows,
        report.stats.server.batch_calls,
        report.stats.server.reads_batched,
        report.stats.store.read_rpcs,
        report.stats.server.shard_lock_contentions
    );
    println!(
        "server wire:     {} B tx, {} B rx, {} json + {} binary frames",
        report.stats.wire.bytes_tx,
        report.stats.wire.bytes_rx,
        report.stats.wire.frames_json,
        report.stats.wire.frames_bin
    );
    for (i, t) in report.tunings.iter().enumerate() {
        println!(
            "  [{}] {} trials={} trial_time={:.1}s chosen={}",
            i,
            t.trigger.name(),
            t.trials,
            t.trial_time,
            t.chosen
                .as_ref()
                .map(|s| s.describe(&space))
                .unwrap_or_else(|| "(none)".into())
        );
    }
    if let Some(path) = args.get("csv") {
        let f = std::fs::File::create(path)?;
        report.recorder.write_csv(f)?;
        println!("wrote {path}");
    }
    if let Some(path) = args.get("stats-json") {
        std::fs::write(path, report.stats.to_json())?;
        println!("wrote {path}");
    }
    Ok(())
}

/// Shared `--drift*` flag overrides (tune and baseline both take them
/// so scenario scripts can pit the two under identical drift).
fn apply_drift_flags(args: &Args, cfg: &mut ExperimentConfig) {
    if let Some(kind) = args.get("drift") {
        cfg.drift = kind.to_string();
    }
    if args.get("drift-at").is_some() {
        cfg.drift_at = args.get_u64("drift-at", cfg.drift_at);
    }
    if args.get("drift-ramp").is_some() {
        cfg.drift_ramp = args.get_u64("drift-ramp", cfg.drift_ramp).max(1);
    }
    if args.get("drift-seed").is_some() {
        cfg.drift_seed = args.get_u64("drift-seed", cfg.drift_seed);
    }
}

fn cmd_baseline(args: &Args) -> Result<()> {
    let kind = args.get_or("kind", "hyperband");
    let seed = args.get_u64("seed", 0);
    let budget = args.get_f64("budget", 432_000.0);
    let mut cfg = ExperimentConfig::from_toml(&format!(
        "app = \"sim\"\nprofile = \"{}\"\nseed = {seed}\n",
        args.get_or("profile", "alexnet_cifar10"),
    ))?;
    apply_drift_flags(args, &mut cfg);
    let (system, space) = cfg.build_system()?;
    let report = match kind {
        "spearmint" => SpearmintDriver::new(system, space, seed).run(budget)?,
        "hyperband" => HyperbandDriver::new(system, space, seed).run(budget)?,
        "coupled" => {
            let lr0 = args.get_f64("lr", 0.01);
            CoupledAdaptiveDriver::new(system, space, lr0).run(budget)?
        }
        other => bail!("unknown baseline {other}"),
    };
    println!("=== {kind} report ===");
    println!("configs tried:  {}", report.configs.len());
    println!("best accuracy:  {:.4}", report.best_accuracy);
    println!("total time:     {:.1}s", report.total_time);
    if let Some(path) = args.get("csv") {
        let f = std::fs::File::create(path)?;
        report.recorder.write_csv(f)?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let lr = args.get_f64("lr", 0.01);
    let momentum = args.get_f64("momentum", 0.9);
    let cfg = ExperimentConfig::from_toml(&format!(
        "app = \"sim\"\nprofile = \"{}\"\nseed = {}\nmax_epochs = {}\nretune = false\n",
        args.get_or("profile", "alexnet_cifar10"),
        args.get_u64("seed", 0),
        args.get_u64("max-epochs", 60),
    ))?;
    let (system, space) = cfg.build_system()?;
    let mut tuner_cfg = cfg.tuner_config(space.clone())?;
    let mut u = vec![0.5; space.dim()];
    u[0] = space.specs[0].encode(lr);
    u[1] = space.specs[1].encode(momentum);
    tuner_cfg.initial_setting = Some(space.decode(&u));
    let mut tuner = MLtuner::new(system, tuner_cfg);
    let report = tuner.run()?;
    println!(
        "fixed setting lr={lr} m={momentum}: epochs={} acc={:.4} time={:.1}s",
        report.epochs, report.final_accuracy, report.total_time
    );
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    println!("SimApp profiles: inception_bn googlenet alexnet_cifar10 rnn_ucf101 mf_netflix");
    let dir = args.get_or("artifacts-dir", "artifacts");
    match Runtime::load(dir) {
        Err(e) => println!("artifacts: unavailable ({e})"),
        Ok(rt) => {
            let mut names: Vec<_> = rt.manifest.models.keys().collect();
            names.sort();
            for name in names {
                let m = &rt.manifest.models[name];
                println!(
                    "model {name}: {} params, dims {}->{:?}->{}",
                    m.num_params(),
                    m.input_dim,
                    m.hidden,
                    m.classes
                );
                for a in &m.artifacts {
                    println!(
                        "  {} bs={} variant={} ({})",
                        a.kind, a.batch_size, a.variant, a.file
                    );
                }
            }
        }
    }
    Ok(())
}
