//! MLtuner core (§3, §4): snapshot/branch-based trial-and-error tuning
//! of training tunables within a single execution.
//!
//! The tuning procedure (Fig. 2 of the paper):
//!
//! 1. Tag the current training state as the **parent branch**.
//! 2. Ask the tunable searcher for a setting; **fork** a trial branch
//!    from the parent and run it for the current *trial time*.
//! 3. Summarize its progress into a convergence speed; report the speed
//!    back to the searcher.
//! 4. The trial time itself is decided by doubling (Algorithm 1) until
//!    at least one setting shows *stable converging* progress.
//! 5. When the searcher's stopping condition fires (top-5 non-zero
//!    speeds within 10%), keep the best branch, free the rest, and
//!    continue training.
//! 6. **Re-tune** when the validation accuracy plateaus, with the
//!    per-setting trial time bounded by one epoch and the trial count
//!    bounded by the previous tuning's count (§4.4) — so a converged
//!    model terminates the search.
//!
//! Outside Algorithm-1 exploration at most three branches are live:
//! parent, current best, current trial (§4.6).
//!
//! With [`TunerConfig::checkpoint`] set, the session additionally
//! journals every message it sends and periodically persists a durable
//! checkpoint (journal + parameter-store segments, see [`session`]);
//! [`TunerConfig::resume`] picks the latest checkpoint back up after a
//! crash — mid-tuning-episode included — by restoring the store plane
//! and replaying the journal.

pub mod session;

use std::time::Instant;

use anyhow::{bail, Result};
use crate::comm::{BranchId, BranchType, TunerMsg};
use crate::metrics::RunRecorder;
use crate::searcher::{Proposal, Searcher, SearcherKind, StoppingCondition};
use crate::summarizer::{BranchLabel, ProgressPoint, ProgressSummarizer, SlopeWatchdog};
use crate::stats::{Snapshot, TrialEvent};
use crate::training::{MessageDriver, Progress, TrainingSystem};
use crate::tunable::{TunableSetting, TunableSpace};

use session::{CheckpointDir, CheckpointPolicy, SessionHeader};

/// When is the model converged?
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ConvergenceCriterion {
    /// Validation accuracy has not increased over the last N epochs
    /// (5 for ILSVRC12/RNN, 20 for Cifar10 in the paper).
    AccuracyPlateau { epochs: u32 },
    /// Training loss reached a fixed threshold (the MF protocol).
    LossThreshold { value: f64 },
}

/// What fired a tuning episode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RetuneTrigger {
    /// The initial tuning stage before training starts (Fig. 2).
    Initial,
    /// The §4.4 accuracy-plateau hook, one epoch before convergence.
    Plateau,
    /// The always-on progress-slope watchdog: training progress
    /// degraded mid-run (non-stationary data, load shift, ...).
    Watchdog,
}

impl RetuneTrigger {
    /// Human label for report lines (`mltuner tune` output).
    pub fn name(self) -> &'static str {
        match self {
            RetuneTrigger::Initial => "initial",
            RetuneTrigger::Plateau => "re-tune",
            RetuneTrigger::Watchdog => "watchdog re-tune",
        }
    }
}

/// Always-on progress-slope watchdog configuration (the re-tune
/// trigger that fires at *any* point during training, not just at the
/// plateau-before-convergence hook).  Gated by [`TunerConfig::retune`]
/// — `retune = false` disarms this watchdog too.
#[derive(Debug, Clone, Copy)]
pub struct WatchdogConfig {
    pub enabled: bool,
    /// Fire when the observed slope stays below this fraction of its
    /// trailing best...
    pub fraction: f64,
    /// ...for this many consecutive summarizer windows.
    pub windows: u32,
    /// Minimum progress points before the slope is trusted at all.
    pub min_points: usize,
}

impl Default for WatchdogConfig {
    fn default() -> Self {
        WatchdogConfig {
            enabled: true,
            fraction: 0.25,
            windows: 3,
            min_points: 8,
        }
    }
}

/// MLtuner configuration.  Everything has paper defaults; only the
/// tunable space is the user's job (§3.1).
#[derive(Debug, Clone)]
pub struct TunerConfig {
    pub space: TunableSpace,
    pub searcher: SearcherKind,
    pub stopping: StoppingCondition,
    pub convergence: ConvergenceCriterion,
    /// Re-tune on plateau (§4.4)?  Off for the MF app and §5.3 runs.
    /// Also gates the slope watchdog: `false` disarms all re-tuning.
    pub retune: bool,
    /// The always-on slope watchdog (see [`WatchdogConfig`]).
    pub watchdog: WatchdogConfig,
    /// Skip the initial tuning stage and start from this setting
    /// (the Fig. 10 robustness experiments).
    pub initial_setting: Option<TunableSetting>,
    pub seed: u64,
    /// Safety rails (never hit in sane runs).
    pub max_epochs: u64,
    pub max_trials_per_tuning: usize,
    pub max_trial_doublings: u32,
    /// Clocks used to estimate a branch's per-clock time (§4.5: "first
    /// schedule that branch to run for some small number of clocks").
    pub measure_clocks: u64,
    /// Durable checkpointing (off by default): where to write
    /// checkpoint steps and how many clocks between them.
    pub checkpoint: Option<CheckpointPolicy>,
    /// Resume from the latest checkpoint under `checkpoint.dir`
    /// instead of starting fresh (requires `checkpoint`).
    pub resume: bool,
    /// Fault injection for crash-recovery tests (`--crash-after-clocks`):
    /// abort the run with a typed error once this many clocks have
    /// executed live.  Never set in production runs.
    pub crash_after_clocks: Option<u64>,
}

impl TunerConfig {
    pub fn new(space: TunableSpace) -> Self {
        TunerConfig {
            space,
            searcher: SearcherKind::HyperOpt,
            stopping: StoppingCondition::default(),
            convergence: ConvergenceCriterion::AccuracyPlateau { epochs: 5 },
            retune: true,
            watchdog: WatchdogConfig::default(),
            initial_setting: None,
            seed: 0,
            max_epochs: 10_000,
            max_trials_per_tuning: 64,
            max_trial_doublings: 24,
            measure_clocks: 3,
            checkpoint: None,
            resume: false,
            crash_after_clocks: None,
        }
    }
}

/// One tuning / re-tuning episode's record (the shaded spans of Fig. 4).
#[derive(Debug, Clone)]
pub struct TuningRecord {
    pub started: f64,
    pub ended: f64,
    pub trials: usize,
    pub trial_time: f64,
    pub chosen: Option<TunableSetting>,
    pub best_speed: f64,
    pub trigger: RetuneTrigger,
}

/// Final report of a tuned training run.
#[derive(Debug, Clone)]
pub struct TunerReport {
    pub recorder: RunRecorder,
    pub tunings: Vec<TuningRecord>,
    pub final_accuracy: f64,
    pub final_loss: f64,
    pub total_time: f64,
    pub tuning_time: f64,
    pub epochs: u64,
    /// Total clocks scheduled (training + all tuning trials).
    pub clocks: u64,
    pub converged: bool,
    pub final_setting: TunableSetting,
    /// Final [`crate::stats::Snapshot`] probed from the training
    /// system: branch-snapshot efficiency (§4.6 — fork count, peak
    /// live branches, copy-on-write traffic) in the `store` plane,
    /// and — for sharded-server systems — how the engine absorbed the
    /// data-parallel update load (batched rows per batch call,
    /// shard-lock contention) in the `server` plane.  `mltuner tune`
    /// prints them after the branching line.
    pub stats: Snapshot,
}

/// A live trial branch during a tuning episode.
struct Trial {
    branch: BranchId,
    point: Vec<f64>,
    setting: TunableSetting,
    trace: Vec<ProgressPoint>,
    run_time: f64,
    /// Tuning episode this trial belongs to, for observability only.
    episode: u32,
    /// Ordinal of this trial within its episode, for observability only.
    id: u32,
}

/// The MLtuner coordinator, wrapping a training system.
pub struct MLtuner<S: TrainingSystem> {
    pub driver: MessageDriver<S>,
    pub cfg: TunerConfig,
    summarizer: ProgressSummarizer,
    clock: u64,
    next_branch: BranchId,
    /// Accumulated run time (virtual or wall seconds, system-defined).
    now: f64,
    tuning_time: f64,
    pub recorder: RunRecorder,
    tunings: Vec<TuningRecord>,
    /// Clock of the last committed checkpoint (0 = none yet).
    last_checkpoint_clock: u64,
    /// Wall-clock searcher decision times (f64 bit patterns) in the
    /// order Algorithm 1 consumed them — the one wall-clock input to
    /// tuner control flow.  Journaled with the session so a resumed
    /// coordinator replays the original values instead of
    /// re-measuring, which is what makes journal replay deterministic
    /// even for systems with very fast clocks (see [`session`]).
    decision_log: Vec<u64>,
    /// Next `decision_log` entry to consume; past the end, decisions
    /// are measured live and appended.
    decision_cursor: usize,
    /// The always-on slope watchdog (see [`WatchdogConfig`]).  Fire
    /// decisions go through [`MLtuner::decision_flag`], so a resumed
    /// session replays the original trigger points bit-exactly.
    watchdog: SlopeWatchdog,
}

impl<S: TrainingSystem> MLtuner<S> {
    pub fn new(system: S, cfg: TunerConfig) -> Self {
        let watchdog = SlopeWatchdog::new(
            cfg.watchdog.fraction,
            cfg.watchdog.windows,
            cfg.watchdog.min_points,
        );
        MLtuner {
            driver: MessageDriver::new(system),
            cfg,
            summarizer: ProgressSummarizer::default(),
            clock: 0,
            next_branch: 1,
            now: 0.0,
            tuning_time: 0.0,
            recorder: RunRecorder::new(),
            tunings: Vec::new(),
            last_checkpoint_clock: 0,
            decision_log: Vec::new(),
            decision_cursor: 0,
            watchdog,
        }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    // ----- branch plumbing (Table 1 messages, §4.5) -----

    fn fork(
        &mut self,
        parent: BranchId,
        setting: &TunableSetting,
        ty: BranchType,
    ) -> Result<BranchId> {
        let id = self.next_branch;
        self.next_branch += 1;
        self.driver.send(&TunerMsg::ForkBranch {
            clock: self.clock,
            branch_id: id,
            parent_branch_id: Some(parent),
            tunable: setting.clone(),
            branch_type: ty,
        })?;
        Ok(id)
    }

    fn free(&mut self, branch: BranchId) -> Result<()> {
        self.driver.send(&TunerMsg::FreeBranch {
            clock: self.clock,
            branch_id: branch,
        })?;
        Ok(())
    }

    fn schedule(&mut self, branch: BranchId) -> Result<Progress> {
        // A ScheduleBranch must come back with a progress report; a
        // driver (possibly fronting a remote training system) that
        // answers without one is violating the §4.5 protocol — that is
        // the peer's bug, surfaced as an error the caller can handle,
        // not a coordinator panic.
        let Some(p) = self.driver.send(&TunerMsg::ScheduleBranch {
            clock: self.clock,
            branch_id: branch,
        })?
        else {
            bail!(
                "protocol violation: ScheduleBranch(clock {}, branch {branch}) \
                 returned no progress report",
                self.clock
            );
        };
        self.clock += 1;
        self.now += p.time;
        if let Some(limit) = self.cfg.crash_after_clocks {
            if !self.driver.is_replaying() && self.clock >= limit {
                bail!("crash injection: clock limit {limit} reached");
            }
        }
        self.maybe_checkpoint()?;
        Ok(p)
    }

    /// The searcher decision time Algorithm 1 should use: the
    /// journaled value during resume replay (so the replayed control
    /// flow — how many clocks each trial runs — matches the original
    /// run exactly, whatever this machine's timing does), the measured
    /// one live (appended to the log for the next checkpoint).
    fn decision_time(&mut self, measured: f64) -> f64 {
        if self.decision_cursor < self.decision_log.len() {
            let v = f64::from_bits(self.decision_log[self.decision_cursor]);
            self.decision_cursor += 1;
            return v;
        }
        self.decision_log.push(measured.to_bits());
        self.decision_cursor = self.decision_log.len();
        measured
    }

    /// A journaled boolean decision, stored in the same log as
    /// [`MLtuner::decision_time`] (0/1 entries) — the watchdog's fire
    /// decisions ride the existing session format unchanged.  Consumed
    /// and appended in the same config-static order on record and
    /// replay (one flag per armed training clock), so a resumed run
    /// re-fires at exactly the original clocks even though the
    /// watchdog re-observes its inputs.
    fn decision_flag(&mut self, measured: bool) -> bool {
        if self.decision_cursor < self.decision_log.len() {
            let v = self.decision_log[self.decision_cursor] != 0;
            self.decision_cursor += 1;
            return v;
        }
        self.decision_log.push(u64::from(measured));
        self.decision_cursor = self.decision_log.len();
        measured
    }

    // ----- durable checkpoints (see [`session`]) -----

    /// Arm journal recording and, on resume, load the latest
    /// checkpoint: restore the store plane through the training system
    /// and put the driver into journal replay.  Called once at the top
    /// of [`MLtuner::run`].
    fn init_checkpointing(&mut self) -> Result<()> {
        let Some(policy) = self.cfg.checkpoint.clone() else {
            if self.cfg.resume {
                bail!("resume requires a checkpoint dir (set TunerConfig::checkpoint)");
            }
            return Ok(());
        };
        self.driver.enable_recording();
        if !self.cfg.resume {
            return Ok(());
        }
        let ckd = CheckpointDir::new(&policy.dir);
        let Some(step) = ckd.latest()? else {
            bail!("nothing to resume: no committed checkpoint under {}", policy.dir.display());
        };
        let loaded = session::load(&step)?;
        let restored = match &loaded.store {
            // durable store: rows come from the segment files; the
            // journal replay skips the system entirely
            Some(store) => {
                if !self.driver.system.restore_session(store, &step)? {
                    bail!(
                        "checkpoint at {} carries a parameter-store snapshot but this \
                         training system cannot restore one — is the config pointing at \
                         the same app that wrote the checkpoint?",
                        step.display()
                    );
                }
                true
            }
            // no durable store (e.g. the simulator): rebuild the
            // system by re-executing the journal against it
            None => false,
        };
        self.driver.load_journal(loaded.entries, !restored);
        self.decision_log = loaded.decisions;
        self.decision_cursor = 0;
        self.last_checkpoint_clock = loaded.header.clock;
        Ok(())
    }

    /// Checkpoint when enough clocks have passed since the last one.
    /// Skipped while the driver is replaying a loaded journal (those
    /// clocks were already checkpointed by the original run).
    fn maybe_checkpoint(&mut self) -> Result<()> {
        let Some(policy) = &self.cfg.checkpoint else {
            return Ok(());
        };
        if self.driver.is_replaying()
            || self.clock - self.last_checkpoint_clock < policy.every_clocks.max(1)
        {
            return Ok(());
        }
        self.save_checkpoint()
    }

    /// Write and commit one checkpoint step: store segments (via the
    /// training system), session journal, recorder, manifest, LATEST
    /// pointer.
    fn save_checkpoint(&mut self) -> Result<()> {
        let Some(policy) = self.cfg.checkpoint.clone() else {
            bail!("save_checkpoint called without a checkpoint policy configured");
        };
        let ckd = CheckpointDir::new(&policy.dir);
        let step = ckd.begin_step(self.clock)?;
        let store = self.driver.system.checkpoint_session(&step)?;
        let header = SessionHeader {
            clock: self.clock,
            next_branch: self.next_branch,
            now: self.now,
            tuning_time: self.tuning_time,
        };
        session::save(
            &step,
            &header,
            self.driver.journal(),
            &self.decision_log,
            store.as_ref(),
            &self.recorder,
        )?;
        ckd.commit_step(self.clock)?;
        self.last_checkpoint_clock = self.clock;
        Ok(())
    }

    /// Run a trial branch until its total run time reaches `target`
    /// seconds (at least `measure_clocks` clocks the first time, to
    /// measure its per-clock time).  Stops early on numeric overflow.
    fn run_trial_until(&mut self, trial: &mut Trial, target: f64) -> Result<()> {
        let min_clocks = if trial.trace.is_empty() {
            self.cfg.measure_clocks.max(1)
        } else {
            1
        };
        let mut ran = 0u64;
        while trial.run_time < target || ran < min_clocks {
            let p = self.schedule(trial.branch)?;
            trial.run_time += p.time;
            trial.trace.push(ProgressPoint {
                t: trial.run_time,
                x: p.value,
            });
            // Side-channel observability: publish directly on the
            // system, NOT through `driver.send` — journaled messages
            // would corrupt checkpoint replay.  Best-effort by design.
            self.driver.system.publish_trial(TrialEvent {
                // the remote store re-stamps this with each server's
                // granted session id; 0 is the local/default case
                session: 0,
                episode: trial.episode,
                trial: trial.id,
                branch: trial.branch,
                clock: self.clock,
                progress: p.value,
                time: trial.run_time,
            });
            ran += 1;
            if !p.value.is_finite() {
                break; // diverged — no point burning more clocks
            }
            if ran >= min_clocks && trial.run_time >= target {
                break;
            }
            // guard: a zero-time system would spin forever
            if ran > 1_000_000 {
                bail!("trial branch reported zero-time clocks");
            }
        }
        Ok(())
    }

    /// One tuning episode (Fig. 2 + Algorithm 1).  Forks trials from
    /// `parent`; returns the winning branch (already trained for its
    /// trial time) or None if no converging setting was found within
    /// bounds.  `trial_time_cap`/`max_trials` implement §4.4's re-tune
    /// bounds; pass `f64::INFINITY`/large for initial tuning.
    fn tune_once(
        &mut self,
        parent: BranchId,
        trial_time_cap: f64,
        max_trials: usize,
        episode: usize,
        trigger: RetuneTrigger,
    ) -> Result<(Option<(BranchId, TunableSetting, f64)>, usize)> {
        let started = self.now;
        let label = match trigger {
            RetuneTrigger::Initial => "tuning_start",
            RetuneTrigger::Plateau => "retuning_start",
            RetuneTrigger::Watchdog => "watchdog_retuning_start",
        };
        self.recorder.event(started, label);
        let searcher_seed = self.cfg.seed.wrapping_add(episode as u64 * 7919);
        let mut searcher: Box<dyn Searcher> =
            self.cfg.searcher.build(self.cfg.space.dim(), searcher_seed);
        let mut trials: Vec<Trial> = Vec::new();
        let mut trial_time = 0.0f64;
        let mut exhausted = false;
        let mut doublings = 0u32;
        let mut trials_forked = 0usize;

        // ---- Algorithm 1: decide the trial time ----
        let decided: Option<f64> = loop {
            // Propose one new setting per round (its decision time
            // lower-bounds the trial time, §4.2).
            if !exhausted && trials_forked < max_trials {
                let t0 = Instant::now();
                match searcher.propose() {
                    Proposal::Exhausted => exhausted = true,
                    Proposal::Point(point) => {
                        let decision = self.decision_time(t0.elapsed().as_secs_f64());
                        trial_time = trial_time.max(decision);
                        let setting = self.cfg.space.decode(&point);
                        let branch =
                            self.fork(parent, &setting, BranchType::Training)?;
                        trials.push(Trial {
                            branch,
                            point,
                            setting,
                            trace: Vec::new(),
                            run_time: 0.0,
                            episode: episode as u32,
                            id: trials_forked as u32,
                        });
                        trials_forked += 1;
                    }
                }
            }
            if trials.is_empty() {
                break None;
            }
            let target = trial_time.min(trial_time_cap);
            for t in &mut trials {
                self.run_trial_until(t, target)?;
            }
            trial_time = trials.iter().map(|t| t.run_time).fold(trial_time, f64::max);

            // Summarize; drop diverged branches (speed 0, §4.1).
            let mut keep = Vec::new();
            let mut best_converging: Option<(usize, f64)> = None;
            for (i, t) in trials.iter().enumerate() {
                let s = self.summarizer.summarize(&t.trace);
                match s.label {
                    BranchLabel::Diverged => {
                        searcher.observe(t.point.clone(), 0.0);
                    }
                    BranchLabel::Converging => {
                        if best_converging.map_or(true, |(_, sp)| s.speed > sp) {
                            best_converging = Some((i, s.speed));
                        }
                        keep.push(i);
                    }
                    BranchLabel::Unstable => keep.push(i),
                }
            }
            // free diverged branches
            let mut kept_trials = Vec::new();
            for (i, t) in trials.drain(..).enumerate() {
                if keep.contains(&i) {
                    kept_trials.push(t);
                } else {
                    self.free(t.branch)?;
                }
            }
            // remap best index into kept vector
            let best_converging = best_converging.map(|(i, sp)| {
                // lint:allow(panic-path): the best index was pushed
                // into `keep` in the labeling loop above, so the
                // position lookup always succeeds
                let new_i = keep.iter().position(|&k| k == i).unwrap();
                (new_i, sp)
            });
            trials = kept_trials;

            if let Some((best_i, best_speed)) = best_converging {
                // Trial time decided: keep the best converging branch,
                // observe + free the others (Algorithm 1's last step).
                let mut best = None;
                for (i, t) in trials.drain(..).enumerate() {
                    let s = self.summarizer.summarize(&t.trace);
                    if i == best_i {
                        searcher.observe(t.point.clone(), best_speed);
                        best = Some(t);
                    } else {
                        searcher.observe(t.point.clone(), s.speed);
                        self.free(t.branch)?;
                    }
                }
                // lint:allow(panic-path): `best_i` indexes the drained
                // vector, so the loop above always sets `best`
                trials.push(best.unwrap());
                break Some(trial_time);
            }

            // No converging branch yet.  Double the trial time (clamped
            // to the §4.4 per-setting cap); at the cap, keep proposing
            // *new* settings each round until the trial-count bound —
            // only then conclude that no converging setting exists
            // (i.e., the model has converged).
            let at_cap = trial_time >= trial_time_cap
                && trials.iter().all(|t| t.run_time >= trial_time_cap);
            let budget_spent = trials_forked >= max_trials || exhausted;
            if (at_cap && budget_spent)
                || doublings > self.cfg.max_trial_doublings
            {
                break None;
            }
            if trial_time < trial_time_cap {
                trial_time = (trial_time * 2.0).min(trial_time_cap);
                doublings += 1;
            }
        };

        let Some(trial_time) = decided else {
            // No converging setting within bounds: free everything.
            for t in trials.drain(..) {
                self.free(t.branch)?;
            }
            let ended = self.now;
            self.tuning_time += ended - started;
            self.recorder.event(ended, "tuning_end");
            self.tunings.push(TuningRecord {
                started,
                ended,
                trials: trials_forked,
                trial_time: 0.0,
                chosen: None,
                best_speed: 0.0,
                trigger,
            });
            return Ok((None, trials_forked));
        };

        // ---- keep searching with the decided trial time ----
        // lint:allow(panic-path): Algorithm 1's decided path leaves
        // exactly the best trial in `trials` (see the decided loop)
        let mut best = trials.pop().expect("best branch from Algorithm 1");
        let mut best_speed = self.summarizer.summarize(&best.trace).speed;
        while trials_forked < max_trials
            && !self.cfg.stopping.should_stop(searcher.observations())
        {
            let point = match searcher.propose() {
                Proposal::Exhausted => break,
                Proposal::Point(p) => p,
            };
            let setting = self.cfg.space.decode(&point);
            let branch = self.fork(parent, &setting, BranchType::Training)?;
            let mut trial = Trial {
                branch,
                point,
                setting,
                trace: Vec::new(),
                run_time: 0.0,
                episode: episode as u32,
                id: trials_forked as u32,
            };
            trials_forked += 1;
            self.run_trial_until(&mut trial, trial_time.min(trial_time_cap))?;
            let s = self.summarizer.summarize(&trial.trace);
            let speed = match s.label {
                BranchLabel::Converging => s.speed,
                _ => 0.0, // unstable settings score 0 at decided trial time
            };
            searcher.observe(trial.point.clone(), speed);
            if speed > best_speed {
                self.free(best.branch)?;
                best = trial;
                best_speed = speed;
            } else {
                self.free(trial.branch)?;
            }
        }

        let ended = self.now;
        self.tuning_time += ended - started;
        self.recorder.event(ended, "tuning_end");
        self.tunings.push(TuningRecord {
            started,
            ended,
            trials: trials_forked,
            trial_time,
            chosen: Some(best.setting.clone()),
            best_speed,
            trigger,
        });
        Ok((Some((best.branch, best.setting, best_speed)), trials_forked))
    }

    /// Measure validation accuracy via a TESTING branch (§4.5).
    fn eval_accuracy(&mut self, train_branch: BranchId) -> Result<f64> {
        let setting = self.cfg.space.decode(&vec![0.5; self.cfg.space.dim()]);
        let b = self.fork(train_branch, &setting, BranchType::Testing)?;
        let p = self.schedule(b)?;
        self.free(b)?;
        Ok(p.value)
    }

    /// Run the full MLtuner-managed training (§5.1 protocol): initial
    /// tuning, epoch-wise training with validation, re-tuning on
    /// plateau, stop at convergence.
    pub fn run(&mut self) -> Result<TunerReport> {
        self.init_checkpointing()?;
        let mut episode = 0usize;
        // --- initial tuning (or hard-coded initial setting, Fig. 10) ---
        let (mut train_branch, mut setting, mut prev_trials) =
            match self.cfg.initial_setting.clone() {
                Some(s) => {
                    let b = self.fork(0, &s, BranchType::Training)?;
                    (b, s, self.cfg.max_trials_per_tuning)
                }
                None => {
                    let (best, trials) = self.tune_once(
                        0,
                        f64::INFINITY,
                        self.cfg.max_trials_per_tuning,
                        episode,
                        RetuneTrigger::Initial,
                    )?;
                    match best {
                        None => bail!("initial tuning found no converging setting"),
                        Some((b, s, _)) => (b, s, trials),
                    }
                }
            };
        episode += 1;

        // --- training loop ---
        let mut epoch = 0u64;
        let mut best_acc = f64::NEG_INFINITY;
        let mut last_acc = 0.0f64;
        let mut last_loss = f64::INFINITY;
        let mut epochs_since_improve = 0u32;
        let mut converged = false;
        #[allow(unused_assignments)]
        let mut epoch_time_est = 0.0f64;

        // Config-static arming: the watchdog observes (and journals
        // one flag per) every training clock iff re-tuning is on at
        // all — so the decision-log cadence is identical on record and
        // replay regardless of what the data does.
        let watchdog_armed = self.cfg.retune && self.cfg.watchdog.enabled;

        'training: while epoch < self.cfg.max_epochs {
            let clocks = self.driver.system.clocks_per_epoch(train_branch).max(1);
            let epoch_started = self.now;
            let mut loss_acc = 0.0f64;
            let mut loss_n = 0u64;
            let mut watchdog_fired = false;
            for _ in 0..clocks {
                let p = self.schedule(train_branch)?;
                self.recorder.record_loss(self.now, self.clock, p.value);
                if p.value.is_finite() {
                    loss_acc += p.value;
                    loss_n += 1;
                }
                if let ConvergenceCriterion::LossThreshold { value } =
                    self.cfg.convergence
                {
                    if p.value.is_finite() && p.value <= value {
                        last_loss = p.value;
                        converged = true;
                        epoch += 1; // count the partial epoch
                        break 'training;
                    }
                }
                if watchdog_armed {
                    let measured = self.watchdog.observe(self.now, p.value);
                    if self.decision_flag(measured) {
                        self.recorder.event(self.now, "watchdog_fire");
                        // Side-channel observability (never through
                        // `driver.send`): `mltuner top` shows the
                        // fired trigger live.
                        self.driver.system.publish_trial(TrialEvent {
                            session: 0,
                            episode: episode as u32,
                            trial: 0,
                            branch: train_branch,
                            clock: self.clock,
                            progress: p.value,
                            time: self.now,
                        });
                        watchdog_fired = true;
                        break;
                    }
                }
            }
            epoch += 1;
            epoch_time_est = self.now - epoch_started;
            last_loss = if loss_n > 0 {
                loss_acc / loss_n as f64
            } else {
                f64::INFINITY
            };

            if watchdog_fired {
                // §4.4 bounds apply to watchdog episodes too: trial
                // time ≤ the (possibly partial) epoch just measured,
                // trial count ≤ the previous tuning's.
                let cap = if epoch_time_est > 0.0 {
                    epoch_time_est
                } else {
                    f64::INFINITY
                };
                let (best, trials) = self.tune_once(
                    train_branch,
                    cap,
                    prev_trials.max(1),
                    episode,
                    RetuneTrigger::Watchdog,
                )?;
                episode += 1;
                match best {
                    Some((b, s, _)) => {
                        if train_branch != 0 {
                            self.free(train_branch)?;
                        }
                        train_branch = b;
                        setting = s;
                        prev_trials = trials;
                        epochs_since_improve = 0;
                        // fresh trailing best for the adopted setting
                        self.watchdog.reset();
                    }
                    None => {
                        // nothing converges better right now — keep
                        // training; the watchdog stays disarmed until
                        // progress recovers (hysteresis), so a
                        // fruitless episode is not retried every clock
                        self.watchdog.reset_window();
                    }
                }
                continue 'training;
            }

            match self.cfg.convergence {
                ConvergenceCriterion::LossThreshold { .. } => {
                    // handled inside the clock loop; keep training
                }
                ConvergenceCriterion::AccuracyPlateau { epochs } => {
                    let acc = self.eval_accuracy(train_branch)?;
                    last_acc = acc;
                    self.recorder.record_accuracy(self.now, epoch, acc);
                    if acc > best_acc + 1e-6 {
                        best_acc = acc;
                        epochs_since_improve = 0;
                    } else {
                        epochs_since_improve += 1;
                    }
                    // Re-tune one epoch before the convergence
                    // condition would fire (§5.1).
                    let trigger = epochs.saturating_sub(1).max(1);
                    if epochs_since_improve >= trigger {
                        if !self.cfg.retune {
                            converged = true;
                            break 'training;
                        }
                        // §4.4 bounds: per-setting trial ≤ 1 epoch,
                        // trials ≤ previous tuning's count.
                        let cap = if epoch_time_est > 0.0 {
                            epoch_time_est
                        } else {
                            f64::INFINITY
                        };
                        let (best, trials) = self.tune_once(
                            train_branch,
                            cap,
                            prev_trials.max(1),
                            episode,
                            RetuneTrigger::Plateau,
                        )?;
                        episode += 1;
                        match best {
                            Some((b, s, _)) => {
                                // continue on the re-tuned branch; the
                                // old parent is superseded.
                                if train_branch != 0 {
                                    self.free(train_branch)?;
                                }
                                train_branch = b;
                                setting = s;
                                prev_trials = trials;
                                epochs_since_improve = 0;
                                self.watchdog.reset();
                            }
                            None => {
                                // no converging setting exists anymore:
                                // the model has converged (§4.4).
                                converged = true;
                                break 'training;
                            }
                        }
                    }
                }
            }
        }

        let final_accuracy = if matches!(
            self.cfg.convergence,
            ConvergenceCriterion::AccuracyPlateau { .. }
        ) {
            best_acc.max(last_acc)
        } else {
            0.0
        };
        Ok(TunerReport {
            recorder: self.recorder.clone(),
            tunings: self.tunings.clone(),
            final_accuracy,
            final_loss: last_loss,
            total_time: self.now,
            tuning_time: self.tuning_time,
            epochs: epoch,
            clocks: self.clock,
            converged,
            final_setting: setting,
            stats: self.driver.system.stats(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::sim::{SimProfile, SimSystem};
    use std::collections::HashSet;

    fn tuner_for(profile: SimProfile, seed: u64) -> MLtuner<SimSystem> {
        let sys = SimSystem::new(profile, 8, seed);
        let mut cfg = TunerConfig::new(sys.space.clone());
        cfg.seed = seed;
        cfg.convergence = ConvergenceCriterion::AccuracyPlateau { epochs: 5 };
        cfg.max_epochs = 400;
        MLtuner::new(sys, cfg)
    }

    #[test]
    fn initial_tuning_finds_converging_setting() {
        let mut t = tuner_for(SimProfile::alexnet_cifar10(), 3);
        let (best, trials) =
            t.tune_once(0, f64::INFINITY, 64, 0, RetuneTrigger::Initial).unwrap();
        let (_, setting, speed) = best.expect("should find a setting");
        assert!(speed > 0.0);
        assert!(
            trials >= 5,
            "needs >=5 non-zero speeds to stop, got {trials}"
        );
        // chosen LR must be in a sane band (not 1e-5, not 1.0)
        let lr = setting.lr(&t.cfg.space);
        assert!(lr > 1e-4 && lr < 0.9, "lr={lr}");
    }

    /// Sim wrapper for the NaN regression tests: the FIRST trial
    /// branch the tuner forks (and any fork of it) reports NaN
    /// progress — the crash-divergence shape a real training system
    /// produces when a setting overflows.
    struct NanSpiking {
        inner: SimSystem,
        bad: HashSet<BranchId>,
        ever_bad: HashSet<BranchId>,
        spiked: bool,
        nan_reports: u64,
    }

    impl NanSpiking {
        fn new(inner: SimSystem) -> Self {
            NanSpiking {
                inner,
                bad: HashSet::new(),
                ever_bad: HashSet::new(),
                spiked: false,
                nan_reports: 0,
            }
        }
    }

    impl TrainingSystem for NanSpiking {
        fn fork_branch(
            &mut self,
            clock: u64,
            branch_id: BranchId,
            parent: Option<BranchId>,
            tunable: &TunableSetting,
            branch_type: BranchType,
        ) -> Result<()> {
            self.inner.fork_branch(clock, branch_id, parent, tunable, branch_type)?;
            let inherited = parent.is_some_and(|p| self.bad.contains(&p));
            if inherited || (!self.spiked && branch_type == BranchType::Training) {
                self.spiked = true;
                self.bad.insert(branch_id);
                self.ever_bad.insert(branch_id);
            }
            Ok(())
        }

        fn free_branch(&mut self, clock: u64, branch_id: BranchId) -> Result<()> {
            self.bad.remove(&branch_id);
            self.inner.free_branch(clock, branch_id)
        }

        fn schedule_branch(&mut self, clock: u64, branch_id: BranchId) -> Result<Progress> {
            let p = self.inner.schedule_branch(clock, branch_id)?;
            if self.bad.contains(&branch_id) {
                self.nan_reports += 1;
                return Ok(Progress {
                    value: f64::NAN,
                    time: p.time,
                });
            }
            Ok(p)
        }

        fn clocks_per_epoch(&self, branch_id: BranchId) -> u64 {
            self.inner.clocks_per_epoch(branch_id)
        }

        fn system_name(&self) -> &'static str {
            "sim-nan-spike"
        }
    }

    #[test]
    fn nan_reporting_trial_loses_without_panicking() {
        // Acceptance (per-PR): a tune session in which one trial
        // yields NaN progress/speed completes without panicking and
        // never selects that setting — the live crash sites were the
        // TPE split sort and the Bayesian EI argmax.
        let sys = SimSystem::new(SimProfile::alexnet_cifar10(), 8, 3);
        let mut cfg = TunerConfig::new(sys.space.clone());
        cfg.seed = 3;
        let mut t = MLtuner::new(NanSpiking::new(sys), cfg);
        let (best, trials) =
            t.tune_once(0, f64::INFINITY, 64, 0, RetuneTrigger::Initial).unwrap();
        let (branch, _setting, speed) = best.expect("good settings exist besides the NaN one");
        assert!(speed > 0.0);
        assert!(trials >= 2, "the NaN trial plus at least one real one");
        assert!(
            t.driver.system.nan_reports > 0,
            "the NaN-reporting trial never ran — nothing was regression-tested"
        );
        assert!(
            !t.driver.system.ever_bad.contains(&branch),
            "tuning selected the diverged NaN branch"
        );
    }

    #[test]
    fn full_run_converges_to_good_accuracy() {
        let mut t = tuner_for(SimProfile::alexnet_cifar10(), 5);
        let report = t.run().unwrap();
        assert!(report.converged);
        assert!(
            report.final_accuracy > 0.70,
            "accuracy {}",
            report.final_accuracy
        );
        assert!(!report.tunings.is_empty());
        assert_eq!(report.tunings[0].trigger, RetuneTrigger::Initial);
    }

    #[test]
    fn retuning_improves_over_initial_plateau() {
        let mut t = tuner_for(SimProfile::alexnet_cifar10(), 11);
        let report = t.run().unwrap();
        // at least one re-tuning should have happened (the LR-decay effect)
        assert!(
            report.tunings.len() >= 2,
            "expected re-tunings, got {:?}",
            report.tunings.len()
        );
        // accuracy after the last re-tuning ≥ accuracy before it
        let retune_t = report.tunings[1].started;
        let before = report
            .recorder
            .accuracies
            .iter()
            .filter(|&&(t, _, _)| t < retune_t)
            .map(|&(_, _, a)| a)
            .fold(0.0, f64::max);
        assert!(report.final_accuracy >= before - 0.02);
    }

    #[test]
    fn hardcoded_initial_setting_skips_initial_tuning() {
        let sys = SimSystem::new(SimProfile::alexnet_cifar10(), 8, 9);
        let space = sys.space.clone();
        let mut cfg = TunerConfig::new(space.clone());
        // suboptimal (10x too small) initial LR — convergent but slow,
        // as in the paper's random suboptimal picks
        let bad = space.decode(&[0.55, 0.2, 0.9, 0.0]);
        cfg.initial_setting = Some(bad);
        cfg.max_epochs = 120;
        cfg.seed = 9;
        let mut t = MLtuner::new(sys, cfg);
        let report = t.run().unwrap();
        // no tuning episode before training started ⇒ first tuning is a re-tune
        assert!(report.tunings.iter().all(|r| r.trigger != RetuneTrigger::Initial));
        // robustness (Fig. 10): re-tuning recovers decent accuracy
        assert!(
            report.final_accuracy > 0.60,
            "accuracy {}",
            report.final_accuracy
        );
    }

    #[test]
    fn loss_threshold_convergence_for_mf_profile() {
        let sys = SimSystem::new(SimProfile::mf_netflix(), 32, 1);
        let space = sys.space.clone();
        let mut cfg = TunerConfig::new(space);
        cfg.convergence = ConvergenceCriterion::LossThreshold {
            value: 8.32e6 * 32.0,
        };
        cfg.retune = false;
        cfg.max_epochs = 4000;
        cfg.seed = 1;
        let mut t = MLtuner::new(sys, cfg);
        let report = t.run().unwrap();
        assert!(report.converged, "never reached the loss threshold");
        assert!(report.final_loss <= 8.32e6 * 32.0 * 1.01);
    }

    #[test]
    fn retune_false_disarms_watchdog_under_forced_drift() {
        use crate::data::DriftSchedule;
        let sys = SimSystem::new(SimProfile::alexnet_cifar10(), 8, 4)
            .with_drift(DriftSchedule::step(30, 11));
        let space = sys.space.clone();
        let mut cfg = TunerConfig::new(space.clone());
        cfg.retune = false;
        cfg.seed = 4;
        cfg.max_epochs = 120;
        cfg.initial_setting = Some(space.decode(&[0.65, 0.2, 0.9, 0.0]));
        let mut t = MLtuner::new(sys, cfg);
        let report = t.run().unwrap();
        assert!(
            report.tunings.is_empty(),
            "retune=false must disarm the watchdog too, got {:?}",
            report.tunings
        );
    }

    #[test]
    fn watchdog_fires_on_mid_training_drift() {
        use crate::data::DriftSchedule;
        let sys = SimSystem::new(SimProfile::alexnet_cifar10(), 8, 7)
            .with_drift(DriftSchedule::step(40, 5));
        let space = sys.space.clone();
        let mut cfg = TunerConfig::new(space.clone());
        cfg.seed = 7;
        cfg.max_epochs = 200;
        cfg.initial_setting = Some(space.decode(&[0.65, 0.2, 0.9, 0.0]));
        let mut t = MLtuner::new(sys, cfg);
        let report = t.run().unwrap();
        assert!(
            report.tunings.iter().any(|r| r.trigger == RetuneTrigger::Watchdog),
            "drift must fire the slope watchdog, got {:?}",
            report.tunings.iter().map(|r| r.trigger).collect::<Vec<_>>()
        );
        assert!(
            report.recorder.events.iter().any(|e| e.label == "watchdog_fire"),
            "the fire must be journaled as a recorder event"
        );
    }

    #[test]
    fn branch_count_stays_bounded_outside_exploration() {
        let mut t = tuner_for(SimProfile::alexnet_cifar10(), 21);
        let report = t.run().unwrap();
        // §4.6: outside Algorithm-1 exploration only parent + best +
        // trial (+ root + testing transient) live.  During exploration
        // one branch per doubling round can accumulate; the doubling
        // budget bounds that.
        assert!(
            t.driver.system.peak_branches
                <= t.cfg.max_trials_per_tuning + 8,
            "peak branches {}",
            t.driver.system.peak_branches
        );
        // and at the end only root + train branch remain
        assert!(t.driver.system.live_branches() <= 2);
        // the report carries the same accounting
        assert_eq!(
            report.stats.store.live_branches,
            t.driver.system.live_branches()
        );
        assert_eq!(
            report.stats.store.peak_branches,
            t.driver.system.peak_branches
        );
        assert!(report.stats.store.forks > 0);
    }
}
