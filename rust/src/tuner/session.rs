//! Durable tune-session checkpoints: the coordinator half of the
//! checkpoint plane (the parameter-store half is
//! [`crate::ps::checkpoint`]).
//!
//! A tune session is **event-sourced**: the [`MessageDriver`] records
//! every Table-1 message and every progress reply (the *session
//! journal*), and replaying that journal through a fresh coordinator
//! deterministically rebuilds every piece of tuner state — searcher
//! observations, live trial traces, the best setting, the clock, and
//! every recorder event — even when the checkpoint landed in the
//! middle of a tuning episode.  Replay is exact because every input to
//! coordinator control flow is journaled: progress values and times as
//! f64 **bit patterns**, the searchers are seeded, and the one
//! remaining wall-clock input — the searcher decision times that
//! lower-bound Algorithm 1's trial time — is journaled too (the
//! `decisions` line), so a resumed coordinator replays the original
//! values instead of re-measuring them.
//!
//! What lands on disk per checkpoint step (`step-<clock>/`):
//!
//! * `session.mlt` — header (clock, branch counter, accumulated time as
//!   bit patterns) + one journal line per message, with a trailing
//!   FNV-1a 64 checksum line;
//! * per-branch segment files (when the training system has a durable
//!   store — written by [`TrainingSystem::checkpoint_session`], on
//!   shard servers for a distributed store);
//! * `recorder.csv` — the run recorder so far (inspection artifact,
//!   not read back on resume);
//! * `MANIFEST` — the commit record tying everything together: store
//!   metadata (optimizer, branches, segment checksums) and the session
//!   file's checksum, itself checksummed.
//!
//! Steps are crash-consistent: a step directory is fully written and
//! fsynced before the `LATEST` pointer file is atomically renamed onto
//! it, and only then is the previous step pruned — a kill at any point
//! leaves either the old or the new checkpoint installed, never a torn
//! one.  Resume ([`MLtuner::run`] with `TunerConfig::resume`) loads
//! `LATEST`, restores the store plane, and replays the journal; how a
//! restored system continues is decided by
//! [`TrainingSystem::restore_session`] — parameter-server apps restore
//! rows from segments and skip re-execution, the simulator re-executes
//! the (cheap, virtual-time) journal instead.
//!
//! [`MessageDriver`]: crate::training::MessageDriver
//! [`TrainingSystem::checkpoint_session`]: crate::training::TrainingSystem::checkpoint_session
//! [`TrainingSystem::restore_session`]: crate::training::TrainingSystem::restore_session
//! [`MLtuner::run`]: crate::tuner::MLtuner::run

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::comm::wire::{decode_tuner_msg, encode_tuner_msg, push_json_str};
use crate::comm::{BranchType, TunerMsg};
use crate::metrics::RunRecorder;
use crate::ps::checkpoint::{
    fnv1a, hex_u64, parse_hex_u64, write_atomic, BranchCkpt, SegmentMeta, StoreCheckpoint,
};
use crate::training::{JournalEntry, Progress};
use crate::util::json::Json;

const SESSION_MAGIC: &str = "mltuner-session v1";
const MANIFEST_MAGIC: &str = "mltuner-checkpoint v1";

/// Checkpointing policy of a tune session (`TunerConfig::checkpoint`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CheckpointPolicy {
    /// Root checkpoint directory (step subdirectories and the `LATEST`
    /// pointer live here).
    pub dir: PathBuf,
    /// Checkpoint at the first safe point after this many clocks since
    /// the previous checkpoint.
    pub every_clocks: u64,
}

/// Summary state written into the session header.  Redundant with the
/// journal (replay rebuilds all of it) — it anchors the fail-closed
/// cross-checks at load time and makes checkpoints inspectable.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SessionHeader {
    /// Clocks executed (= `ScheduleBranch` entries in the journal).
    pub clock: u64,
    /// Next branch id the coordinator would allocate.
    pub next_branch: u32,
    /// Accumulated run time, seconds (bit-exact via bit patterns).
    pub now: f64,
    /// Accumulated tuning time, seconds.
    pub tuning_time: f64,
}

/// Everything a checkpoint step holds, decoded and verified.
#[derive(Debug, Clone)]
pub struct SessionCheckpoint {
    pub header: SessionHeader,
    pub entries: Vec<JournalEntry>,
    /// Searcher decision times (f64 bit patterns) in consumption
    /// order — replayed instead of re-measured on resume.
    pub decisions: Vec<u64>,
    /// The parameter-store half; `None` for systems without a durable
    /// store (resume re-executes the journal).
    pub store: Option<StoreCheckpoint>,
}

// ---------------------------------------------------------------------------
// Step directories and the LATEST pointer
// ---------------------------------------------------------------------------

/// A root checkpoint directory: numbered step subdirectories plus the
/// atomically updated `LATEST` pointer.
#[derive(Debug, Clone)]
pub struct CheckpointDir {
    root: PathBuf,
}

impl CheckpointDir {
    pub fn new(root: impl Into<PathBuf>) -> Self {
        CheckpointDir { root: root.into() }
    }

    fn step_name(clock: u64) -> String {
        format!("step-{clock:012}")
    }

    /// Create (or wipe a half-written) step directory for `clock` and
    /// return its path.  Nothing points at it until
    /// [`CheckpointDir::commit_step`].
    pub fn begin_step(&self, clock: u64) -> Result<PathBuf> {
        let dir = self.root.join(Self::step_name(clock));
        if dir.exists() {
            fs::remove_dir_all(&dir)
                .with_context(|| format!("clearing stale step {}", dir.display()))?;
        }
        fs::create_dir_all(&dir).with_context(|| format!("creating {}", dir.display()))?;
        Ok(dir)
    }

    /// Atomically point `LATEST` at the step for `clock`, then prune
    /// older steps (best-effort).  Until the rename lands, the
    /// previous checkpoint stays the one a resume would load;
    /// `write_atomic` fsyncs the root directory after the rename, so
    /// the new pointer is on disk before any pruning unlinks can be.
    pub fn commit_step(&self, clock: u64) -> Result<()> {
        let name = Self::step_name(clock);
        // make the step's own directory entries durable before the
        // pointer that names them
        crate::ps::checkpoint::fsync_dir(&self.root.join(&name));
        write_atomic(&self.root.join("LATEST"), name.as_bytes())?;
        if let Ok(dirents) = fs::read_dir(&self.root) {
            for ent in dirents.flatten() {
                let fname = ent.file_name();
                let fname = fname.to_string_lossy();
                if fname.starts_with("step-") && fname != name {
                    let _ = fs::remove_dir_all(ent.path());
                }
            }
        }
        Ok(())
    }

    /// The committed checkpoint step, if any.
    pub fn latest(&self) -> Result<Option<PathBuf>> {
        let pointer = self.root.join("LATEST");
        let name = match fs::read_to_string(&pointer) {
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(e).context(format!("reading {}", pointer.display())),
            Ok(s) => s.trim().to_string(),
        };
        let dir = self.root.join(&name);
        if !dir.is_dir() {
            bail!(
                "checkpoint pointer names {name} but {} is not a directory",
                dir.display()
            );
        }
        Ok(Some(dir))
    }
}

// ---------------------------------------------------------------------------
// Codec helpers
// ---------------------------------------------------------------------------

fn hex_f64(v: f64) -> String {
    hex_u64(v.to_bits())
}

fn parse_hex_f64(s: &str) -> Result<f64> {
    Ok(f64::from_bits(parse_hex_u64(s)?))
}

fn str_field<'a>(v: &'a Json, k: &str) -> Result<&'a str> {
    v.get(k)
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("missing string field {k}"))
}

fn u64_field(v: &Json, k: &str) -> Result<u64> {
    let f = v
        .get(k)
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow!("missing numeric field {k}"))?;
    if !f.is_finite() || f.fract() != 0.0 || f < 0.0 {
        bail!("bad numeric field {k}: {f}");
    }
    Ok(f as u64)
}

fn branch_type_name(t: BranchType) -> &'static str {
    match t {
        BranchType::Training => "training",
        BranchType::Testing => "testing",
    }
}

fn parse_branch_type(s: &str) -> Result<BranchType> {
    match s {
        "training" => Ok(BranchType::Training),
        "testing" => Ok(BranchType::Testing),
        other => bail!("unknown branch type {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// Session file
// ---------------------------------------------------------------------------

fn encode_session(header: &SessionHeader, entries: &[JournalEntry], decisions: &[u64]) -> Vec<u8> {
    let mut out = String::new();
    out.push_str(SESSION_MAGIC);
    out.push('\n');
    out.push_str(&format!(
        "{{\"clock\":{},\"next_branch\":{},\"now\":\"{}\",\"tuning_time\":\"{}\",\"entries\":{}}}\n",
        header.clock,
        header.next_branch,
        hex_f64(header.now),
        hex_f64(header.tuning_time),
        entries.len()
    ));
    out.push_str("{\"decisions\":[");
    for (i, d) in decisions.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push('"');
        out.push_str(&hex_u64(*d));
        out.push('"');
    }
    out.push_str("]}\n");
    for e in entries {
        out.push_str("{\"m\":");
        push_json_str(&mut out, &encode_tuner_msg(&e.msg));
        match e.reply {
            None => out.push_str(",\"r\":null}"),
            Some(p) => {
                out.push_str(",\"r\":[\"");
                out.push_str(&hex_f64(p.value));
                out.push_str("\",\"");
                out.push_str(&hex_f64(p.time));
                out.push_str("\"]}");
            }
        }
        out.push('\n');
    }
    let digest = fnv1a(out.as_bytes());
    out.push_str(&format!("checksum {}\n", hex_u64(digest)));
    out.into_bytes()
}

type SessionBody = (SessionHeader, Vec<JournalEntry>, Vec<u64>);

fn decode_session(bytes: &[u8]) -> Result<SessionBody> {
    let text = std::str::from_utf8(bytes).context("session file is not UTF-8")?;
    let body_end = text
        .trim_end_matches('\n')
        .rfind('\n')
        .ok_or_else(|| anyhow!("session file truncated"))?;
    let (body, tail) = text.split_at(body_end + 1);
    let tail = tail.trim_end();
    let stored = tail
        .strip_prefix("checksum ")
        .ok_or_else(|| anyhow!("session file missing checksum line"))?;
    let stored = parse_hex_u64(stored)?;
    let computed = fnv1a(body.as_bytes());
    if stored != computed {
        bail!(
            "session file checksum mismatch: stored {}, computed {}",
            hex_u64(stored),
            hex_u64(computed)
        );
    }
    let mut lines = body.lines();
    let magic = lines.next().ok_or_else(|| anyhow!("empty session file"))?;
    if magic != SESSION_MAGIC {
        bail!("not a session file (magic {magic:?})");
    }
    let header_line = lines.next().ok_or_else(|| anyhow!("session file missing header"))?;
    let h = Json::parse(header_line).context("session header")?;
    let header = SessionHeader {
        clock: u64_field(&h, "clock")?,
        next_branch: u32::try_from(u64_field(&h, "next_branch")?)
            .map_err(|_| anyhow!("next_branch out of range"))?,
        now: parse_hex_f64(str_field(&h, "now")?)?,
        tuning_time: parse_hex_f64(str_field(&h, "tuning_time")?)?,
    };
    let expected_entries = u64_field(&h, "entries")? as usize;
    let decisions_line = lines.next().ok_or_else(|| anyhow!("session file missing decisions"))?;
    let d = Json::parse(decisions_line).context("session decisions")?;
    let decisions = d
        .get("decisions")
        .and_then(Json::as_array)
        .ok_or_else(|| anyhow!("bad decisions line"))?
        .iter()
        .map(|x| parse_hex_u64(x.as_str().ok_or_else(|| anyhow!("bad decision bits"))?))
        .collect::<Result<Vec<u64>>>()?;
    let mut entries = Vec::with_capacity(expected_entries.min(1 << 20));
    for line in lines {
        let v = Json::parse(line).context("session journal line")?;
        let msg = decode_tuner_msg(str_field(&v, "m")?)?;
        let reply = match v.get("r").ok_or_else(|| anyhow!("journal line missing reply"))? {
            Json::Null => None,
            Json::Array(a) if a.len() == 2 => Some(Progress {
                value: parse_hex_f64(
                    a[0].as_str().ok_or_else(|| anyhow!("bad reply value"))?,
                )?,
                time: parse_hex_f64(a[1].as_str().ok_or_else(|| anyhow!("bad reply time"))?)?,
            }),
            other => bail!("bad journal reply {other:?}"),
        };
        entries.push(JournalEntry { msg, reply });
    }
    if entries.len() != expected_entries {
        bail!(
            "session journal truncated: header promises {expected_entries} entries, \
             file holds {}",
            entries.len()
        );
    }
    let schedules = entries
        .iter()
        .filter(|e| matches!(e.msg, TunerMsg::ScheduleBranch { .. }))
        .count() as u64;
    if schedules != header.clock {
        bail!(
            "session journal inconsistent: header clock {} but {} schedules journaled",
            header.clock,
            schedules
        );
    }
    Ok((header, entries, decisions))
}

// ---------------------------------------------------------------------------
// Manifest
// ---------------------------------------------------------------------------

fn encode_manifest(
    header: &SessionHeader,
    store: Option<&StoreCheckpoint>,
    session: u64,
) -> String {
    let mut body = String::new();
    body.push_str(MANIFEST_MAGIC);
    body.push('\n');
    body.push_str(&format!(
        "{{\"version\":1,\"clock\":{},\"session\":{{\"file\":\"session.mlt\",\"checksum\":\"{}\"}}",
        header.clock,
        hex_u64(session)
    ));
    match store {
        None => body.push_str(",\"store\":null}"),
        Some(s) => {
            body.push_str(",\"store\":{\"optimizer\":");
            push_json_str(&mut body, &s.optimizer);
            body.push_str(",\"branches\":[");
            for (i, b) in s.branches.iter().enumerate() {
                if i > 0 {
                    body.push(',');
                }
                body.push_str(&format!(
                    "[{},\"{}\",{},[",
                    b.id,
                    branch_type_name(b.branch_type),
                    b.clocks_run
                ));
                for (j, v) in b.tunable.iter().enumerate() {
                    if j > 0 {
                        body.push(',');
                    }
                    body.push('"');
                    body.push_str(&hex_f64(*v));
                    body.push('"');
                }
                body.push_str("]]");
            }
            body.push_str("],\"segments\":[");
            for (i, m) in s.segments.iter().enumerate() {
                if i > 0 {
                    body.push(',');
                }
                body.push('[');
                push_json_str(&mut body, &m.file);
                body.push_str(&format!(
                    ",{},{},{},{},{},{},\"{}\"]",
                    m.branch,
                    m.range_begin,
                    m.range_end,
                    m.local_shard,
                    m.rows,
                    m.bytes,
                    hex_u64(m.checksum)
                ));
            }
            body.push_str("]}}");
        }
    }
    body.push('\n');
    let digest = fnv1a(body.as_bytes());
    body.push_str(&format!("checksum {}\n", hex_u64(digest)));
    body
}

fn decode_manifest(text: &str) -> Result<(u64, u64, Option<StoreCheckpoint>)> {
    let body_end = text
        .trim_end_matches('\n')
        .rfind('\n')
        .ok_or_else(|| anyhow!("manifest truncated"))?;
    let (body, tail) = text.split_at(body_end + 1);
    let stored = tail
        .trim_end()
        .strip_prefix("checksum ")
        .ok_or_else(|| anyhow!("manifest missing checksum line"))?;
    if parse_hex_u64(stored)? != fnv1a(body.as_bytes()) {
        bail!("manifest checksum mismatch — corrupted or truncated checkpoint");
    }
    let mut lines = body.lines();
    let magic = lines.next().ok_or_else(|| anyhow!("empty manifest"))?;
    if magic != MANIFEST_MAGIC {
        bail!("not a checkpoint manifest (magic {magic:?})");
    }
    let v = Json::parse(lines.next().ok_or_else(|| anyhow!("manifest missing body"))?)?;
    let clock = u64_field(&v, "clock")?;
    let session = v.get("session").ok_or_else(|| anyhow!("manifest missing session"))?;
    let session_checksum = parse_hex_u64(str_field(session, "checksum")?)?;
    let store = match v.get("store").ok_or_else(|| anyhow!("manifest missing store"))? {
        Json::Null => None,
        s => {
            let branches = s
                .get("branches")
                .and_then(Json::as_array)
                .ok_or_else(|| anyhow!("manifest missing branches"))?
                .iter()
                .map(|b| {
                    let b = b.as_array().ok_or_else(|| anyhow!("bad branch entry"))?;
                    if b.len() != 4 {
                        bail!("bad branch entry: len {}", b.len());
                    }
                    let id = b[0]
                        .as_f64()
                        .filter(|f| f.fract() == 0.0 && *f >= 0.0 && *f <= u32::MAX as f64)
                        .ok_or_else(|| anyhow!("bad branch id"))? as u32;
                    let branch_type = parse_branch_type(
                        b[1].as_str().ok_or_else(|| anyhow!("bad branch type"))?,
                    )?;
                    let clocks_run = b[2]
                        .as_f64()
                        .filter(|f| f.fract() == 0.0 && *f >= 0.0)
                        .ok_or_else(|| anyhow!("bad clocks_run"))? as u64;
                    let tunable = b[3]
                        .as_array()
                        .ok_or_else(|| anyhow!("bad tunable"))?
                        .iter()
                        .map(|t| {
                            parse_hex_f64(t.as_str().ok_or_else(|| anyhow!("bad tunable bits"))?)
                        })
                        .collect::<Result<Vec<f64>>>()?;
                    Ok(BranchCkpt {
                        id,
                        branch_type,
                        clocks_run,
                        tunable,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let segments = s
                .get("segments")
                .and_then(Json::as_array)
                .ok_or_else(|| anyhow!("manifest missing segments"))?
                .iter()
                .map(|m| {
                    let m = m.as_array().ok_or_else(|| anyhow!("bad segment entry"))?;
                    if m.len() != 8 {
                        bail!("bad segment entry: len {}", m.len());
                    }
                    let int = |j: &Json, what: &str| -> Result<u64> {
                        j.as_f64()
                            .filter(|f| f.fract() == 0.0 && *f >= 0.0)
                            .map(|f| f as u64)
                            .ok_or_else(|| anyhow!("bad segment {what}"))
                    };
                    Ok(SegmentMeta {
                        file: m[0]
                            .as_str()
                            .ok_or_else(|| anyhow!("bad segment file"))?
                            .to_string(),
                        branch: int(&m[1], "branch")? as u32,
                        range_begin: int(&m[2], "range begin")? as usize,
                        range_end: int(&m[3], "range end")? as usize,
                        local_shard: int(&m[4], "shard")? as usize,
                        rows: int(&m[5], "rows")?,
                        bytes: int(&m[6], "bytes")?,
                        checksum: parse_hex_u64(
                            m[7].as_str().ok_or_else(|| anyhow!("bad segment checksum"))?,
                        )?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let optimizer = str_field(s, "optimizer")?.to_string();
            Some(StoreCheckpoint {
                optimizer,
                branches,
                segments,
            })
        }
    };
    Ok((clock, session_checksum, store))
}

// ---------------------------------------------------------------------------
// Save / load
// ---------------------------------------------------------------------------

/// Write one complete checkpoint step into `step_dir`: session file,
/// recorder CSV and manifest.  Store segments (if any) must already
/// have been written into the same directory by
/// [`crate::training::TrainingSystem::checkpoint_session`].  The
/// caller commits the step afterwards via
/// [`CheckpointDir::commit_step`].
pub fn save(
    step_dir: &Path,
    header: &SessionHeader,
    entries: &[JournalEntry],
    decisions: &[u64],
    store: Option<&StoreCheckpoint>,
    recorder: &RunRecorder,
) -> Result<()> {
    fs::create_dir_all(step_dir)
        .with_context(|| format!("creating {}", step_dir.display()))?;
    let session_bytes = encode_session(header, entries, decisions);
    write_atomic(&step_dir.join("session.mlt"), &session_bytes)?;
    let mut csv = Vec::new();
    recorder.write_csv(&mut csv)?;
    write_atomic(&step_dir.join("recorder.csv"), &csv)?;
    let manifest = encode_manifest(header, store, fnv1a(&session_bytes));
    write_atomic(&step_dir.join("MANIFEST"), manifest.as_bytes())?;
    Ok(())
}

/// Load and fully verify one checkpoint step.  Fail-closed: manifest
/// and session checksums, entry counts, and the schedule/clock
/// cross-check must all hold, otherwise a typed error is returned and
/// nothing is restored.
pub fn load(step_dir: &Path) -> Result<SessionCheckpoint> {
    let manifest_path = step_dir.join("MANIFEST");
    let manifest = fs::read_to_string(&manifest_path)
        .with_context(|| format!("reading {}", manifest_path.display()))?;
    let (clock, session_checksum, store) = decode_manifest(&manifest)?;
    let session_path = step_dir.join("session.mlt");
    let session_bytes = fs::read(&session_path)
        .with_context(|| format!("reading {}", session_path.display()))?;
    if fnv1a(&session_bytes) != session_checksum {
        bail!("session file does not match its manifest checksum — corrupted checkpoint");
    }
    let (header, entries, decisions) = decode_session(&session_bytes)?;
    if header.clock != clock {
        bail!(
            "manifest clock {clock} disagrees with session header clock {}",
            header.clock
        );
    }
    Ok(SessionCheckpoint {
        header,
        entries,
        decisions,
        store,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tunable::TunableSetting;

    fn entries() -> Vec<JournalEntry> {
        vec![
            JournalEntry {
                msg: TunerMsg::ForkBranch {
                    clock: 0,
                    branch_id: 1,
                    parent_branch_id: Some(0),
                    tunable: TunableSetting::new(vec![1.25e-3, 0.9]),
                    branch_type: BranchType::Training,
                },
                reply: None,
            },
            JournalEntry {
                msg: TunerMsg::ScheduleBranch {
                    clock: 0,
                    branch_id: 1,
                },
                reply: Some(Progress {
                    value: f64::NAN,
                    time: 0.125,
                }),
            },
            JournalEntry {
                msg: TunerMsg::ScheduleBranch {
                    clock: 1,
                    branch_id: 1,
                },
                reply: Some(Progress {
                    value: -0.0,
                    time: f64::INFINITY,
                }),
            },
        ]
    }

    fn header() -> SessionHeader {
        SessionHeader {
            clock: 2,
            next_branch: 2,
            now: 0.1 + 0.2, // deliberately non-representable sum
            tuning_time: 0.0,
        }
    }

    fn store() -> StoreCheckpoint {
        StoreCheckpoint {
            optimizer: "adarevision".into(),
            branches: vec![BranchCkpt {
                id: 1,
                branch_type: BranchType::Training,
                clocks_run: 2,
                tunable: vec![f64::NAN, 0.9],
            }],
            segments: vec![SegmentMeta {
                file: "b1-r0-4-s0.seg".into(),
                branch: 1,
                range_begin: 0,
                range_end: 4,
                local_shard: 0,
                rows: 10,
                bytes: 321,
                checksum: u64::MAX,
            }],
        }
    }

    #[test]
    fn session_roundtrips_bit_exact_including_nan_and_inf() {
        let decisions = vec![f64::NAN.to_bits(), 1.5e-4f64.to_bits(), 0];
        let bytes = encode_session(&header(), &entries(), &decisions);
        let (h, e, d) = decode_session(&bytes).unwrap();
        assert_eq!(h.clock, 2);
        assert_eq!(h.now.to_bits(), (0.1f64 + 0.2).to_bits());
        assert_eq!(d, decisions, "decision log must round-trip bit-exactly");
        assert_eq!(e.len(), 3);
        assert_eq!(e[0].msg, entries()[0].msg);
        let p = e[1].reply.unwrap();
        assert!(p.value.is_nan(), "NaN progress must survive");
        assert_eq!(p.time.to_bits(), 0.125f64.to_bits());
        let p = e[2].reply.unwrap();
        assert_eq!(p.value.to_bits(), (-0.0f64).to_bits());
        assert_eq!(p.time, f64::INFINITY);
    }

    #[test]
    fn corrupted_session_fails_closed() {
        let bytes = encode_session(&header(), &entries(), &[7, u64::MAX]);
        // flip any byte → checksum mismatch (or header/entry error)
        for pos in [0usize, 10, bytes.len() / 2, bytes.len() - 2] {
            let mut bad = bytes.clone();
            bad[pos] ^= 0x20;
            assert!(decode_session(&bad).is_err(), "flip at {pos}");
        }
        // truncation at every line boundary
        let text = String::from_utf8(bytes.clone()).unwrap();
        for (pos, ch) in text.char_indices() {
            if ch == '\n' && pos + 1 < text.len() {
                assert!(decode_session(text[..pos + 1].as_bytes()).is_err(), "cut {pos}");
            }
        }
    }

    #[test]
    fn manifest_roundtrips_and_fails_closed() {
        let m = encode_manifest(&header(), Some(&store()), 0x1234);
        let (clock, session, st) = decode_manifest(&m).unwrap();
        assert_eq!(clock, 2);
        assert_eq!(session, 0x1234);
        let st = st.unwrap();
        assert_eq!(st.optimizer, "adarevision");
        assert_eq!(st.branches.len(), 1);
        assert!(st.branches[0].tunable[0].is_nan());
        assert_eq!(st.branches[0].tunable[1], 0.9);
        assert_eq!(st.segments, store().segments);

        // store-less manifests work too (simulator sessions)
        let m = encode_manifest(&header(), None, 7);
        let (_, _, st) = decode_manifest(&m).unwrap();
        assert!(st.is_none());

        // any byte flip fails closed
        let m = encode_manifest(&header(), Some(&store()), 0x1234);
        for pos in [0usize, 24, m.len() / 2, m.len() - 2] {
            let mut bad = m.clone().into_bytes();
            bad[pos] ^= 0x01;
            let bad = String::from_utf8_lossy(&bad).into_owned();
            assert!(decode_manifest(&bad).is_err(), "flip at {pos}");
        }
    }

    #[test]
    fn checkpoint_dir_commits_atomically_and_prunes() {
        let root = std::env::temp_dir().join(format!("mltuner-ckptdir-{}", std::process::id()));
        let _ = fs::remove_dir_all(&root);
        fs::create_dir_all(&root).unwrap();
        let ckd = CheckpointDir::new(&root);
        assert!(ckd.latest().unwrap().is_none());

        let s1 = ckd.begin_step(5).unwrap();
        save(&s1, &header(), &entries(), &[3], None, &RunRecorder::new()).unwrap();
        ckd.commit_step(5).unwrap();
        assert_eq!(ckd.latest().unwrap().unwrap(), s1);

        // a second step replaces the first and prunes it
        let s2 = ckd.begin_step(9).unwrap();
        save(&s2, &header(), &entries(), &[3, 9], None, &RunRecorder::new()).unwrap();
        ckd.commit_step(9).unwrap();
        assert_eq!(ckd.latest().unwrap().unwrap(), s2);
        assert!(!s1.exists(), "previous step must be pruned");

        // an UNcommitted step never becomes LATEST
        let _s3 = ckd.begin_step(11).unwrap();
        assert_eq!(ckd.latest().unwrap().unwrap(), s2);

        let loaded = load(&s2).unwrap();
        assert_eq!(loaded.header.clock, 2);
        assert_eq!(loaded.entries.len(), 3);
        assert_eq!(loaded.decisions, vec![3, 9]);
        assert!(loaded.store.is_none());
        let _ = fs::remove_dir_all(&root);
    }
}
