//! `mltuner top` — a live terminal dashboard over the observability
//! plane (the UX half of the streaming stats channel; see
//! [`crate::stats`] for the data model).
//!
//! The client connects to every shard server of a running cluster,
//! subscribes to the push stream with one `SubscribeStats` control
//! frame per server, and then only *reads*: servers push cumulative
//! [`crate::stats::ServerDelta`] frames from their event loop's
//! low-priority ticker, so a dashboard attached to a busy cluster
//! costs the data plane nothing beyond the frames themselves.  Frames
//! land in a [`StatsCollector`], whose monotonic merge turns the
//! latest per-server documents into one [`ClusterView`] per tick.
//!
//! Output modes:
//! * default — an ANSI dashboard redrawn per tick: cluster totals,
//!   per-shard-server drill-down, the RPC service-time histogram,
//!   live branches, per-trial tuner progress and the per-session
//!   tenant census (rows moved, fairness deferrals, live branches —
//!   the operator's view of who is using a shared cluster).
//!   Dependency-free: plain escape codes, no terminal library.
//! * `--json` — one newline-delimited delta frame per tick per
//!   server, exactly as received (each carries the schema version
//!   `"v"`), for scripts and the distributed CI leg.
//! * `--once` — exit after one frame from every server (composes
//!   with `--json` for machine probes; the ANSI mode skips the
//!   screen-clear so the single render plays well in a pipeline).

use std::io::Write;

use anyhow::{anyhow, bail, Result};

use crate::comm::socket::{Conn, Framing, SocketSpec};
use crate::comm::wire::{decode_ps_reply, encode_ps_request, PsReply, PsRequest};
use crate::ps::remote::StatsCollector;
use crate::stats::{bucket_floor_micros, ClusterView, HIST_BUCKETS};

/// Everything `mltuner top` needs (parsed from the CLI in `main`).
#[derive(Debug, Clone)]
pub struct TopConfig {
    /// One address per shard server (`remote://a,b` minus the scheme).
    pub servers: Vec<SocketSpec>,
    /// Socket framing the cluster runs.  The subscription and the
    /// push stream ride JSON under every framing, so `binary` here
    /// just means length-prefixed frames on the wire.
    pub framing: Framing,
    /// Requested push cadence (the server clamps it to 50..=10000).
    pub interval_ms: u64,
    /// Emit raw newline-delimited delta frames instead of the
    /// dashboard.
    pub json: bool,
    /// Exit after one frame per server.
    pub once: bool,
    /// Stop after this many ticks (`None` = until interrupted or a
    /// server hangs up).  Tests and scripted probes bound runs here.
    pub max_ticks: Option<u64>,
}

impl Default for TopConfig {
    fn default() -> Self {
        TopConfig {
            servers: Vec::new(),
            framing: Framing::Line,
            interval_ms: 1000,
            json: false,
            once: false,
            max_ticks: None,
        }
    }
}

/// Subscribe to every server and stream the dashboard (or NDJSON)
/// into `out` until `--once`/`max_ticks` says stop or a server hangs
/// up.  Errors name the server they came from.
pub fn run(cfg: &TopConfig, out: &mut dyn Write) -> Result<()> {
    if cfg.servers.is_empty() {
        bail!("no shard servers given (want --ps remote://host:port,...)");
    }
    let mut conns: Vec<Conn> = Vec::with_capacity(cfg.servers.len());
    for spec in &cfg.servers {
        let mut conn = spec
            .connect(cfg.framing)
            .map_err(|e| anyhow!("{spec}: connect failed: {e}"))?;
        conn.send(&encode_ps_request(&PsRequest::SubscribeStats {
            interval_ms: cfg.interval_ms,
        }))?;
        match decode_ps_reply(&conn.recv_expect()?)? {
            PsReply::Ok => {}
            PsReply::Err { message } => bail!("{spec}: subscribe rejected: {message}"),
            other => bail!("{spec}: unexpected subscribe reply {other:?}"),
        }
        conns.push(conn);
    }

    let collector = StatsCollector::new(conns.len());
    let mut rates = RateTracker::default();
    let mut ticks = 0u64;
    loop {
        // Round-robin one frame per server per tick.  All servers
        // push at the same requested cadence, so the blocking reads
        // stay in lockstep with the stream instead of falling behind.
        for (si, conn) in conns.iter_mut().enumerate() {
            let spec = &cfg.servers[si];
            let frame = conn
                .recv()
                .map_err(|e| anyhow!("{spec}: stats stream broke: {e}"))?
                .ok_or_else(|| anyhow!("{spec}: server closed the stats stream"))?;
            let reply = decode_ps_reply(&frame)
                .map_err(|e| anyhow!("{spec}: bad frame on the stats stream: {e}"))?;
            let PsReply::StatsDelta(delta) = reply else {
                bail!("{spec}: unexpected frame on the stats stream: {reply:?}");
            };
            collector
                .ingest(si, delta)
                .map_err(|e| anyhow!("{spec}: {e}"))?;
            if cfg.json {
                writeln!(out, "{frame}")?;
            }
        }
        ticks += 1;
        if !cfg.json {
            let view = collector.view();
            let rate = rates.update(&view);
            render(out, cfg, &view, rate, ticks)?;
        }
        out.flush()?;
        if cfg.once || cfg.max_ticks.is_some_and(|max| ticks >= max) {
            return Ok(());
        }
    }
}

/// Instantaneous row throughput between two renders.
#[derive(Debug, Clone, Copy, Default)]
pub struct Rates {
    pub applied_per_s: f64,
    pub read_per_s: f64,
}

/// Turns successive cumulative views into rows-per-second figures.
#[derive(Default)]
struct RateTracker {
    prev: Option<(std::time::Instant, u64, u64)>,
}

impl RateTracker {
    fn update(&mut self, view: &ClusterView) -> Rates {
        let now = std::time::Instant::now();
        let applied = view.snapshot.server.rows_applied;
        let read = view.snapshot.server.rows_read;
        let rate = match self.prev {
            Some((t0, a0, r0)) => {
                let dt = now.duration_since(t0).as_secs_f64();
                if dt > 0.0 {
                    Rates {
                        applied_per_s: applied.saturating_sub(a0) as f64 / dt,
                        read_per_s: read.saturating_sub(r0) as f64 / dt,
                    }
                } else {
                    Rates::default()
                }
            }
            None => Rates::default(),
        };
        self.prev = Some((now, applied, read));
        rate
    }
}

/// `1234567` → `"1.2M"` — counters get big fast at dashboard widths.
fn fmt_count(n: u64) -> String {
    match n {
        0..=9_999 => n.to_string(),
        10_000..=9_999_999 => format!("{:.1}k", n as f64 / 1e3),
        10_000_000..=9_999_999_999 => format!("{:.1}M", n as f64 / 1e6),
        _ => format!("{:.1}G", n as f64 / 1e9),
    }
}

/// Bytes with a binary-ish unit, same spirit as [`fmt_count`].
fn fmt_bytes(n: u64) -> String {
    match n {
        0..=9_999 => format!("{n}B"),
        10_000..=9_999_999 => format!("{:.1}KiB", n as f64 / 1024.0),
        _ => format!("{:.1}MiB", n as f64 / (1024.0 * 1024.0)),
    }
}

/// One dashboard render of `view` into `out`.  Pure with respect to
/// the wire (everything it shows is in the arguments), so tests drive
/// it with hand-built views.
pub fn render(
    out: &mut dyn Write,
    cfg: &TopConfig,
    view: &ClusterView,
    rate: Rates,
    tick: u64,
) -> Result<()> {
    if !cfg.once {
        // clear + home; plain ANSI, no terminal library
        write!(out, "\x1b[2J\x1b[H")?;
    }
    let s = &view.snapshot;
    writeln!(
        out,
        "mltuner top — {}/{} servers reporting, stats schema v{}, tick {tick}",
        view.servers,
        cfg.servers.len(),
        s.version
    )?;
    writeln!(
        out,
        "cluster:  {} rows applied ({}/s), {} rows read ({}/s)",
        fmt_count(s.server.rows_applied),
        fmt_count(rate.applied_per_s as u64),
        fmt_count(s.server.rows_read),
        fmt_count(rate.read_per_s as u64),
    )?;
    writeln!(
        out,
        "server:   {} rows in {} update batches, {} rows batch-read, {} lock contentions",
        fmt_count(s.server.batched_rows),
        fmt_count(s.server.batch_calls),
        fmt_count(s.server.reads_batched),
        fmt_count(s.server.shard_lock_contentions),
    )?;
    writeln!(
        out,
        "wire:     {} tx, {} rx, {} json + {} binary frames",
        fmt_bytes(s.wire.bytes_tx),
        fmt_bytes(s.wire.bytes_rx),
        fmt_count(s.wire.frames_json),
        fmt_count(s.wire.frames_bin),
    )?;
    writeln!(
        out,
        "store:    {} forks, {} live branches (peak {}), {} COW copies",
        s.store.forks, s.store.live_branches, s.store.peak_branches, s.store.cow_buffer_copies,
    )?;
    writeln!(
        out,
        "pool:     {} reused, {} allocated, {} idle buffers",
        fmt_count(s.pool.reused),
        fmt_count(s.pool.allocated),
        fmt_count(s.pool.idle),
    )?;

    render_hist(out, &view.rpc_hist)?;

    if !view.branches.is_empty() {
        write!(out, "branches: ")?;
        for (i, (b, rows)) in view.branches.iter().enumerate() {
            if i > 0 {
                write!(out, "  ")?;
            }
            write!(out, "#{b}:{}", fmt_count(*rows as u64))?;
        }
        writeln!(out)?;
    }

    if !view.trials.is_empty() {
        writeln!(out, "trials:")?;
        for t in &view.trials {
            writeln!(
                out,
                "  s{} ep{} trial{} branch #{} clock {}: progress {:.4} at {:.1}s",
                t.session, t.episode, t.trial, t.branch, t.clock, t.progress, t.time,
            )?;
        }
    }

    if !view.shards.is_empty() {
        writeln!(out, "shards:")?;
        for sh in &view.shards {
            writeln!(
                out,
                "  shard {:>3}: {:>8} applied, {:>8} read",
                sh.shard,
                fmt_count(sh.rows_applied),
                fmt_count(sh.rows_read),
            )?;
        }
    }

    // Tenant census: one line per session that has moved rows or
    // holds branches.  Session 0 is the default namespace, so a
    // single-tenant cluster shows at most that one line.
    if !view.sessions.is_empty() {
        writeln!(out, "sessions:")?;
        for ss in &view.sessions {
            writeln!(
                out,
                "  session {:>3}: {:>8} applied, {:>8} read, {} deferred, {} branches",
                ss.session,
                fmt_count(ss.rows_applied),
                fmt_count(ss.rows_read),
                fmt_count(ss.deferrals),
                ss.live_branches,
            )?;
        }
    }
    Ok(())
}

/// RPC service-time histogram as scaled hash bars, empty tail elided.
fn render_hist(out: &mut dyn Write, hist: &[u64; HIST_BUCKETS]) -> Result<()> {
    let total: u64 = hist.iter().sum();
    if total == 0 {
        return Ok(());
    }
    let last = hist.iter().rposition(|&n| n > 0).unwrap_or(0);
    let max = hist.iter().copied().max().unwrap_or(1).max(1);
    writeln!(out, "rpc service time ({} samples):", fmt_count(total))?;
    for (i, &n) in hist.iter().enumerate().take(last + 1) {
        let width = ((n * 24) / max) as usize;
        writeln!(
            out,
            "  ≥{:>8}µs {:>7} {}",
            bucket_floor_micros(i),
            fmt_count(n),
            "#".repeat(width),
        )?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::BranchId;
    use crate::optim::OptimizerKind;
    use crate::ps::remote::{spawn_local_server, RemoteParamServer, ShardRange};
    use crate::ps::ParamStore;
    use crate::stats::{SessionStats, ShardRows, TrialEvent};

    fn demo_view() -> ClusterView {
        let mut view = ClusterView::default();
        view.servers = 2;
        view.snapshot.server.rows_applied = 123_456;
        view.snapshot.server.rows_read = 42;
        view.snapshot.wire.bytes_tx = 20_480;
        view.snapshot.store.forks = 3;
        view.snapshot.store.live_branches = 2;
        view.shards = vec![
            ShardRows {
                shard: 0,
                rows_applied: 100_000,
                rows_read: 40,
            },
            ShardRows {
                shard: 1,
                rows_applied: 23_456,
                rows_read: 2,
            },
        ];
        view.branches = vec![(0 as BranchId, 64), (5 as BranchId, 64)];
        view.rpc_hist[3] = 90;
        view.rpc_hist[7] = 10;
        view.trials = vec![TrialEvent {
            session: 3,
            episode: 1,
            trial: 2,
            branch: 5,
            clock: 77,
            progress: 0.5,
            time: 12.0,
        }];
        view.sessions = vec![SessionStats {
            session: 3,
            rows_applied: 123_400,
            rows_read: 42,
            deferrals: 7,
            live_branches: 2,
        }];
        view
    }

    #[test]
    fn dashboard_renders_every_section() {
        let cfg = TopConfig {
            servers: vec![
                SocketSpec::Tcp("127.0.0.1:1".into()),
                SocketSpec::Tcp("127.0.0.1:2".into()),
            ],
            once: true, // no ANSI clear: keep the assertion readable
            ..TopConfig::default()
        };
        let mut buf = Vec::new();
        render(&mut buf, &cfg, &demo_view(), Rates::default(), 1).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.contains("2/2 servers reporting"), "{text}");
        assert!(text.contains("stats schema v2"), "{text}");
        assert!(text.contains("123.5k rows applied"), "{text}");
        assert!(text.contains("rpc service time (100 samples)"), "{text}");
        assert!(text.contains("branches: #0:64  #5:64"), "{text}");
        assert!(text.contains("s3 ep1 trial2 branch #5 clock 77"), "{text}");
        assert!(text.contains("shard   0"), "{text}");
        assert!(text.contains("session   3:"), "{text}");
        assert!(text.contains("7 deferred, 2 branches"), "{text}");
        assert!(!text.contains('\x1b'), "--once must not clear the screen");
    }

    #[test]
    fn count_and_byte_formatting() {
        assert_eq!(fmt_count(0), "0");
        assert_eq!(fmt_count(9_999), "9999");
        assert_eq!(fmt_count(123_456), "123.5k");
        assert_eq!(fmt_count(12_000_000), "12.0M");
        assert_eq!(fmt_bytes(100), "100B");
        assert_eq!(fmt_bytes(20_480), "20.0KiB");
    }

    /// End-to-end over real sockets: subscribe to a one-server
    /// "cluster", collect two ticks of NDJSON, check every frame is
    /// schema-versioned and the stream shuts down cleanly.
    #[cfg(unix)]
    #[test]
    fn once_json_emits_versioned_frames() {
        let (spec, handle, _srv) = spawn_local_server(
            ShardRange { begin: 0, end: 2 },
            OptimizerKind::Sgd,
            Framing::Line,
        )
        .unwrap();
        let remote = RemoteParamServer::connect(&[spec.clone()], Framing::Line).unwrap();
        for k in 0..6u64 {
            remote.insert_row(0, 0, k, vec![1.0, 2.0]).unwrap();
        }
        let cfg = TopConfig {
            servers: vec![spec],
            framing: Framing::Line,
            interval_ms: 50,
            json: true,
            once: false,
            max_ticks: Some(2),
        };
        let mut buf = Vec::new();
        run(&cfg, &mut buf).unwrap();
        let text = String::from_utf8(buf).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2, "one frame per tick: {text}");
        for line in &lines {
            assert!(line.contains("\"op\":\"stats_delta\""), "{line}");
            assert!(line.contains("\"v\":2"), "{line}");
            assert!(line.contains("\"shards\":"), "{line}");
        }
        remote.shutdown_all().unwrap();
        drop(remote);
        handle.join().unwrap().unwrap();
    }
}
