//! MLtuner: system support for automatic machine learning tuning.
//!
//! Reproduction of Cui, Ganger & Gibbons, *MLtuner: System Support for
//! Automatic Machine Learning Tuning* (2018).  The crate is the L3
//! coordinator of a three-layer stack:
//!
//! * **L3 (this crate)** — the MLtuner coordinator (branching, trial-time
//!   decision, progress summarization, tunable searchers, re-tuning), the
//!   parameter-server substrate it drives, the optimizer zoo, the
//!   data-parallel training system, the evaluation apps and the
//!   Spearmint / Hyperband baselines.
//! * **L2 (python/compile/model.py)** — the training-job compute graph in
//!   JAX, AOT-lowered to HLO-text artifacts consumed by [`runtime`].
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the compute
//!   hot-spots, lowered into the same artifacts.
//!
//! Python never runs on the training path: [`runtime`] loads the
//! artifacts once via the PJRT CPU client (`xla` crate) and executes
//! them from rust.
//!
//! Start with [`tuner::MLtuner`] (the paper's contribution) and
//! [`training::TrainingSystem`] (the interface of §4.5/Table 1).

pub mod analysis;
pub mod apps;
pub mod baselines;
pub mod comm;
pub mod config;
pub mod data;
pub mod figures;
pub mod metrics;
pub mod optim;
pub mod ps;
pub mod runtime;
pub mod searcher;
pub mod stats;
pub mod summarizer;
pub mod top;
pub mod training;
pub mod tunable;
pub mod tuner;
pub mod util;

pub use comm::{BranchId, BranchType, Clock, SystemMsg, TunerMsg};
pub use data::DriftSchedule;
pub use summarizer::{BranchLabel, ProgressSummarizer, SlopeWatchdog, Summary};
pub use stats::{ServerDelta, Snapshot};
pub use training::{Progress, TrainingSystem};
pub use tunable::{TunableSetting, TunableSpace, TunableSpec};
pub use tuner::{MLtuner, RetuneTrigger, TunerConfig, TunerReport, WatchdogConfig};
