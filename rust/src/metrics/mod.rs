//! Run recorder: the time-series every figure is plotted from.
//!
//! Records (time, clock, training loss) samples, (time, epoch,
//! validation accuracy) points, and labeled events (tuning started /
//! ended, re-tunings — the shaded regions of Fig. 4).  Dumps CSV for
//! external plotting and computes the summary statistics the paper
//! reports (time-to-accuracy, converged accuracy, CoV across runs).

use std::io::Write;

/// One labeled event on the run timeline.
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    pub time: f64,
    pub label: String,
}

/// Recorded time series of one training run.
#[derive(Debug, Clone, Default)]
pub struct RunRecorder {
    /// (time, clock, reported training loss)
    pub losses: Vec<(f64, u64, f64)>,
    /// (time, epoch, validation accuracy)
    pub accuracies: Vec<(f64, u64, f64)>,
    pub events: Vec<Event>,
}

impl RunRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_loss(&mut self, time: f64, clock: u64, loss: f64) {
        self.losses.push((time, clock, loss));
    }

    pub fn record_accuracy(&mut self, time: f64, epoch: u64, acc: f64) {
        self.accuracies.push((time, epoch, acc));
    }

    pub fn event(&mut self, time: f64, label: impl Into<String>) {
        self.events.push(Event {
            time,
            label: label.into(),
        });
    }

    /// Best validation accuracy seen so far at each recorded point —
    /// the bold "max accuracy over time" curves of Fig. 3.
    pub fn best_accuracy_curve(&self) -> Vec<(f64, f64)> {
        let mut best = 0.0f64;
        self.accuracies
            .iter()
            .map(|&(t, _, a)| {
                best = best.max(a);
                (t, best)
            })
            .collect()
    }

    pub fn final_accuracy(&self) -> Option<f64> {
        self.accuracies
            .iter()
            .map(|&(_, _, a)| a)
            .fold(None, |acc, a| Some(acc.map_or(a, |b: f64| b.max(a))))
    }

    /// First time the best-so-far accuracy reaches `target` (Fig. 3's
    /// convergence-time metric).
    pub fn time_to_accuracy(&self, target: f64) -> Option<f64> {
        self.best_accuracy_curve()
            .into_iter()
            .find(|&(_, a)| a >= target)
            .map(|(t, _)| t)
    }

    /// First time the training loss drops to `threshold` (the MF
    /// convergence metric).
    pub fn time_to_loss(&self, threshold: f64) -> Option<f64> {
        self.losses
            .iter()
            .find(|&&(_, _, l)| l <= threshold)
            .map(|&(t, _, _)| t)
    }

    pub fn total_time(&self) -> f64 {
        let lt = self.losses.last().map(|&(t, _, _)| t).unwrap_or(0.0);
        let at = self.accuracies.last().map(|&(t, _, _)| t).unwrap_or(0.0);
        lt.max(at)
    }

    /// Write the three series as CSV sections.
    pub fn write_csv<W: Write>(&self, mut w: W) -> std::io::Result<()> {
        writeln!(w, "# losses")?;
        writeln!(w, "time,clock,loss")?;
        for (t, c, l) in &self.losses {
            writeln!(w, "{t},{c},{l}")?;
        }
        writeln!(w, "# accuracies")?;
        writeln!(w, "time,epoch,accuracy")?;
        for (t, e, a) in &self.accuracies {
            writeln!(w, "{t},{e},{a}")?;
        }
        writeln!(w, "# events")?;
        writeln!(w, "time,label")?;
        for ev in &self.events {
            writeln!(w, "{},{}", ev.time, ev.label)?;
        }
        Ok(())
    }
}

/// Coefficient of variation = σ/μ (Fig. 9's run-variance statistic).
pub fn coefficient_of_variation(values: &[f64]) -> f64 {
    if values.is_empty() {
        return f64::NAN;
    }
    let n = values.len() as f64;
    let mean = values.iter().sum::<f64>() / n;
    if mean == 0.0 {
        return f64::NAN;
    }
    let var = values.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / n;
    var.sqrt() / mean
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn best_accuracy_curve_is_monotone() {
        let mut r = RunRecorder::new();
        for (i, a) in [0.1, 0.3, 0.2, 0.5, 0.4].iter().enumerate() {
            r.record_accuracy(i as f64, i as u64, *a);
        }
        let curve = r.best_accuracy_curve();
        assert_eq!(
            curve.iter().map(|&(_, a)| a).collect::<Vec<_>>(),
            vec![0.1, 0.3, 0.3, 0.5, 0.5]
        );
        assert_eq!(r.final_accuracy(), Some(0.5));
    }

    #[test]
    fn time_to_targets() {
        let mut r = RunRecorder::new();
        r.record_accuracy(1.0, 0, 0.2);
        r.record_accuracy(2.0, 1, 0.6);
        r.record_loss(0.5, 0, 10.0);
        r.record_loss(1.5, 1, 2.0);
        assert_eq!(r.time_to_accuracy(0.5), Some(2.0));
        assert_eq!(r.time_to_accuracy(0.9), None);
        assert_eq!(r.time_to_loss(5.0), Some(1.5));
    }

    #[test]
    fn cov_matches_hand_computation() {
        // values 1,2,3: mean 2, pop-var 2/3
        let cov = coefficient_of_variation(&[1.0, 2.0, 3.0]);
        assert!((cov - (2.0f64 / 3.0).sqrt() / 2.0).abs() < 1e-12);
        assert!(coefficient_of_variation(&[]).is_nan());
    }

    #[test]
    fn csv_has_all_sections() {
        let mut r = RunRecorder::new();
        r.record_loss(0.0, 0, 1.0);
        r.record_accuracy(1.0, 0, 0.5);
        r.event(0.5, "tuning_start");
        let mut buf = Vec::new();
        r.write_csv(&mut buf).unwrap();
        let s = String::from_utf8(buf).unwrap();
        assert!(s.contains("# losses") && s.contains("# accuracies"));
        assert!(s.contains("tuning_start"));
    }
}
