//! PJRT runtime: load the AOT artifacts emitted by `python/compile/aot.py`
//! and execute them from the rust training path.
//!
//! Python runs only at build time; this module makes the binary
//! self-contained afterwards.  Interchange is **HLO text** (see
//! aot.py's module docstring): `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `PjRtClient::compile` → `execute`.
//! One compiled executable per (model, entry kind, batch-size variant),
//! cached after first use.
//!
//! The PJRT execution path needs the heavy `xla` bridge crate, which is
//! not installable offline, so it is gated behind the **`pjrt` cargo
//! feature** (see Cargo.toml).  Manifest parsing is dependency-free and
//! always available; without the feature, [`Runtime`] is a stub whose
//! constructor returns a clear "built without pjrt" error, and every
//! caller (the DNN app, `mltuner info`, the benches, the integration
//! tests) degrades gracefully exactly as it does when artifacts are
//! missing.
//!
//! ## Thread model
//!
//! The runtime is deliberately **not** `Sync`: it owns a single PJRT
//! CPU device and an executable cache behind `&mut self`, and the
//! `xla` bridge types make no cross-thread promises.  The DNN app's
//! data-parallel clock therefore runs its gradient dispatches
//! sequentially through the one runtime (phase 2 of
//! `apps::dnn::DnnSystem`), while the parameter-server gather and
//! batched-update phases on either side fan out across worker
//! threads — the phases this crate's concurrency actually targets.
//! A multi-device runtime pool is ROADMAP material.

use std::collections::HashMap;
use std::path::Path;
#[cfg(feature = "pjrt")]
use std::path::PathBuf;

use anyhow::{anyhow, bail, Result};
#[cfg(feature = "pjrt")]
use anyhow::Context;

use crate::util::json::Json;

/// artifacts/manifest.json (written by aot.py).
#[derive(Debug, Clone)]
pub struct Manifest {
    pub format: u32,
    pub models: HashMap<String, ModelManifest>,
}

impl Manifest {
    /// Parse the manifest from JSON text (aot.py's output format).
    pub fn from_json_text(text: &str) -> Result<Self> {
        let v = Json::parse(text)?;
        let format = v
            .get("format")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest: missing format"))? as u32;
        let mut models = HashMap::new();
        let model_obj = v
            .get("models")
            .and_then(Json::as_object)
            .ok_or_else(|| anyhow!("manifest: missing models"))?;
        for (name, m) in model_obj {
            let usize_field = |k: &str| -> Result<usize> {
                m.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("manifest model {name}: missing {k}"))
            };
            let hidden = m
                .get("hidden")
                .and_then(Json::as_array)
                .ok_or_else(|| anyhow!("manifest: missing hidden"))?
                .iter()
                .filter_map(Json::as_usize)
                .collect();
            let param_shapes = m
                .get("param_shapes")
                .and_then(Json::as_array)
                .ok_or_else(|| anyhow!("manifest: missing param_shapes"))?
                .iter()
                .map(|s| {
                    s.as_array()
                        .map(|a| a.iter().filter_map(Json::as_usize).collect())
                        .ok_or_else(|| anyhow!("bad shape"))
                })
                .collect::<Result<Vec<Vec<usize>>>>()?;
            let artifacts = m
                .get("artifacts")
                .and_then(Json::as_array)
                .ok_or_else(|| anyhow!("manifest: missing artifacts"))?
                .iter()
                .map(|a| {
                    Ok(ArtifactEntry {
                        kind: a
                            .get("kind")
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("artifact: missing kind"))?
                            .to_string(),
                        batch_size: a
                            .get("batch_size")
                            .and_then(Json::as_usize)
                            .ok_or_else(|| anyhow!("artifact: missing batch_size"))?,
                        variant: a
                            .get("variant")
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("artifact: missing variant"))?
                            .to_string(),
                        file: a
                            .get("file")
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("artifact: missing file"))?
                            .to_string(),
                        sha256: a
                            .get("sha256")
                            .and_then(Json::as_str)
                            .unwrap_or("")
                            .to_string(),
                    })
                })
                .collect::<Result<Vec<ArtifactEntry>>>()?;
            models.insert(
                name.clone(),
                ModelManifest {
                    input_dim: usize_field("input_dim")?,
                    hidden,
                    classes: usize_field("classes")?,
                    param_shapes,
                    eval_batch: usize_field("eval_batch")?,
                    artifacts,
                },
            );
        }
        Ok(Manifest { format, models })
    }
}

#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub input_dim: usize,
    pub hidden: Vec<usize>,
    pub classes: usize,
    pub param_shapes: Vec<Vec<usize>>,
    pub eval_batch: usize,
    pub artifacts: Vec<ArtifactEntry>,
}

impl ModelManifest {
    pub fn num_params(&self) -> usize {
        self.param_shapes
            .iter()
            .map(|s| s.iter().product::<usize>())
            .sum()
    }

    pub fn batch_sizes(&self, variant: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == "grad" && a.variant == variant)
            .map(|a| a.batch_size)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub kind: String,
    pub batch_size: usize,
    pub variant: String,
    pub file: String,
    pub sha256: String,
}

/// Key of a compiled executable in the cache.
#[cfg(feature = "pjrt")]
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct ExeKey {
    model: String,
    kind: String,
    batch_size: usize,
    variant: String,
}

/// The PJRT runtime: client + manifest + executable cache.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: HashMap<ExeKey, xla::PjRtLoadedExecutable>,
    /// compile count (a §Perf metric: compiles happen once per variant).
    pub compiles: u64,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Load `artifacts/manifest.json` under `dir` and connect the PJRT
    /// CPU client.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("reading {mpath:?} (run `make artifacts`)"))?;
        let manifest = Manifest::from_json_text(&text)?;
        if manifest.format != 1 {
            bail!("unsupported manifest format {}", manifest.format);
        }
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Runtime {
            client,
            dir,
            manifest,
            cache: HashMap::new(),
            compiles: 0,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.manifest
            .models
            .get(name)
            .ok_or_else(|| anyhow!("model {name} not in manifest"))
    }

    fn ensure_compiled(&mut self, key: &ExeKey) -> Result<()> {
        if self.cache.contains_key(key) {
            return Ok(());
        }
        let model = self.model(&key.model)?;
        let entry = model
            .artifacts
            .iter()
            .find(|a| {
                a.kind == key.kind
                    && a.batch_size == key.batch_size
                    && a.variant == key.variant
            })
            .ok_or_else(|| {
                anyhow!(
                    "no artifact {}:{} bs={} variant={}",
                    key.model,
                    key.kind,
                    key.batch_size,
                    key.variant
                )
            })?;
        let path = self.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {path:?}: {e:?}"))?;
        self.compiles += 1;
        self.cache.insert(key.clone(), exe);
        Ok(())
    }

    fn execute(&mut self, key: &ExeKey, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.ensure_compiled(key)?;
        let exe = self.cache.get(key).unwrap();
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        // aot.py lowers with return_tuple=True
        lit.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}"))
    }

    /// Run one gradient step: `(params, x, y) → (grads, loss_sum)`.
    /// `params` are the flat tensors in manifest order; gradients come
    /// back batch-normalized (see model.py).
    pub fn run_grad(
        &mut self,
        model: &str,
        batch_size: usize,
        variant: &str,
        params: &[Vec<f32>],
        x: &[f32],
        y: &[i32],
    ) -> Result<(Vec<Vec<f32>>, f32)> {
        let mm = self.model(model)?.clone();
        if params.len() != mm.param_shapes.len() {
            bail!(
                "expected {} param tensors, got {}",
                mm.param_shapes.len(),
                params.len()
            );
        }
        let key = ExeKey {
            model: model.into(),
            kind: "grad".into(),
            batch_size,
            variant: variant.into(),
        };
        let inputs = self.marshal_inputs(&mm, params, x, y, batch_size)?;
        let outs = self.execute(&key, &inputs)?;
        if outs.len() != params.len() + 1 {
            bail!("expected {} outputs, got {}", params.len() + 1, outs.len());
        }
        let mut grads = Vec::with_capacity(params.len());
        for lit in &outs[..params.len()] {
            grads.push(lit.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?);
        }
        let loss = outs[params.len()]
            .to_vec::<f32>()
            .map_err(|e| anyhow!("{e:?}"))?[0];
        Ok((grads, loss))
    }

    /// Run one validation pass: `(params, x, y) → (correct, loss_sum)`.
    pub fn run_eval(
        &mut self,
        model: &str,
        variant: &str,
        params: &[Vec<f32>],
        x: &[f32],
        y: &[i32],
    ) -> Result<(f32, f32)> {
        let mm = self.model(model)?.clone();
        let batch_size = mm.eval_batch;
        let key = ExeKey {
            model: model.into(),
            kind: "eval".into(),
            batch_size,
            variant: variant.into(),
        };
        let inputs = self.marshal_inputs(&mm, params, x, y, batch_size)?;
        let outs = self.execute(&key, &inputs)?;
        let correct = outs[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?[0];
        let loss = outs[1].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?[0];
        Ok((correct, loss))
    }

    fn marshal_inputs(
        &self,
        mm: &ModelManifest,
        params: &[Vec<f32>],
        x: &[f32],
        y: &[i32],
        batch_size: usize,
    ) -> Result<Vec<xla::Literal>> {
        if x.len() != batch_size * mm.input_dim {
            bail!(
                "x has {} elements, want {}*{}",
                x.len(),
                batch_size,
                mm.input_dim
            );
        }
        if y.len() != batch_size {
            bail!("y has {} labels, want {batch_size}", y.len());
        }
        let mut inputs = Vec::with_capacity(params.len() + 2);
        for (p, shape) in params.iter().zip(&mm.param_shapes) {
            let expect: usize = shape.iter().product();
            if p.len() != expect {
                bail!("param tensor size {} != shape {:?}", p.len(), shape);
            }
            let lit = xla::Literal::vec1(p);
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            inputs.push(lit.reshape(&dims).map_err(|e| anyhow!("{e:?}"))?);
        }
        inputs.push(
            xla::Literal::vec1(x)
                .reshape(&[batch_size as i64, mm.input_dim as i64])
                .map_err(|e| anyhow!("{e:?}"))?,
        );
        inputs.push(xla::Literal::vec1(y));
        Ok(inputs)
    }

    pub fn cached_executables(&self) -> usize {
        self.cache.len()
    }
}

/// Feature-off stub with the same public surface as the real runtime.
/// [`Runtime::load`] always fails (so a stub can never actually be
/// constructed); the remaining methods exist only so callers compile
/// unchanged.
#[cfg(not(feature = "pjrt"))]
#[derive(Debug)]
pub struct Runtime {
    pub manifest: Manifest,
    /// compile count (a §Perf metric: compiles happen once per variant).
    pub compiles: u64,
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    /// Always errors: this binary was built without PJRT support.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        bail!(
            "PJRT runtime unavailable: mltuner was built without the `pjrt` \
             feature (artifacts dir {:?}); rebuild with `--features pjrt` \
             after adding the optional `xla` dependency — see Cargo.toml",
            dir.as_ref()
        )
    }

    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.manifest
            .models
            .get(name)
            .ok_or_else(|| anyhow!("model {name} not in manifest"))
    }

    fn unavailable(&self) -> anyhow::Error {
        anyhow!("PJRT runtime unavailable: built without the `pjrt` feature")
    }

    /// Run one gradient step — unavailable without the `pjrt` feature.
    pub fn run_grad(
        &mut self,
        _model: &str,
        _batch_size: usize,
        _variant: &str,
        _params: &[Vec<f32>],
        _x: &[f32],
        _y: &[i32],
    ) -> Result<(Vec<Vec<f32>>, f32)> {
        Err(self.unavailable())
    }

    /// Run one validation pass — unavailable without the `pjrt` feature.
    pub fn run_eval(
        &mut self,
        _model: &str,
        _variant: &str,
        _params: &[Vec<f32>],
        _x: &[f32],
        _y: &[i32],
    ) -> Result<(f32, f32)> {
        Err(self.unavailable())
    }

    pub fn cached_executables(&self) -> usize {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full end-to-end runtime tests live in rust/tests/integration_runtime.rs
    // (they need `make artifacts`).  Here: manifest parsing only.

    #[test]
    fn manifest_parses_and_reports_sizes() {
        let json = r#"{
            "format": 1,
            "models": {
                "m": {
                    "input_dim": 4, "hidden": [8], "classes": 3,
                    "param_shapes": [[4,8],[8],[8,3],[3]],
                    "eval_batch": 16,
                    "artifacts": [
                        {"kind":"grad","batch_size":4,"variant":"xla","file":"a.hlo.txt"},
                        {"kind":"grad","batch_size":8,"variant":"xla","file":"b.hlo.txt"},
                        {"kind":"eval","batch_size":16,"variant":"xla","file":"c.hlo.txt"}
                    ]
                }
            }
        }"#;
        let m = Manifest::from_json_text(json).unwrap();
        let mm = &m.models["m"];
        assert_eq!(mm.num_params(), 4 * 8 + 8 + 8 * 3 + 3);
        assert_eq!(mm.batch_sizes("xla"), vec![4, 8]);
        assert_eq!(mm.batch_sizes("pallas"), Vec::<usize>::new());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_load_reports_missing_feature() {
        let err = Runtime::load("artifacts").unwrap_err().to_string();
        assert!(err.contains("pjrt"), "unhelpful error: {err}");
    }
}
