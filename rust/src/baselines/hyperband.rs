//! Infinite-horizon Hyperband baseline (§5.2, Li et al. 2016).
//!
//! The total number of epochs to convergence is unknown, so the
//! algorithm starts with a small budget and doubles it over time.  For
//! each budget it runs successive halving: sample `n` random settings,
//! train each for `r` epochs, keep the half with the higher validation
//! accuracies, double `r`, repeat until one survives.

use anyhow::Result;

use crate::baselines::BaselineReport;
use crate::comm::{BranchId, BranchType, TunerMsg};
use crate::metrics::RunRecorder;
use crate::searcher::{cmp_speed_desc, Proposal, RandomSearcher, Searcher};
use crate::training::{MessageDriver, TrainingSystem};
use crate::tunable::{TunableSetting, TunableSpace};

pub struct HyperbandDriver<S: TrainingSystem> {
    driver: MessageDriver<S>,
    space: TunableSpace,
    /// Epochs of the very first rung.
    pub r0_epochs: u64,
    pub seed: u64,
}

struct Arm {
    branch: BranchId,
    setting: TunableSetting,
    acc: f64,
    dead: bool,
}

/// Rank live arm indices by accuracy, best first.  Divergence zeroes
/// `acc` before ranking, but a Testing-clock accuracy can itself come
/// back NaN without tripping the divergence check — `cmp_speed_desc`
/// ranks NaN strictly worst, so such an arm lands in the culled half
/// instead of panicking the bracket.
fn rank_by_acc_desc(arms: &[Arm], live: &mut [usize]) {
    live.sort_by(|&a, &b| cmp_speed_desc(&arms[a].acc, &arms[b].acc));
}

impl<S: TrainingSystem> HyperbandDriver<S> {
    pub fn new(system: S, space: TunableSpace, seed: u64) -> Self {
        HyperbandDriver {
            driver: MessageDriver::new(system),
            space,
            r0_epochs: 1,
            seed,
        }
    }

    pub fn run(&mut self, time_budget: f64) -> Result<BaselineReport> {
        let mut sampler = RandomSearcher::new(self.space.dim(), self.seed);
        let mut recorder = RunRecorder::new();
        let mut configs = Vec::new();
        let mut clock = 0u64;
        let mut now = 0.0f64;
        let mut next_branch = 1u32;
        let mut best_acc = 0.0f64;
        let mut round = 0u32;

        'outer: while now < time_budget {
            // Infinite horizon: double the bracket size every round.
            let n_arms = 2usize.pow((round + 1).min(6)); // 2,4,8,…,64
            round += 1;
            let mut arms: Vec<Arm> = Vec::with_capacity(n_arms);
            for _ in 0..n_arms {
                let point = match sampler.propose() {
                    Proposal::Exhausted => break,
                    Proposal::Point(p) => p,
                };
                sampler.observe(point.clone(), 0.0);
                let setting = self.space.decode(&point);
                let branch = next_branch;
                next_branch += 1;
                self.driver.send(&TunerMsg::ForkBranch {
                    clock,
                    branch_id: branch,
                    parent_branch_id: Some(0),
                    tunable: setting.clone(),
                    branch_type: BranchType::Training,
                })?;
                arms.push(Arm {
                    branch,
                    setting,
                    acc: 0.0,
                    dead: false,
                });
            }
            let mut r = self.r0_epochs;
            // successive halving
            while arms.iter().filter(|a| !a.dead).count() > 0 {
                for ai in 0..arms.len() {
                    if arms[ai].dead {
                        continue;
                    }
                    let branch = arms[ai].branch;
                    let setting = arms[ai].setting.clone();
                    let mut diverged = false;
                    for _ in 0..r {
                        let clocks =
                            self.driver.system.clocks_per_epoch(branch).max(1);
                        for _ in 0..clocks {
                            let p = self
                                .driver
                                .send(&TunerMsg::ScheduleBranch {
                                    clock,
                                    branch_id: branch,
                                })?
                                .unwrap();
                            clock += 1;
                            now += p.time;
                            recorder.record_loss(now, clock, p.value);
                            if !p.value.is_finite() {
                                diverged = true;
                                break;
                            }
                            if now >= time_budget {
                                break;
                            }
                        }
                        if diverged || now >= time_budget {
                            break;
                        }
                    }
                    // measure accuracy
                    let tb = next_branch;
                    next_branch += 1;
                    self.driver.send(&TunerMsg::ForkBranch {
                        clock,
                        branch_id: tb,
                        parent_branch_id: Some(branch),
                        tunable: setting.clone(),
                        branch_type: BranchType::Testing,
                    })?;
                    let acc = self
                        .driver
                        .send(&TunerMsg::ScheduleBranch {
                            clock,
                            branch_id: tb,
                        })?
                        .unwrap();
                    clock += 1;
                    now += acc.time;
                    self.driver.send(&TunerMsg::FreeBranch {
                        clock,
                        branch_id: tb,
                    })?;
                    let a = if diverged { 0.0 } else { acc.value };
                    arms[ai].acc = a;
                    recorder.record_accuracy(now, r, a);
                    best_acc = best_acc.max(a);
                    if diverged {
                        arms[ai].dead = true;
                        self.driver.send(&TunerMsg::FreeBranch {
                            clock,
                            branch_id: branch,
                        })?;
                        configs.push((setting, 0.0));
                    }
                    if now >= time_budget {
                        // free all live arms and stop
                        for arm in &mut arms {
                            if !arm.dead {
                                self.driver.send(&TunerMsg::FreeBranch {
                                    clock,
                                    branch_id: arm.branch,
                                })?;
                                arm.dead = true;
                                configs.push((arm.setting.clone(), arm.acc));
                            }
                        }
                        break 'outer;
                    }
                }
                // stop the lower-accuracy half
                let mut live: Vec<usize> = (0..arms.len()).filter(|&i| !arms[i].dead).collect();
                if live.len() <= 1 {
                    for &i in &live {
                        self.driver.send(&TunerMsg::FreeBranch {
                            clock,
                            branch_id: arms[i].branch,
                        })?;
                        arms[i].dead = true;
                        configs.push((arms[i].setting.clone(), arms[i].acc));
                    }
                    break;
                }
                rank_by_acc_desc(&arms, &mut live);
                for &i in &live[live.len() / 2..] {
                    self.driver.send(&TunerMsg::FreeBranch {
                        clock,
                        branch_id: arms[i].branch,
                    })?;
                    arms[i].dead = true;
                    configs.push((arms[i].setting.clone(), arms[i].acc));
                }
                r *= 2;
            }
        }
        Ok(BaselineReport {
            recorder,
            configs,
            best_accuracy: best_acc,
            total_time: now,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tunable::TunableSetting;

    fn arm(branch: BranchId, acc: f64) -> Arm {
        Arm {
            branch,
            setting: TunableSetting::new(vec![0.0]),
            acc,
            dead: false,
        }
    }

    #[test]
    fn nan_accuracy_ranks_last_instead_of_panicking() {
        let arms = vec![arm(0, 0.5), arm(1, f64::NAN), arm(2, 0.9), arm(3, 0.7)];
        let mut live: Vec<usize> = (0..arms.len()).collect();
        rank_by_acc_desc(&arms, &mut live);
        assert_eq!(live, vec![2, 3, 0, 1]);
        // the culled half (tail) holds the NaN arm
        assert!(arms[live[3]].acc.is_nan());
    }

    #[test]
    fn all_nan_accuracies_still_give_a_total_order() {
        let arms = vec![arm(0, f64::NAN), arm(1, f64::NAN)];
        let mut live: Vec<usize> = vec![0, 1];
        rank_by_acc_desc(&arms, &mut live);
        assert_eq!(live.len(), 2);
    }
}
