//! Coupled lr+momentum adaptive baseline (arXiv 1908.07607).
//!
//! The scenario suite's adversary for slope-triggered MLtuner: one
//! training branch, never re-tuned by search — instead a
//! [`CoupledRule`] folds each epoch's mean training loss into a
//! coupled (learning-rate, momentum) adjustment that is applied to the
//! *running* branch in place via `update_tunable` (the Fig. 8
//! manual-decay plumbing).  Hill-climbing like this reacts to
//! non-stationary data only through multiplicative creep — the
//! contrast to a re-tune episode, which re-searches the space outright.
//!
//! Runs inside the same harness as every other baseline (same training
//! system, same branch machinery) to control for other performance
//! factors, and is deterministic end to end: the rule is a pure fold
//! and the driver draws no randomness of its own.

use anyhow::{bail, Result};

use crate::baselines::BaselineReport;
use crate::comm::{BranchType, TunerMsg};
use crate::metrics::RunRecorder;
use crate::optim::coupled::CoupledRule;
use crate::training::{MessageDriver, TrainingSystem};
use crate::tunable::{TunableSetting, TunableSpace};

/// Upper bound on the clocks trained between rule updates.  The source
/// rule adapts per mini-batch — far finer than an epoch — so systems
/// whose epochs span millions of clocks (MF on Netflix: ~12.5M) would
/// otherwise fold their first observation long after any drift.  One
/// epoch stays one round wherever epochs are shorter than this.
const ROUND_CLOCKS_CAP: u64 = 256;

pub struct CoupledAdaptiveDriver<S: TrainingSystem> {
    driver: MessageDriver<S>,
    space: TunableSpace,
    rule: CoupledRule,
    /// Mid-space template the adapted (lr, momentum) dims are written
    /// over — other dims (batch size, staleness) stay fixed.
    template: TunableSetting,
}

/// Write the rule's (lr, momentum) over the template setting, clamped
/// into the space through an encode/decode roundtrip.  Spaces without
/// a momentum dim (the MF app) just keep adapting lr alone.
fn setting_for(
    space: &TunableSpace,
    template: &TunableSetting,
    lr: f64,
    momentum: f64,
) -> TunableSetting {
    let mut values = template.values.clone();
    if let Some(i) = space.index_of("lr") {
        values[i] = space.specs[i].decode(space.specs[i].encode(lr));
    }
    if let Some(i) = space.index_of("momentum") {
        values[i] = space.specs[i].decode(space.specs[i].encode(momentum));
    }
    TunableSetting::new(values)
}

impl<S: TrainingSystem> CoupledAdaptiveDriver<S> {
    pub fn new(system: S, space: TunableSpace, initial_lr: f64) -> Self {
        let template = space.decode(&vec![0.5; space.dim()]);
        CoupledAdaptiveDriver {
            driver: MessageDriver::new(system),
            rule: CoupledRule::new(initial_lr),
            template,
            space,
        }
    }

    pub fn run(&mut self, time_budget: f64) -> Result<BaselineReport> {
        let mut recorder = RunRecorder::new();
        let mut clock = 0u64;
        let mut now = 0.0f64;
        let mut next_branch = 1u32;
        let mut best_acc = 0.0f64;

        let mut setting =
            setting_for(&self.space, &self.template, self.rule.lr(), self.rule.momentum());
        let branch = next_branch;
        next_branch += 1;
        self.driver.send(&TunerMsg::ForkBranch {
            clock,
            branch_id: branch,
            parent_branch_id: Some(0),
            tunable: setting.clone(),
            branch_type: BranchType::Training,
        })?;

        let mut epoch = 0u64;
        while now < time_budget {
            let clocks = self
                .driver
                .system
                .clocks_per_epoch(branch)
                .max(1)
                .min(ROUND_CLOCKS_CAP);
            let mut loss_acc = 0.0f64;
            let mut loss_n = 0u64;
            let mut diverged = false;
            for _ in 0..clocks {
                let Some(p) = self.driver.send(&TunerMsg::ScheduleBranch {
                    clock,
                    branch_id: branch,
                })?
                else {
                    bail!("protocol violation: ScheduleBranch returned no progress report");
                };
                clock += 1;
                now += p.time;
                recorder.record_loss(now, clock, p.value);
                if p.value.is_finite() {
                    loss_acc += p.value;
                    loss_n += 1;
                } else {
                    diverged = true;
                    break;
                }
                if now >= time_budget {
                    break;
                }
            }
            epoch += 1;

            // Fold the epoch's mean loss into the rule and apply the
            // adapted setting to the SAME branch — the rule tunes in
            // place, it never forks or searches.
            let mean = if diverged || loss_n == 0 {
                f64::NAN
            } else {
                loss_acc / loss_n as f64
            };
            self.rule.observe(mean);
            setting =
                setting_for(&self.space, &self.template, self.rule.lr(), self.rule.momentum());
            self.driver.system.update_tunable(branch, &setting)?;

            // Accuracy probe via a Testing fork (§4.5 protocol).
            let tb = next_branch;
            next_branch += 1;
            self.driver.send(&TunerMsg::ForkBranch {
                clock,
                branch_id: tb,
                parent_branch_id: Some(branch),
                tunable: setting.clone(),
                branch_type: BranchType::Testing,
            })?;
            let Some(acc) = self.driver.send(&TunerMsg::ScheduleBranch {
                clock,
                branch_id: tb,
            })?
            else {
                bail!("protocol violation: Testing ScheduleBranch returned no progress report");
            };
            clock += 1;
            now += acc.time;
            self.driver.send(&TunerMsg::FreeBranch { clock, branch_id: tb })?;
            recorder.record_accuracy(now, epoch, acc.value);
            if acc.value.is_finite() && acc.value > best_acc {
                best_acc = acc.value;
            }
        }
        self.driver.send(&TunerMsg::FreeBranch { clock, branch_id: branch })?;
        let configs = vec![(setting, best_acc)];
        Ok(BaselineReport {
            recorder,
            configs,
            best_accuracy: best_acc,
            total_time: now,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::sim::{SimProfile, SimSystem};

    #[test]
    fn adapts_a_too_small_lr_up_to_convergence() {
        let sys = SimSystem::new(SimProfile::alexnet_cifar10(), 8, 3);
        let space = sys.space.clone();
        // 10x under the profile's optimal lr: pure SGD at this step is
        // slow; the rule must grow its way to a competitive setting
        let mut d = CoupledAdaptiveDriver::new(sys, space, 0.005);
        let report = d.run(800.0).unwrap();
        assert!(
            report.best_accuracy > 0.5,
            "coupled rule failed to adapt: acc {}",
            report.best_accuracy
        );
    }

    #[test]
    fn baseline_run_is_bit_deterministic() {
        let run = || {
            let sys = SimSystem::new(SimProfile::alexnet_cifar10(), 8, 5);
            let space = sys.space.clone();
            let mut d = CoupledAdaptiveDriver::new(sys, space, 0.005);
            let r = d.run(300.0).unwrap();
            (r.best_accuracy.to_bits(), r.total_time.to_bits())
        };
        assert_eq!(run(), run());
    }
}
