//! State-of-the-art hyperparameter-tuning baselines (§5.2).
//!
//! As in the paper, the baselines' tuning logics are implemented inside
//! our own harness (same training system, same branch machinery) to
//! control for other performance factors:
//!
//! * [`spearmint::SpearmintDriver`] — Bayesian optimization proposing
//!   settings that are each trained **from initialization to
//!   completion** (fork from the pristine root branch).
//! * [`hyperband::HyperbandDriver`] — the Infinite-horizon Hyperband
//!   algorithm: doubling budgets, random sampling, successive halving
//!   on validation accuracy.
//! * [`coupled::CoupledAdaptiveDriver`] — the coupled lr+momentum
//!   adaptive rule (arXiv 1908.07607): one branch, per-epoch in-place
//!   adjustment, the scenario suite's non-stationary adversary.

pub mod coupled;
pub mod hyperband;
pub mod spearmint;

pub use coupled::CoupledAdaptiveDriver;
pub use hyperband::HyperbandDriver;
pub use spearmint::SpearmintDriver;

use crate::metrics::RunRecorder;
use crate::tunable::TunableSetting;

/// Result of one baseline tuning run.
#[derive(Debug, Clone)]
pub struct BaselineReport {
    pub recorder: RunRecorder,
    /// (setting, final validation accuracy) per configuration tried —
    /// the dashed curves of Fig. 3.
    pub configs: Vec<(TunableSetting, f64)>,
    pub best_accuracy: f64,
    pub total_time: f64,
}
