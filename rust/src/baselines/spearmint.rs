//! Spearmint-style baseline: GP Bayesian optimization over the tunable
//! space, training each proposed setting **to completion** and scoring
//! it by final validation accuracy (§2.3.2, §5.2).
//!
//! The first proposal sets every tunable to its minimum (the behaviour
//! the paper observed from Spearmint's package on every run) — on large
//! benchmarks that single configuration can consume the entire tuning
//! budget at a glacial convergence rate, which is exactly Fig. 3a.

use anyhow::Result;

use crate::baselines::BaselineReport;
use crate::comm::{BranchType, TunerMsg};
use crate::metrics::RunRecorder;
use crate::searcher::{BayesianOptSearcher, Proposal, Searcher};
use crate::training::{MessageDriver, TrainingSystem};
use crate::tunable::TunableSpace;

pub struct SpearmintDriver<S: TrainingSystem> {
    driver: MessageDriver<S>,
    space: TunableSpace,
    /// Convergence condition for each full training: accuracy plateau.
    pub plateau_epochs: u32,
    pub max_epochs_per_config: u64,
    pub seed: u64,
}

impl<S: TrainingSystem> SpearmintDriver<S> {
    pub fn new(system: S, space: TunableSpace, seed: u64) -> Self {
        SpearmintDriver {
            driver: MessageDriver::new(system),
            space,
            plateau_epochs: 5,
            max_epochs_per_config: 200,
            seed,
        }
    }

    /// Run until `time_budget` seconds of (system) time are consumed.
    pub fn run(&mut self, time_budget: f64) -> Result<BaselineReport> {
        let mut searcher = BayesianOptSearcher::new(self.space.dim(), self.seed);
        let mut recorder = RunRecorder::new();
        let mut configs = Vec::new();
        let mut clock = 0u64;
        let mut now = 0.0f64;
        let mut next_branch = 1u32;
        let mut best_acc = 0.0f64;

        'outer: while now < time_budget {
            let point = match searcher.propose() {
                Proposal::Exhausted => break,
                Proposal::Point(p) => p,
            };
            let setting = self.space.decode(&point);
            // fresh model: fork from the pristine root
            let branch = next_branch;
            next_branch += 2; // reserve one id for testing forks
            self.driver.send(&TunerMsg::ForkBranch {
                clock,
                branch_id: branch,
                parent_branch_id: Some(0),
                tunable: setting.clone(),
                branch_type: BranchType::Training,
            })?;
            let mut best_config_acc = 0.0f64;
            let mut since_improve = 0u32;
            let mut epoch = 0u64;
            while epoch < self.max_epochs_per_config && now < time_budget {
                let clocks = self.driver.system.clocks_per_epoch(branch).max(1);
                let mut diverged = false;
                for _ in 0..clocks {
                    let p = self
                        .driver
                        .send(&TunerMsg::ScheduleBranch {
                            clock,
                            branch_id: branch,
                        })?
                        .unwrap();
                    clock += 1;
                    now += p.time;
                    recorder.record_loss(now, clock, p.value);
                    if !p.value.is_finite() {
                        diverged = true;
                        break;
                    }
                    if now >= time_budget {
                        break;
                    }
                }
                epoch += 1;
                // validation accuracy via a testing fork
                let tb = next_branch;
                next_branch += 1;
                self.driver.send(&TunerMsg::ForkBranch {
                    clock,
                    branch_id: tb,
                    parent_branch_id: Some(branch),
                    tunable: setting.clone(),
                    branch_type: BranchType::Testing,
                })?;
                let acc = self
                    .driver
                    .send(&TunerMsg::ScheduleBranch {
                        clock,
                        branch_id: tb,
                    })?
                    .unwrap();
                clock += 1;
                now += acc.time;
                self.driver.send(&TunerMsg::FreeBranch {
                    clock,
                    branch_id: tb,
                })?;
                recorder.record_accuracy(now, epoch, acc.value);
                best_acc = best_acc.max(acc.value);
                if acc.value > best_config_acc + 1e-6 {
                    best_config_acc = acc.value;
                    since_improve = 0;
                } else {
                    since_improve += 1;
                }
                if diverged || since_improve >= self.plateau_epochs {
                    break;
                }
                if now >= time_budget {
                    // budget exhausted mid-config
                    self.driver.send(&TunerMsg::FreeBranch {
                        clock,
                        branch_id: branch,
                    })?;
                    configs.push((setting.clone(), best_config_acc));
                    searcher.observe(point.clone(), best_config_acc);
                    break 'outer;
                }
            }
            self.driver.send(&TunerMsg::FreeBranch {
                clock,
                branch_id: branch,
            })?;
            configs.push((setting, best_config_acc));
            searcher.observe(point, best_config_acc);
        }
        Ok(BaselineReport {
            recorder,
            configs,
            best_accuracy: best_acc,
            total_time: now,
        })
    }
}
