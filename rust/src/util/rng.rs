//! Deterministic pseudo-random numbers (offline substrate — the `rand`
//! crate is not vendored in this build).
//!
//! [`Rng`] is xoshiro256++ (Blackman & Vigna) seeded through SplitMix64,
//! with the distribution helpers the rest of the crate needs: uniform
//! f64, integer ranges, Fisher–Yates shuffle and Box–Muller normals.
//! Streams are stable across platforms — experiment seeds reproduce.

/// xoshiro256++ PRNG.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box–Muller sample
    spare_normal: Option<u64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0].wrapping_add(s[3]).rotate_left(23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        // 53 mantissa bits
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn gen_range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo, "empty range");
        let span = (hi - lo) as u64;
        // multiply-shift rejection-free mapping (tiny bias acceptable
        // for simulation workloads; spans here are ≪ 2^32)
        lo + ((self.next_u64() as u128 * span as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (caches the second sample).
    pub fn gen_normal(&mut self) -> f64 {
        if let Some(bits) = self.spare_normal.take() {
            return f64::from_bits(bits);
        }
        loop {
            let u1 = self.gen_f64();
            let u2 = self.gen_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            let z0 = r * theta.cos();
            let z1 = r * theta.sin();
            self.spare_normal = Some(z1.to_bits());
            return z0;
        }
    }

    /// Normal with mean `mu`, std `sigma`.
    #[inline]
    pub fn gen_normal_with(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.gen_normal()
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, slice: &mut [T]) {
        for i in (1..slice.len()).rev() {
            let j = self.gen_range(0, i + 1);
            slice.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        let mut c = Rng::seed_from_u64(43);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn uniform_mean_and_bounds() {
        let mut r = Rng::seed_from_u64(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.gen_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gen_range_covers_all_values() {
        let mut r = Rng::seed_from_u64(2);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.gen_range(0, 10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
        assert_eq!(r.gen_range(7, 8), 7);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(3);
        let n = 50_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.gen_normal();
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(4);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "astronomically unlikely");
    }
}
