//! Micro-bench harness (offline substrate — `criterion` is not
//! vendored).  Warm-up + timed iterations with median / mean / p95
//! reporting, `black_box` to defeat const-folding, and a tabular
//! printer shared by the figure-regeneration benches.

use std::time::Instant;

/// Opaque value sink (stable `std::hint::black_box`).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl BenchStats {
    pub fn print(&self) {
        println!(
            "{:<44} {:>10} iters  mean {:>12}  median {:>12}  p95 {:>12}  min {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.p95_ns),
            fmt_ns(self.min_ns),
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Time `f` adaptively: warm up, then run enough iterations to cover
/// ~`target_ms` of wall time (bounded by `max_iters`).
pub fn bench<F: FnMut()>(name: &str, target_ms: f64, max_iters: usize, mut f: F) -> BenchStats {
    // warm-up
    for _ in 0..3.min(max_iters) {
        f();
    }
    // estimate one iteration
    let t0 = Instant::now();
    f();
    let est = t0.elapsed().as_nanos().max(1) as f64;
    let budget_ns = target_ms * 1e6;
    let iters = ((budget_ns / est) as usize).clamp(5, max_iters);
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.total_cmp(b));
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let stats = BenchStats {
        name: name.to_string(),
        iters: samples.len(),
        mean_ns: mean,
        median_ns: samples[samples.len() / 2],
        p95_ns: samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)],
        min_ns: samples[0],
    };
    stats.print();
    stats
}

/// Print a figure-regeneration table header.
pub fn table_header(title: &str, columns: &[&str]) {
    println!();
    println!("=== {title} ===");
    println!("{}", columns.join("\t"));
}

/// Print one row of a figure table.
pub fn table_row(cells: &[String]) {
    println!("{}", cells.join("\t"));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let mut acc = 0u64;
        let s = bench("noop-ish", 5.0, 1000, || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(s.iters >= 5);
        assert!(s.min_ns <= s.median_ns && s.median_ns <= s.p95_ns);
    }

    #[test]
    fn sample_sort_is_nan_safe() {
        // The sample sort must be a total order: a NaN sample (never
        // produced by Instant, but the ordering contract should not
        // depend on that) sorts last instead of panicking.
        let mut samples = vec![3.0f64, f64::NAN, 1.0, 2.0];
        samples.sort_by(|a, b| a.total_cmp(b));
        assert_eq!(&samples[..3], &[1.0, 2.0, 3.0]);
        assert!(samples[3].is_nan());
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(1500.0), "1.50µs");
        assert_eq!(fmt_ns(2.5e6), "2.50ms");
        assert_eq!(fmt_ns(3.2e9), "3.200s");
    }
}
