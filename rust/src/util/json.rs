//! Minimal JSON parser (offline substrate — `serde_json` is not
//! vendored).  Parses the full JSON grammar into a [`Json`] tree; used
//! to read `artifacts/manifest.json`.  Strict enough for our own
//! artifacts, with real error positions for debugging.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Json>),
    Object(BTreeMap<String, Json>),
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Object(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Number(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Object(m) => Some(m),
            _ => None,
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Number(n) => write!(f, "{n}"),
            Json::String(s) => write!(f, "{:?}", s),
            Json::Array(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Object(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{:?}:{v}", k)?;
                }
                write!(f, "}}")
            }
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.into(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            None => Err(self.err("unexpected end of input")),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::String(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(c) => Err(self.err(&format!("unexpected char '{}'", c as char))),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex digit"))?;
                        }
                        out.push(char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let width = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let end = start + width;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Number)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Array(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Array(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Object(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Object(out)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -1.5e3 ").unwrap(), Json::Number(-1500.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::String("a\nbA".into())
        );
    }

    #[test]
    fn parses_nested_structures() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        let a = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(a[0].as_f64(), Some(1.0));
        assert_eq!(a[2].get("b").unwrap().as_str(), Some("c"));
        assert!(v.get("d").unwrap().as_object().unwrap().is_empty());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn error_positions_are_useful() {
        let e = Json::parse("[1, x]").unwrap_err();
        assert_eq!(e.pos, 4);
    }

    #[test]
    fn parses_a_manifest_shaped_doc() {
        let doc = r#"{
            "format": 1,
            "models": {"m": {"param_shapes": [[4,8],[8]], "eval_batch": 16,
                "artifacts": [{"kind":"grad","batch_size":4,"variant":"xla","file":"a"}]}}
        }"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("format").unwrap().as_usize(), Some(1));
        let m = v.get("models").unwrap().get("m").unwrap();
        let shapes = m.get("param_shapes").unwrap().as_array().unwrap();
        assert_eq!(shapes[0].as_array().unwrap()[1].as_usize(), Some(8));
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse(r#""héllo → 世界""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo → 世界"));
    }
}
