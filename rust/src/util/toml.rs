//! Minimal TOML-subset parser (offline substrate — the `toml` crate is
//! not vendored).  Supports the config grammar this crate uses:
//! `[section]` / `[section.sub]` headers, `key = value` with strings,
//! integers, floats, booleans, and flat arrays.  Comments with `#`.

use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    String(String),
    Integer(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Integer(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Integer(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// A parsed document: dotted-path key → value.  Keys inside
/// `[section]` become `section.key`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TomlDoc {
    pub values: BTreeMap<String, TomlValue>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc, TomlError> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let err = |msg: &str| TomlError {
                line: ln + 1,
                msg: msg.into(),
            };
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| err("unterminated section header"))?
                    .trim();
                if name.is_empty() {
                    return Err(err("empty section name"));
                }
                section = name.to_string();
                continue;
            }
            let eq = line.find('=').ok_or_else(|| err("expected key = value"))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(err("empty key"));
            }
            let value = parse_value(line[eq + 1..].trim()).map_err(|m| err(&m))?;
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            doc.values.insert(full, value);
        }
        Ok(doc)
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.values.get(key)
    }

    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(|v| v.as_str())
    }

    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(|v| v.as_f64())
    }

    pub fn get_i64(&self, key: &str) -> Option<i64> {
        self.get(key).and_then(|v| v.as_i64())
    }

    pub fn get_bool(&self, key: &str) -> Option<bool> {
        self.get(key).and_then(|v| v.as_bool())
    }

    /// All keys under a `section.` prefix exist?
    pub fn has_section(&self, section: &str) -> bool {
        let prefix = format!("{section}.");
        self.values.keys().any(|k| k.starts_with(&prefix))
    }
}

fn strip_comment(line: &str) -> &str {
    // a '#' inside a string literal would break this, but our config
    // grammar never embeds '#' in strings.
    match line.find('#') {
        Some(i) => &line[..i],
        None => line,
    }
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("missing value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        return Ok(TomlValue::String(inner.to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?
            .trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(Vec::new()));
        }
        let mut items = Vec::new();
        for part in inner.split(',') {
            items.push(parse_value(part.trim())?);
        }
        return Ok(TomlValue::Array(items));
    }
    let clean = s.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(TomlValue::Integer(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value: {s}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_flat_and_sections() -> anyhow::Result<()> {
        // TomlError implements std::error::Error, so `?` propagates it
        // through anyhow instead of panicking on malformed input.
        let doc = TomlDoc::parse(
            r#"
            app = "sim"          # trailing comment
            seed = 42
            retune = true
            threshold = 8.32e6
            [mf]
            users = 1_000
            sizes = [2, 4, 8]
        "#,
        )?;
        assert_eq!(doc.get_str("app"), Some("sim"));
        assert_eq!(doc.get_i64("seed"), Some(42));
        assert_eq!(doc.get_bool("retune"), Some(true));
        assert_eq!(doc.get_f64("threshold"), Some(8.32e6));
        assert_eq!(doc.get_i64("mf.users"), Some(1000));
        assert!(doc.has_section("mf"));
        assert!(!doc.has_section("dnn"));
        match doc.get("mf.sizes") {
            Some(TomlValue::Array(a)) => assert_eq!(a.len(), 3),
            other => anyhow::bail!("mf.sizes should parse as an array, got {other:?}"),
        }
        Ok(())
    }

    #[test]
    fn error_carries_line_number() {
        let e = TomlDoc::parse("a = 1\nb : 2\n").unwrap_err();
        assert_eq!(e.line, 2);
    }

    #[test]
    fn rejects_bad_values() {
        assert!(TomlDoc::parse("a = ").is_err());
        assert!(TomlDoc::parse("a = \"x").is_err());
        assert!(TomlDoc::parse("[s\na=1").is_err());
    }

    #[test]
    fn integers_vs_floats() {
        let doc = TomlDoc::parse("i = 3\nf = 3.0\n").unwrap();
        assert_eq!(doc.get("i"), Some(&TomlValue::Integer(3)));
        assert_eq!(doc.get("f"), Some(&TomlValue::Float(3.0)));
        assert_eq!(doc.get_f64("i"), Some(3.0));
    }
}
