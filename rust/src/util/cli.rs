//! Tiny CLI argument parser (offline substrate — `clap` is not
//! vendored).  Supports `--flag value`, `--flag=value`, boolean
//! `--flag`, and positional arguments.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    let v = it.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_bool(&self, name: &str, default: bool) -> bool {
        self.get(name)
            .map(|s| s == "true" || s == "1" || s.is_empty())
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_styles() {
        let a = parse("tune --seed 7 --csv=out.csv --verbose --app sim");
        assert_eq!(a.positional, vec!["tune"]);
        assert_eq!(a.get("seed"), Some("7"));
        assert_eq!(a.get("csv"), Some("out.csv"));
        assert_eq!(a.get_bool("verbose", false), true);
        assert_eq!(a.get_u64("seed", 0), 7);
        assert_eq!(a.get_or("app", "x"), "sim");
        assert_eq!(a.get_or("missing", "dflt"), "dflt");
    }

    #[test]
    fn trailing_boolean_flag() {
        let a = parse("--retune");
        assert!(a.get_bool("retune", false));
    }
}
