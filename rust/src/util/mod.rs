//! Offline substrates: the small libraries this build vendors in-tree
//! because only the PJRT bridge crates are available offline
//! (see Cargo.toml).

pub mod bench;
pub mod cli;
pub mod json;
pub mod rng;
pub mod toml;
