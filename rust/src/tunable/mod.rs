//! Training-tunable specifications and search-space geometry (§3.1).
//!
//! MLtuner requires users to specify each tunable with its *type* —
//! discrete, continuous in linear scale, or continuous in log scale —
//! and its range of valid values.  Searchers operate on the unit cube
//! `[0,1]^d`; this module owns the encode/decode between cube
//! coordinates and concrete tunable values.

/// One tunable's type + valid range (paper §3.1, Table 3).
#[derive(Debug, Clone, PartialEq)]
pub enum TunableSpec {
    /// Finite set of valid values (e.g. batch size, staleness bound).
    Discrete { name: String, values: Vec<f64> },
    /// Continuous, linear scale (e.g. momentum in [0, 1]).
    Linear { name: String, min: f64, max: f64 },
    /// Continuous, log10 scale (e.g. learning rate 10^[-5, 0]).
    /// `min`/`max` are the concrete values (both > 0), not exponents.
    Log { name: String, min: f64, max: f64 },
}

impl TunableSpec {
    pub fn name(&self) -> &str {
        match self {
            TunableSpec::Discrete { name, .. }
            | TunableSpec::Linear { name, .. }
            | TunableSpec::Log { name, .. } => name,
        }
    }

    /// Map a unit-cube coordinate `u ∈ [0,1]` to a concrete value.
    pub fn decode(&self, u: f64) -> f64 {
        let u = u.clamp(0.0, 1.0);
        match self {
            TunableSpec::Discrete { values, .. } => {
                debug_assert!(!values.is_empty());
                let idx = (u * values.len() as f64).floor() as usize;
                values[idx.min(values.len() - 1)]
            }
            TunableSpec::Linear { min, max, .. } => min + u * (max - min),
            TunableSpec::Log { min, max, .. } => {
                let (lmin, lmax) = (min.log10(), max.log10());
                10f64.powf(lmin + u * (lmax - lmin))
            }
        }
    }

    /// Map a concrete value back to a unit-cube coordinate.  Discrete
    /// values snap to the nearest member's bucket center.
    pub fn encode(&self, v: f64) -> f64 {
        match self {
            TunableSpec::Discrete { values, .. } => {
                // Nearest-bucket search through a NaN-proof total
                // order (the `cmp_speed_desc` discipline of the
                // searcher ranking): a NaN distance — e.g. a NaN input
                // value, which a diverged trial can produce — ranks
                // strictly worst, so the search falls back to the
                // first bucket instead of panicking the old
                // `partial_cmp().unwrap()`.
                let idx = values
                    .iter()
                    .enumerate()
                    .min_by(|(_, a), (_, b)| (*a - v).abs().total_cmp(&(*b - v).abs()))
                    .map(|(i, _)| i)
                    .unwrap_or(0);
                (idx as f64 + 0.5) / values.len() as f64
            }
            TunableSpec::Linear { min, max, .. } => {
                if max == min {
                    0.5
                } else {
                    ((v - min) / (max - min)).clamp(0.0, 1.0)
                }
            }
            TunableSpec::Log { min, max, .. } => {
                let (lmin, lmax) = (min.log10(), max.log10());
                if lmax == lmin {
                    0.5
                } else {
                    ((v.log10() - lmin) / (lmax - lmin)).clamp(0.0, 1.0)
                }
            }
        }
    }

    /// Number of distinct values (None for continuous).
    pub fn cardinality(&self) -> Option<usize> {
        match self {
            TunableSpec::Discrete { values, .. } => Some(values.len()),
            _ => None,
        }
    }
}

/// The full search space: an ordered list of tunables.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TunableSpace {
    pub specs: Vec<TunableSpec>,
}

impl TunableSpace {
    pub fn new(specs: Vec<TunableSpec>) -> Self {
        Self { specs }
    }

    pub fn dim(&self) -> usize {
        self.specs.len()
    }

    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.specs.iter().position(|s| s.name() == name)
    }

    /// Decode a unit-cube point into a concrete [`TunableSetting`].
    pub fn decode(&self, u: &[f64]) -> TunableSetting {
        assert_eq!(u.len(), self.dim());
        TunableSetting {
            values: self.specs.iter().zip(u).map(|(s, &ui)| s.decode(ui)).collect(),
        }
    }

    /// Encode a concrete setting back into the unit cube.
    pub fn encode(&self, setting: &TunableSetting) -> Vec<f64> {
        assert_eq!(setting.values.len(), self.dim());
        self.specs
            .iter()
            .zip(&setting.values)
            .map(|(s, &v)| s.encode(v))
            .collect()
    }

    /// The paper's standard 4-tunable space (Table 3): learning rate
    /// (log 10^[-5,0]), momentum (linear [0,1]), per-machine batch size
    /// (model-specific discrete grid), data staleness ({0,1,3,7}).
    pub fn standard(batch_sizes: &[f64]) -> Self {
        Self::new(vec![
            TunableSpec::Log {
                name: "lr".into(),
                min: 1e-5,
                max: 1.0,
            },
            TunableSpec::Linear {
                name: "momentum".into(),
                min: 0.0,
                max: 1.0,
            },
            TunableSpec::Discrete {
                name: "batch_size".into(),
                values: batch_sizes.to_vec(),
            },
            TunableSpec::Discrete {
                name: "staleness".into(),
                values: vec![0.0, 1.0, 3.0, 7.0],
            },
        ])
    }

    /// Fig. 11's "4×2 tunables" setup: the standard space plus a
    /// duplicated copy whose extra tunables are transparent to the
    /// training system (they only enlarge the search space).
    pub fn standard_duplicated(batch_sizes: &[f64]) -> Self {
        let mut space = Self::standard(batch_sizes);
        let extra: Vec<TunableSpec> = space
            .specs
            .iter()
            .map(|s| match s.clone() {
                TunableSpec::Discrete { name, values } => TunableSpec::Discrete {
                    name: format!("{name}_dup"),
                    values,
                },
                TunableSpec::Linear { name, min, max } => TunableSpec::Linear {
                    name: format!("{name}_dup"),
                    min,
                    max,
                },
                TunableSpec::Log { name, min, max } => TunableSpec::Log {
                    name: format!("{name}_dup"),
                    min,
                    max,
                },
            })
            .collect();
        space.specs.extend(extra);
        space
    }
}

/// A concrete assignment of every tunable in a [`TunableSpace`].
#[derive(Debug, Clone, PartialEq)]
pub struct TunableSetting {
    pub values: Vec<f64>,
}

impl TunableSetting {
    pub fn new(values: Vec<f64>) -> Self {
        Self { values }
    }

    /// Value of the tunable called `name` within `space`.
    pub fn get(&self, space: &TunableSpace, name: &str) -> Option<f64> {
        space.index_of(name).map(|i| self.values[i])
    }

    pub fn lr(&self, space: &TunableSpace) -> f64 {
        self.get(space, "lr").unwrap_or(0.01)
    }

    pub fn momentum(&self, space: &TunableSpace) -> f64 {
        self.get(space, "momentum").unwrap_or(0.0)
    }

    pub fn batch_size(&self, space: &TunableSpace) -> usize {
        self.get(space, "batch_size").unwrap_or(32.0) as usize
    }

    pub fn staleness(&self, space: &TunableSpace) -> u32 {
        self.get(space, "staleness").unwrap_or(0.0) as u32
    }

    pub fn describe(&self, space: &TunableSpace) -> String {
        space
            .specs
            .iter()
            .zip(&self.values)
            .map(|(s, v)| format!("{}={:.4e}", s.name(), v))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lr_spec() -> TunableSpec {
        TunableSpec::Log {
            name: "lr".into(),
            min: 1e-5,
            max: 1.0,
        }
    }

    #[test]
    fn log_decode_endpoints() {
        let s = lr_spec();
        assert!((s.decode(0.0) - 1e-5).abs() < 1e-12);
        assert!((s.decode(1.0) - 1.0).abs() < 1e-9);
        // midpoint of log space is 10^-2.5
        assert!((s.decode(0.5) - 10f64.powf(-2.5)).abs() < 1e-9);
    }

    #[test]
    fn log_roundtrip() {
        let s = lr_spec();
        for &v in &[1e-5, 1e-4, 3e-3, 0.5, 1.0] {
            let u = s.encode(v);
            assert!((s.decode(u) - v).abs() / v < 1e-9, "v={v}");
        }
    }

    #[test]
    fn discrete_decode_covers_all_values() {
        let s = TunableSpec::Discrete {
            name: "bs".into(),
            values: vec![4.0, 16.0, 64.0, 256.0],
        };
        let mut seen = std::collections::BTreeSet::new();
        for i in 0..100 {
            let v = s.decode(i as f64 / 99.0);
            seen.insert(v as i64);
        }
        assert_eq!(seen.len(), 4);
        // u=1.0 must not index out of bounds
        assert_eq!(s.decode(1.0), 256.0);
    }

    #[test]
    fn discrete_roundtrip_snaps() {
        let s = TunableSpec::Discrete {
            name: "stale".into(),
            values: vec![0.0, 1.0, 3.0, 7.0],
        };
        for &v in &[0.0, 1.0, 3.0, 7.0] {
            assert_eq!(s.decode(s.encode(v)), v);
        }
        // off-grid values snap to nearest
        assert_eq!(s.decode(s.encode(2.9)), 3.0);
        assert_eq!(s.decode(s.encode(100.0)), 7.0);
    }

    #[test]
    fn discrete_encode_survives_nan_input() {
        // Regression: the nearest-bucket ranking used
        // `partial_cmp().unwrap()` and panicked on a NaN value (the
        // shape a diverged trial hands back).  NaN now simply loses:
        // every distance is NaN, the search falls back to the first
        // bucket, and the coordinate stays inside the unit cube.
        let s = TunableSpec::Discrete {
            name: "bs".into(),
            values: vec![4.0, 16.0, 64.0],
        };
        let u = s.encode(f64::NAN);
        assert!(u.is_finite() && (0.0..=1.0).contains(&u), "u={u}");
        assert_eq!(s.decode(u), 4.0, "NaN falls back to the first bucket");
        // infinities keep working (all-infinite distances tie)
        assert!((0.0..=1.0).contains(&s.encode(f64::INFINITY)));
        // and finite inputs still snap to the nearest member
        assert_eq!(s.decode(s.encode(15.0)), 16.0);
        assert_eq!(s.decode(s.encode(-3.0)), 4.0);
    }

    #[test]
    fn linear_roundtrip_and_clamp() {
        let s = TunableSpec::Linear {
            name: "m".into(),
            min: 0.0,
            max: 1.0,
        };
        assert_eq!(s.decode(s.encode(0.9)), 0.9);
        assert_eq!(s.encode(2.0), 1.0);
        assert_eq!(s.decode(-0.5), 0.0);
    }

    #[test]
    fn standard_space_layout() {
        let sp = TunableSpace::standard(&[2.0, 4.0, 8.0, 16.0, 32.0]);
        assert_eq!(sp.dim(), 4);
        assert_eq!(sp.index_of("lr"), Some(0));
        assert_eq!(sp.index_of("staleness"), Some(3));
        let setting = sp.decode(&[0.5, 0.9, 0.99, 0.0]);
        assert_eq!(setting.batch_size(&sp), 32);
        assert_eq!(setting.staleness(&sp), 0);
        assert!((setting.momentum(&sp) - 0.9).abs() < 1e-12);
    }

    #[test]
    fn duplicated_space_doubles_dim() {
        let sp = TunableSpace::standard_duplicated(&[4.0]);
        assert_eq!(sp.dim(), 8);
        assert_eq!(sp.index_of("lr_dup"), Some(4));
        // real tunable accessors still resolve to the originals
        let setting = sp.decode(&vec![0.5; 8]);
        assert!(setting.lr(&sp) > 0.0);
    }

    #[test]
    fn space_encode_decode_roundtrip() {
        let sp = TunableSpace::standard(&[4.0, 16.0, 64.0]);
        let setting = sp.decode(&[0.3, 0.7, 0.5, 0.8]);
        let u = sp.encode(&setting);
        let setting2 = sp.decode(&u);
        assert_eq!(setting, setting2);
    }
}
