//! Session namespaces for the multi-tenant parameter server.
//!
//! A **session** is one `mltuner tune` run's private branch namespace
//! on a shared, long-lived server.  The registry maps each session's
//! user-visible branch ids to **global** branch ids drawn from
//! [`SESSION_BRANCH_BASE`] upward, far above anything a client names
//! directly, so two tenants forking "branch 3" land on different
//! global branches and the engine below stays completely
//! session-oblivious.  Session 0 is the default namespace: it has no
//! registry entry, no lease, and identity branch mapping — a lone
//! pre-session client is a session-0 client and behaves bit-exactly
//! as before.
//!
//! The registry is plain data with **no interior locking and no
//! clock**: it lives inside [`super::ParamServer`]'s control-plane
//! mutex (lock hierarchy unchanged), and every time-dependent method
//! takes `now_ms` from the caller, so lease expiry is deterministic
//! under test.
//!
//! Lifecycle: `register` admits or re-attaches by name (bounded by
//! [`SessionLimits::max_sessions`]); any stamped frame refreshes the
//! lease via `touch`; `remove_session` is the graceful teardown; and
//! `expired` names the sessions whose lease lapsed so the server can
//! garbage-collect a crashed client's branches.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use crate::comm::{BranchId, SessionId};

/// First global branch id handed to a named session.  User-visible
/// branch ids are small (the tuner counts up from 0), so everything at
/// or above this base belongs to some session namespace — which is
/// also how the default namespace's census filters co-tenant branches
/// out of `ListBranches { session: 0 }`.
pub const SESSION_BRANCH_BASE: BranchId = 0x8000_0000;

/// Lease granted when a `Hello` asks for `lease_ms: 0`.
pub const DEFAULT_LEASE_MS: u64 = 30_000;

/// Admission limits enforced at registration and branch allocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SessionLimits {
    /// Live named sessions allowed at once (`--max-sessions`).
    pub max_sessions: usize,
    /// Branches one session may hold at once
    /// (`--max-branches-per-session`).
    pub max_branches_per_session: usize,
    /// Lease used when the client asks for the server default.
    pub default_lease_ms: u64,
}

impl Default for SessionLimits {
    fn default() -> Self {
        SessionLimits {
            max_sessions: 64,
            max_branches_per_session: 64,
            default_lease_ms: DEFAULT_LEASE_MS,
        }
    }
}

/// What a successful `register` granted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SessionGrant {
    pub id: SessionId,
    /// Effective lease (the requested one, or the server default).
    pub lease_ms: u64,
    /// False when the name was already registered (re-attach).
    pub created: bool,
    /// Global id of the session's root branch (user branch 0), mapped
    /// eagerly so a fresh namespace is born with its root.
    pub root_global: BranchId,
}

#[derive(Debug)]
struct SessionEntry {
    name: String,
    lease_ms: u64,
    last_seen_ms: u64,
    /// user branch id → global branch id.
    branches: HashMap<BranchId, BranchId>,
}

/// Name → id → branch-namespace bookkeeping for named sessions.
#[derive(Debug)]
pub struct SessionRegistry {
    limits: SessionLimits,
    by_name: HashMap<String, SessionId>,
    entries: HashMap<SessionId, SessionEntry>,
    /// Next session id; ids start at 1 (0 is the default namespace).
    next_id: SessionId,
    /// Next global branch id, counting up from the base.
    next_global: BranchId,
}

impl Default for SessionRegistry {
    fn default() -> Self {
        SessionRegistry {
            limits: SessionLimits::default(),
            by_name: HashMap::new(),
            entries: HashMap::new(),
            next_id: 1,
            next_global: SESSION_BRANCH_BASE,
        }
    }
}

impl SessionRegistry {
    pub fn set_limits(&mut self, limits: SessionLimits) {
        self.limits = limits;
    }

    pub fn limits(&self) -> SessionLimits {
        self.limits
    }

    /// Live named sessions.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn name_of(&self, session: SessionId) -> Option<&str> {
        self.entries.get(&session).map(|e| e.name.as_str())
    }

    fn alloc_global(&mut self) -> BranchId {
        let g = self.next_global;
        self.next_global = self.next_global.wrapping_add(1);
        if self.next_global < SESSION_BRANCH_BASE {
            // 2^31 allocations later: stay above the base rather than
            // wrap into user-visible ids (collision with a still-live
            // ancient global id is accepted at that scale).
            self.next_global = SESSION_BRANCH_BASE;
        }
        g
    }

    /// Admit a new session named `name`, or re-attach to the live one
    /// of that name (refreshing its lease).  `lease_ms: 0` asks for
    /// the server default.
    pub fn register(&mut self, name: &str, lease_ms: u64, now_ms: u64) -> Result<SessionGrant> {
        if name.is_empty() {
            bail!("session name must not be empty");
        }
        if let Some(&id) = self.by_name.get(name) {
            if let Some(e) = self.entries.get_mut(&id) {
                e.last_seen_ms = now_ms;
                if lease_ms != 0 {
                    e.lease_ms = lease_ms;
                }
                let root_global = e.branches.get(&0).copied().unwrap_or(SESSION_BRANCH_BASE);
                return Ok(SessionGrant {
                    id,
                    lease_ms: e.lease_ms,
                    created: false,
                    root_global,
                });
            }
        }
        if self.entries.len() >= self.limits.max_sessions {
            bail!(
                "session admission denied: {} sessions live (max {})",
                self.entries.len(),
                self.limits.max_sessions
            );
        }
        let id = self.next_id;
        self.next_id = self.next_id.wrapping_add(1).max(1);
        let lease = if lease_ms == 0 {
            self.limits.default_lease_ms
        } else {
            lease_ms
        };
        let root_global = self.alloc_global();
        let mut branches = HashMap::new();
        branches.insert(0, root_global);
        self.entries.insert(
            id,
            SessionEntry {
                name: name.to_string(),
                lease_ms: lease,
                last_seen_ms: now_ms,
                branches,
            },
        );
        self.by_name.insert(name.to_string(), id);
        Ok(SessionGrant {
            id,
            lease_ms: lease,
            created: true,
            root_global,
        })
    }

    /// Refresh a session's lease; unknown ids are ignored (the frame
    /// that carried them will fail at `resolve` instead).
    pub fn touch(&mut self, session: SessionId, now_ms: u64) {
        if let Some(e) = self.entries.get_mut(&session) {
            e.last_seen_ms = now_ms;
        }
    }

    /// Map a session-scoped branch id to its global id.
    pub fn resolve(&self, session: SessionId, branch: BranchId) -> Result<BranchId> {
        let e = self
            .entries
            .get(&session)
            .ok_or_else(|| anyhow!("unknown session {session}"))?;
        e.branches
            .get(&branch)
            .copied()
            .ok_or_else(|| anyhow!("branch {branch} not in session {session}"))
    }

    /// Allocate a fresh global id for `branch` in `session`
    /// (admission-checked; the branch must not exist yet).
    pub fn allocate_branch(&mut self, session: SessionId, branch: BranchId) -> Result<BranchId> {
        {
            let e = self
                .entries
                .get(&session)
                .ok_or_else(|| anyhow!("unknown session {session}"))?;
            if e.branches.contains_key(&branch) {
                bail!("branch {branch} already exists in session {session}");
            }
            if e.branches.len() >= self.limits.max_branches_per_session {
                bail!(
                    "branch admission denied: session {session} holds {} branches (max {})",
                    e.branches.len(),
                    self.limits.max_branches_per_session
                );
            }
        }
        let g = self.alloc_global();
        if let Some(e) = self.entries.get_mut(&session) {
            e.branches.insert(branch, g);
        }
        Ok(g)
    }

    /// Resolve `branch`, allocating a mapping if the session does not
    /// hold it yet (restore-into-fresh-branch path).
    pub fn resolve_or_allocate(&mut self, session: SessionId, branch: BranchId) -> Result<BranchId> {
        match self.entries.get(&session) {
            None => bail!("unknown session {session}"),
            Some(e) => {
                if let Some(&g) = e.branches.get(&branch) {
                    return Ok(g);
                }
            }
        }
        self.allocate_branch(session, branch)
    }

    /// Drop one branch mapping (after the global branch was freed).
    pub fn remove_branch(&mut self, session: SessionId, branch: BranchId) {
        if let Some(e) = self.entries.get_mut(&session) {
            e.branches.remove(&branch);
        }
    }

    /// Tear a session down, returning the sorted global branch ids its
    /// namespace held (for the caller to free under the same lock).
    pub fn remove_session(&mut self, session: SessionId) -> Result<Vec<BranchId>> {
        let Some(e) = self.entries.remove(&session) else {
            bail!("unknown session {session}");
        };
        self.by_name.remove(&e.name);
        let mut globals: Vec<BranchId> = e.branches.into_values().collect();
        globals.sort_unstable();
        Ok(globals)
    }

    /// Sessions whose lease lapsed as of `now_ms`, ascending id order.
    pub fn expired(&self, now_ms: u64) -> Vec<SessionId> {
        let mut v: Vec<SessionId> = self
            .entries
            .iter()
            .filter(|(_, e)| now_ms.saturating_sub(e.last_seen_ms) > e.lease_ms)
            .map(|(id, _)| *id)
            .collect();
        v.sort_unstable();
        v
    }

    /// `(session, live branches)` for every named session, ascending.
    pub fn census(&self) -> Vec<(SessionId, usize)> {
        let mut v: Vec<(SessionId, usize)> = self
            .entries
            .iter()
            .map(|(id, e)| (*id, e.branches.len()))
            .collect();
        v.sort_unstable();
        v
    }

    /// `(user branch id, global branch id)` pairs of one session,
    /// ascending by user id.
    pub fn branches(&self, session: SessionId) -> Result<Vec<(BranchId, BranchId)>> {
        let e = self
            .entries
            .get(&session)
            .ok_or_else(|| anyhow!("unknown session {session}"))?;
        let mut v: Vec<(BranchId, BranchId)> =
            e.branches.iter().map(|(u, g)| (*u, *g)).collect();
        v.sort_unstable();
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_attach_and_lease_refresh() {
        let mut r = SessionRegistry::default();
        let a = r.register("mf-a", 0, 100).unwrap();
        assert!(a.created);
        assert_eq!(a.id, 1);
        assert_eq!(a.lease_ms, DEFAULT_LEASE_MS);
        assert!(a.root_global >= SESSION_BRANCH_BASE);
        // same name re-attaches with the same id and refreshed lease
        let b = r.register("mf-a", 5_000, 200).unwrap();
        assert!(!b.created);
        assert_eq!(b.id, a.id);
        assert_eq!(b.lease_ms, 5_000);
        assert_eq!(b.root_global, a.root_global);
        assert_eq!(r.len(), 1);
        assert_eq!(r.name_of(a.id), Some("mf-a"));
        // a different name is a different namespace with its own root
        let c = r.register("mf-b", 0, 200).unwrap();
        assert_ne!(c.id, a.id);
        assert_ne!(c.root_global, a.root_global);
        assert!(r.register("", 0, 0).is_err());
    }

    #[test]
    fn admission_limits_sessions_and_branches() {
        let mut r = SessionRegistry::default();
        r.set_limits(SessionLimits {
            max_sessions: 2,
            max_branches_per_session: 3,
            default_lease_ms: 1_000,
        });
        let a = r.register("a", 0, 0).unwrap();
        r.register("b", 0, 0).unwrap();
        let err = r.register("c", 0, 0).unwrap_err().to_string();
        assert!(err.contains("admission"), "{err}");
        // re-attach is not a new admission
        assert!(r.register("a", 0, 1).is_ok());
        // root counts against the branch budget: 2 more fit, not 3
        r.allocate_branch(a.id, 1).unwrap();
        r.allocate_branch(a.id, 2).unwrap();
        let err = r.allocate_branch(a.id, 3).unwrap_err().to_string();
        assert!(err.contains("admission"), "{err}");
        // freeing a branch frees its admission slot
        r.remove_branch(a.id, 1);
        assert!(r.allocate_branch(a.id, 3).is_ok());
        // duplicate allocation is an error, not a silent remap
        assert!(r.allocate_branch(a.id, 2).is_err());
    }

    #[test]
    fn namespaces_do_not_collide() {
        let mut r = SessionRegistry::default();
        let a = r.register("a", 0, 0).unwrap();
        let b = r.register("b", 0, 0).unwrap();
        let a3 = r.allocate_branch(a.id, 3).unwrap();
        let b3 = r.allocate_branch(b.id, 3).unwrap();
        assert_ne!(a3, b3, "same user id, distinct global branches");
        assert_eq!(r.resolve(a.id, 3).unwrap(), a3);
        assert_eq!(r.resolve(b.id, 3).unwrap(), b3);
        assert!(r.resolve(a.id, 4).is_err());
        assert!(r.resolve(99, 0).is_err());
        assert_eq!(
            r.branches(a.id).unwrap(),
            vec![(0, a.root_global), (3, a3)]
        );
        assert_eq!(r.census(), vec![(a.id, 2), (b.id, 2)]);
    }

    #[test]
    fn lease_expiry_is_deterministic() {
        let mut r = SessionRegistry::default();
        let a = r.register("a", 1_000, 0).unwrap();
        let b = r.register("b", 5_000, 0).unwrap();
        assert!(r.expired(1_000).is_empty(), "lease boundary is inclusive");
        assert_eq!(r.expired(1_001), vec![a.id]);
        // touching resets the clock
        r.touch(a.id, 1_000);
        assert!(r.expired(2_000).is_empty());
        assert_eq!(r.expired(6_000), vec![a.id, b.id]);
        // teardown returns the namespace's global branches, sorted
        let globals = r.remove_session(a.id).unwrap();
        assert_eq!(globals, vec![a.root_global]);
        assert!(r.remove_session(a.id).is_err());
        assert_eq!(r.len(), 1);
        // the freed name is reusable, under a fresh id
        let a2 = r.register("a", 0, 6_000).unwrap();
        assert!(a2.created);
        assert_ne!(a2.id, a.id);
    }

    #[test]
    fn resolve_or_allocate_covers_restore_path() {
        let mut r = SessionRegistry::default();
        let a = r.register("a", 0, 0).unwrap();
        assert_eq!(r.resolve_or_allocate(a.id, 0).unwrap(), a.root_global);
        let g7 = r.resolve_or_allocate(a.id, 7).unwrap();
        assert_eq!(r.resolve_or_allocate(a.id, 7).unwrap(), g7);
        assert!(r.resolve_or_allocate(99, 0).is_err());
    }
}
