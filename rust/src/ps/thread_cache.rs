//! Two-level worker cache (§4.6): IterStore provides a distinct
//! **thread-level cache** for each worker thread on top of the
//! machine-level cache, to avoid lock contention between the threads
//! of one worker machine.
//!
//! The thread cache is a small, lock-free-by-ownership L1 over the
//! shared machine cache (L2).  Both levels are branch-oblivious and are
//! cleared on branch switch, exactly like [`super::cache::WorkerCache`].
//! Like the L2, the L1 holds worker-private value copies, so the
//! server's copy-on-write branch storage never invalidates it: SSP
//! staleness and branch switches are the only invalidation sources.
//! Both levels rely on single-thread ownership (`&mut` handed to one
//! worker thread at a time) rather than internal locks — the shard
//! locks live server-side, in [`super`].

use std::collections::HashMap;

use crate::comm::{BranchId, Clock};

use super::cache::WorkerCache;
use super::storage::{RowKey, TableId};

/// Per-thread L1 over a shared machine-level L2.
#[derive(Debug, Default)]
pub struct ThreadCache {
    rows: HashMap<(TableId, RowKey), (Vec<f32>, Clock)>,
    current_branch: Option<BranchId>,
    pub hits: u64,
    pub misses: u64,
    /// max rows held (thread caches are small by design)
    capacity: usize,
}

impl ThreadCache {
    pub fn new(capacity: usize) -> Self {
        ThreadCache {
            capacity: capacity.max(1),
            ..Default::default()
        }
    }

    pub fn switch_branch(&mut self, branch: BranchId) {
        if self.current_branch != Some(branch) {
            self.rows.clear();
            self.current_branch = Some(branch);
        }
    }

    /// Two-level read: L1, then L2 (filling L1), then `fetch` (filling
    /// both).  `staleness` applies at both levels.
    pub fn get_or_fetch(
        &mut self,
        l2: &mut WorkerCache,
        table: TableId,
        key: RowKey,
        now: Clock,
        staleness: u32,
        fetch: impl FnOnce() -> Vec<f32>,
    ) -> Vec<f32> {
        if let Some((row, fetched_at)) = self.rows.get(&(table, key)) {
            if now.saturating_sub(*fetched_at) <= staleness as Clock {
                self.hits += 1;
                return row.clone();
            }
            self.rows.remove(&(table, key));
        }
        self.misses += 1;
        let (row, fetched_at) = match l2.get(table, key, now, staleness) {
            Some(r) => (r.to_vec(), now),
            None => {
                let r = fetch();
                l2.put(table, key, r.clone(), now);
                (r, now)
            }
        };
        if self.rows.len() >= self.capacity {
            // trivial eviction: drop an arbitrary entry (thread caches
            // hold the handful of rows a thread's minibatch touches)
            if let Some(k) = self.rows.keys().next().copied() {
                self.rows.remove(&k);
            }
        }
        self.rows.insert((table, key), (row.clone(), fetched_at));
        row
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn l1_hits_avoid_l2_and_fetch() {
        let mut l1 = ThreadCache::new(8);
        let mut l2 = WorkerCache::new();
        l1.switch_branch(1);
        l2.switch_branch(1);
        let mut fetches = 0;
        for _ in 0..5 {
            let row = l1.get_or_fetch(&mut l2, 0, 7, 0, 0, || {
                fetches += 1;
                vec![1.0, 2.0]
            });
            assert_eq!(row, vec![1.0, 2.0]);
        }
        assert_eq!(fetches, 1, "only the first read fetches");
        assert_eq!(l1.hits, 4);
        // L2 was filled by the first miss
        assert!(l2.get(0, 7, 0, 0).is_some());
    }

    #[test]
    fn l2_serves_other_threads_without_refetch() {
        let mut t1 = ThreadCache::new(8);
        let mut t2 = ThreadCache::new(8);
        let mut l2 = WorkerCache::new();
        for c in [&mut t1, &mut t2] {
            c.switch_branch(1);
        }
        l2.switch_branch(1);
        let mut fetches = 0;
        t1.get_or_fetch(&mut l2, 0, 3, 0, 0, || {
            fetches += 1;
            vec![9.0]
        });
        // second thread: L1 miss, L2 hit, no fetch
        t2.get_or_fetch(&mut l2, 0, 3, 0, 0, || {
            fetches += 1;
            vec![0.0]
        });
        assert_eq!(fetches, 1);
        assert_eq!(t2.misses, 1);
    }

    #[test]
    fn staleness_honored_at_both_levels() {
        let mut l1 = ThreadCache::new(8);
        let mut l2 = WorkerCache::new();
        l1.switch_branch(1);
        l2.switch_branch(1);
        let mut fetches = 0;
        l1.get_or_fetch(&mut l2, 0, 1, 10, 1, || {
            fetches += 1;
            vec![1.0]
        });
        // clock 11, staleness 1: still fresh
        l1.get_or_fetch(&mut l2, 0, 1, 11, 1, || {
            fetches += 1;
            vec![2.0]
        });
        assert_eq!(fetches, 1);
        // clock 12: both levels stale → refetch
        let row = l1.get_or_fetch(&mut l2, 0, 1, 12, 1, || {
            fetches += 1;
            vec![3.0]
        });
        assert_eq!(fetches, 2);
        assert_eq!(row, vec![3.0]);
    }

    #[test]
    fn branch_switch_clears_l1() {
        let mut l1 = ThreadCache::new(8);
        let mut l2 = WorkerCache::new();
        l1.switch_branch(1);
        l2.switch_branch(1);
        l1.get_or_fetch(&mut l2, 0, 1, 0, 0, || vec![1.0]);
        assert_eq!(l1.len(), 1);
        l1.switch_branch(2);
        assert!(l1.is_empty());
    }

    #[test]
    fn capacity_bounded() {
        let mut l1 = ThreadCache::new(2);
        let mut l2 = WorkerCache::new();
        l1.switch_branch(1);
        l2.switch_branch(1);
        for k in 0..10u64 {
            l1.get_or_fetch(&mut l2, 0, k, 0, 0, || vec![k as f32]);
        }
        assert!(l1.len() <= 2);
    }
}
