//! Durable branch checkpoints: the crash-consistency half of the
//! branch-snapshot substrate (§4.6 taken to disk).
//!
//! The in-memory copy-on-write snapshots of [`super::storage`] are
//! what make MLtuner's trial-and-error loop cheap, but they die with
//! the process: a crashed coordinator or shard server loses the whole
//! tuning session.  This module extends the snapshot plane to disk so
//! a long-lived tune survives process death:
//!
//! * **Segment files** — one file per engine shard, holding every row
//!   of one branch that shard owns: parameter data **and** optimizer
//!   slot state **and** the per-row step counter, because a branch
//!   snapshot is only consistent if all training state travels
//!   together (the same invariant the in-memory fork keeps).  Floats
//!   are serialized as their IEEE-754 **bit patterns** — the
//!   [`crate::comm::wire`] codec discipline — so a restored run is
//!   bit-identical to the original, NaN payloads, infinities and `-0.0`
//!   included.
//! * **Checksums** — every segment carries a trailing FNV-1a 64 digest
//!   over its entire contents, and decoding is strict: a truncated,
//!   bit-flipped, or mislabeled segment is a typed error, never a
//!   panic or a silent partial restore.  Restore decodes and verifies
//!   *everything* in memory first and only then swaps the branch in
//!   ([`ParamServer::replace_branch_rows`]), so a failed restore
//!   leaves the engine untouched.
//! * **Shard ranges** — segment files are named by the *global* shard
//!   range they cover plus the engine-local shard index, so each shard
//!   server of a distributed deployment dumps and restores exactly its
//!   own range (`b<branch>-r<begin>-<end>-s<idx>.seg`); the
//!   single-process engine is simply the range `0..num_shards`.  A
//!   restore into a different topology fails closed instead of
//!   silently dropping rows.
//!
//! The dump runs one thread per shard, each under that shard's *read*
//! lock only (rows are cloned out and serialized outside the lock), so
//! concurrent readers are unaffected and writers wait at most one
//! shard-sized critical section — the `apply_batch` hot path of other
//! branches is never blocked for the duration of the file writes.
//!
//! Layered on top, [`crate::tuner::session`] stores the tuner-session
//! half (message journal, recorder, manifest) next to these segments;
//! [`StoreCheckpoint`] and [`BranchCkpt`] are the metadata bridge
//! between the two planes.

use std::fs;
use std::io::Write as _;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::comm::{BranchId, BranchType};

use super::storage::{Entry, RowKey, TableId};
use super::ParamServer;

/// Segment file magic: "MLTC" (MLtuner checkpoint).
const MAGIC: &[u8; 4] = b"MLTC";
/// Segment format version.
const VERSION: u32 = 1;

/// One parameter row as it travels through a checkpoint: data,
/// optimizer slots and step counter — the full [`Entry`], decoupled
/// from the engine's `Arc` sharing.
#[derive(Debug, Clone, PartialEq)]
pub struct RowRecord {
    pub table: TableId,
    pub key: RowKey,
    pub step: u64,
    pub data: Vec<f32>,
    pub slots: Vec<Vec<f32>>,
}

impl RowRecord {
    fn into_entry(self) -> (TableId, RowKey, Entry) {
        (
            self.table,
            self.key,
            Entry {
                data: self.data,
                slots: self.slots,
                step: self.step,
            },
        )
    }
}

/// Metadata of one written segment file, recorded in the checkpoint
/// manifest (and returned over the wire by a shard server's
/// `CheckpointBranch` reply).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SegmentMeta {
    /// File name inside the checkpoint step directory.
    pub file: String,
    pub branch: BranchId,
    /// Global shard range the writing engine covers.
    pub range_begin: usize,
    pub range_end: usize,
    /// Engine-local shard index within the range.
    pub local_shard: usize,
    pub rows: u64,
    pub bytes: u64,
    /// FNV-1a 64 digest over the whole file.
    pub checksum: u64,
}

/// Per-branch metadata serialized alongside the row segments: enough
/// for a training system to rebuild its branch bookkeeping (tunable
/// setting, branch type, clocks run) before restoring the rows.
#[derive(Debug, Clone, PartialEq)]
pub struct BranchCkpt {
    pub id: BranchId,
    pub branch_type: BranchType,
    pub clocks_run: u64,
    /// The branch's tunable setting values (f64, bit-exact in the
    /// manifest via bit-pattern encoding).
    pub tunable: Vec<f64>,
}

/// The parameter-store half of a session checkpoint: which branches
/// were live (with their metadata) and which segment files hold their
/// rows.  `None` at the session level means the training system has no
/// durable store and resume re-executes the message journal instead.
#[derive(Debug, Clone, PartialEq)]
pub struct StoreCheckpoint {
    /// Optimizer the store was built with; restore refuses a mismatch
    /// (slot layouts differ between rules).
    pub optimizer: String,
    /// Live branches, sorted by id.
    pub branches: Vec<BranchCkpt>,
    pub segments: Vec<SegmentMeta>,
}

/// FNV-1a 64 over a byte slice — the checkpoint plane's digest (cheap,
/// dependency-free, and plenty for corruption *detection*; this is not
/// an authentication code).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// A u64 as fixed-width lowercase hex (manifest/wire encoding for
/// values that exceed JSON's 2^53 exact-integer range, e.g. checksums
/// and f64 bit patterns).
pub fn hex_u64(v: u64) -> String {
    format!("{v:016x}")
}

/// Parse [`hex_u64`] output (any-width hex accepted).
pub fn parse_hex_u64(s: &str) -> Result<u64> {
    u64::from_str_radix(s, 16).map_err(|e| anyhow!("bad hex u64 {s:?}: {e}"))
}

/// Deterministic segment file name for one branch / range / shard.
pub fn segment_file_name(
    branch: BranchId,
    range_begin: usize,
    range_end: usize,
    local_shard: usize,
) -> String {
    format!("b{branch}-r{range_begin}-{range_end}-s{local_shard}.seg")
}

// ---------------------------------------------------------------------------
// Binary codec (little-endian, bit-pattern floats, trailing checksum)
// ---------------------------------------------------------------------------

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32s(out: &mut Vec<u8>, vals: &[f32]) {
    put_u32(out, vals.len() as u32);
    for v in vals {
        put_u32(out, v.to_bits());
    }
}

/// Strict little-endian reader over a segment payload; every read
/// checks bounds, so truncation surfaces as an error, never a panic.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| anyhow!("segment truncated reading {what}"))?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        let b = self.take(8, what)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    fn f32s(&mut self, what: &str) -> Result<Vec<f32>> {
        let n = self.u32(what)? as usize;
        // bounds check BEFORE allocating: a corrupt length field must
        // not drive a huge allocation
        let len = n.checked_mul(4).ok_or_else(|| anyhow!("bad {what} length"))?;
        let bytes = self.take(len, what)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes([c[0], c[1], c[2], c[3]])))
            .collect())
    }
}

/// Encode one shard's rows of a branch as a self-verifying segment.
/// Rows are sorted by (table, key) so segment bytes are deterministic
/// for a given branch state.
pub fn encode_segment(
    branch: BranchId,
    range_begin: usize,
    range_end: usize,
    local_shard: usize,
    rows: &mut Vec<RowRecord>,
) -> Vec<u8> {
    rows.sort_unstable_by_key(|r| (r.table, r.key));
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    put_u32(&mut out, VERSION);
    put_u32(&mut out, branch);
    put_u64(&mut out, range_begin as u64);
    put_u64(&mut out, range_end as u64);
    put_u64(&mut out, local_shard as u64);
    put_u64(&mut out, rows.len() as u64);
    for r in rows.iter() {
        put_u32(&mut out, r.table);
        put_u64(&mut out, r.key);
        put_u64(&mut out, r.step);
        put_f32s(&mut out, &r.data);
        put_u32(&mut out, r.slots.len() as u32);
        for slot in &r.slots {
            put_f32s(&mut out, slot);
        }
    }
    let digest = fnv1a(&out);
    put_u64(&mut out, digest);
    out
}

/// Decode and fully verify one segment.  Every field is checked
/// against the caller's expectation (branch, range, shard) and the
/// trailing checksum against the bytes; any mismatch, truncation or
/// bit flip is a typed error.
pub fn decode_segment(
    bytes: &[u8],
    branch: BranchId,
    range_begin: usize,
    range_end: usize,
    local_shard: usize,
) -> Result<Vec<RowRecord>> {
    if bytes.len() < MAGIC.len() + 8 {
        bail!("segment truncated: {} bytes", bytes.len());
    }
    let (body, tail) = bytes.split_at(bytes.len() - 8);
    // lint:allow(panic-path): split_at of a length-checked slice makes
    // the tail exactly 8 bytes; the conversion cannot fail
    let stored = u64::from_le_bytes(tail.try_into().expect("8-byte tail"));
    let computed = fnv1a(body);
    if stored != computed {
        bail!(
            "segment checksum mismatch: stored {}, computed {} — corrupted or truncated file",
            hex_u64(stored),
            hex_u64(computed)
        );
    }
    let mut r = Reader { buf: body, pos: 0 };
    if r.take(4, "magic")? != MAGIC {
        bail!("not a checkpoint segment (bad magic)");
    }
    let version = r.u32("version")?;
    if version != VERSION {
        bail!("unsupported segment version {version} (want {VERSION})");
    }
    let got_branch = r.u32("branch")?;
    let got_begin = r.u64("range begin")? as usize;
    let got_end = r.u64("range end")? as usize;
    let got_shard = r.u64("local shard")? as usize;
    if got_branch != branch || got_begin != range_begin || got_end != range_end {
        bail!(
            "segment labeled branch {got_branch} range {got_begin}..{got_end}, \
             expected branch {branch} range {range_begin}..{range_end}"
        );
    }
    if got_shard != local_shard {
        bail!("segment labeled local shard {got_shard}, expected {local_shard}");
    }
    let count = r.u64("row count")?;
    let mut rows = Vec::new();
    for _ in 0..count {
        let table = r.u32("table")?;
        let key = r.u64("key")?;
        let step = r.u64("step")?;
        let data = r.f32s("row data")?;
        let nslots = r.u32("slot count")? as usize;
        let mut slots = Vec::with_capacity(nslots.min(16));
        for _ in 0..nslots {
            slots.push(r.f32s("slot data")?);
        }
        rows.push(RowRecord {
            table,
            key,
            step,
            data,
            slots,
        });
    }
    if r.pos != r.buf.len() {
        bail!("segment has {} trailing bytes after row {count}", r.buf.len() - r.pos);
    }
    Ok(rows)
}

// ---------------------------------------------------------------------------
// Local-engine dump and restore
// ---------------------------------------------------------------------------

/// Best-effort fsync of a directory — on Linux a rename is only
/// durable once the containing directory is synced, and the
/// checkpoint commit protocol depends on rename ordering (the
/// `LATEST` pointer must hit disk before the previous step is
/// pruned).  Errors are ignored for filesystems that reject directory
/// fsync; on those, crash consistency degrades to the filesystem's
/// own ordering guarantees.
pub(crate) fn fsync_dir(dir: &Path) {
    if let Ok(f) = fs::File::open(dir) {
        let _ = f.sync_all();
    }
}

/// Write `payload` to `path` atomically and durably: a temp file in
/// the same directory is written, fsynced, renamed into place, and
/// the directory is fsynced — so readers never observe a half-written
/// file and the rename is on disk before anything that depends on it.
pub(crate) fn write_atomic(path: &Path, payload: &[u8]) -> Result<()> {
    let tmp = path.with_extension("tmp");
    {
        let mut f = fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(payload)?;
        f.sync_all()?;
    }
    fs::rename(&tmp, path)
        .with_context(|| format!("renaming into {}", path.display()))?;
    if let Some(parent) = path.parent() {
        fsync_dir(parent);
    }
    Ok(())
}

/// Clone one shard's rows of `branch` out of the engine under the
/// shard's read lock (held only for the clone, not for serialization
/// or file IO).
fn dump_shard(ps: &ParamServer, sid: usize, branch: BranchId) -> Vec<RowRecord> {
    let st = super::read_shard(&ps.shards[sid], &ps.counters);
    let mut rows = Vec::new();
    st.shard.for_each_row(branch, |table, key, e| {
        rows.push(RowRecord {
            table,
            key,
            step: e.step,
            data: e.data.clone(),
            slots: e.slots.clone(),
        });
    });
    rows
}

/// Dump `branch` from a local engine covering global shards
/// `range_begin..range_end` into per-shard segment files under `dir`.
/// One thread per shard: each clones its rows under the shard's read
/// lock, then encodes and writes outside the lock.  Returns the
/// segment metadata for the manifest.
pub fn checkpoint_range(
    ps: &ParamServer,
    branch: BranchId,
    range_begin: usize,
    range_end: usize,
    dir: &Path,
) -> Result<Vec<SegmentMeta>> {
    let n = ps.num_shards();
    if range_end.saturating_sub(range_begin) != n {
        bail!(
            "engine has {n} shards but was asked to checkpoint range \
             {range_begin}..{range_end}"
        );
    }
    if !ps.branch_exists(branch) {
        bail!("branch {branch} does not exist");
    }
    fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
    let dump_one = |sid: usize| -> Result<SegmentMeta> {
        let mut rows = dump_shard(ps, sid, branch);
        let payload = encode_segment(branch, range_begin, range_end, sid, &mut rows);
        let file = segment_file_name(branch, range_begin, range_end, sid);
        write_atomic(&dir.join(&file), &payload)?;
        Ok(SegmentMeta {
            file,
            branch,
            range_begin,
            range_end,
            local_shard: sid,
            rows: rows.len() as u64,
            bytes: payload.len() as u64,
            checksum: fnv1a(&payload),
        })
    };
    if n > 1 {
        let dump_one = &dump_one;
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..n)
                .map(|sid| scope.spawn(move || dump_one(sid)))
                .collect();
            handles
                .into_iter()
                // lint:allow(panic-path): join only errs when the
                // worker panicked; re-raising that panic is correct
                .map(|h| h.join().expect("checkpoint dump worker panicked"))
                .collect()
        })
    } else {
        (0..n).map(dump_one).collect()
    }
}

/// Read and fully verify every segment of `branch` for the range
/// `range_begin..range_end` under `dir`.  All-or-nothing: any missing
/// file, truncation, checksum mismatch or label mismatch is an error
/// and nothing is returned.
pub fn load_range(
    branch: BranchId,
    range_begin: usize,
    range_end: usize,
    dir: &Path,
) -> Result<Vec<RowRecord>> {
    let shards = range_end
        .checked_sub(range_begin)
        .filter(|&c| c > 0)
        .ok_or_else(|| anyhow!("bad shard range {range_begin}..{range_end}"))?;
    let mut rows = Vec::new();
    for sid in 0..shards {
        let file = dir.join(segment_file_name(branch, range_begin, range_end, sid));
        let bytes = fs::read(&file)
            .with_context(|| format!("reading checkpoint segment {}", file.display()))?;
        rows.extend(decode_segment(&bytes, branch, range_begin, range_end, sid)?);
    }
    Ok(rows)
}

/// Restore `branch` into a local engine from the segment files under
/// `dir`.  Fail-closed: every segment is decoded and verified in
/// memory first; only then is the branch swapped in wholesale, so a
/// corrupted checkpoint leaves the engine state unchanged.  Returns
/// the number of rows restored.
pub fn restore_range(
    ps: &ParamServer,
    branch: BranchId,
    range_begin: usize,
    range_end: usize,
    dir: &Path,
) -> Result<usize> {
    let n = ps.num_shards();
    if range_end.saturating_sub(range_begin) != n {
        bail!(
            "engine has {n} shards but the restore names range {range_begin}..{range_end} \
             — checkpoint topology must match the serving topology"
        );
    }
    let rows = load_range(branch, range_begin, range_end, dir)?;
    let entries: Vec<(TableId, RowKey, Entry)> =
        rows.into_iter().map(RowRecord::into_entry).collect();
    Ok(ps.replace_branch_rows(branch, entries))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{Hyper, Optimizer, OptimizerKind};
    use std::path::PathBuf;

    struct TempDir(PathBuf);

    impl TempDir {
        fn new(tag: &str) -> Self {
            let p = std::env::temp_dir().join(format!(
                "mltuner-ckpt-unit-{tag}-{}",
                std::process::id()
            ));
            let _ = fs::remove_dir_all(&p);
            fs::create_dir_all(&p).unwrap();
            TempDir(p)
        }

        fn path(&self) -> &Path {
            &self.0
        }
    }

    impl Drop for TempDir {
        fn drop(&mut self) {
            let _ = fs::remove_dir_all(&self.0);
        }
    }

    fn weird_rows() -> Vec<RowRecord> {
        vec![
            RowRecord {
                table: 1,
                key: 7,
                step: 3,
                data: vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -0.0, 1.0e-45],
                slots: vec![vec![0.5, f32::from_bits(0x7fc0_dead)], vec![]],
            },
            RowRecord {
                table: 0,
                key: u64::MAX >> 12,
                step: 0,
                data: vec![],
                slots: vec![],
            },
        ]
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn segment_roundtrip_is_bit_exact() {
        let mut rows = weird_rows();
        let payload = encode_segment(5, 2, 6, 1, &mut rows);
        let back = decode_segment(&payload, 5, 2, 6, 1).unwrap();
        assert_eq!(back.len(), rows.len());
        // encode sorts by (table, key); rows is sorted in place
        for (a, b) in rows.iter().zip(&back) {
            assert_eq!((a.table, a.key, a.step), (b.table, b.key, b.step));
            assert_eq!(bits(&a.data), bits(&b.data));
            assert_eq!(a.slots.len(), b.slots.len());
            for (sa, sb) in a.slots.iter().zip(&b.slots) {
                assert_eq!(bits(sa), bits(sb));
            }
        }
    }

    #[test]
    fn decode_rejects_corruption_and_mislabeling() {
        let mut rows = weird_rows();
        let payload = encode_segment(5, 2, 6, 1, &mut rows);
        // every single-byte flip must be caught by the checksum
        for pos in [0usize, 4, 12, payload.len() / 2, payload.len() - 1] {
            let mut bad = payload.clone();
            bad[pos] ^= 0x40;
            assert!(decode_segment(&bad, 5, 2, 6, 1).is_err(), "flip at {pos}");
        }
        // every truncation point fails cleanly
        for cut in [0usize, 3, 8, payload.len() / 2, payload.len() - 1] {
            assert!(decode_segment(&payload[..cut], 5, 2, 6, 1).is_err(), "cut at {cut}");
        }
        // label mismatches fail even with a valid checksum
        assert!(decode_segment(&payload, 4, 2, 6, 1).is_err(), "wrong branch");
        assert!(decode_segment(&payload, 5, 0, 4, 1).is_err(), "wrong range");
        assert!(decode_segment(&payload, 5, 2, 6, 0).is_err(), "wrong shard");
    }

    #[test]
    fn local_checkpoint_restore_roundtrip() {
        let tmp = TempDir::new("roundtrip");
        let ps = ParamServer::new(3, Optimizer::new(OptimizerKind::Adam));
        for k in 0..32u64 {
            ps.insert_row(0, 0, k, vec![k as f32, -(k as f32)]);
        }
        ps.fork_branch(1, 0).unwrap();
        let h = Hyper { lr: 0.1, momentum: 0.9 };
        for k in 0..16u64 {
            ps.apply_update(1, 0, k, &[1.0, -1.0], h, None).unwrap();
        }
        let metas = checkpoint_range(&ps, 1, 0, 3, tmp.path()).unwrap();
        assert_eq!(metas.len(), 3);
        assert_eq!(metas.iter().map(|m| m.rows).sum::<u64>(), 32);

        // restore into a fresh engine with the same topology
        let fresh = ParamServer::new(3, Optimizer::new(OptimizerKind::Adam));
        fresh.ensure_branch(0);
        let restored = restore_range(&fresh, 1, 0, 3, tmp.path()).unwrap();
        assert_eq!(restored, 32);
        assert_eq!(fresh.branch_row_count(1), 32);
        for k in 0..32u64 {
            let a = ps.read_row(1, 0, k).unwrap();
            let b = fresh.read_row(1, 0, k).unwrap();
            assert_eq!(bits(&a), bits(&b), "row {k}");
            // optimizer slots travel too
            let sa = ps.with_row(1, 0, k, |e| (e.slots.clone(), e.step)).unwrap();
            let sb = fresh.with_row(1, 0, k, |e| (e.slots.clone(), e.step)).unwrap();
            assert_eq!(sa.1, sb.1);
            for (x, y) in sa.0.iter().zip(&sb.0) {
                assert_eq!(bits(x), bits(y));
            }
        }
    }

    #[test]
    fn restore_into_wrong_topology_fails_closed() {
        let tmp = TempDir::new("topology");
        let ps = ParamServer::new(4, Optimizer::new(OptimizerKind::Sgd));
        ps.insert_row(0, 0, 0, vec![1.0]);
        checkpoint_range(&ps, 0, 0, 4, tmp.path()).unwrap();
        let other = ParamServer::new(3, Optimizer::new(OptimizerKind::Sgd));
        other.ensure_branch(0);
        let before = other.read_row(0, 0, 0);
        let err = restore_range(&other, 0, 0, 4, tmp.path()).unwrap_err();
        assert!(err.to_string().contains("topology"), "{err}");
        assert_eq!(other.read_row(0, 0, 0), before, "state must be unchanged");
    }

    #[test]
    fn hex_helpers_roundtrip() {
        for v in [0u64, 1, u64::MAX, 0xdead_beef_0123_4567] {
            assert_eq!(parse_hex_u64(&hex_u64(v)).unwrap(), v);
        }
        assert!(parse_hex_u64("not hex").is_err());
    }
}
