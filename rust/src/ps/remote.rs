//! Distributed parameter server over real sockets (§4.5, §4.6).
//!
//! The concurrent sharded engine of [`super`] becomes a multi-process
//! system by splitting the *global* shard space `0..N` across shard
//! servers:
//!
//! * [`ShardServer`] — one process serving a contiguous global shard
//!   range `begin..end`.  Internally it is an unmodified
//!   [`ParamServer`] with `end - begin` local shards: the engine, its
//!   lock hierarchy, COW branch storage, and per-shard pool arenas are
//!   reused as-is; only the request framing is new.  Connections are
//!   served by the readiness-driven event loop of [`crate::comm::poll`]
//!   — one poll thread owning every socket plus a bounded worker pool
//!   executing decoded requests against the `&self` engine — so the
//!   server's thread count is O(worker pool), not O(connections), and
//!   a failed `accept()` or a garbage connection never takes the
//!   process down.  Branch ops arrive replicated from the client, so
//!   every server holds the same branch index over its own rows and
//!   performs its own last-owner accounting — a freed row's buffers
//!   return to the pool of the one server (and shard) that owns it.
//! * [`RemoteParamServer`] — the client half, implementing the same
//!   `&self` [`ParamStore`] interface as the local server.  Row ops
//!   route with the *identical* [`route_shard`] mix over the global
//!   shard count, then go to the server owning that shard; a batch —
//!   update (`ApplyBatch`) and read (`ReadRows`) alike — is routed
//!   once, grouped per shard server (exactly as the local engine
//!   groups per shard), and sent as **one** RPC per server, so a
//!   data-parallel gather phase costs O(shard servers × workers) RPCs
//!   per clock instead of O(touched rows).  `ForkBranch` /
//!   `FreeBranch` broadcast to every server, which is what replicates
//!   the branch index across processes.  Each server connection is a
//!   small pool (`MAX_IDLE_CONNS_PER_SERVER` idle cap): every
//!   in-flight RPC leases its own socket, so the `num_workers`
//!   clock-phase threads hit the servers concurrently instead of
//!   convoying on one mutex-serialized connection.
//!
//! Because row payloads cross the wire as f32 *bit patterns* (see
//! [`crate::comm::wire`] and [`crate::comm::binwire`]) and the
//! optimizer rule runs server-side on the same engine, a training run
//! against a set of shard servers is bit-identical to the same run
//! against a single in-process server — the distributed CI leg asserts
//! exactly that, under both the JSON and the binary codec.
//!
//! **Codec negotiation**: the `Hello` handshake always rides as JSON.
//! A client built with `--framing binary` requests the binary codec in
//! its `Hello`; a server grants it only when it too runs
//! `--framing binary`, and the client refuses to proceed unless
//! *every* server granted — a mixed-framing cluster is rejected at
//! connect time with a typed error instead of desynchronizing later.
//! JSON-only peers on either side keep working unchanged (the codec
//! field is absent from their hellos, which means JSON).
//!
//! **Multi-tenancy**: a `Hello` carrying a [`SessionHello`] registers
//! (or re-attaches) a named session on each server, subject to the
//! server's admission limits ([`ServeOpts`]).  Every branch-scoped
//! frame the client sends afterwards is stamped with the granted
//! per-server session id, and the server resolves the client's branch
//! ids inside that session's namespace — two tenants can both "fork
//! branch 1" on one cluster without colliding.  Leases are renewed by
//! any stamped traffic; a SIGKILLed client's namespace is garbage-
//! collected (branches freed) once its lease expires.  Teardown is
//! graceful via `EndSession` (sent best-effort on client drop).  The
//! session-scoped `ListBranches` census is what backs the remote
//! store's `live_branches`/`branch_row_count`, so attaching to a
//! shared cluster can only ever see — and free — its own branches.
//! Durable checkpoints of a *named* session are keyed by the
//! session's server-side branch ids, so they restore into the same
//! live session; cross-run portable checkpoints belong to the default
//! namespace (session 0), whose ids are stable.
//!
//! Topology: one coordinator process (the tuner + training system)
//! connects to S shard servers, each started as
//! `mltuner serve --shards a..b --listen ADDR --optimizer K`.
//! The handshake (`Hello`) reports each server's range; the client
//! verifies the ranges tile `0..N` with no gaps or overlaps and that
//! all servers were built with the same optimizer.

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use crate::comm::binwire;
use crate::comm::poll::CoreMetrics;
use crate::comm::socket::{Conn, Framing, PsListener, SocketSpec};
use crate::comm::wire::{
    decode_ps_reply, decode_ps_request, encode_ps_reply, encode_ps_request, PsReply, PsRequest,
    SessionHello, WireCodec,
};
use crate::comm::{BranchId, SessionId};
use crate::optim::{Hyper, Optimizer, OptimizerKind};
use crate::stats::{merge_cluster, ClusterView, ServerDelta, SessionStats, Snapshot, TrialEvent};

use super::checkpoint::{self, SegmentMeta};
use super::session::SessionLimits;
use super::storage::{RowKey, TableId};
use super::{ParamServer, ParamStore, route_shard, RowData};

/// A contiguous range `begin..end` of global shard ids.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardRange {
    pub begin: usize,
    pub end: usize,
}

impl ShardRange {
    /// Parse the CLI form `a..b` (half-open, `b > a`).
    pub fn parse(s: &str) -> Result<ShardRange> {
        let (a, b) = s
            .split_once("..")
            .ok_or_else(|| anyhow!("bad shard range {s:?} (want a..b)"))?;
        let begin: usize = a.trim().parse().with_context(|| format!("bad shard range {s:?}"))?;
        let end: usize = b.trim().parse().with_context(|| format!("bad shard range {s:?}"))?;
        if end <= begin {
            bail!("bad shard range {s:?}: must be non-empty and ascending");
        }
        Ok(ShardRange { begin, end })
    }

    pub fn count(&self) -> usize {
        self.end - self.begin
    }
}

impl fmt::Display for ShardRange {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}..{}", self.begin, self.end)
    }
}

/// Cap on tuner trial-progress events a shard server retains **per
/// session** for the observability stream.  The map is keyed
/// `(session, episode, trial)` with latest-event-wins, so the cap
/// only evicts a session's own oldest trials — one tenant's churn
/// can never evict another tenant's dashboard rows.
const MAX_TRACKED_TRIALS: usize = 64;

/// Multi-tenancy knobs for a shard server: session admission limits,
/// the default lease, and the optional per-session data-plane share.
#[derive(Debug, Clone, Copy)]
pub struct ServeOpts {
    /// Admission cap on concurrently registered named sessions.
    pub max_sessions: usize,
    /// Admission cap on live branches per session namespace.
    pub max_branches_per_session: usize,
    /// Lease granted to sessions that do not request one, ms.
    pub default_lease_ms: u64,
    /// `Some(share)` installs the per-session token bucket at `share`
    /// rows/sec on the event loop; `None` (the default) leaves the
    /// dispatch path untouched.
    pub session_rows_per_sec: Option<u64>,
}

impl Default for ServeOpts {
    fn default() -> Self {
        let limits = SessionLimits::default();
        ServeOpts {
            max_sessions: limits.max_sessions,
            max_branches_per_session: limits.max_branches_per_session,
            default_lease_ms: limits.default_lease_ms,
            session_rows_per_sec: None,
        }
    }
}

/// One executed frame: the encoded reply plus everything the
/// transport layer needs to know about it (shutdown/subscribe
/// control effects, and the session + row cost feeding the fairness
/// plane's post-paid token bucket).
struct FrameOutcome {
    reply: Vec<u8>,
    shutdown: bool,
    subscribe: Option<u64>,
    session: Option<SessionId>,
    cost_rows: u64,
}

/// One shard-server process: the concurrent engine behind a socket.
pub struct ShardServer {
    ps: ParamServer,
    range: ShardRange,
    optimizer: OptimizerKind,
    framing: Framing,
    /// Transport counters, filled by the event loop and overlaid on
    /// the engine's [`crate::stats::Snapshot`] wire plane when
    /// answering a stats probe or pushing a delta.
    metrics: CoreMetrics,
    /// Data-plane frames executed per codec (the event loop counts
    /// bytes; the codec split is only known after dispatch, here).
    frames_json: AtomicU64,
    frames_bin: AtomicU64,
    /// Latest tuner trial-progress events, keyed
    /// `(session, episode, trial)`, bounded at [`MAX_TRACKED_TRIALS`]
    /// per session.  Replicated onto every server by the
    /// coordinator's `PublishProgress` broadcast so any single
    /// subscriber sees trial progress next to shard counters.
    trials: Mutex<BTreeMap<(SessionId, u32, u32), TrialEvent>>,
    /// Cumulative per-session row traffic `(rows_applied, rows_read)`
    /// — the counters behind [`SessionStats`].  Entries are never
    /// removed, so a torn-down session's history stays monotonic
    /// across stats frames.
    session_traffic: Mutex<BTreeMap<SessionId, (u64, u64)>>,
    /// Fairness plane handed to the event loop when a per-session
    /// rows/sec share is configured.
    #[cfg(unix)]
    throttle: Option<crate::comm::poll::SessionThrottle>,
    /// Monotonic lease-clock anchor; sessions age relative to it.
    epoch: std::time::Instant,
    #[cfg(not(unix))]
    shutdown: std::sync::atomic::AtomicBool,
}

impl ShardServer {
    pub fn new(range: ShardRange, optimizer: OptimizerKind, framing: Framing) -> Self {
        Self::with_opts(range, optimizer, framing, ServeOpts::default())
    }

    /// [`ShardServer::new`] with explicit multi-tenancy options.
    pub fn with_opts(
        range: ShardRange,
        optimizer: OptimizerKind,
        framing: Framing,
        opts: ServeOpts,
    ) -> Self {
        let ps = ParamServer::new(range.count(), Optimizer::new(optimizer));
        // The root branch exists on every server even before (or
        // without) any of its rows landing here: replicated fork ops
        // must find their parent on servers whose shard subset holds
        // zero rows of it.
        ps.ensure_branch(0);
        ps.set_session_limits(SessionLimits {
            max_sessions: opts.max_sessions,
            max_branches_per_session: opts.max_branches_per_session,
            default_lease_ms: opts.default_lease_ms,
        });
        ShardServer {
            ps,
            range,
            optimizer,
            framing,
            metrics: CoreMetrics::default(),
            frames_json: AtomicU64::new(0),
            frames_bin: AtomicU64::new(0),
            trials: Mutex::new(BTreeMap::new()),
            session_traffic: Mutex::new(BTreeMap::new()),
            #[cfg(unix)]
            throttle: opts.session_rows_per_sec.map(crate::comm::poll::SessionThrottle::new),
            epoch: std::time::Instant::now(),
            #[cfg(not(unix))]
            shutdown: std::sync::atomic::AtomicBool::new(false),
        }
    }

    /// Milliseconds since this server started — the lease clock every
    /// session-registry call is stamped with.
    fn now_ms(&self) -> u64 {
        u64::try_from(self.epoch.elapsed().as_millis()).unwrap_or(u64::MAX)
    }

    /// The engine (test/bench introspection).
    pub fn ps(&self) -> &ParamServer {
        &self.ps
    }

    pub fn range(&self) -> ShardRange {
        self.range
    }

    pub fn framing(&self) -> Framing {
        self.framing
    }

    /// Transport counters (test/bench introspection: the bounded-pool
    /// acceptance test reads `peak_conns` and `workers` here).
    pub fn metrics(&self) -> &CoreMetrics {
        &self.metrics
    }

    /// One cumulative [`ServerDelta`]: the engine's snapshot overlaid
    /// with transport counters the engine cannot know (it serves
    /// calls, not frames), per-shard row throughput re-addressed from
    /// local shard indices to **global** shard ids, the event loop's
    /// RPC service-time histogram, the branch census, and the latest
    /// tuner trial events.  Every counter is a relaxed-atomic load of
    /// a cumulative total — never a diff — which is what makes the
    /// client's monotonic merge (latest frame wins) correct.
    pub fn delta(&self) -> ServerDelta {
        // Opportunistic lease GC: every stats probe/tick reclaims
        // namespaces whose client stopped heartbeating (a no-op while
        // no named sessions exist, so default-namespace runs see no
        // behavioral change).
        self.ps.sweep_expired_sessions(self.now_ms());
        let snap = self.ps.snapshot();
        let mut shards = self.ps.shard_rows();
        for s in &mut shards {
            s.shard += self.range.begin as u64;
        }
        let branches = self
            .ps
            .live_branches()
            .into_iter()
            .map(|b| (b, self.ps.branch_row_count(b)))
            .collect();
        let trials = self
            .trials
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .values()
            .copied()
            .collect();
        let mut wire = snap.wire;
        wire.bytes_tx = self.metrics.bytes_tx.load(Ordering::Relaxed);
        wire.bytes_rx = self.metrics.bytes_rx.load(Ordering::Relaxed);
        wire.frames_json = self.frames_json.load(Ordering::Relaxed);
        wire.frames_bin = self.frames_bin.load(Ordering::Relaxed);
        ServerDelta {
            server: snap.server,
            store: snap.store,
            pool: snap.pool,
            wire,
            shards,
            rpc_hist: self.metrics.rpc_hist.snapshot(),
            branches,
            trials,
            sessions: self.session_census(),
            ..ServerDelta::default()
        }
    }

    /// Per-session census for the stats stream: live branch counts
    /// from the registry, cumulative row traffic from the dispatch
    /// path, and throttle deferrals from the fairness plane.
    fn session_census(&self) -> Vec<SessionStats> {
        fn entry(map: &mut BTreeMap<SessionId, SessionStats>, s: SessionId) -> &mut SessionStats {
            map.entry(s).or_insert_with(|| SessionStats {
                session: s,
                ..SessionStats::default()
            })
        }
        let mut map = BTreeMap::new();
        for (s, live) in self.ps.session_live_branches() {
            entry(&mut map, s).live_branches = live;
        }
        {
            let traffic = self.session_traffic.lock().unwrap_or_else(|e| e.into_inner());
            for (&s, &(applied, read)) in traffic.iter() {
                let e = entry(&mut map, s);
                e.rows_applied = applied;
                e.rows_read = read;
            }
        }
        #[cfg(unix)]
        if let Some(t) = &self.throttle {
            for (s, deferrals) in t.deferrals() {
                entry(&mut map, s).deferrals = deferrals;
            }
        }
        map.into_values().collect()
    }

    /// Retain one trial-progress event for the stats stream
    /// (latest-wins per `(session, episode, trial)`, the session's
    /// oldest key evicted at the per-session cap).
    fn record_trial(&self, event: TrialEvent) {
        let mut trials = self.trials.lock().unwrap_or_else(|e| e.into_inner());
        let key = (event.session, event.episode, event.trial);
        if !trials.contains_key(&key) {
            let s = event.session;
            let in_session = trials.range((s, 0, 0)..=(s, u32::MAX, u32::MAX)).count();
            if in_session >= MAX_TRACKED_TRIALS {
                let oldest = trials
                    .range((s, 0, 0)..=(s, u32::MAX, u32::MAX))
                    .next()
                    .map(|(k, _)| *k);
                if let Some(k) = oldest {
                    trials.remove(&k);
                }
            }
        }
        trials.insert(key, event);
    }

    /// Accumulate one request's row traffic onto its session's
    /// cumulative counters (no-op for zero-cost control frames).
    fn record_traffic(&self, session: SessionId, applied: u64, read: u64) {
        if applied == 0 && read == 0 {
            return;
        }
        let mut traffic = self.session_traffic.lock().unwrap_or_else(|e| e.into_inner());
        let e = traffic.entry(session).or_insert((0, 0));
        e.0 = e.0.saturating_add(applied);
        e.1 = e.1.saturating_add(read);
    }

    /// Serve connections until a `Shutdown` request arrives: the
    /// readiness-driven event loop of [`crate::comm::poll`] — one poll
    /// thread owning all sockets, a bounded worker pool executing
    /// requests.  Thread count is O(worker pool), not O(connections).
    #[cfg(unix)]
    pub fn serve(&self, listener: PsListener) -> Result<()> {
        crate::comm::poll::ServerCore {
            listener,
            framing: self.framing,
            handler: self,
            metrics: &self.metrics,
            workers: crate::comm::poll::default_workers(),
            throttle: self.throttle.as_ref(),
        }
        .run()
    }

    /// Blocking fallback for platforms without a poller: the old
    /// thread-per-connection model, compiled only off unix.
    #[cfg(not(unix))]
    pub fn serve(&self, listener: PsListener) -> Result<()> {
        let local = listener.local_spec()?;
        std::thread::scope(|scope| -> Result<()> {
            loop {
                if self.shutdown.load(Ordering::SeqCst) {
                    return Ok(());
                }
                let conn = match listener.accept(self.framing) {
                    Ok(c) => c,
                    Err(e) => {
                        if self.shutdown.load(Ordering::SeqCst) {
                            return Ok(());
                        }
                        self.metrics.accept_errors.fetch_add(1, Ordering::Relaxed);
                        eprintln!("mltuner serve: accept error (retrying): {e}");
                        std::thread::sleep(std::time::Duration::from_millis(10));
                        continue;
                    }
                };
                if self.shutdown.load(Ordering::SeqCst) {
                    return Ok(());
                }
                let local = local.clone();
                scope.spawn(move || self.handle_conn_blocking(conn, &local));
            }
        })
    }

    /// One connection's blocking request loop (non-unix fallback).
    #[cfg(not(unix))]
    fn handle_conn_blocking(&self, mut conn: Conn, local: &SocketSpec) {
        loop {
            let frame = if self.framing == Framing::Line {
                match conn.recv() {
                    Ok(Some(f)) => f.into_bytes(),
                    Ok(None) | Err(_) => return,
                }
            } else {
                match conn.recv_bytes() {
                    Ok(Some(f)) => f,
                    Ok(None) | Err(_) => return,
                }
            };
            let outcome = self.execute_frame(&frame);
            let (reply, shutdown, subscribe) = (outcome.reply, outcome.shutdown, outcome.subscribe);
            let sent = if self.framing == Framing::Line {
                match String::from_utf8(reply) {
                    Ok(text) => conn.send(&text).is_ok(),
                    Err(_) => false,
                }
            } else {
                conn.send_bytes(&reply).is_ok()
            };
            if !sent {
                return;
            }
            if let Some(interval_ms) = subscribe {
                // no poller to tick here: dedicate this connection's
                // thread to the push stream until the peer hangs up
                let interval = interval_ms.clamp(50, 10_000);
                while !self.shutdown.load(Ordering::SeqCst) {
                    std::thread::sleep(std::time::Duration::from_millis(interval));
                    let body = encode_ps_reply(&PsReply::StatsDelta(self.delta()));
                    let sent = if self.framing == Framing::Line {
                        conn.send(&body).is_ok()
                    } else {
                        conn.send_bytes(body.as_bytes()).is_ok()
                    };
                    if !sent {
                        return;
                    }
                }
                return;
            }
            if shutdown {
                self.shutdown.store(true, Ordering::SeqCst);
                // poke our own listener so the blocking accept wakes
                // up and observes the flag
                let _ = local.connect(self.framing);
                return;
            }
        }
    }

    /// Execute one frame body in whichever codec it arrived in —
    /// binary opcodes and JSON objects are self-distinguishing by
    /// their first byte — and encode the reply in the same codec.
    /// Undecodable frames get an error reply, not a disconnect; a
    /// frame that is neither binary nor UTF-8 is answered in JSON.
    /// `subscribe` carries the stats-subscription interval when the
    /// frame was a `SubscribeStats` (the transport layer owns the
    /// push cadence, so the request only acknowledges here);
    /// `session` and `cost_rows` feed the fairness plane.
    fn execute_frame(&self, body: &[u8]) -> FrameOutcome {
        let is_bin = binwire::is_binary_frame(body);
        if is_bin {
            self.frames_bin.fetch_add(1, Ordering::Relaxed);
        } else {
            self.frames_json.fetch_add(1, Ordering::Relaxed);
        }
        let decoded = if is_bin {
            binwire::decode_request(body)
        } else {
            match std::str::from_utf8(body) {
                Ok(text) => decode_ps_request(text),
                Err(_) => Err(anyhow!("frame is neither a binary opcode nor UTF-8 JSON")),
            }
        };
        let (reply, shutdown, subscribe, session, cost_rows) = match decoded {
            Ok(req) => {
                let shutdown = req == PsRequest::Shutdown;
                let subscribe = match req {
                    PsRequest::SubscribeStats { interval_ms } => Some(interval_ms),
                    _ => None,
                };
                let session = req.session();
                let cost_rows = req.cost_rows();
                (self.handle(&req), shutdown, subscribe, session, cost_rows)
            }
            Err(e) => (
                PsReply::Err {
                    message: format!("bad request: {e}"),
                },
                false,
                None,
                None,
                0,
            ),
        };
        let encoded = if is_bin {
            let mut out = Vec::new();
            match binwire::encode_reply(&reply, &mut out) {
                Ok(()) => out,
                // unencodable reply (absurd length): fall back to the
                // JSON form, which the client's first-byte dispatch
                // still understands
                Err(e) => encode_ps_reply(&PsReply::Err {
                    message: format!("reply not binary-encodable: {e}"),
                })
                .into_bytes(),
            }
        } else {
            encode_ps_reply(&reply).into_bytes()
        };
        FrameOutcome {
            reply: encoded,
            shutdown,
            subscribe,
            session,
            cost_rows,
        }
    }

    /// Dispatch one request against the engine (transport-free, so
    /// unit tests drive it directly).  Session-stamped frames renew
    /// the session's lease and feed the per-session traffic counters
    /// before dispatch; a session-resolution failure (unknown id,
    /// admission limit, foreign branch) becomes an `Err` reply, never
    /// a disconnect.
    pub fn handle(&self, req: &PsRequest) -> PsReply {
        if let Some(s) = req.session() {
            self.ps.touch_session(s, self.now_ms());
            let cost = req.cost_rows();
            match req {
                PsRequest::ReadRow { .. } | PsRequest::ReadRows { .. } => {
                    self.record_traffic(s, 0, cost)
                }
                _ => self.record_traffic(s, cost, 0),
            }
        }
        match self.handle_inner(req) {
            Ok(reply) => reply,
            Err(e) => PsReply::Err {
                message: e.to_string(),
            },
        }
    }

    /// [`ShardServer::handle`] minus error packaging: `?` bails on
    /// session/branch resolution so every arm reads straight-line.
    fn handle_inner(&self, req: &PsRequest) -> Result<PsReply> {
        match req {
            PsRequest::Hello { codec, session } => {
                let sid = match session {
                    None => 0,
                    Some(h) => {
                        let (sid, _lease) =
                            self.ps.register_session(&h.name, h.lease_ms, self.now_ms())?;
                        sid
                    }
                };
                Ok(PsReply::Hello {
                    shard_begin: self.range.begin,
                    shard_end: self.range.end,
                    optimizer: self.optimizer.name().to_string(),
                    // grant the binary codec only when this server
                    // itself runs binary framing; everyone else
                    // negotiates JSON
                    codec: if *codec == WireCodec::Binary && self.framing == Framing::Binary {
                        WireCodec::Binary
                    } else {
                        WireCodec::Json
                    },
                    session: sid,
                })
            }
            PsRequest::InsertRow {
                session,
                branch,
                table,
                key,
                data,
            } => {
                let g = self.ps.resolve_branch(*session, *branch)?;
                self.ps.insert_row(g, *table, *key, data.clone());
                Ok(PsReply::Ok)
            }
            PsRequest::ReadRow {
                session,
                branch,
                table,
                key,
                with_accum: false,
            } => {
                let g = self.ps.resolve_branch(*session, *branch)?;
                Ok(PsReply::Row {
                    data: self.ps.read_row(g, *table, *key),
                    accum: None,
                })
            }
            PsRequest::ReadRow {
                session,
                branch,
                table,
                key,
                with_accum: true,
            } => {
                let g = self.ps.resolve_branch(*session, *branch)?;
                Ok(match self.ps.read_row_with_accum(g, *table, *key) {
                    None => PsReply::Row {
                        data: None,
                        accum: None,
                    },
                    Some((data, accum)) => PsReply::Row {
                        data: Some(data),
                        accum,
                    },
                })
            }
            PsRequest::ReadRows {
                session,
                branch,
                with_accum,
                keys,
            } => {
                let g = self.ps.resolve_branch(*session, *branch)?;
                Ok(PsReply::RowsData {
                    rows: self.ps.read_rows(g, keys, *with_accum),
                })
            }
            PsRequest::ApplyUpdate {
                session,
                branch,
                table,
                key,
                grad,
                hyper,
                z_old,
            } => {
                let g = self.ps.resolve_branch(*session, *branch)?;
                self.ps.apply_update(g, *table, *key, grad, *hyper, z_old.as_deref())?;
                Ok(PsReply::Ok)
            }
            PsRequest::ApplyBatch {
                session,
                branch,
                hyper,
                updates,
            } => {
                let g = self.ps.resolve_branch(*session, *branch)?;
                let refs: Vec<(TableId, RowKey, &[f32])> = updates
                    .iter()
                    .map(|(t, k, g)| (*t, *k, g.as_slice()))
                    .collect();
                self.ps.apply_batch(g, &refs, *hyper)?;
                Ok(PsReply::Ok)
            }
            PsRequest::ForkBranch {
                session,
                child,
                parent,
            } => {
                self.ps.fork_branch_in(*session, *child, *parent)?;
                Ok(PsReply::Ok)
            }
            PsRequest::FreeBranch { session, branch } => {
                self.ps.free_branch_in(*session, *branch)?;
                Ok(PsReply::Ok)
            }
            PsRequest::CheckpointBranch {
                session,
                branch,
                dir,
            } => {
                let g = self.ps.resolve_branch(*session, *branch)?;
                let range = self.range;
                Ok(
                    match checkpoint::checkpoint_range(
                        &self.ps,
                        g,
                        range.begin,
                        range.end,
                        Path::new(dir),
                    ) {
                        Ok(segments) => PsReply::Segments { segments },
                        Err(e) => PsReply::Err {
                            message: format!("checkpoint failed: {e:#}"),
                        },
                    },
                )
            }
            PsRequest::VerifyBranch {
                session,
                branch,
                dir,
            } => {
                let g = self.ps.resolve_branch(*session, *branch)?;
                let range = self.range;
                Ok(match checkpoint::load_range(g, range.begin, range.end, Path::new(dir)) {
                    Ok(rows) => PsReply::Verified {
                        rows: rows.len() as u64,
                    },
                    Err(e) => PsReply::Err {
                        message: format!("verify failed: {e:#}"),
                    },
                })
            }
            PsRequest::RestoreBranch {
                session,
                branch,
                dir,
            } => {
                let g = self.ps.resolve_or_create_branch(*session, *branch)?;
                let range = self.range;
                Ok(
                    match checkpoint::restore_range(
                        &self.ps,
                        g,
                        range.begin,
                        range.end,
                        Path::new(dir),
                    ) {
                        Ok(rows) => PsReply::Restored { rows: rows as u64 },
                        Err(e) => PsReply::Err {
                            message: format!("restore failed: {e:#}"),
                        },
                    },
                )
            }
            PsRequest::ServerStats => Ok(PsReply::Stats(self.delta())),
            PsRequest::SubscribeStats { .. } => Ok(PsReply::Ok),
            PsRequest::PublishProgress { event } => {
                self.record_trial(*event);
                Ok(PsReply::Ok)
            }
            PsRequest::ListBranches { session } => Ok(PsReply::BranchList {
                branches: self.ps.session_branches(*session)?,
            }),
            PsRequest::EndSession { session } => {
                self.ps.end_session(*session)?;
                Ok(PsReply::Ok)
            }
            PsRequest::Shutdown => Ok(PsReply::Ok),
        }
    }
}

/// The event loop's view of the shard server: one frame body in, one
/// reply body out, executed on the worker pool; the tick hook pushes
/// stats deltas to subscribers from the poll thread.
#[cfg(unix)]
impl crate::comm::poll::FrameHandler for ShardServer {
    fn on_frame(&self, body: Vec<u8>) -> crate::comm::poll::FrameResult {
        let outcome = self.execute_frame(&body);
        crate::comm::poll::FrameResult {
            reply: outcome.reply,
            shutdown: outcome.shutdown,
            subscribe: outcome.subscribe,
            session: outcome.session,
            cost_rows: outcome.cost_rows,
        }
    }

    /// The push stream always rides the JSON codec, whatever the
    /// connection's framing: subscribers dispatch on the frame's
    /// first byte exactly like data-plane replies, and a JSON body is
    /// legal under every framing (line framing rejects embedded
    /// newlines, which compact JSON never contains).
    fn on_tick(&self) -> Option<Vec<u8>> {
        Some(encode_ps_reply(&PsReply::StatsDelta(self.delta())).into_bytes())
    }
}

/// A checkpoint directory as its wire form (paths cross the data
/// plane as UTF-8 strings).
fn utf8_dir(dir: &Path) -> Result<String> {
    dir.to_str()
        .map(str::to_string)
        .ok_or_else(|| anyhow!("checkpoint dir {} is not valid UTF-8", dir.display()))
}

/// Cap on idle pooled connections parked per shard server.  Leases
/// beyond the cap still succeed (a fresh dial); the surplus connection
/// is closed on release instead of parked, so a transient thread spike
/// cannot pin sockets forever.
const MAX_IDLE_CONNS_PER_SERVER: usize = 16;

/// A small per-server connection pool: each in-flight RPC leases its
/// own socket, so the `num_workers` gather/push threads of a clock
/// phase talk to a server concurrently instead of convoying on one
/// mutex-serialized connection (the server spawns one handler thread
/// per connection).  Leases are LIFO — the hottest socket stays hot —
/// and a connection that saw a transport error is dropped, never
/// repooled (its stream may be desynchronized mid-frame).
struct ConnPool {
    spec: SocketSpec,
    framing: Framing,
    idle: Mutex<Vec<Conn>>,
}

impl ConnPool {
    fn new(spec: SocketSpec, framing: Framing, first: Conn) -> Self {
        ConnPool {
            spec,
            framing,
            idle: Mutex::new(vec![first]),
        }
    }

    /// Take an idle connection, or dial a fresh one when every pooled
    /// connection is leased out.
    fn lease(&self) -> Result<Conn> {
        if let Some(conn) = self.idle.lock().unwrap_or_else(|e| e.into_inner()).pop() {
            return Ok(conn);
        }
        self.spec.connect(self.framing)
    }

    /// Park a healthy connection for the next lease.
    fn release(&self, conn: Conn) {
        let mut idle = self.idle.lock().unwrap_or_else(|e| e.into_inner());
        if idle.len() < MAX_IDLE_CONNS_PER_SERVER {
            idle.push(conn);
        } // else: dropping the surplus connection closes it
    }
}

/// One connected shard server, client side.
struct RemoteServer {
    spec: SocketSpec,
    range: ShardRange,
    pool: ConnPool,
    /// Session id this server granted at `Hello` (0 = the default
    /// namespace).  Ids are **per-server** — two servers may grant
    /// the same name different ids — so every request is stamped with
    /// its own server's grant.  Zeroed by an explicit `end_session`
    /// so the drop-time best-effort teardown does not double-end.
    session: AtomicU32,
}

/// Socket-backed [`ParamStore`]: same `&self` interface as the local
/// engine, every row op one RPC to the owning shard server.
pub struct RemoteParamServer {
    servers: Vec<RemoteServer>,
    /// Global shard id → index into `servers`.
    shard_to_server: Vec<usize>,
    num_shards: usize,
    optimizer: OptimizerKind,
    framing: Framing,
    /// Data-plane codec every server granted at `Hello` (binary iff
    /// the whole cluster runs `--framing binary`).
    codec: WireCodec,
    /// Data-plane `ReadRows` RPCs issued by this client (surfaced as
    /// `store.read_rpcs` in the stats snapshot; the distributed CI
    /// leg bounds it at
    /// shard servers × workers per MF training clock).
    read_rpcs: AtomicU64,
}

thread_local! {
    /// Reused binary-encode buffer, one per client thread: the hot
    /// path (`ApplyBatch`/`ReadRows` once per server per clock phase)
    /// re-encodes into this allocation instead of a fresh `Vec` —
    /// after warm-up, encoding a request performs zero heap
    /// allocations and zero float→decimal formatting.
    static BIN_ENC_BUF: std::cell::RefCell<Vec<u8>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

impl fmt::Debug for RemoteParamServer {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("RemoteParamServer")
            .field("num_shards", &self.num_shards)
            .field("servers", &self.servers.iter().map(|s| &s.spec).collect::<Vec<_>>())
            .field("optimizer", &self.optimizer)
            .finish()
    }
}

impl RemoteParamServer {
    /// Connect and handshake with every shard server, verifying that
    /// the advertised ranges tile a contiguous global shard space
    /// `0..N` and that all servers run the same optimizer.  A binary
    /// client additionally requires every server to grant the binary
    /// codec — a mixed-framing cluster is rejected here, not later.
    pub fn connect(specs: &[SocketSpec], framing: Framing) -> Result<RemoteParamServer> {
        Self::connect_session(specs, framing, None)
    }

    /// [`RemoteParamServer::connect`] attaching to a named session on
    /// every server: the `Hello` carries a [`SessionHello`] and all
    /// subsequent traffic is stamped with each server's granted id,
    /// scoping this client's branches to its own namespace.  `None`
    /// is the default session-0 namespace — byte-identical to the
    /// legacy handshake.
    pub fn connect_session(
        specs: &[SocketSpec],
        framing: Framing,
        session_name: Option<&str>,
    ) -> Result<RemoteParamServer> {
        if specs.is_empty() {
            bail!("no shard servers given");
        }
        let wanted = if framing == Framing::Binary {
            WireCodec::Binary
        } else {
            WireCodec::Json
        };
        let mut servers = Vec::with_capacity(specs.len());
        let mut optimizer: Option<OptimizerKind> = None;
        for spec in specs {
            let mut conn = spec.connect(framing)?;
            // the handshake always rides as JSON, whatever the codec
            let hello = PsRequest::Hello {
                codec: wanted,
                session: session_name.map(|name| SessionHello {
                    name: name.to_string(),
                    lease_ms: 0, // the server default
                }),
            };
            conn.send(&encode_ps_request(&hello))?;
            let reply = decode_ps_reply(&conn.recv_expect()?)?;
            if let PsReply::Err { message } = &reply {
                // admission refusals (session table full, name clash
                // semantics) surface here, before any data flows
                bail!("{spec}: handshake rejected: {message}");
            }
            let PsReply::Hello {
                shard_begin,
                shard_end,
                optimizer: opt_name,
                codec: granted,
                session,
            } = reply
            else {
                bail!("{spec}: unexpected handshake reply");
            };
            if granted != wanted {
                bail!(
                    "{spec}: server granted the {} codec but this client wants {} — \
                     a cluster must run one --framing end to end",
                    granted.name(),
                    wanted.name()
                );
            }
            if shard_end <= shard_begin {
                bail!("{spec}: empty shard range {shard_begin}..{shard_end}");
            }
            let kind = OptimizerKind::parse(&opt_name)
                .ok_or_else(|| anyhow!("{spec}: unknown optimizer {opt_name:?}"))?;
            match optimizer {
                None => optimizer = Some(kind),
                Some(k) if k != kind => {
                    bail!("{spec}: optimizer {opt_name} != {} of first server", k.name())
                }
                Some(_) => {}
            }
            if session_name.is_some() && session == 0 {
                bail!("{spec}: server ignored the session attach (pre-session peer)");
            }
            servers.push(RemoteServer {
                spec: spec.clone(),
                range: ShardRange {
                    begin: shard_begin,
                    end: shard_end,
                },
                pool: ConnPool::new(spec.clone(), framing, conn),
                session: AtomicU32::new(session),
            });
        }
        // the ranges must partition 0..N
        let mut order: Vec<usize> = (0..servers.len()).collect();
        order.sort_by_key(|&i| servers[i].range.begin);
        let mut expected = 0usize;
        for &i in &order {
            let r = servers[i].range;
            if r.begin != expected {
                bail!(
                    "shard ranges do not tile the shard space: expected a server \
                     starting at shard {expected}, got {} from {}",
                    r,
                    servers[i].spec
                );
            }
            expected = r.end;
        }
        let num_shards = expected;
        let mut shard_to_server = vec![0usize; num_shards];
        for (si, server) in servers.iter().enumerate() {
            for s in server.range.begin..server.range.end {
                shard_to_server[s] = si;
            }
        }
        Ok(RemoteParamServer {
            servers,
            shard_to_server,
            num_shards,
            // lint:allow(panic-path): connect() bails on an empty
            // server list before this point, so the loop above has
            // always populated the optimizer
            optimizer: optimizer.expect("at least one server"),
            framing,
            codec: wanted,
            read_rpcs: AtomicU64::new(0),
        })
    }

    pub fn num_shards(&self) -> usize {
        self.num_shards
    }

    pub fn num_servers(&self) -> usize {
        self.servers.len()
    }

    pub fn framing(&self) -> Framing {
        self.framing
    }

    /// The codec every server granted at `Hello`.
    pub fn codec(&self) -> WireCodec {
        self.codec
    }

    #[inline]
    fn server_for(&self, table: TableId, key: RowKey) -> usize {
        self.shard_to_server[route_shard(table, key, self.num_shards)]
    }

    /// The session id server `si` granted this client (0 = default
    /// namespace).  Session ids are per-server, so a request is
    /// always built *after* routing decides which server it goes to.
    #[inline]
    fn session_of(&self, si: usize) -> SessionId {
        self.servers[si].session.load(Ordering::Relaxed)
    }

    /// One RPC against server `si`.  Each in-flight RPC leases its own
    /// pooled connection, so concurrent clock-phase threads hit a
    /// server in parallel; a connection that errored mid-RPC is
    /// dropped, not repooled.  Under the binary codec the request is
    /// encoded into a thread-reused buffer (no per-row allocation, no
    /// decimal formatting) and the reply is dispatched on its first
    /// byte, so JSON error replies stay intelligible.
    fn request(&self, si: usize, req: &PsRequest) -> Result<PsReply> {
        let server = &self.servers[si];
        let mut conn = server
            .pool
            .lease()
            .with_context(|| format!("connecting to {}", server.spec))?;
        let sent = match self.codec {
            WireCodec::Json => conn.send(&encode_ps_request(req)),
            WireCodec::Binary => BIN_ENC_BUF.with(|buf| {
                let mut buf = buf.borrow_mut();
                binwire::encode_request(req, &mut buf)?;
                conn.send_bytes(&buf)
            }),
        };
        if let Err(e) = sent {
            return Err(e.context(format!("sending to {}", server.spec)));
        }
        match self.codec {
            WireCodec::Json => match conn.recv_expect() {
                Err(e) => Err(e.context(format!("waiting for {}", server.spec))),
                Ok(frame) => {
                    server.pool.release(conn);
                    decode_ps_reply(&frame)
                }
            },
            WireCodec::Binary => match conn.recv_bytes() {
                Err(e) => Err(e.context(format!("waiting for {}", server.spec))),
                Ok(None) => bail!("{}: connection closed mid-request", server.spec),
                Ok(Some(frame)) => {
                    server.pool.release(conn);
                    if binwire::is_binary_frame(&frame) {
                        binwire::decode_reply(&frame)
                    } else {
                        // servers answer unencodable/undecodable
                        // situations in JSON; first-byte dispatch
                        // keeps that legible here
                        let text = std::str::from_utf8(&frame)
                            .with_context(|| format!("{}: unintelligible reply", server.spec))?;
                        decode_ps_reply(text)
                    }
                }
            },
        }
    }

    /// RPC that must answer `Ok`; an `Err` reply becomes an error.
    fn request_ok(&self, si: usize, req: &PsRequest) -> Result<()> {
        match self.request(si, req)? {
            PsReply::Ok => Ok(()),
            PsReply::Err { message } => bail!("{}: {message}", self.servers[si].spec),
            other => bail!("{}: unexpected reply {other:?}", self.servers[si].spec),
        }
    }

    fn request_row(
        &self,
        branch: BranchId,
        table: TableId,
        key: RowKey,
        with_accum: bool,
    ) -> Result<(Option<Vec<f32>>, Option<Vec<f32>>)> {
        let si = self.server_for(table, key);
        match self.request(
            si,
            &PsRequest::ReadRow {
                session: self.session_of(si),
                branch,
                table,
                key,
                with_accum,
            },
        )? {
            PsReply::Row { data, accum } => Ok((data, accum)),
            PsReply::Err { message } => bail!("{}: {message}", self.servers[si].spec),
            other => bail!("{}: unexpected reply {other:?}", self.servers[si].spec),
        }
    }

    /// Probe every shard server's cumulative [`ServerDelta`], in
    /// server order (the pull side of the observability plane; the
    /// push side streams the same payload via `SubscribeStats`).
    pub fn probe_stats(&self) -> Result<Vec<ServerDelta>> {
        (0..self.servers.len())
            .map(|si| match self.request(si, &PsRequest::ServerStats)? {
                PsReply::Stats(d) => Ok(d),
                other => bail!("{}: unexpected reply {other:?}", self.servers[si].spec),
            })
            .collect()
    }

    /// Server `si`'s branches in this client's session namespace,
    /// with that server's local row counts.
    fn list_branches(&self, si: usize) -> Result<Vec<(BranchId, usize)>> {
        let req = PsRequest::ListBranches {
            session: self.session_of(si),
        };
        match self.request(si, &req)? {
            PsReply::BranchList { branches } => Ok(branches),
            PsReply::Err { message } => bail!("{}: {message}", self.servers[si].spec),
            other => bail!("{}: unexpected reply {other:?}", self.servers[si].spec),
        }
    }

    /// Broadcast to every shard server concurrently (one scoped
    /// thread per server, each leasing its own pooled connection) and
    /// collect the replies in server order.  The request is built
    /// per-server by `make(si)` because session ids differ across
    /// servers — one shared frame cannot be stamped correctly.
    fn broadcast_with<F>(&self, make: F) -> Vec<Result<PsReply>>
    where
        F: Fn(usize) -> PsRequest + Sync,
    {
        std::thread::scope(|scope| {
            let make = &make;
            let handles: Vec<_> = (0..self.servers.len())
                .map(|si| scope.spawn(move || self.request(si, &make(si))))
                .collect();
            handles
                .into_iter()
                // lint:allow(panic-path): join only errs when the
                // worker panicked; re-raising that panic is correct
                .map(|h| h.join().expect("broadcast worker panicked"))
                .collect()
        })
    }

    /// Ask every shard server process to exit (used by tests and
    /// orchestration teardown; the acknowledgement is awaited).
    pub fn shutdown_all(&self) -> Result<()> {
        for si in 0..self.servers.len() {
            self.request_ok(si, &PsRequest::Shutdown)?;
        }
        Ok(())
    }

    /// Gracefully end this client's named session on every server:
    /// frees exactly the namespace's branches and drops the
    /// registration (the graceful counterpart of lease-expiry GC).
    /// No-op for default-namespace clients.
    pub fn end_session(&self) -> Result<()> {
        for si in 0..self.servers.len() {
            let session = self.session_of(si);
            if session == 0 {
                continue;
            }
            self.request_ok(si, &PsRequest::EndSession { session })?;
            self.servers[si].session.store(0, Ordering::Relaxed);
        }
        Ok(())
    }
}

/// Best-effort teardown for named sessions: a client going away ends
/// its session (errors ignored — the server's lease-expiry GC is the
/// backstop for crashed clients).  A complete no-op for
/// default-namespace clients, so legacy drops stay free of traffic.
impl Drop for RemoteParamServer {
    fn drop(&mut self) {
        for si in 0..self.servers.len() {
            let session = self.session_of(si);
            if session != 0 {
                let _ = self.request_ok(si, &PsRequest::EndSession { session });
            }
        }
    }
}

impl ParamStore for RemoteParamServer {
    fn optimizer_kind(&self) -> OptimizerKind {
        self.optimizer
    }

    fn insert_row(
        &self,
        branch: BranchId,
        table: TableId,
        key: RowKey,
        data: Vec<f32>,
    ) -> Result<()> {
        let si = self.server_for(table, key);
        self.request_ok(
            si,
            &PsRequest::InsertRow {
                session: self.session_of(si),
                branch,
                table,
                key,
                data,
            },
        )
    }

    /// Branch-index replication: every shard server forks its own rows
    /// of `parent`.  Not atomic across servers — a mid-broadcast
    /// failure leaves earlier servers forked (the caller sees the
    /// error and aborts the branch, mirroring the local engine's
    /// partial-application semantics for batches).
    fn fork_branch(&self, child: BranchId, parent: BranchId) -> Result<()> {
        for si in 0..self.servers.len() {
            self.request_ok(
                si,
                &PsRequest::ForkBranch {
                    session: self.session_of(si),
                    child,
                    parent,
                },
            )?;
        }
        Ok(())
    }

    fn free_branch(&self, branch: BranchId) -> Result<()> {
        for si in 0..self.servers.len() {
            self.request_ok(
                si,
                &PsRequest::FreeBranch {
                    session: self.session_of(si),
                    branch,
                },
            )?;
        }
        Ok(())
    }

    /// The durable-checkpoint broadcast: every shard server dumps its
    /// own shard range into `dir` **concurrently** (one scoped thread
    /// per server, each leasing its own pooled connection); the
    /// returned segment metadata — sorted by range, then shard — is
    /// what the coordinator records in the manifest.  The coordinator
    /// itself writes no row data.
    fn checkpoint_branch(&self, branch: BranchId, dir: &Path) -> Result<Vec<SegmentMeta>> {
        let dir = utf8_dir(dir)?;
        let replies = self.broadcast_with(|si| PsRequest::CheckpointBranch {
            session: self.session_of(si),
            branch,
            dir: dir.clone(),
        });
        let mut out = Vec::new();
        for (si, reply) in replies.into_iter().enumerate() {
            match reply? {
                PsReply::Segments { segments } => out.extend(segments),
                PsReply::Err { message } => bail!("{}: {message}", self.servers[si].spec),
                other => bail!("{}: unexpected reply {other:?}", self.servers[si].spec),
            }
        }
        out.sort_by_key(|s| (s.range_begin, s.local_shard));
        Ok(out)
    }

    /// Two-phase restore broadcast.  Phase 1 (`VerifyBranch`): every
    /// shard server decodes and checksum-verifies the segment files of
    /// its own range **without installing** — any corruption anywhere
    /// aborts here with every server untouched, so a bad checkpoint
    /// cannot leave a cross-server torn branch.  Phase 2
    /// (`RestoreBranch`): only after every server verified does the
    /// install broadcast go out, each server swapping its rows in
    /// wholesale.  (A file mutated *between* the phases still fails
    /// that server's own re-verification; the coordinator then aborts
    /// the session rather than serving mixed state.)
    fn restore_branch(&self, branch: BranchId, dir: &Path) -> Result<usize> {
        let dir = utf8_dir(dir)?;
        let verified = self.broadcast_with(|si| PsRequest::VerifyBranch {
            session: self.session_of(si),
            branch,
            dir: dir.clone(),
        });
        for (si, reply) in verified.into_iter().enumerate() {
            match reply? {
                PsReply::Verified { .. } => {}
                PsReply::Err { message } => bail!("{}: {message}", self.servers[si].spec),
                other => bail!("{}: unexpected reply {other:?}", self.servers[si].spec),
            }
        }
        let installed = self.broadcast_with(|si| PsRequest::RestoreBranch {
            session: self.session_of(si),
            branch,
            dir: dir.clone(),
        });
        let mut total = 0usize;
        for (si, reply) in installed.into_iter().enumerate() {
            match reply? {
                PsReply::Restored { rows } => total += rows as usize,
                PsReply::Err { message } => bail!("{}: {message}", self.servers[si].spec),
                other => bail!("{}: unexpected reply {other:?}", self.servers[si].spec),
            }
        }
        Ok(total)
    }

    fn read_row(&self, branch: BranchId, table: TableId, key: RowKey) -> Result<Option<Vec<f32>>> {
        Ok(self.request_row(branch, table, key, false)?.0)
    }

    fn read_row_with_accum(
        &self,
        branch: BranchId,
        table: TableId,
        key: RowKey,
    ) -> Result<Option<(Vec<f32>, Option<Vec<f32>>)>> {
        let (data, accum) = self.request_row(branch, table, key, true)?;
        Ok(data.map(|d| (d, accum)))
    }

    /// The batched read plane: route every key once, group per shard
    /// *server* (the read-side mirror of [`ParamStore::apply_batch`]'s
    /// grouping), and issue **one** `ReadRows` RPC per server holding
    /// any of the keys — the per-clock RPC count of a gather phase is
    /// O(shard servers × workers) instead of O(touched rows).  Replies
    /// are scattered back into key order.
    fn read_rows(
        &self,
        branch: BranchId,
        keys: &[(TableId, RowKey)],
        with_accum: bool,
    ) -> Result<Vec<Option<RowData>>> {
        let mut out: Vec<Option<RowData>> = vec![None; keys.len()];
        if keys.is_empty() {
            return Ok(out);
        }
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); self.servers.len()];
        for (i, &(table, key)) in keys.iter().enumerate() {
            groups[self.server_for(table, key)].push(i);
        }
        for (si, group) in groups.iter().enumerate() {
            if group.is_empty() {
                continue;
            }
            let group_keys: Vec<(TableId, RowKey)> = group.iter().map(|&i| keys[i]).collect();
            self.read_rpcs.fetch_add(1, Ordering::Relaxed);
            match self.request(
                si,
                &PsRequest::ReadRows {
                    session: self.session_of(si),
                    branch,
                    with_accum,
                    keys: group_keys,
                },
            )? {
                PsReply::RowsData { rows } => {
                    if rows.len() != group.len() {
                        bail!(
                            "{}: ReadRows answered {} rows for {} keys",
                            self.servers[si].spec,
                            rows.len(),
                            group.len()
                        );
                    }
                    for (&i, row) in group.iter().zip(rows) {
                        out[i] = row;
                    }
                }
                PsReply::Err { message } => bail!("{}: {message}", self.servers[si].spec),
                other => bail!("{}: unexpected reply {other:?}", self.servers[si].spec),
            }
        }
        Ok(out)
    }

    fn apply_update(
        &self,
        branch: BranchId,
        table: TableId,
        key: RowKey,
        grad: &[f32],
        hyper: Hyper,
        z_old: Option<&[f32]>,
    ) -> Result<()> {
        let si = self.server_for(table, key);
        self.request_ok(
            si,
            &PsRequest::ApplyUpdate {
                session: self.session_of(si),
                branch,
                table,
                key,
                grad: grad.to_vec(),
                hyper,
                z_old: z_old.map(<[f32]>::to_vec),
            },
        )
    }

    /// Route once, group per shard *server* (the distributed analog of
    /// the local engine's per-shard grouping), send one `ApplyBatch`
    /// per server, and collect the acknowledgements in server order.
    /// Same-key order inside a group is call order, so the result is
    /// observationally identical to the local batched path.
    fn apply_batch(
        &self,
        branch: BranchId,
        updates: &[(TableId, RowKey, &[f32])],
        hyper: Hyper,
    ) -> Result<()> {
        if updates.is_empty() {
            return Ok(());
        }
        let mut groups: Vec<Vec<(TableId, RowKey, Vec<f32>)>> =
            vec![Vec::new(); self.servers.len()];
        for &(table, key, grad) in updates {
            groups[self.server_for(table, key)].push((table, key, grad.to_vec()));
        }
        for (si, group) in groups.into_iter().enumerate() {
            if group.is_empty() {
                continue;
            }
            self.request_ok(
                si,
                &PsRequest::ApplyBatch {
                    session: self.session_of(si),
                    branch,
                    hyper,
                    updates: group,
                },
            )?;
        }
        Ok(())
    }

    /// Session-scoped branch census via `ListBranches` — **not** the
    /// global stats census, which would leak co-tenant branches into
    /// this client's view (and, through `with_store`'s stale-branch
    /// cleanup, let one attaching session free another's branches).
    fn branch_row_count(&self, branch: BranchId) -> Result<usize> {
        let mut total = 0;
        for si in 0..self.servers.len() {
            total += self
                .list_branches(si)?
                .iter()
                .find(|(b, _)| *b == branch)
                .map_or(0, |(_, rows)| *rows);
        }
        Ok(total)
    }

    /// Branch ids live in **this client's session namespace**, in
    /// this session's (user-visible) numbering.  See
    /// [`RemoteParamServer::branch_row_count`] for why this is not
    /// the global census.
    fn live_branches(&self) -> Result<Vec<BranchId>> {
        let mut all = Vec::new();
        for si in 0..self.servers.len() {
            all.extend(self.list_branches(si)?.into_iter().map(|(b, _)| b));
        }
        all.sort_unstable();
        all.dedup();
        Ok(all)
    }

    /// Aggregate over all shard servers via the same
    /// [`merge_cluster`] the streaming collector uses: counters, pool
    /// and wire planes sum (each buffer lives in exactly one server's
    /// pools); fork count, peak and live branches are replicated on
    /// every server, so the maximum is the global value.
    /// `store.read_rpcs` is a client-side counter, overlaid here.
    fn stats(&self) -> Result<Snapshot> {
        let deltas = self.probe_stats()?;
        let mut snap = merge_cluster(&deltas).snapshot;
        snap.store.read_rpcs = self.read_rpcs.load(Ordering::Relaxed);
        Ok(snap)
    }

    /// Replicate one tuner trial-progress event onto every shard
    /// server, so any single `mltuner top` subscriber sees trial
    /// progress next to that server's counters.
    fn publish_progress(&self, event: TrialEvent) -> Result<()> {
        // stamp the event with each server's own session grant: the
        // event's session field doubles as the frame's session stamp,
        // and a client cannot publish into another tenant's rows
        let replies = self.broadcast_with(|si| {
            let mut e = event;
            e.session = self.session_of(si);
            PsRequest::PublishProgress { event: e }
        });
        for (si, reply) in replies.into_iter().enumerate() {
            match reply? {
                PsReply::Ok => {}
                PsReply::Err { message } => bail!("{}: {message}", self.servers[si].spec),
                other => bail!("{}: unexpected reply {other:?}", self.servers[si].spec),
            }
        }
        Ok(())
    }
}

/// Client-side merge point for the streaming stats channel: one slot
/// per shard server, each holding that server's **latest** cumulative
/// [`ServerDelta`].  Because deltas carry cumulative totals — never
/// diffs — merging is latest-frame-wins per server plus
/// [`merge_cluster`] across servers, and frames may be dropped or
/// reordered per server without corrupting the view.
///
/// `ingest` enforces the monotonic-merge invariant: within one
/// server's stream no counter may ever decrease.  A violating frame
/// is rejected wholesale — the previous good frame stays — and the
/// error is surfaced to the caller instead of silently rewinding the
/// dashboard.
pub struct StatsCollector {
    per_server: Mutex<Vec<Option<ServerDelta>>>,
}

impl StatsCollector {
    pub fn new(servers: usize) -> Self {
        StatsCollector {
            per_server: Mutex::new(vec![None; servers]),
        }
    }

    /// Install `delta` as server `server`'s latest frame, after
    /// checking it never moves a counter backwards relative to the
    /// frame it replaces.
    pub fn ingest(&self, server: usize, delta: ServerDelta) -> Result<()> {
        let mut slots = self.per_server.lock().unwrap_or_else(|e| e.into_inner());
        let slot = slots
            .get_mut(server)
            .ok_or_else(|| anyhow!("stats delta from unknown server index {server}"))?;
        if let Some(prev) = slot {
            delta.check_monotonic(prev)?;
        }
        *slot = Some(delta);
        Ok(())
    }

    /// How many servers have reported at least one frame.
    pub fn servers_reporting(&self) -> usize {
        let slots = self.per_server.lock().unwrap_or_else(|e| e.into_inner());
        slots.iter().flatten().count()
    }

    /// Merge the latest per-server frames into one cluster view.
    pub fn view(&self) -> ClusterView {
        let slots = self.per_server.lock().unwrap_or_else(|e| e.into_inner());
        merge_cluster(slots.iter().flatten())
    }
}

/// What [`spawn_local_server`] hands back: the bound address, the
/// serve-thread handle, and the server itself (so tests can inspect
/// its live metrics).
#[doc(hidden)]
pub type LocalServerHandle = (
    SocketSpec,
    std::thread::JoinHandle<Result<()>>,
    Arc<ShardServer>,
);

/// Spawn an in-process [`ShardServer`] on an ephemeral loopback port —
/// shared scaffolding for unit tests here and in `config`; the
/// multi-process CI leg spawns real `mltuner serve` processes instead.
#[doc(hidden)]
pub fn spawn_local_server(
    range: ShardRange,
    optimizer: OptimizerKind,
    framing: Framing,
) -> Result<LocalServerHandle> {
    spawn_local_server_with(range, optimizer, framing, ServeOpts::default())
}

/// [`spawn_local_server`] with explicit multi-tenancy options.
#[doc(hidden)]
pub fn spawn_local_server_with(
    range: ShardRange,
    optimizer: OptimizerKind,
    framing: Framing,
    opts: ServeOpts,
) -> Result<LocalServerHandle> {
    let listener = PsListener::bind(&SocketSpec::Tcp("127.0.0.1:0".into()))?;
    let spec = listener.local_spec()?;
    let server = Arc::new(ShardServer::with_opts(range, optimizer, framing, opts));
    let srv = Arc::clone(&server);
    let handle = std::thread::spawn(move || srv.serve(listener));
    Ok((spec, handle, server))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ps::pool::PoolStats;
    use crate::stats::ShardRows;

    fn range(begin: usize, end: usize) -> ShardRange {
        ShardRange { begin, end }
    }

    /// Two shard servers + a connected client + a local reference
    /// server with the same global shard count.
    fn cluster(
        optimizer: OptimizerKind,
        framing: Framing,
    ) -> (RemoteParamServer, ParamServer, Vec<std::thread::JoinHandle<Result<()>>>) {
        let (spec_a, h_a, _) = spawn_local_server(range(0, 2), optimizer, framing).unwrap();
        let (spec_b, h_b, _) = spawn_local_server(range(2, 4), optimizer, framing).unwrap();
        // deliberately hand the specs over in reverse order: routing
        // must follow the advertised ranges, not the argument order
        let remote = RemoteParamServer::connect(&[spec_b, spec_a], framing).unwrap();
        let local = ParamServer::new(4, Optimizer::new(optimizer));
        (remote, local, vec![h_a, h_b])
    }

    fn teardown(remote: RemoteParamServer, handles: Vec<std::thread::JoinHandle<Result<()>>>) {
        remote.shutdown_all().unwrap();
        drop(remote); // close client conns so handler threads exit
        for h in handles {
            h.join().unwrap().unwrap();
        }
    }

    #[test]
    fn shard_range_parses() {
        assert_eq!(ShardRange::parse("0..4").unwrap(), range(0, 4));
        assert_eq!(ShardRange::parse(" 2..3 ").unwrap(), range(2, 3));
        assert_eq!(ShardRange::parse("2..3").unwrap().count(), 1);
        assert_eq!(range(1, 5).to_string(), "1..5");
        assert!(ShardRange::parse("3..3").is_err());
        assert!(ShardRange::parse("5..2").is_err());
        assert!(ShardRange::parse("x..2").is_err());
        assert!(ShardRange::parse("4").is_err());
    }

    #[test]
    fn remote_store_matches_local_engine_bit_exact() {
        parity_roundtrip(Framing::Line);
    }

    /// The same parity sweep with the negotiated binary codec on the
    /// data plane: raw f32 bit patterns over fixed LE frames must be
    /// indistinguishable from the JSON decimal round-trip.
    #[test]
    fn remote_binary_codec_matches_local_engine_bit_exact() {
        parity_roundtrip(Framing::Binary);
    }

    fn parity_roundtrip(framing: Framing) {
        let (remote, local, handles) = cluster(OptimizerKind::Sgd, framing);
        if framing == Framing::Binary {
            assert_eq!(remote.codec(), WireCodec::Binary, "binary cluster grants binary");
        } else {
            assert_eq!(remote.codec(), WireCodec::Json);
        }
        let hyper = Hyper { lr: 0.5, momentum: 0.9 };
        let grad = [0.25f32, -1.5];

        for store in [&remote as &dyn ParamStore, &local as &dyn ParamStore] {
            for t in 0..2u32 {
                for k in 0..16u64 {
                    store.insert_row(0, t, k, vec![k as f32, t as f32]).unwrap();
                }
            }
            store.fork_branch(1, 0).unwrap();
            // row-at-a-time updates
            for k in 0..4u64 {
                store.apply_update(1, 0, k, &grad, hyper, None).unwrap();
            }
            // batched updates with duplicate keys (order preserved)
            let updates: Vec<(TableId, RowKey, &[f32])> = [3u64, 7, 3, 15, 9, 3]
                .iter()
                .map(|&k| (1u32, k, &grad[..]))
                .collect();
            store.apply_batch(1, &updates, hyper).unwrap();
        }

        // every row of both branches bit-exact between the two stores
        for b in [0u32, 1] {
            for t in 0..2u32 {
                for k in 0..16u64 {
                    let r = remote.read_row(b, t, k).unwrap().unwrap();
                    let l = ParamStore::read_row(&local, b, t, k).unwrap().unwrap();
                    let rbits: Vec<u32> = r.iter().map(|v| v.to_bits()).collect();
                    let lbits: Vec<u32> = l.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(rbits, lbits, "branch {b} row ({t},{k})");
                }
            }
        }
        assert_eq!(remote.read_row(0, 0, 99).unwrap(), None);
        assert_eq!(remote.branch_row_count(1).unwrap(), 32);
        assert_eq!(remote.live_branches().unwrap(), vec![0, 1]);

        // branch/pool accounting aggregates to the same census
        let rs = ParamStore::stats(&remote).unwrap();
        let ls = ParamStore::stats(&local).unwrap();
        assert_eq!(rs.store.forks, ls.store.forks);
        assert_eq!(rs.store.live_branches, ls.store.live_branches);
        assert_eq!(rs.store.peak_branches, ls.store.peak_branches);
        assert_eq!(rs.store.cow_buffer_copies, ls.store.cow_buffer_copies);
        assert_eq!(rs.pool.idle, ls.pool.idle);

        // free: last-owner reclamation happens server-side
        remote.free_branch(1).unwrap();
        ParamStore::free_branch(&local, 1).unwrap();
        let rs = ParamStore::stats(&remote).unwrap();
        let ls = ParamStore::stats(&local).unwrap();
        assert_eq!(rs.pool, ls.pool, "pool census after free");
        assert_eq!(remote.live_branches().unwrap(), vec![0]);

        teardown(remote, handles);
    }

    #[test]
    fn adarevision_accumulator_crosses_the_wire() {
        let (remote, local, handles) = cluster(OptimizerKind::AdaRevision, Framing::Length);
        let hyper = Hyper { lr: 0.1, momentum: 0.0 };
        for store in [&remote as &dyn ParamStore, &local as &dyn ParamStore] {
            store.insert_row(0, 0, 0, vec![1.0, -1.0]).unwrap();
            for _ in 0..3 {
                let (_, z_old) = store.read_row_with_accum(0, 0, 0).unwrap().unwrap();
                store
                    .apply_update(0, 0, 0, &[1.0, -1.0], hyper, z_old.as_deref())
                    .unwrap();
            }
        }
        let r = remote.read_row(0, 0, 0).unwrap().unwrap();
        let l = ParamStore::read_row(&local, 0, 0, 0).unwrap().unwrap();
        assert_eq!(
            r.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            l.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        teardown(remote, handles);
    }

    #[test]
    fn errors_and_missing_rows_propagate() {
        let (remote, _local, handles) = cluster(OptimizerKind::Sgd, Framing::Line);
        remote.insert_row(0, 0, 0, vec![1.0]).unwrap();
        // duplicate fork child
        remote.fork_branch(1, 0).unwrap();
        let err = remote.fork_branch(1, 0).unwrap_err();
        assert!(err.to_string().contains("already exists"), "{err}");
        // update of a missing row reports table/key
        let err = remote
            .apply_update(0, 0, 99, &[1.0], Hyper::default(), None)
            .unwrap_err();
        assert!(err.to_string().contains("99"), "{err}");
        // batch with a missing row errors too
        let grad = [1.0f32];
        let updates: Vec<(TableId, RowKey, &[f32])> = vec![(0, 0, &grad[..]), (0, 99, &grad[..])];
        assert!(remote.apply_batch(0, &updates, Hyper::default()).is_err());
        teardown(remote, handles);
    }

    #[test]
    fn fork_replicates_to_rowless_servers() {
        // One row only: it lands on exactly one of the two servers,
        // yet fork/free must succeed on both (ensure_branch(0) gives
        // the rowless server a live root).
        let (remote, _local, handles) = cluster(OptimizerKind::Sgd, Framing::Line);
        remote.insert_row(0, 0, 0, vec![1.0]).unwrap();
        remote.fork_branch(1, 0).unwrap();
        assert_eq!(remote.branch_row_count(1).unwrap(), 1);
        assert_eq!(remote.live_branches().unwrap(), vec![0, 1]);
        remote.free_branch(1).unwrap();
        assert_eq!(remote.live_branches().unwrap(), vec![0]);
        teardown(remote, handles);
    }

    #[test]
    fn connect_rejects_bad_topologies() {
        // overlap: 0..2 + 1..3
        let (a, ha, _) =
            spawn_local_server(range(0, 2), OptimizerKind::Sgd, Framing::Line).unwrap();
        let (b, hb, _) =
            spawn_local_server(range(1, 3), OptimizerKind::Sgd, Framing::Line).unwrap();
        assert!(RemoteParamServer::connect(&[a.clone(), b.clone()], Framing::Line).is_err());
        // gap: 0..2 alone claims to be the whole space 0..2 — fine;
        // but 2..4 alone leaves 0..2 uncovered
        assert!(RemoteParamServer::connect(&[b.clone()], Framing::Line).is_err());
        assert!(RemoteParamServer::connect(&[a.clone()], Framing::Line).is_ok());
        // optimizer mismatch
        let (c, hc, _) =
            spawn_local_server(range(2, 3), OptimizerKind::Adam, Framing::Line).unwrap();
        assert!(RemoteParamServer::connect(&[a.clone(), c.clone()], Framing::Line).is_err());
        for spec in [a, b, c] {
            let remote = RemoteParamServer::connect(
                &[SocketSpec::parse("127.0.0.1:1").unwrap()],
                Framing::Line,
            );
            assert!(remote.is_err()); // nothing listens on port 1
            let mut conn = spec.connect(Framing::Line).unwrap();
            conn.send(&encode_ps_request(&PsRequest::Shutdown)).unwrap();
            let _ = conn.recv();
        }
        for h in [ha, hb, hc] {
            h.join().unwrap().unwrap();
        }
    }

    /// Negotiation edge: a binary client against a server that is not
    /// running binary framing gets a clean typed error at connect —
    /// never a silent downgrade or a desynchronized stream.  (Length
    /// framing is byte-compatible with binary framing on the wire, so
    /// the handshake itself works; the grant is what must refuse.)
    #[test]
    fn binary_client_rejected_by_json_only_server() {
        let (spec, handle, _srv) =
            spawn_local_server(range(0, 1), OptimizerKind::Sgd, Framing::Length).unwrap();
        let err = RemoteParamServer::connect(&[spec.clone()], Framing::Binary).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("granted the json codec"), "{msg}");
        assert!(msg.contains("one --framing"), "{msg}");
        // the server is unharmed; shut it down over its own framing
        let remote = RemoteParamServer::connect(&[spec], Framing::Length).unwrap();
        remote.shutdown_all().unwrap();
        drop(remote);
        handle.join().unwrap().unwrap();
    }

    /// A garbage connection — unframeable bytes, unknown binary
    /// opcodes, truncated frames — must neither panic the server nor
    /// disturb well-behaved clients (the accept/serve loop survives;
    /// regression test for the old `return Err(e)` accept loop).
    #[test]
    fn garbage_connections_do_not_kill_the_server() {
        let (spec, handle, server) =
            spawn_local_server(range(0, 1), OptimizerKind::Sgd, Framing::Binary).unwrap();
        // 1) raw unframeable garbage: a 4 GiB length header
        if let SocketSpec::Tcp(addr) = &spec {
            use std::io::Write as _;
            let mut raw = std::net::TcpStream::connect(addr).unwrap();
            raw.write_all(&[0xff; 64]).unwrap();
            // the server drops this connection; give it a moment
        }
        let mut conn = spec.connect(Framing::Binary).unwrap();
        // 2) well-framed unknown opcode: binary error reply, same conn
        conn.send_bytes(&[0x1f]).unwrap();
        let frame = conn.recv_bytes().unwrap().unwrap();
        assert!(binwire::is_binary_frame(&frame));
        let reply = binwire::decode_reply(&frame).unwrap();
        assert!(matches!(reply, PsReply::Err { .. }), "{reply:?}");
        // 3) well-framed truncated binary request: error reply too
        let mut full = Vec::new();
        binwire::encode_request(
            &PsRequest::ReadRow {
                session: 0,
                branch: 0,
                table: 0,
                key: 1,
                with_accum: false,
            },
            &mut full,
        )
        .unwrap();
        conn.send_bytes(&full[..full.len() - 2]).unwrap();
        let frame = conn.recv_bytes().unwrap().unwrap();
        let reply = binwire::decode_reply(&frame).unwrap();
        assert!(matches!(reply, PsReply::Err { .. }), "{reply:?}");
        // 4) a frame that is neither binary nor UTF-8: JSON error
        conn.send_bytes(&[0xc3, 0x28, 0xa0, 0xa1]).unwrap();
        let frame = conn.recv_bytes().unwrap().unwrap();
        assert!(!binwire::is_binary_frame(&frame));
        let reply = decode_ps_reply(std::str::from_utf8(&frame).unwrap()).unwrap();
        assert!(matches!(reply, PsReply::Err { .. }), "{reply:?}");
        // ...and the server still serves real clients
        let remote = RemoteParamServer::connect(&[spec], Framing::Binary).unwrap();
        remote.insert_row(0, 0, 0, vec![2.5]).unwrap();
        assert_eq!(remote.read_row(0, 0, 0).unwrap().unwrap(), vec![2.5]);
        drop(conn);
        remote.shutdown_all().unwrap();
        drop(remote);
        handle.join().unwrap().unwrap();
        assert!(server.metrics().conns_accepted.load(Ordering::Relaxed) >= 3);
    }

    /// The thread-count acceptance test: ≥64 simultaneously-open
    /// client connections are all served by the event loop's bounded
    /// worker pool — the server never spawns per-connection threads.
    #[cfg(unix)]
    #[test]
    fn event_loop_serves_64_connections_with_bounded_worker_pool() {
        let (spec, handle, server) =
            spawn_local_server(range(0, 1), OptimizerKind::Sgd, Framing::Binary).unwrap();
        let mut conns: Vec<Conn> = (0..64)
            .map(|_| spec.connect(Framing::Binary).unwrap())
            .collect();
        // every connection completes a handshake while all 64 are open
        for conn in &mut conns {
            let hello = PsRequest::Hello {
                codec: WireCodec::Binary,
                session: None,
            };
            let mut buf = Vec::new();
            binwire::encode_request(&hello, &mut buf).unwrap();
            conn.send_bytes(&buf).unwrap();
            let frame = conn.recv_bytes().unwrap().unwrap();
            let reply = binwire::decode_reply(&frame).unwrap();
            assert!(
                matches!(
                    reply,
                    PsReply::Hello {
                        codec: WireCodec::Binary,
                        ..
                    }
                ),
                "{reply:?}"
            );
        }
        let peak = server.metrics().peak_conns.load(Ordering::Relaxed);
        assert!(peak >= 64, "all 64 conns open at once, peak {peak}");
        let workers = server.metrics().workers.load(Ordering::Relaxed);
        assert!(
            (1..=8).contains(&workers),
            "worker pool must be O(cores), not O(conns): {workers}"
        );
        drop(conns);
        let remote = RemoteParamServer::connect(&[spec], Framing::Binary).unwrap();
        remote.shutdown_all().unwrap();
        drop(remote);
        handle.join().unwrap().unwrap();
    }

    #[test]
    fn malformed_frames_get_error_replies_not_disconnects() {
        let (spec, handle, _srv) =
            spawn_local_server(range(0, 1), OptimizerKind::Sgd, Framing::Line).unwrap();
        let mut conn = spec.connect(Framing::Line).unwrap();
        conn.send("this is not a request").unwrap();
        let reply = decode_ps_reply(&conn.recv_expect().unwrap()).unwrap();
        let PsReply::Err { message } = reply else {
            panic!("wanted an error reply")
        };
        assert!(message.contains("bad request"), "{message}");
        // the connection is still usable afterwards; a bare JSON
        // hello (no codec field — an old peer) negotiates JSON
        conn.send("{\"op\":\"hello\"}").unwrap();
        let reply = decode_ps_reply(&conn.recv_expect().unwrap()).unwrap();
        assert!(matches!(
            reply,
            PsReply::Hello {
                codec: WireCodec::Json,
                ..
            }
        ));
        conn.send(&encode_ps_request(&PsRequest::Shutdown)).unwrap();
        let _ = conn.recv();
        drop(conn);
        handle.join().unwrap().unwrap();
    }

    #[test]
    fn batched_reads_match_row_reads_and_bound_rpcs() {
        let (remote, _local, handles) = cluster(OptimizerKind::AdaRevision, Framing::Length);
        let hyper = Hyper { lr: 0.1, momentum: 0.0 };
        for t in 0..2u32 {
            for k in 0..16u64 {
                remote.insert_row(0, t, k, vec![k as f32, t as f32]).unwrap();
            }
        }
        // accumulate AdaRevision slot state so the accum variant has
        // something non-trivial to carry
        for k in 0..16u64 {
            let (_, z) = remote.read_row_with_accum(0, 0, k).unwrap().unwrap();
            remote
                .apply_update(0, 0, k, &[1.0, -1.0], hyper, z.as_deref())
                .unwrap();
        }
        let mut keys: Vec<(TableId, RowKey)> = Vec::new();
        for t in 0..2u32 {
            for k in 0..16u64 {
                keys.push((t, k));
            }
        }
        keys.push((0, 99)); // missing row rides along as None
        let before = ParamStore::stats(&remote).unwrap().store.read_rpcs;
        let rows = remote.read_rows(0, &keys, true).unwrap();
        let after = ParamStore::stats(&remote).unwrap().store.read_rpcs;
        // one ReadRows RPC per shard server, however many keys
        assert_eq!(after - before, 2);
        assert_eq!(rows.len(), keys.len());
        for (&(t, k), got) in keys.iter().zip(&rows) {
            assert_eq!(
                got,
                &remote.read_row_with_accum(0, t, k).unwrap(),
                "row ({t},{k})"
            );
        }
        // server-side batched-read accounting sums to the key count
        let batched: u64 = remote
            .probe_stats()
            .unwrap()
            .iter()
            .map(|p| p.server.reads_batched)
            .sum();
        assert_eq!(batched, keys.len() as u64);
        teardown(remote, handles);
    }

    #[test]
    fn pooled_connections_serve_concurrent_workers() {
        // 4 threads of batched reads against the same two servers: the
        // per-worker pool must hand each thread its own socket (the old
        // single mutex-serialized conn still passes this test — the
        // pool is a perf property — but any frame interleaving bug
        // would corrupt replies here).
        let (remote, _local, handles) = cluster(OptimizerKind::Sgd, Framing::Line);
        for k in 0..64u64 {
            remote.insert_row(0, 0, k, vec![k as f32]).unwrap();
        }
        let keys: Vec<(TableId, RowKey)> = (0..64u64).map(|k| (0u32, k)).collect();
        std::thread::scope(|s| {
            for _ in 0..4 {
                let remote = &remote;
                let keys = &keys;
                s.spawn(move || {
                    for _ in 0..8 {
                        let rows = remote.read_rows(0, keys, false).unwrap();
                        for (&(_, k), row) in keys.iter().zip(&rows) {
                            assert_eq!(row.as_ref().unwrap().0[0], k as f32);
                        }
                    }
                });
            }
        });
        teardown(remote, handles);
    }

    #[test]
    fn checkpoint_survives_server_death_and_fails_closed_on_corruption() {
        let dir = std::env::temp_dir().join(format!(
            "mltuner-remote-ckpt-{}-{}",
            std::process::id(),
            std::thread::current().name().unwrap_or("t").len()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();

        // first cluster: train a bit, checkpoint branch 1, then die
        let (remote, _local, handles) = cluster(OptimizerKind::AdaRevision, Framing::Line);
        let hyper = Hyper { lr: 0.1, momentum: 0.0 };
        for k in 0..24u64 {
            remote.insert_row(0, 0, k, vec![k as f32, -1.0]).unwrap();
        }
        remote.fork_branch(1, 0).unwrap();
        for k in 0..24u64 {
            let (_, z) = remote.read_row_with_accum(1, 0, k).unwrap().unwrap();
            remote.apply_update(1, 0, k, &[0.5, 0.5], hyper, z.as_deref()).unwrap();
        }
        let metas = remote.checkpoint_branch(1, &dir).unwrap();
        assert_eq!(metas.len(), 4, "two servers x two local shards");
        assert_eq!(metas.iter().map(|m| m.rows).sum::<u64>(), 24);
        let want: Vec<Vec<u32>> = (0..24u64)
            .map(|k| {
                remote
                    .read_row(1, 0, k)
                    .unwrap()
                    .unwrap()
                    .iter()
                    .map(|v| v.to_bits())
                    .collect()
            })
            .collect();
        teardown(remote, handles); // the whole first cluster dies

        // second cluster (fresh processes, same topology): restore
        let (remote, _local, handles) = cluster(OptimizerKind::AdaRevision, Framing::Line);
        for k in 0..24u64 {
            remote.insert_row(0, 0, k, vec![k as f32, -1.0]).unwrap();
        }
        let rows = remote.restore_branch(1, &dir).unwrap();
        assert_eq!(rows, 24);
        for (k, want) in want.iter().enumerate() {
            let got: Vec<u32> = remote
                .read_row(1, 0, k as u64)
                .unwrap()
                .unwrap()
                .iter()
                .map(|v| v.to_bits())
                .collect();
            assert_eq!(&got, want, "row {k} after cross-process restore");
        }

        // corrupt one segment: the restore must fail closed with the
        // restored state intact on every server
        let victim = dir.join(&metas[1].file);
        let mut bytes = std::fs::read(&victim).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        std::fs::write(&victim, &bytes).unwrap();
        let err = remote.restore_branch(1, &dir).unwrap_err();
        // phase 1 (verify) catches it, so NO server installed anything
        assert!(err.to_string().contains("verify failed"), "{err}");
        for (k, want) in want.iter().enumerate() {
            let got: Vec<u32> = remote
                .read_row(1, 0, k as u64)
                .unwrap()
                .unwrap()
                .iter()
                .map(|v| v.to_bits())
                .collect();
            assert_eq!(&got, want, "row {k} must be unchanged after failed restore");
        }
        assert_eq!(remote.live_branches().unwrap(), vec![0, 1]);
        teardown(remote, handles);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stats_probe_reports_per_server_batching() {
        let (remote, _local, handles) = cluster(OptimizerKind::Sgd, Framing::Line);
        for k in 0..32u64 {
            remote.insert_row(0, 0, k, vec![0.0]).unwrap();
        }
        let grad = [1.0f32];
        let updates: Vec<(TableId, RowKey, &[f32])> =
            (0..32u64).map(|k| (0u32, k, &grad[..])).collect();
        remote.apply_batch(0, &updates, Hyper::default()).unwrap();
        let probes = remote.probe_stats().unwrap();
        assert_eq!(probes.len(), 2);
        let batched: u64 = probes.iter().map(|p| p.server.batched_rows).sum();
        assert_eq!(batched, 32, "every routed row lands in some server's batch");
        assert!(probes.iter().all(|p| p.server.batch_calls == 1));
        // per-shard rows re-addressed to *global* shard ids: the two
        // servers' shard lists must tile 0..4 with no overlap
        let mut shard_ids: Vec<u64> = probes
            .iter()
            .flat_map(|p| p.shards.iter().map(|s| s.shard))
            .collect();
        shard_ids.sort_unstable();
        assert_eq!(shard_ids, vec![0, 1, 2, 3]);
        let applied: u64 = probes
            .iter()
            .flat_map(|p| p.shards.iter().map(|s| s.rows_applied))
            .sum();
        assert_eq!(applied, 32, "per-shard throughput sums to the batch");
        // PoolStats default sanity: nothing was materialized yet
        assert_eq!(ParamStore::stats(&remote).unwrap().pool, PoolStats::default());
        teardown(remote, handles);
    }

    /// The push side of the observability plane: a subscriber gets an
    /// ack, then periodic `StatsDelta` frames it never asked for
    /// again, each monotonic relative to the previous one and carrying
    /// globally-addressed shard throughput.
    #[cfg(unix)]
    #[test]
    fn subscribers_receive_pushed_deltas() {
        let (spec, handle, _srv) =
            spawn_local_server(range(0, 2), OptimizerKind::Sgd, Framing::Line).unwrap();
        let remote = RemoteParamServer::connect(&[spec.clone()], Framing::Line).unwrap();
        for k in 0..8u64 {
            remote.insert_row(0, 0, k, vec![1.0]).unwrap();
        }
        let mut conn = spec.connect(Framing::Line).unwrap();
        conn.send(&encode_ps_request(&PsRequest::SubscribeStats { interval_ms: 50 }))
            .unwrap();
        let ack = decode_ps_reply(&conn.recv_expect().unwrap()).unwrap();
        assert!(matches!(ack, PsReply::Ok), "{ack:?}");
        let collector = StatsCollector::new(1);
        for _ in 0..2 {
            let frame = conn.recv_expect().unwrap();
            let PsReply::StatsDelta(d) = decode_ps_reply(&frame).unwrap() else {
                panic!("wanted a pushed StatsDelta");
            };
            assert_eq!(d.version, crate::stats::SCHEMA_VERSION);
            assert_eq!(d.shards.len(), 2);
            collector.ingest(0, d).unwrap();
        }
        assert_eq!(collector.servers_reporting(), 1);
        let view = collector.view();
        assert_eq!(view.servers, 1);
        assert!(view.snapshot.wire.bytes_rx > 0, "{:?}", view.snapshot.wire);
        drop(conn);
        remote.shutdown_all().unwrap();
        drop(remote);
        handle.join().unwrap().unwrap();
    }

    /// Monotonic-merge regression: a frame that rewinds any counter is
    /// rejected wholesale (the previous good frame survives), and
    /// concurrent per-server writers never trip each other's checks.
    #[test]
    fn stats_collector_rejects_backwards_counters() {
        let collector = StatsCollector::new(2);
        let mut d = ServerDelta::default();
        d.server.rows_applied = 10;
        collector.ingest(0, d.clone()).unwrap();
        let mut rewound = d.clone();
        rewound.server.rows_applied = 5;
        let err = collector.ingest(0, rewound).unwrap_err();
        assert!(err.to_string().contains("went backwards"), "{err}");
        // the rejected frame must not have replaced the good one
        assert_eq!(collector.view().snapshot.server.rows_applied, 10);
        // out-of-range server index is an error, not a panic
        assert!(collector.ingest(7, d).is_err());

        // racing writers: each server's stream advances independently
        std::thread::scope(|s| {
            for server in 0..2usize {
                let collector = &collector;
                s.spawn(move || {
                    for i in 0..100u64 {
                        let mut d = ServerDelta::default();
                        d.server.rows_applied = 10 + i;
                        d.shards = vec![ShardRows {
                            shard: server as u64,
                            rows_applied: 10 + i,
                            rows_read: i,
                        }];
                        collector.ingest(server, d).unwrap();
                    }
                });
            }
        });
        assert_eq!(collector.servers_reporting(), 2);
        let view = collector.view();
        assert_eq!(view.snapshot.server.rows_applied, 2 * 109);
        assert_eq!(view.shards.len(), 2);
    }

    /// Satellite regression: the latest-per-`(episode, trial)` map is
    /// bounded **per session** — one tenant publishing hundreds of
    /// trials evicts only its own oldest entries, never a
    /// co-tenant's (the cap used to be global).
    #[test]
    fn trial_map_is_bounded_per_session() {
        let server = ShardServer::new(range(0, 1), OptimizerKind::Sgd, Framing::Line);
        let event = |session: SessionId, trial: u32| TrialEvent {
            session,
            trial,
            ..TrialEvent::default()
        };
        for trial in 0..3 {
            server.record_trial(event(7, trial));
        }
        let noisy = MAX_TRACKED_TRIALS as u32 + 10;
        for trial in 0..noisy {
            server.record_trial(event(1, trial));
        }
        let trials = server.delta().trials;
        let count_of = |s: SessionId| trials.iter().filter(|t| t.session == s).count();
        assert_eq!(count_of(1), MAX_TRACKED_TRIALS, "noisy session capped");
        assert_eq!(count_of(7), 3, "quiet session untouched by the noisy one");
        // latest-wins inside the cap: newest survive, oldest evicted
        assert!(trials.iter().any(|t| t.session == 1 && t.trial == noisy - 1));
        assert!(!trials.iter().any(|t| t.session == 1 && t.trial == 0));
    }

    /// Two named sessions on one cluster get fully disjoint branch
    /// namespaces — same user-visible branch ids, different rows —
    /// and one session's stale-branch cleanup or teardown cannot
    /// touch the other's branches (the `with_store` regression).
    #[test]
    fn sessions_scope_branch_namespaces_end_to_end() {
        let (spec_a, h_a, _) =
            spawn_local_server(range(0, 2), OptimizerKind::Sgd, Framing::Line).unwrap();
        let (spec_b, h_b, _) =
            spawn_local_server(range(2, 4), OptimizerKind::Sgd, Framing::Line).unwrap();
        let specs = [spec_a, spec_b];
        let alice =
            RemoteParamServer::connect_session(&specs, Framing::Line, Some("alice")).unwrap();
        let bob = RemoteParamServer::connect_session(&specs, Framing::Line, Some("bob")).unwrap();

        // same user branch ids, disjoint state (even user branch 0:
        // each namespace is born with its own root)
        for k in 0..8u64 {
            alice.insert_row(0, 0, k, vec![1.0]).unwrap();
            bob.insert_row(0, 0, k, vec![2.0]).unwrap();
        }
        alice.fork_branch(1, 0).unwrap();
        bob.fork_branch(1, 0).unwrap();
        assert_eq!(alice.read_row(1, 0, 3).unwrap().unwrap(), vec![1.0]);
        assert_eq!(bob.read_row(1, 0, 3).unwrap().unwrap(), vec![2.0]);

        // each branch census is scoped to its own namespace
        assert_eq!(alice.live_branches().unwrap(), vec![0, 1]);
        assert_eq!(bob.live_branches().unwrap(), vec![0, 1]);
        assert_eq!(alice.branch_row_count(1).unwrap(), 8);

        // the attach-time stale-branch sweep (`free every live branch
        // != 0`) now frees bob's leftovers only — alice's branch 1
        // survives bob's cleanup
        for b in bob.live_branches().unwrap() {
            if b != 0 {
                bob.free_branch(b).unwrap();
            }
        }
        assert_eq!(bob.live_branches().unwrap(), vec![0]);
        assert_eq!(alice.read_row(1, 0, 3).unwrap().unwrap(), vec![1.0]);

        // graceful teardown frees exactly alice's namespace
        alice.end_session().unwrap();
        assert_eq!(bob.read_row(0, 0, 3).unwrap().unwrap(), vec![2.0]);

        bob.shutdown_all().unwrap();
        drop(alice);
        drop(bob);
        h_a.join().unwrap().unwrap();
        h_b.join().unwrap().unwrap();
    }
}
