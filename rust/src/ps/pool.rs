//! User-level memory pool for branch data (§4.6).
//!
//! Under the copy-on-write storage layer (see [`super::storage`]) the
//! pool is no longer on the *fork* path — forks copy no buffers at
//! all.  It serves the two remaining buffer-churn paths of the tuning
//! loop:
//!
//! * **first-write materialization** ([`MemoryPool::alloc_entry_copy`]):
//!   when a branch first writes a shared row, its private copy's
//!   buffers are drawn from here;
//! * **last-owner reclamation** ([`MemoryPool::recycle_entry`]): when
//!   the final branch referencing a row is freed, the row's buffers
//!   are parked here for future materializations.
//!
//! Pooling keeps steady-state tuning (fork → write some rows → free)
//! allocation-free after warm-up and avoids allocator churn, and its
//! `idle` statistic is an exact census of reclaimed-but-unreused
//! buffers — the invariant the proptest suite checks.
//!
//! Under the concurrent engine each server shard owns a **private pool
//! arena** guarded by the shard's lock (see [`super`]): a buffer is
//! materialized and reclaimed by the same shard, so no cross-shard
//! synchronization is needed and per-arena censuses stay exact.  The
//! server-wide view is the field-wise sum ([`PoolStats::accumulate`]).

use std::collections::BTreeMap;

use super::storage::Entry;

/// Size-bucketed free list of `Vec<f32>` buffers.
#[derive(Debug, Default)]
pub struct MemoryPool {
    free: BTreeMap<usize, Vec<Vec<f32>>>,
    stats: PoolStats,
}

#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PoolStats {
    /// Buffers handed out that were reused from the free list.
    pub reused: u64,
    /// Buffers that had to be freshly allocated.
    pub allocated: u64,
    /// Buffers currently parked in the free list.
    pub idle: u64,
    /// f32 slots currently parked in the free list.
    pub idle_len: u64,
}

impl PoolStats {
    /// Field-wise accumulation, used to aggregate the per-shard arenas
    /// into a server-wide view.  Exact because every buffer's whole
    /// alloc/recycle/reuse life happens inside one arena.
    pub fn accumulate(&mut self, other: PoolStats) {
        self.reused += other.reused;
        self.allocated += other.allocated;
        self.idle += other.idle;
        self.idle_len += other.idle_len;
    }
}

impl MemoryPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Get a zero-length buffer with capacity ≥ `len`, preferring an
    /// idle buffer of exactly-matching capacity bucket.  Zero-length
    /// requests are not pooled and not counted, mirroring
    /// [`MemoryPool::recycle`]'s zero-capacity skip — this keeps the
    /// allocated/idle conservation exact.
    pub fn alloc(&mut self, len: usize) -> Vec<f32> {
        if len == 0 {
            return Vec::new();
        }
        if let Some(bucket) = self.free.get_mut(&len) {
            if let Some(mut buf) = bucket.pop() {
                self.stats.reused += 1;
                self.stats.idle -= 1;
                self.stats.idle_len -= len as u64;
                buf.clear();
                return buf;
            }
        }
        self.stats.allocated += 1;
        Vec::with_capacity(len)
    }

    /// Allocate and fill with a copy of `src` (the copy-on-write
    /// materialization hot path).
    pub fn alloc_copy(&mut self, src: &[f32]) -> Vec<f32> {
        let mut buf = self.alloc(src.len());
        buf.extend_from_slice(src);
        buf
    }

    /// Materialize a private copy of a whole entry — row data, every
    /// optimizer slot buffer, and the step counter.
    pub fn alloc_entry_copy(&mut self, src: &Entry) -> Entry {
        Entry {
            data: self.alloc_copy(&src.data),
            slots: src.slots.iter().map(|s| self.alloc_copy(s)).collect(),
            step: src.step,
        }
    }

    /// Return a buffer to the pool for future branches.
    pub fn recycle(&mut self, buf: Vec<f32>) {
        let cap = buf.capacity();
        if cap == 0 {
            return;
        }
        self.stats.idle += 1;
        self.stats.idle_len += cap as u64;
        self.free.entry(cap).or_default().push(buf);
    }

    /// Reclaim all buffers of a last-owner entry.
    pub fn recycle_entry(&mut self, entry: Entry) {
        self.recycle(entry.data);
        for s in entry.slots {
            self.recycle(s);
        }
    }

    pub fn stats(&self) -> PoolStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuses_recycled_buffers() {
        let mut pool = MemoryPool::new();
        let a = pool.alloc_copy(&[1.0, 2.0, 3.0]);
        assert_eq!(pool.stats().allocated, 1);
        pool.recycle(a);
        assert_eq!(pool.stats().idle, 1);
        let b = pool.alloc(3);
        assert_eq!(pool.stats().reused, 1);
        assert_eq!(pool.stats().idle, 0);
        assert!(b.is_empty() && b.capacity() >= 3);
    }

    #[test]
    fn alloc_copy_copies() {
        let mut pool = MemoryPool::new();
        let src = vec![5.0f32; 16];
        let buf = pool.alloc_copy(&src);
        assert_eq!(buf, src);
    }

    #[test]
    fn entry_copy_and_recycle_roundtrip() {
        let mut pool = MemoryPool::new();
        let src = Entry {
            data: vec![1.0; 8],
            slots: vec![vec![2.0; 8], vec![3.0; 8]],
            step: 7,
        };
        let copy = pool.alloc_entry_copy(&src);
        assert_eq!(copy.data, src.data);
        assert_eq!(copy.slots, src.slots);
        assert_eq!(copy.step, 7);
        assert_eq!(pool.stats().allocated, 3);
        pool.recycle_entry(copy);
        assert_eq!(pool.stats().idle, 3);
        // the next materialization is allocation-free
        let again = pool.alloc_entry_copy(&src);
        assert_eq!(pool.stats().allocated, 3);
        assert_eq!(pool.stats().reused, 3);
        assert_eq!(again.data, src.data);
    }

    #[test]
    fn fork_free_cycle_never_leaks_allocations() {
        // Steady-state materialize/reclaim must stop allocating after
        // warm-up: the invariant behind §4.6's "reclaimed to the
        // memory pool".
        let mut pool = MemoryPool::new();
        let src = vec![0.5f32; 128];
        let mut held = Vec::new();
        for _ in 0..3 {
            held.push(pool.alloc_copy(&src)); // warm-up: 3 live buffers
        }
        let after_warmup = pool.stats().allocated;
        for _ in 0..100 {
            let b = pool.alloc_copy(&src);
            pool.recycle(held.pop().unwrap());
            held.push(b);
        }
        assert_eq!(pool.stats().allocated, after_warmup + 1);
    }

    #[test]
    fn stats_accumulate_fieldwise() {
        let mut a = PoolStats {
            reused: 1,
            allocated: 2,
            idle: 3,
            idle_len: 4,
        };
        a.accumulate(PoolStats {
            reused: 10,
            allocated: 20,
            idle: 30,
            idle_len: 40,
        });
        assert_eq!(a, PoolStats {
            reused: 11,
            allocated: 22,
            idle: 33,
            idle_len: 44,
        });
    }

    #[test]
    fn zero_capacity_buffers_are_dropped() {
        let mut pool = MemoryPool::new();
        pool.recycle(Vec::new());
        assert_eq!(pool.stats().idle, 0);
    }

    #[test]
    fn zero_length_allocs_are_uncounted() {
        // Symmetry with recycle()'s zero-capacity skip: an alloc(0) /
        // recycle roundtrip must leave the conservation counters
        // untouched (idle == allocated stays provable).
        let mut pool = MemoryPool::new();
        let buf = pool.alloc(0);
        assert!(buf.is_empty());
        assert_eq!(pool.stats().allocated, 0);
        let copy = pool.alloc_copy(&[]);
        pool.recycle(copy);
        assert_eq!(pool.stats(), PoolStats::default());
    }
}
