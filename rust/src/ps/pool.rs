//! User-level memory pool for branch data (§4.6).
//!
//! When a branch is forked the parameter server allocates its storage
//! from this pool; when a branch is freed all its buffers are reclaimed
//! for future branches.  Pooling keeps fork latency at memcpy cost and
//! avoids allocator churn in the tuning loop, where branches are forked
//! and freed continuously.

use std::collections::BTreeMap;

/// Size-bucketed free list of `Vec<f32>` buffers.
#[derive(Debug, Default)]
pub struct MemoryPool {
    free: BTreeMap<usize, Vec<Vec<f32>>>,
    stats: PoolStats,
}

#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PoolStats {
    /// Buffers handed out that were reused from the free list.
    pub reused: u64,
    /// Buffers that had to be freshly allocated.
    pub allocated: u64,
    /// Buffers currently parked in the free list.
    pub idle: u64,
    /// f32 slots currently parked in the free list.
    pub idle_len: u64,
}

impl MemoryPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Get a zero-length buffer with capacity ≥ `len`, preferring an
    /// idle buffer of exactly-matching capacity bucket.
    pub fn alloc(&mut self, len: usize) -> Vec<f32> {
        if let Some(bucket) = self.free.get_mut(&len) {
            if let Some(mut buf) = bucket.pop() {
                self.stats.reused += 1;
                self.stats.idle -= 1;
                self.stats.idle_len -= len as u64;
                buf.clear();
                return buf;
            }
        }
        self.stats.allocated += 1;
        Vec::with_capacity(len)
    }

    /// Allocate and fill with a copy of `src` (the fork hot path).
    pub fn alloc_copy(&mut self, src: &[f32]) -> Vec<f32> {
        let mut buf = self.alloc(src.len());
        buf.extend_from_slice(src);
        buf
    }

    /// Return a buffer to the pool for future branches.
    pub fn recycle(&mut self, buf: Vec<f32>) {
        let cap = buf.capacity();
        if cap == 0 {
            return;
        }
        self.stats.idle += 1;
        self.stats.idle_len += cap as u64;
        self.free.entry(cap).or_default().push(buf);
    }

    pub fn stats(&self) -> PoolStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuses_recycled_buffers() {
        let mut pool = MemoryPool::new();
        let a = pool.alloc_copy(&[1.0, 2.0, 3.0]);
        assert_eq!(pool.stats().allocated, 1);
        pool.recycle(a);
        assert_eq!(pool.stats().idle, 1);
        let b = pool.alloc(3);
        assert_eq!(pool.stats().reused, 1);
        assert_eq!(pool.stats().idle, 0);
        assert!(b.is_empty() && b.capacity() >= 3);
    }

    #[test]
    fn alloc_copy_copies() {
        let mut pool = MemoryPool::new();
        let src = vec![5.0f32; 16];
        let buf = pool.alloc_copy(&src);
        assert_eq!(buf, src);
    }

    #[test]
    fn fork_free_cycle_never_leaks_allocations() {
        // Steady-state fork/free must stop allocating after warm-up:
        // the invariant behind §4.6's "reclaimed to the memory pool".
        let mut pool = MemoryPool::new();
        let src = vec![0.5f32; 128];
        let mut held = Vec::new();
        for _ in 0..3 {
            held.push(pool.alloc_copy(&src)); // warm-up: 3 live buffers
        }
        let after_warmup = pool.stats().allocated;
        for _ in 0..100 {
            let b = pool.alloc_copy(&src);
            pool.recycle(held.pop().unwrap());
            held.push(b);
        }
        assert_eq!(pool.stats().allocated, after_warmup + 1);
    }

    #[test]
    fn zero_capacity_buffers_are_dropped() {
        let mut pool = MemoryPool::new();
        pool.recycle(Vec::new());
        assert_eq!(pool.stats().idle, 0);
    }
}
