//! Worker-side parameter cache with SSP staleness (§2.2, §4.6).
//!
//! Each ML worker keeps a local cache of parameter rows.  Under a
//! bounded-staleness (SSP) consistency model a cached row read at clock
//! `c_read` may be used at clock `c` as long as `c - c_read <= s`, where
//! `s` is the data-staleness tunable.
//!
//! Per §4.6, MLtuner runs only one branch at a time, so the cache is
//! **shared between branches and cleared on every branch switch** —
//! sharing the cache memory (instead of duplicating it per branch) is
//! what makes the GPU-memory-constrained systems fit.
//!
//! The server's copy-on-write branch storage is invisible here: a
//! cached row is a worker-private value copy, so server-side
//! materialization never invalidates it.  Staleness (SSP) and branch
//! switches remain the only two invalidation sources.
//!
//! Under the concurrent engine each cache is **owned by exactly one
//! worker thread per clock** (the gather phase hands each spawned
//! thread `&mut` to its own cache), so the cache itself needs no
//! internal locking — `Send` ownership transfer is the whole
//! synchronization story, mirroring IterStore's thread-private caches.

use std::collections::hash_map::Entry as MapEntry;
use std::collections::HashMap;

use crate::comm::{BranchId, Clock};

use super::storage::{RowKey, TableId};

#[derive(Debug, Clone)]
struct CachedRow {
    data: Vec<f32>,
    /// Clock at which this row was fetched from the server.
    fetched_at: Clock,
}

/// Cache statistics (hit ratio is a §Perf metric).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub stale_evictions: u64,
    pub branch_clears: u64,
}

/// One worker's parameter cache.
#[derive(Debug, Default)]
pub struct WorkerCache {
    rows: HashMap<(TableId, RowKey), CachedRow>,
    current_branch: Option<BranchId>,
    stats: CacheStats,
}

impl WorkerCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Point the cache at `branch`; clears it if the branch changed
    /// (branches share the cache memory, §4.6).
    pub fn switch_branch(&mut self, branch: BranchId) {
        if self.current_branch != Some(branch) {
            if self.current_branch.is_some() {
                self.stats.branch_clears += 1;
            }
            self.rows.clear();
            self.current_branch = Some(branch);
        }
    }

    /// Read a row if present and fresh enough under staleness bound
    /// `staleness` at clock `now`.
    pub fn get(
        &mut self,
        table: TableId,
        key: RowKey,
        now: Clock,
        staleness: u32,
    ) -> Option<&[f32]> {
        // Single hash lookup on the hot path (§Perf): the occupied
        // entry serves both the freshness check and the hit/evict.
        match self.rows.entry((table, key)) {
            MapEntry::Occupied(e) => {
                if now.saturating_sub(e.get().fetched_at) <= staleness as Clock {
                    self.stats.hits += 1;
                    Some(&e.into_mut().data)
                } else {
                    e.remove();
                    self.stats.stale_evictions += 1;
                    self.stats.misses += 1;
                    None
                }
            }
            MapEntry::Vacant(_) => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// The miss-side bookkeeping of [`WorkerCache::get`], split out
    /// for the batched gather: a servable row returns `true` with the
    /// hit *not* counted (the assembly's later `get` counts it);
    /// otherwise the miss is counted — a stale row evicted and counted
    /// exactly as `get` would — and `false` says "fetch this row in
    /// the batch".  Scanning with `probe` and reading hits with `get`
    /// therefore keeps [`CacheStats`] identical to the row-at-a-time
    /// gather's.
    pub fn probe(&mut self, table: TableId, key: RowKey, now: Clock, staleness: u32) -> bool {
        match self.rows.entry((table, key)) {
            MapEntry::Occupied(e) => {
                if now.saturating_sub(e.get().fetched_at) <= staleness as Clock {
                    true
                } else {
                    e.remove();
                    self.stats.stale_evictions += 1;
                    self.stats.misses += 1;
                    false
                }
            }
            MapEntry::Vacant(_) => {
                self.stats.misses += 1;
                false
            }
        }
    }

    /// Install a freshly-fetched row.
    pub fn put(&mut self, table: TableId, key: RowKey, data: Vec<f32>, now: Clock) {
        self.rows.insert((table, key), CachedRow {
            data,
            fetched_at: now,
        });
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    pub fn current_branch(&self) -> Option<BranchId> {
        self.current_branch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_within_staleness_bound() {
        let mut c = WorkerCache::new();
        c.switch_branch(0);
        c.put(0, 1, vec![1.0], 10);
        assert!(c.get(0, 1, 10, 0).is_some()); // same clock, s=0
        assert!(c.get(0, 1, 13, 3).is_some()); // 3 stale, s=3
        assert_eq!(c.stats().hits, 2);
    }

    #[test]
    fn miss_beyond_staleness_bound_evicts() {
        let mut c = WorkerCache::new();
        c.switch_branch(0);
        c.put(0, 1, vec![1.0], 10);
        assert!(c.get(0, 1, 12, 1).is_none()); // 2 stale > s=1
        assert_eq!(c.stats().stale_evictions, 1);
        assert_eq!(c.len(), 0);
    }

    #[test]
    fn ssp_never_exposes_staleness_above_bound() {
        let mut c = WorkerCache::new();
        c.switch_branch(0);
        for s in [0u32, 1, 3, 7] {
            for age in 0..10u64 {
                c.put(0, 9, vec![0.0], 100);
                let got = c.get(0, 9, 100 + age, s);
                assert_eq!(got.is_some(), age <= s as u64, "age={age} s={s}");
            }
        }
    }

    #[test]
    fn probe_counts_misses_and_evicts_like_get_but_not_hits() {
        let mut c = WorkerCache::new();
        c.switch_branch(1);
        c.put(0, 5, vec![1.0], 10);
        assert!(c.probe(0, 5, 12, 2)); // servable: NOT counted as a hit
        assert_eq!(c.stats(), CacheStats::default());
        // a probed-servable row then hits through get, counted once
        assert!(c.get(0, 5, 12, 2).is_some());
        assert_eq!(c.stats().hits, 1);
        assert!(!c.probe(0, 6, 12, 2)); // absent: counted as a miss
        assert_eq!(c.stats().misses, 1);
        assert!(!c.probe(0, 5, 13, 2)); // stale: evicted + counted
        assert_eq!(c.stats().stale_evictions, 1);
        assert_eq!(c.stats().misses, 2);
        assert_eq!(c.len(), 0, "stale row must be evicted like get does");
    }

    #[test]
    fn branch_switch_clears_shared_cache() {
        let mut c = WorkerCache::new();
        c.switch_branch(1);
        c.put(0, 1, vec![1.0], 0);
        c.switch_branch(2);
        assert!(c.is_empty());
        assert_eq!(c.stats().branch_clears, 1);
        // switching to the same branch again does NOT clear
        c.put(0, 1, vec![2.0], 0);
        c.switch_branch(2);
        assert_eq!(c.len(), 1);
    }
}
