//! Parameter-server substrate (§4.6): the IterStore / GeePS analog that
//! MLtuner's branch operations drive — now a **concurrent sharded
//! engine** rather than a single-threaded `&mut self` object.
//!
//! Parameter data lives as key→row pairs in memory, sharded across
//! server shards (one per worker machine in the paper's deployments).
//! Branch support adds the branch ID as an additional index field, and
//! branches are **copy-on-write** (see [`storage`]): a fork snapshots
//! only the index, the first write to a row under a branch materializes
//! a private copy, and a free reclaims a row's buffers only when the
//! freed branch was its last owner.  Optimizer slot state is
//! row-resident and is snapshotted together with the data, so a branch
//! snapshot is a *consistent* snapshot of all training state — and,
//! because slots travel with the row, a shard's write lock is the only
//! synchronization an update needs: there is no separate optimizer
//! state store to keep coherent.
//!
//! ## Thread model and lock hierarchy
//!
//! The server exposes an entirely `&self` API and is `Send + Sync`:
//! data-parallel workers drive it concurrently from N threads (the
//! paper's deployment shape).  Three kinds of state, three locks:
//!
//! * **Per-shard state** — each shard's row index *plus its own
//!   [`MemoryPool`] arena* lives behind one `RwLock<ShardState>`.
//!   Readers (`read_row`, `with_row`, `row_shared`; `gather_table`
//!   additionally requires its branch to stay live for the whole call)
//!   take shared read locks and never block each other; writers
//!   (`insert_row`, `apply_update`, `apply_batch`, branch fan-out) take
//!   the write lock.  The pool is *inside* the shard lock on purpose:
//!   copy-on-write materialization and last-owner reclamation then need
//!   no second lock, and a buffer recycled on shard `s` is reused by
//!   shard `s` — the per-pool `idle` census stays an exact census.
//! * **Control plane** — branch bookkeeping (`branch_rows`, fork
//!   count, peak live branches) is a small `Mutex<ControlPlane>`.  It
//!   is held for the *whole* of `fork_branch`/`free_branch`, which
//!   serializes branch ops against each other (they are rare: §4.6
//!   keeps at most a handful of branches live) while leaving the
//!   update/read hot path — which never touches the control plane —
//!   completely unaffected.
//! * **Counters** — contention and batching statistics are relaxed
//!   atomics, lock-free on every path.
//!
//! Lock order is `control plane → shard`, and shard locks are taken
//! one at a time (or concurrently by *independent* fan-out threads, one
//! shard each), so there is no lock-order cycle anywhere: update paths
//! take only a single shard lock, branch ops take control first and
//! never a second shard lock from the same thread.  `insert_row` takes
//! a shard lock and the control mutex *sequentially*, never nested.
//!
//! ## Batched updates
//!
//! The row-at-a-time [`ParamServer::apply_update`] acquires one write
//! lock per row.  The hot path for data-parallel training is
//! [`ParamServer::apply_batch`]: route every `(table, key)` once, group
//! the updates per shard, and apply each shard's whole group under a
//! **single** lock acquisition.  Groups are visited starting at a
//! rotating shard offset so concurrent workers pushing whole-model
//! batches do not convoy on shard 0.  A batch is *not* atomic across
//! shards: on a missing row the call stops and reports the error, with
//! earlier groups already applied (the same partial-application a
//! sequence of `apply_update` calls would leave behind).  Within one
//! key, batch order equals call order, so `apply_batch` is
//! observationally identical to the equivalent `apply_update` sequence
//! (`prop_apply_batch_equals_update_sequence` checks this).
//!
//! ## Batched reads
//!
//! The read plane mirrors the write plane.  Row-at-a-time readers
//! (`read_row`, `with_row`) take one shard read lock per row; the hot
//! path for data-parallel gather phases is [`ParamServer::read_rows`]:
//! route every `(table, key)` once, group the keys per shard, and
//! serve each shard's whole group under a **single** read-lock
//! acquisition, visiting shards from a rotating offset exactly like
//! `apply_batch` so concurrent gather workers don't convoy.  Reads
//! never mutate, so `read_rows` is trivially observationally identical
//! to the equivalent `read_row` sequence
//! (`prop_read_rows_matches_row_reads` checks it anyway).  The
//! `with_accum` variant additionally snapshots each row's AdaRevision
//! grad accumulator (slot 1), so one batched call replaces the
//! read+read_with_accum pair of the AdaRevision gather.
//!
//! ## Branch fan-out
//!
//! `fork_branch`/`free_branch` touch every shard.  For small branches
//! the loop is sequential (a fork is O(#rows) `Arc` bumps — cheaper
//! than spawning); at [`PARALLEL_BRANCH_OP_MIN_ROWS`] rows and above
//! the fan-out runs one scoped thread per shard, each locking only its
//! own shard.
//!
//! ## Checkpoint plane
//!
//! [`checkpoint`] extends the in-memory snapshots to disk: a branch's
//! rows (data + optimizer slots + step) dump to per-shard segment
//! files as f32 bit patterns with trailing checksums, and restore
//! swaps the verified rows back in wholesale
//! ([`ParamServer::replace_branch_rows`]) so a corrupted checkpoint
//! never leaves partial state.  The [`ParamStore`] methods
//! `checkpoint_branch`/`restore_branch` expose the plane uniformly:
//! the local engine dumps its shards in parallel under read locks; the
//! remote client broadcasts [`crate::comm::wire::PsRequest`]
//! `CheckpointBranch`/`RestoreBranch` frames so every shard server
//! dumps or restores its own range concurrently.  Restored branches
//! are born fully materialized (the `Arc` sharing of the original
//! process cannot be reconstructed from files), which affects only
//! pool statistics, never row values.

pub mod cache;
pub mod checkpoint;
pub mod pool;
pub mod remote;
pub mod session;
pub mod storage;
pub mod thread_cache;

use std::collections::HashMap;
use std::ops::{Deref, DerefMut};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard, TryLockError};

use anyhow::{anyhow, bail, Result};

use crate::comm::{BranchId, SessionId};
use crate::optim::{Hyper, Optimizer, OptimizerKind};
use crate::stats::{ServerPlane, ShardRows, Snapshot, StorePlane, TrialEvent};

use checkpoint::SegmentMeta;
use pool::{MemoryPool, PoolStats};
use remote::RemoteParamServer;
use session::{SessionLimits, SessionRegistry, SESSION_BRANCH_BASE};
use storage::{Entry, RowKey, Shard, TableId};

/// Branch fork/free fan-out runs one thread per shard at this many
/// rows and above; below it the per-shard loop is sequential (an
/// index-only fork is cheaper than thread spawns).
pub const PARALLEL_BRANCH_OP_MIN_ROWS: usize = 8192;

/// One row as returned by the batched read plane: the row data plus —
/// when requested `with_accum` — the AdaRevision grad-accumulator
/// snapshot (slot 1) to be handed back as `z_old` with the update.
pub type RowData = (Vec<f32>, Option<Vec<f32>>);

/// One shard's lock domain: its row index and its private buffer pool.
/// Keeping the pool inside the shard lock makes copy-on-write
/// materialization and last-owner reclamation single-lock operations
/// and keeps each pool's `idle` census exact.
#[derive(Debug, Default)]
struct ShardState {
    shard: Shard,
    pool: MemoryPool,
}

/// Branch bookkeeping shared by all shards.  Guarded by one mutex that
/// is held across whole branch operations, serializing fork/free
/// against each other without touching the update hot path.
#[derive(Debug, Default)]
struct ControlPlane {
    /// rows per branch (all shards), for accounting.  Keys are
    /// **global** branch ids: the default namespace's ids pass through
    /// unchanged, named sessions' ids are mapped up from
    /// [`SESSION_BRANCH_BASE`] by the registry below.
    branch_rows: HashMap<BranchId, usize>,
    /// Branch forks served since construction.
    forks: u64,
    /// Peak number of simultaneously-live branches (§4.6 memory check).
    peak_branches: usize,
    /// Named-session namespaces (see [`session`]); shares this mutex
    /// so a branch op and its namespace bookkeeping are one critical
    /// section, and the lock hierarchy stays `control → shard`.
    sessions: SessionRegistry,
}

/// Lock-free concurrency counters (relaxed atomics).
#[derive(Debug, Default)]
struct Counters {
    /// Shard lock acquisitions that found the lock held (would-block).
    contended: AtomicU64,
    /// `apply_batch` invocations (also drives the anti-convoy shard
    /// rotation).
    batch_calls: AtomicU64,
    /// Rows applied through `apply_batch`.
    batched_rows: AtomicU64,
    /// `read_rows` invocations (drives the read-side shard rotation).
    read_calls: AtomicU64,
    /// Rows requested through `read_rows`.
    reads_batched: AtomicU64,
}

/// Per-shard row-throughput counters (relaxed atomics, one slot per
/// shard so hot-path increments never share a cache line with the
/// control plane).  These feed the [`ShardRows`] drill-down of the
/// observability plane.
#[derive(Debug, Default)]
struct ShardCounters {
    /// Update rows routed to this shard (single + batched; a missing
    /// row still counts — the request hit the shard either way).
    rows_applied: AtomicU64,
    /// Read rows routed to this shard (same convention).
    rows_read: AtomicU64,
}

/// Number of shard guards live on the current thread — the debug-build
/// mirror of `mltuner_lint`'s static `lock-order` pass.  The hierarchy
/// is `control plane → shard` (module docs above); [`lock_control`]
/// asserts this census is zero so an inverted acquisition fails loudly
/// in tests instead of deadlocking against a concurrent fork/free.
#[cfg(debug_assertions)]
thread_local! {
    static LIVE_SHARD_GUARDS: std::cell::Cell<usize> = const { std::cell::Cell::new(0) };
}

#[cfg(debug_assertions)]
fn note_shard_guard_acquired() {
    LIVE_SHARD_GUARDS.with(|c| c.set(c.get() + 1));
}

#[cfg(debug_assertions)]
fn note_shard_guard_released() {
    LIVE_SHARD_GUARDS.with(|c| c.set(c.get() - 1));
}

/// Shard read guard that keeps the per-thread live-guard census for
/// the debug-build lock-order assertion; dereferences to the shard
/// state exactly like the raw `RwLockReadGuard` it wraps.  Release
/// builds carry no `Drop` impl, so the wrapper costs nothing there.
struct ShardReadGuard<'a>(RwLockReadGuard<'a, ShardState>);

impl Deref for ShardReadGuard<'_> {
    type Target = ShardState;
    fn deref(&self) -> &ShardState {
        &self.0
    }
}

#[cfg(debug_assertions)]
impl Drop for ShardReadGuard<'_> {
    fn drop(&mut self) {
        note_shard_guard_released();
    }
}

/// Write-side counterpart of [`ShardReadGuard`].
struct ShardWriteGuard<'a>(RwLockWriteGuard<'a, ShardState>);

impl Deref for ShardWriteGuard<'_> {
    type Target = ShardState;
    fn deref(&self) -> &ShardState {
        &self.0
    }
}

impl DerefMut for ShardWriteGuard<'_> {
    fn deref_mut(&mut self) -> &mut ShardState {
        &mut self.0
    }
}

#[cfg(debug_assertions)]
impl Drop for ShardWriteGuard<'_> {
    fn drop(&mut self) {
        note_shard_guard_released();
    }
}

#[inline]
fn lock_control(m: &Mutex<ControlPlane>) -> MutexGuard<'_, ControlPlane> {
    #[cfg(debug_assertions)]
    LIVE_SHARD_GUARDS.with(|c| {
        assert_eq!(
            c.get(),
            0,
            "lock-order violation: control mutex requested while a shard \
             guard is live (hierarchy is control -> shard; see module docs)"
        );
    });
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Read-lock a shard, counting contention without double-locking.
fn read_shard<'a>(lock: &'a RwLock<ShardState>, counters: &Counters) -> ShardReadGuard<'a> {
    let g = match lock.try_read() {
        Ok(g) => g,
        Err(TryLockError::WouldBlock) => {
            counters.contended.fetch_add(1, Ordering::Relaxed);
            lock.read().unwrap_or_else(|e| e.into_inner())
        }
        Err(TryLockError::Poisoned(e)) => e.into_inner(),
    };
    #[cfg(debug_assertions)]
    note_shard_guard_acquired();
    ShardReadGuard(g)
}

/// Write-lock a shard, counting contention without double-locking.
fn write_shard<'a>(lock: &'a RwLock<ShardState>, counters: &Counters) -> ShardWriteGuard<'a> {
    let g = match lock.try_write() {
        Ok(g) => g,
        Err(TryLockError::WouldBlock) => {
            counters.contended.fetch_add(1, Ordering::Relaxed);
            lock.write().unwrap_or_else(|e| e.into_inner())
        }
        Err(TryLockError::Poisoned(e)) => e.into_inner(),
    };
    #[cfg(debug_assertions)]
    note_shard_guard_acquired();
    ShardWriteGuard(g)
}

/// splitmix64 finalizer: a full-avalanche mix so that `h % n` is
/// uniform even for tiny shard counts and structured key patterns.
/// (The previous router multiplied the key by one odd constant, which
/// leaves the low bits — everything `% n` sees for small `n` —
/// poorly mixed for strided key sets.)
#[inline]
fn splitmix64(mut h: u64) -> u64 {
    h = h.wrapping_add(0x9E37_79B9_7F4A_7C15);
    h ^= h >> 30;
    h = h.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    h ^= h >> 27;
    h = h.wrapping_mul(0x94D0_49BB_1331_11EB);
    h ^= h >> 31;
    h
}

/// Deterministic shard router: mix the table into the key, then
/// avalanche.  Pure function of `(table, key, n)` so every thread —
/// and every remote client — routes identically without touching
/// shared state.  Public as [`route_shard`]: the distributed client
/// routes `(table, key)` to a *global* shard id with the same
/// function, then maps the shard id to the server owning it.
#[inline]
fn route(table: TableId, key: RowKey, n: usize) -> usize {
    let h = splitmix64(key ^ (table as u64).wrapping_mul(0xA24B_AED4_963E_E407));
    (h % n as u64) as usize
}

/// The shard router as a pure public function (see the private
/// `route` above for the mixing rationale).
#[inline]
pub fn route_shard(table: TableId, key: RowKey, num_shards: usize) -> usize {
    route(table, key, num_shards)
}

/// Sharded, branch-versioned, **concurrent** parameter server.
#[derive(Debug)]
pub struct ParamServer {
    shards: Vec<RwLock<ShardState>>,
    control: Mutex<ControlPlane>,
    optimizer: Optimizer,
    counters: Counters,
    shard_counters: Vec<ShardCounters>,
}

impl ParamServer {
    pub fn new(num_shards: usize, optimizer: Optimizer) -> Self {
        assert!(num_shards > 0);
        ParamServer {
            shards: (0..num_shards).map(|_| RwLock::default()).collect(),
            control: Mutex::new(ControlPlane::default()),
            optimizer,
            counters: Counters::default(),
            shard_counters: (0..num_shards).map(|_| ShardCounters::default()).collect(),
        }
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn optimizer(&self) -> &Optimizer {
        &self.optimizer
    }

    /// Register `branch` in the control plane with zero rows if it is
    /// not live yet.  A shard server whose shard subset happens to hold
    /// no rows of the root branch still needs the branch to *exist* so
    /// replicated fork/free ops succeed there (see [`remote`]).
    pub fn ensure_branch(&self, branch: BranchId) {
        let mut ctl = lock_control(&self.control);
        ctl.branch_rows.entry(branch).or_insert(0);
        ctl.peak_branches = ctl.peak_branches.max(ctl.branch_rows.len());
    }

    #[inline]
    fn sid(&self, table: TableId, key: RowKey) -> usize {
        route(table, key, self.shards.len())
    }

    /// Install a fresh row into `branch` (used when initializing the
    /// root branch's model state).  Re-inserting an existing key
    /// overwrites it: the displaced row's buffers are reclaimed when
    /// this branch was their last owner, and the row count is not
    /// double-counted.
    pub fn insert_row(&self, branch: BranchId, table: TableId, key: RowKey, data: Vec<f32>) {
        let sid = self.sid(table, key);
        let mut entry = Entry {
            data,
            slots: Vec::new(),
            step: 0,
        };
        self.optimizer.init_slots(&mut entry);
        // shard lock and control mutex are taken sequentially, never
        // nested (lock-order discipline, see module docs).
        let displaced = {
            let mut st = write_shard(&self.shards[sid], &self.counters);
            let ShardState { shard, pool } = &mut *st;
            match shard.insert(branch, table, key, entry) {
                Some(old) => {
                    if let Ok(old) = Arc::try_unwrap(old) {
                        pool.recycle_entry(old);
                    }
                    true
                }
                None => false,
            }
        };
        if !displaced {
            let mut ctl = lock_control(&self.control);
            *ctl.branch_rows.entry(branch).or_insert(0) += 1;
            ctl.peak_branches = ctl.peak_branches.max(ctl.branch_rows.len());
        }
    }

    /// Fork `child` from `parent`: a consistent copy-on-write snapshot
    /// of parameter data + optimizer state.  Cost is O(#rows) index
    /// clones — independent of row length, no buffer copies.  Large
    /// branches fan out one thread per shard (see module docs); the
    /// control plane stays locked throughout, so branch ops are
    /// serialized against each other but never against updates/reads.
    pub fn fork_branch(&self, child: BranchId, parent: BranchId) -> Result<()> {
        let mut ctl = lock_control(&self.control);
        self.fork_locked(&mut ctl, child, parent)
    }

    /// The fork body, for callers already holding the control mutex
    /// (the session-scoped fork shares one critical section with its
    /// namespace bookkeeping).
    fn fork_locked(&self, ctl: &mut ControlPlane, child: BranchId, parent: BranchId) -> Result<()> {
        if ctl.branch_rows.contains_key(&child) {
            bail!("branch {child} already exists");
        }
        let Some(&parent_rows) = ctl.branch_rows.get(&parent) else {
            bail!("parent branch {parent} does not exist");
        };
        let rows = self.fan_out(parent_rows, |shard, pool| shard.fork(child, parent, pool));
        ctl.branch_rows.insert(child, rows);
        ctl.forks += 1;
        ctl.peak_branches = ctl.peak_branches.max(ctl.branch_rows.len());
        Ok(())
    }

    /// Run `op` on every shard (under its write lock), one scoped
    /// thread per shard when the branch is large enough, sequentially
    /// otherwise.  Returns the summed per-shard results.  Shared
    /// fan-out machinery of `fork_branch`/`free_branch` — keep the
    /// threshold and lock discipline in exactly one place.
    fn fan_out<F>(&self, branch_rows: usize, op: F) -> usize
    where
        F: Fn(&mut Shard, &mut MemoryPool) -> usize + Sync,
    {
        if self.shards.len() > 1 && branch_rows >= PARALLEL_BRANCH_OP_MIN_ROWS {
            std::thread::scope(|scope| {
                let handles: Vec<_> = self
                    .shards
                    .iter()
                    .map(|lock| {
                        let counters = &self.counters;
                        let op = &op;
                        scope.spawn(move || {
                            let mut st = write_shard(lock, counters);
                            let ShardState { shard, pool } = &mut *st;
                            op(shard, pool)
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    // lint:allow(panic-path): join only errs when the
                    // worker panicked; re-raising that panic is correct
                    .map(|h| h.join().expect("shard fan-out worker panicked"))
                    .sum()
            })
        } else {
            let mut total = 0;
            for lock in &self.shards {
                let mut st = write_shard(lock, &self.counters);
                let ShardState { shard, pool } = &mut *st;
                total += op(shard, pool);
            }
            total
        }
    }

    /// Free `branch`.  Row buffers return to their shard's pool only
    /// once their last owning branch is freed; rows still shared with
    /// ancestors or siblings stay live under those owners.  Fans out
    /// like [`ParamServer::fork_branch`].
    pub fn free_branch(&self, branch: BranchId) -> Result<()> {
        let mut ctl = lock_control(&self.control);
        self.free_locked(&mut ctl, branch)
    }

    /// The free body, for callers already holding the control mutex
    /// (session teardown frees a whole namespace under one guard).
    fn free_locked(&self, ctl: &mut ControlPlane, branch: BranchId) -> Result<()> {
        let Some(rows) = ctl.branch_rows.remove(&branch) else {
            bail!("branch {branch} does not exist");
        };
        self.fan_out(rows, |shard, pool| shard.free(branch, pool));
        Ok(())
    }

    /// Install `rows` as the complete content of `branch`, replacing
    /// whatever the branch previously held — the restore half of the
    /// [`checkpoint`] plane.  Creates the branch if it does not exist.
    /// Rows are routed with the normal shard router; the control plane
    /// stays locked for the whole operation exactly like a fork/free,
    /// so restores serialize against branch ops without touching the
    /// update/read hot path of other branches.  Displaced sole-owner
    /// buffers of the old branch content are reclaimed into the shard
    /// pools.  Returns the installed row count.
    pub fn replace_branch_rows(
        &self,
        branch: BranchId,
        rows: Vec<(TableId, RowKey, Entry)>,
    ) -> usize {
        let mut ctl = lock_control(&self.control);
        let n_shards = self.shards.len();
        let n_rows = rows.len();
        let mut groups: Vec<Vec<(TableId, RowKey, Entry)>> =
            (0..n_shards).map(|_| Vec::new()).collect();
        for (table, key, entry) in rows {
            groups[route(table, key, n_shards)].push((table, key, entry));
        }
        for (sid, group) in groups.into_iter().enumerate() {
            let mut st = write_shard(&self.shards[sid], &self.counters);
            let ShardState { shard, pool } = &mut *st;
            shard.free(branch, pool);
            for (table, key, entry) in group {
                shard.insert(branch, table, key, entry);
            }
        }
        ctl.branch_rows.insert(branch, n_rows);
        ctl.peak_branches = ctl.peak_branches.max(ctl.branch_rows.len());
        n_rows
    }

    pub fn branch_exists(&self, branch: BranchId) -> bool {
        lock_control(&self.control).branch_rows.contains_key(&branch)
    }

    pub fn live_branches(&self) -> Vec<BranchId> {
        let mut v: Vec<_> = lock_control(&self.control).branch_rows.keys().copied().collect();
        v.sort_unstable();
        v
    }

    pub fn branch_row_count(&self, branch: BranchId) -> usize {
        lock_control(&self.control).branch_rows.get(&branch).copied().unwrap_or(0)
    }

    /// Branch forks served since construction.
    pub fn fork_count(&self) -> u64 {
        lock_control(&self.control).forks
    }

    /// Peak number of simultaneously-live branches.
    pub fn peak_branches(&self) -> usize {
        lock_control(&self.control).peak_branches
    }

    /// Buffers privately materialized by copy-on-write since
    /// construction (the pools are only ever drawn from for COW copies).
    pub fn cow_buffer_copies(&self) -> u64 {
        let s = self.pool_stats();
        s.allocated + s.reused
    }

    /// Unified stats probe (the engine's side of
    /// [`crate::stats::Snapshot`]).  Counters are relaxed-atomic loads
    /// racing with writers, so a snapshot can be *stale* mid-clock but
    /// each counter individually never moves backwards — the
    /// monotonic-merge invariant the observability plane relies on.
    /// The wire plane is zeroed: the in-process engine serves calls,
    /// not frames; `ShardServer` overlays its transport counters
    /// before answering a probe.
    pub fn snapshot(&self) -> Snapshot {
        let pool = self.pool_stats();
        let mut rows_applied = 0u64;
        let mut rows_read = 0u64;
        for c in &self.shard_counters {
            rows_applied += c.rows_applied.load(Ordering::Relaxed);
            rows_read += c.rows_read.load(Ordering::Relaxed);
        }
        Snapshot {
            server: ServerPlane {
                shard_lock_contentions: self.counters.contended.load(Ordering::Relaxed),
                batch_calls: self.counters.batch_calls.load(Ordering::Relaxed),
                batched_rows: self.counters.batched_rows.load(Ordering::Relaxed),
                reads_batched: self.counters.reads_batched.load(Ordering::Relaxed),
                rows_applied,
                rows_read,
            },
            store: StorePlane {
                forks: self.fork_count(),
                peak_branches: self.peak_branches(),
                live_branches: ParamServer::live_branches(self).len(),
                cow_buffer_copies: pool.allocated + pool.reused,
                read_rpcs: 0, // in-process: reads never cross a wire
            },
            pool,
            ..Snapshot::default()
        }
    }

    /// Per-shard cumulative row throughput, local shard-index order.
    /// `ShardServer` re-addresses these to global shard ids before
    /// putting them on the wire.
    pub fn shard_rows(&self) -> Vec<ShardRows> {
        self.shard_counters
            .iter()
            .enumerate()
            .map(|(i, c)| ShardRows {
                shard: i as u64,
                rows_applied: c.rows_applied.load(Ordering::Relaxed),
                rows_read: c.rows_read.load(Ordering::Relaxed),
            })
            .collect()
    }

    /// Is this row's buffer still shared with another branch?
    /// (Test/bench introspection of the COW state.)
    pub fn row_shared(&self, branch: BranchId, table: TableId, key: RowKey) -> Option<bool> {
        let sid = self.sid(table, key);
        let st = read_shard(&self.shards[sid], &self.counters);
        st.shard.row_shared(branch, table, key)
    }

    /// Run `f` over one row under the shard's read lock, without
    /// copying.  Returns `None` when the row is absent.  Do not call
    /// re-entrantly for a second row while inside `f` — a writer
    /// queued between the two read acquisitions of the same shard can
    /// deadlock; take rows one at a time (`read_row_into`) instead.
    pub fn with_row<R>(
        &self,
        branch: BranchId,
        table: TableId,
        key: RowKey,
        f: impl FnOnce(&Entry) -> R,
    ) -> Option<R> {
        let sid = self.sid(table, key);
        self.shard_counters[sid].rows_read.fetch_add(1, Ordering::Relaxed);
        let st = read_shard(&self.shards[sid], &self.counters);
        st.shard.get(branch, table, key).map(f)
    }

    /// Read one row (server-side authoritative copy).
    pub fn read_row(&self, branch: BranchId, table: TableId, key: RowKey) -> Option<Vec<f32>> {
        self.with_row(branch, table, key, |e| e.data.clone())
    }

    /// Copy one row into `buf` (cleared first), avoiding a fresh
    /// allocation on repeated reads.  Returns false when the row is
    /// absent.
    pub fn read_row_into(
        &self,
        branch: BranchId,
        table: TableId,
        key: RowKey,
        buf: &mut Vec<f32>,
    ) -> bool {
        self.with_row(branch, table, key, |e| {
            buf.clear();
            buf.extend_from_slice(&e.data);
        })
        .is_some()
    }

    /// Read a whole batch of rows: route every key once, group the
    /// keys per shard, and serve each shard's group under a single
    /// read-lock acquisition, visiting shards from a rotating offset
    /// (the read-plane mirror of [`ParamServer::apply_batch`]).
    /// Results come back in key order; a missing row is `None`.  With
    /// `with_accum` each row also carries its AdaRevision
    /// grad-accumulator snapshot (slot 1).
    pub fn read_rows(
        &self,
        branch: BranchId,
        keys: &[(TableId, RowKey)],
        with_accum: bool,
    ) -> Vec<Option<RowData>> {
        let n = self.shards.len();
        let mut out: Vec<Option<RowData>> = vec![None; keys.len()];
        if keys.is_empty() {
            return out;
        }
        let rotation = self.counters.read_calls.fetch_add(1, Ordering::Relaxed) as usize % n;
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (i, &(table, key)) in keys.iter().enumerate() {
            groups[route(table, key, n)].push(i);
        }
        for off in 0..n {
            let sid = (rotation + off) % n;
            if groups[sid].is_empty() {
                continue;
            }
            self.shard_counters[sid]
                .rows_read
                .fetch_add(groups[sid].len() as u64, Ordering::Relaxed);
            let st = read_shard(&self.shards[sid], &self.counters);
            for &i in &groups[sid] {
                let (table, key) = keys[i];
                out[i] = st.shard.get(branch, table, key).map(|e| {
                    let accum = if with_accum { e.slots.get(1).cloned() } else { None };
                    (e.data.clone(), accum)
                });
            }
        }
        self.counters.reads_batched.fetch_add(keys.len() as u64, Ordering::Relaxed);
        out
    }

    /// AdaRevision's read: row data plus the current grad-accumulator
    /// snapshot `z` (to be handed back as `z_old` with the update).
    pub fn read_row_with_accum(
        &self,
        branch: BranchId,
        table: TableId,
        key: RowKey,
    ) -> Option<(Vec<f32>, Option<Vec<f32>>)> {
        self.with_row(branch, table, key, |e| {
            (e.data.clone(), e.slots.get(1).cloned())
        })
    }

    /// Apply one batch-normalized gradient to a row; the server applies
    /// the learning rate / momentum / adaptive rule (`hyper` carries the
    /// tunables).  The write goes through the copy-on-write path: a row
    /// still shared with other branches is privately materialized
    /// first.  One shard write-lock acquisition per call — prefer
    /// [`ParamServer::apply_batch`] on the data-parallel hot path.
    pub fn apply_update(
        &self,
        branch: BranchId,
        table: TableId,
        key: RowKey,
        grad: &[f32],
        hyper: Hyper,
        z_old: Option<&[f32]>,
    ) -> Result<()> {
        let sid = self.sid(table, key);
        let opt = self.optimizer;
        self.shard_counters[sid].rows_applied.fetch_add(1, Ordering::Relaxed);
        let mut st = write_shard(&self.shards[sid], &self.counters);
        let ShardState { shard, pool } = &mut *st;
        match shard.get_mut(branch, table, key, pool) {
            None => bail!("row ({table},{key}) missing in branch {branch}"),
            Some(entry) => {
                opt.apply(hyper, entry, grad, z_old);
                Ok(())
            }
        }
    }

    /// Apply a whole batch of updates: route every key once, group the
    /// updates per shard, and apply each shard's group under a single
    /// write-lock acquisition.  Observationally identical to calling
    /// [`ParamServer::apply_update`] per element in order (same-key
    /// updates stay in call order; distinct rows are independent).  Not
    /// atomic across shards: a missing row stops the batch with earlier
    /// groups already applied, exactly like the equivalent update
    /// sequence.
    pub fn apply_batch(
        &self,
        branch: BranchId,
        updates: &[(TableId, RowKey, &[f32])],
        hyper: Hyper,
    ) -> Result<()> {
        let n = self.shards.len();
        if updates.is_empty() {
            return Ok(());
        }
        // Stagger the shard visit order across concurrent callers so
        // data-parallel workers pushing whole-model batches don't
        // convoy on shard 0.  (Also counts the call — empty batches
        // were returned above, so the per-batch stats stay honest.)
        let rotation = self.counters.batch_calls.fetch_add(1, Ordering::Relaxed) as usize % n;
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (i, &(table, key, _)) in updates.iter().enumerate() {
            groups[route(table, key, n)].push(i);
        }
        let opt = self.optimizer;
        let mut applied = 0u64;
        let mut result = Ok(());
        'shards: for off in 0..n {
            let sid = (rotation + off) % n;
            if groups[sid].is_empty() {
                continue;
            }
            self.shard_counters[sid]
                .rows_applied
                .fetch_add(groups[sid].len() as u64, Ordering::Relaxed);
            let mut st = write_shard(&self.shards[sid], &self.counters);
            let ShardState { shard, pool } = &mut *st;
            for &i in &groups[sid] {
                let (table, key, grad) = updates[i];
                match shard.get_mut(branch, table, key, pool) {
                    None => {
                        result = Err(anyhow!("row ({table},{key}) missing in branch {branch}"));
                        break 'shards;
                    }
                    Some(entry) => {
                        opt.apply(hyper, entry, grad, None);
                        applied += 1;
                    }
                }
            }
        }
        self.counters.batched_rows.fetch_add(applied, Ordering::Relaxed);
        result
    }

    /// Enumerate a branch's (table, key) pairs across all shards.
    pub fn keys(&self, branch: BranchId) -> Vec<(TableId, RowKey)> {
        let mut all = Vec::with_capacity(self.branch_row_count(branch));
        for lock in &self.shards {
            all.extend(read_shard(lock, &self.counters).shard.keys(branch));
        }
        all.sort_unstable();
        all
    }

    /// Gather a whole table of `branch` into a flat vec ordered by key
    /// (how the DNN app reassembles flattened tensors for PJRT).
    ///
    /// Caller contract: `branch` must stay live for the duration of
    /// the call.  The row set is snapshotted per shard and the rows
    /// are then re-read one lock at a time, so a concurrent
    /// `free_branch(branch)` landing in between panics here rather
    /// than returning silently truncated tensors.  (MLtuner's protocol
    /// guarantees this: only the single-threaded coordinator frees
    /// branches, never while a clock is running on one.)
    pub fn gather_table(&self, branch: BranchId, table: TableId) -> Vec<f32> {
        let mut keys: Vec<RowKey> = self
            .keys(branch)
            .into_iter()
            .filter(|(t, _)| *t == table)
            .map(|(_, k)| k)
            .collect();
        keys.sort_unstable();
        let mut out = Vec::new();
        for k in keys {
            self.with_row(branch, table, k, |e| out.extend_from_slice(&e.data))
                // lint:allow(panic-path): documented caller contract —
                // a free during a gather is a protocol violation that
                // must fail loudly, not return truncated tensors
                .expect("row vanished during gather");
        }
        out
    }

    /// Aggregate pool statistics over every shard's arena.  Exactness
    /// is preserved by aggregation: each buffer lives its whole
    /// recycle/reuse life inside one shard's pool.
    pub fn pool_stats(&self) -> PoolStats {
        let mut total = PoolStats::default();
        for lock in &self.shards {
            total.accumulate(read_shard(lock, &self.counters).pool.stats());
        }
        total
    }

    /// Per-shard row counts of a branch (routing-balance
    /// introspection).
    pub fn shard_row_counts(&self, branch: BranchId) -> Vec<usize> {
        self.shards
            .iter()
            .map(|lock| read_shard(lock, &self.counters).shard.branch_row_count(branch))
            .collect()
    }

    // -- Session namespaces (multi-tenancy, see [`session`]) ---------------
    //
    // Session 0 is the default namespace: branch ids pass through
    // untouched without taking the control mutex, so a lone legacy
    // client pays nothing and behaves bit-exactly.  Named sessions map
    // user branch ids to global ids under the control mutex; every
    // time-dependent method takes `now_ms` from the caller so lease
    // expiry stays deterministic under test.

    /// Configure admission limits (served from `--max-sessions` /
    /// `--max-branches-per-session`).
    pub fn set_session_limits(&self, limits: SessionLimits) {
        lock_control(&self.control).sessions.set_limits(limits);
    }

    pub fn session_limits(&self) -> SessionLimits {
        lock_control(&self.control).sessions.limits()
    }

    /// Register or re-attach the session named `name` (lease refresh
    /// either way), garbage-collecting expired co-tenants first so
    /// their admission slots are reusable.  Returns the granted id and
    /// effective lease.  A freshly created namespace is born with its
    /// root branch (user id 0) live and empty.
    pub fn register_session(
        &self,
        name: &str,
        lease_ms: u64,
        now_ms: u64,
    ) -> Result<(SessionId, u64)> {
        let mut ctl = lock_control(&self.control);
        self.sweep_locked(&mut ctl, now_ms);
        let grant = ctl.sessions.register(name, lease_ms, now_ms)?;
        if grant.created {
            ctl.branch_rows.entry(grant.root_global).or_insert(0);
            ctl.peak_branches = ctl.peak_branches.max(ctl.branch_rows.len());
        }
        Ok((grant.id, grant.lease_ms))
    }

    /// Free every branch of every session whose lease lapsed (crashed
    /// clients never send `EndSession`).  Returns the number of
    /// sessions collected.
    pub fn sweep_expired_sessions(&self, now_ms: u64) -> usize {
        let mut ctl = lock_control(&self.control);
        self.sweep_locked(&mut ctl, now_ms)
    }

    fn sweep_locked(&self, ctl: &mut ControlPlane, now_ms: u64) -> usize {
        let expired = ctl.sessions.expired(now_ms);
        let mut swept = 0;
        for id in expired {
            if let Ok(globals) = ctl.sessions.remove_session(id) {
                for g in globals {
                    if ctl.branch_rows.contains_key(&g) {
                        let _ = self.free_locked(ctl, g);
                    }
                }
                swept += 1;
            }
        }
        swept
    }

    /// Refresh a session's lease (any stamped frame is a heartbeat).
    /// Session 0 has no lease; unknown ids are ignored — the frame
    /// that carried them fails at [`ParamServer::resolve_branch`].
    pub fn touch_session(&self, session: SessionId, now_ms: u64) {
        if session == 0 {
            return;
        }
        lock_control(&self.control).sessions.touch(session, now_ms);
    }

    /// Map a session-scoped branch id to the engine's global id.
    /// Session 0 is the identity and takes no lock.
    pub fn resolve_branch(&self, session: SessionId, branch: BranchId) -> Result<BranchId> {
        if session == 0 {
            return Ok(branch);
        }
        lock_control(&self.control).sessions.resolve(session, branch)
    }

    /// Resolve `branch`, allocating a namespace mapping when the
    /// session does not hold it yet (the restore-into-fresh-branch
    /// path; admission-checked).
    pub fn resolve_or_create_branch(
        &self,
        session: SessionId,
        branch: BranchId,
    ) -> Result<BranchId> {
        if session == 0 {
            return Ok(branch);
        }
        let mut ctl = lock_control(&self.control);
        ctl.sessions.resolve_or_allocate(session, branch)
    }

    /// Session-scoped [`ParamServer::ensure_branch`].
    pub fn ensure_branch_in(&self, session: SessionId, branch: BranchId) -> Result<()> {
        if session == 0 {
            self.ensure_branch(branch);
            return Ok(());
        }
        let mut ctl = lock_control(&self.control);
        let g = ctl.sessions.resolve_or_allocate(session, branch)?;
        ctl.branch_rows.entry(g).or_insert(0);
        ctl.peak_branches = ctl.peak_branches.max(ctl.branch_rows.len());
        Ok(())
    }

    /// Session-scoped fork: namespace bookkeeping and the fork itself
    /// are one critical section, so a failed fork never leaves a
    /// dangling mapping.
    pub fn fork_branch_in(
        &self,
        session: SessionId,
        child: BranchId,
        parent: BranchId,
    ) -> Result<()> {
        if session == 0 {
            return self.fork_branch(child, parent);
        }
        let mut ctl = lock_control(&self.control);
        let parent_g = ctl.sessions.resolve(session, parent)?;
        let child_g = ctl.sessions.allocate_branch(session, child)?;
        match self.fork_locked(&mut ctl, child_g, parent_g) {
            Ok(()) => Ok(()),
            Err(e) => {
                ctl.sessions.remove_branch(session, child);
                Err(e)
            }
        }
    }

    /// Session-scoped free: frees the global branch and drops the
    /// namespace mapping.
    pub fn free_branch_in(&self, session: SessionId, branch: BranchId) -> Result<()> {
        if session == 0 {
            return self.free_branch(branch);
        }
        let mut ctl = lock_control(&self.control);
        let g = ctl.sessions.resolve(session, branch)?;
        self.free_locked(&mut ctl, g)?;
        ctl.sessions.remove_branch(session, branch);
        Ok(())
    }

    /// Graceful session teardown: free exactly this namespace's
    /// branches and drop the registration.  Returns the number of
    /// branches freed.  Session 0 has no lifecycle and is rejected.
    pub fn end_session(&self, session: SessionId) -> Result<usize> {
        if session == 0 {
            bail!("session 0 is the default namespace and cannot be ended");
        }
        let mut ctl = lock_control(&self.control);
        let globals = ctl.sessions.remove_session(session)?;
        let mut freed = 0;
        for g in globals {
            if ctl.branch_rows.contains_key(&g) {
                self.free_locked(&mut ctl, g)?;
                freed += 1;
            }
        }
        Ok(freed)
    }

    /// One session's live branches under their **user-visible** ids,
    /// with this server's local row counts (the `ListBranches`
    /// census).  Session 0 lists only default-namespace branches —
    /// ids below [`SESSION_BRANCH_BASE`] — so a legacy census never
    /// sees a named co-tenant's branches.
    pub fn session_branches(&self, session: SessionId) -> Result<Vec<(BranchId, usize)>> {
        let ctl = lock_control(&self.control);
        if session == 0 {
            let mut v: Vec<(BranchId, usize)> = ctl
                .branch_rows
                .iter()
                .filter(|(id, _)| **id < SESSION_BRANCH_BASE)
                .map(|(id, rows)| (*id, *rows))
                .collect();
            v.sort_unstable();
            return Ok(v);
        }
        let pairs = ctl.sessions.branches(session)?;
        Ok(pairs
            .into_iter()
            .map(|(user, global)| (user, ctl.branch_rows.get(&global).copied().unwrap_or(0)))
            .collect())
    }

    /// `(session, live branches)` for the stats census: session 0's
    /// default namespace first, then every named session ascending.
    pub fn session_live_branches(&self) -> Vec<(SessionId, usize)> {
        let ctl = lock_control(&self.control);
        let default_live = ctl
            .branch_rows
            .keys()
            .filter(|id| **id < SESSION_BRANCH_BASE)
            .count();
        let mut v = vec![(0, default_live)];
        v.extend(ctl.sessions.census());
        v
    }
}

/// The parameter-server interface the training systems drive —
/// implemented by the in-process [`ParamServer`], by the socket-backed
/// [`RemoteParamServer`], and by the [`PsHandle`] enum the apps hold.
///
/// Everything is `&self` and `Send + Sync` (data-parallel worker
/// threads share the store), and every method returns `Result`: local
/// stores never fail on transport, but remote calls can.
pub trait ParamStore: Send + Sync {
    /// Which optimizer rule the store applies server-side.
    fn optimizer_kind(&self) -> OptimizerKind;

    /// Install a fresh row (root-branch model initialization).
    fn insert_row(
        &self,
        branch: BranchId,
        table: TableId,
        key: RowKey,
        data: Vec<f32>,
    ) -> Result<()>;

    /// Fork `child` from `parent` (replicated to every shard server
    /// for a remote store).
    fn fork_branch(&self, child: BranchId, parent: BranchId) -> Result<()>;

    /// Free `branch`; last-owner buffers return to the owning pools.
    fn free_branch(&self, branch: BranchId) -> Result<()>;

    /// Read one row; `Ok(None)` when the row is absent.
    fn read_row(&self, branch: BranchId, table: TableId, key: RowKey) -> Result<Option<Vec<f32>>>;

    /// Row data plus the AdaRevision grad-accumulator snapshot.
    fn read_row_with_accum(
        &self,
        branch: BranchId,
        table: TableId,
        key: RowKey,
    ) -> Result<Option<(Vec<f32>, Option<Vec<f32>>)>>;

    /// Read a whole batch of rows, results in key order (`None` for a
    /// missing row); `with_accum` additionally snapshots each row's
    /// AdaRevision grad accumulator.  The batched read plane: a local
    /// store serves each shard's group under one lock acquisition, a
    /// remote store issues one `ReadRows` RPC per shard server — the
    /// data-parallel gather phases read through this.
    fn read_rows(
        &self,
        branch: BranchId,
        keys: &[(TableId, RowKey)],
        with_accum: bool,
    ) -> Result<Vec<Option<RowData>>>;

    /// Copy one row into `buf` (cleared first); `Ok(false)` when absent.
    fn read_row_into(
        &self,
        branch: BranchId,
        table: TableId,
        key: RowKey,
        buf: &mut Vec<f32>,
    ) -> Result<bool> {
        match self.read_row(branch, table, key)? {
            None => Ok(false),
            Some(row) => {
                buf.clear();
                buf.extend_from_slice(&row);
                Ok(true)
            }
        }
    }

    /// Append one row's data to `out` (tensor reassembly); `Ok(false)`
    /// when absent.  Local stores copy straight out of the shard read
    /// lock with no intermediate allocation.
    fn extend_row_into(
        &self,
        branch: BranchId,
        table: TableId,
        key: RowKey,
        out: &mut Vec<f32>,
    ) -> Result<bool> {
        match self.read_row(branch, table, key)? {
            None => Ok(false),
            Some(row) => {
                out.extend_from_slice(&row);
                Ok(true)
            }
        }
    }

    /// Apply one row update (AdaRevision carries `z_old`).
    fn apply_update(
        &self,
        branch: BranchId,
        table: TableId,
        key: RowKey,
        grad: &[f32],
        hyper: Hyper,
        z_old: Option<&[f32]>,
    ) -> Result<()>;

    /// Apply a whole batch: routed once, grouped per shard (local) or
    /// per shard server (remote), applied group-wise.
    fn apply_batch(
        &self,
        branch: BranchId,
        updates: &[(TableId, RowKey, &[f32])],
        hyper: Hyper,
    ) -> Result<()>;

    /// Durably checkpoint every row of `branch` — data, optimizer
    /// slots and step counters — into per-shard-range segment files
    /// under `dir` (see [`checkpoint`]).  The local engine dumps its
    /// shards in parallel under read locks; a remote store broadcasts
    /// one `CheckpointBranch` RPC per shard server so every server
    /// dumps its own range concurrently.  Returns the segment metadata
    /// for the checkpoint manifest.
    fn checkpoint_branch(&self, branch: BranchId, dir: &Path) -> Result<Vec<SegmentMeta>>;

    /// Restore `branch` from the segment files under `dir`, replacing
    /// the branch's current content wholesale (the branch is created
    /// if absent).  Fail-closed: segments are decoded and verified
    /// before anything is installed — locally in one pass, remotely as
    /// a two-phase broadcast (every server verifies its range, then
    /// every server installs) — so a **corrupted** checkpoint is a
    /// typed error with store state unchanged.  One caveat remains for
    /// a remote store: if the install phase itself fails partway
    /// (server death or file loss *between* the two phases), servers
    /// can be left heterogeneous — callers must treat any restore
    /// error as fatal to the session rather than continuing on the
    /// store.  Returns the restored row count.
    fn restore_branch(&self, branch: BranchId, dir: &Path) -> Result<usize>;

    /// Rows live under `branch` (summed over shard servers).
    fn branch_row_count(&self, branch: BranchId) -> Result<usize>;

    /// Sorted live branch ids.
    fn live_branches(&self) -> Result<Vec<BranchId>>;

    /// The unified, versioned stats document
    /// ([`crate::stats::Snapshot`]): hot-path counters, branch census,
    /// pool census and wire counters in one probe.  For a remote store
    /// the planes are merged over all shard servers (counters and pool
    /// stats sum; fork count and peak are replicated identically on
    /// every server, so the maximum is taken) and `store.read_rpcs` is
    /// overlaid from the client side.
    fn stats(&self) -> Result<Snapshot>;

    /// Publish a tuner trial-progress event into the observability
    /// stream, so `mltuner top` subscribers see per-trial progress
    /// next to the server counters.  Local stores have no stream —
    /// the default is a no-op; the remote store broadcasts the event
    /// to every shard server.
    fn publish_progress(&self, _event: TrialEvent) -> Result<()> {
        Ok(())
    }
}

impl ParamStore for ParamServer {
    fn optimizer_kind(&self) -> OptimizerKind {
        self.optimizer.kind
    }

    fn insert_row(
        &self,
        branch: BranchId,
        table: TableId,
        key: RowKey,
        data: Vec<f32>,
    ) -> Result<()> {
        ParamServer::insert_row(self, branch, table, key, data);
        Ok(())
    }

    fn fork_branch(&self, child: BranchId, parent: BranchId) -> Result<()> {
        ParamServer::fork_branch(self, child, parent)
    }

    fn free_branch(&self, branch: BranchId) -> Result<()> {
        ParamServer::free_branch(self, branch)
    }

    fn read_row(&self, branch: BranchId, table: TableId, key: RowKey) -> Result<Option<Vec<f32>>> {
        Ok(ParamServer::read_row(self, branch, table, key))
    }

    fn read_row_with_accum(
        &self,
        branch: BranchId,
        table: TableId,
        key: RowKey,
    ) -> Result<Option<(Vec<f32>, Option<Vec<f32>>)>> {
        Ok(ParamServer::read_row_with_accum(self, branch, table, key))
    }

    fn read_rows(
        &self,
        branch: BranchId,
        keys: &[(TableId, RowKey)],
        with_accum: bool,
    ) -> Result<Vec<Option<RowData>>> {
        Ok(ParamServer::read_rows(self, branch, keys, with_accum))
    }

    fn read_row_into(
        &self,
        branch: BranchId,
        table: TableId,
        key: RowKey,
        buf: &mut Vec<f32>,
    ) -> Result<bool> {
        Ok(ParamServer::read_row_into(self, branch, table, key, buf))
    }

    fn extend_row_into(
        &self,
        branch: BranchId,
        table: TableId,
        key: RowKey,
        out: &mut Vec<f32>,
    ) -> Result<bool> {
        Ok(self
            .with_row(branch, table, key, |e| out.extend_from_slice(&e.data))
            .is_some())
    }

    fn apply_update(
        &self,
        branch: BranchId,
        table: TableId,
        key: RowKey,
        grad: &[f32],
        hyper: Hyper,
        z_old: Option<&[f32]>,
    ) -> Result<()> {
        ParamServer::apply_update(self, branch, table, key, grad, hyper, z_old)
    }

    fn apply_batch(
        &self,
        branch: BranchId,
        updates: &[(TableId, RowKey, &[f32])],
        hyper: Hyper,
    ) -> Result<()> {
        ParamServer::apply_batch(self, branch, updates, hyper)
    }

    fn checkpoint_branch(&self, branch: BranchId, dir: &Path) -> Result<Vec<SegmentMeta>> {
        checkpoint::checkpoint_range(self, branch, 0, self.num_shards(), dir)
    }

    fn restore_branch(&self, branch: BranchId, dir: &Path) -> Result<usize> {
        checkpoint::restore_range(self, branch, 0, self.num_shards(), dir)
    }

    fn branch_row_count(&self, branch: BranchId) -> Result<usize> {
        Ok(ParamServer::branch_row_count(self, branch))
    }

    fn live_branches(&self) -> Result<Vec<BranchId>> {
        Ok(ParamServer::live_branches(self))
    }

    fn stats(&self) -> Result<Snapshot> {
        Ok(self.snapshot())
    }
}

/// Enum dispatch over the two store backends (mirrors
/// [`crate::config::AnySystem`]: keeps the apps monomorphic, no boxed
/// trait objects on the read/update hot path).
#[derive(Debug)]
pub enum PsHandle {
    Local(ParamServer),
    Remote(RemoteParamServer),
}

impl PsHandle {
    /// The in-process server, when this handle is local (tests and
    /// benches introspect pool state through this).
    pub fn as_local(&self) -> Option<&ParamServer> {
        match self {
            PsHandle::Local(ps) => Some(ps),
            PsHandle::Remote(_) => None,
        }
    }
}

macro_rules! dispatch {
    ($self:ident, $ps:ident => $e:expr) => {
        match $self {
            PsHandle::Local($ps) => $e,
            PsHandle::Remote($ps) => $e,
        }
    };
}

impl ParamStore for PsHandle {
    fn optimizer_kind(&self) -> OptimizerKind {
        dispatch!(self, ps => ps.optimizer_kind())
    }

    fn insert_row(
        &self,
        branch: BranchId,
        table: TableId,
        key: RowKey,
        data: Vec<f32>,
    ) -> Result<()> {
        dispatch!(self, ps => ParamStore::insert_row(ps, branch, table, key, data))
    }

    fn fork_branch(&self, child: BranchId, parent: BranchId) -> Result<()> {
        dispatch!(self, ps => ParamStore::fork_branch(ps, child, parent))
    }

    fn free_branch(&self, branch: BranchId) -> Result<()> {
        dispatch!(self, ps => ParamStore::free_branch(ps, branch))
    }

    fn read_row(&self, branch: BranchId, table: TableId, key: RowKey) -> Result<Option<Vec<f32>>> {
        dispatch!(self, ps => ParamStore::read_row(ps, branch, table, key))
    }

    fn read_row_with_accum(
        &self,
        branch: BranchId,
        table: TableId,
        key: RowKey,
    ) -> Result<Option<(Vec<f32>, Option<Vec<f32>>)>> {
        dispatch!(self, ps => ParamStore::read_row_with_accum(ps, branch, table, key))
    }

    fn read_rows(
        &self,
        branch: BranchId,
        keys: &[(TableId, RowKey)],
        with_accum: bool,
    ) -> Result<Vec<Option<RowData>>> {
        dispatch!(self, ps => ParamStore::read_rows(ps, branch, keys, with_accum))
    }

    fn read_row_into(
        &self,
        branch: BranchId,
        table: TableId,
        key: RowKey,
        buf: &mut Vec<f32>,
    ) -> Result<bool> {
        dispatch!(self, ps => ParamStore::read_row_into(ps, branch, table, key, buf))
    }

    fn extend_row_into(
        &self,
        branch: BranchId,
        table: TableId,
        key: RowKey,
        out: &mut Vec<f32>,
    ) -> Result<bool> {
        dispatch!(self, ps => ParamStore::extend_row_into(ps, branch, table, key, out))
    }

    fn apply_update(
        &self,
        branch: BranchId,
        table: TableId,
        key: RowKey,
        grad: &[f32],
        hyper: Hyper,
        z_old: Option<&[f32]>,
    ) -> Result<()> {
        dispatch!(self, ps => ParamStore::apply_update(ps, branch, table, key, grad, hyper, z_old))
    }

    fn apply_batch(
        &self,
        branch: BranchId,
        updates: &[(TableId, RowKey, &[f32])],
        hyper: Hyper,
    ) -> Result<()> {
        dispatch!(self, ps => ParamStore::apply_batch(ps, branch, updates, hyper))
    }

    fn checkpoint_branch(&self, branch: BranchId, dir: &Path) -> Result<Vec<SegmentMeta>> {
        dispatch!(self, ps => ParamStore::checkpoint_branch(ps, branch, dir))
    }

    fn restore_branch(&self, branch: BranchId, dir: &Path) -> Result<usize> {
        dispatch!(self, ps => ParamStore::restore_branch(ps, branch, dir))
    }

    fn branch_row_count(&self, branch: BranchId) -> Result<usize> {
        dispatch!(self, ps => ParamStore::branch_row_count(ps, branch))
    }

    fn live_branches(&self) -> Result<Vec<BranchId>> {
        dispatch!(self, ps => ParamStore::live_branches(ps))
    }

    fn stats(&self) -> Result<Snapshot> {
        dispatch!(self, ps => ParamStore::stats(ps))
    }

    fn publish_progress(&self, event: TrialEvent) -> Result<()> {
        dispatch!(self, ps => ParamStore::publish_progress(ps, event))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::OptimizerKind;

    fn ps(kind: OptimizerKind) -> ParamServer {
        ParamServer::new(4, Optimizer::new(kind))
    }

    fn init_root(ps: &ParamServer, rows: usize, len: usize) {
        for k in 0..rows {
            ps.insert_row(0, 0, k as RowKey, vec![k as f32; len]);
        }
    }

    #[test]
    fn server_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<ParamServer>();
        assert_send_sync::<Optimizer>();
    }

    /// Debug builds enforce the `control -> shard` hierarchy at
    /// runtime (the dynamic half of the `lock-order` lint): taking the
    /// control mutex while a shard guard is live must fail loudly
    /// instead of risking a deadlock against a concurrent fork/free.
    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "lock-order violation")]
    fn control_lock_under_shard_guard_panics_in_debug() {
        let ps = ps(OptimizerKind::Sgd);
        ps.insert_row(0, 0, 0, vec![1.0]);
        // with_row holds the shard read guard while the closure runs;
        // branch_exists takes the control mutex inside it — inverted.
        ps.with_row(0, 0, 0, |_| ps.branch_exists(0));
    }

    #[test]
    fn insert_read_roundtrip_across_shards() {
        let ps = ps(OptimizerKind::Sgd);
        init_root(&ps, 64, 8);
        for k in 0..64u64 {
            assert_eq!(ps.read_row(0, 0, k).unwrap()[0], k as f32);
        }
        assert_eq!(ps.branch_row_count(0), 64);
    }

    #[test]
    fn reinsert_overwrites_without_double_count() {
        let ps = ps(OptimizerKind::Sgd);
        ps.insert_row(0, 0, 0, vec![1.0, 2.0]);
        ps.insert_row(0, 0, 0, vec![3.0, 4.0]);
        assert_eq!(ps.branch_row_count(0), 1);
        assert_eq!(ps.read_row(0, 0, 0).unwrap(), &[3.0, 4.0]);
        // the displaced sole-owner row (data + velocity) was reclaimed
        assert_eq!(ps.pool_stats().idle, 2);
    }

    #[test]
    fn fork_copies_no_buffers() {
        // The COW contract: forking even a large branch allocates and
        // copies nothing — only the index is cloned.
        let ps = ps(OptimizerKind::Adam);
        init_root(&ps, 64, 256);
        let before = ps.pool_stats();
        ps.fork_branch(1, 0).unwrap();
        let after = ps.pool_stats();
        assert_eq!(before, after, "fork must not touch the pool");
        assert_eq!(ps.branch_row_count(1), 64);
        assert_eq!(ps.row_shared(1, 0, 0), Some(true));
        assert_eq!(ps.fork_count(), 1);
    }

    #[test]
    fn fork_then_update_isolated() {
        let ps = ps(OptimizerKind::Sgd);
        init_root(&ps, 8, 4);
        ps.fork_branch(1, 0).unwrap();
        ps.apply_update(1, 0, 3, &[1.0; 4], Hyper { lr: 1.0, momentum: 0.0 }, None)
            .unwrap();
        assert_eq!(ps.read_row(0, 0, 3).unwrap()[0], 3.0);
        assert_eq!(ps.read_row(1, 0, 3).unwrap()[0], 2.0);
        // only the written row was materialized
        assert_eq!(ps.row_shared(1, 0, 3), Some(false));
        assert_eq!(ps.row_shared(1, 0, 4), Some(true));
    }

    #[test]
    fn optimizer_state_snapshots_with_branch() {
        // Momentum accumulated in the parent must carry into the fork;
        // updates after the fork must not leak back.
        let ps = ps(OptimizerKind::Sgd);
        init_root(&ps, 1, 1);
        let h = Hyper { lr: 0.1, momentum: 0.9 };
        ps.apply_update(0, 0, 0, &[1.0], h, None).unwrap();
        ps.fork_branch(1, 0).unwrap();
        // both take the same next step => same velocity was copied
        ps.apply_update(0, 0, 0, &[1.0], h, None).unwrap();
        ps.apply_update(1, 0, 0, &[1.0], h, None).unwrap();
        assert_eq!(ps.read_row(0, 0, 0).unwrap(), ps.read_row(1, 0, 0).unwrap());
    }

    #[test]
    fn free_unknown_branch_errors() {
        let ps = ps(OptimizerKind::Sgd);
        init_root(&ps, 1, 1);
        assert!(ps.free_branch(42).is_err());
        assert!(ps.fork_branch(1, 42).is_err());
        ps.fork_branch(1, 0).unwrap();
        assert!(ps.fork_branch(1, 0).is_err(), "duplicate child");
    }

    #[test]
    fn fork_write_free_cycle_reuses_pool_memory() {
        // Steady-state tuning churn: fork a trial, update every row
        // (worst-case materialization), free it.  After the first
        // cycle the pool serves every materialization.
        let ps = ps(OptimizerKind::Adam);
        init_root(&ps, 32, 16);
        let h = Hyper { lr: 0.01, momentum: 0.0 };
        let cycle = |ps: &ParamServer, b: BranchId| {
            ps.fork_branch(b, 0).unwrap();
            for k in 0..32u64 {
                ps.apply_update(b, 0, k, &[0.1; 16], h, None).unwrap();
            }
            ps.free_branch(b).unwrap();
        };
        cycle(&ps, 1);
        let allocated_before = ps.pool_stats().allocated;
        for b in 2..50u32 {
            cycle(&ps, b);
        }
        // steady state: everything comes from the pool
        assert_eq!(ps.pool_stats().allocated, allocated_before);
        assert!(ps.pool_stats().reused > 0);
    }

    #[test]
    fn shared_free_keeps_pool_idle_exact() {
        // Free a branch whose rows are still shared: nothing enters the
        // pool.  Free the remaining owner of materialized rows: exactly
        // those buffers enter the pool.
        let ps = ps(OptimizerKind::Sgd); // 1 slot => 2 buffers/row
        init_root(&ps, 8, 4);
        ps.fork_branch(1, 0).unwrap();
        ps.fork_branch(2, 0).unwrap();
        ps.free_branch(1).unwrap();
        assert_eq!(ps.pool_stats().idle, 0, "shared rows must not recycle");
        let h = Hyper { lr: 1.0, momentum: 0.0 };
        ps.apply_update(2, 0, 0, &[1.0; 4], h, None).unwrap();
        ps.apply_update(2, 0, 1, &[1.0; 4], h, None).unwrap();
        ps.free_branch(2).unwrap();
        // only branch 2's two materialized rows (data + velocity each)
        assert_eq!(ps.pool_stats().idle, 4);
        assert_eq!(ps.live_branches(), vec![0]);
    }

    #[test]
    fn gather_table_orders_by_key() {
        let ps = ps(OptimizerKind::Sgd);
        ps.insert_row(0, 0, 2, vec![3.0, 4.0]);
        ps.insert_row(0, 0, 0, vec![0.0]);
        ps.insert_row(0, 0, 1, vec![1.0, 2.0]);
        ps.insert_row(0, 1, 0, vec![9.0]); // other table ignored
        assert_eq!(ps.gather_table(0, 0), vec![0.0, 1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn adarevision_roundtrip_through_server() {
        let ps = ps(OptimizerKind::AdaRevision);
        init_root(&ps, 1, 2);
        let (_, z_old) = ps.read_row_with_accum(0, 0, 0).unwrap();
        ps.apply_update(
            0,
            0,
            0,
            &[1.0, -1.0],
            Hyper { lr: 0.1, momentum: 0.0 },
            z_old.as_deref(),
        )
        .unwrap();
        assert!(ps.read_row(0, 0, 0).unwrap()[0] < 0.0);
    }

    #[test]
    fn apply_batch_matches_looped_updates() {
        let batched = ps(OptimizerKind::Sgd);
        let looped = ps(OptimizerKind::Sgd);
        init_root(&batched, 16, 4);
        init_root(&looped, 16, 4);
        let h = Hyper { lr: 0.5, momentum: 0.9 };
        let grad = [1.0f32; 4];
        // duplicate keys on purpose: same-key order must be preserved
        let keys: [RowKey; 6] = [3, 7, 3, 0, 15, 3];
        let updates: Vec<(TableId, RowKey, &[f32])> =
            keys.iter().map(|&k| (0, k, &grad[..])).collect();
        batched.apply_batch(0, &updates, h).unwrap();
        for &k in &keys {
            looped.apply_update(0, 0, k, &grad, h, None).unwrap();
        }
        for k in 0..16u64 {
            assert_eq!(
                batched.read_row(0, 0, k).unwrap(),
                looped.read_row(0, 0, k).unwrap(),
                "row {k} diverged"
            );
        }
    }

    #[test]
    fn apply_batch_missing_row_errors() {
        let ps = ps(OptimizerKind::Sgd);
        init_root(&ps, 4, 2);
        let grad = [1.0f32; 2];
        let updates: Vec<(TableId, RowKey, &[f32])> =
            vec![(0, 0, &grad[..]), (0, 99, &grad[..])];
        let err = ps.apply_batch(0, &updates, Hyper::default()).unwrap_err();
        assert!(err.to_string().contains("99"), "unhelpful error: {err}");
    }

    #[test]
    fn batch_counters_track_calls_and_rows() {
        let ps = ps(OptimizerKind::Sgd);
        init_root(&ps, 16, 4);
        let grad = [0.1f32; 4];
        let updates: Vec<(TableId, RowKey, &[f32])> =
            (0..16u64).map(|k| (0, k, &grad[..])).collect();
        ps.apply_batch(0, &updates, Hyper::default()).unwrap();
        ps.apply_batch(0, &updates[..4], Hyper::default()).unwrap();
        let st = ps.snapshot().server;
        assert_eq!(st.batch_calls, 2);
        assert_eq!(st.batched_rows, 20);
        assert_eq!(st.rows_applied, 20);
        // single-threaded: no shard lock was ever contended
        assert_eq!(st.shard_lock_contentions, 0);
        // the per-shard drill-down covers every shard and sums to the
        // plane total
        let per_shard = ps.shard_rows();
        assert_eq!(per_shard.len(), ps.num_shards());
        assert_eq!(per_shard.iter().map(|s| s.rows_applied).sum::<u64>(), 20);
    }

    #[test]
    fn read_rows_matches_row_reads_including_accum_and_missing() {
        let ps = ps(OptimizerKind::AdaRevision);
        init_root(&ps, 16, 4);
        let h = Hyper { lr: 0.1, momentum: 0.0 };
        // build up non-trivial accumulator state first
        for k in 0..16u64 {
            let (_, z) = ps.read_row_with_accum(0, 0, k).unwrap();
            ps.apply_update(0, 0, k, &[1.0; 4], h, z.as_deref()).unwrap();
        }
        let mut keys: Vec<(TableId, RowKey)> = (0..16u64).map(|k| (0u32, k)).collect();
        keys.push((0, 99)); // missing row
        keys.push((7, 0)); // missing table
        let batched = ps.read_rows(0, &keys, true);
        assert_eq!(batched.len(), keys.len());
        for (&(t, k), got) in keys.iter().zip(&batched) {
            assert_eq!(got, &ps.read_row_with_accum(0, t, k), "row ({t},{k})");
        }
        // without accum the snapshot is suppressed
        let plain = ps.read_rows(0, &keys[..16], false);
        for (&(t, k), got) in keys[..16].iter().zip(&plain) {
            let (data, accum) = got.as_ref().unwrap();
            assert_eq!(Some(data.clone()), ps.read_row(0, t, k));
            assert_eq!(accum, &None);
        }
        let st = ps.snapshot().server;
        assert_eq!(st.reads_batched, 18 + 16);
        // every single-row and batched read above routed to a shard:
        // 16 accum reads + 18 batched + 18 compare reads + 16 batched
        // + 16 plain reads
        assert_eq!(st.rows_read, 16 + 18 + 18 + 16 + 16);
        assert_eq!(ps.shard_rows().iter().map(|s| s.rows_read).sum::<u64>(), st.rows_read);
        assert!(ps.read_rows(0, &[], false).is_empty());
    }

    #[test]
    fn shard_routing_balances_bench_table() {
        // The splitmix64-mixed router must spread the 2048-row bench
        // table so no shard holds more than 2x the mean, for every
        // small shard count (the regime where the old multiply-only
        // router clustered).
        for shards in [2usize, 3, 4, 5, 7, 8, 16] {
            let ps = ParamServer::new(shards, Optimizer::new(OptimizerKind::Sgd));
            for k in 0..2048u64 {
                ps.insert_row(0, 0, k, vec![0.0]);
            }
            let counts = ps.shard_row_counts(0);
            assert_eq!(counts.iter().sum::<usize>(), 2048);
            let mean = 2048.0 / shards as f64;
            let max = *counts.iter().max().unwrap();
            assert!(
                (max as f64) <= 2.0 * mean,
                "{shards} shards: counts {counts:?} (mean {mean:.0})"
            );
        }
    }

    #[test]
    fn routing_mixes_tables_apart() {
        // Rows with the same key in different tables must not all land
        // on the same shard (the MF app keys both factor tables 0..n).
        let ps = ParamServer::new(4, Optimizer::new(OptimizerKind::Sgd));
        for t in 0..2u32 {
            for k in 0..512u64 {
                ps.insert_row(0, t, k, vec![0.0]);
            }
        }
        let counts = ps.shard_row_counts(0);
        let max = *counts.iter().max().unwrap();
        assert!((max as f64) <= 2.0 * 256.0, "counts {counts:?}");
    }

    #[test]
    fn sessions_namespace_branches_and_tear_down_cleanly() {
        let ps = ps(OptimizerKind::Sgd);
        init_root(&ps, 8, 4); // default-namespace (session 0) model
        let (a, _) = ps.register_session("tenant-a", 0, 0).unwrap();
        let (b, _) = ps.register_session("tenant-b", 0, 0).unwrap();
        assert_ne!(a, b);
        // each tenant's root is its own empty branch; fill tenant A's
        let ga = ps.resolve_branch(a, 0).unwrap();
        let gb = ps.resolve_branch(b, 0).unwrap();
        assert_ne!(ga, gb);
        assert!(ga >= SESSION_BRANCH_BASE && gb >= SESSION_BRANCH_BASE);
        for k in 0..4u64 {
            ps.insert_row(ga, 0, k, vec![1.0]);
        }
        // both tenants fork "branch 1" — distinct global branches
        ps.fork_branch_in(a, 1, 0).unwrap();
        ps.fork_branch_in(b, 1, 0).unwrap();
        assert_ne!(
            ps.resolve_branch(a, 1).unwrap(),
            ps.resolve_branch(b, 1).unwrap()
        );
        // session-0 census sees only default-namespace branches
        assert_eq!(
            ps.session_branches(0).unwrap(),
            vec![(0, 8)],
            "legacy census must not see tenant branches"
        );
        assert_eq!(ps.session_branches(a).unwrap(), vec![(0, 4), (1, 4)]);
        // tearing tenant A down frees exactly its namespace
        let live_before = ParamServer::live_branches(&ps).len();
        assert_eq!(ps.end_session(a).unwrap(), 2);
        assert_eq!(ParamServer::live_branches(&ps).len(), live_before - 2);
        assert!(ps.resolve_branch(a, 0).is_err(), "session gone");
        assert_eq!(ps.session_branches(b).unwrap().len(), 2, "B untouched");
        assert_eq!(ps.read_row(0, 0, 3).unwrap(), &[3.0, 3.0, 3.0, 3.0]);
        assert!(ps.end_session(0).is_err(), "default namespace has no end");
    }

    #[test]
    fn lease_expiry_garbage_collects_crashed_sessions() {
        let ps = ps(OptimizerKind::Sgd);
        init_root(&ps, 4, 2);
        let (a, lease) = ps.register_session("crasher", 1_000, 0).unwrap();
        assert_eq!(lease, 1_000);
        ps.fork_branch_in(a, 1, 0).unwrap();
        // heartbeats hold the lease open
        ps.touch_session(a, 900);
        assert_eq!(ps.sweep_expired_sessions(1_800), 0);
        // silence past the lease: the sweep frees the namespace
        assert_eq!(ps.sweep_expired_sessions(2_000), 1);
        assert!(ps.resolve_branch(a, 1).is_err());
        assert_eq!(ps.session_live_branches(), vec![(0, 1)]);
        // the default namespace survived untouched
        assert_eq!(ps.branch_row_count(0), 4);
    }

    #[test]
    fn session_admission_limits_are_enforced() {
        let ps = ps(OptimizerKind::Sgd);
        ps.set_session_limits(SessionLimits {
            max_sessions: 1,
            max_branches_per_session: 2,
            default_lease_ms: 1_000,
        });
        let (a, _) = ps.register_session("only", 0, 0).unwrap();
        assert!(ps.register_session("second", 0, 0).is_err());
        init_root(&ps, 2, 2);
        let ga = ps.resolve_branch(a, 0).unwrap();
        ps.insert_row(ga, 0, 0, vec![1.0, 2.0]);
        ps.fork_branch_in(a, 1, 0).unwrap();
        let err = ps.fork_branch_in(a, 2, 0).unwrap_err().to_string();
        assert!(err.contains("admission"), "{err}");
        // freeing makes room again, and a failed fork leaves no
        // mapping behind (forking from a missing parent)
        ps.free_branch_in(a, 1).unwrap();
        assert!(ps.fork_branch_in(a, 2, 7).is_err(), "missing parent");
        assert!(ps.fork_branch_in(a, 2, 0).is_ok(), "no stale mapping");
        // an expired co-tenant's admission slot is reclaimed by the
        // register-time sweep
        assert!(ps.register_session("second", 0, 5_000).is_ok());
    }
}
