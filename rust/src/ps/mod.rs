//! Parameter-server substrate (§4.6): the IterStore / GeePS analog that
//! MLtuner's branch operations drive.
//!
//! Parameter data lives as key→row pairs in memory, sharded across
//! server shards (one per worker machine in the paper's deployments).
//! Branch support adds the branch ID as an additional index field.
//! Branches are **copy-on-write** (see [`storage`]): a fork snapshots
//! only the index (O(#rows) pointer copies, zero buffer traffic), the
//! first write to a row under a branch materializes a private copy from
//! the user-level [`pool::MemoryPool`], and a free reclaims a row's
//! buffers only when the freed branch was its last owner.  Optimizer
//! slot state is row-resident and is snapshotted together with the
//! data, so a branch snapshot is a *consistent* snapshot of all
//! training state.

pub mod cache;
pub mod thread_cache;
pub mod pool;
pub mod storage;

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::comm::BranchId;
use crate::optim::{Hyper, Optimizer};

use pool::{MemoryPool, PoolStats};
use storage::{Entry, RowKey, Shard, TableId};

/// Sharded, branch-versioned parameter server.
#[derive(Debug)]
pub struct ParamServer {
    shards: Vec<Shard>,
    pool: MemoryPool,
    optimizer: Optimizer,
    /// rows per branch (all shards), for accounting.
    branch_rows: HashMap<BranchId, usize>,
    /// Branch forks served since construction.
    forks: u64,
    /// Peak number of simultaneously-live branches (§4.6 memory check).
    peak_branches: usize,
}

impl ParamServer {
    pub fn new(num_shards: usize, optimizer: Optimizer) -> Self {
        assert!(num_shards > 0);
        ParamServer {
            shards: (0..num_shards).map(|_| Shard::default()).collect(),
            pool: MemoryPool::new(),
            optimizer,
            branch_rows: HashMap::new(),
            forks: 0,
            peak_branches: 0,
        }
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn optimizer(&self) -> &Optimizer {
        &self.optimizer
    }

    #[inline]
    fn shard_of(&self, table: TableId, key: RowKey) -> usize {
        // Cheap deterministic router: mix table into the key.
        let h = key
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(table as u64);
        (h % self.shards.len() as u64) as usize
    }

    /// Install a fresh row into `branch` (used when initializing the
    /// root branch's model state).  Re-inserting an existing key
    /// overwrites it: the displaced row's buffers are reclaimed when
    /// this branch was their last owner, and the row count is not
    /// double-counted.
    pub fn insert_row(
        &mut self,
        branch: BranchId,
        table: TableId,
        key: RowKey,
        data: Vec<f32>,
    ) {
        let sid = self.shard_of(table, key);
        let mut entry = Entry {
            data,
            slots: Vec::new(),
            step: 0,
        };
        self.optimizer.init_slots(&mut entry);
        match self.shards[sid].insert(branch, table, key, entry) {
            Some(displaced) => {
                if let Ok(old) = Arc::try_unwrap(displaced) {
                    self.pool.recycle_entry(old);
                }
            }
            None => {
                *self.branch_rows.entry(branch).or_insert(0) += 1;
            }
        }
        self.peak_branches = self.peak_branches.max(self.branch_rows.len());
    }

    /// Fork `child` from `parent`: a consistent copy-on-write snapshot
    /// of parameter data + optimizer state.  Cost is O(#rows) index
    /// clones — independent of row length, no buffer copies.
    pub fn fork_branch(&mut self, child: BranchId, parent: BranchId) -> Result<()> {
        if self.branch_rows.contains_key(&child) {
            bail!("branch {child} already exists");
        }
        if !self.branch_rows.contains_key(&parent) {
            bail!("parent branch {parent} does not exist");
        }
        let mut rows = 0;
        for shard in &mut self.shards {
            rows += shard.fork(child, parent, &mut self.pool);
        }
        self.branch_rows.insert(child, rows);
        self.forks += 1;
        self.peak_branches = self.peak_branches.max(self.branch_rows.len());
        Ok(())
    }

    /// Free `branch`.  Row buffers return to the pool only once their
    /// last owning branch is freed; rows still shared with ancestors or
    /// siblings stay live under those owners.
    pub fn free_branch(&mut self, branch: BranchId) -> Result<()> {
        if self.branch_rows.remove(&branch).is_none() {
            bail!("branch {branch} does not exist");
        }
        for shard in &mut self.shards {
            shard.free(branch, &mut self.pool);
        }
        Ok(())
    }

    pub fn branch_exists(&self, branch: BranchId) -> bool {
        self.branch_rows.contains_key(&branch)
    }

    pub fn live_branches(&self) -> Vec<BranchId> {
        let mut v: Vec<_> = self.branch_rows.keys().copied().collect();
        v.sort_unstable();
        v
    }

    pub fn branch_row_count(&self, branch: BranchId) -> usize {
        self.branch_rows.get(&branch).copied().unwrap_or(0)
    }

    /// Branch forks served since construction.
    pub fn fork_count(&self) -> u64 {
        self.forks
    }

    /// Peak number of simultaneously-live branches.
    pub fn peak_branches(&self) -> usize {
        self.peak_branches
    }

    /// Buffers privately materialized by copy-on-write since
    /// construction (the pool is only ever drawn from for COW copies).
    pub fn cow_buffer_copies(&self) -> u64 {
        let s = self.pool.stats();
        s.allocated + s.reused
    }

    /// Is this row's buffer still shared with another branch?
    /// (Test/bench introspection of the COW state.)
    pub fn row_shared(
        &self,
        branch: BranchId,
        table: TableId,
        key: RowKey,
    ) -> Option<bool> {
        let sid = self.shard_of(table, key);
        self.shards[sid].row_shared(branch, table, key)
    }

    /// Read one row (server-side authoritative copy).
    pub fn read_row(
        &self,
        branch: BranchId,
        table: TableId,
        key: RowKey,
    ) -> Option<&[f32]> {
        let sid = self.shard_of(table, key);
        self.shards[sid].get(branch, table, key).map(|e| &e.data[..])
    }

    /// AdaRevision's read: row data plus the current grad-accumulator
    /// snapshot `z` (to be handed back as `z_old` with the update).
    pub fn read_row_with_accum(
        &self,
        branch: BranchId,
        table: TableId,
        key: RowKey,
    ) -> Option<(&[f32], Option<&[f32]>)> {
        let sid = self.shard_of(table, key);
        self.shards[sid].get(branch, table, key).map(|e| {
            let z = e.slots.get(1).map(|s| &s[..]);
            (&e.data[..], z)
        })
    }

    /// Apply one batch-normalized gradient to a row; the server applies
    /// the learning rate / momentum / adaptive rule (`hyper` carries the
    /// tunables).  The write goes through the copy-on-write path: a row
    /// still shared with other branches is privately materialized
    /// first.
    pub fn apply_update(
        &mut self,
        branch: BranchId,
        table: TableId,
        key: RowKey,
        grad: &[f32],
        hyper: Hyper,
        z_old: Option<&[f32]>,
    ) -> Result<()> {
        let sid = self.shard_of(table, key);
        let opt = self.optimizer;
        match self.shards[sid].get_mut(branch, table, key, &mut self.pool) {
            None => bail!("row ({table},{key}) missing in branch {branch}"),
            Some(entry) => {
                opt.apply(hyper, entry, grad, z_old);
                Ok(())
            }
        }
    }

    /// Enumerate a branch's (table, key) pairs across all shards.
    pub fn keys(&self, branch: BranchId) -> Vec<(TableId, RowKey)> {
        let mut all = Vec::with_capacity(self.branch_row_count(branch));
        for shard in &self.shards {
            all.extend(shard.keys(branch));
        }
        all.sort_unstable();
        all
    }

    /// Gather a whole table of `branch` into a flat vec ordered by key
    /// (how the DNN app reassembles flattened tensors for PJRT).
    pub fn gather_table(&self, branch: BranchId, table: TableId) -> Vec<f32> {
        let mut keys: Vec<RowKey> = self
            .keys(branch)
            .into_iter()
            .filter(|(t, _)| *t == table)
            .map(|(_, k)| k)
            .collect();
        keys.sort_unstable();
        let mut out = Vec::new();
        for k in keys {
            out.extend_from_slice(self.read_row(branch, table, k).unwrap());
        }
        out
    }

    pub fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::OptimizerKind;

    fn ps(kind: OptimizerKind) -> ParamServer {
        ParamServer::new(4, Optimizer::new(kind))
    }

    fn init_root(ps: &mut ParamServer, rows: usize, len: usize) {
        for k in 0..rows {
            ps.insert_row(0, 0, k as RowKey, vec![k as f32; len]);
        }
    }

    #[test]
    fn insert_read_roundtrip_across_shards() {
        let mut ps = ps(OptimizerKind::Sgd);
        init_root(&mut ps, 64, 8);
        for k in 0..64u64 {
            assert_eq!(ps.read_row(0, 0, k).unwrap()[0], k as f32);
        }
        assert_eq!(ps.branch_row_count(0), 64);
    }

    #[test]
    fn reinsert_overwrites_without_double_count() {
        let mut ps = ps(OptimizerKind::Sgd);
        ps.insert_row(0, 0, 0, vec![1.0, 2.0]);
        ps.insert_row(0, 0, 0, vec![3.0, 4.0]);
        assert_eq!(ps.branch_row_count(0), 1);
        assert_eq!(ps.read_row(0, 0, 0).unwrap(), &[3.0, 4.0]);
        // the displaced sole-owner row (data + velocity) was reclaimed
        assert_eq!(ps.pool_stats().idle, 2);
    }

    #[test]
    fn fork_copies_no_buffers() {
        // The COW contract: forking even a large branch allocates and
        // copies nothing — only the index is cloned.
        let mut ps = ps(OptimizerKind::Adam);
        init_root(&mut ps, 64, 256);
        let before = ps.pool_stats();
        ps.fork_branch(1, 0).unwrap();
        let after = ps.pool_stats();
        assert_eq!(before, after, "fork must not touch the pool");
        assert_eq!(ps.branch_row_count(1), 64);
        assert_eq!(ps.row_shared(1, 0, 0), Some(true));
        assert_eq!(ps.fork_count(), 1);
    }

    #[test]
    fn fork_then_update_isolated() {
        let mut ps = ps(OptimizerKind::Sgd);
        init_root(&mut ps, 8, 4);
        ps.fork_branch(1, 0).unwrap();
        ps.apply_update(1, 0, 3, &[1.0; 4], Hyper { lr: 1.0, momentum: 0.0 }, None)
            .unwrap();
        assert_eq!(ps.read_row(0, 0, 3).unwrap()[0], 3.0);
        assert_eq!(ps.read_row(1, 0, 3).unwrap()[0], 2.0);
        // only the written row was materialized
        assert_eq!(ps.row_shared(1, 0, 3), Some(false));
        assert_eq!(ps.row_shared(1, 0, 4), Some(true));
    }

    #[test]
    fn optimizer_state_snapshots_with_branch() {
        // Momentum accumulated in the parent must carry into the fork;
        // updates after the fork must not leak back.
        let mut ps = ps(OptimizerKind::Sgd);
        init_root(&mut ps, 1, 1);
        let h = Hyper { lr: 0.1, momentum: 0.9 };
        ps.apply_update(0, 0, 0, &[1.0], h, None).unwrap();
        ps.fork_branch(1, 0).unwrap();
        // both take the same next step => same velocity was copied
        ps.apply_update(0, 0, 0, &[1.0], h, None).unwrap();
        ps.apply_update(1, 0, 0, &[1.0], h, None).unwrap();
        assert_eq!(
            ps.read_row(0, 0, 0).unwrap(),
            ps.read_row(1, 0, 0).unwrap()
        );
    }

    #[test]
    fn free_unknown_branch_errors() {
        let mut ps = ps(OptimizerKind::Sgd);
        init_root(&mut ps, 1, 1);
        assert!(ps.free_branch(42).is_err());
        assert!(ps.fork_branch(1, 42).is_err());
        ps.fork_branch(1, 0).unwrap();
        assert!(ps.fork_branch(1, 0).is_err(), "duplicate child");
    }

    #[test]
    fn fork_write_free_cycle_reuses_pool_memory() {
        // Steady-state tuning churn: fork a trial, update every row
        // (worst-case materialization), free it.  After the first
        // cycle the pool serves every materialization.
        let mut ps = ps(OptimizerKind::Adam);
        init_root(&mut ps, 32, 16);
        let h = Hyper { lr: 0.01, momentum: 0.0 };
        let cycle = |ps: &mut ParamServer, b: BranchId| {
            ps.fork_branch(b, 0).unwrap();
            for k in 0..32u64 {
                ps.apply_update(b, 0, k, &[0.1; 16], h, None).unwrap();
            }
            ps.free_branch(b).unwrap();
        };
        cycle(&mut ps, 1);
        let allocated_before = ps.pool_stats().allocated;
        for b in 2..50u32 {
            cycle(&mut ps, b);
        }
        // steady state: everything comes from the pool
        assert_eq!(ps.pool_stats().allocated, allocated_before);
        assert!(ps.pool_stats().reused > 0);
    }

    #[test]
    fn shared_free_keeps_pool_idle_exact() {
        // Free a branch whose rows are still shared: nothing enters the
        // pool.  Free the remaining owner of materialized rows: exactly
        // those buffers enter the pool.
        let mut ps = ps(OptimizerKind::Sgd); // 1 slot => 2 buffers/row
        init_root(&mut ps, 8, 4);
        ps.fork_branch(1, 0).unwrap();
        ps.fork_branch(2, 0).unwrap();
        ps.free_branch(1).unwrap();
        assert_eq!(ps.pool_stats().idle, 0, "shared rows must not recycle");
        let h = Hyper { lr: 1.0, momentum: 0.0 };
        ps.apply_update(2, 0, 0, &[1.0; 4], h, None).unwrap();
        ps.apply_update(2, 0, 1, &[1.0; 4], h, None).unwrap();
        ps.free_branch(2).unwrap();
        // only branch 2's two materialized rows (data + velocity each)
        assert_eq!(ps.pool_stats().idle, 4);
        assert_eq!(ps.live_branches(), vec![0]);
    }

    #[test]
    fn gather_table_orders_by_key() {
        let mut ps = ps(OptimizerKind::Sgd);
        ps.insert_row(0, 0, 2, vec![3.0, 4.0]);
        ps.insert_row(0, 0, 0, vec![0.0]);
        ps.insert_row(0, 0, 1, vec![1.0, 2.0]);
        ps.insert_row(0, 1, 0, vec![9.0]); // other table ignored
        assert_eq!(ps.gather_table(0, 0), vec![0.0, 1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn adarevision_roundtrip_through_server() {
        let mut ps = ps(OptimizerKind::AdaRevision);
        init_root(&mut ps, 1, 2);
        let (_, z) = ps.read_row_with_accum(0, 0, 0).unwrap();
        let z_old = z.map(|s| s.to_vec());
        ps.apply_update(
            0,
            0,
            0,
            &[1.0, -1.0],
            Hyper { lr: 0.1, momentum: 0.0 },
            z_old.as_deref(),
        )
        .unwrap();
        assert!(ps.read_row(0, 0, 0).unwrap()[0] < 0.0);
    }
}
