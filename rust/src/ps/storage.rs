//! Branch-versioned parameter storage (§4.6).
//!
//! Parameter data is key→row in memory, sharded across server shards;
//! to support MLtuner the branch ID is an **additional field in the
//! index**: each shard keeps a per-branch map of rows.  Forking a
//! branch allocates storage from the memory pool and copies the parent
//! branch's rows; freeing a branch reclaims all its memory to the pool.
//!
//! Each row carries its optimizer slot buffers (momentum / adaptive-LR
//! accumulators), which are *training state* and therefore snapshotted
//! and restored with the branch, exactly like the parameter values.

use std::collections::HashMap;

use crate::comm::BranchId;

use super::pool::MemoryPool;

/// Row key within a table (e.g. chunk index of a flattened tensor, or
/// user/movie id for matrix factorization).
pub type RowKey = u64;

/// Table id (one logical tensor / factor matrix per table).
pub type TableId = u32;

/// One parameter row plus its optimizer slots.
#[derive(Debug, Default)]
pub struct Entry {
    pub data: Vec<f32>,
    /// Optimizer slot buffers (meaning depends on `optim::Optimizer`):
    /// slot 0 = velocity / first moment, slot 1 = second moment, …
    pub slots: Vec<Vec<f32>>,
    /// Per-row update counter (drives Adam bias correction and
    /// AdaRevision's revision bookkeeping).
    pub step: u64,
}

/// One server shard: branch id → (table, key) → entry.
#[derive(Debug, Default)]
pub struct Shard {
    branches: HashMap<BranchId, HashMap<(TableId, RowKey), Entry>>,
}

impl Shard {
    pub fn insert(
        &mut self,
        branch: BranchId,
        table: TableId,
        key: RowKey,
        entry: Entry,
    ) {
        self.branches
            .entry(branch)
            .or_default()
            .insert((table, key), entry);
    }

    pub fn get(
        &self,
        branch: BranchId,
        table: TableId,
        key: RowKey,
    ) -> Option<&Entry> {
        self.branches.get(&branch)?.get(&(table, key))
    }

    pub fn get_mut(
        &mut self,
        branch: BranchId,
        table: TableId,
        key: RowKey,
    ) -> Option<&mut Entry> {
        self.branches.get_mut(&branch)?.get_mut(&(table, key))
    }

    /// Copy-on-fork: duplicate every parent row (and its optimizer
    /// slots) into `child`, drawing buffers from `pool`.
    pub fn fork(
        &mut self,
        child: BranchId,
        parent: BranchId,
        pool: &mut MemoryPool,
    ) -> usize {
        let parent_rows: Vec<((TableId, RowKey), Vec<f32>, Vec<Vec<f32>>, u64)> =
            match self.branches.get(&parent) {
                None => Vec::new(),
                Some(rows) => rows
                    .iter()
                    .map(|(k, e)| {
                        (
                            *k,
                            pool.alloc_copy(&e.data),
                            e.slots.iter().map(|s| pool.alloc_copy(s)).collect(),
                            e.step,
                        )
                    })
                    .collect(),
            };
        let n = parent_rows.len();
        let child_map = self.branches.entry(child).or_default();
        for (k, data, slots, step) in parent_rows {
            child_map.insert(k, Entry { data, slots, step });
        }
        n
    }

    /// Free a branch, reclaiming all its buffers into `pool`.
    pub fn free(&mut self, branch: BranchId, pool: &mut MemoryPool) -> usize {
        match self.branches.remove(&branch) {
            None => 0,
            Some(rows) => {
                let n = rows.len();
                for (_, e) in rows {
                    pool.recycle(e.data);
                    for s in e.slots {
                        pool.recycle(s);
                    }
                }
                n
            }
        }
    }

    pub fn branch_row_count(&self, branch: BranchId) -> usize {
        self.branches.get(&branch).map_or(0, |m| m.len())
    }

    pub fn live_branches(&self) -> Vec<BranchId> {
        let mut v: Vec<_> = self.branches.keys().copied().collect();
        v.sort_unstable();
        v
    }

    /// Iterate all (table, key) pairs of a branch (row enumeration for
    /// bulk reads).
    pub fn keys(&self, branch: BranchId) -> Vec<(TableId, RowKey)> {
        self.branches
            .get(&branch)
            .map(|m| m.keys().copied().collect())
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(vals: &[f32]) -> Entry {
        Entry {
            data: vals.to_vec(),
            slots: vec![vec![0.0; vals.len()]],
            step: 0,
        }
    }

    #[test]
    fn fork_copies_parent_rows_and_slots() {
        let mut shard = Shard::default();
        let mut pool = MemoryPool::new();
        shard.insert(0, 0, 7, entry(&[1.0, 2.0]));
        shard.insert(0, 1, 3, entry(&[5.0]));
        let n = shard.fork(1, 0, &mut pool);
        assert_eq!(n, 2);
        assert_eq!(shard.get(1, 0, 7).unwrap().data, vec![1.0, 2.0]);
        assert_eq!(shard.get(1, 1, 3).unwrap().slots.len(), 1);
    }

    #[test]
    fn fork_isolates_child_from_parent_writes() {
        let mut shard = Shard::default();
        let mut pool = MemoryPool::new();
        shard.insert(0, 0, 0, entry(&[1.0]));
        shard.fork(1, 0, &mut pool);
        shard.get_mut(0, 0, 0).unwrap().data[0] = 99.0;
        assert_eq!(shard.get(1, 0, 0).unwrap().data[0], 1.0);
        shard.get_mut(1, 0, 0).unwrap().data[0] = -1.0;
        assert_eq!(shard.get(0, 0, 0).unwrap().data[0], 99.0);
    }

    #[test]
    fn free_reclaims_to_pool_and_removes_rows() {
        let mut shard = Shard::default();
        let mut pool = MemoryPool::new();
        shard.insert(0, 0, 0, entry(&[1.0, 2.0, 3.0]));
        shard.fork(1, 0, &mut pool);
        let freed = shard.free(1, &mut pool);
        assert_eq!(freed, 1);
        assert!(shard.get(1, 0, 0).is_none());
        // data buffer + 1 slot buffer reclaimed
        assert_eq!(pool.stats().idle, 2);
    }

    #[test]
    fn fork_of_missing_parent_is_empty() {
        let mut shard = Shard::default();
        let mut pool = MemoryPool::new();
        assert_eq!(shard.fork(5, 99, &mut pool), 0);
        assert_eq!(shard.branch_row_count(5), 0);
    }

    #[test]
    fn live_branches_sorted() {
        let mut shard = Shard::default();
        shard.insert(3, 0, 0, entry(&[0.0]));
        shard.insert(1, 0, 0, entry(&[0.0]));
        assert_eq!(shard.live_branches(), vec![1, 3]);
    }
}
