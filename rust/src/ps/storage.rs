//! Branch-versioned parameter storage (§4.6) with **copy-on-write
//! snapshots**.
//!
//! Parameter data is key→row in memory, sharded across server shards;
//! to support MLtuner the branch ID is an **additional field in the
//! index**: each shard keeps a per-branch map of rows.
//!
//! ## Copy-on-write design
//!
//! MLtuner's trial-and-error loop forks and frees branches
//! continuously, so snapshot cost is the substrate's hottest path.  A
//! naive fork deep-copies every parameter row and every optimizer slot
//! buffer — O(model size) allocation and memcpy per trial branch,
//! exactly the cost the paper argues a tuning-aware parameter server
//! must avoid.  Instead, rows are stored as [`Arc`]-shared [`Entry`]s
//! and snapshots are taken lazily:
//!
//! * **Fork** clones only the parent branch's *index*: O(#rows) `Arc`
//!   pointer bumps, zero buffer copies.  Fork latency is therefore
//!   independent of row length (model size) — see the
//!   `micro_hotpaths` / `ablations` benches.
//! * **First write** to a row under a branch materializes a private
//!   copy ([`Shard::get_mut`]): if the row's `Arc` is shared, the
//!   entry's buffers are duplicated through the [`MemoryPool`]
//!   (`alloc_entry_copy`) and the branch's index slot is repointed at
//!   the private copy.  Sole-owner rows are written in place with no
//!   copy at all.  A trial branch that touches k of n rows pays for k
//!   copies, not n.
//! * **Free** removes the branch's index and recycles a row's buffers
//!   into the pool **only when the branch was the row's last owner**
//!   (`Arc::try_unwrap` succeeds).  Rows still shared by the parent or
//!   sibling branches are merely unreferenced; their memory is
//!   reclaimed later, when the final owner is freed.  This keeps
//!   [`MemoryPool`] `idle` accounting exact: a buffer is parked in the
//!   free list if and only if no live branch can reach it.
//!
//! Each row carries its optimizer slot buffers (momentum / adaptive-LR
//! accumulators), which are *training state* and therefore snapshotted
//! and restored with the branch, exactly like the parameter values:
//! parent and child see identical velocities/accumulators at fork time
//! and diverge only through their own writes.
//!
//! The eager deep-copy fork is retained as [`Shard::fork_eager`] — it
//! is the measured baseline in the benches and a semantic cross-check
//! in the tests, not a production path.
//!
//! ## Concurrency
//!
//! A `Shard` is deliberately lock-free *internally*: under the
//! concurrent engine (see [`super`]) each shard lives behind its own
//! `RwLock` together with its private [`MemoryPool`] arena, and every
//! method here runs with that lock held.  `&self` methods run under
//! the shared read lock (many concurrent readers), `&mut self` methods
//! under the exclusive write lock.  The `Arc<Entry>` sharing *between*
//! shard-local branch indexes never crosses a shard boundary — the
//! router assigns a `(table, key)` to exactly one shard — so strong
//! counts are only ever observed and mutated under one shard's lock.

use std::collections::hash_map::Entry as MapEntry;
use std::collections::HashMap;
use std::sync::Arc;

use crate::comm::BranchId;

use super::pool::MemoryPool;

/// Row key within a table (e.g. chunk index of a flattened tensor, or
/// user/movie id for matrix factorization).
pub type RowKey = u64;

/// Table id (one logical tensor / factor matrix per table).
pub type TableId = u32;

/// One parameter row plus its optimizer slots.
#[derive(Debug, Default)]
pub struct Entry {
    pub data: Vec<f32>,
    /// Optimizer slot buffers (meaning depends on `optim::Optimizer`):
    /// slot 0 = velocity / first moment, slot 1 = second moment, …
    pub slots: Vec<Vec<f32>>,
    /// Per-row update counter (drives Adam bias correction and
    /// AdaRevision's revision bookkeeping).
    pub step: u64,
}

/// One server shard: branch id → (table, key) → shared entry.
#[derive(Debug, Default)]
pub struct Shard {
    branches: HashMap<BranchId, HashMap<(TableId, RowKey), Arc<Entry>>>,
}

impl Shard {
    /// Install a fresh row.  Returns the displaced entry when
    /// `(branch, table, key)` was already present so the caller can
    /// reclaim sole-owner buffers (keeping the pool's idle census
    /// exact — see [`ParamServer::insert_row`](super::ParamServer)).
    pub fn insert(
        &mut self,
        branch: BranchId,
        table: TableId,
        key: RowKey,
        entry: Entry,
    ) -> Option<Arc<Entry>> {
        self.branches
            .entry(branch)
            .or_default()
            .insert((table, key), Arc::new(entry))
    }

    pub fn get(&self, branch: BranchId, table: TableId, key: RowKey) -> Option<&Entry> {
        self.branches.get(&branch)?.get(&(table, key)).map(|arc| &**arc)
    }

    /// Mutable access with copy-on-write: if the row is shared with
    /// other branches, a private copy is materialized from `pool`
    /// first; sole-owner rows are handed out in place.
    pub fn get_mut(
        &mut self,
        branch: BranchId,
        table: TableId,
        key: RowKey,
        pool: &mut MemoryPool,
    ) -> Option<&mut Entry> {
        let arc = self.branches.get_mut(&branch)?.get_mut(&(table, key))?;
        if Arc::strong_count(arc) > 1 {
            let private = pool.alloc_entry_copy(&**arc);
            *arc = Arc::new(private);
        }
        // lint:allow(panic-path): sole ownership is established just
        // above (strong_count==1 path or a fresh Arc), and this module
        // never creates Weak refs — get_mut cannot fail
        Some(Arc::get_mut(arc).expect("row must be sole-owned after COW"))
    }

    /// Is this row's buffer shared with another branch?  (Test/bench
    /// introspection of the COW state.)
    pub fn row_shared(&self, branch: BranchId, table: TableId, key: RowKey) -> Option<bool> {
        self.branches
            .get(&branch)?
            .get(&(table, key))
            .map(|arc| Arc::strong_count(arc) > 1)
    }

    /// Copy-on-write fork: `child` gets a clone of the parent's *index*
    /// only — O(#rows) pointer copies, no buffer copies.  Returns the
    /// number of rows snapshotted.  A missing parent forks nothing (no
    /// phantom child branch is registered); if `child` already holds
    /// rows, displaced sole-owner entries are reclaimed into `pool` so
    /// the idle census stays exact.
    pub fn fork(&mut self, child: BranchId, parent: BranchId, pool: &mut MemoryPool) -> usize {
        let snapshot = match self.branches.get(&parent) {
            None => return 0,
            Some(rows) => rows.clone(), // Arc clones: pointer bumps only
        };
        let n = snapshot.len();
        match self.branches.entry(child) {
            // common case (fresh child): adopt the snapshot wholesale,
            // no per-entry re-hash
            MapEntry::Vacant(slot) => {
                slot.insert(snapshot);
            }
            MapEntry::Occupied(mut slot) => {
                let child_map = slot.get_mut();
                for (k, arc) in snapshot {
                    if let Some(displaced) = child_map.insert(k, arc) {
                        if let Ok(entry) = Arc::try_unwrap(displaced) {
                            pool.recycle_entry(entry);
                        }
                    }
                }
            }
        }
        n
    }

    /// Eager deep-copy fork: the pre-COW behavior, duplicating every
    /// parent row (and its optimizer slots) into `child` through
    /// `pool`.  Kept as the measured baseline for the fork benches and
    /// as a semantic cross-check in tests.
    pub fn fork_eager(
        &mut self,
        child: BranchId,
        parent: BranchId,
        pool: &mut MemoryPool,
    ) -> usize {
        let parent_rows: Vec<((TableId, RowKey), Entry)> =
            match self.branches.get(&parent) {
                None => return 0,
                Some(rows) => rows
                    .iter()
                    .map(|(k, e)| (*k, pool.alloc_entry_copy(e)))
                    .collect(),
            };
        let n = parent_rows.len();
        let child_map = self.branches.entry(child).or_default();
        for (k, entry) in parent_rows {
            if let Some(displaced) = child_map.insert(k, Arc::new(entry)) {
                if let Ok(old) = Arc::try_unwrap(displaced) {
                    pool.recycle_entry(old);
                }
            }
        }
        n
    }

    /// Free a branch.  Buffers are reclaimed into `pool` only for rows
    /// whose last owner this branch was; rows still shared by siblings
    /// or ancestors stay live under their other owners.
    pub fn free(&mut self, branch: BranchId, pool: &mut MemoryPool) -> usize {
        match self.branches.remove(&branch) {
            None => 0,
            Some(rows) => {
                let n = rows.len();
                for (_, arc) in rows {
                    if let Ok(entry) = Arc::try_unwrap(arc) {
                        pool.recycle_entry(entry);
                    }
                }
                n
            }
        }
    }

    pub fn branch_row_count(&self, branch: BranchId) -> usize {
        self.branches.get(&branch).map_or(0, |m| m.len())
    }

    pub fn live_branches(&self) -> Vec<BranchId> {
        let mut v: Vec<_> = self.branches.keys().copied().collect();
        v.sort_unstable();
        v
    }

    /// Visit every row of `branch` (the checkpoint plane's dump path:
    /// called under the shard's read lock, rows are cloned out by the
    /// visitor and serialized outside the lock).
    pub fn for_each_row(&self, branch: BranchId, mut f: impl FnMut(TableId, RowKey, &Entry)) {
        if let Some(rows) = self.branches.get(&branch) {
            for (&(table, key), arc) in rows {
                f(table, key, arc);
            }
        }
    }

    /// Iterate all (table, key) pairs of a branch (row enumeration for
    /// bulk reads).
    pub fn keys(&self, branch: BranchId) -> Vec<(TableId, RowKey)> {
        self.branches
            .get(&branch)
            .map(|m| m.keys().copied().collect())
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(vals: &[f32]) -> Entry {
        Entry {
            data: vals.to_vec(),
            slots: vec![vec![0.0; vals.len()]],
            step: 0,
        }
    }

    #[test]
    fn fork_shares_parent_rows_and_slots() {
        let mut shard = Shard::default();
        let mut pool = MemoryPool::new();
        shard.insert(0, 0, 7, entry(&[1.0, 2.0]));
        shard.insert(0, 1, 3, entry(&[5.0]));
        let n = shard.fork(1, 0, &mut pool);
        assert_eq!(n, 2);
        // zero buffer copies: nothing was drawn from the pool
        assert_eq!(pool.stats().allocated + pool.stats().reused, 0);
        assert_eq!(shard.get(1, 0, 7).unwrap().data, vec![1.0, 2.0]);
        assert_eq!(shard.get(1, 1, 3).unwrap().slots.len(), 1);
        // zero buffer copies: both branches point at the same entries
        assert_eq!(shard.row_shared(1, 0, 7), Some(true));
        assert_eq!(shard.row_shared(0, 1, 3), Some(true));
    }

    #[test]
    fn fork_isolates_child_from_parent_writes() {
        let mut shard = Shard::default();
        let mut pool = MemoryPool::new();
        shard.insert(0, 0, 0, entry(&[1.0]));
        shard.fork(1, 0, &mut pool);
        shard.get_mut(0, 0, 0, &mut pool).unwrap().data[0] = 99.0;
        assert_eq!(shard.get(1, 0, 0).unwrap().data[0], 1.0);
        shard.get_mut(1, 0, 0, &mut pool).unwrap().data[0] = -1.0;
        assert_eq!(shard.get(0, 0, 0).unwrap().data[0], 99.0);
        // after both wrote, neither row is shared any more
        assert_eq!(shard.row_shared(0, 0, 0), Some(false));
        assert_eq!(shard.row_shared(1, 0, 0), Some(false));
    }

    #[test]
    fn first_write_materializes_then_writes_in_place() {
        let mut shard = Shard::default();
        let mut pool = MemoryPool::new();
        shard.insert(0, 0, 0, entry(&[1.0, 2.0]));
        shard.fork(1, 0, &mut pool);
        assert_eq!(shard.row_shared(1, 0, 0), Some(true));
        shard.get_mut(1, 0, 0, &mut pool).unwrap().data[0] = 5.0;
        // one materialization: data + 1 slot buffer
        assert_eq!(pool.stats().allocated, 2);
        shard.get_mut(1, 0, 0, &mut pool).unwrap().data[1] = 6.0;
        // second write is in place — no further pool traffic
        assert_eq!(pool.stats().allocated, 2);
        assert_eq!(pool.stats().reused, 0);
        assert_eq!(shard.get(1, 0, 0).unwrap().data, vec![5.0, 6.0]);
        assert_eq!(shard.get(0, 0, 0).unwrap().data, vec![1.0, 2.0]);
    }

    #[test]
    fn free_of_shared_branch_recycles_nothing() {
        let mut shard = Shard::default();
        let mut pool = MemoryPool::new();
        shard.insert(0, 0, 0, entry(&[1.0, 2.0, 3.0]));
        shard.fork(1, 0, &mut pool);
        let freed = shard.free(1, &mut pool);
        assert_eq!(freed, 1);
        assert!(shard.get(1, 0, 0).is_none());
        // the parent still owns the row — nothing may enter the pool
        assert_eq!(pool.stats().idle, 0);
        assert_eq!(shard.get(0, 0, 0).unwrap().data, vec![1.0, 2.0, 3.0]);
        assert_eq!(shard.row_shared(0, 0, 0), Some(false));
    }

    #[test]
    fn free_of_last_owner_recycles_to_pool() {
        let mut shard = Shard::default();
        let mut pool = MemoryPool::new();
        shard.insert(0, 0, 0, entry(&[1.0, 2.0, 3.0]));
        shard.fork(1, 0, &mut pool);
        // materialize the child's private copy, then free the child
        shard.get_mut(1, 0, 0, &mut pool).unwrap().data[0] = 4.0;
        let freed = shard.free(1, &mut pool);
        assert_eq!(freed, 1);
        // the private data + slot buffers were last-owner reclaimed
        assert_eq!(pool.stats().idle, 2);
    }

    #[test]
    fn fork_of_missing_parent_is_empty() {
        let mut shard = Shard::default();
        let mut pool = MemoryPool::new();
        assert_eq!(shard.fork(5, 99, &mut pool), 0);
        // no phantom child branch may be registered by the failed fork
        assert_eq!(shard.branch_row_count(5), 0);
        assert!(shard.live_branches().is_empty());
    }

    #[test]
    fn eager_fork_matches_cow_fork_semantics() {
        let mk = || {
            let mut shard = Shard::default();
            shard.insert(0, 0, 0, entry(&[1.0, 2.0]));
            shard.insert(0, 0, 1, entry(&[3.0]));
            shard
        };
        let mut pool = MemoryPool::new();
        let (mut cow, mut eager) = (mk(), mk());
        assert_eq!(cow.fork(1, 0, &mut pool), eager.fork_eager(1, 0, &mut pool));
        for shard in [&mut cow, &mut eager] {
            shard.get_mut(1, 0, 0, &mut pool).unwrap().data[0] = 9.0;
        }
        for k in 0..2u64 {
            assert_eq!(
                cow.get(1, 0, k).unwrap().data,
                eager.get(1, 0, k).unwrap().data
            );
            assert_eq!(
                cow.get(0, 0, k).unwrap().data,
                eager.get(0, 0, k).unwrap().data
            );
        }
        // eager forks are born private
        assert_eq!(eager.row_shared(1, 0, 1), Some(false));
        assert_eq!(cow.row_shared(1, 0, 1), Some(true));
    }

    #[test]
    fn live_branches_sorted() {
        let mut shard = Shard::default();
        shard.insert(3, 0, 0, entry(&[0.0]));
        shard.insert(1, 0, 0, entry(&[0.0]));
        assert_eq!(shard.live_branches(), vec![1, 3]);
    }
}
