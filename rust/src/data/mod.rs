//! Synthetic dataset generators (DESIGN.md §3 substitutions).
//!
//! * [`ImageDataset`] — Gaussian-cluster "images" standing in for
//!   Cifar10 / ILSVRC12: `classes` cluster centers in `dim` dimensions;
//!   examples are center + noise.  Separable enough that a correctly
//!   tuned classifier climbs steadily, hard enough that tuning matters.
//! * [`RatingsDataset`] — low-rank synthetic ratings standing in for
//!   Netflix: `X ≈ L·R + noise`, sampled sparsely.
//! * [`DriftSchedule`] — deterministic non-stationarity: a clock
//!   schedule (`none | step | ramp`) plus pure per-example transforms
//!   (rating rotation, covariate shift, label shift) that the apps
//!   apply at consumption time.  Every transform is a pure function of
//!   `(drift_seed, example key, clock)` — never of worker count or
//!   shard layout — so drifted runs stay bit-reproducible.
//!
//! Everything is deterministic per seed (Fig. 9 varies seeds on
//! purpose; everything else must be reproducible).

use anyhow::{bail, Result};

use crate::util::rng::Rng;

/// Shape of the non-stationarity on the clock axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DriftKind {
    /// Stationary workload (the default): every transform is identity.
    None,
    /// The distribution jumps at `drift_at` and stays shifted.
    Step,
    /// The distribution interpolates linearly from the original to the
    /// shifted one over `ramp_clocks` clocks starting at `drift_at`.
    Ramp,
}

/// A deterministic drift schedule plus its seeded per-example
/// transforms.  Apps hold one and consult [`DriftSchedule::factor`]
/// with the clock of the message they are executing; the transforms
/// below blend between the original datum and a seeded target by that
/// factor.  The schedule never touches the tuner's message stream —
/// drift is system-internal state keyed off the `clock` argument every
/// `ScheduleBranch` already carries, which is what keeps journal
/// replay (`--resume`) bit-exact under an active schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DriftSchedule {
    pub kind: DriftKind,
    /// First clock at which the drift is in effect.
    pub at: u64,
    /// Ramp length in clocks (ignored for `none`/`step`).
    pub ramp_clocks: u64,
    /// Seed of the shifted distribution (independent of the data seed).
    pub seed: u64,
}

const DRIFT_KEY_MIX: u64 = 0x9E37_79B9_7F4A_7C15;

impl DriftSchedule {
    /// The stationary schedule: `factor` is 0 everywhere and every
    /// transform is the identity.
    pub fn none() -> Self {
        DriftSchedule {
            kind: DriftKind::None,
            at: 0,
            ramp_clocks: 64,
            seed: 0,
        }
    }

    /// Parse the config surface (`drift = "none|step|ramp"`,
    /// `drift_at`, `drift_ramp`, `drift_seed`).  Unknown kinds are a
    /// typed error, never a silent default.
    pub fn parse(kind: &str, at: u64, ramp_clocks: u64, seed: u64) -> Result<Self> {
        let kind = match kind {
            "none" => DriftKind::None,
            "step" => DriftKind::Step,
            "ramp" => DriftKind::Ramp,
            other => bail!("unknown drift kind {other} (expected none|step|ramp)"),
        };
        Ok(DriftSchedule {
            kind,
            at,
            ramp_clocks: ramp_clocks.max(1),
            seed,
        })
    }

    pub fn step(at: u64, seed: u64) -> Self {
        DriftSchedule {
            kind: DriftKind::Step,
            at,
            ramp_clocks: 64,
            seed,
        }
    }

    pub fn ramp(at: u64, ramp_clocks: u64, seed: u64) -> Self {
        DriftSchedule {
            kind: DriftKind::Ramp,
            at,
            ramp_clocks: ramp_clocks.max(1),
            seed,
        }
    }

    pub fn is_active(&self) -> bool {
        self.kind != DriftKind::None
    }

    /// Drift progress in `[0, 1]` at `clock`: 0 before `at`, 1 once
    /// fully shifted; a ramp interpolates linearly in between.
    pub fn factor(&self, clock: u64) -> f64 {
        match self.kind {
            DriftKind::None => 0.0,
            DriftKind::Step | DriftKind::Ramp if clock < self.at => 0.0,
            DriftKind::Step => 1.0,
            DriftKind::Ramp => {
                let progressed = (clock - self.at).saturating_add(1);
                (progressed as f64 / self.ramp_clocks.max(1) as f64).min(1.0)
            }
        }
    }

    /// A deterministic uniform in `[0, 1)` keyed by `(seed, key)` —
    /// the per-example randomness source of every transform.  Pure in
    /// its inputs: shard layout and worker count can never change it.
    fn unit(&self, key: u64) -> f64 {
        let mixed = self.seed ^ key.wrapping_mul(DRIFT_KEY_MIX).wrapping_add(0x5851_F42D);
        Rng::seed_from_u64(mixed).gen_f64()
    }

    /// MF rating drift: each (user, item) pair's rating rotates toward
    /// a seeded target preference in `[-2, 2]`, blended by the drift
    /// factor.  Finite in, finite out (the blend of two finite bounded
    /// values); non-finite ratings pass through untouched.
    pub fn drifted_rating(&self, clock: u64, user: u32, item: u32, rating: f32) -> f32 {
        let f = self.factor(clock);
        if f <= 0.0 || !rating.is_finite() {
            return rating;
        }
        let key = ((user as u64) << 32) | item as u64;
        let target = -2.0 + 4.0 * self.unit(key);
        ((1.0 - f) * rating as f64 + f * target) as f32
    }

    /// Covariate-shift direction for `dim`-dimensional features: a
    /// seeded unit-norm vector, constant over the run (the *amount* of
    /// shift applied is `factor(clock)` times an app-chosen magnitude).
    pub fn shift_direction(&self, dim: usize) -> Vec<f32> {
        let mut rng = Rng::seed_from_u64(self.seed ^ 0xC0FF_EE00_D15E_A5E5);
        let mut dir: Vec<f64> = (0..dim).map(|_| rng.gen_normal()).collect();
        let norm = dir.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-9);
        dir.iter_mut().for_each(|v| *v /= norm);
        dir.into_iter().map(|v| v as f32).collect()
    }

    /// Label shift: a seeded subset of examples (growing with the
    /// drift factor, up to 25%) rotates to the next class.  The result
    /// is always a valid class index for `classes >= 1`.
    pub fn drifted_label(&self, clock: u64, key: u64, label: i32, classes: usize) -> i32 {
        let f = self.factor(clock);
        if f <= 0.0 || classes <= 1 {
            return label;
        }
        if self.unit(key ^ 0xA5A5_A5A5_A5A5_A5A5) < f * 0.25 {
            (label.rem_euclid(classes as i32) + 1) % classes as i32
        } else {
            label
        }
    }
}

/// Labeled feature vectors (the classifier workload).
#[derive(Debug, Clone)]
pub struct ImageDataset {
    pub dim: usize,
    pub classes: usize,
    /// row-major [n, dim]
    pub x: Vec<f32>,
    pub y: Vec<i32>,
}

impl ImageDataset {
    /// `spread` controls difficulty: noise σ relative to unit-norm
    /// cluster centers (≈1.0 is hard, ≈0.3 is easy).
    pub fn gaussian_clusters(n: usize, dim: usize, classes: usize, spread: f64, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        // unit-norm class centers
        let mut centers = vec![0f32; classes * dim];
        for c in 0..classes {
            let mut norm = 0.0f64;
            for d in 0..dim {
                let v: f64 = rng.gen_normal();
                centers[c * dim + d] = v as f32;
                norm += v * v;
            }
            let inv = 1.0 / norm.sqrt().max(1e-9);
            for d in 0..dim {
                centers[c * dim + d] *= inv as f32;
            }
        }
        let mut x = Vec::with_capacity(n * dim);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let c = rng.gen_range(0, classes);
            y.push(c as i32);
            for d in 0..dim {
                let noise: f64 = rng.gen_normal();
                x.push(centers[c * dim + d] + (noise * spread) as f32);
            }
        }
        ImageDataset {
            dim,
            classes,
            x,
            y,
        }
    }

    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Copy example `i`'s features into `out`.
    pub fn fill_example(&self, i: usize, out: &mut [f32]) {
        out.copy_from_slice(&self.x[i * self.dim..(i + 1) * self.dim]);
    }

    /// Split into (train, validation) — same cluster centers, disjoint
    /// examples (a fresh dataset per seed would have *different*
    /// centers and be unlearnable).
    pub fn split(mut self, val: usize) -> (ImageDataset, ImageDataset) {
        assert!(val < self.len());
        let n_train = self.len() - val;
        let vx = self.x.split_off(n_train * self.dim);
        let vy = self.y.split_off(n_train);
        let val_ds = ImageDataset {
            dim: self.dim,
            classes: self.classes,
            x: vx,
            y: vy,
        };
        (self, val_ds)
    }

    /// The contiguous index range of worker `w` out of `num_workers`
    /// (data-parallel partitioning).
    pub fn partition(&self, w: usize, num_workers: usize) -> std::ops::Range<usize> {
        let n = self.len();
        let lo = w * n / num_workers;
        let hi = (w + 1) * n / num_workers;
        lo..hi
    }
}

/// Epoch-shuffled mini-batch cursor over one worker's partition.
#[derive(Debug, Clone)]
pub struct BatchCursor {
    indices: Vec<usize>,
    pos: usize,
    rng: Rng,
}

impl BatchCursor {
    pub fn new(range: std::ops::Range<usize>, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let mut indices: Vec<usize> = range.collect();
        rng.shuffle(&mut indices);
        BatchCursor {
            indices,
            pos: 0,
            rng,
        }
    }

    /// Next `bs` example indices, reshuffling at epoch boundaries
    /// ("shuffle the training data every epoch", §5.1).
    pub fn next_batch(&mut self, bs: usize, out: &mut Vec<usize>) {
        out.clear();
        for _ in 0..bs {
            if self.pos >= self.indices.len() {
                self.rng.shuffle(&mut self.indices);
                self.pos = 0;
            }
            out.push(self.indices[self.pos]);
            self.pos += 1;
        }
    }
}

/// Sparse ratings: (user, item, rating) triples from a low-rank model.
#[derive(Debug, Clone)]
pub struct RatingsDataset {
    pub users: usize,
    pub items: usize,
    pub ratings: Vec<(u32, u32, f32)>,
}

impl RatingsDataset {
    pub fn low_rank(
        users: usize,
        items: usize,
        rank_true: usize,
        n_ratings: usize,
        noise: f64,
        seed: u64,
    ) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
                let scale = (1.0 / rank_true as f64).sqrt();
        let mut l = vec![0f32; users * rank_true];
        let mut r = vec![0f32; items * rank_true];
        for v in l.iter_mut().chain(r.iter_mut()) {
            *v = (rng.gen_normal() * scale) as f32;
        }
        let mut ratings = Vec::with_capacity(n_ratings);
        for _ in 0..n_ratings {
            let u = rng.gen_range(0, users) as u32;
            let i = rng.gen_range(0, items) as u32;
            let mut dot = 0f32;
            for k in 0..rank_true {
                dot += l[u as usize * rank_true + k] * r[i as usize * rank_true + k];
            }
            let e: f64 = rng.gen_normal();
            ratings.push((u, i, dot + (e * noise) as f32));
        }
        RatingsDataset {
            users,
            items,
            ratings,
        }
    }

    pub fn len(&self) -> usize {
        self.ratings.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ratings.is_empty()
    }

    pub fn partition(&self, w: usize, num_workers: usize) -> &[(u32, u32, f32)] {
        let n = self.len();
        let lo = w * n / num_workers;
        let hi = (w + 1) * n / num_workers;
        &self.ratings[lo..hi]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clusters_deterministic_and_labeled() {
        let a = ImageDataset::gaussian_clusters(100, 8, 4, 0.3, 42);
        let b = ImageDataset::gaussian_clusters(100, 8, 4, 0.3, 42);
        let c = ImageDataset::gaussian_clusters(100, 8, 4, 0.3, 43);
        assert_eq!(a.x, b.x);
        assert_ne!(a.x, c.x);
        assert!(a.y.iter().all(|&l| (0..4).contains(&l)));
        assert_eq!(a.x.len(), 100 * 8);
    }

    #[test]
    fn clusters_are_separable() {
        // nearest-center classification should beat chance easily
        let ds = ImageDataset::gaussian_clusters(400, 16, 4, 0.2, 7);
        // recompute centers from the labeled data
        let mut centers = vec![0f64; 4 * 16];
        let mut counts = [0usize; 4];
        for i in 0..ds.len() {
            let c = ds.y[i] as usize;
            counts[c] += 1;
            for d in 0..16 {
                centers[c * 16 + d] += ds.x[i * 16 + d] as f64;
            }
        }
        for c in 0..4 {
            for d in 0..16 {
                centers[c * 16 + d] /= counts[c].max(1) as f64;
            }
        }
        let mut correct = 0;
        for i in 0..ds.len() {
            let mut best = (f64::INFINITY, 0);
            for c in 0..4 {
                let mut d2 = 0.0;
                for d in 0..16 {
                    let diff = ds.x[i * 16 + d] as f64 - centers[c * 16 + d];
                    d2 += diff * diff;
                }
                if d2 < best.0 {
                    best = (d2, c);
                }
            }
            if best.1 == ds.y[i] as usize {
                correct += 1;
            }
        }
        assert!(correct > 300, "only {correct}/400 correct");
    }

    #[test]
    fn partitions_cover_and_disjoint() {
        let ds = ImageDataset::gaussian_clusters(103, 4, 2, 0.5, 1);
        let mut seen = vec![false; ds.len()];
        for w in 0..8 {
            for i in ds.partition(w, 8) {
                assert!(!seen[i]);
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn cursor_visits_all_before_repeat() {
        let mut cur = BatchCursor::new(0..10, 3);
        let mut out = Vec::new();
        let mut seen = std::collections::BTreeSet::new();
        cur.next_batch(10, &mut out);
        for &i in &out {
            seen.insert(i);
        }
        assert_eq!(seen.len(), 10, "one epoch visits every example");
    }

    #[test]
    fn drift_factor_shapes() {
        let none = DriftSchedule::none();
        let step = DriftSchedule::step(10, 7);
        let ramp = DriftSchedule::ramp(10, 5, 7);
        for c in 0..40 {
            assert_eq!(none.factor(c), 0.0);
        }
        assert_eq!(step.factor(9), 0.0);
        assert_eq!(step.factor(10), 1.0);
        assert_eq!(step.factor(1_000_000), 1.0);
        assert_eq!(ramp.factor(9), 0.0);
        let mut prev = 0.0;
        for c in 10..20 {
            let f = ramp.factor(c);
            assert!((0.0..=1.0).contains(&f));
            assert!(f >= prev, "ramp must be monotone");
            prev = f;
        }
        assert_eq!(ramp.factor(14), 1.0, "ramp saturates after ramp_clocks");
        assert!(!none.is_active() && step.is_active() && ramp.is_active());
    }

    #[test]
    fn drift_parse_rejects_unknown_kind() {
        assert!(DriftSchedule::parse("step", 5, 1, 0).is_ok());
        assert!(DriftSchedule::parse("ramp", 5, 0, 0).is_ok());
        assert!(DriftSchedule::parse("sine", 5, 1, 0).is_err());
    }

    #[test]
    fn drifted_rating_deterministic_finite_and_identity_before_at() {
        let d = DriftSchedule::step(100, 42);
        // identity before the drift point
        assert_eq!(d.drifted_rating(99, 3, 4, 1.25), 1.25);
        // deterministic and finite after it
        let a = d.drifted_rating(100, 3, 4, 1.25);
        let b = d.drifted_rating(100, 3, 4, 1.25);
        assert_eq!(a.to_bits(), b.to_bits());
        assert!(a.is_finite());
        assert_ne!(a, 1.25, "a fully-stepped rating moves to its target");
        // different pairs get different targets
        let c = d.drifted_rating(100, 5, 6, 1.25);
        assert_ne!(a.to_bits(), c.to_bits());
    }

    #[test]
    fn shift_direction_is_unit_norm_and_seeded() {
        let a = DriftSchedule::step(0, 1).shift_direction(16);
        let b = DriftSchedule::step(0, 1).shift_direction(16);
        let c = DriftSchedule::step(0, 2).shift_direction(16);
        assert_eq!(a, b);
        assert_ne!(a, c);
        let norm: f64 = a.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt();
        assert!((norm - 1.0).abs() < 1e-3, "norm {norm}");
        assert!(a.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn drifted_labels_stay_in_range() {
        let d = DriftSchedule::step(0, 9);
        let classes = 4usize;
        let mut moved = 0;
        for key in 0..400u64 {
            let label = (key % classes as u64) as i32;
            let out = d.drifted_label(0, key, label, classes);
            assert!((0..classes as i32).contains(&out));
            if out != label {
                moved += 1;
            }
        }
        assert!(moved > 0, "a step drift must move some labels");
        assert!(moved < 400, "label shift is partial, not total");
        // single-class datasets are untouched
        assert_eq!(d.drifted_label(0, 7, 0, 1), 0);
    }

    #[test]
    fn ratings_low_rank_recoverable() {
        let ds = RatingsDataset::low_rank(50, 40, 4, 2000, 0.01, 9);
        assert_eq!(ds.len(), 2000);
        // ratings are bounded-ish (low-rank dot products)
        let max = ds.ratings.iter().map(|r| r.2.abs()).fold(0f32, f32::max);
        assert!(max < 10.0);
        let p = ds.partition(3, 8);
        assert_eq!(p.len(), 250);
    }
}
