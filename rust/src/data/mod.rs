//! Synthetic dataset generators (DESIGN.md §3 substitutions).
//!
//! * [`ImageDataset`] — Gaussian-cluster "images" standing in for
//!   Cifar10 / ILSVRC12: `classes` cluster centers in `dim` dimensions;
//!   examples are center + noise.  Separable enough that a correctly
//!   tuned classifier climbs steadily, hard enough that tuning matters.
//! * [`RatingsDataset`] — low-rank synthetic ratings standing in for
//!   Netflix: `X ≈ L·R + noise`, sampled sparsely.
//!
//! Everything is deterministic per seed (Fig. 9 varies seeds on
//! purpose; everything else must be reproducible).

use crate::util::rng::Rng;

/// Labeled feature vectors (the classifier workload).
#[derive(Debug, Clone)]
pub struct ImageDataset {
    pub dim: usize,
    pub classes: usize,
    /// row-major [n, dim]
    pub x: Vec<f32>,
    pub y: Vec<i32>,
}

impl ImageDataset {
    /// `spread` controls difficulty: noise σ relative to unit-norm
    /// cluster centers (≈1.0 is hard, ≈0.3 is easy).
    pub fn gaussian_clusters(n: usize, dim: usize, classes: usize, spread: f64, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        // unit-norm class centers
        let mut centers = vec![0f32; classes * dim];
        for c in 0..classes {
            let mut norm = 0.0f64;
            for d in 0..dim {
                let v: f64 = rng.gen_normal();
                centers[c * dim + d] = v as f32;
                norm += v * v;
            }
            let inv = 1.0 / norm.sqrt().max(1e-9);
            for d in 0..dim {
                centers[c * dim + d] *= inv as f32;
            }
        }
        let mut x = Vec::with_capacity(n * dim);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let c = rng.gen_range(0, classes);
            y.push(c as i32);
            for d in 0..dim {
                let noise: f64 = rng.gen_normal();
                x.push(centers[c * dim + d] + (noise * spread) as f32);
            }
        }
        ImageDataset {
            dim,
            classes,
            x,
            y,
        }
    }

    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Copy example `i`'s features into `out`.
    pub fn fill_example(&self, i: usize, out: &mut [f32]) {
        out.copy_from_slice(&self.x[i * self.dim..(i + 1) * self.dim]);
    }

    /// Split into (train, validation) — same cluster centers, disjoint
    /// examples (a fresh dataset per seed would have *different*
    /// centers and be unlearnable).
    pub fn split(mut self, val: usize) -> (ImageDataset, ImageDataset) {
        assert!(val < self.len());
        let n_train = self.len() - val;
        let vx = self.x.split_off(n_train * self.dim);
        let vy = self.y.split_off(n_train);
        let val_ds = ImageDataset {
            dim: self.dim,
            classes: self.classes,
            x: vx,
            y: vy,
        };
        (self, val_ds)
    }

    /// The contiguous index range of worker `w` out of `num_workers`
    /// (data-parallel partitioning).
    pub fn partition(&self, w: usize, num_workers: usize) -> std::ops::Range<usize> {
        let n = self.len();
        let lo = w * n / num_workers;
        let hi = (w + 1) * n / num_workers;
        lo..hi
    }
}

/// Epoch-shuffled mini-batch cursor over one worker's partition.
#[derive(Debug, Clone)]
pub struct BatchCursor {
    indices: Vec<usize>,
    pos: usize,
    rng: Rng,
}

impl BatchCursor {
    pub fn new(range: std::ops::Range<usize>, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let mut indices: Vec<usize> = range.collect();
        rng.shuffle(&mut indices);
        BatchCursor {
            indices,
            pos: 0,
            rng,
        }
    }

    /// Next `bs` example indices, reshuffling at epoch boundaries
    /// ("shuffle the training data every epoch", §5.1).
    pub fn next_batch(&mut self, bs: usize, out: &mut Vec<usize>) {
        out.clear();
        for _ in 0..bs {
            if self.pos >= self.indices.len() {
                self.rng.shuffle(&mut self.indices);
                self.pos = 0;
            }
            out.push(self.indices[self.pos]);
            self.pos += 1;
        }
    }
}

/// Sparse ratings: (user, item, rating) triples from a low-rank model.
#[derive(Debug, Clone)]
pub struct RatingsDataset {
    pub users: usize,
    pub items: usize,
    pub ratings: Vec<(u32, u32, f32)>,
}

impl RatingsDataset {
    pub fn low_rank(
        users: usize,
        items: usize,
        rank_true: usize,
        n_ratings: usize,
        noise: f64,
        seed: u64,
    ) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
                let scale = (1.0 / rank_true as f64).sqrt();
        let mut l = vec![0f32; users * rank_true];
        let mut r = vec![0f32; items * rank_true];
        for v in l.iter_mut().chain(r.iter_mut()) {
            *v = (rng.gen_normal() * scale) as f32;
        }
        let mut ratings = Vec::with_capacity(n_ratings);
        for _ in 0..n_ratings {
            let u = rng.gen_range(0, users) as u32;
            let i = rng.gen_range(0, items) as u32;
            let mut dot = 0f32;
            for k in 0..rank_true {
                dot += l[u as usize * rank_true + k] * r[i as usize * rank_true + k];
            }
            let e: f64 = rng.gen_normal();
            ratings.push((u, i, dot + (e * noise) as f32));
        }
        RatingsDataset {
            users,
            items,
            ratings,
        }
    }

    pub fn len(&self) -> usize {
        self.ratings.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ratings.is_empty()
    }

    pub fn partition(&self, w: usize, num_workers: usize) -> &[(u32, u32, f32)] {
        let n = self.len();
        let lo = w * n / num_workers;
        let hi = (w + 1) * n / num_workers;
        &self.ratings[lo..hi]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clusters_deterministic_and_labeled() {
        let a = ImageDataset::gaussian_clusters(100, 8, 4, 0.3, 42);
        let b = ImageDataset::gaussian_clusters(100, 8, 4, 0.3, 42);
        let c = ImageDataset::gaussian_clusters(100, 8, 4, 0.3, 43);
        assert_eq!(a.x, b.x);
        assert_ne!(a.x, c.x);
        assert!(a.y.iter().all(|&l| (0..4).contains(&l)));
        assert_eq!(a.x.len(), 100 * 8);
    }

    #[test]
    fn clusters_are_separable() {
        // nearest-center classification should beat chance easily
        let ds = ImageDataset::gaussian_clusters(400, 16, 4, 0.2, 7);
        // recompute centers from the labeled data
        let mut centers = vec![0f64; 4 * 16];
        let mut counts = [0usize; 4];
        for i in 0..ds.len() {
            let c = ds.y[i] as usize;
            counts[c] += 1;
            for d in 0..16 {
                centers[c * 16 + d] += ds.x[i * 16 + d] as f64;
            }
        }
        for c in 0..4 {
            for d in 0..16 {
                centers[c * 16 + d] /= counts[c].max(1) as f64;
            }
        }
        let mut correct = 0;
        for i in 0..ds.len() {
            let mut best = (f64::INFINITY, 0);
            for c in 0..4 {
                let mut d2 = 0.0;
                for d in 0..16 {
                    let diff = ds.x[i * 16 + d] as f64 - centers[c * 16 + d];
                    d2 += diff * diff;
                }
                if d2 < best.0 {
                    best = (d2, c);
                }
            }
            if best.1 == ds.y[i] as usize {
                correct += 1;
            }
        }
        assert!(correct > 300, "only {correct}/400 correct");
    }

    #[test]
    fn partitions_cover_and_disjoint() {
        let ds = ImageDataset::gaussian_clusters(103, 4, 2, 0.5, 1);
        let mut seen = vec![false; ds.len()];
        for w in 0..8 {
            for i in ds.partition(w, 8) {
                assert!(!seen[i]);
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn cursor_visits_all_before_repeat() {
        let mut cur = BatchCursor::new(0..10, 3);
        let mut out = Vec::new();
        let mut seen = std::collections::BTreeSet::new();
        cur.next_batch(10, &mut out);
        for &i in &out {
            seen.insert(i);
        }
        assert_eq!(seen.len(), 10, "one epoch visits every example");
    }

    #[test]
    fn ratings_low_rank_recoverable() {
        let ds = RatingsDataset::low_rank(50, 40, 4, 2000, 0.01, 9);
        assert_eq!(ds.len(), 2000);
        // ratings are bounded-ish (low-rank dot products)
        let max = ds.ratings.iter().map(|r| r.2.abs()).fold(0f32, f32::max);
        assert!(max < 10.0);
        let p = ds.partition(3, 8);
        assert_eq!(p.len(), 250);
    }
}
