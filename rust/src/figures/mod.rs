//! Figure/table regeneration experiments (§5, DESIGN.md experiment
//! index).  Each function reproduces one figure's experiment on the
//! simulated benchmarks (or the native MF app) and returns the series
//! the paper plots; the `rust/benches/fig*.rs` binaries print them as
//! tables (`cargo bench --bench fig3_sota`, …).
//!
//! Absolute numbers are testbed-dependent; the *shapes* (who wins, by
//! roughly what factor, where crossovers fall) are the reproduction
//! target — see EXPERIMENTS.md for paper-vs-measured.

use anyhow::Result;

use crate::apps::mf::{MfConfig, MfSystem};
use crate::apps::sim::{optimizer_gain, SimProfile, SimSystem};
use crate::baselines::{BaselineReport, HyperbandDriver, SpearmintDriver};
use crate::comm::BranchType;
use crate::metrics::coefficient_of_variation;
use crate::optim::OptimizerKind;
use crate::training::TrainingSystem;
use crate::tunable::{TunableSpace, TunableSpec};
use crate::tuner::{ConvergenceCriterion, MLtuner, RetuneTrigger, TunerConfig, TunerReport};

/// Convenience: full MLtuner run on a simulated profile.
pub fn mltuner_run(
    profile: SimProfile,
    seed: u64,
    plateau_epochs: u32,
    max_epochs: u64,
) -> Result<TunerReport> {
    let sys = SimSystem::new(profile, 8, seed);
    let mut cfg = TunerConfig::new(sys.space.clone());
    cfg.seed = seed;
    cfg.max_epochs = max_epochs;
    cfg.convergence = ConvergenceCriterion::AccuracyPlateau {
        epochs: plateau_epochs,
    };
    let mut tuner = MLtuner::new(sys, cfg);
    tuner.run()
}

/// Fixed-setting run (no tuner search; optional LR decay schedule) —
/// the "manually tuned" arms of Figs. 6/8/9.
pub struct ManualSchedule {
    pub lr0: f64,
    pub momentum: f64,
    pub batch_size: f64,
    pub staleness: f64,
    /// multiply LR by `decay_factor` every `decay_every` epochs (1.0 =
    /// no decay).
    pub decay_factor: f64,
    pub decay_every: u64,
}

pub struct ManualResult {
    pub final_accuracy: f64,
    pub total_time: f64,
    pub epochs: u64,
}

pub fn manual_run(
    profile: SimProfile,
    sched: &ManualSchedule,
    optimizer: OptimizerKind,
    seed: u64,
    plateau_epochs: u32,
    max_epochs: u64,
) -> Result<ManualResult> {
    let mut sys = SimSystem::new(profile, 8, seed).with_optimizer(optimizer);
    let space = sys.space.clone();
    let mk = |lr: f64| {
        space.decode(&[
            space.specs[0].encode(lr),
            space.specs[1].encode(sched.momentum),
            space.specs[2].encode(sched.batch_size),
            space.specs[3].encode(sched.staleness),
        ])
    };
    let mut lr = sched.lr0;
    sys.fork_branch(0, 1, None, &mk(lr), BranchType::Training)?;
    let mut now = 0.0;
    let mut clock = 0u64;
    let mut best_acc = f64::NEG_INFINITY;
    let mut since_improve = 0u32;
    let mut epoch = 0u64;
    let mut next_branch = 2u32;
    while epoch < max_epochs {
        let clocks = sys.clocks_per_epoch(1).max(1);
        let mut diverged = false;
        for _ in 0..clocks {
            let p = sys.schedule_branch(clock, 1)?;
            clock += 1;
            now += p.time;
            if !p.value.is_finite() {
                diverged = true;
                break;
            }
        }
        epoch += 1;
        // validation via a testing fork
        let tb = next_branch;
        next_branch += 1;
        sys.fork_branch(clock, tb, Some(1), &mk(lr), BranchType::Testing)?;
        let acc = sys.schedule_branch(clock, tb)?;
        clock += 1;
        now += acc.time;
        sys.free_branch(clock, tb)?;
        if acc.value > best_acc + 1e-9 {
            best_acc = acc.value;
            since_improve = 0;
        } else {
            since_improve += 1;
        }
        if diverged || since_improve >= plateau_epochs {
            break;
        }
        if sched.decay_factor != 1.0 && epoch % sched.decay_every.max(1) == 0 {
            lr *= sched.decay_factor;
            sys.update_tunable(1, &mk(lr))?;
        }
    }
    Ok(ManualResult {
        final_accuracy: best_acc.max(0.0),
        total_time: now,
        epochs: epoch,
    })
}

// ----- Fig. 3: MLtuner vs Spearmint vs Hyperband -----

pub struct Fig3Arm {
    pub name: &'static str,
    /// best-so-far validation accuracy over time
    pub curve: Vec<(f64, f64)>,
    pub best_accuracy: f64,
    pub total_time: f64,
    pub configs_tried: usize,
}

pub fn fig3(profile: SimProfile, budget: f64, seed: u64) -> Result<Vec<Fig3Arm>> {
    let plateau = if profile.name == "alexnet_cifar10" { 20 } else { 5 };
    let mut arms = Vec::new();

    let report = mltuner_run(profile.clone(), seed, plateau, 3000)?;
    arms.push(Fig3Arm {
        name: "MLtuner",
        curve: report.recorder.best_accuracy_curve(),
        best_accuracy: report.final_accuracy,
        total_time: report.total_time,
        configs_tried: report.tunings.iter().map(|t| t.trials).sum(),
    });

    let push_baseline = |arms: &mut Vec<Fig3Arm>, name, r: BaselineReport| {
        arms.push(Fig3Arm {
            name,
            curve: r.recorder.best_accuracy_curve(),
            best_accuracy: r.best_accuracy,
            total_time: r.total_time,
            configs_tried: r.configs.len(),
        });
    };
    let sys = SimSystem::new(profile.clone(), 8, seed);
    let space = sys.space.clone();
    let r = SpearmintDriver::new(sys, space, seed).run(budget)?;
    push_baseline(&mut arms, "Spearmint", r);

    let sys = SimSystem::new(profile, 8, seed);
    let space = sys.space.clone();
    let r = HyperbandDriver::new(sys, space, seed).run(budget)?;
    push_baseline(&mut arms, "Hyperband", r);
    Ok(arms)
}

// ----- Fig. 4/5: tuning behaviour + multi-run consistency -----

pub struct Fig4Run {
    pub profile: &'static str,
    pub accuracies: Vec<(f64, u64, f64)>,
    pub tuning_spans: Vec<(f64, f64, bool)>,
    pub final_accuracy: f64,
    pub total_time: f64,
}

pub fn fig4(seed: u64) -> Result<Vec<Fig4Run>> {
    SimProfile::dl_profiles()
        .into_iter()
        .map(|p| {
            let plateau = if p.name == "alexnet_cifar10" { 20 } else { 5 };
            let name = p.name;
            let report = mltuner_run(p, seed, plateau, 3000)?;
            Ok(Fig4Run {
                profile: name,
                accuracies: report.recorder.accuracies.clone(),
                tuning_spans: report
                    .tunings
                    .iter()
                    .map(|t| (t.started, t.ended, t.trigger == RetuneTrigger::Initial))
                    .collect(),
                final_accuracy: report.final_accuracy,
                total_time: report.total_time,
            })
        })
        .collect()
}

pub struct Fig5Row {
    pub profile: &'static str,
    pub finals: Vec<(f64, f64)>, // (time, accuracy) per run
    pub time_cov: f64,
    pub acc_cov: f64,
}

pub fn fig5(runs_small: usize, runs_large: usize) -> Result<Vec<Fig5Row>> {
    let mut out = Vec::new();
    for p in SimProfile::dl_profiles() {
        let (plateau, runs) = if p.name == "alexnet_cifar10" {
            (20, runs_small)
        } else {
            (5, runs_large)
        };
        let name = p.name;
        let mut finals = Vec::new();
        for seed in 0..runs as u64 {
            let r = mltuner_run(p.clone(), seed * 31 + 1, plateau, 3000)?;
            finals.push((r.total_time, r.final_accuracy));
        }
        let times: Vec<f64> = finals.iter().map(|f| f.0).collect();
        let accs: Vec<f64> = finals.iter().map(|f| f.1).collect();
        out.push(Fig5Row {
            profile: name,
            time_cov: coefficient_of_variation(&times),
            acc_cov: coefficient_of_variation(&accs),
            finals,
        });
    }
    Ok(out)
}

// ----- Fig. 6: converged accuracy vs initial LR per adaptive rule -----

pub struct Fig6Row {
    pub optimizer: OptimizerKind,
    pub grid: Vec<(f64, f64)>, // (lr, converged accuracy)
    pub mltuner_pick: (f64, f64),
}

fn lr_only_space() -> TunableSpace {
    TunableSpace::new(vec![TunableSpec::Log {
        name: "lr".into(),
        min: 1e-5,
        max: 1.0,
    }])
}

pub fn fig6(grid: &[f64], seed: u64) -> Result<Vec<Fig6Row>> {
    let profile = SimProfile::alexnet_cifar10();
    let mut rows = Vec::new();
    for kind in OptimizerKind::ADAPTIVE {
        let mut grid_results = Vec::new();
        for &lr in grid {
            let space = lr_only_space();
            let sys =
                SimSystem::with_space(profile.clone(), space.clone(), 8, seed)
                    .with_optimizer(kind);
            let mut cfg = TunerConfig::new(space.clone());
            cfg.initial_setting = Some(space.decode(&[space.specs[0].encode(lr)]));
            cfg.retune = false;
            cfg.convergence = ConvergenceCriterion::AccuracyPlateau { epochs: 10 };
            cfg.max_epochs = 250;
            cfg.seed = seed;
            let r = MLtuner::new(sys, cfg).run()?;
            grid_results.push((lr, r.final_accuracy));
        }
        // MLtuner tunes only the initial LR (no re-tuning) — §5.3
        let space = lr_only_space();
        let sys = SimSystem::with_space(profile.clone(), space.clone(), 8, seed)
            .with_optimizer(kind);
        let mut cfg = TunerConfig::new(space.clone());
        cfg.retune = false;
        cfg.convergence = ConvergenceCriterion::AccuracyPlateau { epochs: 10 };
        cfg.max_epochs = 250;
        cfg.seed = seed;
        let r = MLtuner::new(sys, cfg).run()?;
        rows.push(Fig6Row {
            optimizer: kind,
            grid: grid_results,
            mltuner_pick: (r.final_setting.lr(&space), r.final_accuracy),
        });
    }
    Ok(rows)
}

// ----- Fig. 7: MF convergence time vs initial AdaRevision LR -----

pub struct Fig7Result {
    pub grid: Vec<(f64, Option<u64>)>, // (lr, passes to threshold)
    pub mltuner_passes: u64,
    pub mltuner_lr: f64,
    pub threshold: f64,
}

pub fn fig7(grid: &[f64], seed: u64, cap_passes: u64) -> Result<Fig7Result> {
    let mk = || {
        MfSystem::new(MfConfig {
            users: 300,
            items: 200,
            rank: 16,
            n_ratings: 20_000,
            num_workers: 8,
            seed,
            ..Default::default()
        })
    };
    let threshold = mk().default_threshold();
    let mut grid_results = Vec::new();
    for &lr in grid {
        let mut sys = mk();
        let space = sys.space().clone();
        let setting = space.decode(&[space.specs[0].encode(lr)]);
        sys.fork_branch(0, 1, None, &setting, BranchType::Training)?;
        let mut passes = None;
        for c in 0..cap_passes {
            let p = sys.schedule_branch(c, 1)?;
            if !p.value.is_finite() {
                break;
            }
            if p.value <= threshold {
                passes = Some(c + 1);
                break;
            }
        }
        grid_results.push((lr, passes));
    }
    let sys = mk();
    let space = sys.space().clone();
    let mut cfg = TunerConfig::new(space.clone());
    cfg.convergence = ConvergenceCriterion::LossThreshold { value: threshold };
    cfg.retune = false;
    cfg.seed = seed;
    cfg.max_epochs = cap_passes * 4;
    let mut tuner = MLtuner::new(sys, cfg);
    let r = tuner.run()?;
    // MLtuner's total cost in passes = clocks (1 clock = 1 pass),
    // including every tuning trial's clocks.
    let passes = r.clocks;
    Ok(Fig7Result {
        grid: grid_results,
        mltuner_passes: passes,
        mltuner_lr: r.final_setting.lr(&space),
        threshold,
    })
}

// ----- Fig. 8: MLtuner vs idealized manual settings -----

pub struct Fig8Row {
    pub profile: &'static str,
    pub manual_acc: f64,
    pub manual_time: f64,
    pub mltuner_acc: f64,
    pub mltuner_time: f64,
}

pub fn fig8(seed: u64) -> Result<Vec<Fig8Row>> {
    // The paper's literature-suggested manual schedules, mapped onto
    // the profiles (raw LRs; momentum 0.9, staleness 0):
    let arms: Vec<(SimProfile, ManualSchedule, u32)> = vec![
        (
            SimProfile::inception_bn(),
            ManualSchedule {
                lr0: 0.045,
                momentum: 0.9,
                batch_size: 32.0,
                staleness: 0.0,
                decay_factor: 0.97, // -3% every epoch [Ioffe & Szegedy]
                decay_every: 1,
            },
            5,
        ),
        (
            SimProfile::googlenet(),
            ManualSchedule {
                lr0: 0.03, // scaled analog of the paper's setting
                momentum: 0.9,
                batch_size: 32.0,
                staleness: 0.0,
                decay_factor: 0.96, // -4% every 8 epochs [Szegedy et al.]
                decay_every: 8,
            },
            5,
        ),
        (
            SimProfile::alexnet_cifar10(),
            ManualSchedule {
                lr0: 0.01,
                momentum: 0.9,
                batch_size: 256.0,
                staleness: 0.0,
                decay_factor: 1.0, // optimal fixed RMSProp LR (paper)
                decay_every: 1,
            },
            20,
        ),
        (
            SimProfile::rnn_ucf101(),
            ManualSchedule {
                lr0: 0.001,
                momentum: 0.9,
                batch_size: 1.0,
                staleness: 0.0,
                decay_factor: 0.926, // -7.4% every epoch [Donahue et al.]
                decay_every: 1,
            },
            5,
        ),
    ];
    let mut rows = Vec::new();
    for (profile, sched, plateau) in arms {
        let name = profile.name;
        let optimizer = if name == "alexnet_cifar10" {
            OptimizerKind::RmsProp
        } else {
            OptimizerKind::Sgd
        };
        // For RMSProp the preferred LR band is shifted; translate the
        // manual raw LR into the rule's preferred scale.
        let manual_lr0 = if optimizer == OptimizerKind::RmsProp {
            optimizer_gain(optimizer, profile.opt_lr).0 * (1.0 - 0.9 * 0.9)
        } else {
            sched.lr0
        };
        let manual = manual_run(
            profile.clone(),
            &ManualSchedule {
                lr0: manual_lr0,
                ..sched
            },
            optimizer,
            seed,
            // run manual arms to full saturation, as the paper does
            plateau * 2,
            4000,
        )?;
        let report = mltuner_run(profile, seed, plateau, 3000)?;
        rows.push(Fig8Row {
            profile: name,
            manual_acc: manual.final_accuracy,
            manual_time: manual.total_time,
            mltuner_acc: report.final_accuracy,
            mltuner_time: report.total_time,
        });
    }
    Ok(rows)
}

// ----- Fig. 9: fixed-setting run-to-run variance -----

pub struct Fig9Result {
    pub same_seed_times: Vec<f64>,
    pub distinct_seed_times: Vec<f64>,
    pub same_cov: f64,
    pub distinct_cov: f64,
    pub acc_cov: f64,
}

pub fn fig9(runs: usize) -> Result<Fig9Result> {
    let profile = SimProfile::alexnet_cifar10();
    let sched = ManualSchedule {
        lr0: optimizer_gain(OptimizerKind::RmsProp, profile.opt_lr).0
            * (1.0 - 0.9 * 0.9),
        momentum: 0.9,
        batch_size: 256.0,
        staleness: 0.0,
        decay_factor: 1.0,
        decay_every: 1,
    };
    // "Same seed" runs share data/init seed; the residual variance
    // models non-deterministic floating-point reduction order, which
    // the SimSystem folds into its per-branch rng stream (branch ids
    // differ run to run is not available here, so we perturb the rng
    // stream by run index while keeping the data seed fixed).
    let mut same = Vec::new();
    let mut distinct = Vec::new();
    let mut accs = Vec::new();
    for run in 0..runs as u64 {
        let r = manual_run(
            profile.clone(),
            &sched,
            OptimizerKind::RmsProp,
            1_000 + run, // distinct rng stream, same "experiment"
            20,
            2000,
        )?;
        same.push(r.total_time);
        accs.push(r.final_accuracy);
        let r = manual_run(
            profile.clone(),
            &sched,
            OptimizerKind::RmsProp,
            31 * run + 7, // fully distinct seeds
            20,
            2000,
        )?;
        distinct.push(r.total_time);
    }
    Ok(Fig9Result {
        same_cov: coefficient_of_variation(&same),
        distinct_cov: coefficient_of_variation(&distinct),
        acc_cov: coefficient_of_variation(&accs),
        same_seed_times: same,
        distinct_seed_times: distinct,
    })
}

// ----- Fig. 10: robustness to suboptimal initial settings -----

pub struct Fig10Row {
    pub start_lr: f64,
    pub final_accuracy: f64,
    pub total_time: f64,
    pub retunings: usize,
}

pub fn fig10(starts: &[f64], seed: u64) -> Result<Vec<Fig10Row>> {
    let profile = SimProfile::alexnet_cifar10();
    let mut rows = Vec::new();
    for (i, &lr) in starts.iter().enumerate() {
        let sys = SimSystem::new(profile.clone(), 8, seed + i as u64);
        let space = sys.space.clone();
        let mut cfg = TunerConfig::new(space.clone());
        cfg.initial_setting = Some(space.decode(&[
            space.specs[0].encode(lr),
            0.3,
            0.8,
            0.0,
        ]));
        cfg.seed = seed + i as u64;
        cfg.max_epochs = 600;
        cfg.convergence = ConvergenceCriterion::AccuracyPlateau { epochs: 5 };
        let r = MLtuner::new(sys, cfg).run()?;
        rows.push(Fig10Row {
            start_lr: lr,
            final_accuracy: r.final_accuracy,
            total_time: r.total_time,
            retunings: r.tunings.len(),
        });
    }
    Ok(rows)
}

// ----- Fig. 11: scalability with more tunables -----

pub struct Fig11Row {
    pub tunables: usize,
    pub final_accuracy: f64,
    pub total_time: f64,
    pub tuning_time: f64,
    /// duration of the initial tuning stage (the Fig. 11 comparison)
    pub initial_tuning_time: f64,
    pub trials: usize,
}

pub fn fig11(seeds: &[u64]) -> Result<Vec<Fig11Row>> {
    let profile = SimProfile::alexnet_cifar10();
    let spaces = [
        TunableSpace::standard(&profile.batch_sizes),
        TunableSpace::standard_duplicated(&profile.batch_sizes),
    ];
    let mut rows = Vec::new();
    for space in spaces {
        let (mut acc, mut total, mut tuning, mut initial, mut trials) =
            (0.0, 0.0, 0.0, 0.0, 0usize);
        for &seed in seeds {
            let sys = SimSystem::with_space(profile.clone(), space.clone(), 8, seed);
            let mut cfg = TunerConfig::new(space.clone());
            cfg.seed = seed;
            cfg.max_epochs = 600;
            cfg.convergence = ConvergenceCriterion::AccuracyPlateau { epochs: 20 };
            let r = MLtuner::new(sys, cfg).run()?;
            acc += r.final_accuracy;
            total += r.total_time;
            tuning += r.tuning_time;
            if let Some(t0) = r.tunings.iter().find(|t| t.trigger == RetuneTrigger::Initial) {
                initial += t0.ended - t0.started;
                trials += t0.trials;
            }
        }
        let n = seeds.len() as f64;
        rows.push(Fig11Row {
            tunables: space.dim(),
            final_accuracy: acc / n,
            total_time: total / n,
            tuning_time: tuning / n,
            initial_tuning_time: initial / n,
            trials: (trials as f64 / n) as usize,
        });
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manual_run_trains_and_stops() {
        let r = manual_run(
            SimProfile::alexnet_cifar10(),
            &ManualSchedule {
                lr0: 0.01,
                momentum: 0.9,
                batch_size: 256.0,
                staleness: 0.0,
                decay_factor: 0.95,
                decay_every: 1,
            },
            OptimizerKind::Sgd,
            3,
            10,
            800,
        )
        .unwrap();
        assert!(r.final_accuracy > 0.5, "{}", r.final_accuracy);
        assert!(r.epochs < 800);
    }

    #[test]
    fn manual_divergent_lr_stops_early_with_low_accuracy() {
        let r = manual_run(
            SimProfile::alexnet_cifar10(),
            &ManualSchedule {
                lr0: 1.0,
                momentum: 0.9,
                batch_size: 4.0,
                staleness: 0.0,
                decay_factor: 1.0,
                decay_every: 1,
            },
            OptimizerKind::Sgd,
            3,
            10,
            800,
        )
        .unwrap();
        assert!(r.final_accuracy < 0.1);
        assert!(r.epochs <= 2);
    }
}
