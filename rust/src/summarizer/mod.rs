//! Progress summarizer (§4.1): turn a noisy per-clock progress trace
//! into a conservative convergence-speed estimate and a stability label.
//!
//! The trace `{(t_i, x_i)}` is down-sampled into `K` non-overlapping
//! windows (window value = mean of its points); the noisiness is the
//! maximum upward jump between consecutive down-sampled points; the
//! speed is penalized by that noise:
//!
//! ```text
//! noise(x̃)  = max(max_i (x̃_{i+1} - x̃_i), 0)
//! speed     = max((-range(x̃) - noise(x̃)) / range(t̃), 0)
//! ```
//!
//! Labels: **converging** if `range(x̃) < 0` and
//! `noise(x̃) < ε·|range(x̃)|`; **diverged** if the trace contains
//! numerically-overflowed values; otherwise **unstable** (needs a longer
//! trial).  Defaults `K = 10` (white-noise false-positive < 0.1%) and
//! `ε = 1/K` are the paper's and need no user tuning.
//!
//! [`SlopeWatchdog`] reuses the same downsample/slope machinery for the
//! always-on re-tune trigger: it watches the *training* loss stream
//! (log-domain, so the healthy exponential descent has a constant
//! slope), tracks the trailing best slope, and reports degradation once
//! the slope stays below a configured fraction of that best for K
//! consecutive observations.  NaN/Inf windows, sub-minimum windows and
//! flat-zero slopes never fire (see the unit tests); all comparisons go
//! through `total_cmp` so a NaN can never invert a ranking.

use std::cmp::Ordering;

/// One progress observation: (timestamp seconds, progress value).
/// For SGD apps the progress value is the per-clock training loss.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ProgressPoint {
    pub t: f64,
    pub x: f64,
}

/// Stability label assigned to a trial branch (§4.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BranchLabel {
    /// Stable converging progress; its speed is trustworthy.
    Converging,
    /// Numerically overflowed (NaN/inf loss).  Speed is reported as 0
    /// and all diverged branches are treated as equally bad.
    Diverged,
    /// Neither: the speed estimate needs a longer trial to stabilize.
    Unstable,
}

/// Output of [`ProgressSummarizer::summarize`].
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub label: BranchLabel,
    /// Conservative (noise-penalized) convergence speed, ≥ 0.
    pub speed: f64,
    /// `x̃_K - x̃_1` of the down-sampled trace (negative when improving).
    pub range_x: f64,
    /// Maximum upward jump between consecutive down-sampled points.
    pub noise: f64,
    /// The down-sampled trace itself (for logging / debugging).
    pub downsampled: Vec<ProgressPoint>,
}

impl Summary {
    fn diverged() -> Self {
        Summary {
            label: BranchLabel::Diverged,
            speed: 0.0,
            range_x: f64::INFINITY,
            noise: f64::INFINITY,
            downsampled: Vec::new(),
        }
    }
}

/// The summarizer module.  `K` and `ε` are fixed by the paper's analysis
/// (§4.1 "Deciding number of samples and stability threshold") — users
/// never tune them.
#[derive(Debug, Clone)]
pub struct ProgressSummarizer {
    /// Number of down-sampling windows (paper: 10).
    pub k: usize,
    /// Stability threshold (paper: 1/K).
    pub epsilon: f64,
}

impl Default for ProgressSummarizer {
    fn default() -> Self {
        let k = 10;
        ProgressSummarizer {
            k,
            epsilon: 1.0 / k as f64,
        }
    }
}

impl ProgressSummarizer {
    pub fn new(k: usize) -> Self {
        assert!(k >= 2, "need at least 2 windows");
        ProgressSummarizer {
            k,
            epsilon: 1.0 / k as f64,
        }
    }

    /// Down-sample `trace` into at most `self.k` windows by uniform
    /// division; each window's value is the mean of its points.
    pub fn downsample(&self, trace: &[ProgressPoint]) -> Vec<ProgressPoint> {
        if trace.is_empty() {
            return Vec::new();
        }
        let k = self.k.min(trace.len());
        let n = trace.len();
        let mut out = Vec::with_capacity(k);
        for w in 0..k {
            let lo = w * n / k;
            let hi = ((w + 1) * n / k).max(lo + 1);
            let slice = &trace[lo..hi];
            let inv = 1.0 / slice.len() as f64;
            let (mut st, mut sx) = (0.0, 0.0);
            for p in slice {
                st += p.t;
                sx += p.x;
            }
            out.push(ProgressPoint {
                t: st * inv,
                x: sx * inv,
            });
        }
        out
    }

    /// Summarize a trial branch's progress trace (§4.1).
    pub fn summarize(&self, trace: &[ProgressPoint]) -> Summary {
        // Divergence: numerically overflowed numbers anywhere in the trace.
        if trace.iter().any(|p| !p.x.is_finite()) {
            return Summary::diverged();
        }
        let ds = self.downsample(trace);
        // The K-window false-positive analysis (§4.1) needs K actual
        // windows: traces shorter than K points can never be labelled
        // Converging (a 3-point monotone run is 12.5% likely by chance).
        let enough_points = trace.len() >= self.k;
        if ds.len() < 2 {
            return Summary {
                label: BranchLabel::Unstable,
                speed: 0.0,
                range_x: 0.0,
                noise: 0.0,
                downsampled: ds,
            };
        }
        let range_x = ds[ds.len() - 1].x - ds[0].x;
        let range_t = ds[ds.len() - 1].t - ds[0].t;
        let noise = ds
            .windows(2)
            .map(|w| w[1].x - w[0].x)
            .fold(0.0f64, f64::max)
            .max(0.0);
        let speed = if range_t > 0.0 {
            ((-range_x - noise) / range_t).max(0.0)
        } else {
            0.0
        };
        let label = if enough_points
            && range_x < 0.0
            && noise < self.epsilon * range_x.abs()
        {
            BranchLabel::Converging
        } else {
            BranchLabel::Unstable
        };
        Summary {
            label,
            speed,
            range_x,
            noise,
            downsampled: ds,
        }
    }
}

/// Always-on progress-slope watchdog (the re-tune plane's trigger).
///
/// Feed it every training-clock loss via [`SlopeWatchdog::observe`]; it
/// keeps a rolling window of log-loss points, summarizes the window
/// with the §4.1 downsampler, and returns `true` once the slope has
/// stayed below `fraction` of its trailing best for `windows`
/// consecutive observations.  Firing disarms the watchdog; the caller
/// re-arms it with [`SlopeWatchdog::reset`] after adopting a new
/// setting (or leaves it disarmed, in which case it re-arms itself only
/// once the slope recovers to half the trailing best — so a run sitting
/// at its convergence plateau costs at most one speculative re-tune).
///
/// Hostile inputs are inert by construction: non-finite losses poison
/// the window into the Diverged label (no fire), windows below
/// `min_points` or with fewer than two downsampled windows report
/// nothing, and a flat or rising trace keeps the trailing best at zero,
/// which can never be degraded from.
#[derive(Debug, Clone)]
pub struct SlopeWatchdog {
    summarizer: ProgressSummarizer,
    /// Fire when slope < `fraction` × trailing best…
    fraction: f64,
    /// …for this many consecutive observations.
    windows: u32,
    /// Minimum points in the rolling window before slopes count.
    min_points: usize,
    /// Rolling-window capacity (points beyond it scroll off).
    cap: usize,
    window: Vec<ProgressPoint>,
    best_speed: f64,
    degraded: u32,
    armed: bool,
}

impl SlopeWatchdog {
    pub fn new(fraction: f64, windows: u32, min_points: usize) -> Self {
        let summarizer = ProgressSummarizer::default();
        let min_points = min_points.max(2);
        SlopeWatchdog {
            cap: min_points.max(summarizer.k) * 4,
            summarizer,
            fraction,
            windows: windows.max(1),
            min_points,
            window: Vec::new(),
            best_speed: 0.0,
            degraded: 0,
            armed: true,
        }
    }

    /// Trailing best log-loss slope seen since the last full reset.
    pub fn best_speed(&self) -> f64 {
        self.best_speed
    }

    pub fn is_armed(&self) -> bool {
        self.armed
    }

    /// Full re-arm after a re-tune adopted a new setting: the slope
    /// scale starts over (a recovered run should not be held to the
    /// pre-drift best forever).
    pub fn reset(&mut self) {
        self.window.clear();
        self.best_speed = 0.0;
        self.degraded = 0;
        self.armed = true;
    }

    /// Soft reset after a re-tune found nothing better: drop the stale
    /// window (trial time passed between its points and the next) but
    /// keep the trailing best and stay disarmed until the slope
    /// genuinely recovers.
    pub fn reset_window(&mut self) {
        self.window.clear();
        self.degraded = 0;
    }

    /// Observe one training-clock loss at time `t`.  Returns `true`
    /// when the degradation trigger fires (and disarms itself).
    pub fn observe(&mut self, t: f64, loss: f64) -> bool {
        // Log domain: healthy exponential descent has constant slope
        // there, so "slope fell to a fraction of its best" means the
        // *rate* collapsed, not that training matured.  Non-finite
        // losses stay non-finite and poison the window to Diverged.
        let x = if loss.is_finite() {
            loss.max(1e-300).ln()
        } else {
            f64::NAN
        };
        if self.window.len() == self.cap {
            self.window.remove(0);
        }
        self.window.push(ProgressPoint { t, x });
        if self.window.len() < self.min_points {
            return false;
        }
        let summary = self.summarizer.summarize(&self.window);
        if summary.label == BranchLabel::Diverged || summary.downsampled.len() < 2 {
            self.degraded = 0;
            return false;
        }
        let speed = summary.speed;
        if speed.total_cmp(&self.best_speed) == Ordering::Greater {
            self.best_speed = speed;
        }
        if !self.armed {
            // recovery re-arm: slope back to half the trailing best
            if self.best_speed > 0.0
                && speed.total_cmp(&(0.5 * self.best_speed)) != Ordering::Less
            {
                self.armed = true;
                self.degraded = 0;
            }
            return false;
        }
        let threshold = self.fraction * self.best_speed;
        if self.best_speed > 0.0 && speed.total_cmp(&threshold) == Ordering::Less {
            self.degraded += 1;
        } else {
            self.degraded = 0;
        }
        if self.degraded >= self.windows {
            self.degraded = 0;
            self.armed = false;
            return true;
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace(xs: &[f64]) -> Vec<ProgressPoint> {
        xs.iter()
            .enumerate()
            .map(|(i, &x)| ProgressPoint { t: i as f64, x })
            .collect()
    }

    #[test]
    fn clean_descent_is_converging() {
        let s = ProgressSummarizer::default();
        let tr = trace(&(0..100).map(|i| 10.0 - 0.05 * i as f64).collect::<Vec<_>>());
        let sum = s.summarize(&tr);
        assert_eq!(sum.label, BranchLabel::Converging);
        // slope ≈ 0.05/clock, zero noise
        assert!(sum.noise == 0.0);
        assert!((sum.speed - 0.05).abs() < 5e-3, "speed={}", sum.speed);
    }

    #[test]
    fn flat_trace_is_unstable_not_converging() {
        let s = ProgressSummarizer::default();
        let sum = s.summarize(&trace(&[5.0; 50]));
        assert_eq!(sum.label, BranchLabel::Unstable);
        assert_eq!(sum.speed, 0.0);
    }

    #[test]
    fn nan_or_inf_is_diverged_with_zero_speed() {
        let s = ProgressSummarizer::default();
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let mut tr = trace(&[3.0, 2.0, 1.5]);
            tr.push(ProgressPoint { t: 3.0, x: bad });
            let sum = s.summarize(&tr);
            assert_eq!(sum.label, BranchLabel::Diverged);
            assert_eq!(sum.speed, 0.0);
        }
    }

    #[test]
    fn diverged_branches_are_equal_quality() {
        // §4.1: a diverged branch with smaller loss is NOT better.
        let s = ProgressSummarizer::default();
        let a = s.summarize(&trace(&[1.0, 2.0, f64::INFINITY]));
        let b = s.summarize(&trace(&[1.0, 2e30, f64::INFINITY]));
        assert_eq!(a.speed, b.speed);
        assert_eq!(a.label, b.label);
    }

    #[test]
    fn noise_penalty_reduces_speed() {
        let s = ProgressSummarizer::new(5);
        // Strictly decreasing trace vs same trend with one upward jump.
        let clean = trace(&[10.0, 8.0, 6.0, 4.0, 2.0]);
        let jumpy = trace(&[10.0, 8.0, 9.0, 4.0, 2.0]);
        let sc = s.summarize(&clean);
        let sj = s.summarize(&jumpy);
        assert!(sj.speed < sc.speed);
        assert!(sj.noise > 0.0);
    }

    #[test]
    fn jumpy_trace_is_unstable() {
        let s = ProgressSummarizer::new(5);
        // ends lower but with a big upward excursion (> ε·|range|)
        let sum = s.summarize(&trace(&[10.0, 4.0, 9.0, 5.0, 8.0]));
        assert_eq!(sum.label, BranchLabel::Unstable);
    }

    #[test]
    fn increasing_trace_has_zero_speed() {
        let s = ProgressSummarizer::default();
        let sum = s.summarize(&trace(&(0..50).map(|i| i as f64).collect::<Vec<_>>()));
        assert_eq!(sum.speed, 0.0);
        assert_eq!(sum.label, BranchLabel::Unstable);
    }

    #[test]
    fn downsample_window_counts_and_means() {
        let s = ProgressSummarizer::new(2);
        let tr = trace(&[1.0, 3.0, 5.0, 7.0]);
        let ds = s.downsample(&tr);
        assert_eq!(ds.len(), 2);
        assert_eq!(ds[0].x, 2.0);
        assert_eq!(ds[1].x, 6.0);
    }

    #[test]
    fn downsample_short_trace_keeps_points() {
        let s = ProgressSummarizer::default();
        let tr = trace(&[4.0, 3.0, 2.0]);
        assert_eq!(s.downsample(&tr).len(), 3);
        assert_eq!(s.downsample(&[]).len(), 0);
    }

    #[test]
    fn longer_trial_stabilizes_noisy_converging_trace() {
        // §4.2's premise: with more points per window, noise averages
        // out and a genuinely-converging branch becomes Converging.
        let s = ProgressSummarizer::default();
        let noisy = |n: usize| -> Vec<ProgressPoint> {
            (0..n)
                .map(|i| {
                    let base = 10.0 - 8.0 * (i as f64) / (n as f64);
                    // deterministic "noise", ±2.0 (dominates the
                    // per-point trend on the short trace)
                    let jitter = if i % 2 == 0 { 2.0 } else { -2.0 };
                    ProgressPoint {
                        t: i as f64,
                        x: base + jitter,
                    }
                })
                .collect()
        };
        let short = s.summarize(&noisy(10));
        let long = s.summarize(&noisy(400));
        assert_eq!(short.label, BranchLabel::Unstable);
        assert_eq!(long.label, BranchLabel::Converging);
    }

    #[test]
    fn speed_is_time_scale_aware() {
        let s = ProgressSummarizer::default();
        let slow: Vec<_> = (0..100)
            .map(|i| ProgressPoint {
                t: 10.0 * i as f64,
                x: 10.0 - 0.05 * i as f64,
            })
            .collect();
        let fast: Vec<_> = (0..100)
            .map(|i| ProgressPoint {
                t: i as f64,
                x: 10.0 - 0.05 * i as f64,
            })
            .collect();
        let ss = s.summarize(&slow);
        let sf = s.summarize(&fast);
        assert!((sf.speed / ss.speed - 10.0).abs() < 1e-6);
    }

    /// Drive a watchdog over an exponential-descent loss stream with a
    /// per-step log-rate given by `rate(step)`.
    fn drive(
        w: &mut SlopeWatchdog,
        steps: std::ops::Range<u64>,
        rate: impl Fn(u64) -> f64,
    ) -> Option<u64> {
        let mut ln_loss = 10.0f64;
        for s in steps {
            ln_loss -= rate(s);
            if w.observe(s as f64, ln_loss.exp()) {
                return Some(s);
            }
        }
        None
    }

    #[test]
    fn watchdog_never_fires_on_healthy_exponential_descent() {
        let mut w = SlopeWatchdog::new(0.25, 3, 8);
        assert_eq!(drive(&mut w, 0..300, |_| 0.05), None);
        assert!(w.best_speed() > 0.0);
        assert!(w.is_armed());
    }

    #[test]
    fn watchdog_fires_on_rate_collapse_then_disarms() {
        let mut w = SlopeWatchdog::new(0.25, 3, 8);
        assert_eq!(drive(&mut w, 0..100, |_| 0.2), None, "healthy phase must not fire");
        let fired = drive(&mut w, 100..400, |_| 0.002);
        assert!(fired.is_some(), "20x rate collapse must fire");
        assert!(!w.is_armed(), "firing disarms the watchdog");
        // without a reset it stays disarmed on the degraded slope
        assert_eq!(drive(&mut w, 400..600, |_| 0.002), None);
        // a full reset re-arms it and restarts the slope scale: the
        // degraded rate becomes the new normal and never re-fires
        w.reset();
        assert!(w.is_armed());
        assert_eq!(drive(&mut w, 600..800, |_| 0.002), None);
    }

    #[test]
    fn watchdog_rearms_on_recovery_without_reset() {
        let mut w = SlopeWatchdog::new(0.25, 3, 8);
        drive(&mut w, 0..100, |_| 0.2);
        assert!(drive(&mut w, 100..400, |_| 0.002).is_some());
        assert!(!w.is_armed());
        // slope recovers to the healthy rate: the watchdog re-arms
        drive(&mut w, 400..500, |_| 0.2);
        assert!(w.is_armed());
    }

    #[test]
    fn watchdog_all_nan_window_never_fires() {
        let mut w = SlopeWatchdog::new(0.25, 1, 2);
        for s in 0..100 {
            assert!(!w.observe(s as f64, f64::NAN));
        }
        // NaNs arriving after an established healthy slope poison the
        // window to Diverged instead of reading as degradation
        let mut w = SlopeWatchdog::new(0.25, 3, 8);
        drive(&mut w, 0..100, |_| 0.2);
        for s in 100..200 {
            assert!(!w.observe(s as f64, f64::NAN), "NaN window fired at {s}");
        }
    }

    #[test]
    fn watchdog_single_point_window_never_fires() {
        // min_points clamps to >= 2, so one observation can never fire
        let mut w = SlopeWatchdog::new(0.25, 1, 0);
        assert!(!w.observe(0.0, 5.0));
        // and a 2-point watchdog still needs a real slope before any
        // degradation bookkeeping starts
        let mut w = SlopeWatchdog::new(0.25, 1, 2);
        assert!(!w.observe(0.0, 5.0));
    }

    #[test]
    fn watchdog_flat_zero_slope_never_fires() {
        // flat loss (zero included): the trailing best stays 0 and
        // "degraded below a fraction of 0" is unsatisfiable
        for flat in [0.0f64, 7.5] {
            let mut w = SlopeWatchdog::new(0.25, 1, 2);
            for s in 0..200 {
                assert!(!w.observe(s as f64, flat), "flat {flat} fired at {s}");
            }
            assert_eq!(w.best_speed(), 0.0);
        }
        // rising loss likewise pins speed (and so best) at 0
        let mut w = SlopeWatchdog::new(0.25, 1, 2);
        for s in 0..200 {
            assert!(!w.observe(s as f64, 1.0 + s as f64));
        }
    }
}
