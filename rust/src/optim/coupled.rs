//! Coupled learning-rate + momentum adaptation (arXiv 1908.07607).
//!
//! The adversary baseline for the non-stationary scenario suite: a
//! client-side rule that adjusts learning rate and momentum *together*
//! from the observed training loss, in the spirit of "Automatic and
//! Simultaneous Adjustment of Learning Rate and Momentum for SGD".
//! The one adapted quantity is the **effective step**
//! `lr / (1 - momentum)` — the asymptotic per-gradient displacement of
//! heavy-ball SGD — grown multiplicatively while the loss improves and
//! cut when it regresses or diverges.  The split back into `(lr,
//! momentum)` is the coupling: momentum absorbs growth first (up to
//! 0.95), the learning rate only scales beyond that, so the rule walks
//! the same lr–momentum ridge the paper identifies.
//!
//! The rule is a pure, deterministic fold over the loss sequence — no
//! clocks, no RNG — so baseline runs are bit-reproducible, and every
//! float comparison goes through `total_cmp` (NaN losses take the
//! divergence path, they never poison the state).

use std::cmp::Ordering;

/// Multiplicative growth while the loss improves.
const GROW: f64 = 1.2;
/// Multiplicative cut on a loss regression.
const SHRINK: f64 = 0.5;
/// Hard backoff on a non-finite loss.
const DIVERGE_CUT: f64 = 0.1;
/// Momentum ceiling — beyond it the learning rate scales instead.
const MOMENTUM_CAP: f64 = 0.95;

/// The coupled lr+momentum rule.  Feed it one loss per epoch via
/// [`CoupledRule::observe`]; read the adapted setting back through
/// [`CoupledRule::lr`] / [`CoupledRule::momentum`].
#[derive(Debug, Clone, Copy)]
pub struct CoupledRule {
    /// The user's initial learning rate — the pivot of the coupling.
    base_lr: f64,
    /// Effective step `lr / (1 - momentum)`, the adapted quantity.
    step: f64,
    /// Previous finite observation (`INFINITY` before the first one
    /// and after a divergence, so the next epoch counts as improving).
    last_loss: f64,
    min_step: f64,
    max_step: f64,
}

impl CoupledRule {
    pub fn new(lr0: f64) -> Self {
        let base = lr0.max(1e-12);
        CoupledRule {
            base_lr: base,
            step: base,
            last_loss: f64::INFINITY,
            min_step: 1e-10,
            max_step: 1e3,
        }
    }

    /// The effective step `lr / (1 - momentum)` currently in force.
    pub fn effective_step(&self) -> f64 {
        self.step
    }

    /// Momentum component: zero while the step is within the base
    /// learning rate, then rising toward the cap as the step grows.
    pub fn momentum(&self) -> f64 {
        if self.step.total_cmp(&self.base_lr) != Ordering::Greater {
            return 0.0;
        }
        (1.0 - self.base_lr / self.step).min(MOMENTUM_CAP)
    }

    /// Learning-rate component, defined so that
    /// `lr / (1 - momentum) == effective_step` always holds.
    pub fn lr(&self) -> f64 {
        self.step * (1.0 - self.momentum())
    }

    /// Fold one end-of-epoch training loss into the rule.
    pub fn observe(&mut self, loss: f64) {
        if !loss.is_finite() {
            // divergence: hard backoff and forget the reference loss —
            // whatever the next finite loss is counts as improvement
            self.step = (self.step * DIVERGE_CUT).max(self.min_step);
            self.last_loss = f64::INFINITY;
            return;
        }
        let improved = loss.total_cmp(&self.last_loss) == Ordering::Less;
        let factor = if improved { GROW } else { SHRINK };
        self.step = (self.step * factor).clamp(self.min_step, self.max_step);
        self.last_loss = loss;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn improving_stream_grows_the_effective_step() {
        let mut r = CoupledRule::new(0.01);
        let start = r.effective_step();
        for i in 0..20 {
            r.observe(100.0 - i as f64);
        }
        assert!(r.effective_step() > start * 10.0);
        // momentum absorbed the growth first; lr stays pinned at base
        // until the momentum cap
        assert!(r.momentum() > 0.5);
        assert!((r.lr() / (1.0 - r.momentum()) - r.effective_step()).abs() < 1e-12);
    }

    #[test]
    fn regression_cuts_and_divergence_backs_off_hard() {
        let mut r = CoupledRule::new(0.01);
        for i in 0..30 {
            r.observe(100.0 - i as f64);
        }
        let grown = r.effective_step();
        r.observe(1e6); // regression
        assert!(r.effective_step() < grown);
        let before_nan = r.effective_step();
        r.observe(f64::NAN);
        assert!(r.effective_step() < before_nan * 0.2);
        assert!(r.lr().is_finite() && r.momentum().is_finite());
        // the first finite loss after divergence counts as improving
        let floored = r.effective_step();
        r.observe(5e5);
        assert!(r.effective_step() > floored);
    }

    #[test]
    fn momentum_and_lr_stay_in_their_bands() {
        let mut r = CoupledRule::new(0.05);
        for i in 0..200 {
            // alternate long improvement runs with occasional spikes
            let loss = if i % 17 == 0 { 1e9 } else { 1e4 / (i + 1) as f64 };
            r.observe(loss);
            assert!(r.lr() > 0.0, "lr must stay positive");
            assert!((0.0..=MOMENTUM_CAP).contains(&r.momentum()));
            assert!(r.effective_step() <= 1e3 + 1e-9);
        }
    }

    #[test]
    fn rule_is_a_pure_fold() {
        let feed = |losses: &[f64]| {
            let mut r = CoupledRule::new(0.01);
            for &l in losses {
                r.observe(l);
            }
            (r.lr().to_bits(), r.momentum().to_bits())
        };
        let seq: Vec<f64> = (0..50).map(|i| 1000.0 / (i + 1) as f64).collect();
        assert_eq!(feed(&seq), feed(&seq), "bit-reproducible per input");
    }

    #[test]
    fn cap_shifts_growth_from_momentum_to_lr() {
        let mut r = CoupledRule::new(0.01);
        for i in 0..60 {
            r.observe(1e6 - i as f64);
        }
        // deep in growth: momentum pinned at the cap, lr carrying the
        // rest of the effective step
        assert!((r.momentum() - MOMENTUM_CAP).abs() < 1e-9);
        assert!(r.lr() > 0.01);
    }
}
