//! Server-side optimizer zoo (§2.3.3, §5.3).
//!
//! Mirroring the paper's training setup, workers send **batch-size
//! normalized gradients** to the parameter server and the server applies
//! the learning rate, momentum and any adaptive-LR algorithm.  The LR
//! and momentum therefore arrive per update as [`Hyper`] — they are
//! MLtuner *tunables*, changeable at runtime without recompilation.
//!
//! Implemented algorithms: plain SGD with momentum [Sutskever et al.],
//! Nesterov, AdaGrad [Duchi et al.], RMSProp [Tieleman & Hinton],
//! AdaDelta \[Zeiler\], Adam [Kingma & Ba], and AdaRevision [McMahan &
//! Streeter] (delay-tolerant AdaGrad; per-parameter LR adjustment from
//! a user-set initial LR — the MF app's optimizer, Fig. 7).
//!
//! All of these *still require the user to pick the initial learning
//! rate* — that is exactly the knob MLtuner tunes in §5.3.
//!
//! [`Optimizer`] is a plain-old-data rule description (`Copy + Send +
//! Sync`): the concurrent sharded server shares one instance across
//! all worker threads without synchronization, because every piece of
//! *mutable* optimizer state (velocity, moment, accumulator slots and
//! the step counter) is row-resident in [`Entry`] and therefore
//! protected by the owning shard's lock — and snapshotted/forked with
//! the branch like any other training state.

pub mod coupled;

use crate::ps::storage::Entry;

/// Runtime hyperparameters applied server-side (the tunables).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Hyper {
    pub lr: f32,
    pub momentum: f32,
}

impl Default for Hyper {
    fn default() -> Self {
        Hyper { lr: 0.01, momentum: 0.0 }
    }
}

/// Which update rule the server applies.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OptimizerKind {
    /// SGD with (classical) momentum — the paper's default for the
    /// image/video classification benchmarks.
    #[default]
    Sgd,
    Nesterov,
    AdaGrad,
    RmsProp,
    AdaDelta,
    Adam,
    /// Delay-tolerant AdaGrad; the update may carry the accumulated
    /// gradient `z_old` observed when the worker read the row, and the
    /// accumulator is "revised" by the gradient that arrived in between.
    AdaRevision,
}

impl OptimizerKind {
    pub const ALL: [OptimizerKind; 7] = [
        OptimizerKind::Sgd,
        OptimizerKind::Nesterov,
        OptimizerKind::AdaGrad,
        OptimizerKind::RmsProp,
        OptimizerKind::AdaDelta,
        OptimizerKind::Adam,
        OptimizerKind::AdaRevision,
    ];

    /// The six *adaptive* algorithms of Fig. 6 (everything but plain SGD).
    pub const ADAPTIVE: [OptimizerKind; 6] = [
        OptimizerKind::Nesterov,
        OptimizerKind::AdaGrad,
        OptimizerKind::RmsProp,
        OptimizerKind::AdaDelta,
        OptimizerKind::Adam,
        OptimizerKind::AdaRevision,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            OptimizerKind::Sgd => "sgd",
            OptimizerKind::Nesterov => "nesterov",
            OptimizerKind::AdaGrad => "adagrad",
            OptimizerKind::RmsProp => "rmsprop",
            OptimizerKind::AdaDelta => "adadelta",
            OptimizerKind::Adam => "adam",
            OptimizerKind::AdaRevision => "adarevision",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Self::ALL.iter().copied().find(|k| k.name() == s)
    }
}

/// Fixed (non-tuned) algorithm constants.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Optimizer {
    pub kind: OptimizerKind,
    pub eps: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub rho: f32,
}

impl Optimizer {
    pub fn new(kind: OptimizerKind) -> Self {
        Optimizer {
            kind,
            eps: 1e-6,
            beta1: 0.9,
            beta2: 0.999,
            rho: 0.95,
        }
    }

    /// Number of per-row slot buffers this rule needs.
    pub fn num_slots(&self) -> usize {
        match self.kind {
            OptimizerKind::Sgd | OptimizerKind::Nesterov => 1, // velocity
            OptimizerKind::AdaGrad | OptimizerKind::RmsProp => 1, // sq-accum
            OptimizerKind::AdaDelta => 2, // sq-accum, delta-accum
            OptimizerKind::Adam => 2,     // m1, m2
            OptimizerKind::AdaRevision => 2, // sq-accum n, grad-accum z
        }
    }

    /// Initialize `entry`'s slots for this rule (idempotent).
    pub fn init_slots(&self, entry: &mut Entry) {
        let n = entry.data.len();
        while entry.slots.len() < self.num_slots() {
            entry.slots.push(vec![0.0; n]);
        }
        for s in &mut entry.slots {
            if s.len() != n {
                s.resize(n, 0.0);
            }
        }
    }

    /// Apply one update to `entry.data` in place.  `grad` is the
    /// batch-normalized gradient; `z_old` is AdaRevision's snapshot of
    /// the grad-accumulator at read time (ignored by other rules).
    pub fn apply(&self, hyper: Hyper, entry: &mut Entry, grad: &[f32], z_old: Option<&[f32]>) {
        debug_assert_eq!(entry.data.len(), grad.len());
        self.init_slots(entry);
        entry.step += 1;
        let lr = hyper.lr;
        let mom = hyper.momentum;
        let eps = self.eps;
        match self.kind {
            OptimizerKind::Sgd => {
                // zip iterators: bounds-check-free, auto-vectorized
                // (§Perf: 2.8x over indexed loop)
                let (p, v) = (&mut entry.data, &mut entry.slots[0]);
                for ((p, v), &g) in p.iter_mut().zip(v.iter_mut()).zip(grad) {
                    *v = mom * *v + g;
                    *p -= lr * *v;
                }
            }
            OptimizerKind::Nesterov => {
                let (p, v) = (&mut entry.data, &mut entry.slots[0]);
                for ((p, v), &g) in p.iter_mut().zip(v.iter_mut()).zip(grad) {
                    *v = mom * *v + g;
                    *p -= lr * (g + mom * *v);
                }
            }
            OptimizerKind::AdaGrad => {
                let (p, n) = (&mut entry.data, &mut entry.slots[0]);
                for ((p, n), &g) in p.iter_mut().zip(n.iter_mut()).zip(grad) {
                    *n += g * g;
                    *p -= lr * g / (n.sqrt() + eps);
                }
            }
            OptimizerKind::RmsProp => {
                let rho = 0.9; // RMSProp's canonical decay
                let (p, n) = (&mut entry.data, &mut entry.slots[0]);
                for ((p, n), &g) in p.iter_mut().zip(n.iter_mut()).zip(grad) {
                    *n = rho * *n + (1.0 - rho) * g * g;
                    *p -= lr * g / (n.sqrt() + eps);
                }
            }
            OptimizerKind::AdaDelta => {
                let rho = self.rho;
                let (data, rest) = (&mut entry.data, &mut entry.slots);
                let (n_slot, d_slot) = rest.split_at_mut(1);
                let (n, d) = (&mut n_slot[0], &mut d_slot[0]);
                for i in 0..data.len() {
                    n[i] = rho * n[i] + (1.0 - rho) * grad[i] * grad[i];
                    let dx =
                        ((d[i] + eps).sqrt() / (n[i] + eps).sqrt()) * grad[i];
                    d[i] = rho * d[i] + (1.0 - rho) * dx * dx;
                    // The initial LR scales AdaDelta's step, as in the
                    // framework implementations the paper tunes (§5.3).
                    data[i] -= lr * dx;
                }
            }
            OptimizerKind::Adam => {
                let (b1, b2) = (self.beta1, self.beta2);
                let t = entry.step as f32;
                let c1 = 1.0 - b1.powf(t);
                let c2 = 1.0 - b2.powf(t);
                let (data, rest) = (&mut entry.data, &mut entry.slots);
                let (m_slot, v_slot) = rest.split_at_mut(1);
                let (m, v) = (&mut m_slot[0], &mut v_slot[0]);
                for i in 0..data.len() {
                    m[i] = b1 * m[i] + (1.0 - b1) * grad[i];
                    v[i] = b2 * v[i] + (1.0 - b2) * grad[i] * grad[i];
                    let mhat = m[i] / c1;
                    let vhat = v[i] / c2;
                    data[i] -= lr * mhat / (vhat.sqrt() + eps);
                }
            }
            OptimizerKind::AdaRevision => {
                let (data, rest) = (&mut entry.data, &mut entry.slots);
                let (n_slot, z_slot) = rest.split_at_mut(1);
                let (n, z) = (&mut n_slot[0], &mut z_slot[0]);
                for i in 0..data.len() {
                    let g = grad[i];
                    // Revision term: gradient mass that other workers
                    // applied between this worker's read and its update.
                    let bck = match z_old {
                        Some(zo) => z[i] - zo[i],
                        None => 0.0,
                    };
                    // keep the accumulator non-negative: a strongly
                    // anti-correlated revision must not push n below 0.
                    n[i] = (n[i] + g * g + 2.0 * g * bck).max(0.0);
                    z[i] += g;
                    let denom = n[i].max(0.0).sqrt() + eps;
                    data[i] -= lr * g / denom;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(vals: &[f32]) -> Entry {
        Entry {
            data: vals.to_vec(),
            slots: Vec::new(),
            step: 0,
        }
    }

    fn hyper(lr: f32, mom: f32) -> Hyper {
        Hyper { lr, momentum: mom }
    }

    #[test]
    fn sgd_single_step_closed_form() {
        let opt = Optimizer::new(OptimizerKind::Sgd);
        let mut e = entry(&[1.0, -2.0]);
        opt.apply(hyper(0.1, 0.0), &mut e, &[0.5, -1.0], None);
        assert!((e.data[0] - (1.0 - 0.05)).abs() < 1e-6);
        assert!((e.data[1] - (-2.0 + 0.1)).abs() < 1e-6);
    }

    #[test]
    fn momentum_accumulates_velocity() {
        let opt = Optimizer::new(OptimizerKind::Sgd);
        let mut e = entry(&[0.0]);
        // constant gradient 1, momentum 0.9: v after k steps = sum 0.9^j
        opt.apply(hyper(1.0, 0.9), &mut e, &[1.0], None);
        opt.apply(hyper(1.0, 0.9), &mut e, &[1.0], None);
        // p = -(1) - (1 + 0.9) = -2.9
        assert!((e.data[0] + 2.9).abs() < 1e-6, "{}", e.data[0]);
    }

    #[test]
    fn nesterov_differs_from_classical_momentum() {
        let mut a = entry(&[0.0]);
        let mut b = entry(&[0.0]);
        Optimizer::new(OptimizerKind::Sgd).apply(hyper(0.1, 0.9), &mut a, &[1.0], None);
        Optimizer::new(OptimizerKind::Nesterov).apply(hyper(0.1, 0.9), &mut b, &[1.0], None);
        assert!(a.data[0] != b.data[0]);
        // Nesterov's first step: -(lr * (g + m*v)) = -0.1*(1+0.9) = -0.19
        assert!((b.data[0] + 0.19).abs() < 1e-6);
    }

    #[test]
    fn adagrad_first_step_is_lr_sign() {
        let opt = Optimizer::new(OptimizerKind::AdaGrad);
        let mut e = entry(&[0.0, 0.0]);
        opt.apply(hyper(0.5, 0.0), &mut e, &[3.0, -0.01], None);
        // g/sqrt(g^2) = sign(g)
        assert!((e.data[0] + 0.5).abs() < 1e-4);
        assert!((e.data[1] - 0.5).abs() < 1e-2);
    }

    #[test]
    fn adam_first_step_is_lr_sign() {
        let opt = Optimizer::new(OptimizerKind::Adam);
        let mut e = entry(&[0.0]);
        opt.apply(hyper(0.001, 0.0), &mut e, &[42.0], None);
        assert!((e.data[0] + 0.001).abs() < 1e-5, "{}", e.data[0]);
    }

    #[test]
    fn per_parameter_adaptivity() {
        // Fig. 6 premise: adaptive rules scale per-parameter — the
        // frequently-large-gradient coordinate gets a smaller step.
        let opt = Optimizer::new(OptimizerKind::AdaGrad);
        let mut e = entry(&[0.0, 0.0]);
        for _ in 0..10 {
            opt.apply(hyper(0.1, 0.0), &mut e, &[10.0, 0.1], None);
        }
        // both move, but per-unit-gradient step is far smaller for coord 0
        let step0 = e.data[0].abs() / 10.0;
        let step1 = e.data[1].abs() / 0.1;
        assert!(step1 > 5.0 * step0, "step0={step0} step1={step1}");
    }

    #[test]
    fn all_optimizers_descend_quadratic_bowl() {
        // loss = 0.5*||p||^2, grad = p; every rule must reduce |p|.
        for kind in OptimizerKind::ALL {
            let opt = Optimizer::new(kind);
            let mut e = entry(&[4.0, -3.0]);
            let lr = match kind {
                OptimizerKind::Sgd | OptimizerKind::Nesterov => 0.1,
                // AdaDelta's accumulator-ratio steps start tiny and
                // self-accelerate; it needs a large scale + more steps.
                OptimizerKind::AdaDelta => 30.0,
                _ => 0.5,
            };
            for _ in 0..2000 {
                let grad: Vec<f32> = e.data.clone();
                opt.apply(hyper(lr, 0.5), &mut e, &grad, None);
            }
            let norm = (e.data[0].powi(2) + e.data[1].powi(2)).sqrt();
            assert!(norm < 1.0, "{kind:?} ended at |p|={norm}");
        }
    }

    #[test]
    fn adarevision_revision_shrinks_step_under_contention() {
        // When other workers applied gradient mass in between (z moved
        // since z_old), the accumulator grows faster => smaller steps.
        let opt = Optimizer::new(OptimizerKind::AdaRevision);
        let mut fresh = entry(&[0.0]);
        let mut stale = entry(&[0.0]);
        // warm both with one update
        opt.apply(hyper(0.1, 0.0), &mut fresh, &[1.0], None);
        opt.apply(hyper(0.1, 0.0), &mut stale, &[1.0], None);
        let p0 = fresh.data[0];
        // fresh: z_old == current z (no contention)
        let z_now = fresh.slots[1].clone();
        opt.apply(hyper(0.1, 0.0), &mut fresh, &[1.0], Some(&z_now));
        // stale: z_old from before the first update (missed 1.0 of mass)
        let z_old = vec![0.0];
        opt.apply(hyper(0.1, 0.0), &mut stale, &[1.0], Some(&z_old));
        let step_fresh = (fresh.data[0] - p0).abs();
        let step_stale = (stale.data[0] - p0).abs();
        assert!(step_stale < step_fresh, "{step_stale} !< {step_fresh}");
    }

    #[test]
    fn slot_counts() {
        assert_eq!(Optimizer::new(OptimizerKind::Sgd).num_slots(), 1);
        assert_eq!(Optimizer::new(OptimizerKind::Adam).num_slots(), 2);
        assert_eq!(Optimizer::new(OptimizerKind::AdaRevision).num_slots(), 2);
    }

    #[test]
    fn optimizer_is_sync_shareable() {
        // the concurrent server shares one Optimizer across N worker
        // threads — this must never silently regress
        fn assert_shareable<T: Send + Sync + Copy>() {}
        assert_shareable::<Optimizer>();
        assert_shareable::<Hyper>();
        assert_shareable::<OptimizerKind>();
    }

    #[test]
    fn kind_name_roundtrip() {
        for k in OptimizerKind::ALL {
            assert_eq!(OptimizerKind::parse(k.name()), Some(k));
        }
        assert_eq!(OptimizerKind::parse("bogus"), None);
    }
}
