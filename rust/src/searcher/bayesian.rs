//! BayesianOptSearcher: Spearmint-style Gaussian-process Bayesian
//! optimization (§4.3, §5.2).
//!
//! Faithful to the behaviour the paper reports for Spearmint's package:
//! the **first proposal sets every tunable to its minimum value** (the
//! all-zeros cube corner) — the very pathology that makes the Spearmint
//! baseline of Fig. 3 converge at an extremely slow rate on ILSVRC12.
//! After a handful of pseudo-random warm-up points, proposals maximize
//! expected improvement under a Matérn-5/2 GP posterior, evaluated over
//! a random candidate set.

use crate::util::rng::Rng;

use super::gp::Gp;
use super::{Proposal, Searcher};

const WARMUP: usize = 4;
const CANDIDATES: usize = 512;

#[derive(Debug)]
pub struct BayesianOptSearcher {
    dim: usize,
    rng: Rng,
    observations: Vec<(Vec<f64>, f64)>,
    proposed: usize,
}

impl BayesianOptSearcher {
    pub fn new(dim: usize, seed: u64) -> Self {
        BayesianOptSearcher {
            dim,
            rng: Rng::seed_from_u64(seed),
            observations: Vec::new(),
            proposed: 0,
        }
    }
}

impl Searcher for BayesianOptSearcher {
    fn propose(&mut self) -> Proposal {
        self.proposed += 1;
        // Spearmint's first proposal: all tunables at their minimum.
        if self.proposed == 1 {
            return Proposal::Point(vec![0.0; self.dim]);
        }
        if self.observations.len() < WARMUP {
            return Proposal::Point((0..self.dim).map(|_| self.rng.gen_f64()).collect());
        }
        let xs: Vec<Vec<f64>> = self.observations.iter().map(|(x, _)| x.clone()).collect();
        let ys: Vec<f64> = self.observations.iter().map(|(_, y)| *y).collect();
        let best = ys.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let gp = match Gp::fit(xs, &ys, 1e-6) {
            Some(gp) => gp,
            None => {
                return Proposal::Point((0..self.dim).map(|_| self.rng.gen_f64()).collect())
            }
        };
        let mut best_x: Option<Vec<f64>> = None;
        let mut best_ei = f64::NEG_INFINITY;
        for _ in 0..CANDIDATES {
            let cand: Vec<f64> =
                (0..self.dim).map(|_| self.rng.gen_f64()).collect();
            let ei = gp.expected_improvement(&cand, best);
            // a NaN EI (a GP poisoned by degenerate observations) must
            // never win the argmax — `ei > best_ei` is false for NaN,
            // so it is skipped rather than crowning a garbage point
            if ei > best_ei {
                best_ei = ei;
                best_x = Some(cand);
            }
        }
        match best_x {
            Some(x) => Proposal::Point(x),
            // Regression: when EVERY candidate's EI is NaN the argmax
            // stays empty — `best_x.unwrap()` here used to panic the
            // tune.  Fall back to pure exploration instead.
            None => Proposal::Point((0..self.dim).map(|_| self.rng.gen_f64()).collect()),
        }
    }

    fn observe(&mut self, point: Vec<f64>, speed: f64) {
        // A diverged trial can report a NaN or ±Inf speed; one such
        // observation poisons the whole GP posterior (every kernel
        // solve and EI turns NaN).  Record it as the worst legal
        // score — the paper's treatment of diverged settings — so the
        // searcher keeps working and the setting simply loses.
        let speed = if speed.is_finite() { speed } else { 0.0 };
        self.observations.push((point, speed));
    }

    fn observations(&self) -> &[(Vec<f64>, f64)] {
        &self.observations
    }

    fn name(&self) -> &'static str {
        "bayesian_opt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_proposal_is_all_minimums() {
        // The Spearmint pathology of §5.2, reproduced deliberately.
        let mut s = BayesianOptSearcher::new(4, 123);
        assert_eq!(s.propose(), Proposal::Point(vec![0.0; 4]));
    }

    #[test]
    fn nan_observations_never_panic_the_proposer() {
        // Regression: NaN speeds fed to `observe` poisoned the GP and
        // `best_x.unwrap()` panicked in `propose`.  NaN/±Inf are now
        // sanitized to 0.0 and a NaN-EI sweep falls back to a random
        // point, so proposals keep flowing inside the unit cube.
        let mut s = BayesianOptSearcher::new(2, 99);
        for round in 0..20 {
            match s.propose() {
                Proposal::Exhausted => unreachable!("bayesian never exhausts"),
                Proposal::Point(p) => {
                    assert_eq!(p.len(), 2);
                    assert!(p.iter().all(|&u| (0.0..=1.0).contains(&u)), "{p:?}");
                    let bad = [f64::NAN, f64::INFINITY, f64::NEG_INFINITY][round % 3];
                    s.observe(p, bad);
                }
            }
        }
        assert_eq!(s.observations().len(), 20);
        assert!(
            s.observations().iter().all(|(_, sp)| *sp == 0.0),
            "non-finite speeds must be recorded as the worst legal score"
        );
    }

    #[test]
    fn finds_peak_of_smooth_objective() {
        let mut s = BayesianOptSearcher::new(1, 5);
        let f = |x: f64| 1.0 - (x - 0.7).powi(2) * 4.0;
        for _ in 0..25 {
            if let Proposal::Point(p) = s.propose() {
                let y = f(p[0]);
                s.observe(p, y.max(0.0));
            }
        }
        let best = s
            .observations()
            .iter()
            .max_by(|a, b| a.1.total_cmp(&b.1))
            .unwrap();
        assert!((best.0[0] - 0.7).abs() < 0.15, "best x = {:?}", best.0);
    }

    #[test]
    fn argmax_over_observations_is_nan_safe() {
        // Regression for the old `partial_cmp().unwrap()` EI argmax
        // idiom: picking the best observation must not panic when a
        // NaN score is present, and NaN must never win the argmax
        // (`total_cmp` ranks NaN above every finite value, so scan
        // finite-only when NaN may be present).
        let obs: Vec<(Vec<f64>, f64)> =
            vec![(vec![0.1], 0.4), (vec![0.2], f64::NAN), (vec![0.7], 0.9)];
        let best = obs
            .iter()
            .filter(|(_, y)| !y.is_nan())
            .max_by(|a, b| a.1.total_cmp(&b.1))
            .unwrap();
        assert_eq!(best.0, vec![0.7]);
    }
}
