//! TpeSearcher: the HyperOpt algorithm (Tree-structured Parzen
//! Estimator, Bergstra et al.) — MLtuner's default searcher (§4.3).
//!
//! Observations are split by convergence speed into a "good" set (top
//! γ quantile) and a "bad" set.  Each dimension gets two 1-D Parzen
//! mixtures, `l(x)` over good points and `g(x)` over bad points;
//! candidates are sampled from `l` and the one maximizing `l(x)/g(x)`
//! is proposed.

use crate::util::rng::Rng;

use super::{cmp_speed_desc, Proposal, Searcher};

const N_STARTUP_MIN: usize = 10;
const N_CANDIDATES: usize = 24;
const GAMMA: f64 = 0.25;

#[derive(Debug)]
pub struct TpeSearcher {
    dim: usize,
    rng: Rng,
    observations: Vec<(Vec<f64>, f64)>,
    /// Random warm-up trials before the Parzen model kicks in; scales
    /// with dimensionality (as HyperOpt's startup budget effectively
    /// does) — this is what makes tuning cost grow with the number of
    /// tunables (Fig. 11).
    n_startup: usize,
}

impl TpeSearcher {
    pub fn new(dim: usize, seed: u64) -> Self {
        TpeSearcher {
            dim,
            rng: Rng::seed_from_u64(seed),
            observations: Vec::new(),
            n_startup: N_STARTUP_MIN.max(2 * dim + 2),
        }
    }

    fn random_point(&mut self) -> Vec<f64> {
        (0..self.dim).map(|_| self.rng.gen_f64()).collect()
    }

    /// Split observed points into (good, bad) by the γ quantile of speed.
    fn split(&self) -> (Vec<Vec<f64>>, Vec<Vec<f64>>) {
        let mut idx: Vec<usize> = (0..self.observations.len()).collect();
        // NaN-strictly-worst total order: a diverged trial's NaN speed
        // lands in the "bad" set instead of panicking the sort — this
        // was the live `partial_cmp().unwrap()` crash site (nothing
        // filtered NaN ahead of it, unlike should_stop's ranking).
        idx.sort_by(|&a, &b| {
            cmp_speed_desc(&self.observations[a].1, &self.observations[b].1)
        });
        let n_good = ((GAMMA * idx.len() as f64).ceil() as usize)
            .clamp(1, idx.len().saturating_sub(1).max(1));
        let good = idx[..n_good].iter().map(|&i| self.observations[i].0.clone()).collect();
        let bad = idx[n_good..].iter().map(|&i| self.observations[i].0.clone()).collect();
        (good, bad)
    }
}

/// Parzen mixture density at `x` over 1-D centers with bandwidth `bw`,
/// plus a uniform smoothing component (keeps g(x) > 0 everywhere).
fn parzen_density(x: f64, centers: &[f64], bw: f64) -> f64 {
    let uniform = 1.0; // density of U[0,1]
    if centers.is_empty() {
        return uniform;
    }
    let norm = 1.0 / ((2.0 * std::f64::consts::PI).sqrt() * bw);
    let mut acc = 0.0;
    for &c in centers {
        let z = (x - c) / bw;
        acc += norm * (-0.5 * z * z).exp();
    }
    // mixture: points + one uniform pseudo-component
    (acc + uniform) / (centers.len() as f64 + 1.0)
}

fn bandwidth(n: usize) -> f64 {
    // Scott-style shrinking bandwidth on the unit interval.
    (1.0 / (n as f64 + 1.0)).max(0.08)
}

impl Searcher for TpeSearcher {
    fn propose(&mut self) -> Proposal {
        if self.observations.len() < self.n_startup {
            return Proposal::Point(self.random_point());
        }
        let (good, bad) = self.split();
        let bw_good = bandwidth(good.len());
        let bw_bad = bandwidth(bad.len());
        let mut best: Option<(Vec<f64>, f64)> = None;
        for _ in 0..N_CANDIDATES {
            // sample each dim from l(x): pick a good center, jitter.
            let mut cand = Vec::with_capacity(self.dim);
            for d in 0..self.dim {
                let c = good[self.rng.gen_range(0, good.len())][d];
                let mut v = self.rng.gen_normal_with(c, bw_good);
                if !(0.0..=1.0).contains(&v) {
                    v = self.rng.gen_f64();
                }
                cand.push(v);
            }
            // score = sum_d log l_d(x) - log g_d(x)
            let mut score = 0.0;
            for d in 0..self.dim {
                let centers_g: Vec<f64> = good.iter().map(|p| p[d]).collect();
                let centers_b: Vec<f64> = bad.iter().map(|p| p[d]).collect();
                let l = parzen_density(cand[d], &centers_g, bw_good);
                let g = parzen_density(cand[d], &centers_b, bw_bad);
                score += l.ln() - g.ln();
            }
            if best.as_ref().map_or(true, |(_, s)| score > *s) {
                best = Some((cand, score));
            }
        }
        // lint:allow(panic-path): the candidate loop runs at least
        // once and its first iteration always sets `best` (map_or
        // returns true for None, NaN scores included)
        Proposal::Point(best.unwrap().0)
    }

    fn observe(&mut self, point: Vec<f64>, speed: f64) {
        // Non-finite speeds (diverged trials) are recorded as the
        // worst legal score, mirroring BayesianOptSearcher: they must
        // lose the quantile split, never poison it.
        let speed = if speed.is_finite() { speed } else { 0.0 };
        self.observations.push((point, speed));
    }

    fn observations(&self) -> &[(Vec<f64>, f64)] {
        &self.observations
    }

    fn name(&self) -> &'static str {
        "hyperopt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn startup_is_random_then_model_based() -> anyhow::Result<()> {
        let mut s = TpeSearcher::new(2, 3);
        let n0 = s.n_startup;
        for i in 0..n0 {
            if let Proposal::Point(p) = s.propose() {
                s.observe(p, i as f64);
            }
        }
        // after startup it still proposes valid points
        match s.propose() {
            Proposal::Point(p) => {
                assert_eq!(p.len(), 2);
                assert!(p.iter().all(|&u| (0.0..=1.0).contains(&u)));
            }
            Proposal::Exhausted => {
                anyhow::bail!("TPE must never report an exhausted search space")
            }
        }
        Ok(())
    }

    #[test]
    fn nan_observations_never_panic_the_split() {
        // Regression (sibling of the bayesian fix): a NaN speed fed
        // straight to observe used to panic split()'s sort once the
        // model kicked in.  It now lands in the "bad" set via the
        // 0.0 sanitization and the NaN-worst total order.
        let mut s = TpeSearcher::new(2, 7);
        for round in 0..(s.n_startup + 8) {
            match s.propose() {
                Proposal::Exhausted => unreachable!("TPE never exhausts"),
                Proposal::Point(p) => {
                    assert!(p.iter().all(|&u| (0.0..=1.0).contains(&u)), "{p:?}");
                    let speed = if round % 3 == 0 { f64::NAN } else { round as f64 };
                    s.observe(p, speed);
                }
            }
        }
        assert!(s.observations().iter().all(|(_, sp)| sp.is_finite()));
    }

    #[test]
    fn concentrates_near_good_region() {
        let mut s = TpeSearcher::new(1, 11);
        let f = |x: f64| (-(x - 0.2f64).powi(2) * 50.0).exp();
        for _ in 0..40 {
            if let Proposal::Point(p) = s.propose() {
                let y = f(p[0]);
                s.observe(p, y);
            }
        }
        // late proposals should cluster near 0.2
        let late: Vec<f64> = s.observations()[30..].iter().map(|(p, _)| p[0]).collect();
        let near = late.iter().filter(|&&x| (x - 0.2).abs() < 0.25).count();
        assert!(
            near * 2 >= late.len(),
            "late proposals not concentrated: {late:?}"
        );
    }

    #[test]
    fn parzen_density_positive_everywhere() {
        assert!(parzen_density(0.9, &[], 0.1) > 0.0);
        assert!(parzen_density(0.0, &[1.0], 0.05) > 0.0);
    }
}
