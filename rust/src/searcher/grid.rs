//! GridSearcher: discretizes the continuous search space into a grid
//! and proposes each grid point in turn (§4.3).  Works surprisingly
//! well for low-dimensional cases (e.g. a single tunable); exhausts.

use super::{Proposal, Searcher};

#[derive(Debug)]
pub struct GridSearcher {
    dim: usize,
    points_per_dim: usize,
    next: usize,
    total: usize,
    observations: Vec<(Vec<f64>, f64)>,
}

impl GridSearcher {
    pub fn new(dim: usize, points_per_dim: usize) -> Self {
        assert!(points_per_dim >= 1);
        GridSearcher {
            dim,
            points_per_dim,
            next: 0,
            total: points_per_dim.pow(dim as u32),
            observations: Vec::new(),
        }
    }

    /// Grid coordinate for index `i` along one dimension: bucket centers
    /// so that discrete tunables decode onto distinct values.
    fn coord(&self, i: usize) -> f64 {
        (i as f64 + 0.5) / self.points_per_dim as f64
    }
}

impl Searcher for GridSearcher {
    fn propose(&mut self) -> Proposal {
        if self.next >= self.total {
            return Proposal::Exhausted;
        }
        let mut idx = self.next;
        self.next += 1;
        let mut point = Vec::with_capacity(self.dim);
        for _ in 0..self.dim {
            point.push(self.coord(idx % self.points_per_dim));
            idx /= self.points_per_dim;
        }
        Proposal::Point(point)
    }

    fn observe(&mut self, point: Vec<f64>, speed: f64) {
        self.observations.push((point, speed));
    }

    fn observations(&self) -> &[(Vec<f64>, f64)] {
        &self.observations
    }

    fn name(&self) -> &'static str {
        "grid"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enumerates_full_grid_then_exhausts() {
        let mut s = GridSearcher::new(2, 3);
        let mut seen = std::collections::BTreeSet::new();
        loop {
            match s.propose() {
                Proposal::Exhausted => break,
                Proposal::Point(p) => {
                    seen.insert(
                        p.iter()
                            .map(|u| format!("{u:.3}"))
                            .collect::<Vec<_>>()
                            .join(","),
                    );
                }
            }
        }
        assert_eq!(seen.len(), 9);
        assert_eq!(s.propose(), Proposal::Exhausted);
    }

    #[test]
    fn one_dim_grid_is_bucket_centers() {
        let mut s = GridSearcher::new(1, 4);
        let mut pts = Vec::new();
        while let Proposal::Point(p) = s.propose() {
            pts.push(p[0]);
        }
        assert_eq!(pts, vec![0.125, 0.375, 0.625, 0.875]);
    }
}
