//! Tunable searchers (§4.3): black-box optimizers proposing unit-cube
//! points; the observed objective is the (noise-penalized) convergence
//! speed from the progress summarizer.
//!
//! Implemented searchers, as in the paper: [`RandomSearcher`],
//! [`GridSearcher`], [`BayesianOptSearcher`] (Spearmint-style GP +
//! expected improvement) and [`TpeSearcher`] (the HyperOpt algorithm —
//! MLtuner's default).  The stopping condition is the paper's
//! rule-of-thumb: stop when the top five best non-zero convergence
//! speeds differ by less than 10%.

pub mod bayesian;
pub mod gp;
pub mod grid;
pub mod random;
pub mod tpe;

pub use bayesian::BayesianOptSearcher;
pub use grid::GridSearcher;
pub use random::RandomSearcher;
pub use tpe::TpeSearcher;

/// A searcher proposal.
#[derive(Debug, Clone, PartialEq)]
pub enum Proposal {
    /// Try this unit-cube point next.
    Point(Vec<f64>),
    /// The search space is exhausted (GridSearcher only).
    Exhausted,
}

/// Black-box tunable searcher over the unit cube `[0,1]^d`.
pub trait Searcher: Send {
    /// Propose the next point to evaluate.
    fn propose(&mut self) -> Proposal;
    /// Report the convergence speed achieved by a proposed point
    /// (0.0 for diverged/unstable settings).
    fn observe(&mut self, point: Vec<f64>, speed: f64);
    /// All observations so far (point, speed).
    fn observations(&self) -> &[(Vec<f64>, f64)];
    fn name(&self) -> &'static str;
}

/// Which searcher to instantiate (config-file selectable).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SearcherKind {
    Random,
    Grid,
    BayesianOpt,
    /// HyperOpt/TPE — the paper's default searcher.
    #[default]
    HyperOpt,
}

impl SearcherKind {
    pub fn build(self, dim: usize, seed: u64) -> Box<dyn Searcher> {
        match self {
            SearcherKind::Random => Box::new(RandomSearcher::new(dim, seed)),
            SearcherKind::Grid => Box::new(GridSearcher::new(dim, 5)),
            SearcherKind::BayesianOpt => {
                Box::new(BayesianOptSearcher::new(dim, seed))
            }
            SearcherKind::HyperOpt => Box::new(TpeSearcher::new(dim, seed)),
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "random" => Some(SearcherKind::Random),
            "grid" => Some(SearcherKind::Grid),
            "bayesian_opt" | "bayesian" | "spearmint" => {
                Some(SearcherKind::BayesianOpt)
            }
            "hyperopt" | "tpe" => Some(SearcherKind::HyperOpt),
            _ => None,
        }
    }
}

/// Descending total order on convergence speeds that ranks NaN (and
/// treats it like any other diverged score) **strictly worst**.  A
/// diverged trial can surface a NaN speed; comparing it with
/// `partial_cmp().unwrap()` panics the whole tune instead of letting
/// the bad setting lose (that crash was live in `TpeSearcher::split`;
/// see also the Bayesian EI argmax) — every speed ranking must go
/// through a total order like this one.
pub fn cmp_speed_desc(a: &f64, b: &f64) -> std::cmp::Ordering {
    use std::cmp::Ordering;
    match (a.is_nan(), b.is_nan()) {
        (true, true) => Ordering::Equal,
        (true, false) => Ordering::Greater, // NaN sorts after everything
        (false, true) => Ordering::Less,
        // lint:allow(float-ord, panic-path): this IS the total-order
        // helper — both operands are proven non-NaN by the match above
        (false, false) => b.partial_cmp(a).expect("both comparable"),
    }
}

/// The paper's stopping condition: stop searching when the top five
/// best **non-zero** convergence speeds differ by less than 10%.
#[derive(Debug, Clone, Copy)]
pub struct StoppingCondition {
    pub top_n: usize,
    pub rel_tolerance: f64,
}

impl Default for StoppingCondition {
    fn default() -> Self {
        StoppingCondition {
            top_n: 5,
            rel_tolerance: 0.10,
        }
    }
}

impl StoppingCondition {
    pub fn should_stop(&self, observations: &[(Vec<f64>, f64)]) -> bool {
        // Only finite positive speeds count toward the top-5: a NaN
        // speed is a diverged setting that must simply lose (the old
        // `> 0.0` filter happened to drop NaN before the
        // `partial_cmp().unwrap()` sort, but only by accident — the
        // total order makes that immune to filter changes), and an
        // infinite speed can't support a relative-spread comparison.
        let mut speeds: Vec<f64> = observations
            .iter()
            .map(|(_, s)| *s)
            .filter(|s| s.is_finite() && *s > 0.0)
            .collect();
        if speeds.len() < self.top_n {
            return false;
        }
        speeds.sort_by(cmp_speed_desc);
        let top = &speeds[..self.top_n];
        let best = top[0];
        let worst = top[self.top_n - 1];
        (best - worst) / best < self.rel_tolerance
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(speeds: &[f64]) -> Vec<(Vec<f64>, f64)> {
        speeds.iter().map(|&s| (vec![0.5], s)).collect()
    }

    #[test]
    fn stopping_needs_five_nonzero() {
        let c = StoppingCondition::default();
        assert!(!c.should_stop(&obs(&[1.0, 1.0, 1.0, 1.0])));
        assert!(!c.should_stop(&obs(&[1.0, 1.0, 1.0, 1.0, 0.0])));
        assert!(c.should_stop(&obs(&[1.0, 1.0, 1.0, 1.0, 1.0])));
    }

    #[test]
    fn stopping_tolerance_boundary() {
        let c = StoppingCondition::default();
        // spread clearly above 10% => keep searching
        assert!(!c.should_stop(&obs(&[1.0, 1.0, 1.0, 1.0, 0.88])));
        assert!(c.should_stop(&obs(&[1.0, 1.0, 1.0, 1.0, 0.91])));
        // worse tails beyond the top-5 don't matter
        assert!(c.should_stop(&obs(&[1.0, 0.99, 0.98, 0.97, 0.96, 0.1, 0.0])));
    }

    #[test]
    fn stopping_survives_nan_and_inf_speeds() {
        // NaN and ±Inf speeds must simply not count toward the top-5
        // (and must never panic the ranking, whatever the filter in
        // front of it does — the unwrap-sort crash was live in
        // TpeSearcher::split, which now shares cmp_speed_desc).
        let c = StoppingCondition::default();
        assert!(!c.should_stop(&obs(&[f64::NAN; 8])));
        assert!(!c.should_stop(&obs(&[
            1.0,
            1.0,
            f64::NAN,
            1.0,
            1.0,
            f64::INFINITY,
            f64::NEG_INFINITY
        ])));
        // five finite near-equal speeds still stop, NaNs mixed in
        assert!(c.should_stop(&obs(&[f64::NAN, 1.0, 1.0, 1.0, 0.99, 1.0, f64::NAN])));
    }

    #[test]
    fn speed_order_ranks_nan_strictly_worst() {
        let mut speeds = vec![0.5, f64::NAN, 2.0, f64::NAN, 1.0, f64::INFINITY];
        speeds.sort_by(cmp_speed_desc);
        assert_eq!(speeds[0], f64::INFINITY);
        assert_eq!(&speeds[1..4], &[2.0, 1.0, 0.5]);
        assert!(speeds[4].is_nan() && speeds[5].is_nan());
    }

    #[test]
    fn kind_parse() {
        assert_eq!(
            SearcherKind::parse("hyperopt"),
            Some(SearcherKind::HyperOpt)
        );
        assert_eq!(
            SearcherKind::parse("spearmint"),
            Some(SearcherKind::BayesianOpt)
        );
        assert_eq!(SearcherKind::parse("nope"), None);
    }

    #[test]
    fn all_searchers_propose_in_unit_cube() {
        for kind in [
            SearcherKind::Random,
            SearcherKind::Grid,
            SearcherKind::BayesianOpt,
            SearcherKind::HyperOpt,
        ] {
            let mut s = kind.build(3, 7);
            for i in 0..30 {
                match s.propose() {
                    Proposal::Exhausted => break,
                    Proposal::Point(p) => {
                        assert_eq!(p.len(), 3);
                        assert!(
                            p.iter().all(|&u| (0.0..=1.0).contains(&u)),
                            "{:?} out of cube: {p:?}",
                            s.name()
                        );
                        // feed back a synthetic objective
                        let speed = 1.0 - (p[0] - 0.3).abs();
                        s.observe(p, speed + 0.01 * i as f64);
                    }
                }
            }
            assert!(!s.observations().is_empty(), "{}", s.name());
        }
    }
}
