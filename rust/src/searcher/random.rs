//! RandomSearcher: uniform samples from the search space, ignoring the
//! convergence speeds of previous trials (§4.3).

use crate::util::rng::Rng;

use super::{Proposal, Searcher};

#[derive(Debug)]
pub struct RandomSearcher {
    dim: usize,
    rng: Rng,
    observations: Vec<(Vec<f64>, f64)>,
}

impl RandomSearcher {
    pub fn new(dim: usize, seed: u64) -> Self {
        RandomSearcher {
            dim,
            rng: Rng::seed_from_u64(seed),
            observations: Vec::new(),
        }
    }
}

impl Searcher for RandomSearcher {
    fn propose(&mut self) -> Proposal {
        Proposal::Point((0..self.dim).map(|_| self.rng.gen_f64()).collect())
    }

    fn observe(&mut self, point: Vec<f64>, speed: f64) {
        self.observations.push((point, speed));
    }

    fn observations(&self) -> &[(Vec<f64>, f64)] {
        &self.observations
    }

    fn name(&self) -> &'static str {
        "random"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let p1 = RandomSearcher::new(4, 1).propose();
        let p2 = RandomSearcher::new(4, 1).propose();
        let p3 = RandomSearcher::new(4, 2).propose();
        assert_eq!(p1, p2);
        assert_ne!(p1, p3);
    }

    #[test]
    fn covers_the_cube() {
        let mut s = RandomSearcher::new(1, 0);
        let mut lo = 1.0f64;
        let mut hi = 0.0f64;
        for _ in 0..200 {
            if let Proposal::Point(p) = s.propose() {
                lo = lo.min(p[0]);
                hi = hi.max(p[0]);
            }
        }
        assert!(lo < 0.1 && hi > 0.9);
    }
}
