//! Minimal Gaussian-process regression for the Bayesian-optimization
//! searcher: Matérn-5/2 kernel, jittered Cholesky factorization, and
//! posterior mean/variance prediction.  Self-contained (no BLAS).

/// Dense symmetric positive-definite solver via Cholesky.
#[derive(Debug, Clone)]
pub struct Cholesky {
    /// Lower-triangular factor, row-major n×n.
    l: Vec<f64>,
    n: usize,
}

impl Cholesky {
    /// Factor `a` (row-major n×n, SPD).  Adds `jitter` to the diagonal,
    /// escalating ×10 until the factorization succeeds.
    pub fn new(mut a: Vec<f64>, n: usize, mut jitter: f64) -> Option<Self> {
        assert_eq!(a.len(), n * n);
        for _attempt in 0..8 {
            let mut l = a.clone();
            if Self::factor_in_place(&mut l, n) {
                return Some(Cholesky { l, n });
            }
            for i in 0..n {
                a[i * n + i] += jitter;
            }
            jitter *= 10.0;
        }
        None
    }

    fn factor_in_place(l: &mut [f64], n: usize) -> bool {
        for i in 0..n {
            for j in 0..=i {
                let mut s = l[i * n + j];
                for k in 0..j {
                    s -= l[i * n + k] * l[j * n + k];
                }
                if i == j {
                    if s <= 0.0 || !s.is_finite() {
                        return false;
                    }
                    l[i * n + j] = s.sqrt();
                } else {
                    l[i * n + j] = s / l[j * n + j];
                }
            }
            for j in (i + 1)..n {
                l[i * n + j] = 0.0;
            }
        }
        true
    }

    /// Solve `L y = b` (forward substitution).
    pub fn solve_lower(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n;
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut s = b[i];
            for k in 0..i {
                s -= self.l[i * n + k] * y[k];
            }
            y[i] = s / self.l[i * n + i];
        }
        y
    }

    /// Solve `A x = b` via `L L^T x = b`.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n;
        let y = self.solve_lower(b);
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in (i + 1)..n {
                s -= self.l[k * n + i] * x[k];
            }
            x[i] = s / self.l[i * n + i];
        }
        x
    }
}

/// Matérn-5/2 covariance with isotropic lengthscale.
pub fn matern52(a: &[f64], b: &[f64], lengthscale: f64, signal_var: f64) -> f64 {
    let mut d2 = 0.0;
    for (x, y) in a.iter().zip(b) {
        let d = x - y;
        d2 += d * d;
    }
    let r = d2.sqrt() / lengthscale;
    let s5 = 5f64.sqrt();
    signal_var * (1.0 + s5 * r + 5.0 * r * r / 3.0) * (-s5 * r).exp()
}

/// A fitted GP posterior over observations `(xs, ys)`.
#[derive(Debug)]
pub struct Gp {
    xs: Vec<Vec<f64>>,
    alpha: Vec<f64>,
    chol: Cholesky,
    lengthscale: f64,
    signal_var: f64,
    y_mean: f64,
    y_std: f64,
}

impl Gp {
    /// Fit with normalized targets and moment-matched hyperparameters
    /// (fixed lengthscale heuristic — Spearmint would marginalize, but
    /// for tunable search a robust fixed scale suffices).
    pub fn fit(xs: Vec<Vec<f64>>, ys: &[f64], noise_var: f64) -> Option<Self> {
        let n = xs.len();
        if n == 0 {
            return None;
        }
        let y_mean = ys.iter().sum::<f64>() / n as f64;
        let var =
            ys.iter().map(|y| (y - y_mean).powi(2)).sum::<f64>() / n as f64;
        let y_std = var.sqrt().max(1e-12);
        let ys_n: Vec<f64> = ys.iter().map(|y| (y - y_mean) / y_std).collect();
        let dim = xs[0].len().max(1);
        let lengthscale = 0.5 * (dim as f64).sqrt();
        let signal_var = 1.0;
        let mut k = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                k[i * n + j] = matern52(&xs[i], &xs[j], lengthscale, signal_var);
                if i == j {
                    k[i * n + j] += noise_var;
                }
            }
        }
        let chol = Cholesky::new(k, n, 1e-8)?;
        let alpha = chol.solve(&ys_n);
        Some(Gp {
            xs,
            alpha,
            chol,
            lengthscale,
            signal_var,
            y_mean,
            y_std,
        })
    }

    /// Posterior mean and variance at `x` (in original y units).
    pub fn predict(&self, x: &[f64]) -> (f64, f64) {
        let n = self.xs.len();
        let kx: Vec<f64> = (0..n)
            .map(|i| matern52(&self.xs[i], x, self.lengthscale, self.signal_var))
            .collect();
        let mean_n: f64 = kx.iter().zip(&self.alpha).map(|(k, a)| k * a).sum();
        let v = self.chol.solve_lower(&kx);
        let var_n = (self.signal_var - v.iter().map(|vi| vi * vi).sum::<f64>()).max(1e-12);
        (
            mean_n * self.y_std + self.y_mean,
            var_n * self.y_std * self.y_std,
        )
    }

    /// Expected improvement over `best` (maximization).
    pub fn expected_improvement(&self, x: &[f64], best: f64) -> f64 {
        let (mu, var) = self.predict(x);
        let sigma = var.sqrt();
        if sigma < 1e-12 {
            return (mu - best).max(0.0);
        }
        let z = (mu - best) / sigma;
        let (pdf, cdf) = (norm_pdf(z), norm_cdf(z));
        (mu - best) * cdf + sigma * pdf
    }
}

fn norm_pdf(z: f64) -> f64 {
    (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Abramowitz–Stegun approximation of the standard normal CDF.
pub fn norm_cdf(z: f64) -> f64 {
    let t = 1.0 / (1.0 + 0.2316419 * z.abs());
    let poly = t
        * (0.319381530
            + t * (-0.356563782
                + t * (1.781477937 + t * (-1.821255978 + t * 1.330274429))));
    let tail = norm_pdf(z.abs()) * poly;
    if z >= 0.0 {
        1.0 - tail
    } else {
        tail
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cholesky_solves_identity() {
        let chol = Cholesky::new(vec![1.0, 0.0, 0.0, 1.0], 2, 0.0).unwrap();
        assert_eq!(chol.solve(&[3.0, -4.0]), vec![3.0, -4.0]);
    }

    #[test]
    fn cholesky_solves_spd_system() {
        // A = [[4,2],[2,3]], b = [10, 9] => x = [1.5, 2]
        let chol = Cholesky::new(vec![4.0, 2.0, 2.0, 3.0], 2, 0.0).unwrap();
        let x = chol.solve(&[10.0, 9.0]);
        assert!((x[0] - 1.5).abs() < 1e-10);
        assert!((x[1] - 2.0).abs() < 1e-10);
    }

    #[test]
    fn cholesky_jitters_semidefinite() {
        // Singular matrix: needs jitter to factor.
        let a = vec![1.0, 1.0, 1.0, 1.0];
        assert!(Cholesky::new(a, 2, 1e-9).is_some());
    }

    #[test]
    fn matern_is_one_at_zero_distance_and_decays() {
        let k0 = matern52(&[0.5, 0.5], &[0.5, 0.5], 0.3, 1.0);
        let k1 = matern52(&[0.0, 0.0], &[1.0, 1.0], 0.3, 1.0);
        assert!((k0 - 1.0).abs() < 1e-12);
        assert!(k1 < 0.1 && k1 > 0.0);
    }

    #[test]
    fn gp_interpolates_observations() {
        let xs = vec![vec![0.0], vec![0.5], vec![1.0]];
        let ys = [0.0, 1.0, 0.0];
        let gp = Gp::fit(xs, &ys, 1e-6).unwrap();
        for (x, y) in [(0.0, 0.0), (0.5, 1.0), (1.0, 0.0)] {
            let (mu, _) = gp.predict(&[x]);
            assert!((mu - y).abs() < 0.05, "gp({x})={mu}, want {y}");
        }
        // uncertainty is larger away from data
        let (_, var_at) = gp.predict(&[0.5]);
        let (_, var_off) = gp.predict(&[0.25]);
        assert!(var_off > var_at);
    }

    #[test]
    fn ei_prefers_unexplored_promising_regions() {
        let xs = vec![vec![0.0], vec![1.0]];
        let ys = [0.0, 0.8];
        let gp = Gp::fit(xs, &ys, 1e-6).unwrap();
        // near the best observation, EI should beat the worst corner
        let ei_good = gp.expected_improvement(&[0.9], 0.8);
        let ei_bad = gp.expected_improvement(&[0.0], 0.8);
        assert!(ei_good > ei_bad);
    }

    #[test]
    fn norm_cdf_sanity() {
        assert!((norm_cdf(0.0) - 0.5).abs() < 1e-7);
        assert!(norm_cdf(3.0) > 0.99);
        assert!(norm_cdf(-3.0) < 0.01);
        assert!((norm_cdf(1.0) - 0.8413).abs() < 1e-3);
    }
}
