//! Training-system abstraction (§4.5) and the data-parallel machinery
//! shared by the real apps (§4.6).
//!
//! A [`TrainingSystem`] is anything MLtuner can drive with Table-1
//! branch operations: fork a branch from a consistent snapshot, free a
//! branch, schedule a branch for one clock and get back its progress
//! report.  Three implementations ship with this crate:
//!
//! * [`crate::apps::sim::SimSystem`] — calibrated analytic convergence
//!   model (regenerates the paper's figures in seconds),
//! * [`crate::apps::dnn::DnnSystem`] — the real three-layer stack:
//!   PJRT-executed JAX/Pallas artifacts over the parameter server,
//! * [`crate::apps::mf::MfSystem`] — native matrix-factorization SGD
//!   with AdaRevision (the paper's CPU app).
//!
//! The MLtuner protocol itself is single-threaded — one message at a
//! time through [`MessageDriver`] — but *inside* one `schedule_branch`
//! clock the parameter-server-backed systems fan the work out across
//! `num_workers` threads against the concurrent sharded
//! [`crate::ps::ParamServer`] (data-parallel clocks, the paper's
//! deployment shape).  [`crate::stats::Snapshot`] — probed through
//! [`TrainingSystem::stats`] — reports how the server absorbed that
//! load.

pub mod clock;

use std::path::Path;

use anyhow::{bail, Result};

use crate::comm::{BranchId, BranchType, Clock, ProtocolChecker, TunerMsg};
use crate::ps::checkpoint::StoreCheckpoint;
use crate::stats::{Snapshot, TrialEvent};
use crate::tunable::TunableSetting;

/// One clock's progress report: `value` is the aggregated training loss
/// (or validation accuracy for Testing branches); `time` is the elapsed
/// seconds of this clock — wall time for the real apps, virtual time
/// for the simulator.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Progress {
    pub value: f64,
    pub time: f64,
}

/// The training-system side of the Table-1 message interface.
///
/// Branch 0 is the root: the pristine initial training state, created
/// at system construction and never scheduled directly.
pub trait TrainingSystem {
    /// Fork `branch_id` from `parent` (None = root) with `tunable`.
    fn fork_branch(
        &mut self,
        clock: Clock,
        branch_id: BranchId,
        parent: Option<BranchId>,
        tunable: &TunableSetting,
        branch_type: BranchType,
    ) -> Result<()>;

    /// Free `branch_id`, reclaiming its resources.
    fn free_branch(&mut self, clock: Clock, branch_id: BranchId) -> Result<()>;

    /// Run `branch_id` for one clock; returns its progress report.
    fn schedule_branch(&mut self, clock: Clock, branch_id: BranchId) -> Result<Progress>;

    /// Clocks per epoch for this branch (depends on its batch size).
    fn clocks_per_epoch(&self, branch_id: BranchId) -> u64;

    /// Update a *running* branch's tunable setting in place.  Not part
    /// of the paper's MLtuner interface — used only by the manual
    /// LR-decay baseline drivers of Fig. 8.
    fn update_tunable(&mut self, _branch_id: BranchId, _tunable: &TunableSetting) -> Result<()> {
        anyhow::bail!("this training system does not support update_tunable")
    }

    /// Human-readable system name (logging).
    fn system_name(&self) -> &'static str {
        "training-system"
    }

    /// The unified stats probe ([`crate::stats::Snapshot`], §4.6
    /// snapshot efficiency included): branch census, copy-on-write
    /// cost, hot-path counters, wire counters.  Parameter-server apps
    /// forward the store's probe and overlay their own branch view;
    /// systems without branch bookkeeping may keep the zeroed default.
    fn stats(&self) -> Snapshot {
        Snapshot::default()
    }

    /// Publish one trial's latest progress into the observability
    /// stream (surfaced by shard servers to `mltuner top`
    /// subscribers).  Best-effort and side-channel: events must NOT go
    /// through the journaled message interface, or replay would
    /// diverge.  Systems without a remote store keep the no-op
    /// default.
    fn publish_trial(&self, _event: TrialEvent) {}

    /// Durably checkpoint this system's branch state — parameter rows,
    /// optimizer slots, and per-branch metadata — into `dir` (the
    /// checkpoint plane of [`crate::ps::checkpoint`]).  Returns `None`
    /// when the system has no durable store; resume then re-executes
    /// the session journal against a freshly built system instead
    /// (exact for virtual-time systems like the simulator).
    fn checkpoint_session(&self, _dir: &Path) -> Result<Option<StoreCheckpoint>> {
        Ok(None)
    }

    /// Restore the branch state written by
    /// [`TrainingSystem::checkpoint_session`] from `dir` into this
    /// (freshly constructed) system.  Returns `Ok(false)` when the
    /// system does not support durable restore — the caller then falls
    /// back to journal re-execution.
    fn restore_session(&mut self, _store: &StoreCheckpoint, _dir: &Path) -> Result<bool> {
        Ok(false)
    }
}

/// One recorded protocol exchange: a Table-1 message and (for
/// `ScheduleBranch`) the progress report it returned.  The sequence of
/// these — the **session journal** — is the event-sourced serialization
/// of a tune session: replaying it through a [`MessageDriver`]
/// deterministically rebuilds every piece of coordinator state
/// (searcher, trial traces, recorder, clock), even mid-episode.
#[derive(Debug, Clone, PartialEq)]
pub struct JournalEntry {
    pub msg: TunerMsg,
    pub reply: Option<Progress>,
}

/// Message-level driver: validates the §4.5 protocol (clock order, one
/// schedule per clock) before dispatching to the [`TrainingSystem`].
/// MLtuner and the baselines drive systems exclusively through this.
///
/// The driver is also the session journal's capture and replay point:
/// with recording enabled, every message/reply pair is appended to an
/// in-memory journal (serialized to disk by checkpoints, see
/// [`crate::tuner::session`]); with a journal loaded, messages are
/// matched against the recorded sequence and answered from it — bit
/// exactly — until the journal is exhausted, at which point the driver
/// switches to live dispatch.  A resumed coordinator that emits a
/// message differing from the journal is a determinism bug and fails
/// closed with a typed error.
pub struct MessageDriver<S: TrainingSystem> {
    pub system: S,
    checker: ProtocolChecker,
    /// Recorded traffic (journal); during replay, `cursor` walks it.
    journal: Vec<JournalEntry>,
    /// Next journal index to match.  `cursor < journal.len()` means
    /// the driver is replaying; once equal, it is live.
    cursor: usize,
    /// During replay, also re-execute each message against the system
    /// (used when the system has no durable store and must be rebuilt
    /// by deterministic re-execution).
    forward_replay: bool,
    /// Append live traffic to the journal (checkpointing enabled).
    recording: bool,
}

impl<S: TrainingSystem> MessageDriver<S> {
    pub fn new(system: S) -> Self {
        MessageDriver {
            system,
            checker: ProtocolChecker::default(),
            journal: Vec::new(),
            cursor: 0,
            forward_replay: false,
            recording: false,
        }
    }

    /// Start appending live traffic to the in-memory session journal.
    pub fn enable_recording(&mut self) {
        self.recording = true;
    }

    /// The recorded session journal so far (what a checkpoint
    /// serializes).
    pub fn journal(&self) -> &[JournalEntry] {
        &self.journal
    }

    /// Load a session journal for replay.  Subsequent sends must match
    /// the recorded sequence and are answered from it; with
    /// `forward_to_system` the messages are additionally re-executed
    /// against the training system (re-execution resume for systems
    /// without a durable store).  Recording stays on so the journal
    /// keeps growing past the replayed prefix.
    pub fn load_journal(&mut self, entries: Vec<JournalEntry>, forward_to_system: bool) {
        self.journal = entries;
        self.cursor = 0;
        self.forward_replay = forward_to_system;
        self.recording = true;
    }

    /// Is the driver still answering from a loaded journal?
    pub fn is_replaying(&self) -> bool {
        self.cursor < self.journal.len()
    }

    /// Dispatch one tuner message; `ScheduleBranch` returns progress.
    pub fn send(&mut self, msg: &TunerMsg) -> Result<Option<Progress>> {
        if self.cursor < self.journal.len() {
            let entry = self.journal[self.cursor].clone();
            if entry.msg != *msg {
                bail!(
                    "session journal divergence at entry {}: resumed coordinator sent \
                     {msg:?}, journal holds {:?} — every control-flow input is \
                     journaled (replies, decision times, searcher seeds), so this \
                     indicates a nondeterministic coordinator change; the checkpoint \
                     itself is intact",
                    self.cursor,
                    entry.msg
                );
            }
            self.checker.check(msg)?;
            if self.forward_replay {
                let live = self.dispatch(msg)?;
                if let (Some(live), Some(rec)) = (live, entry.reply) {
                    if live.value.to_bits() != rec.value.to_bits() {
                        bail!(
                            "session replay diverged at entry {}: system reported progress \
                             {}, journal holds {} — this training system is not \
                             deterministic enough to resume by re-execution",
                            self.cursor,
                            live.value,
                            rec.value
                        );
                    }
                }
            }
            self.cursor += 1;
            return Ok(entry.reply);
        }
        self.checker.check(msg)?;
        let reply = self.dispatch(msg)?;
        if self.recording {
            self.journal.push(JournalEntry {
                msg: msg.clone(),
                reply,
            });
            self.cursor = self.journal.len();
        }
        Ok(reply)
    }

    fn dispatch(&mut self, msg: &TunerMsg) -> Result<Option<Progress>> {
        match msg {
            TunerMsg::ForkBranch {
                clock,
                branch_id,
                parent_branch_id,
                tunable,
                branch_type,
            } => {
                self.system.fork_branch(
                    *clock,
                    *branch_id,
                    *parent_branch_id,
                    tunable,
                    *branch_type,
                )?;
                Ok(None)
            }
            TunerMsg::FreeBranch { clock, branch_id } => {
                self.system.free_branch(*clock, *branch_id)?;
                Ok(None)
            }
            TunerMsg::ScheduleBranch { clock, branch_id } => {
                Ok(Some(self.system.schedule_branch(*clock, *branch_id)?))
            }
        }
    }

    pub fn schedules_seen(&self) -> u64 {
        self.checker.schedules_seen()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    /// Trivial in-memory system for driver tests.
    #[derive(Default)]
    struct Toy {
        branches: HashMap<BranchId, f64>,
    }

    impl TrainingSystem for Toy {
        fn fork_branch(
            &mut self,
            _c: Clock,
            b: BranchId,
            parent: Option<BranchId>,
            _t: &TunableSetting,
            _ty: BranchType,
        ) -> Result<()> {
            let v = parent
                .map(|p| *self.branches.get(&p).unwrap_or(&10.0))
                .unwrap_or(10.0);
            self.branches.insert(b, v);
            Ok(())
        }
        fn free_branch(&mut self, _c: Clock, b: BranchId) -> Result<()> {
            self.branches.remove(&b);
            Ok(())
        }
        fn schedule_branch(&mut self, _c: Clock, b: BranchId) -> Result<Progress> {
            let v = self.branches.get_mut(&b).unwrap();
            *v *= 0.9;
            Ok(Progress { value: *v, time: 1.0 })
        }
        fn clocks_per_epoch(&self, _b: BranchId) -> u64 {
            10
        }
    }

    #[test]
    fn driver_enforces_clock_order() {
        let mut d = MessageDriver::new(Toy::default());
        let t = TunableSetting::new(vec![]);
        d.send(&TunerMsg::ForkBranch {
            clock: 0,
            branch_id: 1,
            parent_branch_id: None,
            tunable: t,
            branch_type: BranchType::Training,
        })
        .unwrap();
        let p = d
            .send(&TunerMsg::ScheduleBranch {
                clock: 0,
                branch_id: 1,
            })
            .unwrap()
            .unwrap();
        assert!(p.value < 10.0);
        // re-sending clock 0 schedule violates the protocol
        assert!(d
            .send(&TunerMsg::ScheduleBranch {
                clock: 0,
                branch_id: 1
            })
            .is_err());
    }

    fn fork(clock: Clock) -> TunerMsg {
        TunerMsg::ForkBranch {
            clock,
            branch_id: 1,
            parent_branch_id: None,
            tunable: TunableSetting::new(vec![]),
            branch_type: BranchType::Training,
        }
    }

    fn sched(clock: Clock) -> TunerMsg {
        TunerMsg::ScheduleBranch {
            clock,
            branch_id: 1,
        }
    }

    #[test]
    fn driver_records_and_replays_a_journal() {
        // record a short session against the deterministic Toy system
        let mut d = MessageDriver::new(Toy::default());
        d.enable_recording();
        let script = [fork(0), sched(0), sched(1), sched(2)];
        let mut replies = Vec::new();
        for m in &script {
            replies.push(d.send(m).unwrap());
        }
        assert_eq!(d.journal().len(), script.len());
        let journal = d.journal().to_vec();

        // replay it into a FRESH system (forward: Toy has no durable
        // store, so resume is re-execution) — replies must be served
        // bit-exactly from the journal
        let mut d2 = MessageDriver::new(Toy::default());
        d2.load_journal(journal.clone(), true);
        assert!(d2.is_replaying());
        for (m, want) in script.iter().zip(&replies) {
            let got = d2.send(m).unwrap();
            assert_eq!(got.map(|p| p.value.to_bits()), want.map(|p| p.value.to_bits()));
        }
        assert!(!d2.is_replaying(), "journal exhausted, driver is live");
        // and the session continues live, with the journal still growing
        let p = d2.send(&sched(3)).unwrap().unwrap();
        assert!(p.value < replies[3].unwrap().value);
        assert_eq!(d2.journal().len(), script.len() + 1);

        // a resumed coordinator that emits a different message than
        // the journal fails closed
        let mut d3 = MessageDriver::new(Toy::default());
        d3.load_journal(journal, false);
        let err = d3.send(&sched(0)).unwrap_err();
        assert!(err.to_string().contains("divergence"), "{err}");
    }
}
