//! Training-system abstraction (§4.5) and the data-parallel machinery
//! shared by the real apps (§4.6).
//!
//! A [`TrainingSystem`] is anything MLtuner can drive with Table-1
//! branch operations: fork a branch from a consistent snapshot, free a
//! branch, schedule a branch for one clock and get back its progress
//! report.  Three implementations ship with this crate:
//!
//! * [`crate::apps::sim::SimSystem`] — calibrated analytic convergence
//!   model (regenerates the paper's figures in seconds),
//! * [`crate::apps::dnn::DnnSystem`] — the real three-layer stack:
//!   PJRT-executed JAX/Pallas artifacts over the parameter server,
//! * [`crate::apps::mf::MfSystem`] — native matrix-factorization SGD
//!   with AdaRevision (the paper's CPU app).
//!
//! The MLtuner protocol itself is single-threaded — one message at a
//! time through [`MessageDriver`] — but *inside* one `schedule_branch`
//! clock the parameter-server-backed systems fan the work out across
//! `num_workers` threads against the concurrent sharded
//! [`crate::ps::ParamServer`] (data-parallel clocks, the paper's
//! deployment shape).  [`SnapshotStats`] reports how the server
//! absorbed that load.

pub mod clock;

use anyhow::Result;

use crate::comm::{BranchId, BranchType, Clock, ProtocolChecker, TunerMsg};
use crate::tunable::TunableSetting;

/// One clock's progress report: `value` is the aggregated training loss
/// (or validation accuracy for Testing branches); `time` is the elapsed
/// seconds of this clock — wall time for the real apps, virtual time
/// for the simulator.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Progress {
    pub value: f64,
    pub time: f64,
}

/// Snapshot-efficiency introspection (§4.6): how much branching cost a
/// training system actually paid.  For parameter-server-backed systems
/// `cow_buffer_copies` counts the buffers privately materialized by
/// copy-on-write — with lazy snapshots it is proportional to the rows
/// *written* under trial branches, not to forks × model size.  The
/// concurrency counters (`shard_lock_contentions`, `batch_calls`,
/// `batched_rows`) report how the sharded engine absorbed the
/// data-parallel update traffic of the worker threads.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SnapshotStats {
    /// Branches currently live (root included).
    pub live_branches: usize,
    /// Peak number of simultaneously-live branches.
    pub peak_branches: usize,
    /// Branch forks served since construction.
    pub forks: u64,
    /// Buffers privately materialized by copy-on-write (0 for systems
    /// without parameter-server storage, e.g. the simulator).
    pub cow_buffer_copies: u64,
    /// Shard-lock acquisitions that had to wait behind another thread
    /// (0 for systems without a sharded server, e.g. the simulator).
    pub shard_lock_contentions: u64,
    /// Batched-update calls served by the parameter server.
    pub batch_calls: u64,
    /// Rows applied through the batched update path.
    pub batched_rows: u64,
    /// Rows requested through the batched read path (`read_rows` —
    /// the gather phases of the parameter-server apps).
    pub reads_batched: u64,
    /// Data-plane `ReadRows` RPCs the store's client issued: 0 for an
    /// in-process store; for a remote store the batched read plane
    /// bounds it at O(shard servers × workers) per training clock
    /// (asserted by the distributed CI leg).
    pub read_rpcs: u64,
}

/// The training-system side of the Table-1 message interface.
///
/// Branch 0 is the root: the pristine initial training state, created
/// at system construction and never scheduled directly.
pub trait TrainingSystem {
    /// Fork `branch_id` from `parent` (None = root) with `tunable`.
    fn fork_branch(
        &mut self,
        clock: Clock,
        branch_id: BranchId,
        parent: Option<BranchId>,
        tunable: &TunableSetting,
        branch_type: BranchType,
    ) -> Result<()>;

    /// Free `branch_id`, reclaiming its resources.
    fn free_branch(&mut self, clock: Clock, branch_id: BranchId) -> Result<()>;

    /// Run `branch_id` for one clock; returns its progress report.
    fn schedule_branch(&mut self, clock: Clock, branch_id: BranchId) -> Result<Progress>;

    /// Clocks per epoch for this branch (depends on its batch size).
    fn clocks_per_epoch(&self, branch_id: BranchId) -> u64;

    /// Update a *running* branch's tunable setting in place.  Not part
    /// of the paper's MLtuner interface — used only by the manual
    /// LR-decay baseline drivers of Fig. 8.
    fn update_tunable(&mut self, _branch_id: BranchId, _tunable: &TunableSetting) -> Result<()> {
        anyhow::bail!("this training system does not support update_tunable")
    }

    /// Human-readable system name (logging).
    fn system_name(&self) -> &'static str {
        "training-system"
    }

    /// Snapshot-efficiency counters (§4.6).  Systems without branch
    /// bookkeeping may keep the zeroed default.
    fn snapshot_stats(&self) -> SnapshotStats {
        SnapshotStats::default()
    }
}

/// Message-level driver: validates the §4.5 protocol (clock order, one
/// schedule per clock) before dispatching to the [`TrainingSystem`].
/// MLtuner and the baselines drive systems exclusively through this.
pub struct MessageDriver<S: TrainingSystem> {
    pub system: S,
    checker: ProtocolChecker,
}

impl<S: TrainingSystem> MessageDriver<S> {
    pub fn new(system: S) -> Self {
        MessageDriver {
            system,
            checker: ProtocolChecker::default(),
        }
    }

    /// Dispatch one tuner message; `ScheduleBranch` returns progress.
    pub fn send(&mut self, msg: &TunerMsg) -> Result<Option<Progress>> {
        self.checker.check(msg)?;
        match msg {
            TunerMsg::ForkBranch {
                clock,
                branch_id,
                parent_branch_id,
                tunable,
                branch_type,
            } => {
                self.system.fork_branch(
                    *clock,
                    *branch_id,
                    *parent_branch_id,
                    tunable,
                    *branch_type,
                )?;
                Ok(None)
            }
            TunerMsg::FreeBranch { clock, branch_id } => {
                self.system.free_branch(*clock, *branch_id)?;
                Ok(None)
            }
            TunerMsg::ScheduleBranch { clock, branch_id } => {
                Ok(Some(self.system.schedule_branch(*clock, *branch_id)?))
            }
        }
    }

    pub fn schedules_seen(&self) -> u64 {
        self.checker.schedules_seen()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    /// Trivial in-memory system for driver tests.
    #[derive(Default)]
    struct Toy {
        branches: HashMap<BranchId, f64>,
    }

    impl TrainingSystem for Toy {
        fn fork_branch(
            &mut self,
            _c: Clock,
            b: BranchId,
            parent: Option<BranchId>,
            _t: &TunableSetting,
            _ty: BranchType,
        ) -> Result<()> {
            let v = parent
                .map(|p| *self.branches.get(&p).unwrap_or(&10.0))
                .unwrap_or(10.0);
            self.branches.insert(b, v);
            Ok(())
        }
        fn free_branch(&mut self, _c: Clock, b: BranchId) -> Result<()> {
            self.branches.remove(&b);
            Ok(())
        }
        fn schedule_branch(&mut self, _c: Clock, b: BranchId) -> Result<Progress> {
            let v = self.branches.get_mut(&b).unwrap();
            *v *= 0.9;
            Ok(Progress { value: *v, time: 1.0 })
        }
        fn clocks_per_epoch(&self, _b: BranchId) -> u64 {
            10
        }
    }

    #[test]
    fn driver_enforces_clock_order() {
        let mut d = MessageDriver::new(Toy::default());
        let t = TunableSetting::new(vec![]);
        d.send(&TunerMsg::ForkBranch {
            clock: 0,
            branch_id: 1,
            parent_branch_id: None,
            tunable: t,
            branch_type: BranchType::Training,
        })
        .unwrap();
        let p = d
            .send(&TunerMsg::ScheduleBranch {
                clock: 0,
                branch_id: 1,
            })
            .unwrap()
            .unwrap();
        assert!(p.value < 10.0);
        // re-sending clock 0 schedule violates the protocol
        assert!(d
            .send(&TunerMsg::ScheduleBranch {
                clock: 0,
                branch_id: 1
            })
            .is_err());
    }
}
