//! SSP (stale synchronous parallel) clock manager (§2.2).
//!
//! Tracks per-worker clocks for data-parallel training.  Under a
//! staleness bound `s`, a worker at clock `c` may proceed only if every
//! other worker has reached at least `c - s`; equivalently, worker
//! clocks never spread more than `s` apart.  `s = 0` is BSP (bulk
//! synchronous); larger `s` lets fast workers run ahead, trading
//! parameter freshness for pipeline efficiency — the data-staleness
//! tunable of Table 3.

use crate::comm::Clock;

#[derive(Debug, Clone)]
pub struct SspClock {
    worker_clocks: Vec<Clock>,
    staleness: u32,
}

impl SspClock {
    pub fn new(num_workers: usize, staleness: u32) -> Self {
        assert!(num_workers > 0);
        SspClock {
            worker_clocks: vec![0; num_workers],
            staleness,
        }
    }

    pub fn staleness(&self) -> u32 {
        self.staleness
    }

    pub fn set_staleness(&mut self, staleness: u32) {
        self.staleness = staleness;
    }

    pub fn num_workers(&self) -> usize {
        self.worker_clocks.len()
    }

    /// Slowest worker's clock — the globally-visible "stable" clock.
    pub fn min_clock(&self) -> Clock {
        *self.worker_clocks.iter().min().unwrap()
    }

    pub fn worker_clock(&self, w: usize) -> Clock {
        self.worker_clocks[w]
    }

    /// May worker `w` start its next clock without violating the bound?
    pub fn can_advance(&self, w: usize) -> bool {
        self.worker_clocks[w] < self.min_clock() + self.staleness as Clock + 1
    }

    /// Worker `w` finished one clock of work.
    pub fn advance(&mut self, w: usize) {
        debug_assert!(self.can_advance(w), "SSP bound violated by worker {w}");
        self.worker_clocks[w] += 1;
    }

    /// Reset all workers to clock 0 (branch switch).
    pub fn reset(&mut self) {
        self.worker_clocks.iter_mut().for_each(|c| *c = 0);
    }

    /// Maximum clock spread currently in the system.
    pub fn spread(&self) -> Clock {
        let max = *self.worker_clocks.iter().max().unwrap();
        max - self.min_clock()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bsp_locksteps_workers() {
        let mut c = SspClock::new(2, 0);
        assert!(c.can_advance(0));
        c.advance(0);
        // worker 0 must now wait for worker 1
        assert!(!c.can_advance(0));
        assert!(c.can_advance(1));
        c.advance(1);
        assert!(c.can_advance(0));
    }

    #[test]
    fn staleness_allows_bounded_runahead() {
        let mut c = SspClock::new(2, 3);
        for _ in 0..4 {
            assert!(c.can_advance(0));
            c.advance(0);
        }
        // 4 ahead of worker 1's clock 0 => blocked (bound is 3)
        assert!(!c.can_advance(0));
        assert_eq!(c.spread(), 4);
        c.advance(1);
        assert!(c.can_advance(0));
    }

    #[test]
    fn spread_never_exceeds_bound_plus_one() {
        // greedy scheduler: always advance the first advanceable worker
        let mut c = SspClock::new(4, 2);
        for _ in 0..100 {
            for w in 0..4 {
                if c.can_advance(w) {
                    c.advance(w);
                    break;
                }
            }
            assert!(c.spread() <= 3);
        }
    }

    #[test]
    fn reset_returns_to_zero() {
        let mut c = SspClock::new(2, 1);
        c.advance(0);
        c.advance(1);
        c.reset();
        assert_eq!(c.min_clock(), 0);
        assert_eq!(c.spread(), 0);
    }
}
