//! Rust token lexer for the house lint (offline substrate — `syn` /
//! `proc_macro2` are not vendored).  Produces a flat token stream with
//! 1-based line numbers, plus the `//` line comments (the carriers of
//! `lint:allow` pragmas).  It handles the lexical shapes that break
//! naive regex scanning: raw strings (`r#"…"#`), byte and raw-byte
//! strings, byte chars (`b'\n'`), char literals vs lifetimes (`'a'` vs
//! `'a`), nested block comments, and raw identifiers (`r#type`).
//!
//! The stream is deliberately lossy — whitespace and comments are not
//! tokens — because the rule passes in [`crate::analysis::rules`]
//! match on identifier/punct adjacency, never on spacing.  String and
//! char literals survive as opaque [`TokKind::Str`]/[`TokKind::Char`]
//! tokens, so `"partial_cmp"` inside a message can never trip a rule.

/// Kind of a lexed token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Lifetime,
    Str,
    Char,
    Num,
    Punct,
}

/// One token with its 1-based source line.
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

/// A `//` line comment (pragmas ride on these).
#[derive(Debug, Clone)]
pub struct Comment {
    pub line: u32,
    pub text: String,
}

/// Lexer output: the token stream plus all line comments.
#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub comments: Vec<Comment>,
}

/// Lex `src` into tokens.  The lexer never fails: unrecognized bytes
/// are skipped, unterminated literals run to end of input.  Good
/// enough for lint passes over code that rustc already accepted.
pub fn lex(src: &str) -> Lexed {
    Lexer {
        bytes: src.as_bytes(),
        pos: 0,
        line: 1,
        out: Lexed::default(),
    }
    .run()
}

/// Index of the token closing the delimiter opened at `open`
/// (`(`, `[` or `{`), if balanced.  Only the matching delimiter kind
/// is counted — valid Rust keeps each kind independently balanced.
pub fn match_delim(toks: &[Tok], open: usize) -> Option<usize> {
    let (oc, cc) = match toks.get(open)?.text.as_str() {
        "(" => ("(", ")"),
        "[" => ("[", "]"),
        "{" => ("{", "}"),
        _ => return None,
    };
    let mut depth = 0usize;
    for (i, t) in toks.iter().enumerate().skip(open) {
        if t.kind != TokKind::Punct {
            continue;
        }
        if t.text == oc {
            depth += 1;
        } else if t.text == cc {
            depth -= 1;
            if depth == 0 {
                return Some(i);
            }
        }
    }
    None
}

struct Lexer<'a> {
    bytes: &'a [u8],
    pos: usize,
    line: u32,
    out: Lexed,
}

fn ident_start(b: u8) -> bool {
    b == b'_' || b.is_ascii_alphabetic()
}

fn ident_continue(b: u8) -> bool {
    b == b'_' || b.is_ascii_alphanumeric()
}

impl<'a> Lexer<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn peek_at(&self, off: usize) -> Option<u8> {
        self.bytes.get(self.pos + off).copied()
    }

    /// Advance one byte, tracking line numbers.
    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        if b == b'\n' {
            self.line += 1;
        }
        Some(b)
    }

    fn push(&mut self, kind: TokKind, start: usize, line: u32) {
        let text = String::from_utf8_lossy(&self.bytes[start..self.pos]).into_owned();
        self.out.toks.push(Tok { kind, text, line });
    }

    fn run(mut self) -> Lexed {
        while let Some(b) = self.peek() {
            match b {
                b' ' | b'\t' | b'\r' | b'\n' => {
                    self.bump();
                }
                b'/' if self.peek_at(1) == Some(b'/') => self.line_comment(),
                b'/' if self.peek_at(1) == Some(b'*') => self.block_comment(),
                b'\'' => self.quote(),
                b'"' => {
                    let (start, line) = (self.pos, self.line);
                    self.string_body();
                    self.push(TokKind::Str, start, line);
                }
                b'0'..=b'9' => self.number(),
                _ if ident_start(b) => self.word(),
                _ => self.punct(),
            }
        }
        self.out
    }

    fn line_comment(&mut self) {
        let (start, line) = (self.pos, self.line);
        while !matches!(self.peek(), None | Some(b'\n')) {
            self.pos += 1;
        }
        let text = String::from_utf8_lossy(&self.bytes[start..self.pos]).into_owned();
        self.out.comments.push(Comment { line, text });
    }

    fn block_comment(&mut self) {
        self.pos += 2; // the `/*`
        let mut depth = 1usize;
        while depth > 0 {
            match self.bump() {
                None => break,
                Some(b'/') if self.peek() == Some(b'*') => {
                    self.bump();
                    depth += 1;
                }
                Some(b'*') if self.peek() == Some(b'/') => {
                    self.bump();
                    depth -= 1;
                }
                _ => {}
            }
        }
    }

    /// Cursor on an opening `"`; consumes the quoted body including
    /// escape sequences (`\"` does not terminate).
    fn string_body(&mut self) {
        self.bump(); // opening quote
        while let Some(b) = self.bump() {
            match b {
                b'"' => break,
                b'\\' => {
                    self.bump(); // the escaped byte
                }
                _ => {}
            }
        }
    }

    /// Cursor on a `'`: char literal or lifetime/label.
    fn quote(&mut self) {
        let (start, line) = (self.pos, self.line);
        self.bump(); // the quote
        match self.peek() {
            Some(b'\\') => {
                // escaped char literal: `'\n'`, `'\''`, `'\u{1F600}'`
                self.bump(); // backslash
                self.bump(); // escaped byte (or the x/u introducer)
                while let Some(b) = self.bump() {
                    if b == b'\'' {
                        break;
                    }
                }
                self.push(TokKind::Char, start, line);
            }
            Some(b) if ident_start(b) => {
                // `'a'` is a char, `'a` / `'static` / `'outer:` are
                // lifetimes or labels — disambiguated by the closing
                // quote after the identifier run.
                let mut j = self.pos;
                while j < self.bytes.len() && ident_continue(self.bytes[j]) {
                    j += 1;
                }
                if self.bytes.get(j) == Some(&b'\'') {
                    self.pos = j + 1;
                    self.push(TokKind::Char, start, line);
                } else {
                    self.pos = j;
                    self.push(TokKind::Lifetime, start, line);
                }
            }
            Some(_) => {
                // unescaped char literal: `'('`, `'9'`, `'→'`
                self.bump(); // first byte of the char
                while let Some(b) = self.bump() {
                    if b == b'\'' {
                        break;
                    }
                }
                self.push(TokKind::Char, start, line);
            }
            None => {}
        }
    }

    fn number(&mut self) {
        let (start, line) = (self.pos, self.line);
        self.digits_run();
        if self.peek() == Some(b'.') && matches!(self.peek_at(1), Some(b'0'..=b'9')) {
            self.pos += 1;
            self.digits_run();
        }
        // exponent sign: `1e-5`, `2.5E+3`
        if matches!(self.bytes.get(self.pos.wrapping_sub(1)), Some(b'e' | b'E'))
            && matches!(self.peek(), Some(b'+' | b'-'))
        {
            self.pos += 1;
            self.digits_run();
        }
        self.push(TokKind::Num, start, line);
    }

    /// `[0-9a-zA-Z_]*` — digits, hex digits, suffixes, exponents.
    fn digits_run(&mut self) {
        while matches!(self.peek(), Some(b) if ident_continue(b)) {
            self.pos += 1;
        }
    }

    /// Cursor on an identifier-start byte: plain identifier, raw
    /// identifier, or a string-literal prefix (`r"`, `r#"`, `b"`,
    /// `b'`, `br"`, `br#"`).
    fn word(&mut self) {
        let (start, line) = (self.pos, self.line);
        match (self.bytes[self.pos], self.peek_at(1)) {
            (b'b', Some(b'\'')) => {
                self.pos += 1; // the prefix; quote() lexes the literal
                self.quote();
                return;
            }
            (b'b', Some(b'"')) => {
                self.pos += 1;
                self.string_body();
                self.push(TokKind::Str, start, line);
                return;
            }
            (b'r', Some(b'"' | b'#')) => {
                if self.raw_string(start, line, 1) {
                    return; // else raw identifier `r#name`: fall through
                }
            }
            (b'b', Some(b'r')) if matches!(self.peek_at(2), Some(b'"' | b'#')) => {
                if self.raw_string(start, line, 2) {
                    return;
                }
            }
            _ => {}
        }
        let mut j = self.pos;
        if self.bytes[j] == b'r' && self.bytes.get(j + 1) == Some(&b'#') {
            j += 2; // raw identifier prefix
        }
        let tstart = j;
        while j < self.bytes.len() && ident_continue(self.bytes[j]) {
            j += 1;
        }
        self.pos = j;
        // raw identifiers lex as their bare name so rules match on it
        let text = String::from_utf8_lossy(&self.bytes[tstart..j]).into_owned();
        self.out.toks.push(Tok {
            kind: TokKind::Ident,
            text,
            line,
        });
    }

    /// Try to lex a raw (byte) string whose `r`/`br` prefix starts at
    /// the cursor; `skip` is the prefix length.  Returns false when
    /// the shape is actually a raw identifier (`r#name`).
    fn raw_string(&mut self, start: usize, line: u32, skip: usize) -> bool {
        let mut k = self.pos + skip;
        let mut hashes = 0usize;
        while self.bytes.get(k) == Some(&b'#') {
            hashes += 1;
            k += 1;
        }
        if self.bytes.get(k) != Some(&b'"') {
            return false;
        }
        self.pos = k + 1; // past the opening quote (no newlines skipped)
        loop {
            match self.bump() {
                None => break,
                Some(b'"') => {
                    let mut h = 0usize;
                    while h < hashes && self.peek() == Some(b'#') {
                        self.bump();
                        h += 1;
                    }
                    if h == hashes {
                        break;
                    }
                }
                _ => {}
            }
        }
        self.push(TokKind::Str, start, line);
        true
    }

    fn punct(&mut self) {
        let line = self.line;
        let b = self.bytes[self.pos];
        self.pos += 1;
        if b.is_ascii() {
            self.out.toks.push(Tok {
                kind: TokKind::Punct,
                text: char::from(b).to_string(),
                line,
            });
        }
        // non-ASCII bytes outside strings/comments are skipped
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).toks.into_iter().map(|t| (t.kind, t.text)).collect()
    }

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .toks
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn raw_strings_are_opaque() {
        let src = r##"let s = r#"a.partial_cmp(b).unwrap()"#; s.len()"##;
        assert_eq!(idents(src), vec!["let", "s", "s", "len"]);
    }

    #[test]
    fn byte_and_raw_byte_strings() {
        let src = r##"let a = b"unwrap"; let c = br#"panic!"#;"##;
        assert_eq!(idents(src), vec!["let", "a", "let", "c"]);
        let strs = lex(src)
            .toks
            .into_iter()
            .filter(|t| t.kind == TokKind::Str)
            .count();
        assert_eq!(strs, 2);
    }

    #[test]
    fn byte_char_and_escaped_quote() {
        let src = r"let nl = b'\n'; let q = '\''; let p = '(';";
        assert_eq!(idents(src), vec!["let", "nl", "let", "q", "let", "p"]);
        let chars = lex(src)
            .toks
            .into_iter()
            .filter(|t| t.kind == TokKind::Char)
            .count();
        assert_eq!(chars, 3);
    }

    #[test]
    fn lifetimes_vs_chars_vs_labels() {
        let src = "fn f<'a>(x: &'a str) { 'outer: loop { break 'outer; } let c = 'z'; }";
        let lifetimes: Vec<String> = lex(src)
            .toks
            .into_iter()
            .filter(|t| t.kind == TokKind::Lifetime)
            .map(|t| t.text)
            .collect();
        assert_eq!(lifetimes, vec!["'a", "'a", "'outer", "'outer"]);
        let chars: Vec<String> = lex(src)
            .toks
            .into_iter()
            .filter(|t| t.kind == TokKind::Char)
            .map(|t| t.text)
            .collect();
        assert_eq!(chars, vec!["'z'"]);
    }

    #[test]
    fn nested_block_comments_and_line_comments() {
        let src = "a /* x /* y */ still comment */ b // trailing unwrap()\nc";
        assert_eq!(idents(src), vec!["a", "b", "c"]);
        let lx = lex(src);
        assert_eq!(lx.comments.len(), 1);
        assert!(lx.comments[0].text.contains("trailing"));
    }

    #[test]
    fn raw_identifiers_lex_as_bare_name() {
        assert_eq!(idents("let r#type = 1;"), vec!["let", "type"]);
    }

    #[test]
    fn line_numbers_track_every_literal_shape() {
        let src = "a\n\"two\nlines\"\nb /* c\nd */ e\nr#\"raw\nraw\"# f";
        let lx = lex(src);
        let find = |name: &str| lx.toks.iter().find(|t| t.text == name).unwrap().line;
        assert_eq!(find("a"), 1);
        assert_eq!(find("b"), 4);
        assert_eq!(find("e"), 5);
        assert_eq!(find("f"), 7);
    }

    #[test]
    fn numbers_do_not_swallow_ranges() {
        let src = "for i in 0..10 { x += 1.5e-3; }";
        let k = kinds(src);
        assert!(k.contains(&(TokKind::Num, "0".to_string())));
        assert!(k.contains(&(TokKind::Num, "10".to_string())));
        assert!(k.contains(&(TokKind::Num, "1.5e-3".to_string())));
    }

    #[test]
    fn match_delim_balances() {
        let lx = lex("f(a, (b), [c{d}])");
        assert_eq!(match_delim(&lx.toks, 1), Some(lx.toks.len() - 1));
        assert_eq!(match_delim(&lx.toks, 0), None); // `f` is not a delim
    }
}
