//! The four rule passes behind `mltuner_lint`.  Each pass is a linear
//! scan over the token stream from [`crate::analysis::lexer`]; none of
//! them re-read raw source text, so string and comment contents can
//! never produce false positives.
//!
//! Rule applicability (which passes run for which `src/` subtree) and
//! pragma suppression both live in [`crate::analysis`]; the passes
//! here only detect.

use super::lexer::{match_delim, Tok, TokKind};
use super::Diagnostic;

/// Rule id constants — shared with pragma parsing and `--rules`.
pub const FLOAT_ORD: &str = "float-ord";
pub const WIRE_INT_CAST: &str = "wire-int-cast";
pub const PANIC_PATH: &str = "panic-path";
pub const LOCK_ORDER: &str = "lock-order";

/// Shared per-file context handed to each rule pass.
pub struct Ctx<'a> {
    pub file: &'a str,
    pub toks: &'a [Tok],
    /// Token-index ranges (inclusive) lexically under `#[cfg(test)]`
    /// or `#[test]`.
    pub test_spans: &'a [(usize, usize)],
}

impl<'a> Ctx<'a> {
    fn in_test(&self, i: usize) -> bool {
        self.test_spans.iter().any(|&(a, b)| a <= i && i <= b)
    }

    fn diag(&self, line: u32, rule: &'static str, msg: String) -> Diagnostic {
        Diagnostic {
            file: self.file.to_string(),
            line,
            rule,
            msg,
        }
    }

    fn is_ident(&self, i: usize, name: &str) -> bool {
        matches!(self.toks.get(i), Some(t) if t.kind == TokKind::Ident && t.text == name)
    }

    fn is_punct(&self, i: usize, ch: &str) -> bool {
        matches!(self.toks.get(i), Some(t) if t.kind == TokKind::Punct && t.text == ch)
    }
}

/// Comparator-taking methods policed by [`float_ord`].
const COMPARATOR_SINKS: [&str; 4] = ["sort_by", "sort_unstable_by", "max_by", "min_by"];

/// **float-ord**: `partial_cmp` chained into `.unwrap()`/`.expect(`
/// panics on NaN, and a `sort_by`/`max_by`-style comparator built on
/// `partial_cmp` without `total_cmp`/`cmp_speed_desc` has no total
/// order.  Applies everywhere, tests included — the PR 4/5 NaN panics
/// started life as "can't happen here" test idioms.
pub fn float_ord(ctx: &Ctx<'_>) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let mut chained = Vec::new();
    for i in 0..ctx.toks.len() {
        if !ctx.is_ident(i, "partial_cmp") || !ctx.is_punct(i + 1, "(") {
            continue;
        }
        let Some(close) = match_delim(ctx.toks, i + 1) else {
            continue;
        };
        for sink in ["unwrap", "expect"] {
            if ctx.is_punct(close + 1, ".")
                && ctx.is_ident(close + 2, sink)
                && ctx.is_punct(close + 3, "(")
            {
                out.push(ctx.diag(
                    ctx.toks[i].line,
                    FLOAT_ORD,
                    format!(
                        "`partial_cmp(..).{sink}(..)` panics on NaN; use `f64::total_cmp` \
                         or `searcher::cmp_speed_desc`"
                    ),
                ));
                chained.push(i);
            }
        }
    }
    for i in 0..ctx.toks.len() {
        if !COMPARATOR_SINKS.iter().any(|s| ctx.is_ident(i, s)) || !ctx.is_punct(i + 1, "(") {
            continue;
        }
        let Some(close) = match_delim(ctx.toks, i + 1) else {
            continue;
        };
        let span = (i + 2)..close;
        let has = |name: &str| span.clone().any(|j| ctx.is_ident(j, name));
        // a chained violation inside the span already reported the site
        if has("partial_cmp")
            && !has("total_cmp")
            && !has("cmp_speed_desc")
            && !chained.iter().any(|c| span.contains(c))
        {
            out.push(ctx.diag(
                ctx.toks[i].line,
                FLOAT_ORD,
                format!(
                    "`{}` comparator uses `partial_cmp` without `total_cmp`/`cmp_speed_desc`; \
                     NaN breaks the required total order",
                    ctx.toks[i].text
                ),
            ));
        }
    }
    out
}

/// Integer types a bare `as` cast may silently truncate into.
const INT_TYPES: [&str; 12] = [
    "u8", "u16", "u32", "u64", "u128", "usize", "i8", "i16", "i32", "i64", "i128", "isize",
];

/// **wire-int-cast**: bare `as <int>` casts in `comm/` silently
/// truncate wire-derived values (the PR 3 bug class); decode through
/// the strict helpers or `try_from`.  Non-test code only.
pub fn wire_int_cast(ctx: &Ctx<'_>) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for i in 0..ctx.toks.len() {
        if !ctx.is_ident(i, "as") || ctx.in_test(i) {
            continue;
        }
        let Some(t) = ctx.toks.get(i + 1) else {
            continue;
        };
        if t.kind == TokKind::Ident && INT_TYPES.contains(&t.text.as_str()) {
            out.push(ctx.diag(
                ctx.toks[i].line,
                WIRE_INT_CAST,
                format!(
                    "bare `as {}` integer cast in comm/; decode through the strict helpers \
                     (`num_u64`/`num_usize`) or `{}::try_from`",
                    t.text, t.text
                ),
            ));
        }
    }
    out
}

/// **panic-path**: `.unwrap()` / `.expect(` / `panic!` in non-test
/// coordinator and parameter-server code takes down every tenant of a
/// long-lived PS; return an error or justify with a pragma.
pub fn panic_path(ctx: &Ctx<'_>) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for i in 0..ctx.toks.len() {
        if ctx.in_test(i) {
            continue;
        }
        let msg = if (ctx.is_ident(i, "unwrap") || ctx.is_ident(i, "expect"))
            && i > 0
            && ctx.is_punct(i - 1, ".")
            && ctx.is_punct(i + 1, "(")
        {
            Some(format!(
                "`.{}()` on a non-test path; return an error or justify with \
                 `// lint:allow(panic-path): reason`",
                ctx.toks[i].text
            ))
        } else if ctx.is_ident(i, "panic") && ctx.is_punct(i + 1, "!") {
            Some(
                "`panic!` on a non-test path; return an error or justify with \
                 `// lint:allow(panic-path): reason`"
                    .to_string(),
            )
        } else {
            None
        };
        if let Some(msg) = msg {
            out.push(ctx.diag(ctx.toks[i].line, PANIC_PATH, msg));
        }
    }
    out
}

/// **lock-order**: token-level guard-scope tracking for `ps/`.  A
/// shard guard (`read_shard(..)`/`write_shard(..)`) bound directly by
/// `let` (`let st = read_shard(..);`) lives to the end of its block;
/// any other use is a temporary that dies at the end of its statement
/// (`let n = read_shard(..).len();` included).  Calling
/// `lock_control(..)` while any shard guard is live inverts the
/// documented control→shard hierarchy and can deadlock against
/// `replace_branch_rows`.
pub fn lock_order(ctx: &Ctx<'_>) -> Vec<Diagnostic> {
    struct Guard {
        depth: usize,
        let_bound: bool,
        line: u32,
    }
    let mut out = Vec::new();
    let mut guards: Vec<Guard> = Vec::new();
    let mut depth = 0usize;
    let mut stmt_has_let = false;
    for i in 0..ctx.toks.len() {
        if ctx.in_test(i) {
            continue;
        }
        let t = &ctx.toks[i];
        match (t.kind, t.text.as_str()) {
            (TokKind::Punct, "{") => {
                depth += 1;
                stmt_has_let = false;
            }
            (TokKind::Punct, "}") => {
                guards.retain(|g| g.depth < depth);
                depth = depth.saturating_sub(1);
                stmt_has_let = false;
            }
            (TokKind::Punct, ";") => {
                guards.retain(|g| g.let_bound || g.depth < depth);
                stmt_has_let = false;
            }
            (TokKind::Ident, "let") => stmt_has_let = true,
            (TokKind::Ident, "read_shard" | "write_shard") if ctx.is_punct(i + 1, "(") => {
                // bound directly by `let` iff the call closes the
                // statement: `let st = read_shard(..);`
                let direct = match_delim(ctx.toks, i + 1)
                    .map_or(false, |close| ctx.is_punct(close + 1, ";"));
                guards.push(Guard {
                    depth,
                    let_bound: stmt_has_let && direct,
                    line: t.line,
                });
            }
            (TokKind::Ident, "lock_control") if ctx.is_punct(i + 1, "(") => {
                if let Some(g) = guards.first() {
                    out.push(ctx.diag(
                        t.line,
                        LOCK_ORDER,
                        format!(
                            "control-plane mutex acquired while the shard guard from line {} \
                             is live; the documented hierarchy is control -> shard",
                            g.line
                        ),
                    ));
                }
            }
            _ => {}
        }
    }
    out
}
