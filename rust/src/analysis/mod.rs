//! House static analysis behind the `mltuner_lint` binary (offline
//! substrate — `clippy` custom lints and `dylint` are not vendored).
//!
//! Four rule passes enforce the crate's recurring-bug-class
//! disciplines over `src/` (see `docs/ARCHITECTURE.md`, "Enforced
//! invariants"):
//!
//! * `float-ord` — no `partial_cmp` chained into `.unwrap()`/
//!   `.expect(`, and no float comparator without `total_cmp`/
//!   `cmp_speed_desc` (everywhere, tests included).
//! * `wire-int-cast` — no bare `as` integer casts in `comm/`
//!   (non-test); wire-derived values go through the strict decode
//!   helpers or `try_from`.
//! * `panic-path` — no `.unwrap()`/`.expect(`/`panic!` in non-test
//!   code under `ps/`, `comm/`, `tuner/`, `searcher/`.
//! * `lock-order` — in `ps/` (non-test), never acquire the
//!   control-plane mutex while a shard `RwLock` guard is live.
//!
//! A finding is suppressed by a pragma on, or directly above, the
//! offending line:
//!
//! ```text
//! // lint:allow(panic-path): join propagates a worker panic
//! ```
//!
//! Multiple rules may be listed (`lint:allow(float-ord, panic-path):
//! …`).  The reason is mandatory; a malformed pragma is itself a
//! diagnostic (rule id `pragma`) and suppresses nothing.

pub mod lexer;
pub mod rules;

use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

use lexer::{Comment, Tok, TokKind};

/// Rule identifiers, as accepted by `--rules` and by
/// `// lint:allow(rule): reason` pragmas.
pub const RULES: [&str; 4] = [
    rules::FLOAT_ORD,
    rules::WIRE_INT_CAST,
    rules::PANIC_PATH,
    rules::LOCK_ORDER,
];

/// Rule id reported for malformed pragmas; always enabled and never
/// suppressible.
pub const PRAGMA_RULE: &str = "pragma";

/// One lint finding, printed as `file:line [rule] message`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    pub file: String,
    pub line: u32,
    pub rule: &'static str,
    pub msg: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{} [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Aggregate result of linting a tree.
#[derive(Debug, Default)]
pub struct Report {
    pub files: usize,
    pub diags: Vec<Diagnostic>,
}

/// Lint one file's source.  `rel` is the path relative to the `src`
/// root (e.g. `ps/mod.rs`) — rule applicability keys off its first
/// component.  Returns findings from every applicable rule, pragma
/// suppression already applied, sorted by line.
pub fn check_source(rel: &str, src: &str) -> Vec<Diagnostic> {
    let lexed = lexer::lex(src);
    let spans = test_spans(&lexed.toks);
    let ctx = rules::Ctx {
        file: rel,
        toks: &lexed.toks,
        test_spans: &spans,
    };
    let mut diags = rules::float_ord(&ctx);
    if rel.starts_with("comm/") {
        diags.extend(rules::wire_int_cast(&ctx));
    }
    let panic_roots = ["ps/", "comm/", "tuner/", "searcher/"];
    if panic_roots.iter().any(|p| rel.starts_with(p)) {
        diags.extend(rules::panic_path(&ctx));
    }
    if rel.starts_with("ps/") {
        diags.extend(rules::lock_order(&ctx));
    }
    let (pragmas, mut pragma_diags) = collect_pragmas(rel, &lexed.comments);
    diags.retain(|d| !suppressed(d, &pragmas, &lexed.toks));
    diags.append(&mut pragma_diags);
    diags.sort_by_key(|d| (d.line, d.rule));
    diags
}

/// Walk `root` (normally `rust/src`), lint every `.rs` file, and
/// return the aggregate report.  `enabled` filters which rule ids are
/// reported; malformed-pragma diagnostics are always kept.
pub fn run_dir(root: &Path, enabled: &[&str]) -> io::Result<Report> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();
    let mut report = Report::default();
    for path in files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace('\\', "/");
        let src = fs::read_to_string(&path)?;
        report.files += 1;
        report.diags.extend(
            check_source(&rel, &src)
                .into_iter()
                .filter(|d| d.rule == PRAGMA_RULE || enabled.contains(&d.rule)),
        );
    }
    Ok(report)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().map_or(false, |e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// A well-formed `// lint:allow(rule, …): reason` pragma.
#[derive(Debug)]
struct Pragma {
    line: u32,
    rules: Vec<&'static str>,
}

/// Parse pragmas out of the line comments.  Malformed pragmas
/// (unknown rule, missing reason) become diagnostics instead of
/// suppressions, so a typo can never silently disable a rule.
fn collect_pragmas(file: &str, comments: &[Comment]) -> (Vec<Pragma>, Vec<Diagnostic>) {
    let mut pragmas = Vec::new();
    let mut diags = Vec::new();
    for c in comments {
        let Some(at) = c.text.find("lint:allow") else {
            continue;
        };
        let bad = |msg: String| Diagnostic {
            file: file.to_string(),
            line: c.line,
            rule: PRAGMA_RULE,
            msg,
        };
        let rest = &c.text[at + "lint:allow".len()..];
        let close = match (rest.starts_with('('), rest.find(')')) {
            (true, Some(close)) => close,
            _ => {
                diags.push(bad(
                    "malformed pragma: expected `lint:allow(rule, …): reason`".to_string(),
                ));
                continue;
            }
        };
        let mut names = Vec::new();
        let mut ok = true;
        for part in rest[1..close].split(',') {
            let name = part.trim();
            match RULES.iter().find(|r| **r == name) {
                Some(r) => names.push(*r),
                None => {
                    diags.push(bad(format!("unknown lint rule `{name}` in pragma")));
                    ok = false;
                }
            }
        }
        let reason_ok = rest[close + 1..]
            .trim_start()
            .strip_prefix(':')
            .map(str::trim)
            .map_or(false, |r| !r.is_empty());
        if !reason_ok {
            diags.push(bad(
                "pragma missing a reason: `lint:allow(rule): why this is safe`".to_string(),
            ));
            ok = false;
        }
        if ok {
            pragmas.push(Pragma {
                line: c.line,
                rules: names,
            });
        }
    }
    (pragmas, diags)
}

/// A pragma covers its own line and the next line holding any token —
/// i.e. it sits at the end of the offending line or on its own line
/// directly above it.
fn suppressed(d: &Diagnostic, pragmas: &[Pragma], toks: &[Tok]) -> bool {
    pragmas.iter().any(|p| {
        p.rules.contains(&d.rule)
            && (d.line == p.line || Some(d.line) == next_code_line(toks, p.line))
    })
}

fn next_code_line(toks: &[Tok], after: u32) -> Option<u32> {
    toks.iter().map(|t| t.line).filter(|&l| l > after).min()
}

/// Token-index spans (inclusive) of items under `#[cfg(test)]` or
/// `#[test]`: from the attribute's `#` through the `}` (or `;`)
/// closing the annotated item.
fn test_spans(toks: &[Tok]) -> Vec<(usize, usize)> {
    let is_p = |i: usize, ch: &str| {
        matches!(toks.get(i), Some(t) if t.kind == TokKind::Punct && t.text == ch)
    };
    let is_i = |i: usize, name: &str| {
        matches!(toks.get(i), Some(t) if t.kind == TokKind::Ident && t.text == name)
    };
    let mut spans = Vec::new();
    let mut i = 0;
    while i < toks.len() {
        if !is_p(i, "#") || !is_p(i + 1, "[") {
            i += 1;
            continue;
        }
        let is_test_attr = (is_i(i + 2, "test") && is_p(i + 3, "]"))
            || (is_i(i + 2, "cfg")
                && is_p(i + 3, "(")
                && is_i(i + 4, "test")
                && is_p(i + 5, ")")
                && is_p(i + 6, "]"));
        if !is_test_attr {
            i += 1;
            continue;
        }
        // skip this and any stacked attributes (`#[should_panic(…)]`)
        let mut j = i;
        while is_p(j, "#") && is_p(j + 1, "[") {
            match lexer::match_delim(toks, j + 1) {
                Some(c) => j = c + 1,
                None => break,
            }
        }
        // the annotated item runs to its body's closing brace, or to
        // `;` for brace-less items (`#[cfg(test)] use …;`)
        let mut end = None;
        let mut k = j;
        while k < toks.len() {
            if is_p(k, ";") {
                end = Some(k);
                break;
            }
            if is_p(k, "{") {
                end = lexer::match_delim(toks, k);
                break;
            }
            k += 1;
        }
        match end {
            Some(e) => {
                spans.push((i, e));
                i = e + 1;
            }
            None => i += 1,
        }
    }
    spans
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_hit(rel: &str, src: &str) -> Vec<&'static str> {
        check_source(rel, src).into_iter().map(|d| d.rule).collect()
    }

    #[test]
    fn chained_partial_cmp_unwrap_is_flagged_anywhere() {
        let src = "fn f(xs: &mut [f64]) { xs.sort_by(|a, b| a.partial_cmp(b).unwrap()); }";
        assert_eq!(rules_hit("util/x.rs", src), vec![rules::FLOAT_ORD]);
        // …and only once: the comparator check defers to the chained one
    }

    #[test]
    fn comparator_without_total_order_is_flagged() {
        let src = "fn f(xs: &[f64]) -> Option<&f64> {\n    \
                   xs.iter().max_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal))\n\
                   }";
        assert_eq!(rules_hit("util/x.rs", src), vec![rules::FLOAT_ORD]);
    }

    #[test]
    fn total_cmp_comparators_pass() {
        let src = "fn f(xs: &mut [f64]) { xs.sort_by(|a, b| a.total_cmp(b)); }";
        assert!(rules_hit("util/x.rs", src).is_empty());
    }

    #[test]
    fn float_ord_applies_inside_tests_too() {
        let src = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        \
                   let mut v = vec![1.0f64];\n        \
                   v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n    }\n}";
        assert_eq!(rules_hit("searcher/x.rs", src), vec![rules::FLOAT_ORD]);
    }

    #[test]
    fn wire_casts_only_policed_under_comm() {
        let src = "fn f(x: u64) -> usize { x as usize }";
        assert_eq!(rules_hit("comm/x.rs", src), vec![rules::WIRE_INT_CAST]);
        assert!(rules_hit("util/x.rs", src).is_empty());
        // float casts stay legal on the wire (f32 bit patterns)
        let fsrc = "fn f(x: u32) -> f64 { x as f64 }";
        assert!(rules_hit("comm/x.rs", fsrc).is_empty());
    }

    #[test]
    fn panic_path_skips_test_modules() {
        let src = "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n\
                   #[cfg(test)]\nmod tests {\n    fn g(x: Option<u32>) -> u32 { x.unwrap() }\n}";
        assert_eq!(rules_hit("ps/x.rs", src), vec![rules::PANIC_PATH]);
        assert!(rules_hit("util/x.rs", src).is_empty());
    }

    #[test]
    fn lock_order_inversion_is_flagged() {
        let src = "fn f(s: &Server) -> usize {\n    \
                   let st = read_shard(&s.shards[0], &s.counters);\n    \
                   let ctl = lock_control(&s.control);\n    ctl.n + st.n\n}";
        assert_eq!(rules_hit("ps/x.rs", src), vec![rules::LOCK_ORDER]);
        // legal order passes
        let ok = "fn f(s: &Server) -> usize {\n    \
                  let ctl = lock_control(&s.control);\n    \
                  let st = read_shard(&s.shards[0], &s.counters);\n    ctl.n + st.n\n}";
        assert!(rules_hit("ps/x.rs", ok).is_empty());
    }

    #[test]
    fn temporary_shard_guard_dies_at_statement_end() {
        let src = "fn f(s: &Server) -> usize {\n    \
                   let n = read_shard(&s.shards[0], &s.counters).len();\n    \
                   lock_control(&s.control).m + n\n}";
        assert!(rules_hit("ps/x.rs", src).is_empty());
    }

    #[test]
    fn let_bound_guard_dies_at_block_end() {
        let src = "fn f(s: &Server) -> usize {\n    \
                   let d = { let st = write_shard(&s.shards[0], &s.counters); st.evict() };\n    \
                   lock_control(&s.control).m + d\n}";
        assert!(rules_hit("ps/x.rs", src).is_empty());
    }

    #[test]
    fn pragma_suppresses_on_own_and_next_line() {
        let above = "fn f(x: Option<u32>) -> u32 {\n    \
                     // lint:allow(panic-path): provably present\n    x.unwrap()\n}";
        assert!(rules_hit("ps/x.rs", above).is_empty());
        let trailing = "fn f(x: Option<u32>) -> u32 {\n    \
                        x.unwrap() // lint:allow(panic-path): provably present\n}";
        assert!(rules_hit("ps/x.rs", trailing).is_empty());
    }

    #[test]
    fn pragma_lists_multiple_rules() {
        let src = "fn f(a: f64, b: f64) -> std::cmp::Ordering {\n    \
                   // lint:allow(float-ord, panic-path): operands proven non-NaN\n    \
                   b.partial_cmp(&a).expect(\"non-NaN\")\n}";
        assert!(rules_hit("searcher/x.rs", src).is_empty());
    }

    #[test]
    fn pragma_without_reason_reports_and_does_not_suppress() {
        let src = "fn f(x: Option<u32>) -> u32 {\n    \
                   // lint:allow(panic-path)\n    x.unwrap()\n}";
        let hit = rules_hit("ps/x.rs", src);
        assert!(hit.contains(&PRAGMA_RULE));
        assert!(hit.contains(&rules::PANIC_PATH));
    }

    #[test]
    fn pragma_with_unknown_rule_reports() {
        let src = "fn f() {}\n// lint:allow(made-up): whatever\n";
        assert_eq!(rules_hit("ps/x.rs", src), vec![PRAGMA_RULE]);
    }

    #[test]
    fn pragma_for_wrong_rule_does_not_suppress() {
        let src = "fn f(x: Option<u32>) -> u32 {\n    \
                   // lint:allow(float-ord): wrong rule\n    x.unwrap()\n}";
        assert_eq!(rules_hit("ps/x.rs", src), vec![rules::PANIC_PATH]);
    }

    #[test]
    fn test_spans_cover_stacked_attributes() {
        let src = "#[test]\n#[should_panic(expected = \"boom\")]\n\
                   fn t() { Option::<u32>::None.unwrap(); }";
        assert!(rules_hit("ps/x.rs", src).is_empty());
    }

    #[test]
    fn cfg_not_test_is_not_a_test_span() {
        let src = "#[cfg(not(test))]\nfn f(x: Option<u32>) -> u32 { x.unwrap() }";
        assert_eq!(rules_hit("ps/x.rs", src), vec![rules::PANIC_PATH]);
    }

    #[test]
    fn diagnostics_carry_file_line_and_render() {
        let src = "fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}";
        let d = &check_source("tuner/x.rs", src)[0];
        assert_eq!((d.file.as_str(), d.line), ("tuner/x.rs", 2));
        assert!(d.to_string().starts_with("tuner/x.rs:2 [panic-path]"));
    }
}
